"""End-to-end filtered-RAG serving: the paper's motivating query shape
("similar to X but priced below $100") inside a serving loop.

  corpus docs (tokens + price/date attrs)
    -> LM embeddings -> CompassIndex
  request (prompt + predicate)
    -> SearchService (shape-bucketed continuous batching over CompassSearch)
    -> augmented prompt -> continuous-batching decode

Requests carry *mixed* predicate shapes (a pure conjunction and a
disjunction); the service buckets them by padded term count, so the whole
stream is served by exactly one compiled executable per occupied bucket.

  PYTHONPATH=src python examples/serve_filtered_rag.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import predicate as P
from repro.models.model import init_params
from repro.serving.rag import RagIndex, augment_prompt, embed_tokens
from repro.serving.scheduler import ContinuousBatcher, Request


def main():
    rng = np.random.default_rng(0)
    cfg = reduced(get_config("tinyllama-1.1b"))
    params = init_params(cfg, jax.random.PRNGKey(0))

    # toy product corpus: 64 docs, 16 tokens each, attrs = (price, freshness)
    n_docs, doc_len = 64, 16
    doc_tokens = rng.integers(0, cfg.vocab_size, (n_docs, doc_len)).astype(np.int32)
    doc_attrs = rng.uniform(size=(n_docs, 2)).astype(np.float32)
    rag = RagIndex.build(params, cfg, doc_tokens, doc_attrs)
    print(f"indexed {n_docs} docs (price, freshness attrs)")

    # mixed-shape request stream:
    #   even rids: price <= 0.3                      (conjunction, T=1)
    #   odd rids:  price <= 0.2 OR freshness >= 0.8  (disjunction,  T=2)
    preds = [
        P.Pred.le(0, 0.3),
        P.Pred.or_(P.Pred.le(0, 0.2), P.Pred.ge(1, 0.8)),
    ]
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32) for _ in range(6)]
    embs = np.asarray(embed_tokens(params, cfg, np.stack(prompts)))

    service = rag.make_service(k=2, ef=16, batch_size=4, max_wait_s=0.0)
    rids = [service.submit(embs[i], preds[i % 2], k=2) for i in range(len(prompts))]
    service.run_until_idle()
    results = [service.poll(rid) for rid in rids]
    doc_ids = np.stack([r.ids for r in results])
    stats = service.stats()
    print(
        f"served {stats['n_requests']} requests through "
        f"{stats['occupied_buckets']} shape buckets with {stats['compiles']} compiles"
    )

    # verify the filters held
    for b, ids in enumerate(doc_ids):
        for i in ids:
            if i < n_docs:
                price, fresh = doc_attrs[i]
                if b % 2 == 0:
                    assert price <= 0.3 + 1e-6, (i, doc_attrs[i])
                else:
                    assert price <= 0.2 + 1e-6 or fresh >= 0.8 - 1e-6, (i, doc_attrs[i])
    print("all retrieved docs satisfy their request's predicate")

    batcher = ContinuousBatcher(cfg, params, n_slots=3, max_seq=128)
    for rid, prompt in enumerate(prompts):
        full = augment_prompt(doc_tokens, doc_ids[rid], prompt)
        batcher.submit(Request(rid=rid, prompt=full, max_tokens=8))
    batcher.run_until_done()
    print(f"served {len(prompts)} augmented requests through the continuous batcher")


if __name__ == "__main__":
    main()
