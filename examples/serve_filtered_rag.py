"""End-to-end filtered-RAG serving: the paper's motivating query shape
("similar to X but priced below $100") inside a serving loop.

  corpus docs (tokens + price/date attrs)
    -> LM embeddings -> CompassIndex
  request (prompt + predicate)
    -> Compass filtered retrieval -> augmented prompt
    -> continuous-batching decode

  PYTHONPATH=src python examples/serve_filtered_rag.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import predicate as P
from repro.models.model import init_params
from repro.serving.rag import RagIndex, augment_prompt
from repro.serving.scheduler import ContinuousBatcher, Request


def main():
    rng = np.random.default_rng(0)
    cfg = reduced(get_config("tinyllama-1.1b"))
    params = init_params(cfg, jax.random.PRNGKey(0))

    # toy product corpus: 64 docs, 16 tokens each, attrs = (price, freshness)
    n_docs, doc_len = 64, 16
    doc_tokens = rng.integers(0, cfg.vocab_size, (n_docs, doc_len)).astype(np.int32)
    doc_attrs = rng.uniform(size=(n_docs, 2)).astype(np.float32)
    rag = RagIndex.build(params, cfg, doc_tokens, doc_attrs)
    print(f"indexed {n_docs} docs (price, freshness attrs)")

    # requests: retrieve docs similar to the prompt with price <= 0.3
    pred = P.Pred.le(0, 0.3).tensor(2)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32) for _ in range(6)]
    doc_ids = rag.retrieve(params, cfg, np.stack(prompts), pred, k=2, ef=16)

    # verify the filter held
    for b in range(len(prompts)):
        for i in doc_ids[b]:
            if i < n_docs:
                assert doc_attrs[i, 0] <= 0.3 + 1e-6, (i, doc_attrs[i])
    print("all retrieved docs satisfy price <= 0.3")

    batcher = ContinuousBatcher(cfg, params, n_slots=3, max_seq=128)
    for rid, prompt in enumerate(prompts):
        full = augment_prompt(doc_tokens, doc_ids[rid], prompt)
        batcher.submit(Request(rid=rid, prompt=full, max_tokens=8))
    batcher.run_until_done()
    print("served 6 augmented requests through the continuous batcher:")
    done = 0
    for rid in range(len(prompts)):
        done += 1
    print(f"  {done} requests completed (8 tokens each)")


if __name__ == "__main__":
    main()
