"""Multi-tenant serving walkthrough: many collections, one front door.

Two collections — a hot product catalog taking most of the traffic and a
cold document archive — live behind one :class:`CollectionService`.  The
walkthrough shows the three things the tenancy layer adds on top of a
plain ``SearchService``:

  1. **Fair scheduling with shared executables** — both collections fold
     into the same ShapePolicy row bucket, so the service compiles each
     ``(batch, predicate-shape)`` once *total*, not once per tenant, and
     the hot tenant's 4x weight buys it 4x the micro-batch share instead
     of a private engine.
  2. **A semantic result cache** — repeated (query, predicate, k)
     traffic is answered from the exact tier, bitwise-identical to a
     live search; an epoch swap (compaction) invalidates the owner only.
  3. **Typed load shedding** — when a collection's admission queue is at
     its configured depth, ``submit`` returns a :class:`Rejected` the
     caller can see and act on; nothing is silently dropped.

  PYTHONPATH=src python examples/multitenant.py
"""
import numpy as np

from repro.compass import (
    BuildConfig,
    CollectionService,
    CompassParams,
    MutableIndex,
    Pred,
    Rejected,
    ShapePolicy,
)


def build_collection(n, d, a, seed, shape):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    attrs = rng.uniform(size=(n, a)).astype(np.float32)
    return MutableIndex.build(
        x, attrs, BuildConfig(m=8, nlist=16), delta_cap=64, shape=shape
    )


def main():
    d, a = 16, 4
    shape = ShapePolicy(min_rows=1024, delta_cap=64)
    pm = CompassParams(k=5, ef=32, shape=shape)
    svc = CollectionService(pm, batch_size=4, max_wait_s=0.0)

    # -- 1. two collections, one scheduler, shared executables -------------
    # different corpus sizes (900 vs 600 rows) that bucket to the same
    # 1024-row fold: the compiled programs are interchangeable, so the
    # service compiles once and both tenants reuse it
    catalog = svc.create(
        "catalog", build_collection(900, d, a, 0, shape),
        weight=4.0, cache_capacity=64,
    )
    archive = svc.create(
        "archive", build_collection(600, d, a, 1, shape),
        weight=1.0, cache_capacity=64, max_queue_depth=4,
    )

    rng = np.random.default_rng(2)
    cheap = Pred.range(0, 0.1, 0.9)  # one-term predicate: the T=1 bucket
    hot_queries = [rng.normal(size=d).astype(np.float32) for _ in range(8)]
    rid_first = catalog.submit(hot_queries[0], cheap)
    for q in hot_queries[1:]:
        catalog.submit(q, cheap)
    archive.submit(rng.normal(size=d).astype(np.float32), cheap)
    svc.flush()
    print(f"compiles after serving both tenants: {svc.compile_count} "
          f"(shared — not one per collection)")

    # -- 2. the semantic result cache --------------------------------------
    # resubmit a query the catalog already answered during the flush
    # above: the exact tier serves it without touching the engine,
    # bitwise-identical to the uncached answer
    first = svc.poll(rid_first)
    rid_hit = catalog.submit(hot_queries[0], cheap)
    svc.flush()
    hit = svc.poll(rid_hit)
    assert hit.cache_tier == "exact"
    assert np.array_equal(hit.ids, first.ids)
    print(f"cache hit: tier={hit.cache_tier!r}, ids bitwise-equal to the "
          f"uncached answer {first.ids.tolist()}")

    # compaction swaps the catalog's epoch: its cache drops, the
    # archive's survives — invalidation is scoped to the owner
    catalog.compact()
    rid_after = catalog.submit(hot_queries[0], cheap)
    svc.flush()
    assert svc.poll(rid_after).cache_tier is None
    print("after catalog.compact(): same query misses (owner invalidated)")

    # -- 3. typed load shedding --------------------------------------------
    # the archive's queue depth is 4: a 10-request burst gets 4 queued
    # and 6 typed Rejected results the caller can retry or downgrade
    outcomes = [
        archive.submit(rng.normal(size=d).astype(np.float32), cheap)
        for _ in range(10)
    ]
    shed = [o for o in outcomes if isinstance(o, Rejected)]
    print(f"burst of 10 at depth 4: {10 - len(shed)} admitted, "
          f"{len(shed)} shed ({shed[0].reason!r}, limit {shed[0].limit})")
    svc.flush()

    # per-tenant accounting stays disjoint
    for name in svc.collections():
        st = svc.collection_stats(name)
        print(f"  {name}: submitted={st['n_submitted']} shed={st['n_shed']} "
              f"cache_served={st['n_cache_served']} "
              f"hit_rate={st['cache']['hit_rate']:.0%}")


if __name__ == "__main__":
    main()
