"""Quickstart: build a Compass index, run general filtered queries, compare
against exact brute force — then quantize it (PQ codes + two-stage
ADC-then-rerank search, core/quant) and mutate it: upsert/search/compact
round-trip through the mutable-index subsystem (core/mutable).

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import predicate as P
from repro.core.baselines import brute_force, recall
from repro.core.index import BuildConfig, build_index
from repro.core.mutable import MutableIndex
from repro.core.quant import QuantConfig, QuantParams, quantize_index
from repro.compass import CompassParams, compass_search
from repro.data.synthetic import make_vector_corpus


def main():
    n, d, a = 20000, 32, 4
    print(f"corpus: {n} vectors x {d} dims with {a} numeric attributes")
    x, attrs, queries = make_vector_corpus(n, d, a, n_modes=64, seed=0)
    queries = queries[:16]

    t0 = time.time()
    index = build_index(x, attrs, BuildConfig(m=16, nlist=64))
    print(f"index built in {time.time()-t0:.1f}s "
          f"(graph + IVF + clustered per-attribute sorted runs)")

    # "similar to q, priced in [0.2, 0.5] AND newer than 0.7"  (conjunction)
    conj = P.Pred.and_(P.Pred.range(0, 0.2, 0.5), P.Pred.ge(1, 0.7))
    # "... OR flagged in category band [0.9, 1.0]"              (disjunction)
    tree = P.Pred.or_(conj, P.Pred.range(2, 0.9, 1.0))
    pred = P.stack_predicates([tree.tensor(a)] * len(queries))

    qj = jnp.asarray(queries)
    truth = brute_force(jnp.asarray(x), jnp.asarray(attrs), qj, pred, 10)
    t0 = time.time()
    res = compass_search(index, qj, pred, CompassParams(k=10, ef=96))
    res.ids.block_until_ready()
    dt = time.time() - t0
    r = recall(np.asarray(res.ids), np.asarray(truth.ids), np.asarray(truth.dists), n)
    nd = float(np.asarray(res.stats.n_dist).mean())
    print(f"compass: recall@10={r:.3f}  #Comp={nd:.0f}/query "
          f"({100*nd/n:.2f}% of corpus)  wall={dt:.2f}s (incl. compile)")
    print("top-1 ids:", np.asarray(res.ids)[:8, 0].tolist())
    assert r > 0.85

    # -- quantize: attach a PQ tier, search through ADC + exact rerank ------
    # (8 uint8 codes per row instead of d float32s; stage one scores
    # candidates from per-query lookup tables at ef*refine_factor, stage
    # two reranks the survivors against the float32 rows)
    qindex = quantize_index(index, QuantConfig(m=8), "l2")
    bpv = qindex.qvecs.bytes_per_vector
    print(f"quantized: {bpv:.1f} bytes/vector vs {4 * d} full precision "
          f"({4 * d / bpv:.1f}x compression)")
    pmq = CompassParams(k=10, ef=96, quant=QuantParams(refine_factor=4))
    resq = compass_search(qindex, qj, pred, pmq)
    rq = recall(np.asarray(resq.ids), np.asarray(truth.ids), np.asarray(truth.dists), n)
    r_vs_exact = recall(
        np.asarray(resq.ids), np.asarray(res.ids), np.asarray(res.dists), n
    )
    na = float(np.asarray(resq.stats.n_adc).mean())
    nr = float(np.asarray(resq.stats.n_rerank).mean())
    print(f"quantized search: recall@10={rq:.3f} (vs exact index: {r_vs_exact:.3f})  "
          f"#ADC={na:.0f} #rerank={nr:.0f}/query")
    assert r_vs_exact >= 0.95, "rerank contract: quantized top-k ~ exact top-k"

    # -- writes: wrap the quantized index in the mutable subsystem ----------
    # (delta segment + tombstones; delta rows are encoded against the
    # frozen codebooks so base+delta share one ADC scan, search fans out
    # over both tiers and results are global ids, stable across
    # compactions)
    mut = MutableIndex(qindex, delta_cap=128)
    pm = CompassParams(k=10, ef=96)
    q0 = queries[:1]
    hit_id = 10_000_000  # fresh id, vector right at the query, passing attrs
    mut.upsert(hit_id, q0[0], np.float32([0.3, 0.9, 0.95, 0.5]))
    res2 = mut.search(jnp.asarray(q0), P.stack_predicates([tree.tensor(a)]), pmq)
    ids2 = np.asarray(res2.ids)[0]
    print(f"after upsert: top-1 id={ids2[0]} (expected {hit_id}, epoch {mut.epoch})")
    assert ids2[0] == hit_id
    mut.compact()  # folds the delta; re-encodes it against the frozen codebooks
    assert mut.base.qvecs is not None
    print(f"after compact: epoch {mut.epoch}, decode-MSE drift "
          f"{mut.quant_drift_log[-1]:.4f} (train {float(mut.base.qvecs.train_mse):.4f})")
    res2b = mut.search(jnp.asarray(q0), P.stack_predicates([tree.tensor(a)]), pmq)
    assert np.asarray(res2b.ids)[0][0] == hit_id
    mut.delete(hit_id)
    res3 = mut.search(jnp.asarray(q0), P.stack_predicates([tree.tensor(a)]), pm)
    assert hit_id not in np.asarray(res3.ids)[0]
    print("after delete: id gone; quantize -> upsert -> search -> compact "
          "-> delete round-trip OK")


if __name__ == "__main__":
    main()
