"""Quickstart: build a Compass index, run general filtered queries, compare
against exact brute force — then mutate it: upsert/delete/search round-trip
through the mutable-index subsystem (core/mutable).

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import predicate as P
from repro.core.baselines import brute_force, recall
from repro.core.index import BuildConfig, build_index
from repro.core.mutable import MutableIndex
from repro.core.search import CompassParams, compass_search
from repro.data.synthetic import make_vector_corpus


def main():
    n, d, a = 20000, 32, 4
    print(f"corpus: {n} vectors x {d} dims with {a} numeric attributes")
    x, attrs, queries = make_vector_corpus(n, d, a, n_modes=64, seed=0)
    queries = queries[:16]

    t0 = time.time()
    index = build_index(x, attrs, BuildConfig(m=16, nlist=64))
    print(f"index built in {time.time()-t0:.1f}s "
          f"(graph + IVF + clustered per-attribute sorted runs)")

    # "similar to q, priced in [0.2, 0.5] AND newer than 0.7"  (conjunction)
    conj = P.Pred.and_(P.Pred.range(0, 0.2, 0.5), P.Pred.ge(1, 0.7))
    # "... OR flagged in category band [0.9, 1.0]"              (disjunction)
    tree = P.Pred.or_(conj, P.Pred.range(2, 0.9, 1.0))
    pred = P.stack_predicates([tree.tensor(a)] * len(queries))

    qj = jnp.asarray(queries)
    truth = brute_force(jnp.asarray(x), jnp.asarray(attrs), qj, pred, 10)
    t0 = time.time()
    res = compass_search(index, qj, pred, CompassParams(k=10, ef=96))
    res.ids.block_until_ready()
    dt = time.time() - t0
    r = recall(np.asarray(res.ids), np.asarray(truth.ids), np.asarray(truth.dists), n)
    nd = float(np.asarray(res.stats.n_dist).mean())
    print(f"compass: recall@10={r:.3f}  #Comp={nd:.0f}/query "
          f"({100*nd/n:.2f}% of corpus)  wall={dt:.2f}s (incl. compile)")
    print("top-1 ids:", np.asarray(res.ids)[:8, 0].tolist())
    assert r > 0.85

    # -- writes: wrap the same index in the mutable subsystem ---------------
    # (delta segment + tombstones; search fans out over base+delta and
    # results are global ids, stable across compactions)
    mut = MutableIndex(index, delta_cap=128)
    pm = CompassParams(k=10, ef=96)
    q0 = queries[:1]
    hit_id = 10_000_000  # fresh id, vector right at the query, passing attrs
    mut.upsert(hit_id, q0[0], np.float32([0.3, 0.9, 0.95, 0.5]))
    res2 = mut.search(jnp.asarray(q0), P.stack_predicates([tree.tensor(a)]), pm)
    ids2 = np.asarray(res2.ids)[0]
    print(f"after upsert: top-1 id={ids2[0]} (expected {hit_id}, epoch {mut.epoch})")
    assert ids2[0] == hit_id
    mut.delete(hit_id)
    res3 = mut.search(jnp.asarray(q0), P.stack_predicates([tree.tensor(a)]), pm)
    assert hit_id not in np.asarray(res3.ids)[0]
    print("after delete: id gone; upsert -> search -> delete round-trip OK")


if __name__ == "__main__":
    main()
