"""Observability walkthrough: explain traces, the metrics registry, and
the event log — the quickstart's ad-hoc ``res.stats`` prints, redone
through ``repro.obs``.

Where quickstart.py reads raw counter arrays off one result
(``res.stats.n_dist.mean()``), this example asks the engine to explain
itself: ``compass_search(..., explain=True)`` returns one
:class:`QueryTrace` per query (planner estimate vs. measured selectivity,
chosen mode, kernel route, work counters), the process-global metrics
registry accumulates the same counters across *every* search for
Prometheus/JSON export, and the event log records index lifecycle
(compactions, epoch swaps) as structured JSONL.

Section 4 turns the counters into *continuous* monitoring: a
:class:`~repro.serving.SearchService` with a Monitor attached snapshots
the registry every scheduling round, evaluates SLO burn rates and drift
watchdogs, and answers ``service.health()`` with graded checks and
remediations; ``python -m repro.obs.report`` renders the same data as a
text dashboard.

Everything here is opt-in and bitwise-free: with ``REPRO_OBS`` unset and
no ``explain=True``, none of this code runs and results are unchanged.

  PYTHONPATH=src python examples/observability.py
"""
import numpy as np
import jax.numpy as jnp

from repro.compass import CompassParams, compass_search, explain
from repro.core import predicate as P
from repro.core.index import BuildConfig, build_index
from repro.core.mutable import MutableIndex
from repro.data.synthetic import make_vector_corpus
from repro.obs import EVENTS, registry as obs_registry
from repro.obs.registry import registry, set_enabled


def main():
    n, d, a = 20000, 32, 4
    x, attrs, queries = make_vector_corpus(n, d, a, n_modes=64, seed=0)
    queries = queries[:8]
    index = build_index(x, attrs, BuildConfig(m=16, nlist=64))

    # -- 1. explain traces: per-query "what did the planner do and why" ----
    # a selective conjunction next to a near-vacuous filter: the traces
    # show the planner routing them differently, and why (estimate, run
    # budget)
    selective = P.Pred.and_(P.Pred.range(0, 0.2, 0.25), P.Pred.ge(1, 0.9))
    vacuous = P.Pred.range(0, 0.0, 1.0)
    pred = P.stack_predicates(
        [selective.tensor(a)] * 4 + [vacuous.tensor(a)] * 4
    )
    pm = CompassParams(k=10, ef=96, planner=True)
    res, traces = compass_search(index, jnp.asarray(queries), pred, pm, explain=True)
    print("== explain: selective conjunction vs. vacuous filter ==")
    print(explain(traces[0]))  # one trace ...
    print(explain(traces[4]))
    modes = [t.mode for t in traces]
    print(f"modes across the batch: {modes}")
    # the planner's estimate vs. what the search measured, side by side
    for t in traces[:1] + traces[4:5]:
        print(
            f"  query[{t.query}]: est_selectivity={t.est_selectivity:.3f} "
            f"actual={t.actual_selectivity:.3f} route={t.kernel_route}"
        )

    # -- 2. the metrics registry: fleet-level accumulation ------------------
    # (quickstart printed res.stats.n_dist.mean() for ONE result; the
    # registry folds every recorded search into process-global counters)
    prev = set_enabled(True)  # or REPRO_OBS=1 in the environment
    try:
        obs_registry.record_search_stats(res.stats)  # fold the batch above
        res2 = compass_search(index, jnp.asarray(queries), pred, pm)
        obs_registry.record_search_stats(res2.stats)
        reg = registry()
        q_total = reg.get("compass_queries_total")
        d_total = reg.get("compass_dist_total")
        print("\n== registry: counters across both searches ==")
        print(f"queries folded: {q_total.value(bucket='', shard=''):.0f}")
        nd = d_total.value(bucket="", shard="")
        nq = q_total.value(bucket="", shard="")
        print(f"distance computations: {nd:.0f} ({nd / nq:.0f}/query, "
              f"{100 * nd / nq / n:.2f}% of corpus)")
        print("\nPrometheus exposition (first lines):")
        print("\n".join(reg.to_prometheus().splitlines()[:6]))

        # -- 3. the event log: index lifecycle as structured records --------
        mut = MutableIndex(index, delta_cap=64)
        rng = np.random.default_rng(1)
        for i in range(80):  # overflow the delta -> auto-compaction
            mut.upsert(n + i, rng.normal(size=d).astype(np.float32),
                       rng.uniform(size=a).astype(np.float32))
        print("\n== events: what the mutable index did ==")
        print(f"counts: {EVENTS.counts()}")
        for e in EVENTS.tail(2, kind="compaction"):
            print(f"  compaction: epoch={e['epoch']} rows={e['n_rows']} "
                  f"wall={e['wall_s']:.2f}s")
        # EVENTS.configure("events.jsonl") would mirror these to disk

        # -- 4. continuous monitoring: health, SLOs, the report CLI ---------
        # a served index with a Monitor attached: every step() snapshots
        # the registry into a time-series ring and runs SLO burn-rate +
        # watchdog evaluation (all host-side; bitwise-free like the rest)
        from repro.compass import SearchService
        from repro.obs import report as obs_report

        svc = SearchService(mut, CompassParams(k=10, ef=64), batch_size=8,
                            max_wait_s=0.0)
        svc.enable_monitoring(interval_s=0.0)  # snapshot every round
        for _ in range(4):  # several scheduling rounds -> several snapshots
            for q in queries:
                svc.submit(q, vacuous)
            svc.run_until_idle()
        rep = svc.health()  # graded checks + remediations, on demand
        print("\n== health: SLO burn + drift watchdogs ==")
        # expect the serve-latency SLO to burn here: the first round pays
        # XLA compilation inside exec wall time, far past the 250ms
        # objective — exactly the kind of incident the monitor exists to
        # surface (a warmed steady-state service recovers to ok)
        print(f"overall: {rep.status}")
        for c in rep.checks:
            line = f"  [{c.status:>4}] {c.name}: {c.detail}"
            if c.status != "ok" and c.remediation:
                line += f"  -> {c.remediation}"
            print(line)
        # the same report renders through the CLI dashboard
        # (``python -m repro.obs.report --from METRICS.json`` for files)
        print("\n== report: windowed rates/quantiles from the ring ==")
        ring = svc.monitor.ring
        qps = ring.rate("compass_serve_requests_total", window_s=60.0)
        p50 = ring.quantile("compass_serve_exec_seconds", 0.5, window_s=60.0)
        print(f"windowed QPS: {0.0 if qps is None else qps:.0f}  "
              f"p50 exec: {0.0 if p50 is None else p50 * 1e3:.1f}ms")
        print(obs_report.render_health(rep))
    finally:
        set_enabled(prev)


if __name__ == "__main__":
    main()
