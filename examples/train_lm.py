"""End-to-end training driver: ~100M-parameter llama-family model, a few
hundred steps on synthetic structured data, with checkpoint/restart and the
straggler watchdog active.  Loss must decrease.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M-param config of the tinyllama family
    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b"),
        name="tinyllama-100m",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=4,
        d_ff=1536, vocab_size=8192,
    )
    n_params = cfg.param_count()
    print(f"training {cfg.name}: ~{n_params/1e6:.0f}M params, {args.steps} steps")
    _, losses = train_loop(
        cfg,
        steps=args.steps,
        global_batch=8,
        seq_len=256,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
    )
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
