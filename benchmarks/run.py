"""Benchmark driver — one module per paper table/figure.

  bench_index_size    -> Table IV
  bench_conjunctions  -> Figs. 4/5 + Table V (top)
  bench_disjunctions  -> Figs. 6/7 + Table V (bottom)
  bench_qps_recall    -> Figs. 8-10
  bench_ablation      -> Fig. 11

``python -m benchmarks.run [--only name] [--quick]``
"""
from __future__ import annotations

import argparse
import time

ALL = (
    "bench_index_size",
    "bench_conjunctions",
    "bench_disjunctions",
    "bench_qps_recall",
    "bench_ablation",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true", help="shrink corpus for CI")
    args = ap.parse_args()
    if args.quick:
        import os

        os.environ.setdefault("REPRO_BENCH_N", "20000")
        os.environ.setdefault("REPRO_BENCH_Q", "32")
    names = [args.only] if args.only else list(ALL)
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        print(f"==== {name} ====", flush=True)
        mod.run()
        print(f"==== {name} done in {time.time()-t0:.0f}s ====", flush=True)


if __name__ == "__main__":
    main()
