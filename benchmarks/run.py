"""Benchmark driver — one module per paper table/figure.

  bench_index_size    -> Table IV
  bench_conjunctions  -> Figs. 4/5 + Table V (top)
  bench_disjunctions  -> Figs. 6/7 + Table V (bottom)
  bench_qps_recall    -> Figs. 8-10
  bench_ablation      -> Fig. 11
  bench_serving       -> serving-layer QPS/latency/compile counts (ours)
  bench_planner       -> planner selectivity sweep: mode/QPS/recall (ours)
  bench_updates       -> mutable-index churn: QPS/recall/compaction (ours)
  bench_quant         -> PQ tier: recall/QPS/bytes-per-vector sweep (ours)
  bench_kernels       -> fused-visit / pq / ivf kernel microbench (ours)
  bench_obs           -> observability overhead: obs-on vs obs-off QPS (ours)
  bench_tenancy       -> multi-tenant zipfian workload: per-tenant p50/p99,
                         cache hit rates, shared-executable compiles (ours)

``python -m benchmarks.run [--only name] [--quick] [--json-dir DIR]``

Each module's rows are also written to ``BENCH_<name>.json`` next to this
file (or under ``--json-dir``), wrapped with a provenance block (engine
version, scoring backend, platform, corpus scale — see
``common.bench_metadata``) so benchmark trajectories across PRs are
attributable to the code that produced them.

The driver additionally exports the process-global metrics registry as
``METRICS.json`` (schema ``repro.obs.metrics/v1``; empty-but-valid when
``REPRO_OBS`` is off), a per-bench snapshot timeline as
``TIMESERIES.json`` (schema ``repro.obs.timeseries/v1``: one registry
snapshot before the first bench and after each one, so windowed
rates/quantiles per bench phase are derivable offline), and — unless
``--history ''`` disables it — appends one schema-validated summary row
(``repro.bench.history/v1``: wall time + every extracted QPS label per
bench) to ``BENCH_HISTORY.jsonl``.  The per-run BENCH JSONs are
gitignored; the history file is the committable perf trajectory, and
``benchmarks/compare.py --history`` diffs its latest row against the
committed smoke baselines.  When ``REPRO_OBS_PROFILE`` is set, the whole
run is wrapped in a ``jax.profiler`` capture whose XPlane/perfetto
artifacts land in the named directory.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time


ALL = (
    "bench_index_size",
    "bench_conjunctions",
    "bench_disjunctions",
    "bench_qps_recall",
    "bench_ablation",
    "bench_serving",
    "bench_planner",
    "bench_updates",
    "bench_quant",
    "bench_kernels",
    "bench_obs",
    "bench_tenancy",
)


def write_metrics_json(json_dir: str) -> str:
    """Export the global metrics registry next to the BENCH artifacts.

    Always written: a run with obs disabled exports an empty-but-valid
    payload, so the CI schema gate (``python -m repro.obs.validate``) can
    run unconditionally.
    """
    from repro.obs import registry as obs_reg

    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, "METRICS.json")
    with open(path, "w") as f:
        json.dump(obs_reg.registry().to_json(), f, indent=1)
    return path


def write_timeseries_json(ring, json_dir: str) -> str:
    """Export the run's snapshot ring next to METRICS.json.  Like the
    metrics export this is unconditional: with obs off the snapshots are
    empty and the payload is empty-but-valid, so the CI schema gate runs
    either way."""
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, "TIMESERIES.json")
    with open(path, "w") as f:
        json.dump(ring.to_json(), f, indent=1)
    return path


def append_history(payloads: dict, history_path: str) -> str:
    """Append one ``repro.bench.history/v1`` row summarizing this run.

    The row carries the provenance block plus, per bench, the wall time
    and every QPS figure ``compare.extract_qps`` can see — the same
    labels the baseline diff uses, so history rows and committed
    baselines stay directly comparable.  Validated before the append: a
    malformed row raises instead of poisoning the trajectory.
    """
    from . import common as C
    from . import compare as cmp
    from . import validate as V

    row = {
        "schema": V.HISTORY_SCHEMA,
        "ts": time.time(),
        "meta": C.bench_metadata(),
        "benches": {
            name: {"wall_s": p["wall_s"], "qps": cmp.extract_qps(p)}
            for name, p in payloads.items()
        },
    }
    errs = V.validate_history_row(row)
    if errs:
        raise ValueError(f"refusing to append invalid history row: {errs[0]}")
    d = os.path.dirname(os.path.abspath(history_path))
    os.makedirs(d, exist_ok=True)
    with open(history_path, "a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")
    return history_path


def _jsonable(obj):
    """Benchmark rows are nested tuples/dicts of RunResults and numpy
    scalars; lower them to plain JSON types."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return _jsonable(dataclasses.asdict(obj))
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "tolist"):  # numpy / jax array or scalar
        return _jsonable(obj.tolist())
    if hasattr(obj, "item"):  # other 0-d scalar wrappers
        return obj.item()
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def write_json(name: str, rows, wall_s: float, json_dir: str) -> tuple[str, dict]:
    from . import common as C

    payload = {
        "bench": name,
        "meta": C.bench_metadata(),
        "wall_s": wall_s,
        "rows": _jsonable(rows),
    }
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"BENCH_{name.removeprefix('bench_')}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path, payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true", help="shrink corpus for CI")
    ap.add_argument(
        "--json-dir", default=os.path.dirname(os.path.abspath(__file__)),
        help="where BENCH_<name>.json files land",
    )
    ap.add_argument(
        "--history",
        default=os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "BENCH_HISTORY.jsonl"),
        help="perf-trajectory JSONL to append this run's summary row to "
        "('' disables)",
    )
    args = ap.parse_args()
    if args.quick:
        os.environ.setdefault("REPRO_BENCH_N", "20000")
        os.environ.setdefault("REPRO_BENCH_Q", "32")
    names = [args.only] if args.only else list(ALL)
    from repro.obs import profiling as obs_prof
    from repro.obs import timeseries as obs_ts

    # one registry snapshot before the first bench and after each one, so
    # TIMESERIES.json holds a per-bench-phase timeline of every series the
    # run recorded (empty snapshots with obs off)
    snapper = obs_ts.Snapshotter(capacity=len(names) + 1, interval_s=0.0)
    snapper.maybe_snapshot()
    payloads: dict[str, dict] = {}
    with obs_prof.profile_capture() as prof_dir:  # no-op without REPRO_OBS_PROFILE
        for name in names:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            t0 = time.time()
            print(f"==== {name} ====", flush=True)
            rows = mod.run()
            wall = time.time() - t0
            path, payloads[name] = write_json(name, rows, wall, args.json_dir)
            snapper.maybe_snapshot()
            print(f"==== {name} done in {wall:.0f}s -> {path} ====", flush=True)
    mpath = write_metrics_json(args.json_dir)
    print(f"==== metrics registry -> {mpath} ====", flush=True)
    tpath = write_timeseries_json(snapper.ring, args.json_dir)
    print(f"==== snapshot timeline -> {tpath} ====", flush=True)
    if args.history:
        hpath = append_history(payloads, args.history)
        print(f"==== history row -> {hpath} ====", flush=True)
    if prof_dir:
        print(f"==== profiler capture -> {prof_dir} ====", flush=True)


if __name__ == "__main__":
    main()
