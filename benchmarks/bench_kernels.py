"""Kernel microbenchmarks: the per-visit-step hot path in isolation.

Three experiments, all timed as steady-state jitted programs (untimed
warmup compiles both arms equally):

  * **visit_step** — the fused gather + distance + DNF predicate +
    tombstone + admission kernel (``kernels/visit_step.py``) against the
    unfused composition it replaced (``filter_distance`` kernel + jnp
    live gather + admission select), over a (d, V) sweep for both "l2"
    and "ip".  This is the engine's per-step hot spot: the fused kernel
    saves one full gather of the visit rows plus two intermediate
    materializations per step.
  * **pq_score** — the ADC kernel over an m sweep (subspace count is the
    bytes-moved knob), pallas vs the jnp ref path.  The adc/exact row
    cost ratio behind the planner's ``COST_ADC_ROW`` constant.
  * **ivf_score** — the blocked centroid-ranking matmul at two nlist
    shapes, pallas vs ref.

On CPU the pallas arms execute in interpret mode, so absolute QPS and
even fused-vs-unfused ordering are *advisory* there (the interpreter
pays per-ref-access Python overhead the Mosaic lowering doesn't); the
compiled-TPU path is where the fused kernel must win at every (d, V).
The committed baseline records the CPU-interpret numbers to keep the
trajectory attributable; ``meta.backend``/``platform`` say which regime
a given artifact measured.

The final row snapshots the autotuner's measured block table
(``kernels/autotune.snapshot``) so an artifact records *which* block
configs produced its numbers.

``python -m benchmarks.bench_kernels --selfcheck`` runs the fallback
tripwire only: it fails (SystemExit) if the engine's pallas backend
stops routing VISIT through the fused kernel — the regression CI must
catch loudly, because the ref fallback is silent by design.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune, ops

D_SWEEP = (16, 48)
V_SWEEP = (64, 256)
M_SWEEP = (4, 8, 16)
NLIST_SWEEP = (64, 256)
METRICS = ("l2", "ip")
N_ROWS = 4096
B = 16
N_ATTRS = 4
N_TERMS = 2
REPS = 3


def _mk_problem(rng, d: int, v: int):
    """Corpus rows + a per-query visit batch shaped like the engine's."""
    n = N_ROWS
    vecs = np.concatenate(
        [rng.normal(size=(n, d)).astype(np.float32), np.zeros((1, d), np.float32)]
    )
    attrs = np.concatenate(
        [
            rng.uniform(size=(n, N_ATTRS)).astype(np.float32),
            np.full((1, N_ATTRS), np.inf, np.float32),
        ]
    )
    live = np.ones(n + 1, bool)
    live[rng.integers(0, n, size=n // 10)] = False
    idx = rng.integers(0, n, size=(B, v)).astype(np.int32)
    mask = np.ones((B, v), bool)
    q = rng.normal(size=(B, d)).astype(np.float32)
    lo = np.full((N_TERMS, N_ATTRS), -np.inf, np.float32)
    hi = np.full((N_TERMS, N_ATTRS), np.inf, np.float32)
    lo[0, 0], hi[0, 0] = 0.2, 0.8
    return tuple(jnp.asarray(a) for a in (vecs, attrs, live, idx, mask, q, lo, hi))


def _time_fn(fn, *args, reps: int = REPS) -> float:
    """Steady-state seconds per call (min over reps after a warmup)."""
    jax.block_until_ready(fn(*args))
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _arm(method: str, wall: float) -> dict:
    return {"method": method, "qps": B / wall if wall else 0.0, "wall_s": wall}


def _visit_bench(rng, d: int, v: int, metric: str) -> dict:
    vecs, attrs, live, idx, mask, q, lo, hi = _mk_problem(rng, d, v)

    @jax.jit
    def fused(qs, ids):
        return jax.vmap(
            lambda q1, i1, m1: ops.visit_step(
                vecs, attrs, live, i1, m1, q1, lo, hi, metric=metric
            )
        )(qs, ids, mask)

    @jax.jit
    def unfused(qs, ids):
        # the pre-fusion engine sequence: filter_distance kernel, then the
        # jnp tombstone gather, then the admission select
        def one(q1, i1, m1):
            dist, passing = ops.filter_distance(
                vecs, attrs, i1, m1, q1, lo, hi, metric=metric
            )
            passing = passing & m1 & live[i1]
            return dist, jnp.where(passing, dist, jnp.inf)

        return jax.vmap(one)(qs, ids, mask)

    row = {
        "kernel": "visit_step",
        "metric": metric,
        "d": d,
        "v": v,
        "fused": _arm("fused_visit", _time_fn(fused, q, idx)),
        "unfused": _arm("unfused_visit", _time_fn(unfused, q, idx)),
    }
    row["fused_speedup"] = row["fused"]["qps"] / max(row["unfused"]["qps"], 1e-9)
    return row


def _pq_bench(rng, m: int, metric: str, v: int = 256, ks: int = 16) -> dict:
    d = m * 4  # dsub = 4
    vecs, attrs, live, idx, mask, q, lo, hi = _mk_problem(rng, d, v)
    codes = jnp.asarray(
        np.concatenate(
            [
                rng.integers(0, ks, size=(N_ROWS, m)).astype(np.uint8),
                np.zeros((1, m), np.uint8),
            ]
        )
    )
    codebooks = jnp.asarray(rng.normal(size=(m, ks, 4)).astype(np.float32))

    def make(use_pallas):
        @jax.jit
        def f(qs, ids):
            return jax.vmap(
                lambda q1, i1, m1: ops.pq_score(
                    codes, attrs, i1, m1, q1, codebooks, lo, hi,
                    metric=metric, use_pallas=use_pallas,
                )
            )(qs, ids, mask)

        return f

    return {
        "kernel": "pq_score",
        "metric": metric,
        "d": d,
        "v": v,
        "m": m,
        "pallas": _arm("pq_pallas", _time_fn(make(True), q, idx)),
        "ref": _arm("pq_ref", _time_fn(make(False), q, idx)),
    }


def _ivf_bench(rng, nlist: int, metric: str, d: int = 48) -> dict:
    qs = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    cents = jnp.asarray(rng.normal(size=(nlist, d)).astype(np.float32))
    pal = jax.jit(lambda a, b: ops.ivf_score(a, b, metric=metric))
    ref = jax.jit(lambda a, b: ops.ivf_score(a, b, metric=metric, use_pallas=False))
    return {
        "kernel": "ivf_score",
        "metric": metric,
        "d": d,
        "v": nlist,
        "pallas": _arm("ivf_pallas", _time_fn(pal, qs, cents)),
        "ref": _arm("ivf_ref", _time_fn(ref, qs, cents)),
    }


def selfcheck() -> None:
    """Tripwire: the engine's pallas backend must reach the fused kernel.

    ``visit_step.TRACE_COUNT`` advances every time the kernel *wrapper* is
    traced; a refactor that reroutes PallasBackend.visit_step to the ref
    composition (or a guard that starts rejecting "l2") would leave it
    flat — silently, because the fallback is behavioral parity by design.
    Exercised at both the ops layer and through a full compass_search.
    """
    from repro.core import predicate as P
    from repro.core.engine.backend import PallasBackend
    from repro.core.index import BuildConfig, build_index
    from repro.compass import CompassParams, compass_search
    import repro.kernels.visit_step as vs

    rng = np.random.default_rng(0)
    vecs, attrs, live, idx, mask, q, lo, hi = _mk_problem(rng, 16, 32)

    before = vs.TRACE_COUNT
    jax.block_until_ready(
        jax.jit(
            lambda: ops.visit_step(
                vecs, attrs, live, idx[0], mask[0], q[0], lo, hi, metric="l2"
            )
        )()
    )
    if vs.TRACE_COUNT <= before:
        raise SystemExit("selfcheck FAIL: ops.visit_step did not trace the fused kernel")

    n, d, a = 500, 8, 2
    x = rng.normal(size=(n, d)).astype(np.float32)
    at = rng.uniform(size=(n, a)).astype(np.float32)
    index = build_index(x, at, BuildConfig(m=8, nlist=8))
    plo = np.full((2, 1, a), -np.inf, np.float32)
    phi = np.full((2, 1, a), np.inf, np.float32)
    plo[:, 0, 0] = 0.2
    pred = P.Predicate(jnp.asarray(plo), jnp.asarray(phi))
    queries = jnp.asarray(rng.normal(size=(2, d)).astype(np.float32))

    before = vs.TRACE_COUNT
    res = compass_search(index, queries, pred, CompassParams(backend="pallas"))
    jax.block_until_ready(res.ids)
    if vs.TRACE_COUNT <= before:
        raise SystemExit(
            "selfcheck FAIL: compass_search(backend='pallas') never traced the "
            "fused visit_step kernel — VISIT is silently on the ref/unfused path"
        )
    assert isinstance(PallasBackend().visit_step, object)  # surface still exists
    print(f"selfcheck ok: fused visit_step traced (TRACE_COUNT={vs.TRACE_COUNT})")


def run(out=print):
    rng = np.random.default_rng(13)
    out(f"# kernel microbench n={N_ROWS} b={B} reps={REPS}")
    rows = []
    out("kernel,metric,d,v,extra,arm_a_qps,arm_b_qps")
    for metric in METRICS:
        for d in D_SWEEP:
            for v in V_SWEEP:
                row = _visit_bench(rng, d, v, metric)
                rows.append(row)
                out(
                    f"visit_step,{metric},{d},{v},speedup={row['fused_speedup']:.2f},"
                    f"{row['fused']['qps']:.1f},{row['unfused']['qps']:.1f}"
                )
    for metric in METRICS:
        for m in M_SWEEP:
            row = _pq_bench(rng, m, metric)
            rows.append(row)
            out(
                f"pq_score,{metric},{row['d']},{row['v']},m={m},"
                f"{row['pallas']['qps']:.1f},{row['ref']['qps']:.1f}"
            )
    for metric in METRICS:
        for nlist in NLIST_SWEEP:
            row = _ivf_bench(rng, nlist, metric)
            rows.append(row)
            out(
                f"ivf_score,{metric},{row['d']},{nlist},-,"
                f"{row['pallas']['qps']:.1f},{row['ref']['qps']:.1f}"
            )
    # provenance: which block configs the autotuner measured/selected for
    # the numbers above (empty when pinned or measurement-disabled)
    rows.append(
        {"kernel": "autotune_table", "metric": "-", "d": 0, "v": 0,
         "table": autotune.snapshot()}
    )
    return rows


def main():
    if "--selfcheck" in sys.argv[1:]:
        selfcheck()
        return
    run()


if __name__ == "__main__":
    main()
