"""Shared benchmark infrastructure: datasets, workloads, method runners.

Scale note: the paper's corpora are ~1-2M vectors x 100-1024 dims on a Xeon
with SIMD; this container is a single CPU core running batched JAX, so the
default benchmark corpus is 60k x 48d with the same *structure* (clustered
modes + 4 uniform attributes, paper §V.A).  All comparisons are relative
and the primary hardware-independent metric is #Comp (vector distance
computations), exactly as the paper argues.  Set REPRO_BENCH_N/REPRO_BENCH_D
to rescale.

Indices are built once and cached on disk (benchmarks/.cache).
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predicate as P
from repro.core.baselines import brute_force, navix_search, postfilter_search, prefilter_search, recall
from repro.core.index import BuildConfig, build_index
from repro.compass import CompassParams, compass_search
from repro.data.synthetic import make_vector_corpus

CACHE = os.path.join(os.path.dirname(__file__), ".cache")
N = int(os.environ.get("REPRO_BENCH_N", 60000))
D = int(os.environ.get("REPRO_BENCH_D", 48))
N_ATTRS = 4
N_QUERIES = int(os.environ.get("REPRO_BENCH_Q", 64))
K = 10
# scoring backend for the compass runs: "ref" | "pallas" | "auto"
BACKEND = os.environ.get("REPRO_BENCH_BACKEND", "auto")


def bench_metadata() -> dict:
    """Provenance block written into every BENCH_*.json: which engine and
    backend produced the numbers, on what platform/scale — so benchmark
    trajectories across PRs stay attributable."""
    from repro.compass import ENGINE_VERSION
    from repro.core.engine import resolve_backend

    return {
        "engine_version": ENGINE_VERSION,
        "backend_requested": BACKEND,
        "backend": resolve_backend(BACKEND).name,
        # prefilter/brute-force rows are pure matmul scans with no engine
        # backend; the backend fields describe every compass/navix/postfilter
        # row in the file.
        "backend_applies_to": ["compass*", "navix", "postfilter"],
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "n": N,
        "d": D,
        # full-precision per-row footprint; the quantized tier's figures
        # (codes + amortized codebooks) are in bench_quant's rows — tracked
        # here so the memory trajectory across PRs has a fixed anchor
        "bytes_per_vector_full": 4 * D,
        "n_attrs": N_ATTRS,
        "n_queries": N_QUERIES,
        "k": K,
    }

# paper-aligned defaults
EF_SWEEP = (16, 32, 64, 128, 256, 512)
DATASETS = {
    # name -> (n_modes, mode_scale): SYN-EASY has crisp modes (CRAWL/GIST
    # regime), SYN-HARD has overlapping flat structure (VIDEO/GLOVE regime)
    "SYN-EASY": dict(n_modes=64, mode_scale=3.0),
    "SYN-HARD": dict(n_modes=512, mode_scale=1.0),
}


def get_dataset(name: str):
    kw = DATASETS[name]
    x, attrs, queries = make_vector_corpus(N, D, N_ATTRS, seed=7, **kw)
    return x, attrs, queries[:N_QUERIES]


def get_index(name: str, nlist: int = 128, m: int = 16):
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, f"{name}_n{N}_d{D}_m{m}_nl{nlist}.pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            idx_host, build_s = pickle.load(f)
        # caches written before the planner existed lack attribute stats;
        # rebuild so planner benches don't fail on a stale pickle
        if getattr(idx_host, "astats", None) is not None:
            return idx_host, build_s
        os.remove(path)
    x, attrs, _ = get_dataset(name)
    t0 = time.time()
    idx = build_index(x, attrs, BuildConfig(m=m, nlist=nlist))
    build_s = time.time() - t0
    idx_host = jax.tree.map(np.asarray, idx)
    with open(path, "wb") as f:
        pickle.dump((idx_host, build_s), f)
    return idx_host, build_s


def index_to_device(idx_host):
    return jax.tree.map(jnp.asarray, idx_host)


def make_workload(rng, n_queries: int, passrate: float, n_terms: int, disj: bool):
    """Range predicates with per-attribute passrate (attrs are U[0,1])."""
    preds = []
    for _ in range(n_queries):
        terms = []
        for a in range(n_terms):
            lo = rng.uniform(0, 1 - passrate)
            terms.append(P.Pred.range(a, lo, lo + passrate))
        tree = P.Pred.or_(*terms) if disj else P.Pred.and_(*terms)
        preds.append(tree.tensor(N_ATTRS, n_terms=N_ATTRS))  # pad T for shape reuse
    return P.stack_predicates(preds)


@dataclasses.dataclass
class RunResult:
    method: str
    ef: int
    recall: float
    n_dist: float
    wall_s: float
    qps: float

    def row(self):
        return (
            f"{self.method},{self.ef},{self.recall:.4f},{self.n_dist:.0f},"
            f"{self.wall_s*1e6/max(N_QUERIES,1):.0f},{self.qps:.1f}"
        )


def _finish(method, ef, res, truth, n, wall):
    r = recall(np.asarray(res.ids), np.asarray(truth.ids), np.asarray(truth.dists), n)
    nd = float(np.asarray(res.stats.n_dist).mean())
    return RunResult(method, ef, r, nd, wall, N_QUERIES / wall if wall else 0.0)


def run_method(method: str, idx, x, attrs, queries, pred, ef: int, truth) -> RunResult:
    qj = jnp.asarray(queries)
    n = x.shape[0]
    t0 = time.time()
    if method == "compass":
        res = compass_search(idx, qj, pred, CompassParams(k=K, ef=ef, backend=BACKEND))
        res.ids.block_until_ready()
    elif method == "compass_graph":  # ablation handled by caller's index
        res = compass_search(idx, qj, pred, CompassParams(k=K, ef=ef, backend=BACKEND))
        res.ids.block_until_ready()
    elif method == "compass_relational":
        res = compass_search(
            idx, qj, pred, CompassParams(k=K, ef=ef, use_graph=False, backend=BACKEND)
        )
        res.ids.block_until_ready()
    elif method == "navix":
        res = navix_search(idx, qj, pred, CompassParams(k=K, ef=ef, backend=BACKEND))
        res.ids.block_until_ready()
    elif method == "postfilter":
        res = postfilter_search(idx, qj, pred, K, ef0=ef, backend=BACKEND)
        res.ids.block_until_ready()
    elif method == "prefilter":
        bf = prefilter_search(idx, qj, pred, K)
        bf.ids.block_until_ready()
        wall = time.time() - t0
        r = recall(np.asarray(bf.ids), np.asarray(truth.ids), np.asarray(truth.dists), n)
        return RunResult(method, ef, r, float(n), wall, N_QUERIES / wall)
    else:
        raise ValueError(method)
    wall = time.time() - t0
    return _finish(method, ef, res, truth, n, wall)


def ground_truth(x, attrs, queries, pred):
    return brute_force(jnp.asarray(x), jnp.asarray(attrs), jnp.asarray(queries), pred, K)


def find_ef_for_recall(method, idx, x, attrs, queries, pred, target, truth):
    """Smallest swept ef reaching the recall target (paper's protocol:
    report QPS at fixed recall).  Returns (RunResult, reached)."""
    best = None
    for ef in EF_SWEEP:
        rr = run_method(method, idx, x, attrs, queries, pred, ef, truth)
        best = rr
        if rr.recall >= target:
            return rr, True
    return best, False
