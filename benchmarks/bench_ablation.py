"""Paper Fig. 11 (ablation): CompassRelational (no graph) and CompassGraph
(nlist=1) against full Compass, at the default 30% passrate, on an easy and
a hard dataset."""
from __future__ import annotations

import numpy as np

from . import common as C


def run(out=print):
    rng = np.random.default_rng(3)
    rows = []
    out("# ablation passrate=0.3")
    out("dataset,method,ef,recall,ndist,us_per_query,qps")
    for dataset in ("SYN-EASY", "SYN-HARD"):
        x, attrs, queries = C.get_dataset(dataset)
        idx_full = C.index_to_device(C.get_index(dataset)[0])
        idx_g1 = C.index_to_device(C.get_index(dataset, nlist=1)[0])
        pred = C.make_workload(rng, C.N_QUERIES, 0.3, 1, disj=False)
        truth = C.ground_truth(x, attrs, queries, pred)
        for method, idx in (
            ("compass", idx_full),
            ("compass_relational", idx_full),
            ("compass_graph", idx_g1),
        ):
            for ef in C.EF_SWEEP:
                rr = C.run_method(method, idx, x, attrs, queries, pred, ef, truth)
                out(
                    f"{dataset},{method},{ef},{rr.recall:.4f},{rr.n_dist:.0f},"
                    f"{rr.wall_s*1e6/C.N_QUERIES:.0f},{rr.qps:.1f}"
                )
                rows.append((dataset, method, rr))
                if rr.recall >= 0.999:
                    break
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
