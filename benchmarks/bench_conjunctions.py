"""Paper Fig. 4/5 + Table V (top): conjunctive range filtering, 1-4
attributes at 30% per-attribute passrate (overall 30% -> 0.8%), QPS/#Comp
at a target recall."""
from __future__ import annotations

import numpy as np

from . import common as C


def run(target_recall: float = 0.9, dataset: str = "SYN-EASY", out=print):
    idx_host, _ = C.get_index(dataset)
    idx = C.index_to_device(idx_host)
    x, attrs, queries = C.get_dataset(dataset)
    rng = np.random.default_rng(0)
    out(f"# conjunctions dataset={dataset} target_recall={target_recall}")
    out("method,n_attrs,ef,recall,ndist,us_per_query,qps")
    rows = []
    for n_terms in (1, 2, 3, 4):
        pred = C.make_workload(rng, C.N_QUERIES, 0.3, n_terms, disj=False)
        truth = C.ground_truth(x, attrs, queries, pred)
        for method in ("compass", "navix", "postfilter", "prefilter"):
            rr, reached = (
                C.find_ef_for_recall(method, idx, x, attrs, queries, pred, target_recall, truth)
                if method != "prefilter"
                else (C.run_method(method, idx, x, attrs, queries, pred, 0, truth), True)
            )
            flag = "" if reached and rr.recall >= target_recall else "*"  # * == never reached (paper's x)
            out(
                f"{method}{flag},{n_terms},{rr.ef},{rr.recall:.4f},{rr.n_dist:.0f},"
                f"{rr.wall_s*1e6/C.N_QUERIES:.0f},{rr.qps:.1f}"
            )
            rows.append((method, n_terms, rr, reached))
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
