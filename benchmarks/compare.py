"""Diff two directories of BENCH_*.json artifacts and fail on QPS
regressions — the advisory trajectory gate behind bench-smoke.

  python -m benchmarks.compare BASE_DIR NEW_DIR [--threshold 0.2]
  python -m benchmarks.compare BASE_DIR --history BENCH_HISTORY.jsonl

Every ``qps`` figure is extracted from both artifacts by a recursive walk
(rows are bench-specific shapes: tuples of RunResults, planner sweep
objects, serving summaries), matched by a deterministic label built from
the surrounding method / workload / passrate fields, and compared: a label
whose new QPS falls more than ``threshold`` (default 20%) below the base
fails the run.  Labels present on only one side are reported but never
fail — benches come and go across PRs.

Wall-clock QPS on shared CI runners is noisy, which is why the CI step is
*advisory* (``continue-on-error``): the artifact is the signal, the red ✗
is the prompt to look, the committed baseline under ``benchmarks/baselines``
is what "before" means.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def extract_qps(payload: dict) -> dict[str, float]:
    """All (label, qps) figures in a BENCH payload, deterministically.

    A dict node carrying ``workload``/``passrate`` contributes a breadcrumb
    (planner-sweep rows); a dict node carrying a numeric ``qps`` emits one
    figure labeled by breadcrumbs + its ``method``/``ef`` fields.  Repeated
    labels get a stable occurrence suffix (row order is deterministic).
    """
    out: dict[str, float] = {}

    def emit(label: str, qps: float) -> None:
        base, i = label, 2
        while label in out:
            label = f"{base}#{i}"
            i += 1
        out[label] = qps

    def visit(node, crumbs: tuple) -> None:
        if isinstance(node, dict):
            if "workload" in node and "passrate" in node:
                crumbs = crumbs + (f"{node['workload']}@{node['passrate']}",)
            qps = node.get("qps")
            if isinstance(qps, (int, float)) and not isinstance(qps, bool):
                parts = list(crumbs)
                if isinstance(node.get("method"), str):
                    parts.append(node["method"])
                if isinstance(node.get("ef"), (int, float)):
                    parts.append(f"ef{node['ef']}")
                emit("/".join(parts) or "qps", float(qps))
            for v in node.values():
                visit(v, crumbs)
        elif isinstance(node, list):
            for v in node:
                visit(v, crumbs)

    visit(payload.get("rows"), ())
    return out


def diff_labels(
    name: str, base: dict[str, float], new: dict[str, float], threshold: float
) -> list[str]:
    """Diff two {label: qps} maps; returns regression messages (empty ==
    ok).  Shared by the directory diff and the history-row diff."""
    regressions = []
    for label in sorted(base):
        if label not in new:
            print(f"note {name}: {label!r} only in baseline")
            continue
        b, n = base[label], new[label]
        if b <= 0.0:
            continue
        ratio = n / b
        flag = "REGRESSION" if ratio < 1.0 - threshold else "ok"
        print(f"{flag:>10} {name}: {label}: {b:.1f} -> {n:.1f} qps ({ratio:.2f}x)")
        if ratio < 1.0 - threshold:
            regressions.append(f"{name}: {label}: {b:.1f} -> {n:.1f} ({ratio:.2f}x)")
    for label in sorted(set(new) - set(base)):
        print(f"note {name}: {label!r} new (no baseline)")
    return regressions


def compare_file(base_path: str, new_path: str, threshold: float) -> list[str]:
    """Returns a list of regression messages (empty == ok)."""
    with open(base_path) as f:
        base = extract_qps(json.load(f))
    with open(new_path) as f:
        new = extract_qps(json.load(f))
    return diff_labels(os.path.basename(new_path), base, new, threshold)


def compare_history(base_dir: str, history_path: str, threshold: float) -> int:
    """Diff the *latest* BENCH_HISTORY.jsonl row against the committed
    baselines: each bench's qps label map (extracted at run time by
    ``run.py --history``) against ``extract_qps`` of the matching
    ``BENCH_*.json`` under ``base_dir``."""
    try:
        with open(history_path) as f:
            lines = [ln for ln in f.read().splitlines() if ln.strip()]
    except OSError as e:
        print(f"FAIL: unreadable history {history_path}: {e}")
        return 1
    if not lines:
        print(f"FAIL: {history_path} holds no rows")
        return 1
    latest = json.loads(lines[-1])
    meta = latest.get("meta", {})
    print(
        f"history row {len(lines) - 1}: {meta.get('engine_version', '?')} "
        f"backend={meta.get('backend', '?')} n={meta.get('n', '?')}"
    )
    all_regressions, compared = [], 0
    for bench, info in sorted(latest.get("benches", {}).items()):
        fname = f"BENCH_{bench.removeprefix('bench_')}.json"
        base_path = os.path.join(base_dir, fname)
        if not os.path.exists(base_path):
            print(f"note: {fname} has no committed baseline")
            continue
        with open(base_path) as f:
            base = extract_qps(json.load(f))
        compared += 1
        all_regressions.extend(
            diff_labels(fname, base, dict(info.get("qps", {})), threshold)
        )
    if not compared:
        print(f"FAIL: no bench in the latest row has a baseline under {base_dir}")
        return 1
    if all_regressions:
        print(f"\n{len(all_regressions)} QPS regression(s) > {threshold:.0%}:")
        for r in all_regressions:
            print(f"  {r}")
        return 1
    print(f"\nlatest history row within {threshold:.0%} on {compared} bench(es)")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("base_dir", help="baseline BENCH_*.json directory")
    ap.add_argument(
        "new_dir", nargs="?", default=None,
        help="candidate BENCH_*.json directory (omit with --history)",
    )
    ap.add_argument(
        "--history", default=None, metavar="JSONL",
        help="diff the latest BENCH_HISTORY.jsonl row against base_dir "
        "instead of a candidate directory",
    )
    ap.add_argument(
        "--threshold", type=float, default=0.2,
        help="max tolerated fractional QPS drop (default 0.2 == 20%%)",
    )
    args = ap.parse_args(argv)
    if (args.new_dir is None) == (args.history is None):
        ap.error("provide exactly one of new_dir or --history")
    if args.history is not None:
        return compare_history(args.base_dir, args.history, args.threshold)
    base_files = {
        os.path.basename(p): p
        for p in glob.glob(os.path.join(args.base_dir, "BENCH_*.json"))
    }
    new_files = {
        os.path.basename(p): p
        for p in glob.glob(os.path.join(args.new_dir, "BENCH_*.json"))
    }
    shared = sorted(set(base_files) & set(new_files))
    if not shared:
        print(
            f"FAIL: no BENCH_*.json in common between {args.base_dir} "
            f"({sorted(base_files)}) and {args.new_dir} ({sorted(new_files)})"
        )
        return 1
    all_regressions = []
    for name in shared:
        all_regressions.extend(
            compare_file(base_files[name], new_files[name], args.threshold)
        )
    for name in sorted(set(new_files) - set(base_files)):
        print(f"note: {name} has no committed baseline")
    if all_regressions:
        print(f"\n{len(all_regressions)} QPS regression(s) > {args.threshold:.0%}:")
        for r in all_regressions:
            print(f"  {r}")
        return 1
    print(f"\nall {len(shared)} shared artifact(s) within {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
