"""Serving-layer benchmark: QPS / latency / compile counts for the
continuous-batching SearchService under a mixed predicate-shape workload.

Not a paper figure — the serving subsystem is our production extension —
but directly motivated by Compass §VI: throughput under mixed hybrid
workloads is decided by batching and routing, not just per-query latency.

Three interleaved shape classes:
  * ``conj2``  — 2-attribute conjunction, 30% per-attr passrate (T=1)
  * ``disj4``  — 4-way single-attribute disjunction (T=4)
  * ``hisel3`` — high-selectivity 3-attribute conjunction, 10% passrate (T=1)

The stream occupies two (B, T) buckets; the measured invariants are (a)
total XLA compiles == occupied buckets, steady state included, and (b)
every service response is bitwise-identical to the corresponding direct
``compass_search`` call (checked on a subsample, recorded as
``bitwise_ok``).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import predicate as P
from repro.compass import CompassParams, compass_search
from repro.serving.search_service import SearchService

from . import common as C

EF = 64
BATCH = 8
MAX_WAIT_S = 0.005

SHAPE_CLASSES = ("conj2", "disj4", "hisel3")


def _make_pred(rng, cls: str) -> P.Pred:
    if cls == "conj2":
        return P.Pred.and_(*[_rng_range(rng, a, 0.3) for a in range(2)])
    if cls == "disj4":
        return P.Pred.or_(*[_rng_range(rng, a, 0.3) for a in range(4)])
    if cls == "hisel3":
        return P.Pred.and_(*[_rng_range(rng, a, 0.1) for a in range(3)])
    raise ValueError(cls)


def _rng_range(rng, attr: int, passrate: float) -> P.Pred:
    lo = rng.uniform(0, 1 - passrate)
    return P.Pred.range(attr, lo, lo + passrate)


def _percentile(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if len(xs) else 0.0


def run(dataset: str = "SYN-EASY", out=print):
    idx_host, _ = C.get_index(dataset)
    idx = C.index_to_device(idx_host)
    _, _, queries = C.get_dataset(dataset)
    rng = np.random.default_rng(11)
    pm = CompassParams(k=C.K, ef=EF, backend=C.BACKEND)
    service = SearchService(idx, pm, batch_size=BATCH, max_wait_s=MAX_WAIT_S)

    n_requests = 3 * C.N_QUERIES
    workload = [
        (SHAPE_CLASSES[i % 3], queries[i % len(queries)], _make_pred(rng, SHAPE_CLASSES[i % 3]))
        for i in range(n_requests)
    ]

    def drive():
        t0 = time.time()
        rid_job = {}  # rid -> (class, query, pred tree), in submission order
        for cls, q, tree in workload:
            rid_job[service.submit(q, tree, k=C.K)] = (cls, q, tree)
            service.step()
        results = {r.rid: r for r in service.flush()}
        for rid in rid_job:
            results.setdefault(rid, service.poll(rid))
        wall = time.time() - t0
        lat = {c: [] for c in SHAPE_CLASSES}
        for rid, (cls, _, _) in rid_job.items():
            r = results[rid]
            lat[cls].append(r.queue_wait_s + r.batch_exec_s)
        return wall, lat, rid_job, results

    # pass 1 pays the per-bucket compiles; pass 2 is steady state
    warm_wall, _, _, _ = drive()
    compiles_after_warmup = service.compile_count
    steady_wall, lat, rid_job, results = drive()
    stats = service.stats()

    assert service.compile_count == compiles_after_warmup, "steady state recompiled"
    assert stats["compiles"] == stats["occupied_buckets"], stats

    # bitwise parity vs direct compass_search on a subsample
    sample = list(rid_job.items())[:: max(1, n_requests // 24)]
    bitwise_ok = True
    for rid, (_cls, q, tree) in sample:
        direct = compass_search(
            idx, jnp.asarray(q[None]),
            P.stack_predicates([tree.tensor(C.N_ATTRS)]), pm,
        )
        r = results[rid]
        bitwise_ok &= np.array_equal(r.ids, np.asarray(direct.ids)[0, : C.K])
        bitwise_ok &= np.array_equal(
            r.dists.view(np.uint32), np.asarray(direct.dists)[0, : C.K].view(np.uint32)
        )
    assert bitwise_ok, "service response != direct compass_search"

    out(f"# serving dataset={dataset} B={BATCH} max_wait={MAX_WAIT_S*1e3:.1f}ms")
    out("class,n,lat_p50_ms,lat_p99_ms")
    per_class = {}
    for cls in SHAPE_CLASSES:
        p50, p99 = _percentile(lat[cls], 50) * 1e3, _percentile(lat[cls], 99) * 1e3
        out(f"{cls},{len(lat[cls])},{p50:.2f},{p99:.2f}")
        per_class[cls] = {"n": len(lat[cls]), "lat_p50_ms": p50, "lat_p99_ms": p99}
    qps = n_requests / steady_wall if steady_wall else 0.0
    out(
        f"steady_qps={qps:.1f} compiles={stats['compiles']} "
        f"occupied_buckets={stats['occupied_buckets']} bitwise_ok={bitwise_ok}"
    )
    return {
        "n_requests_per_pass": n_requests,
        "warmup_wall_s": warm_wall,
        "steady_wall_s": steady_wall,
        "steady_qps": qps,
        "per_class": per_class,
        "bitwise_ok": bool(bitwise_ok),
        "service": stats,
    }


def main():
    run()


if __name__ == "__main__":
    main()
