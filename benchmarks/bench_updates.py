"""Sustained mixed upsert/delete/search workload over the mutable index.

The serving question behind core/mutable: what does absorbing writes cost,
and what does it buy over the build-once alternative?  The bench interleaves
write bursts (60% new upserts / 20% re-upserts / 20% deletes) with timed
search batches across ``ROUNDS`` rounds, sized so the delta segment
overflows and triggers online compaction mid-run, then reports

  * steady-state search QPS during churn (per workload: a moderate
    conjunction and a ≤1% "narrow" predicate, planner on), with per-call
    p50/p99 latency — only round 0 warms up, so any epoch-crossing
    recompile lands in a *timed* call and shows up as a p99 cliff,
  * per-phase compile accounting (``n_compiles`` / ``n_cache_hits`` on
    every row, measured as jit trace-cache deltas): the shape-stable
    serving claim is ``n_compiles == occupied buckets`` after round 0 —
    zero recompiles across compaction epochs under the default
    ``ShapePolicy`` row bucketing (the ``steady_state`` row),
  * final recall vs exact brute force over the materialized table, next to
    a fresh ``build_index`` over the same table searched identically
    (recall-vs-fresh-rebuild: the delta/tombstone machinery should cost
    nothing),
  * sustained write throughput (compaction pauses *included*) and the
    compaction pause profile,
  * the rebuild-per-write strawman: a build-once index absorbs a write
    only by rebuilding, so its write "QPS" is 1/build_time — the
    ``speedup_vs_rebuild_per_write`` figure is the point of the subsystem.

``--selfcheck`` is the CI tripwire (exit 1 on failure): a tiny churn run
crossing ≥3 compaction epochs asserting (a) zero steady-state recompiles
for the bucketed index and (b) bitwise result parity against an unbucketed
(``bucket_rows=False``) twin fed the identical write history — padding
rows never surface.
"""
from __future__ import annotations

import os
import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.compass import CompassParams, MutableIndex, ShapePolicy, compass_search
from repro.core.baselines import brute_force, recall
from repro.core.engine import compass_search_jit
from repro.core.index import BuildConfig, build_index
from repro.core.mutable import mutable_search
from repro.obs import registry as obs_reg

from . import common as C

ROUNDS = int(os.environ.get("REPRO_BENCH_UPDATE_ROUNDS", 6))
DELTA_CAP = int(os.environ.get("REPRO_BENCH_DELTA_CAP", 192))
REPS = 3  # timed search repetitions per round per workload
EF = 64

# per-workload (n_terms, per-attr passrate, overall passrate, disjunction)
WORKLOADS = {
    "conj": (2, 0.45, 0.2, False),
    "narrow": (1, 0.01, 0.01, False),
    "disj": (4, 0.05, 0.19, True),
}


def _cache_entries() -> int:
    """Total jitted-trace cache entries on the two search entry points.

    Deltas of this figure around a phase are that phase's compile count:
    each entry is one (shapes, static params) trace, i.e. one XLA compile.
    (``compass_search`` is a host wrapper now; the jit cache lives on
    ``compass_search_jit``.)
    """
    return int(mutable_search._cache_size()) + int(compass_search_jit._cache_size())


def _registry_value(kind: str, name: str, default: float = 0.0) -> float:
    """Sum a registry metric across its label series (0 if unregistered)."""
    m = obs_reg.registry().get(name)
    if m is None:
        return default
    if kind == "gauge":  # report the most recent series value
        vals = list(m._series.values())
        return float(vals[-1]) if vals else default
    return float(sum(m._series.values()))


def _recall_gids(res_ids, truth, table_gids, n_table) -> float:
    """Recall of gid-valued results against positional brute-force truth."""
    tids = np.asarray(truth.ids)
    tg = np.where(
        np.isfinite(np.asarray(truth.dists)) & (tids < n_table),
        table_gids[np.clip(tids, 0, n_table - 1)],
        -1,
    )
    big = int(max(table_gids.max(), np.asarray(res_ids).max()) + 1)
    return recall(np.asarray(res_ids), np.where(tg >= 0, tg, big), np.asarray(truth.dists), big)


def run(dataset: str = "SYN-EASY", out=print):
    # churn is where the lifecycle metrics live (compactions, drift, write
    # errors): run with the registry on so the rows can report them, and
    # restore the caller's setting on the way out
    _obs_prev = obs_reg.set_enabled(True)
    try:
        return _run(dataset, out)
    finally:
        obs_reg.set_enabled(_obs_prev)


def _run(dataset: str, out):
    x, attrs, queries = C.get_dataset(dataset)
    qj = jnp.asarray(queries)
    rng = np.random.default_rng(0)
    cfg = BuildConfig(m=16, nlist=128)
    t0 = time.time()
    mi = MutableIndex.build(x, attrs, cfg, delta_cap=DELTA_CAP)
    build_s = time.time() - t0
    pm = CompassParams(k=C.K, ef=EF, planner=True, backend=C.BACKEND)
    preds = {
        name: C.make_workload(rng, C.N_QUERIES, per_attr, n_terms, disj)
        for name, (n_terms, per_attr, _, disj) in WORKLOADS.items()
    }
    out(
        f"# updates bench dataset={dataset} n={C.N} delta_cap={DELTA_CAP} "
        f"rounds={ROUNDS} writes/round={DELTA_CAP // 2} build={build_s:.1f}s "
        f"row_bucket={mi.base.n_records}"
    )

    live = list(range(C.N))
    next_gid = C.N
    write_wall = 0.0
    write_compiles = 0
    n_writes = 0
    lat_ms = {w: [] for w in WORKLOADS}  # per-call, rounds >= 1 untruncated
    n_calls = {w: 0 for w in WORKLOADS}
    wl_compiles = {w: 0 for w in WORKLOADS}
    compiles_by_round = []  # search-phase compile deltas, one per round
    epoch_by_round = []
    for rnd in range(ROUNDS):
        t0 = time.time()
        c0 = _cache_entries()
        for _ in range(DELTA_CAP // 2):
            u = rng.random()
            if u < 0.6 or not live:
                gid = next_gid
                next_gid += 1
                live.append(gid)
                mi.upsert(gid, rng.normal(size=C.D).astype(np.float32),
                          rng.uniform(size=C.N_ATTRS).astype(np.float32))
            elif u < 0.8:
                gid = live[rng.integers(len(live))]
                mi.upsert(gid, rng.normal(size=C.D).astype(np.float32),
                          rng.uniform(size=C.N_ATTRS).astype(np.float32))
            else:
                gid = live.pop(int(rng.integers(len(live))))
                mi.delete(gid)
            n_writes += 1
        write_wall += time.time() - t0
        write_compiles += _cache_entries() - c0
        c_round = _cache_entries()
        for name, pred in preds.items():
            c0 = _cache_entries()
            if rnd == 0:  # warmup: the bucket's one expected compile
                mi.search(qj, pred, pm).ids.block_until_ready()
                n_calls[name] += 1
            # rounds >= 1 run untruncated: a post-compaction recompile
            # would land in a timed call and surface in the p99 column
            for _ in range(REPS):
                t1 = time.time()
                res = mi.search(qj, pred, pm)
                res.ids.block_until_ready()
                lat_ms[name].append((time.time() - t1) * 1e3)
            n_calls[name] += REPS
            wl_compiles[name] += _cache_entries() - c0
        compiles_by_round.append(_cache_entries() - c_round)
        epoch_by_round.append(mi.epoch)

    # final-state evaluation: exact truth over the materialized table, and a
    # fresh rebuild over the very same table as the recall reference point
    vec, att, gids = mi.materialize()
    n_table = vec.shape[0]
    t0 = time.time()
    fresh = build_index(vec, att, cfg)
    rebuild_s = time.time() - t0
    rows = []
    out("workload,passrate,mutable_qps,p99_ms,n_compiles,mutable_recall,rebuild_recall")
    for name, (_, _, passrate, _) in WORKLOADS.items():
        pred = preds[name]
        truth = brute_force(jnp.asarray(vec), jnp.asarray(att), qj, pred, C.K)
        res_m = mi.search(qj, pred, pm)
        r_mut = _recall_gids(res_m.ids, truth, gids, n_table)
        c0 = _cache_entries()
        compass_search(fresh, qj, pred, pm).ids.block_until_ready()  # warmup
        t0 = time.time()
        res_f = compass_search(fresh, qj, pred, pm)
        res_f.ids.block_until_ready()
        fresh_wall = time.time() - t0
        fresh_compiles = _cache_entries() - c0
        r_fresh = _recall_gids(
            np.where(np.asarray(res_f.ids) < n_table,
                     gids[np.clip(np.asarray(res_f.ids), 0, n_table - 1)], -1),
            truth, gids, n_table,
        )
        lat = np.asarray(lat_ms[name])
        qps_mut = REPS * ROUNDS * C.N_QUERIES / lat.sum() * 1e3 if lat.size else 0.0
        rows.append(
            {
                "phase": "search_churn",
                "workload": name,
                "passrate": passrate,
                "method": "mutable",
                "ef": EF,
                "qps": qps_mut,
                "recall": r_mut,
                "recall_fresh_rebuild": r_fresh,
                "n_dist": float(np.asarray(res_m.stats.n_dist).mean()),
                "n_compiles": wl_compiles[name],
                "n_cache_hits": n_calls[name] - wl_compiles[name],
                # batch-call latency across every churn round — compaction
                # events included, so epoch-crossing cliffs show here
                "p50_ms": float(np.percentile(lat, 50)) if lat.size else 0.0,
                "p99_ms": float(np.percentile(lat, 99)) if lat.size else 0.0,
            }
        )
        rows.append(
            {
                "phase": "search_fresh",
                "workload": name,
                "passrate": passrate,
                "method": "rebuild",
                "ef": EF,
                "qps": C.N_QUERIES / fresh_wall if fresh_wall else 0.0,
                "recall": r_fresh,
                "n_compiles": fresh_compiles,
                "n_cache_hits": 2 - fresh_compiles,
            }
        )
        out(
            f"{name},{passrate},{qps_mut:.1f},"
            f"{float(np.percentile(lat, 99)) if lat.size else 0:.1f},"
            f"{wl_compiles[name]},{r_mut:.4f},{r_fresh:.4f}"
        )

    # the shape-stable serving claim, measured: round 0 compiles the
    # occupied buckets; every later round (compactions included) must
    # re-use them.  steady_compiles > 0 means a shape leaked.
    warm_compiles = compiles_by_round[0] if compiles_by_round else 0
    steady_compiles = sum(compiles_by_round[1:])
    steady_calls = sum(n_calls.values()) - len(WORKLOADS)  # minus warmups
    rows.append(
        {
            "phase": "steady_state",
            "qps": sum(
                REPS * ROUNDS * C.N_QUERIES / np.asarray(v).sum() * 1e3
                for v in lat_ms.values()
                if np.asarray(v).size
            ),
            "n_compiles": steady_compiles,
            "n_cache_hits": steady_calls - steady_compiles,
            "occupied_buckets": warm_compiles,
            "compiles_by_round": compiles_by_round,
            "epoch_by_round": epoch_by_round,
            "epochs_crossed": mi.epoch,
            "row_bucket": mi.base.n_records,
            "zero_steady_state_recompiles": steady_compiles == 0,
        }
    )
    out(
        f"steady state: {warm_compiles} warmup compiles (occupied buckets), "
        f"{steady_compiles} recompiles across {mi.epoch} compaction epochs"
    )

    pauses = mi.compaction_log
    write_qps = n_writes / write_wall if write_wall else 0.0
    rebuild_per_write_qps = 1.0 / rebuild_s if rebuild_s else 0.0
    speedup = write_qps / rebuild_per_write_qps if rebuild_per_write_qps else 0.0
    rows.append(
        {
            "phase": "writes",
            "method": "mutable_write",
            "qps": write_qps,
            "n_writes": n_writes,
            "n_compiles": write_compiles,
            "n_cache_hits": 0,
            "compaction_count": len(pauses),
            "compaction_mean_s": float(np.mean(pauses)) if pauses else 0.0,
            "compaction_max_s": float(np.max(pauses)) if pauses else 0.0,
            "rebuild_s": rebuild_s,
            "rebuild_per_write_qps": rebuild_per_write_qps,
            "speedup_vs_rebuild_per_write": speedup,
            "final_epoch": mi.epoch,
            "n_live": mi.n_live,
            # registry-sourced lifecycle figures (satellite: quant drift
            # and write errors flow through repro.obs, not ad-hoc attrs);
            # drift falls back to the index's own log when the registry
            # never saw a quantized compaction (exact-mode workloads)
            "n_write_errors": int(_registry_value("counter", "compass_write_errors_total")),
            "obs_compactions": int(_registry_value("counter", "compass_compactions_total")),
            "quant_drift_mse": (
                _registry_value("gauge", "compass_quant_drift_mse")
                if obs_reg.registry().get("compass_quant_drift_mse") is not None
                else (mi.quant_drift_log[-1] if mi.quant_drift_log else None)
            ),
        }
    )
    out(
        f"writes: {write_qps:.0f}/s sustained ({len(pauses)} compactions, "
        f"max pause {max(pauses) if pauses else 0:.2f}s) vs rebuild-per-write "
        f"{rebuild_per_write_qps:.3f}/s -> {speedup:.0f}x"
    )
    return rows


def selfcheck(out=print) -> int:
    """CI tripwire: zero steady-state recompiles + bitwise bucket parity.

    Tiny corpus, fixed sizes (independent of the REPRO_BENCH_* knobs so the
    gate is stable): churn a bucketed index and an unbucketed twin through
    the identical write history across >= 3 compaction epochs; after one
    warmup search the bucketed index must add zero jit cache entries, and
    every round's results must match the twin's bitwise (ids and dists).
    Returns a process exit code (0 ok, 1 failed).
    """
    rng = np.random.default_rng(0)
    n, d, cap = 600, 16, 48
    x = rng.normal(size=(n, d)).astype(np.float32)
    at = rng.uniform(size=(n, C.N_ATTRS)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(8, d)).astype(np.float32))
    cfg = BuildConfig(m=8, nlist=16, kmeans_iters=4)
    mi = MutableIndex.build(x, at, cfg, shape=ShapePolicy(min_rows=1024, delta_cap=cap))
    ref = MutableIndex.build(
        x, at, cfg, delta_cap=cap, shape=ShapePolicy(bucket_rows=False)
    )
    pm = CompassParams(k=C.K, ef=32, planner=True, backend=C.BACKEND)
    pred = C.make_workload(rng, 8, 0.3, 2, False)
    assert mi.base.n_records == 1024, mi.base.n_records

    mi.search(q, pred, pm).ids.block_until_ready()  # warmup: the one compile
    failures = []
    steady_compiles = 0
    live = list(range(n))
    next_gid = n
    rounds = 0
    while len(mi.compaction_log) < 3 and rounds < 30:
        rounds += 1
        for _ in range(cap // 2):
            u = rng.random()
            if u < 0.6 or not live:
                gid, next_gid = next_gid, next_gid + 1
                live.append(gid)
                v = rng.normal(size=d).astype(np.float32)
                a = rng.uniform(size=C.N_ATTRS).astype(np.float32)
                mi.upsert(gid, v, a)
                ref.upsert(gid, v, a)
            elif u < 0.8:
                gid = live[rng.integers(len(live))]
                v = rng.normal(size=d).astype(np.float32)
                a = rng.uniform(size=C.N_ATTRS).astype(np.float32)
                mi.upsert(gid, v, a)
                ref.upsert(gid, v, a)
            else:
                gid = live.pop(int(rng.integers(len(live))))
                mi.delete(gid)
                ref.delete(gid)
        # measure the cache delta around the *bucketed* search only — the
        # twin legitimately recompiles every epoch (that is the baseline
        # behaviour this subsystem removes)
        c0 = _cache_entries()
        r_b = mi.search(q, pred, pm)
        r_b.ids.block_until_ready()
        steady_compiles += _cache_entries() - c0
        r_u = ref.search(q, pred, pm)
        if not (
            np.array_equal(np.asarray(r_b.ids), np.asarray(r_u.ids))
            and np.array_equal(np.asarray(r_b.dists), np.asarray(r_u.dists))
        ):
            failures.append(f"round {rounds}: bucketed != unbucketed results")
    if len(mi.compaction_log) < 3:
        failures.append(f"only {len(mi.compaction_log)} compactions in {rounds} rounds")
    if mi.epoch != ref.epoch:
        failures.append(f"epoch drift: bucketed {mi.epoch} vs twin {ref.epoch}")
    if steady_compiles != 0:
        failures.append(
            f"{steady_compiles} steady-state recompiles across "
            f"{len(mi.compaction_log)} compactions (expected 0)"
        )
    if failures:
        for f in failures:
            out(f"FAIL bench_updates selfcheck: {f}")
        return 1
    out(
        f"ok bench_updates selfcheck: 0 steady-state recompiles, bitwise "
        f"parity over {rounds} rounds / {len(mi.compaction_log)} compactions "
        f"(bucket {mi.base.n_records} rows, twin at {ref.base.n_records})"
    )
    return 0


def main(argv: list[str] | None = None):
    args = sys.argv[1:] if argv is None else argv
    if "--selfcheck" in args:
        sys.exit(selfcheck())
    run()


if __name__ == "__main__":
    main()
