"""Sustained mixed upsert/delete/search workload over the mutable index.

The serving question behind core/mutable: what does absorbing writes cost,
and what does it buy over the build-once alternative?  The bench interleaves
write bursts (60% new upserts / 20% re-upserts / 20% deletes) with timed
search batches across ``ROUNDS`` rounds, sized so the delta segment
overflows and triggers online compaction mid-run, then reports

  * steady-state search QPS during churn (per workload: a moderate
    conjunction and a ≤1% "narrow" predicate, planner on),
  * final recall vs exact brute force over the materialized table, next to
    a fresh ``build_index`` over the same table searched identically
    (recall-vs-fresh-rebuild: the delta/tombstone machinery should cost
    nothing),
  * sustained write throughput (compaction pauses *included*) and the
    compaction pause profile,
  * the rebuild-per-write strawman: a build-once index absorbs a write
    only by rebuilding, so its write "QPS" is 1/build_time — the
    ``speedup_vs_rebuild_per_write`` figure is the point of the subsystem.
"""
from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.baselines import brute_force, recall
from repro.core.index import BuildConfig, build_index
from repro.core.mutable import MutableIndex
from repro.core.search import CompassParams, compass_search

from . import common as C

ROUNDS = int(os.environ.get("REPRO_BENCH_UPDATE_ROUNDS", 6))
DELTA_CAP = int(os.environ.get("REPRO_BENCH_DELTA_CAP", 192))
REPS = 3  # timed search repetitions per round per workload
EF = 64

# per-workload (n_terms, per-attr passrate, overall passrate, disjunction)
WORKLOADS = {
    "conj": (2, 0.45, 0.2, False),
    "narrow": (1, 0.01, 0.01, False),
    "disj": (4, 0.05, 0.19, True),
}


def _recall_gids(res_ids, truth, table_gids, n_table) -> float:
    """Recall of gid-valued results against positional brute-force truth."""
    tids = np.asarray(truth.ids)
    tg = np.where(
        np.isfinite(np.asarray(truth.dists)) & (tids < n_table),
        table_gids[np.clip(tids, 0, n_table - 1)],
        -1,
    )
    big = int(max(table_gids.max(), np.asarray(res_ids).max()) + 1)
    return recall(np.asarray(res_ids), np.where(tg >= 0, tg, big), np.asarray(truth.dists), big)


def run(dataset: str = "SYN-EASY", out=print):
    x, attrs, queries = C.get_dataset(dataset)
    qj = jnp.asarray(queries)
    rng = np.random.default_rng(0)
    cfg = BuildConfig(m=16, nlist=128)
    t0 = time.time()
    mi = MutableIndex.build(x, attrs, cfg, delta_cap=DELTA_CAP)
    build_s = time.time() - t0
    pm = CompassParams(k=C.K, ef=EF, planner=True, backend=C.BACKEND)
    preds = {
        name: C.make_workload(rng, C.N_QUERIES, per_attr, n_terms, disj)
        for name, (n_terms, per_attr, _, disj) in WORKLOADS.items()
    }
    out(
        f"# updates bench dataset={dataset} n={C.N} delta_cap={DELTA_CAP} "
        f"rounds={ROUNDS} writes/round={DELTA_CAP // 2} build={build_s:.1f}s"
    )

    live = list(range(C.N))
    next_gid = C.N
    write_wall = 0.0
    n_writes = 0
    search_wall = {w: 0.0 for w in WORKLOADS}
    search_q = {w: 0 for w in WORKLOADS}
    for _ in range(ROUNDS):
        t0 = time.time()
        for _ in range(DELTA_CAP // 2):
            u = rng.random()
            if u < 0.6 or not live:
                gid = next_gid
                next_gid += 1
                live.append(gid)
                mi.upsert(gid, rng.normal(size=C.D).astype(np.float32),
                          rng.uniform(size=C.N_ATTRS).astype(np.float32))
            elif u < 0.8:
                gid = live[rng.integers(len(live))]
                mi.upsert(gid, rng.normal(size=C.D).astype(np.float32),
                          rng.uniform(size=C.N_ATTRS).astype(np.float32))
            else:
                gid = live.pop(int(rng.integers(len(live))))
                mi.delete(gid)
            n_writes += 1
        write_wall += time.time() - t0
        for name, pred in preds.items():
            mi.search(qj, pred, pm).ids.block_until_ready()  # warmup/compile
            t0 = time.time()
            for _ in range(REPS):
                res = mi.search(qj, pred, pm)
                res.ids.block_until_ready()
            search_wall[name] += time.time() - t0
            search_q[name] += REPS * C.N_QUERIES

    # final-state evaluation: exact truth over the materialized table, and a
    # fresh rebuild over the very same table as the recall reference point
    vec, att, gids = mi.materialize()
    n_table = vec.shape[0]
    t0 = time.time()
    fresh = build_index(vec, att, cfg)
    rebuild_s = time.time() - t0
    rows = []
    out("workload,passrate,mutable_qps,mutable_recall,rebuild_recall")
    for name, (_, _, passrate, _) in WORKLOADS.items():
        pred = preds[name]
        truth = brute_force(jnp.asarray(vec), jnp.asarray(att), qj, pred, C.K)
        res_m = mi.search(qj, pred, pm)
        r_mut = _recall_gids(res_m.ids, truth, gids, n_table)
        compass_search(fresh, qj, pred, pm).ids.block_until_ready()  # warmup
        t0 = time.time()
        res_f = compass_search(fresh, qj, pred, pm)
        res_f.ids.block_until_ready()
        fresh_wall = time.time() - t0
        r_fresh = _recall_gids(
            np.where(np.asarray(res_f.ids) < n_table,
                     gids[np.clip(np.asarray(res_f.ids), 0, n_table - 1)], -1),
            truth, gids, n_table,
        )
        qps_mut = search_q[name] / search_wall[name] if search_wall[name] else 0.0
        rows.append(
            {
                "phase": "search_churn",
                "workload": name,
                "passrate": passrate,
                "method": "mutable",
                "ef": EF,
                "qps": qps_mut,
                "recall": r_mut,
                "recall_fresh_rebuild": r_fresh,
                "n_dist": float(np.asarray(res_m.stats.n_dist).mean()),
            }
        )
        rows.append(
            {
                "phase": "search_fresh",
                "workload": name,
                "passrate": passrate,
                "method": "rebuild",
                "ef": EF,
                "qps": C.N_QUERIES / fresh_wall if fresh_wall else 0.0,
                "recall": r_fresh,
            }
        )
        out(f"{name},{passrate},{qps_mut:.1f},{r_mut:.4f},{r_fresh:.4f}")

    pauses = mi.compaction_log
    write_qps = n_writes / write_wall if write_wall else 0.0
    rebuild_per_write_qps = 1.0 / rebuild_s if rebuild_s else 0.0
    speedup = write_qps / rebuild_per_write_qps if rebuild_per_write_qps else 0.0
    rows.append(
        {
            "phase": "writes",
            "method": "mutable_write",
            "qps": write_qps,
            "n_writes": n_writes,
            "compaction_count": len(pauses),
            "compaction_mean_s": float(np.mean(pauses)) if pauses else 0.0,
            "compaction_max_s": float(np.max(pauses)) if pauses else 0.0,
            "rebuild_s": rebuild_s,
            "rebuild_per_write_qps": rebuild_per_write_qps,
            "speedup_vs_rebuild_per_write": speedup,
            "final_epoch": mi.epoch,
            "n_live": mi.n_live,
        }
    )
    out(
        f"writes: {write_qps:.0f}/s sustained ({len(pauses)} compactions, "
        f"max pause {max(pauses) if pauses else 0:.2f}s) vs rebuild-per-write "
        f"{rebuild_per_write_qps:.3f}/s -> {speedup:.0f}x"
    )
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
