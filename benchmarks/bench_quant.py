"""Quantized-tier sweep: recall@k / QPS / bytes-per-vector over the PQ
configuration space, against the exact baseline (DESIGN.md §Quantization).

Two experiments per dataset:

  * **search sweep** — ``m ∈ {4, 8, 16}`` subspaces × ``refine_factor ∈
    {1, 2, 4}``, on a conjunction, a disjunction, and a ≤1%-selectivity
    workload.  Each point runs the identical query batch through the
    two-stage quantized search (ADC candidate generation + exact rerank)
    and the exact engine; ``recall_vs_exact`` is the quantized run scored
    against the exact run's results (the rerank contract: → 1.0 as
    ``refine_factor`` grows), ``recall`` against brute-force ground truth.
  * **scan microbench** — the raw hot-path comparison behind the cost
    model's ``COST_ADC_ROW``: one full-corpus predicate-filtered scan per
    query through ``scan_scores_quantized`` (the pq_score (B, N) grid /
    its jnp twin) vs ``scan_scores`` (``filter_distance``).  ADC moves
    ``m`` bytes per row instead of ``4·d``, which is the whole pitch.

Timed runs are preceded by an untimed warmup so QPS measures steady-state
execution, not XLA compilation (both arms equally).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine.backend import resolve_backend
from repro.core.quant import (
    QuantConfig,
    QuantParams,
    build_luts,
    quantize_index,
    residual_queries,
)
from repro.compass import CompassParams, compass_search

from . import common as C

M_SWEEP = (4, 8, 16)
REFINE_SWEEP = (1, 2, 4)
EF = 64
KMEANS_ITERS = 8


def _workloads(rng):
    """(name, (B, T, A) predicate batch) for the three required shapes."""
    conj = C.make_workload(rng, C.N_QUERIES, passrate=0.45, n_terms=2, disj=False)
    disj = C.make_workload(rng, C.N_QUERIES, passrate=0.10, n_terms=4, disj=True)
    # ≤1% overall selectivity: two-term conjunction at 10% per attribute
    narrow = C.make_workload(rng, C.N_QUERIES, passrate=0.10, n_terms=2, disj=False)
    return (("conj", conj), ("disj", disj), ("narrow", narrow))


def _timed(idx, qj, pred, pm):
    res = compass_search(idx, qj, pred, pm)  # warmup: compile + cache
    res.ids.block_until_ready()
    t0 = time.time()
    res = compass_search(idx, qj, pred, pm)
    res.ids.block_until_ready()
    return res, time.time() - t0


def _scan_microbench(qidx, queries, pred, backend, metric="l2", reps: int = 5):
    """Full-corpus filtered scan QPS: ADC codes vs float32 rows.

    Both arms run as one jitted program (how the engine consumes them —
    eager per-op dispatch would swamp the row-scoring cost being compared);
    the ADC arm includes its per-query LUT construction, which is part of
    every real ADC scan.
    """
    n = qidx.n_records
    b = queries.shape[0]
    ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    mask = jnp.ones((b, n), bool)

    @jax.jit
    def adc(qs):
        luts = build_luts(qidx.qvecs, qs, metric)
        qr = residual_queries(qidx.qvecs, qs)
        d, p = backend.scan_scores_quantized(qidx, qr, luts, pred, ids, mask, metric)
        return d, p

    @jax.jit
    def exact(qs):
        return backend.scan_scores(qidx, qs, pred, ids, mask, metric)

    out = {}
    for name, fn in (("adc_scan", adc), ("exact_scan", exact)):
        fn(queries)[0].block_until_ready()  # warmup: compile
        t0 = time.time()
        for _ in range(reps):
            fn(queries)[0].block_until_ready()
        wall = (time.time() - t0) / reps
        out[name] = {"method": name, "qps": b / wall if wall else 0.0, "wall_s": wall}
    return out


def run(dataset: str = "SYN-EASY", out=print):
    idx_host, _ = C.get_index(dataset)
    x, attrs, queries = C.get_dataset(dataset)
    qj = jnp.asarray(queries)
    rng = np.random.default_rng(5)
    backend = resolve_backend(C.BACKEND)
    workloads = _workloads(rng)
    out(f"# quant sweep dataset={dataset} ef={EF} n={C.N} d={C.D}")
    out("workload,m,refine,bytes/vec,quant_qps,exact_qps,recall_vs_exact,recall")
    rows = []
    pm_exact = CompassParams(k=C.K, ef=EF, backend=C.BACKEND)
    exact_runs = {}
    truths = {}
    for name, pred in workloads:
        truths[name] = C.ground_truth(x, attrs, queries, pred)
        res, wall = _timed(C.index_to_device(idx_host), qj, pred, pm_exact)
        exact_runs[name] = (res, C._finish("exact", EF, res, truths[name], C.N, wall))
    for m in M_SWEEP:
        qidx = quantize_index(
            C.index_to_device(idx_host), QuantConfig(m=m, iters=KMEANS_ITERS)
        )
        bpv = qidx.qvecs.bytes_per_vector
        for name, pred in workloads:
            exact_res, exact_rr = exact_runs[name]
            for rf in REFINE_SWEEP:
                pm_q = CompassParams(
                    k=C.K, ef=EF, backend=C.BACKEND, quant=QuantParams(refine_factor=rf)
                )
                res, wall = _timed(qidx, qj, pred, pm_q)
                rr = C._finish(f"quant_m{m}_rf{rf}", EF, res, truths[name], C.N, wall)
                r_vs_exact = C.recall(
                    np.asarray(res.ids),
                    np.asarray(exact_res.ids),
                    np.asarray(exact_res.dists),
                    C.N,
                )
                rows.append(
                    {
                        "workload": name,
                        "m": m,
                        "refine_factor": rf,
                        "bytes_per_vector": bpv,
                        "compression": 4.0 * C.D / bpv,
                        "recall_vs_exact": r_vs_exact,
                        "quant": dataclasses.asdict(rr),
                        "exact": dataclasses.asdict(exact_rr),
                    }
                )
                out(
                    f"{name},{m},{rf},{bpv:.1f},{rr.qps:.1f},{exact_rr.qps:.1f},"
                    f"{r_vs_exact:.4f},{rr.recall:.4f}"
                )
        # scan microbench once per m (refine_factor plays no role in a scan)
        scan_pred = workloads[0][1]
        scans = _scan_microbench(qidx, qj, scan_pred, backend)
        rows.append(
            {
                "workload": "scan",
                "m": m,
                "refine_factor": 0,
                "bytes_per_vector": bpv,
                "compression": 4.0 * C.D / bpv,
                "adc_scan": scans["adc_scan"],
                "exact_scan": scans["exact_scan"],
            }
        )
        out(
            f"scan,{m},-,{bpv:.1f},adc={scans['adc_scan']['qps']:.1f},"
            f"exact={scans['exact_scan']['qps']:.1f}"
        )
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
