"""Observability overhead bench: obs-on vs obs-off serving QPS.

The tentpole claim of repro.obs is that it is *free when off and cheap
when on*: registry recording happens only at sync points that already
exist (the service's ``block_until_ready``), kernel scopes are pure
metadata, and nothing obs does can enter the traced program.  This bench
measures the claim instead of asserting it:

  * one ``SearchService`` is built and warmed ONCE (so both arms run the
    identical compiled executables — the comparison is pure dispatch +
    recording cost, not compilation noise),
  * trials alternate obs-off / obs-on (interleaving absorbs drift from
    CPU frequency scaling and allocator state),
  * each arm reports best-of-trials wall time (the standard
    microbenchmark noise floor), plus an ``explain`` arm showing what the
    opt-in trace build costs on top.

``--selfcheck`` is the blocking CI gate: enabled QPS must be within 5% of
disabled QPS, results must stay bitwise identical across arms, and the
registry export must pass schema validation.  Since PR 9 the service
runs with the full continuous-monitoring stack attached — a Monitor
ticking a timeseries snapshot, SLO burn-rate evaluation and every health
watchdog on each scheduling round — so the 5% budget and the bitwise
parity probe now cover the whole layer, and the selfcheck additionally
requires a populated snapshot ring, a health report, and a schema-valid
``repro.obs.timeseries/v1`` export.  Exit 1 on any failure.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.compass import (
    BuildConfig,
    CompassParams,
    Pred,
    SearchService,
    build_index,
)
from repro.obs import registry as obs_reg

from . import common as C

N_REQUESTS = 64  # per trial
TRIALS = 5  # per arm, interleaved
TOLERANCE = 0.05  # enabled QPS must be >= (1 - this) * disabled QPS


def _build_service(n: int, d: int, n_attrs: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    at = rng.uniform(size=(n, n_attrs)).astype(np.float32)
    index = build_index(x, at, BuildConfig(m=8, nlist=16, kmeans_iters=4))
    pm = CompassParams(k=10, ef=32, planner=True, backend=C.BACKEND)
    svc = SearchService(index, pm, batch_size=8, max_wait_s=0.0)
    # the continuous-monitoring layer rides inside the measured arms:
    # interval_s=0 makes every step() snapshot the registry and run SLO +
    # watchdog evaluation (the most expensive cadence), all inside the 5%
    # budget.  Ticks are no-ops in the obs-off arm (Monitor.tick gates on
    # registry.enabled()), so the off arm stays the clean baseline.
    svc.enable_monitoring(interval_s=0.0)
    queries = rng.normal(size=(N_REQUESTS, d)).astype(np.float32)
    preds = [
        Pred.range(i % n_attrs, 0.1, 0.7).tensor(n_attrs) for i in range(N_REQUESTS)
    ]
    return svc, queries, preds


def _trial(svc, queries, preds) -> tuple[float, list]:
    """Submit the fixed request set and drain it; returns (wall_s, results
    sorted by rid) — the result list is the bitwise-parity probe."""
    t0 = time.perf_counter()
    for q, p in zip(queries, preds):
        svc.submit(q, p)
    done = svc.run_until_idle()
    wall = time.perf_counter() - t0
    return wall, sorted(done, key=lambda r: r.rid)


def measure(n: int = 2000, d: int = 16, n_attrs: int = 4, out=print):
    """Interleaved obs-off/obs-on trials over one warmed service.

    Returns ``(summary, service)`` — the service rides along so the
    selfcheck can interrogate its Monitor (snapshot ring, health report,
    timeseries export) after the measured arms finish."""
    svc, queries, preds = _build_service(n, d, n_attrs)
    prev = obs_reg.set_enabled(False)
    try:
        _trial(svc, queries, preds)  # warmup: compiles the occupied buckets
        walls = {"off": [], "on": []}
        results = {}
        for t in range(TRIALS):
            for arm in ("off", "on"):
                obs_reg.set_enabled(arm == "on")
                wall, res = _trial(svc, queries, preds)
                walls[arm].append(wall)
                results[arm] = res
        obs_reg.set_enabled(True)
        wall_explain, _ = _trial(svc, queries, preds)
    finally:
        obs_reg.set_enabled(prev)
    best = {arm: min(w) for arm, w in walls.items()}
    qps = {arm: N_REQUESTS / w for arm, w in best.items()}
    # rids increment globally across trials; submission order (rid order
    # within a trial) is the stable alignment for the parity probe
    mismatch = any(
        not (np.array_equal(a.ids, b.ids) and np.array_equal(a.dists, b.dists))
        for a, b in zip(results["off"], results["on"])
    )
    overhead = best["on"] / best["off"] - 1.0
    out(
        f"obs overhead: off={qps['off']:.0f} qps on={qps['on']:.0f} qps "
        f"({overhead * 100:+.1f}%), bitwise={'FAIL' if mismatch else 'ok'}"
    )
    return {
        "n": n,
        "n_requests": N_REQUESTS,
        "trials": TRIALS,
        "qps_off": qps["off"],
        "qps_on": qps["on"],
        "qps_explain_arm": N_REQUESTS / wall_explain,
        "overhead_frac": overhead,
        "bitwise_identical": not mismatch,
        "monitor_snapshots": len(svc.monitor.ring),
        "service_stats": svc.stats(),
    }, svc


def run(dataset: str = "SYN-EASY", out=print):
    summary, _svc = measure(out=out)
    rows = [
        {"arm": "off", "qps": summary["qps_off"], "n_requests": N_REQUESTS},
        {"arm": "on", "qps": summary["qps_on"], "n_requests": N_REQUESTS},
        {"arm": "explain", "qps": summary["qps_explain_arm"], "n_requests": N_REQUESTS},
        {"arm": "summary", "qps": summary["qps_on"], **summary},
    ]
    return rows


def selfcheck(out=print) -> int:
    """Blocking CI gate: obs-on serving QPS within 5% of obs-off (with
    timeseries snapshotting, SLO evaluation and health watchdogs ticking
    in the on arm), bitwise result parity across arms, a populated
    snapshot ring + health report, and schema-valid metrics AND
    timeseries exports."""
    from repro.obs import timeseries as obs_ts

    failures = []
    summary, svc = measure(n=800, out=out)
    if not summary["bitwise_identical"]:
        failures.append("obs on/off results differ bitwise")
    if summary["qps_on"] < (1.0 - TOLERANCE) * summary["qps_off"]:
        failures.append(
            f"obs-on QPS {summary['qps_on']:.0f} < "
            f"{(1 - TOLERANCE) * summary['qps_off']:.0f} "
            f"(95% of obs-off {summary['qps_off']:.0f})"
        )
    # the measure() run recorded with obs on — the export must validate
    payload = obs_reg.registry().to_json()
    if not payload["metrics"]:
        failures.append("registry export empty after an obs-on run")
    errs = obs_reg.validate_export(payload)
    failures.extend(f"metrics export: {e}" for e in errs)
    # the continuous-monitoring layer must have actually run in the on
    # arms: snapshots in the ring, a health report, a valid ts export
    if len(svc.monitor.ring) < 2:
        failures.append(
            f"monitor ring holds {len(svc.monitor.ring)} snapshots (< 2) "
            "after the obs-on arms"
        )
    if svc.monitor.last_report is None:
        failures.append("monitor produced no health report")
    ts_payload = svc.monitor.ring.to_json()
    if not ts_payload["series"]:
        failures.append("timeseries export has no derived series")
    ts_errs = obs_ts.validate_timeseries_export(ts_payload)
    failures.extend(f"timeseries export: {e}" for e in ts_errs)
    if failures:
        for f in failures:
            out(f"FAIL bench_obs selfcheck: {f}")
        return 1
    health = svc.monitor.last_report
    out(
        f"ok bench_obs selfcheck: overhead {summary['overhead_frac'] * 100:+.1f}% "
        f"(tolerance {TOLERANCE * 100:.0f}%), bitwise parity, "
        f"{len(payload['metrics'])} metrics schema-valid, "
        f"{len(svc.monitor.ring)} snapshots / {len(ts_payload['series'])} "
        f"derived series, health={health.status}"
    )
    return 0


def main(argv: list[str] | None = None):
    args = sys.argv[1:] if argv is None else argv
    if "--selfcheck" in args:
        sys.exit(selfcheck())
    run()


if __name__ == "__main__":
    main()
