"""Multi-tenant serving bench: zipfian hot/cold collections behind one
CollectionService.

The tenancy layer's three load-bearing claims, measured:

  * **Executable sharing** — N collections sharing one ShapePolicy
    occupy shape buckets, not tenants x buckets: total compiles (and the
    global ``mutable_search`` jit cache delta) equals the number of
    distinct ``(B, T, A, params, rows, delta_cap)`` keys the traffic
    touched, regardless of how many tenants touched them.
  * **QoS under skew** — a zipfian tenant mix (one hot collection takes
    most of the traffic) still yields per-tenant p50/p99 in the same
    regime, because weighted-fair scheduling charges the hot tenant for
    its extra batches instead of letting it starve the cold ones.
  * **Semantic result cache** — repeated (query, pred, k) traffic is
    answered from the exact tier without touching the engine; the bench
    reports per-tenant hit rates alongside the latency quantiles so the
    cache's contribution is attributable.

``--selfcheck`` is the blocking CI gate (ISSUE 10 acceptance): >= 3
collections sharing one ShapePolicy must compile exactly once per
occupied shape bucket; overload must produce typed ``Rejected`` results
with ``compass_shed_total`` incremented; exact-tier cache hits must be
bitwise-identical to an uncached search and invalidated by the owning
collection's epoch swap (and only that collection's).  Exit 1 on any
failure.
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

from repro.compass import (
    BuildConfig,
    CollectionService,
    CompassParams,
    MutableIndex,
    Pred,
    Rejected,
    ShapePolicy,
    stack_predicates,
)
from repro.core.mutable import mutable_search
from repro.obs import registry as obs_reg

from . import common as C

N_TENANTS = int(os.environ.get("REPRO_BENCH_TENANTS", 4))
N_REQUESTS = 240  # zipfian stream length
POOL = 24  # distinct (query, pred) pairs per tenant — repeats hit the cache
ZIPF_S = 1.2  # tenant popularity exponent (hot/cold skew)
D = 16
N_ATTRS = 4
BURST = 16  # requests submitted between scheduling rounds


def _zipf_weights(n: int, s: float) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1) ** s
    return w / w.sum()


def _build_service(seed: int = 0, n_tenants: int = N_TENANTS):
    """>= 3 mutable collections of *different* corpus sizes that all
    collapse into one ShapePolicy row bucket — the precondition for
    cross-tenant executable sharing."""
    rng = np.random.default_rng(seed)
    shape = ShapePolicy(min_rows=1024, delta_cap=64)
    pm = CompassParams(k=10, ef=32, backend=C.BACKEND, shape=shape)
    svc = CollectionService(pm, batch_size=8, max_wait_s=0.0)
    names = [f"t{i}" for i in range(n_tenants)]
    sizes = [900 - 120 * (i % 4) for i in range(n_tenants)]
    clients = {}
    for i, (name, n) in enumerate(zip(names, sizes)):
        x = rng.normal(size=(n, D)).astype(np.float32)
        at = rng.uniform(size=(n, N_ATTRS)).astype(np.float32)
        mut = MutableIndex.build(
            x, at, BuildConfig(m=8, nlist=16, kmeans_iters=3),
            delta_cap=64, shape=shape,
        )
        # the hot tenant (zipf rank 0) gets the largest fair share
        clients[name] = svc.create(
            name, mut, weight=4.0 if i == 0 else 1.0, cache_capacity=256
        )
    # per-tenant request pool: half conjunctive (T=1), half disjunctive
    # (T=2) — two predicate-shape buckets shared by every tenant
    pools = {}
    for name in names:
        pool = []
        for j in range(POOL):
            q = rng.normal(size=D).astype(np.float32)
            a = j % N_ATTRS
            pred = (
                Pred.range(a, 0.1, 0.7)
                if j % 2 == 0
                else Pred.or_(Pred.le(a, 0.3), Pred.ge(a, 0.8))
            )
            pool.append((q, pred.tensor(N_ATTRS)))
        pools[name] = pool
    return svc, clients, pools, names


def measure(seed: int = 0, n_requests: int = N_REQUESTS, out=print) -> dict:
    svc, clients, pools, names = _build_service(seed)
    rng = np.random.default_rng(seed + 1)
    tw = _zipf_weights(len(names), ZIPF_S)

    # warmup: occupy both shape buckets once so the measured stream is
    # steady-state serving, not compilation
    for name in names[:1]:
        for q, pred in pools[name][:2]:
            clients[name].submit(q, pred)
    svc.run_until_idle()
    warm_compiles = svc.compile_count
    jit0 = mutable_search._cache_size()

    lat = {name: [] for name in names}
    n_sub = {name: 0 for name in names}
    t0 = time.perf_counter()
    submitted = 0
    while submitted < n_requests:
        for _ in range(min(BURST, n_requests - submitted)):
            name = names[rng.choice(len(names), p=tw)]
            q, pred = pools[name][rng.integers(0, POOL)]
            r = clients[name].submit(q, pred)
            n_sub[name] += 1
            submitted += 1
            assert not isinstance(r, Rejected)  # depth 1024 >> burst
        for res in svc.step():
            lat[res.collection].append(res.queue_wait_s + res.batch_exec_s)
    for res in svc.run_until_idle():
        lat[res.collection].append(res.queue_wait_s + res.batch_exec_s)
    wall = time.perf_counter() - t0

    jit_delta = mutable_search._cache_size() - jit0
    stats = svc.stats()
    per_tenant = {}
    for name in names:
        arr = np.array(lat[name]) if lat[name] else np.array([0.0])
        cs = stats["collections"][name]
        per_tenant[name] = {
            "tenant": name,
            "weight": cs["weight"],
            "n_requests": cs["n_requests"],
            "n_shed": cs["n_shed"],
            "p50_ms": float(np.percentile(arr, 50) * 1e3),
            "p99_ms": float(np.percentile(arr, 99) * 1e3),
            "cache_hit_rate": cs["cache"]["hit_rate"],
            "qps": cs["n_requests"] / wall if wall else 0.0,
        }
    agg_lat = np.array([v for vs in lat.values() for v in vs] or [0.0])
    hits = sum(stats["collections"][n]["cache"]["hits_exact"] for n in names)
    looked = sum(
        stats["collections"][n]["cache"]["hits_exact"]
        + stats["collections"][n]["cache"]["misses"]
        for n in names
    )
    summary = {
        "n_tenants": len(names),
        "n_requests": n_requests,
        "qps": n_requests / wall if wall else 0.0,
        "p50_ms": float(np.percentile(agg_lat, 50) * 1e3),
        "p99_ms": float(np.percentile(agg_lat, 99) * 1e3),
        "cache_hit_rate": hits / looked if looked else 0.0,
        "n_compiles": svc.compile_count,
        "warm_compiles": warm_compiles,
        "steady_state_compiles": svc.compile_count - warm_compiles,
        "jit_cache_delta": jit_delta,
        "occupied_shape_buckets": svc.compile_count,
        "tenants_x_buckets": len(names) * max(
            len(stats["collections"][n]["buckets"]) for n in names
        ),
        "per_tenant": per_tenant,
    }
    out(
        f"tenancy: {len(names)} tenants, {n_requests} reqs @ "
        f"{summary['qps']:.0f} qps, p50 {summary['p50_ms']:.1f}ms "
        f"p99 {summary['p99_ms']:.1f}ms, cache hit {summary['cache_hit_rate']:.0%}, "
        f"{summary['n_compiles']} compiles for "
        f"{summary['tenants_x_buckets']} tenant-buckets"
    )
    return summary, svc, clients, pools


def run(dataset: str = "SYN-EASY", out=print):
    summary, _svc, _clients, _pools = measure(out=out)
    rows = [dict(v) for v in summary["per_tenant"].values()]
    agg = {k: v for k, v in summary.items() if k != "per_tenant"}
    rows.append({"tenant": "_aggregate", **agg})
    return rows


def selfcheck(out=print) -> int:
    """Blocking CI gate — the ISSUE 10 acceptance criteria, executed."""
    failures: list[str] = []
    prev = obs_reg.set_enabled(True)
    try:
        rng = np.random.default_rng(7)
        shape = ShapePolicy(min_rows=512, delta_cap=64)
        pm = CompassParams(k=8, ef=32, backend=C.BACKEND, shape=shape)
        svc = CollectionService(pm, batch_size=4, max_wait_s=0.0)
        clients = {}
        for name, n in (("a", 300), ("b", 420), ("c", 360)):
            x = rng.normal(size=(n, D)).astype(np.float32)
            at = rng.uniform(size=(n, N_ATTRS)).astype(np.float32)
            mut = MutableIndex.build(
                x, at, BuildConfig(m=8, nlist=8, kmeans_iters=3),
                delta_cap=64, shape=shape,
            )
            clients[name] = svc.create(name, mut, cache_capacity=64)

        # -- 1. compiles == occupied shape buckets, not tenants x buckets
        jit0 = mutable_search._cache_size()
        preds = [Pred.range(0, 0.1, 0.8), Pred.or_(Pred.le(1, 0.3), Pred.ge(1, 0.8))]
        queries = {}
        for name, cl in clients.items():
            for j in range(4):
                q = rng.normal(size=D).astype(np.float32)
                cl.submit(q, preds[j % 2], k=5)
                queries.setdefault(name, []).append((q, preds[j % 2]))
        svc.run_until_idle()
        jit_delta = mutable_search._cache_size() - jit0
        occupied = svc.compile_count
        if occupied != 2:
            failures.append(
                f"3 same-shape tenants across 2 predicate buckets occupy "
                f"{occupied} shape keys, expected 2"
            )
        if jit_delta != occupied:
            failures.append(
                f"jit cache grew by {jit_delta} != {occupied} occupied shape keys "
                "(tenants are not sharing compiled programs)"
            )
        if occupied >= len(clients) * 2:
            failures.append(
                f"compiles {occupied} >= tenants x buckets {len(clients) * 2}"
            )

        # -- 2. exact-tier cache hit: bitwise parity with uncached search
        q0, p0 = queries["a"][0]
        rid1 = clients["a"].submit(q0, p0, k=5)
        svc.run_until_idle()
        r1 = svc.poll(rid1)
        if r1 is None or r1.cache_tier != "exact":
            failures.append(
                f"repeat submission served from tier {getattr(r1, 'cache_tier', None)!r}, "
                "expected 'exact'"
            )
        else:
            col = svc._col("a")
            direct = col.mutable.search(
                q0[None], stack_predicates([p0.tensor(N_ATTRS)]), col.params
            )
            ids_direct = np.asarray(direct.ids)[0, :5]
            dists_direct = np.asarray(direct.dists)[0, :5]
            if not np.array_equal(r1.ids, ids_direct):
                failures.append("exact-tier hit ids != uncached search ids")
            if not np.array_equal(
                r1.dists.view(np.uint32), dists_direct.view(np.uint32)
            ):
                failures.append("exact-tier hit dists not bitwise-equal to uncached")

        # -- 3. epoch swap invalidates the owning collection (and only it)
        b_entries_before = svc._col("b").cache.stats()["entries_exact"]
        svc.compact("a")
        rid2 = clients["a"].submit(q0, p0, k=5)
        svc.run_until_idle()
        r2 = svc.poll(rid2)
        if r2 is None or r2.cache_tier is not None:
            failures.append(
                f"post-compaction submission served from tier "
                f"{getattr(r2, 'cache_tier', None)!r}, expected a live search"
            )
        elif r2.epoch != svc._col("a").mutable.epoch:
            failures.append("post-compaction result pinned to a stale epoch")
        if svc._col("b").cache.stats()["entries_exact"] != b_entries_before:
            failures.append("collection A's epoch swap touched collection B's cache")
        # the compacted shapes must have stayed inside the occupied keys
        if svc.compile_count != occupied:
            failures.append(
                f"compaction changed compile count {occupied} -> {svc.compile_count} "
                "(ShapePolicy not holding shapes stable)"
            )

        # -- 4. overload -> typed Rejected + compass_shed_total
        x = rng.normal(size=(280, D)).astype(np.float32)
        at = rng.uniform(size=(280, N_ATTRS)).astype(np.float32)
        mut = MutableIndex.build(
            x, at, BuildConfig(m=8, nlist=8, kmeans_iters=3),
            delta_cap=64, shape=shape,
        )
        bcl = svc.create("burst", mut, max_queue_depth=4)
        outcomes = [
            bcl.submit(rng.normal(size=D).astype(np.float32), preds[0])
            for _ in range(10)
        ]
        shed = [o for o in outcomes if isinstance(o, Rejected)]
        if len(shed) != 6:
            failures.append(f"10 submissions over depth 4 shed {len(shed)}, expected 6")
        if shed and not all(
            s.reason == "queue_depth" and s.collection == "burst" and s.limit == 4
            for s in shed
        ):
            failures.append("Rejected results carry wrong reason/collection/limit")
        c = obs_reg.registry().get("compass_shed_total")
        got = 0.0 if c is None else c.value(tenant="burst")
        if got != len(shed):
            failures.append(
                f"compass_shed_total{{tenant='burst'}} == {got}, expected {len(shed)}"
            )
        svc.run_until_idle()  # drain the admitted remainder
        errs = obs_reg.validate_export(obs_reg.registry().to_json())
        failures.extend(f"metrics export: {e}" for e in errs)
    finally:
        obs_reg.set_enabled(prev)

    if failures:
        for f in failures:
            out(f"FAIL bench_tenancy selfcheck: {f}")
        return 1
    out(
        f"ok bench_tenancy selfcheck: {occupied} compiles for 3 tenants x 2 "
        f"buckets (jit delta {jit_delta}), exact-tier bitwise parity, "
        f"epoch-swap invalidation scoped to owner, {len(shed)} typed sheds "
        "counted per tenant"
    )
    return 0


def main(argv: list[str] | None = None):
    args = sys.argv[1:] if argv is None else argv
    if "--selfcheck" in args:
        sys.exit(selfcheck())
    run()


if __name__ == "__main__":
    main()
