"""Planner selectivity sweep: chosen execution mode, QPS, and recall as the
predicate pass rate walks from 1.0 down to 1e-3, single- and
multi-attribute (the crossover experiment behind DESIGN.md §Planner).

Each point runs the same workload twice — planner-enabled vs
forced-COOPERATIVE (``planner=False``, i.e. the pre-planner engine) — so a
row directly exhibits the mode the cost model picked and what it bought.
Timed runs are preceded by an untimed warmup call so QPS measures
steady-state execution, not XLA compilation (both arms equally).
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core.planner.plan import MODE_NAMES
from repro.compass import CompassParams, compass_search

from . import common as C

# overall target pass rates for the sweep (paper regime: robust from
# vacuous filters down to needle-in-haystack)
PASSRATES = (1.0, 0.5, 0.2, 0.1, 0.03, 0.01, 0.003, 0.001)
EF = 64


def _timed(idx, qj, pred, pm):
    res = compass_search(idx, qj, pred, pm)  # warmup: compile + cache
    res.ids.block_until_ready()
    t0 = time.time()
    res = compass_search(idx, qj, pred, pm)
    res.ids.block_until_ready()
    wall = time.time() - t0
    return res, wall


def _mode_counts(res) -> dict:
    modes = np.asarray(res.stats.mode)
    return {name: int(np.sum(modes == m)) for m, name in enumerate(MODE_NAMES)}


def run(dataset: str = "SYN-EASY", out=print):
    idx_host, _ = C.get_index(dataset)
    idx = C.index_to_device(idx_host)
    x, attrs, queries = C.get_dataset(dataset)
    qj = jnp.asarray(queries)
    rng = np.random.default_rng(0)
    out(f"# planner sweep dataset={dataset} ef={EF} n={C.N}")
    out("workload,passrate,modes,planner_qps,cooperative_qps,planner_recall,cooperative_recall")
    rows = []
    for workload, n_terms in (("single", 1), ("multi", 2)):
        for target in PASSRATES:
            per_attr = target ** (1.0 / n_terms)  # conjunction of U[0,1] ranges
            pred = C.make_workload(rng, C.N_QUERIES, per_attr, n_terms, disj=False)
            truth = C.ground_truth(x, attrs, queries, pred)
            pm_on = CompassParams(k=C.K, ef=EF, planner=True, backend=C.BACKEND)
            pm_off = CompassParams(k=C.K, ef=EF, planner=False, backend=C.BACKEND)
            res_on, wall_on = _timed(idx, qj, pred, pm_on)
            res_off, wall_off = _timed(idx, qj, pred, pm_off)
            rr_on = C._finish("planner", EF, res_on, truth, C.N, wall_on)
            rr_off = C._finish("cooperative", EF, res_off, truth, C.N, wall_off)
            modes = _mode_counts(res_on)
            row = {
                "workload": workload,
                "n_terms": n_terms,
                "passrate": target,
                "mode_counts": modes,
                "planner": dataclasses.asdict(rr_on),
                "cooperative": dataclasses.asdict(rr_off),
            }
            rows.append(row)
            mode_str = "/".join(f"{k}:{v}" for k, v in modes.items() if v)
            out(
                f"{workload},{target},{mode_str},{rr_on.qps:.1f},{rr_off.qps:.1f},"
                f"{rr_on.recall:.4f},{rr_off.recall:.4f}"
            )
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
