"""Paper Figs. 8-10: recall vs #Comp/QPS curves by sweeping ef, at three
single-attribute selectivities: 80% (not selective), 30% (default), 1%
(selective)."""
from __future__ import annotations

import numpy as np

from . import common as C


def run(dataset: str = "SYN-EASY", out=print):
    idx_host, _ = C.get_index(dataset)
    idx = C.index_to_device(idx_host)
    x, attrs, queries = C.get_dataset(dataset)
    rng = np.random.default_rng(2)
    out(f"# qps_recall dataset={dataset}")
    out("selectivity,method,ef,recall,ndist,us_per_query,qps")
    rows = []
    for passrate in (0.8, 0.3, 0.01):
        pred = C.make_workload(rng, C.N_QUERIES, passrate, 1, disj=False)
        truth = C.ground_truth(x, attrs, queries, pred)
        for method in ("compass", "navix", "prefilter"):
            efs = C.EF_SWEEP if method != "prefilter" else (0,)
            for ef in efs:
                rr = C.run_method(method, idx, x, attrs, queries, pred, ef, truth)
                out(
                    f"{passrate},{method},{ef},{rr.recall:.4f},{rr.n_dist:.0f},"
                    f"{rr.wall_s*1e6/C.N_QUERIES:.0f},{rr.qps:.1f}"
                )
                rows.append((passrate, method, rr))
                if rr.recall >= 0.999:
                    break
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
