"""Validate BENCH_*.json artifacts — the CI gate behind bench-smoke.

Every file must parse as JSON and carry the provenance envelope written by
``benchmarks/run.py`` (``bench`` / ``meta`` / ``wall_s`` / ``rows`` with the
engine-version + backend fields from ``common.bench_metadata``), so a
malformed or provenance-free artifact fails the workflow instead of
silently polluting the benchmark trajectory.

  python -m benchmarks.validate [dir]
"""
from __future__ import annotations

import glob
import json
import os
import sys

REQUIRED = ("bench", "meta", "wall_s", "rows")
META_REQUIRED = ("engine_version", "backend", "platform", "jax_version", "n")

# Per-bench row schemas: every row of the named bench must be an object
# carrying these keys (benches whose rows are positional tuples are not
# listed — their shape is covered by the envelope check alone).
ROW_REQUIRED = {
    "bench_planner": ("workload", "passrate", "mode_counts", "planner", "cooperative"),
    # every updates row carries a phase, a qps figure and the compile
    # accounting (the shape-stable serving claim is only a claim if the
    # recompile count ships in the artifact); search rows add
    # workload/recall + p50/p99 latency, the steady_state row the
    # occupied-bucket/per-round compile breakdown, the writes row the
    # compaction profile
    "bench_updates": ("phase", "qps", "n_compiles", "n_cache_hits"),
    # sweep rows add recall_vs_exact + quant/exact RunResults; scan rows
    # (workload == "scan") add adc_scan/exact_scan QPS instead
    "bench_quant": ("workload", "m", "refine_factor", "bytes_per_vector"),
    # visit_step rows add fused/unfused qps arms, pq/ivf rows pallas/ref
    # arms; the trailing autotune_table row carries the measured block table
    "bench_kernels": ("kernel", "metric", "d", "v"),
    # off/on/explain arms plus a summary row with the overhead fraction
    "bench_obs": ("arm", "qps"),
}


def _validate_rows(bench: str, rows) -> list[str]:
    required = ROW_REQUIRED.get(bench)
    if required is None:
        return []
    if not isinstance(rows, list) or not rows:
        return [f"{bench}: rows must be a non-empty list"]
    errs = []
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errs.append(f"{bench}: row {i} is {type(row).__name__}, expected object")
            continue
        errs.extend(f"{bench}: row {i} missing {k!r}" for k in required if k not in row)
    return errs


def validate_file(path: str) -> list[str]:
    """Returns a list of problems (empty == valid)."""
    errs = []
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable/malformed JSON: {e}"]
    if not isinstance(payload, dict):
        return [f"top level is {type(payload).__name__}, expected object"]
    for key in REQUIRED:
        if key not in payload:
            errs.append(f"missing key {key!r}")
    meta = payload.get("meta")
    if not isinstance(meta, dict):
        errs.append("meta is not an object")
    else:
        errs.extend(f"meta missing {k!r}" for k in META_REQUIRED if k not in meta)
    if "wall_s" in payload and not isinstance(payload["wall_s"], (int, float)):
        errs.append("wall_s is not numeric")
    if "bench" in payload and "rows" in payload:
        errs.extend(_validate_rows(payload["bench"], payload["rows"]))
    return errs


def main(argv: list[str] | None = None) -> int:
    args = sys.argv[1:] if argv is None else argv
    bench_dir = args[0] if args else os.path.dirname(os.path.abspath(__file__))
    paths = sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json")))
    if not paths:
        print(f"FAIL: no BENCH_*.json files under {bench_dir}")
        return 1
    bad = 0
    for path in paths:
        errs = validate_file(path)
        if errs:
            bad += 1
            for e in errs:
                print(f"FAIL {os.path.basename(path)}: {e}")
        else:
            print(f"ok   {os.path.basename(path)}")
    # the metrics-registry export rides next to the bench artifacts and has
    # its own schema (repro.obs.metrics/v1) — validate it when present
    mpath = os.path.join(bench_dir, "METRICS.json")
    n_extra = 0
    if os.path.exists(mpath):
        from repro.obs import registry as obs_reg

        n_extra = 1
        errs = obs_reg.validate_file(mpath)
        if errs:
            bad += 1
            for e in errs:
                print(f"FAIL METRICS.json: {e}")
        else:
            print("ok   METRICS.json")
    print(f"{len(paths) + n_extra - bad}/{len(paths) + n_extra} artifacts valid")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
