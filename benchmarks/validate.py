"""Validate BENCH_*.json artifacts — the CI gate behind bench-smoke.

Every file must parse as JSON and carry the provenance envelope written by
``benchmarks/run.py`` (``bench`` / ``meta`` / ``wall_s`` / ``rows`` with the
engine-version + backend fields from ``common.bench_metadata``), so a
malformed or provenance-free artifact fails the workflow instead of
silently polluting the benchmark trajectory.

  python -m benchmarks.validate [dir]
"""
from __future__ import annotations

import glob
import json
import os
import sys

REQUIRED = ("bench", "meta", "wall_s", "rows")
META_REQUIRED = ("engine_version", "backend", "platform", "jax_version", "n")

#: the perf-trajectory row schema appended by ``run.py`` to
#: BENCH_HISTORY.jsonl — one row per bench run, carrying the same
#: provenance block as the per-run artifacts plus per-bench wall time and
#: the extract_qps label map the baseline diff consumes
HISTORY_SCHEMA = "repro.bench.history/v1"


def validate_history_row(row) -> list[str]:
    """Schema-check one BENCH_HISTORY.jsonl row (empty == valid)."""
    if not isinstance(row, dict):
        return [f"history row is {type(row).__name__}, expected object"]
    errs = []
    if row.get("schema") != HISTORY_SCHEMA:
        errs.append(f"schema is {row.get('schema')!r}, expected {HISTORY_SCHEMA!r}")
    if not isinstance(row.get("ts"), (int, float)):
        errs.append("ts is not numeric")
    meta = row.get("meta")
    if not isinstance(meta, dict):
        errs.append("meta is not an object")
    else:
        errs.extend(f"meta missing {k!r}" for k in META_REQUIRED if k not in meta)
    benches = row.get("benches")
    if not isinstance(benches, dict) or not benches:
        errs.append("benches is not a non-empty object")
        return errs
    for name, info in benches.items():
        if not isinstance(info, dict):
            errs.append(f"benches[{name}] is not an object")
            continue
        if not isinstance(info.get("wall_s"), (int, float)):
            errs.append(f"benches[{name}].wall_s is not numeric")
        qps = info.get("qps")
        if not isinstance(qps, dict) or any(
            not isinstance(k, str)
            or not isinstance(v, (int, float))
            or isinstance(v, bool)
            for k, v in qps.items()
        ):
            errs.append(f"benches[{name}].qps is not a str->number map")
    return errs


def validate_history_file(path: str) -> list[str]:
    """Every row of a BENCH_HISTORY.jsonl must parse and pass the row
    schema; an empty file is invalid (the trajectory must be non-empty
    once the file exists)."""
    try:
        with open(path) as f:
            lines = [ln for ln in f.read().splitlines() if ln.strip()]
    except OSError as e:
        return [f"unreadable: {e}"]
    if not lines:
        return ["history file exists but holds no rows"]
    errs = []
    for i, ln in enumerate(lines):
        try:
            row = json.loads(ln)
        except json.JSONDecodeError as e:
            errs.append(f"row {i}: malformed JSON: {e}")
            continue
        errs.extend(f"row {i}: {e}" for e in validate_history_row(row))
    return errs

# Per-bench row schemas: every row of the named bench must be an object
# carrying these keys (benches whose rows are positional tuples are not
# listed — their shape is covered by the envelope check alone).
ROW_REQUIRED = {
    "bench_planner": ("workload", "passrate", "mode_counts", "planner", "cooperative"),
    # every updates row carries a phase, a qps figure and the compile
    # accounting (the shape-stable serving claim is only a claim if the
    # recompile count ships in the artifact); search rows add
    # workload/recall + p50/p99 latency, the steady_state row the
    # occupied-bucket/per-round compile breakdown, the writes row the
    # compaction profile
    "bench_updates": ("phase", "qps", "n_compiles", "n_cache_hits"),
    # sweep rows add recall_vs_exact + quant/exact RunResults; scan rows
    # (workload == "scan") add adc_scan/exact_scan QPS instead
    "bench_quant": ("workload", "m", "refine_factor", "bytes_per_vector"),
    # visit_step rows add fused/unfused qps arms, pq/ivf rows pallas/ref
    # arms; the trailing autotune_table row carries the measured block table
    "bench_kernels": ("kernel", "metric", "d", "v"),
    # off/on/explain arms plus a summary row with the overhead fraction
    "bench_obs": ("arm", "qps"),
    # one row per tenant (zipfian hot/cold mix) plus a trailing
    # "_aggregate" row that adds the shared-executable compile accounting
    # (n_compiles / occupied_shape_buckets / tenants_x_buckets)
    "bench_tenancy": ("tenant", "n_requests", "p50_ms", "p99_ms",
                      "cache_hit_rate", "qps"),
}


def _validate_rows(bench: str, rows) -> list[str]:
    required = ROW_REQUIRED.get(bench)
    if required is None:
        return []
    if not isinstance(rows, list) or not rows:
        return [f"{bench}: rows must be a non-empty list"]
    errs = []
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            errs.append(f"{bench}: row {i} is {type(row).__name__}, expected object")
            continue
        errs.extend(f"{bench}: row {i} missing {k!r}" for k in required if k not in row)
    return errs


def validate_file(path: str) -> list[str]:
    """Returns a list of problems (empty == valid)."""
    errs = []
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable/malformed JSON: {e}"]
    if not isinstance(payload, dict):
        return [f"top level is {type(payload).__name__}, expected object"]
    for key in REQUIRED:
        if key not in payload:
            errs.append(f"missing key {key!r}")
    meta = payload.get("meta")
    if not isinstance(meta, dict):
        errs.append("meta is not an object")
    else:
        errs.extend(f"meta missing {k!r}" for k in META_REQUIRED if k not in meta)
    if "wall_s" in payload and not isinstance(payload["wall_s"], (int, float)):
        errs.append("wall_s is not numeric")
    if "bench" in payload and "rows" in payload:
        errs.extend(_validate_rows(payload["bench"], payload["rows"]))
    return errs


def main(argv: list[str] | None = None) -> int:
    args = sys.argv[1:] if argv is None else argv
    bench_dir = args[0] if args else os.path.dirname(os.path.abspath(__file__))
    paths = sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json")))
    if not paths:
        print(f"FAIL: no BENCH_*.json files under {bench_dir}")
        return 1
    bad = 0
    for path in paths:
        errs = validate_file(path)
        if errs:
            bad += 1
            for e in errs:
                print(f"FAIL {os.path.basename(path)}: {e}")
        else:
            print(f"ok   {os.path.basename(path)}")
    # the observability exports ride next to the bench artifacts with
    # their own schemas (repro.obs.metrics/v1, repro.obs.timeseries/v1) —
    # validate them when present, schema-dispatched
    n_extra = 0
    from repro.obs.validate import validate_any_file

    for extra in ("METRICS.json", "TIMESERIES.json"):
        epath = os.path.join(bench_dir, extra)
        if not os.path.exists(epath):
            continue
        n_extra += 1
        errs = validate_any_file(epath)
        if errs:
            bad += 1
            for e in errs:
                print(f"FAIL {extra}: {e}")
        else:
            print(f"ok   {extra}")
    hpath = os.path.join(bench_dir, "BENCH_HISTORY.jsonl")
    if os.path.exists(hpath):
        n_extra += 1
        errs = validate_history_file(hpath)
        if errs:
            bad += 1
            for e in errs:
                print(f"FAIL BENCH_HISTORY.jsonl: {e}")
        else:
            print("ok   BENCH_HISTORY.jsonl")
    print(f"{len(paths) + n_extra - bad}/{len(paths) + n_extra} artifacts valid")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
