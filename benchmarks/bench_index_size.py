"""Paper Table IV: index sizes.  Compass stores ONE graph + IVF + clustered
per-attribute sorted permutations; a SeRF-style specialized 1D index
duplicates the vector-graph component once per attribute; NaviX equals a
plain HNSW of doubled bottom-layer degree."""
from __future__ import annotations

import numpy as np

from . import common as C


def _bytes(tree) -> int:
    import jax

    return int(sum(np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(tree)))


def run(out=print):
    idx_host, build_s = C.get_index("SYN-EASY")
    idx = idx_host
    out("# index_size (MiB), dataset=SYN-EASY")
    graph_b = _bytes(idx.graph)
    ivf_b = _bytes((idx.centroids, idx.medoids))
    battrs_b = _bytes(idx.cattrs)
    vectors_b = _bytes(idx.vectors)
    compass_total = graph_b + ivf_b + battrs_b
    # SeRF-style: one graph-index clone per attribute (the paper's x4)
    serf_total = C.N_ATTRS * graph_b
    # NaviX: HNSW with doubled bottom-layer degree (paper §V.B: M doubles)
    navix_total = 2 * graph_b
    mib = 1 / (1 << 20)
    out(f"vectors(raw),{vectors_b*mib:.1f}")
    out(f"compass_graph,{graph_b*mib:.1f}")
    out(f"compass_ivf,{ivf_b*mib:.1f}")
    out(f"compass_clustered_btrees,{battrs_b*mib:.1f}")
    out(f"compass_total,{compass_total*mib:.1f}")
    out(f"serf_x{C.N_ATTRS}_total,{serf_total*mib:.1f}")
    out(f"navix_total,{navix_total*mib:.1f}")
    out(f"compass_build_seconds,{build_s:.1f}")
    return {
        "compass": compass_total,
        "serf": serf_total,
        "navix": navix_total,
    }


def main():
    run()


if __name__ == "__main__":
    main()
