"""Multi-tenant serving tests: the CollectionService front door.

Contracts under test (ISSUE 10): exact-tier result-cache hits are
bitwise-identical to an uncached search and invalidated by the owning
collection's epoch swap only; the near-duplicate tier keys on the
collection's *own* PQ codes and never serves across collections;
interleaved writes to different collections never surface each other's
gids; executables are shared across tenants whose shape keys collapse;
overload sheds with a typed ``Rejected``; weighted-fair scheduling gives
a hot tenant its configured share; and the widened ``(bucket, shard,
tenant)`` obs schema stays back-compatible with old exports.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np
import pytest

from repro.core import predicate as P
from repro.core.index import BuildConfig, build_index
from repro.core.mutable import MutableIndex, mutable_search
from repro.core.quant import QuantConfig
from repro.core.quant.encode import encode_rows, quantize_index
from repro.compass import (
    CollectionClient,
    CollectionService,
    CompassParams,
    Rejected,
    ShapePolicy,
)
from repro.obs import events as obs_ev
from repro.obs import health as obs_h
from repro.obs import registry as obs_reg
from repro.obs import slo as obs_slo
from repro.obs import timeseries as obs_ts
from repro.serving.rag import RagIndex

D = 8
N_ATTRS = 4
SHAPE = ShapePolicy(min_rows=512, delta_cap=32)
PM = CompassParams(k=8, ef=16, shape=SHAPE)
CFG = BuildConfig(m=8, nlist=8, kmeans_iters=2)


@pytest.fixture(autouse=True)
def _isolated_obs():
    """Same isolation contract as test_obs: clean registry, obs off, no
    leakage of enablement into the rest of the suite."""
    prev = obs_reg.set_enabled(False)
    obs_reg.reset()
    obs_ev.EVENTS.clear()
    yield
    obs_reg.set_enabled(prev)
    obs_reg.reset()
    obs_ev.EVENTS.clear()


def _mut(n: int, seed: int, gid_base: int = 0) -> MutableIndex:
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, D)).astype(np.float32)
    at = rng.uniform(size=(n, N_ATTRS)).astype(np.float32)
    return MutableIndex.build(
        x, at, CFG, delta_cap=32, shape=SHAPE,
        gids=np.arange(gid_base, gid_base + n, dtype=np.int64),
    )


def _svc(**kw) -> CollectionService:
    kw.setdefault("batch_size", 4)
    kw.setdefault("max_wait_s", 0.0)
    return CollectionService(PM, **kw)


def _qp(seed: int = 0):
    rng = np.random.default_rng(seed)
    q = rng.normal(size=D).astype(np.float32)
    pred = P.Pred.range(0, 0.1, 0.9)
    return q, pred


def _result_of(rid, results):
    (r,) = [rr for rr in results if rr.rid == rid]
    return r


# -- exact-tier cache: bitwise parity + scoped invalidation -------------------


def test_exact_cache_hit_bitwise_identical_to_uncached():
    svc = _svc()
    client = svc.create("a", _mut(300, 0), cache_capacity=16)
    q, pred = _qp()

    r1 = _result_of(client.submit(q, pred), svc.flush())
    assert r1.cache_tier is None  # cold cache: a live search
    r2 = _result_of(client.submit(q, pred), svc.flush())
    assert r2.cache_tier == "exact"

    np.testing.assert_array_equal(r2.ids, r1.ids)
    np.testing.assert_array_equal(
        r2.dists.view(np.uint32), r1.dists.view(np.uint32)
    )
    # and both match a direct uncached search on the same snapshot
    direct = client.mutable.search(
        q[None], P.stack_predicates([pred.tensor(N_ATTRS)]), PM
    )
    np.testing.assert_array_equal(r2.ids, np.asarray(direct.ids)[0, : PM.k])
    np.testing.assert_array_equal(
        r2.dists.view(np.uint32),
        np.asarray(direct.dists)[0, : PM.k].view(np.uint32),
    )
    st = client.stats()["cache"]
    assert st["hits_exact"] == 1 and st["misses"] == 1


def test_epoch_swap_invalidates_only_the_owning_collection():
    svc = _svc()
    a = svc.create("a", _mut(300, 0), cache_capacity=16)
    b = svc.create("b", _mut(360, 1), cache_capacity=16)
    qa, pa = _qp(0)
    qb, pb = _qp(1)
    for client, q, p in ((a, qa, pa), (b, qb, pb)):
        client.submit(q, p)
    svc.flush()
    # both caches warm
    assert _result_of(a.submit(qa, pa), svc.flush()).cache_tier == "exact"
    assert _result_of(b.submit(qb, pb), svc.flush()).cache_tier == "exact"

    a.compact()  # epoch swap on A, done via the operator surface
    ra = _result_of(a.submit(qa, pa), svc.flush())
    rb = _result_of(b.submit(qb, pb), svc.flush())
    assert ra.cache_tier is None  # A's entries dropped
    assert rb.cache_tier == "exact"  # B untouched


def test_write_application_invalidates_the_writer_only():
    svc = _svc()
    a = svc.create("a", _mut(300, 0), cache_capacity=16)
    b = svc.create("b", _mut(360, 1), cache_capacity=16)
    qa, pa = _qp(0)
    qb, pb = _qp(1)
    a.submit(qa, pa)
    b.submit(qb, pb)
    svc.flush()
    rng = np.random.default_rng(7)
    a.submit_upsert(
        9000,
        rng.normal(size=D).astype(np.float32),
        rng.uniform(size=N_ATTRS).astype(np.float32),
    )
    svc.step()  # applies A's upsert -> A's cache dropped
    assert _result_of(a.submit(qa, pa), svc.flush()).cache_tier is None
    assert _result_of(b.submit(qb, pb), svc.flush()).cache_tier == "exact"


# -- near-duplicate tier: own-codebook keys, never cross-collection -----------


def _quantized_immutable(n: int, seed: int):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 16)).astype(np.float32)
    at = rng.uniform(size=(n, N_ATTRS)).astype(np.float32)
    idx = build_index(x, at, BuildConfig(m=8, nlist=8, kmeans_iters=2))
    return quantize_index(idx, QuantConfig(m=4, ks=16, iters=2))


def test_near_tier_hits_on_same_code_and_never_crosses_collections():
    pm = CompassParams(k=8, ef=16)
    svc = CollectionService(pm, batch_size=4, max_wait_s=0.0)
    ia = _quantized_immutable(400, 0)
    ib = _quantized_immutable(400, 1)
    a = svc.create("a", ia, cache_capacity=16, near_cache=True)
    b = svc.create("b", ib, cache_capacity=16, near_cache=True)

    rng = np.random.default_rng(2)
    q = rng.normal(size=16).astype(np.float32)
    q2 = q + np.float32(1e-6)  # different bytes, same PQ cell
    qv = ia.qvecs
    c1 = np.asarray(encode_rows(qv.codebooks, qv.mean, q[None]))
    c2 = np.asarray(encode_rows(qv.codebooks, qv.mean, q2[None]))
    np.testing.assert_array_equal(c1, c2)  # test precondition
    assert q.tobytes() != q2.tobytes()

    pred = P.Pred.range(0, 0.0, 1.0)
    r1 = _result_of(a.submit(q, pred), svc.flush())
    assert r1.cache_tier is None
    r2 = _result_of(a.submit(q2, pred), svc.flush())
    assert r2.cache_tier == "near"  # exact key missed, code key hit
    np.testing.assert_array_equal(r2.ids, r1.ids)

    # the same near-duplicate submitted to B must NOT see A's entry: the
    # code word is keyed on the collection's own codebooks and the cache
    # itself is per-collection
    rb = _result_of(b.submit(q2, pred), svc.flush())
    assert rb.cache_tier is None
    assert b.stats()["cache"]["hits_near"] == 0
    assert a.stats()["cache"]["hits_near"] == 1


def test_near_cache_requires_quantized_index():
    svc = _svc()
    with pytest.raises(ValueError, match="near_cache"):
        svc.create("a", _mut(300, 0), cache_capacity=16, near_cache=True)


# -- cross-tenant isolation ---------------------------------------------------


def test_interleaved_writes_never_surface_across_collections():
    obs_reg.set_enabled(True)
    svc = _svc()
    a = svc.create("a", _mut(300, 0, gid_base=0), cache_capacity=0)
    b = svc.create("b", _mut(300, 1, gid_base=100_000), cache_capacity=0)
    rng = np.random.default_rng(3)
    for i in range(8):  # interleaved writes, distinct gid spaces
        va = rng.normal(size=D).astype(np.float32)
        vb = rng.normal(size=D).astype(np.float32)
        at = rng.uniform(size=N_ATTRS).astype(np.float32)
        a.submit_upsert(10_000 + i, va, at)
        b.submit_upsert(110_000 + i, vb, at)
    svc.step()

    q, pred = _qp(4)
    ra = _result_of(a.submit(q, pred), svc.flush())
    rb = _result_of(b.submit(q, pred), svc.flush())
    ids_a = set(ra.ids[ra.ids >= 0].tolist())
    ids_b = set(rb.ids[rb.ids >= 0].tolist())
    assert ids_a and ids_b
    assert all(g < 100_000 for g in ids_a)  # only A's gid space
    assert all(g >= 100_000 for g in ids_b)  # only B's gid space
    assert not (ids_a & ids_b)

    # per-tenant accounting is disjoint under the tenant label
    reg = obs_reg.registry()
    assert reg.get("compass_submitted_total").value(tenant="a") == 1.0
    assert reg.get("compass_submitted_total").value(tenant="b") == 1.0
    served = reg.get("compass_serve_requests_total")
    tenants = {s["labels"]["tenant"] for s in served.samples()}
    assert {"a", "b"} <= tenants
    sa = svc.collection_stats("a")
    sb = svc.collection_stats("b")
    assert sa["n_upserts"] == 8 and sb["n_upserts"] == 8


# -- load shedding ------------------------------------------------------------


def test_overload_sheds_typed_rejected_and_counts_it():
    obs_reg.set_enabled(True)
    svc = _svc()
    client = svc.create("tiny", _mut(300, 0), max_queue_depth=2, cache_capacity=0)
    rng = np.random.default_rng(5)
    outcomes = []
    for i in range(6):
        q = rng.normal(size=D).astype(np.float32)
        outcomes.append(client.submit(q, _qp()[1]))
    shed = [o for o in outcomes if isinstance(o, Rejected)]
    rids = [o for o in outcomes if not isinstance(o, Rejected)]
    assert len(rids) == 2 and len(shed) == 4
    for rej in shed:
        assert rej.collection == "tiny"
        assert rej.reason == "queue_depth"
        assert rej.limit == 2 and rej.queue_depth == 2
    # accepted work still completes; nothing was silently dropped
    results = svc.flush()
    assert {r.rid for r in results} == set(rids)
    assert client.stats()["n_shed"] == 4
    reg = obs_reg.registry()
    assert reg.get("compass_shed_total").value(tenant="tiny") == 4.0
    assert reg.get("compass_submitted_total").value(tenant="tiny") == 6.0


# -- executable sharing -------------------------------------------------------


def test_executables_shared_across_same_shape_tenants():
    svc = _svc()
    clients = {
        name: svc.create(name, _mut(n, i), cache_capacity=0)
        for i, (name, n) in enumerate((("a", 300), ("b", 360), ("c", 420)))
    }
    jit0 = mutable_search._cache_size()
    q, pred = _qp(6)
    for client in clients.values():
        client.submit(q, pred)
    svc.flush()
    # three tenants, one occupied (B, T, A, rows-bucket) shape -> at most
    # one compile, shared: all three corpora fold into the 512-row bucket
    # (0 when an earlier test in this process already traced the shape —
    # the global jit cache is exactly the sharing mechanism under test)
    assert mutable_search._cache_size() - jit0 <= 1
    assert svc.compile_count == 1
    for name in clients:
        st = svc.collection_stats(name)
        assert st["compiles"] == 1
        assert st["occupied_buckets"] == 1


# -- weighted-fair scheduling -------------------------------------------------


def test_wfq_gives_the_hot_tenant_its_weighted_share():
    svc = _svc(max_batches_per_step=1)
    hot = svc.create("hot", _mut(300, 0), weight=4.0, cache_capacity=0)
    cold = svc.create("cold", _mut(360, 1), weight=1.0, cache_capacity=0)
    rng = np.random.default_rng(8)
    pred = _qp()[1]
    for _ in range(10 * svc.batch_size):  # 10 full batches per tenant
        hot.submit(rng.normal(size=D).astype(np.float32), pred)
        cold.submit(rng.normal(size=D).astype(np.float32), pred)
    order = []
    for _ in range(10):  # one micro-batch per step
        res = svc.step()
        assert len({r.collection for r in res}) == 1
        order.append(res[0].collection)
    # weight 4:1 -> the hot tenant owns ~8 of the first 10 batches, and
    # the cold tenant is never starved out entirely
    assert order.count("hot") >= 7
    assert order.count("cold") >= 1
    svc.flush()  # drain the rest; everything completes
    assert svc.pending() == 0


# -- rag routing --------------------------------------------------------------


def test_rag_make_service_routes_through_a_named_collection(built_index, corpus):
    _, _, queries = corpus
    rag = RagIndex(index=built_index, doc_tokens=np.zeros((4, 4), np.int32))
    client = rag.make_service(k=4, ef=16, cache_capacity=8)
    assert isinstance(client, CollectionClient)
    pred = P.Pred.range(0, 0.0, 1.0)
    rid = client.submit(queries[0], pred)
    r = _result_of(rid, client.run_until_idle())
    assert r.collection == "docs"
    assert r.ids.shape == (4,)
    assert client.stats()["compiles"] == 1

    # co-hosting: a shared service takes a second corpus as a second
    # collection, but refuses constructor kwargs it can no longer apply
    svc = client.service
    rag2 = RagIndex(index=built_index, doc_tokens=np.zeros((4, 4), np.int32))
    c2 = rag2.make_service(collection="docs2", service=svc, cache_capacity=8)
    assert set(svc.collections()) == {"docs", "docs2"}
    with pytest.raises(ValueError, match="fresh service"):
        rag2.make_service(collection="docs3", service=svc, batch_size=2)
    assert c2.submit(queries[1], pred) is not None


# -- obs: widened label schema stays back-compatible --------------------------


def test_old_narrow_label_exports_still_validate(tmp_path):
    # a registry written before the tenant dimension existed: the same
    # family names with the old (bucket, shard) label set must still
    # round-trip through the schema gate
    old = obs_reg.MetricsRegistry()
    c = old.counter("compass_queries_total", "q", ("bucket", "shard"))
    c.inc(3, bucket="(8, 1)", shard="")
    h = old.histogram(
        "compass_serve_exec_seconds", "t", ("bucket",), buckets=(0.01, 0.1, 1.0)
    )
    h.observe(0.05, bucket="(8, 1)")
    payload = old.to_json()
    assert obs_reg.validate_export(payload) == []
    path = tmp_path / "METRICS.json"
    path.write_text(json.dumps(payload))
    from repro.obs.validate import validate_any_file

    assert validate_any_file(str(path)) == []


def test_widened_schema_records_and_validates():
    obs_reg.set_enabled(True)
    svc = _svc()
    client = svc.create("a", _mut(300, 0), cache_capacity=0)
    client.submit(*_qp())
    svc.flush()
    reg = obs_reg.registry()
    q = reg.get("compass_queries_total")
    assert q is not None
    for s in q.samples():
        assert set(s["labels"]) == {"bucket", "shard", "tenant"}
    assert obs_reg.validate_export(reg.to_json()) == []


# -- admission watchdog + per-tenant SLOs -------------------------------------


def test_admission_pressure_watchdog_grades_shed_rate_and_queue_fill():
    r = obs_reg.MetricsRegistry()
    ring = obs_ts.TimeSeriesRing(capacity=8)
    chk = obs_h.admission_pressure(r, ring, now=1.0)
    assert chk.status == "ok" and "no collection service" in chk.detail

    sub = r.counter("compass_submitted_total", "s", ("tenant",))
    shed = r.counter("compass_shed_total", "s", ("tenant",))
    ring.snapshot(r, ts=0.0)
    sub.inc(100, tenant="hot")
    shed.inc(10, tenant="hot")  # 10% shed rate: past the 5% crit line
    sub.inc(100, tenant="cold")
    ring.snapshot(r, ts=1.0)
    chk = obs_h.admission_pressure(r, ring, now=1.0)
    assert chk.status == "crit"
    assert "'hot'" in chk.detail and chk.value == pytest.approx(0.10)
    assert chk.remediation

    # queue fill is a leading indicator: escalates an otherwise-ok verdict
    r2 = obs_reg.MetricsRegistry()
    ring2 = obs_ts.TimeSeriesRing(capacity=8)
    r2.counter("compass_submitted_total", "s", ("tenant",)).inc(100, tenant="a")
    r2.gauge("compass_queue_depth", "d", ("tenant",)).set(90, tenant="a")
    r2.gauge("compass_queue_limit", "l", ("tenant",)).set(100, tenant="a")
    ring2.snapshot(r2, ts=0.0)
    ring2.snapshot(r2, ts=1.0)
    chk2 = obs_h.admission_pressure(r2, ring2, now=1.0)
    assert chk2.status == "warn"  # 90% fill: warn, not yet crit
    assert "90%" in chk2.detail


def test_tenant_slos_scope_to_the_tenant_label():
    specs = obs_slo.tenant_slos("hot", latency_threshold_s=0.1)
    by_name = {s.name: s for s in specs}
    assert set(by_name) == {"serve_latency:hot", "admission:hot"}
    lat = by_name["serve_latency:hot"]
    assert lat.kind == "latency" and lat.threshold == 0.1
    assert lat.labels == {"tenant": "hot"}
    adm = by_name["admission:hot"]
    assert adm.kind == "ratio"
    assert adm.metric == "compass_shed_total"
    assert adm.total_metric == "compass_submitted_total"
    assert adm.labels == {"tenant": "hot"}


# -- service-level invariants -------------------------------------------------


def test_duplicate_and_mismatched_collections_fail_at_create():
    svc = _svc()
    svc.create("a", _mut(300, 0))
    with pytest.raises(ValueError, match="already exists"):
        svc.create("a", _mut(300, 1))
    other_shape = dataclasses.replace(SHAPE, min_rows=256)
    rng = np.random.default_rng(9)
    x = rng.normal(size=(300, D)).astype(np.float32)
    at = rng.uniform(size=(300, N_ATTRS)).astype(np.float32)
    mismatched = MutableIndex.build(x, at, CFG, delta_cap=32, shape=other_shape)
    with pytest.raises(ValueError, match="ShapePolicy"):
        svc.create("b", mismatched)
    with pytest.raises(KeyError, match="unknown collection"):
        svc.collection("nope")


def test_drop_discards_queued_work_but_keeps_shared_executables():
    svc = _svc()
    a = svc.create("a", _mut(300, 0), cache_capacity=0)
    b = svc.create("b", _mut(360, 1), cache_capacity=0)
    q, pred = _qp()
    a.submit(q, pred)
    svc.flush()
    n = svc.compile_count
    b.submit(q, pred)
    svc.drop("b")
    assert svc.collections() == ("a",)
    assert svc.pending() == 0
    assert svc.compile_count == n  # shared shapes outlive the tenant
    # the surviving tenant still serves without a recompile
    a.submit(q, pred)
    svc.flush()
    assert svc.compile_count == n
