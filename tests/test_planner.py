"""Cost-based planner: estimator accuracy/monotonicity (property tests),
exact run probes, PREFILTER exactness + parity with COOPERATIVE, per-mode
dispatch, and the trustworthy-stats fixes (n_cdist / n_clusters_ranked)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import predicate as P
from repro.core.baselines import brute_force, recall
from repro.core.clustered_attrs import build_clustered_attrs
from repro.core.planner import estimate as E
from repro.core.planner import plan as QP
from repro.core.planner.stats import build_attr_stats, term_run_bounds
from repro.compass import CompassParams, compass_search


@pytest.fixture(scope="module")
def stats_data():
    rng = np.random.default_rng(11)
    n, a, nlist = 4000, 3, 16
    attrs = rng.uniform(size=(n, a)).astype(np.float32)
    assign = rng.integers(0, nlist, n)
    ca = build_clustered_attrs(attrs, assign, nlist)
    astats = build_attr_stats(attrs, assign, nlist)
    return attrs, assign, ca, astats


def _pred(n_attrs, bounds):  # bounds: {attr: (lo, hi)}
    lo = np.full((1, n_attrs), P.NEG_INF, np.float32)
    hi = np.full((1, n_attrs), P.POS_INF, np.float32)
    for a, (l, h) in bounds.items():
        lo[0, a], hi[0, a] = l, h
    return jnp.asarray(lo), jnp.asarray(hi)


def _exact_passrate(attrs, lo, hi):
    lo, hi = np.asarray(lo), np.asarray(hi)
    term_ok = np.all((attrs[:, None, :] >= lo) & (attrs[:, None, :] <= hi), axis=-1)
    return np.any(term_ok, axis=-1).mean()


# -- stats ------------------------------------------------------------------


def test_index_carries_attr_stats(built_index):
    s = built_index.astats
    assert s is not None
    nlist, a = built_index.nlist, built_index.n_attrs
    assert s.edges.shape == (a, 65)
    assert s.cluster_edges.shape == (nlist, a, 9)
    assert np.all(np.diff(np.asarray(s.edges), axis=-1) >= 0)
    assert float(np.sum(np.asarray(s.cluster_counts))) == built_index.n_records


def test_exact_run_probes_match_numpy(stats_data):
    attrs, assign, ca, _ = stats_data
    rng = np.random.default_rng(3)
    for _ in range(10):
        a = int(rng.integers(0, attrs.shape[1]))
        lo, hi = sorted(rng.uniform(0, 1, 2))
        plo, phi = _pred(attrs.shape[1], {a: (lo, hi)})
        chosen = P.chosen_attrs(P.Predicate(plo, phi))
        beg, end = term_run_bounds(ca, plo, phi, chosen)
        got = int(np.sum(np.maximum(np.asarray(end) - np.asarray(beg), 0)))
        want = int(
            ((attrs[:, a] >= np.float32(lo)) & (attrs[:, a] <= np.float32(hi))).sum()
        )
        assert got == want
        # per-cluster counts too, not just the total
        per_c = np.asarray(end - beg)[0]
        for c in range(ca.n_clusters):
            wc = int(
                (
                    (assign == c)
                    & (attrs[:, a] >= np.float32(lo))
                    & (attrs[:, a] <= np.float32(hi))
                ).sum()
            )
            assert per_c[c] == wc


# -- estimator (property tests) ---------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    lo0=st.floats(0, 1),
    w0=st.floats(0, 1),
    lo1=st.floats(0, 1),
    w1=st.floats(0, 1),
)
def test_estimate_close_to_exact(stats_data, lo0, w0, lo1, w1):
    """Estimated selectivity within epsilon of the exact pass rate on
    synthetic (uniform, independent) attrs — conjunction of two ranges."""
    attrs, _, _, astats = stats_data
    plo, phi = _pred(
        attrs.shape[1], {0: (lo0, min(lo0 + w0, 1.0)), 1: (lo1, min(lo1 + w1, 1.0))}
    )
    _, est = E.estimate_matches(astats, plo, phi)
    exact = _exact_passrate(attrs, plo, phi)
    assert abs(float(est) - exact) <= 0.06


@settings(max_examples=30, deadline=None)
@given(
    lo=st.floats(0, 1),
    w=st.floats(0, 0.8),
    dlo=st.floats(0, 0.3),
    dhi=st.floats(0, 0.3),
    attr=st.integers(0, 2),
)
def test_estimate_monotone_under_widening(stats_data, lo, w, dlo, dhi, attr):
    attrs, _, _, astats = stats_data
    hi = min(lo + w, 1.0)
    plo, phi = _pred(attrs.shape[1], {attr: (lo, hi)})
    wlo, whi = _pred(attrs.shape[1], {attr: (lo - dlo, hi + dhi)})
    _, est = E.estimate_matches(astats, plo, phi)
    _, est_wide = E.estimate_matches(astats, wlo, whi)
    assert float(est_wide) >= float(est) - 1e-6
    # the global-histogram path must be monotone too
    g = float(E.estimate_selectivity_global(astats, plo, phi))
    g_wide = float(E.estimate_selectivity_global(astats, wlo, whi))
    assert g_wide >= g - 1e-6


def test_estimate_handles_padding_and_vacuous(stats_data):
    attrs, _, _, astats = stats_data
    a = attrs.shape[1]
    # unsatisfiable pad term contributes nothing
    pad = P.pad_terms(P.Pred.range(0, 0.2, 0.4).tensor(a), 4)
    nat = P.Pred.range(0, 0.2, 0.4).tensor(a)
    _, est_pad = E.estimate_matches(astats, pad.lo, pad.hi)
    _, est_nat = E.estimate_matches(astats, nat.lo, nat.hi)
    assert float(est_pad) == pytest.approx(float(est_nat), abs=1e-6)
    # vacuous predicate estimates ~1, never_true estimates ~0
    true_p = P.always_true(a)
    _, est_true = E.estimate_matches(astats, true_p.lo, true_p.hi)
    assert float(est_true) >= 0.99
    false_p = P.never_true(a)
    _, est_false = E.estimate_matches(astats, false_p.lo, false_p.hi)
    assert float(est_false) <= 1e-6


# -- mode selection + execution ---------------------------------------------


def _preds(rng, n_queries, n_attrs, passrate, n_terms, disj=False):
    preds = []
    for _ in range(n_queries):
        terms = []
        for a in range(n_terms):
            lo = rng.uniform(0, 1 - passrate)
            terms.append(P.Pred.range(a, lo, lo + passrate))
        tree = P.Pred.or_(*terms) if disj else P.Pred.and_(*terms)
        preds.append(tree.tensor(n_attrs))
    return P.stack_predicates(preds)


def test_high_selectivity_chooses_prefilter_and_is_exact(built_index, corpus):
    """Acceptance: pass rate ~1% -> PREFILTER, bitwise equal to a
    brute-force filtered scan.

    The reference scan materializes *every* passing record (found
    independently in numpy) and scores it through the engine's own
    ``scan_scores`` at the engine's shape, so the comparison pins down the
    planner's materialization / dedup / top-k merge exactly: ids are
    asserted bitwise.  Distances are asserted to ~1 f32 ULP: XLA fuses the
    row reduction differently inside the jitted search than in a
    standalone call, so bit-for-bit float equality only holds *within* one
    compiled program (the ref-vs-pallas parity test covers that); across
    programs the same caveat as ivf_score applies (engine/backend.py).
    """
    from repro.core.engine import resolve_backend

    x, attrs, queries = corpus
    rng = np.random.default_rng(21)
    pred = _preds(rng, 16, 4, 0.01, 1)
    qj = jnp.asarray(queries)
    pm = CompassParams(k=10, ef=64, planner=True, backend="ref")
    res = compass_search(built_index, qj, pred, pm)
    assert np.all(np.asarray(res.stats.mode) == QP.PREFILTER)
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    n = x.shape[0]
    cap = pm.resolved().prefilter_cap
    lo, hi = np.asarray(pred.lo), np.asarray(pred.hi)

    # brute-force filtered scan: all passing ids, engine scoring, top-k
    passing_sets = [
        np.where(
            np.any(np.all((attrs[:, None, :] >= lo[b]) & (attrs[:, None, :] <= hi[b]), -1), -1)
        )[0]
        for b in range(ids.shape[0])
    ]
    assert max(len(p) for p in passing_sets) <= cap  # fully materializable
    scan_ids = np.full((ids.shape[0], cap), n, np.int32)
    scan_mask = np.zeros((ids.shape[0], cap), bool)
    for b, p in enumerate(passing_sets):
        scan_ids[b, : len(p)] = p
        scan_mask[b, : len(p)] = True
    d_scan, p_scan = resolve_backend("ref").scan_scores(
        built_index, qj, P.Predicate(pred.lo, pred.hi),
        jnp.asarray(scan_ids), jnp.asarray(scan_mask), "l2",
    )
    d_scan = np.asarray(jnp.where(p_scan, d_scan, jnp.inf))

    xj = jnp.asarray(x)
    for b, p in enumerate(passing_sets):
        order = np.argsort(d_scan[b], kind="stable")[:10]
        k_real = min(len(p), 10)
        want_ids = scan_ids[b][order][:k_real]
        np.testing.assert_array_equal(ids[b, :k_real], want_ids)
        np.testing.assert_allclose(
            dists[b, :k_real], d_scan[b][order][:k_real], rtol=1e-6
        )
        assert np.all(ids[b, k_real:] == n)  # unfilled slots are sentinels
        assert np.all(~np.isfinite(dists[b, k_real:]))
        # independent recompute anchors the scoring itself (ULP tolerance)
        d_ind = np.asarray(jnp.sum((xj[ids[b, :k_real]] - qj[b]) ** 2, axis=-1))
        np.testing.assert_allclose(dists[b, :k_real], d_ind, rtol=1e-5)


def test_prefilter_matches_cooperative_topk(built_index, corpus):
    """Recall parity: on fully-materializable predicates PREFILTER and
    forced-COOPERATIVE return identical top-k."""
    x, attrs, queries = corpus
    rng = np.random.default_rng(22)
    pred = _preds(rng, 16, 4, 0.008, 1)  # ~48 matches of 6000, < ef
    qj = jnp.asarray(queries)
    on = compass_search(built_index, qj, pred, CompassParams(k=10, ef=64, planner=True))
    off = compass_search(built_index, qj, pred, CompassParams(k=10, ef=64, planner=False))
    assert np.all(np.asarray(on.stats.mode) == QP.PREFILTER)
    assert np.all(np.asarray(off.stats.mode) == QP.COOPERATIVE)
    np.testing.assert_array_equal(np.asarray(on.ids), np.asarray(off.ids))
    np.testing.assert_array_equal(np.asarray(on.dists), np.asarray(off.dists))


def test_postfilter_mode_on_vacuous_filters(built_index, corpus):
    x, attrs, queries = corpus
    rng = np.random.default_rng(23)
    pred = _preds(rng, 16, 4, 1.0, 1)
    qj = jnp.asarray(queries)
    res = compass_search(built_index, qj, pred, CompassParams(k=10, ef=128, planner=True))
    assert np.all(np.asarray(res.stats.mode) == QP.POSTFILTER)
    assert np.all(np.asarray(res.stats.n_bcalls) == 0)  # B.NEXT disabled
    truth = brute_force(jnp.asarray(x), jnp.asarray(attrs), qj, pred, 10)
    r = recall(np.asarray(res.ids), np.asarray(truth.ids), np.asarray(truth.dists), x.shape[0])
    assert r >= 0.85, r


def test_moderate_selectivity_stays_cooperative(built_index, corpus):
    x, attrs, queries = corpus
    rng = np.random.default_rng(24)
    pred = _preds(rng, 16, 4, 0.3, 2)
    res = compass_search(
        built_index, jnp.asarray(queries), pred, CompassParams(k=10, ef=64, planner=True)
    )
    assert np.all(np.asarray(res.stats.mode) == QP.COOPERATIVE)
    truth = brute_force(jnp.asarray(x), jnp.asarray(attrs), jnp.asarray(queries), pred, 10)
    r = recall(np.asarray(res.ids), np.asarray(truth.ids), np.asarray(truth.dists), x.shape[0])
    assert r >= 0.9, r


@pytest.mark.parametrize(
    "case",
    ["prefilter_regime", "cooperative_regime", "postfilter_regime", "disjunction"],
)
def test_planner_backend_parity(built_index, corpus, case):
    """ref and pallas backends stay bitwise-identical with the planner on
    (the batched run scan included)."""
    kw = {
        "prefilter_regime": dict(passrate=0.01, n_terms=1),
        "cooperative_regime": dict(passrate=0.3, n_terms=2),
        "postfilter_regime": dict(passrate=1.0, n_terms=1),
        "disjunction": dict(passrate=0.02, n_terms=3, disj=True),
    }[case]
    x, attrs, queries = corpus
    rng = np.random.default_rng(25)
    pred = _preds(rng, 16, 4, **kw)
    qj = jnp.asarray(queries)
    ref = compass_search(built_index, qj, pred, CompassParams(k=10, ef=64, planner=True, backend="ref"))
    pal = compass_search(built_index, qj, pred, CompassParams(k=10, ef=64, planner=True, backend="pallas"))
    np.testing.assert_array_equal(np.asarray(ref.stats.mode), np.asarray(pal.stats.mode))
    np.testing.assert_array_equal(np.asarray(ref.ids), np.asarray(pal.ids))
    np.testing.assert_array_equal(np.asarray(ref.dists), np.asarray(pal.dists))


def test_planner_off_by_default_and_flag_respected(built_index, corpus):
    assert CompassParams().planner is False
    x, attrs, queries = corpus
    rng = np.random.default_rng(26)
    pred = _preds(rng, 16, 4, 0.01, 1)  # would be PREFILTER if planner ran
    res = compass_search(built_index, jnp.asarray(queries), pred, CompassParams(k=10, ef=64))
    assert np.all(np.asarray(res.stats.mode) == QP.COOPERATIVE)


def test_planner_requires_attr_stats(built_index, corpus):
    x, attrs, queries = corpus
    legacy = built_index._replace(astats=None)  # pre-planner index
    rng = np.random.default_rng(27)
    pred = _preds(rng, 4, 4, 0.3, 1)
    with pytest.raises(ValueError, match="attribute statistics"):
        compass_search(
            legacy, jnp.asarray(queries[:4]), pred, CompassParams(k=10, ef=64, planner=True)
        )


def test_disjunction_prefilter_dedups_across_terms(built_index, corpus):
    """A record matching several OR terms must appear once in the top-k."""
    x, attrs, queries = corpus
    # two overlapping ranges on the same attribute -> every match sits in
    # both terms' runs
    tree = P.Pred.or_(P.Pred.range(0, 0.10, 0.13), P.Pred.range(0, 0.10, 0.13))
    pred = P.stack_predicates([tree.tensor(4) for _ in range(8)])
    res = compass_search(
        built_index, jnp.asarray(queries[:8]), pred, CompassParams(k=10, ef=64, planner=True)
    )
    assert np.all(np.asarray(res.stats.mode) == QP.PREFILTER)
    ids = np.asarray(res.ids)
    n = x.shape[0]
    for b in range(ids.shape[0]):
        real = ids[b][ids[b] < n]
        assert len(set(real.tolist())) == len(real)


# -- trustworthy stats (satellite fix) --------------------------------------


def test_ncdist_reports_true_count(built_index, corpus):
    """n_cdist was hardcoded to nlist even when the centroid ranking had no
    consumer; it must now report the true count."""
    x, attrs, queries = corpus
    rng = np.random.default_rng(28)
    pred = _preds(rng, 16, 4, 0.3, 1)
    qj = jnp.asarray(queries)
    nlist = built_index.nlist
    res = compass_search(built_index, qj, pred, CompassParams(k=10, ef=64))
    assert np.all(np.asarray(res.stats.n_cdist) == nlist)  # ranking consumed
    # pure-graph ablation with non-adaptive entry: ranking never consumed
    pm_off = CompassParams(k=10, ef=64, use_btree=False, adaptive_entry=False)
    res_off = compass_search(built_index, qj, pred, pm_off)
    assert np.all(np.asarray(res_off.stats.n_cdist) == 0)
    # adaptive entry alone still consumes the full ranking
    pm_entry = CompassParams(k=10, ef=64, use_btree=False, adaptive_entry=True)
    res_entry = compass_search(built_index, qj, pred, pm_entry)
    assert np.all(np.asarray(res_entry.stats.n_cdist) == nlist)


def test_n_clusters_ranked_tracks_bnext(built_index, corpus):
    x, attrs, queries = corpus
    qj = jnp.asarray(queries)
    rng = np.random.default_rng(29)
    # low passrate forces relational injection -> clusters actually opened
    pred = _preds(rng, 16, 4, 0.3, 4)
    res = compass_search(built_index, qj, pred, CompassParams(k=10, ef=64))
    ranked = np.asarray(res.stats.n_clusters_ranked)
    assert np.all(ranked <= built_index.nlist)
    assert ranked.mean() > 0
    # btree disabled -> nothing is ever opened
    res_nb = compass_search(built_index, qj, pred, CompassParams(k=10, ef=64, use_btree=False))
    assert np.all(np.asarray(res_nb.stats.n_clusters_ranked) == 0)
