"""SearchService behaviour: padding/stripping round-trips bitwise against
direct compass_search, deadline flush, executable-cache accounting, and
predicate shape-bucket plumbing."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import predicate as P
from repro.compass import CompassParams, compass_search
from repro.serving.search_service import SearchService

PM = CompassParams(k=10, ef=32)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _trees(n_attrs=4):
    """Predicate trees straddling the T=1 / T=2 / T=4 bucket boundaries."""
    return {
        1: P.Pred.and_(P.Pred.range(0, 0.1, 0.7), P.Pred.le(1, 0.8)),  # T=1
        2: P.Pred.or_(P.Pred.le(0, 0.4), P.Pred.ge(1, 0.6)),  # T=2
        3: P.Pred.or_(P.Pred.le(0, 0.3), P.Pred.ge(1, 0.7), P.Pred.eq(2, 0.5)),  # T=3 -> 4
        4: P.Pred.or_(*[P.Pred.range(a, 0.2, 0.6) for a in range(4)]),  # T=4
    }


def _direct(index, q, tree, pm=PM):
    """The reference a service response must match bitwise: a direct
    compass_search on the lone query with its natural-T predicate."""
    pred = P.stack_predicates([tree.tensor(index.n_attrs)])
    return compass_search(index, jnp.asarray(q[None]), pred, pm)


def test_round_trip_bitwise_across_bucket_boundaries(built_index, corpus):
    x, attrs, queries = corpus
    trees = _trees()
    svc = SearchService(built_index, PM, batch_size=4, max_wait_s=0.0)
    jobs = [(i, queries[i % len(queries)], trees[1 + i % 4]) for i in range(9)]
    rids = {svc.submit(q, tree, k=PM.k): (q, tree) for _, q, tree in jobs}
    results = {r.rid: r for r in svc.run_until_idle()}
    assert svc.pending() == 0
    assert set(results) == set(rids)
    for rid, (q, tree) in rids.items():
        direct = _direct(built_index, q, tree)
        r = results[rid]
        np.testing.assert_array_equal(r.ids, np.asarray(direct.ids)[0])
        # bitwise: compare float payloads as raw uint32
        np.testing.assert_array_equal(
            r.dists.view(np.uint32), np.asarray(direct.dists)[0].view(np.uint32)
        )


def test_per_request_k_truncates_the_direct_result(built_index, corpus):
    _, _, queries = corpus
    tree = _trees()[2]
    svc = SearchService(built_index, PM, batch_size=2, max_wait_s=0.0)
    rid = svc.submit(queries[0], tree, k=3)
    results = svc.run_until_idle()
    (r,) = [rr for rr in results if rr.rid == rid]
    direct = _direct(built_index, queries[0], tree)
    assert r.ids.shape == (3,)
    np.testing.assert_array_equal(r.ids, np.asarray(direct.ids)[0, :3])


def test_full_bucket_flushes_without_deadline(built_index, corpus):
    _, _, queries = corpus
    clock = FakeClock()
    svc = SearchService(built_index, PM, batch_size=2, max_wait_s=1e9, clock=clock)
    svc.submit(queries[0], _trees()[1])
    assert svc.step() == []  # half-full bucket, deadline far away: waits
    svc.submit(queries[1], _trees()[1])
    done = svc.step()  # full bucket flushes immediately
    assert len(done) == 2
    st = svc.stats()["buckets"]["B2xT1"]
    assert st["n_full_flush"] == 1 and st["n_deadline_flush"] == 0
    assert st["n_fillers"] == 0


def test_timeout_flush_pads_partial_batch(built_index, corpus):
    _, _, queries = corpus
    clock = FakeClock()
    svc = SearchService(built_index, PM, batch_size=4, max_wait_s=0.5, clock=clock)
    rid = svc.submit(queries[0], _trees()[4])
    assert svc.step() == []  # deadline not reached
    clock.advance(0.6)
    done = svc.step()
    assert [r.rid for r in done] == [rid]
    st = svc.stats()["buckets"]["B4xT4"]
    assert st["n_deadline_flush"] == 1
    assert st["n_fillers"] == 3  # 1 real + 3 unsatisfiable fillers
    # padded lanes must not leak into the response
    direct = _direct(built_index, queries[0], _trees()[4])
    np.testing.assert_array_equal(done[0].ids, np.asarray(direct.ids)[0])


def test_executable_cache_hit_accounting(built_index, corpus):
    _, _, queries = corpus
    svc = SearchService(built_index, PM, batch_size=2, max_wait_s=0.0)
    trees = _trees()
    # 3 batches in bucket T=1, 1 batch in bucket T=4
    for i in range(6):
        svc.submit(queries[i % len(queries)], trees[1])
    for i in range(2):
        svc.submit(queries[i], trees[4])
    svc.run_until_idle()
    stats = svc.stats()
    assert svc.compile_count == 2  # one executable per occupied bucket
    assert stats["compiles"] == stats["occupied_buckets"] == 2
    b1 = stats["buckets"]["B2xT1"]
    assert b1["n_compiles"] == 1 and b1["n_cache_hits"] == 2
    b4 = stats["buckets"]["B2xT4"]
    assert b4["n_compiles"] == 1 and b4["n_cache_hits"] == 0
    # same shapes again: only cache hits, no new executables
    for i in range(4):
        svc.submit(queries[i], trees[3 if i % 2 else 1])  # T=3 pads into T=4 bucket
    svc.run_until_idle()
    assert svc.compile_count == 2
    assert svc.stats()["buckets"]["B2xT4"]["n_cache_hits"] == 1


def test_mixed_t_shapes_share_one_bucket_executable(built_index, corpus):
    """T=3 and T=4 predicates pad to the same bucket and the same compile."""
    _, _, queries = corpus
    svc = SearchService(built_index, PM, batch_size=2, max_wait_s=0.0)
    trees = _trees()
    r3 = svc.submit(queries[0], trees[3])
    r4 = svc.submit(queries[1], trees[4])
    results = {r.rid: r for r in svc.run_until_idle()}
    assert svc.compile_count == 1
    assert results[r3].bucket == results[r4].bucket == (2, 4)
    for rid, tree in ((r3, trees[3]), (r4, trees[4])):
        direct = _direct(built_index, queries[0 if rid == r3 else 1], tree)
        np.testing.assert_array_equal(results[rid].ids, np.asarray(direct.ids)[0])


def test_poll_pops_once(built_index, corpus):
    _, _, queries = corpus
    svc = SearchService(built_index, PM, batch_size=1, max_wait_s=0.0)
    rid = svc.submit(queries[0], _trees()[1])
    assert svc.poll(rid) is None  # not dispatched yet
    svc.run_until_idle()
    assert svc.poll(rid) is not None
    assert svc.poll(rid) is None  # popped


def test_submit_validation(built_index, corpus):
    _, _, queries = corpus
    svc = SearchService(built_index, PM, batch_size=2, max_terms=8)
    with pytest.raises(ValueError, match="outside"):
        svc.submit(queries[0], _trees()[1], k=PM.k + 1)
    with pytest.raises(ValueError, match="query shape"):
        svc.submit(queries[0][:3], _trees()[1])
    with pytest.raises(ValueError, match="attrs"):
        svc.submit(queries[0], P.Pred.le(0, 0.5).tensor(2))
    with pytest.raises(ValueError, match="max_terms"):
        svc.submit(queries[0], P.Pred.or_(*[P.Pred.eq(0, i / 16) for i in range(9)]))


def test_result_buffer_evicts_oldest_unpolled(built_index, corpus):
    _, _, queries = corpus
    svc = SearchService(built_index, PM, batch_size=2, max_wait_s=0.0, result_buffer=3)
    rids = [svc.submit(queries[i % len(queries)], _trees()[1]) for i in range(6)]
    svc.run_until_idle()
    assert [svc.poll(r) is not None for r in rids] == [False] * 3 + [True] * 3


def test_unsatisfiable_request_returns_all_padding(built_index, corpus):
    x, _, queries = corpus
    svc = SearchService(built_index, PM, batch_size=2, max_wait_s=0.0)
    rid = svc.submit(queries[0], P.Pred.range(0, 2.0, 3.0))  # attrs are U[0,1]
    svc.run_until_idle()
    r = svc.poll(rid)
    assert np.all(r.ids == x.shape[0])
    assert np.all(~np.isfinite(r.dists))


def test_planner_modes_surface_in_bucket_stats(built_index, corpus):
    """A planner-enabled service reports the execution mode the cost model
    chose per real lane (fillers excluded), and responses still round-trip
    bitwise against direct planner-enabled compass_search."""
    _, _, queries = corpus
    pm = CompassParams(k=10, ef=32, planner=True)
    svc = SearchService(built_index, pm, batch_size=4, max_wait_s=0.0)
    narrow = P.Pred.range(0, 0.40, 0.41)  # ~1% pass -> PREFILTER
    vacuous = P.Pred.range(0, -10.0, 10.0)  # pass-all -> POSTFILTER
    moderate = P.Pred.and_(P.Pred.range(0, 0.1, 0.5), P.Pred.range(1, 0.2, 0.7))
    jobs = {
        svc.submit(queries[i], tree): tree
        for i, tree in enumerate([narrow, vacuous, moderate, narrow, vacuous])
    }
    results = {r.rid: r for r in svc.run_until_idle()}
    stats = svc.stats()
    assert stats["planner"] is True
    assert stats["modes"]["prefilter"] >= 2
    assert stats["modes"]["postfilter"] >= 2
    assert sum(stats["modes"].values()) == len(jobs)  # fillers not counted
    for rid, tree in jobs.items():
        direct = _direct(built_index, queries[rid], tree, pm)
        np.testing.assert_array_equal(results[rid].ids, np.asarray(direct.ids)[0])
        np.testing.assert_array_equal(
            results[rid].dists.view(np.uint32),
            np.asarray(direct.dists)[0].view(np.uint32),
        )
