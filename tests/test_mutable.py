"""Mutable-index subsystem (core/mutable): delta segments, tombstones,
online compaction, epoch-pinned serving, per-shard distributed deltas."""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import predicate as P
from repro.core.baselines import brute_force, recall
from repro.core.distributed import DistributedMutableIndex
from repro.core.graph_build import insert_nodes, remove_nodes
from repro.core.index import BuildConfig, build_index, cluster_medoids
from repro.core.mutable import MutableIndex
from repro.core.planner.plan import COOPERATIVE, POSTFILTER, PREFILTER
from repro.compass import CompassParams, compass_search
from repro.serving.search_service import SearchService

A = 4


@pytest.fixture(scope="module")
def mcorpus():
    rng = np.random.default_rng(11)
    n, d = 2500, 16
    centers = rng.normal(size=(24, d)).astype(np.float32) * 3
    x = (centers[rng.integers(0, 24, n)] + rng.normal(size=(n, d))).astype(np.float32)
    attrs = rng.uniform(size=(n, A)).astype(np.float32)
    queries = (centers[rng.integers(0, 24, 8)] + rng.normal(size=(8, d))).astype(np.float32)
    return x, attrs, queries


MCFG = BuildConfig(m=12, nlist=16)


@pytest.fixture(scope="module")
def mbase(mcorpus):
    x, attrs, _ = mcorpus
    return build_index(x, attrs, MCFG)


def wrap(mbase, **kw) -> MutableIndex:
    kw.setdefault("cfg", MCFG)
    return MutableIndex(mbase, **kw)


def stacked(tree, b):
    return P.stack_predicates([tree.tensor(A)] * b)


# ---------------------------------------------------------------------------
# writes + delta search
# ---------------------------------------------------------------------------


def test_upsert_is_searchable_before_compaction(mbase, mcorpus):
    _, _, queries = mcorpus
    mi = wrap(mbase, delta_cap=32)
    pred = stacked(P.Pred.range(0, 0.2, 0.8), 8)
    pm = CompassParams(k=10, ef=64)
    mi.upsert([7_000_000, 7_000_001],
              np.stack([queries[0], queries[0] + 0.01]),
              np.tile(np.float32([0.5] * A), (2, 1)))
    res = mi.search(queries, pred, pm)
    ids0 = np.asarray(res.ids)[0]
    assert ids0[0] == 7_000_000 and 7_000_001 in ids0
    assert mi.epoch == 0 and mi.delta_fill == 2
    # delta rows still honor the predicate
    mi.upsert(7_000_002, queries[0], np.float32([0.95] * A))  # attr0 outside range
    ids2 = np.asarray(mi.search(queries, pred, pm).ids)[0]
    assert 7_000_002 not in ids2


def test_superseded_base_version_never_surfaces(mbase, mcorpus):
    _, _, queries = mcorpus
    mi = wrap(mbase, delta_cap=32)
    pred = stacked(P.Pred.range(0, 0.0, 1.0), 8)
    pm = CompassParams(k=5, ef=64)
    victim = int(np.asarray(mi.search(queries, pred, pm).ids)[0, 0])
    # move the record far away: its old (near) base version must not be used
    mi.upsert(victim, np.full((mi.dim,), 50.0, np.float32), np.float32([0.5] * A))
    ids = np.asarray(mi.search(queries, pred, pm).ids)[0]
    assert victim not in ids


def test_delete_unknown_or_twice_raises(mbase):
    mi = wrap(mbase, delta_cap=8)
    with pytest.raises(KeyError):
        mi.delete(10**9)
    mi.delete(0)
    with pytest.raises(KeyError):
        mi.delete(0)
    assert 0 not in mi and 1 in mi
    # deleting a delta-resident id invalidates the slot
    mi.upsert(10**6, np.zeros((mi.dim,), np.float32), np.float32([0.5] * A))
    assert 10**6 in mi
    mi.delete(10**6)
    assert 10**6 not in mi


# ---------------------------------------------------------------------------
# tombstones never surface — all three planner modes
# ---------------------------------------------------------------------------


def _mode_pred(mcorpus, mode):
    x, attrs, _ = mcorpus
    if mode == PREFILTER:  # <=1% selectivity -> run materialization
        lo = float(np.quantile(attrs[:, 0], 0.50))
        hi = float(np.quantile(attrs[:, 0], 0.508))
        return P.Pred.range(0, lo, hi)
    if mode == POSTFILTER:  # vacuous filter
        return P.Pred.range(0, -10.0, 10.0)
    return P.Pred.and_(P.Pred.range(0, 0.2, 0.7), P.Pred.range(1, 0.1, 0.9))


@pytest.mark.parametrize("mode", [PREFILTER, COOPERATIVE, POSTFILTER])
def test_tombstoned_ids_never_surface(mbase, mcorpus, mode):
    _, _, queries = mcorpus
    mi = wrap(mbase, delta_cap=32)
    pred = stacked(_mode_pred(mcorpus, mode), 8)
    pm = CompassParams(k=5, ef=32, planner=True)
    res = mi.search(queries, pred, pm)
    assert np.all(np.asarray(res.stats.mode) == mode)
    victims = {int(i) for i in np.asarray(res.ids)[:, 0] if i >= 0}
    for v in victims:
        mi.delete(v)
    res2 = mi.search(queries, pred, pm)
    assert not victims & {int(i) for i in np.asarray(res2.ids).ravel()}
    # planner off (plain cooperative loop) must agree
    res3 = mi.search(queries, pred, CompassParams(k=5, ef=32))
    assert not victims & {int(i) for i in np.asarray(res3.ids).ravel()}


# ---------------------------------------------------------------------------
# compaction
# ---------------------------------------------------------------------------


def test_delta_overflow_triggers_compaction(mbase, mcorpus):
    _, _, queries = mcorpus
    mi = wrap(mbase, delta_cap=8)
    rng = np.random.default_rng(0)
    for i in range(9):  # 9th upsert overflows the 8-slot delta
        mi.upsert(5_000_000 + i, rng.normal(size=mi.dim).astype(np.float32),
                  rng.uniform(size=A).astype(np.float32))
    assert mi.epoch == 1 and mi.delta_fill == 1
    assert len(mi.compaction_log) == 1
    # every upsert survives the fold, now in the base tier
    assert all(5_000_000 + i in mi for i in range(9))
    pm = CompassParams(k=10, ef=64)
    pred = stacked(P.Pred.range(0, 0.0, 1.0), 8)
    ids = set(np.asarray(mi.search(queries, pred, pm).ids).ravel().tolist())
    assert ids  # searchable post-compaction


def test_overflow_without_auto_compact_raises(mbase):
    mi = wrap(mbase, delta_cap=4, auto_compact=False)
    rng = np.random.default_rng(0)
    for i in range(4):
        mi.upsert(i + 10**6, rng.normal(size=mi.dim).astype(np.float32),
                  rng.uniform(size=A).astype(np.float32))
    with pytest.raises(RuntimeError, match="delta segment full"):
        mi.upsert(10**7, rng.normal(size=mi.dim).astype(np.float32),
                  rng.uniform(size=A).astype(np.float32))
    mi.compact()
    assert mi.epoch == 1 and mi.delta_fill == 0


def _groups(gids, dists):
    out = {}
    for g, d in zip(gids, dists):
        if np.isfinite(d):
            out.setdefault(float(np.float32(d)), set()).add(int(g))
    return out


def assert_same_topk(gids_a, d_a, gids_b, d_b):
    """Same top-k up to ties: identical distance multisets, identical id
    sets within each exact-distance group (the truncated last group is
    compared as sets of distances only)."""
    gids_a, d_a = np.asarray(gids_a), np.asarray(d_a)
    gids_b, d_b = np.asarray(gids_b), np.asarray(d_b)
    np.testing.assert_allclose(d_a, d_b, rtol=1e-6, atol=1e-6)
    for b in range(gids_a.shape[0]):
        ga, gb = _groups(gids_a[b], d_a[b]), _groups(gids_b[b], d_b[b])
        last = max(ga) if ga else None
        for key in ga:
            if key == last:  # k-boundary may truncate a tie group
                assert len(ga[key]) == len(gb.get(key, set()))
            else:
                assert ga[key] == gb.get(key), (b, key)


def test_mixed_history_matches_fresh_rebuild(mcorpus):
    """Acceptance: planner on, delta at 50% capacity after a mixed
    upsert/delete history (including one mid-history compaction), the
    mutable search equals a fresh build_index over the materialized table
    across conjunction / disjunction / <=1%-selectivity predicates."""
    x, attrs, queries = mcorpus
    cap = 32
    mi = MutableIndex.build(x, attrs, MCFG, delta_cap=cap)
    rng = np.random.default_rng(5)
    live = list(range(len(x)))
    next_gid = len(x)
    for i in range(3 * cap // 2):  # 48 upserts -> one compaction, fill 16/32
        if i % 3 == 2:  # update an existing record
            gid = live[int(rng.integers(len(live)))]
        else:
            gid = next_gid
            next_gid += 1
            live.append(gid)
        mi.upsert(gid, (x[rng.integers(len(x))] + rng.normal(size=mi.dim) * 0.1).astype(np.float32),
                  rng.uniform(size=A).astype(np.float32))
    for _ in range(20):
        gid = live.pop(int(rng.integers(len(live))))
        if gid in mi:
            mi.delete(gid)
    assert mi.epoch == 1 and mi.delta_fill == cap // 2  # 50% full delta

    vec, att, gids = mi.materialize()
    fresh = build_index(vec, att, MCFG)
    n_table = vec.shape[0]
    pm = CompassParams(k=10, ef=256, planner=True)
    narrow_lo = float(np.quantile(att[:, 1], 0.7))
    narrow_hi = float(np.quantile(att[:, 1], 0.708))  # <=1% selectivity
    cases = [
        P.Pred.and_(P.Pred.range(0, 0.2, 0.7), P.Pred.range(1, 0.1, 0.9)),
        P.Pred.or_(P.Pred.range(0, 0.0, 0.15), P.Pred.range(2, 0.85, 1.0)),
        P.Pred.range(1, narrow_lo, narrow_hi),
    ]
    for tree in cases:
        pred = stacked(tree, len(queries))
        res_m = mi.search(queries, pred, pm)
        res_f = compass_search(fresh, jnp.asarray(queries), pred, pm)
        fids = np.asarray(res_f.ids)
        fg = np.where(fids < n_table, gids[np.clip(fids, 0, n_table - 1)], -1)
        assert_same_topk(np.asarray(res_m.ids), np.asarray(res_m.dists),
                         fg, np.asarray(res_f.dists))


def test_compaction_refreshes_planner_stats(mbase, mcorpus):
    _, _, queries = mcorpus
    mi = wrap(mbase, delta_cap=64)
    rng = np.random.default_rng(3)
    # new rows with attr0 in [2, 3] — far outside the base U[0,1] range
    new_attrs = np.column_stack([
        rng.uniform(2.0, 3.0, 40),
        *[rng.uniform(size=40) for _ in range(A - 1)],
    ]).astype(np.float32)
    for i in range(40):
        mi.upsert(8_000_000 + i, rng.normal(size=mi.dim).astype(np.float32), new_attrs[i])
    mi.compact()
    ast = mi.base.astats
    assert float(ast.edges[0, -1]) >= 2.0  # histogram edges cover new range
    assert int(ast.cluster_counts.sum()) == mi.n_live
    # the planner sees the new rows: narrow range over them -> PREFILTER,
    # exact materialization returns precisely those rows
    pred = stacked(P.Pred.range(0, 2.0, 3.0), 8)
    res = mi.search(queries, pred, CompassParams(k=10, ef=32, planner=True))
    assert np.all(np.asarray(res.stats.mode) == PREFILTER)
    ids = np.asarray(res.ids)
    assert np.all((ids >= 8_000_000) | (ids == -1))


def test_vectorized_medoids_match_reference_loop():
    rng = np.random.default_rng(7)
    n, d, nlist = 500, 8, 12
    x = rng.normal(size=(n, d)).astype(np.float32)
    cent = rng.normal(size=(nlist, d)).astype(np.float32)
    assign = rng.integers(0, nlist - 2, n)  # clusters nlist-2, nlist-1 empty
    got = cluster_medoids(x, assign, cent, fallback=42)
    x2 = (x * x).sum(1)
    for c in range(nlist):
        members = np.where(assign == c)[0]
        if members.size == 0:
            assert got[c] == 42
            continue
        dd = x2[members] - 2.0 * (x[members] @ cent[c])
        assert got[c] == members[np.argmin(dd)]


# ---------------------------------------------------------------------------
# graph maintenance primitives
# ---------------------------------------------------------------------------


def test_remove_nodes_reindexes_and_drops_dead_edges():
    nb = np.array([[1, 2, 4], [0, 4, 4], [3, 0, 4], [2, 4, 4]], np.int32)  # n=4, sent=4
    keep = np.array([True, False, True, True])
    out = remove_nodes(nb, keep)
    # new ids: 0->0, 2->1, 3->2; sentinel 3
    assert out.shape == (3, 3)
    assert out[0].tolist() == [1, 3, 3]  # edge to removed node 1 dropped, compacted
    assert out[1].tolist() == [2, 0, 3]
    assert out[2].tolist() == [1, 3, 3]


def test_insert_nodes_connects_new_rows_bidirectionally():
    rng = np.random.default_rng(0)
    n_old, n_new, d, m = 60, 5, 8, 6
    x = rng.normal(size=(n_old + n_new, d)).astype(np.float32)
    cent = x[:4].copy()  # 4 crude clusters
    from repro.core.mutable.compact import assign_to_centroids
    assign = assign_to_centroids(x, cent)
    base = build_index(x[:n_old], rng.uniform(size=(n_old, A)).astype(np.float32),
                       BuildConfig(m=m, nlist=4))
    nb = np.asarray(base.graph.neighbors)
    out = insert_nodes(nb, x, n_old, assign, cent, m)
    assert out.shape == (n_old + n_new, m)
    n_total = n_old + n_new
    for i in range(n_old, n_total):
        fwd = out[i][out[i] < n_total]
        assert fwd.size > 0  # new node has out-edges
        # and at least one survivor points back (reverse edge)
        assert any(i in out[j] for j in fwd)


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


def test_mutable_backend_parity_ref_vs_pallas(mbase, mcorpus):
    _, _, queries = mcorpus
    queries = queries[:4]
    mi = wrap(mbase, delta_cap=16)
    rng = np.random.default_rng(9)
    for i in range(8):
        mi.upsert(6_000_000 + i, (queries[i % 4] + rng.normal(size=mi.dim) * 0.05).astype(np.float32),
                  rng.uniform(size=A).astype(np.float32))
    mi.delete(0)
    pred = stacked(P.Pred.range(0, 0.1, 0.9), 4)
    res_r = mi.search(queries, pred, CompassParams(k=5, ef=32, backend="ref"))
    res_p = mi.search(queries, pred, CompassParams(k=5, ef=32, backend="pallas"))
    np.testing.assert_array_equal(np.asarray(res_r.ids), np.asarray(res_p.ids))
    np.testing.assert_array_equal(np.asarray(res_r.dists), np.asarray(res_p.dists))


# ---------------------------------------------------------------------------
# serving: write jobs + epoch pinning
# ---------------------------------------------------------------------------


def test_service_writes_and_epoch_pinning(mbase, mcorpus):
    _, _, queries = mcorpus
    mi = wrap(mbase, delta_cap=8)
    svc = SearchService(mi, CompassParams(k=5, ef=32), batch_size=4, max_wait_s=0.0)
    tree = P.Pred.range(0, 0.1, 0.9)
    for i in range(4):
        svc.submit(queries[i], tree)
    first = svc.run_until_idle()
    assert {r.epoch for r in first} == {0}
    victim = int(first[0].ids[0])
    # queue writes that overflow the delta (9 > 8 -> compaction), plus a
    # delete; they apply at the next round boundary, before batch formation
    for i in range(9):
        svc.submit_upsert(9_000_000 + i, queries[0], np.float32([0.5] * A))
    svc.submit_delete(victim)
    assert svc.pending_writes() == 10
    for i in range(4):
        svc.submit(queries[i], tree)
    second = svc.run_until_idle()
    assert svc.pending_writes() == 0
    # one batch, one epoch — formed strictly after the compaction
    assert {r.epoch for r in second} == {1}
    assert victim not in second[0].ids
    assert any(9_000_000 + i in second[0].ids for i in range(9))
    st = svc.stats()
    assert st["mutable"] and st["epoch"] == 1
    assert st["n_upserts"] == 9 and st["n_deletes"] == 1 and st["n_compactions"] == 1


def test_service_result_matches_direct_mutable_search(mbase, mcorpus):
    _, _, queries = mcorpus
    mi = wrap(mbase, delta_cap=8)
    mi.upsert(9_500_000, queries[0], np.float32([0.5] * A))
    pm = CompassParams(k=5, ef=32)
    svc = SearchService(mi, pm, batch_size=2, max_wait_s=0.0)
    tree = P.Pred.range(0, 0.1, 0.9)
    rids = [svc.submit(queries[i], tree) for i in range(2)]
    svc.run_until_idle()
    direct = mi.search(queries[:2], P.stack_predicates([tree.tensor(A)] * 2), pm)
    for i, rid in enumerate(rids):
        got = svc.poll(rid)
        np.testing.assert_array_equal(got.ids, np.asarray(direct.ids)[i])
        np.testing.assert_array_equal(got.dists, np.asarray(direct.dists)[i])


def test_immutable_service_rejects_writes(mbase, mcorpus):
    svc = SearchService(mbase, CompassParams(k=5, ef=32))
    with pytest.raises(ValueError, match="MutableIndex"):
        svc.submit_upsert(1, np.zeros((mbase.dim,), np.float32), np.zeros((A,), np.float32))
    with pytest.raises(ValueError, match="MutableIndex"):
        svc.submit_delete(1)


def test_service_delete_validation(mbase):
    mi = wrap(mbase, delta_cap=8)
    svc = SearchService(mi, CompassParams(k=5, ef=32))
    with pytest.raises(KeyError):  # unknown id rejected at admission
        svc.submit_delete(10**9)
    # deleting an id that only exists as a queued upsert is admissible;
    # application order resolves it
    svc.submit_upsert(10**6, np.zeros((mi.dim,), np.float32), np.float32([0.5] * A))
    svc.submit_delete(10**6)
    # a duplicate queued delete degrades to a counted no-op at drain time
    svc.submit_delete(3)
    svc.submit_delete(3)
    assert svc.apply_writes() == 4
    assert svc.n_deletes == 2 and svc.n_write_errors == 1
    assert 10**6 not in mi and 3 not in mi


# ---------------------------------------------------------------------------
# distributed: per-shard deltas, independent compaction
# ---------------------------------------------------------------------------


def test_distributed_mutable_per_shard_deltas(mcorpus):
    x, attrs, queries = mcorpus
    dmi = DistributedMutableIndex.build(x, attrs, 2, MCFG, delta_cap=8)
    pred = stacked(P.Pred.range(0, 0.1, 0.9), len(queries))
    pm = CompassParams(k=5, ef=64)
    res = dmi.search(queries, pred, pm)
    truth = brute_force(jnp.asarray(x), jnp.asarray(attrs), jnp.asarray(queries), pred, 5)
    r = recall(np.asarray(res.ids), np.asarray(truth.ids), np.asarray(truth.dists), len(x))
    assert r >= 0.9
    victim = int(np.asarray(res.ids)[0, 0])
    dmi.delete(victim)
    dmi.upsert(4_000_000, queries[0][None], np.float32([[0.5] * A]))
    res2 = dmi.search(queries, pred, pm)
    ids2 = np.asarray(res2.ids)[0]
    assert victim not in ids2 and 4_000_000 in ids2
    # overflow only the even-id shard: its epoch advances, the other stays
    rng = np.random.default_rng(1)
    for i in range(10):
        dmi.upsert(4_100_000 + 2 * i, rng.normal(size=x.shape[1]).astype(np.float32),
                   rng.uniform(size=A).astype(np.float32))
    assert dmi.epochs[0] >= 1 and dmi.epochs[1] == 0
    assert 4_000_000 in np.asarray(dmi.search(queries, pred, pm).ids)[0]
