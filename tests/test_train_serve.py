"""Integration: short training runs (loss decreases, checkpoint restart
continues identically), continuous-batching serving, filtered RAG."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch.train import train_loop
from repro.models.model import init_params


@pytest.fixture(scope="module")
def tiny_cfg():
    return dataclasses.replace(
        reduced(get_config("tinyllama-1.1b")),
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    )


def test_training_loss_decreases(tiny_cfg):
    _, losses = train_loop(tiny_cfg, steps=30, global_batch=4, seq_len=64, log=lambda *_: None)
    assert losses[-1] < losses[0]


def test_checkpoint_restart_resumes(tmp_path, tiny_cfg):
    d = str(tmp_path / "run")
    _, full = train_loop(
        tiny_cfg, steps=20, global_batch=4, seq_len=64, ckpt_dir=d, ckpt_every=10,
        log=lambda *_: None,
    )
    # restart from step-10 checkpoint and replay 10..20
    import shutil

    shutil.rmtree(d + "/step_00000020")
    _, resumed = train_loop(
        tiny_cfg, steps=20, global_batch=4, seq_len=64, ckpt_dir=d, ckpt_every=100,
        log=lambda *_: None,
    )
    # deterministic data + restored state => same trailing losses
    np.testing.assert_allclose(resumed[-3:], full[-3:], rtol=1e-3, atol=1e-3)


@pytest.mark.xfail(strict=False, reason="pre-existing at seed under pinned jax 0.4.37 (see CHANGES.md PR 1)")
def test_microbatched_equals_single_batch_grads(tiny_cfg):
    """Gradient accumulation invariant: mean of 4 microbatch grads equals
    the full-batch grad (compared pre-optimizer: Adam's rsqrt amplifies
    numerically-tiny grad differences into sign flips)."""
    from repro.data.synthetic import DataConfig, SyntheticTokens
    from repro.train.step import TrainConfig, make_loss_fn

    data = SyntheticTokens(DataConfig(tiny_cfg.vocab_size, 32, 8, seed=1))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    params = init_params(tiny_cfg, jax.random.PRNGKey(0))
    tc = TrainConfig(n_microbatches=1, remat=False)
    loss_fn = make_loss_fn(tiny_cfg, tc)
    l_full, g_full = jax.value_and_grad(loss_fn)(params, batch)

    nm = 4
    micro = jax.tree.map(lambda x: x.reshape((nm, x.shape[0] // nm) + x.shape[1:]), batch)
    l_acc, g_acc = 0.0, jax.tree.map(jnp.zeros_like, g_full)
    for i in range(nm):
        mb = jax.tree.map(lambda x: x[i], micro)
        l, g = jax.value_and_grad(loss_fn)(params, mb)
        l_acc += float(l) / nm
        g_acc = jax.tree.map(lambda a, b: a + b / nm, g_acc, g)
    assert l_acc == pytest.approx(float(l_full), rel=1e-4)
    for a, b in zip(jax.tree.leaves(g_acc), jax.tree.leaves(g_full)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-2, atol=5e-4)


def test_continuous_batcher_serves_requests(tiny_cfg):
    from repro.serving.scheduler import ContinuousBatcher, Request

    params = init_params(tiny_cfg, jax.random.PRNGKey(0))
    b = ContinuousBatcher(tiny_cfg, params, n_slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, 256, 5).astype(np.int32), max_tokens=4)
        for i in range(5)
    ]
    for r in reqs:
        b.submit(r)
    b.run_until_done()
    for r in reqs:
        assert r.done and len(r.out_tokens) == 4


def test_prefill_bucket_shapes():
    from repro.serving.scheduler import prefill_bucket

    assert [prefill_bucket(p, 64) for p in (1, 5, 8, 9, 33)] == [8, 8, 8, 16, 64]
    assert prefill_bucket(60, 64) == 64  # capped at max_seq
    # recurrent configs (SSM/hybrid) must prefill exact-length: pad tokens
    # would be scanned into the recurrent state
    assert prefill_bucket(5, 64, recurrent=True) == 5
    with pytest.raises(ValueError):
        prefill_bucket(65, 64)


def test_batcher_ragged_prompt_lengths_match_padded_prefill(tiny_cfg):
    """Prompts straddling prefill buckets (3, 8, 13 tokens) decode the same
    tokens as a prompt-length-identical run — bucketed prefill is
    output-neutral for attention configs."""
    from repro.serving.scheduler import ContinuousBatcher, Request

    params = init_params(tiny_cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 256, n).astype(np.int32) for n in (3, 8, 13)]

    def serve(n_slots):
        b = ContinuousBatcher(tiny_cfg, params, n_slots=n_slots, max_seq=64)
        reqs = [Request(rid=i, prompt=p, max_tokens=4) for i, p in enumerate(prompts)]
        for r in reqs:
            b.submit(r)
        b.run_until_done()
        return [r.out_tokens for r in reqs]

    # single-slot (sequential, each prompt prefilled alone) == 3-slot batch
    assert serve(1) == serve(3)


def test_filtered_rag_respects_predicate(tiny_cfg):
    from repro.core import predicate as P
    from repro.core.index import BuildConfig
    from repro.serving.rag import RagIndex

    rng = np.random.default_rng(1)
    params = init_params(tiny_cfg, jax.random.PRNGKey(0))
    doc_tokens = rng.integers(0, 256, (48, 8)).astype(np.int32)
    doc_attrs = rng.uniform(size=(48, 2)).astype(np.float32)
    rag = RagIndex.build(params, tiny_cfg, doc_tokens, doc_attrs,
                         BuildConfig(m=8, nlist=4))
    pred = P.Pred.le(0, 0.4).tensor(2)
    prompts = np.stack([rng.integers(0, 256, 8).astype(np.int32) for _ in range(4)])
    ids = rag.retrieve(params, tiny_cfg, prompts, pred, k=3, ef=16)
    found_any = False
    for b_ in range(4):
        for i in ids[b_]:
            if i < 48:
                found_any = True
                assert doc_attrs[i, 0] <= 0.4 + 1e-6
    assert found_any
    # the serving-layer path returns the same docs (padding is
    # result-neutral; same CompassParams via make_service)
    service = rag.make_service(k=3, ef=16, batch_size=4, max_wait_s=0.0)
    ids_svc = rag.retrieve(params, tiny_cfg, prompts, pred, k=3, service=service)
    np.testing.assert_array_equal(ids_svc, ids)
    assert service.stats()["compiles"] == 1
