"""Continuous-monitoring tests: time-series ring math (windows, counter
resets, wraparound, bucket-delta quantiles), SLO burn rates under a fake
clock, drift watchdogs flipped by *injected* drift (stale planner stats,
shifted upserts against frozen codebooks, compaction debt, synthetic
recompiles and shard skew), Monitor cadence/gating, the serving health
surface, and the distributed explain fan-out.

The contract mirrors test_obs.py: everything here is host-side dict work
— enabling the monitor must not change a bit of any search result — and
nothing runs unless observability is enabled and something ticks a
snapshot.
"""
from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import predicate as P
from repro.core.engine import CompassParams, compass_search
from repro.obs import events as obs_ev
from repro.obs import health as obs_h
from repro.obs import registry as obs_reg
from repro.obs import slo as obs_slo
from repro.obs import timeseries as obs_ts


@pytest.fixture(autouse=True)
def _isolated_obs():
    prev = obs_reg.set_enabled(False)
    obs_reg.reset()
    obs_ev.EVENTS.clear()
    yield
    obs_reg.set_enabled(prev)
    obs_reg.reset()
    obs_ev.EVENTS.clear()
    obs_ev.EVENTS.configure(None)


# -- time-series ring: windows, deltas, resets, wraparound --------------------


def test_quantile_from_counts_interpolation_and_overflow():
    buckets = (1.0, 2.0, 4.0)
    # all mass in the first bucket: interpolate from lower edge 0
    assert obs_ts.quantile_from_counts(buckets, [4, 0, 0, 0], 0.5) == pytest.approx(0.5)
    # mass in an interior bucket: interpolate inside (1, 2]
    assert obs_ts.quantile_from_counts(buckets, [0, 4, 0, 0], 0.5) == pytest.approx(1.5)
    # +Inf overflow slot clamps to the highest finite edge
    assert obs_ts.quantile_from_counts(buckets, [0, 0, 0, 3], 0.99) == pytest.approx(4.0)
    assert obs_ts.quantile_from_counts(buckets, [0, 0, 0, 0], 0.5) is None


def test_ring_window_delta_rate():
    r = obs_reg.MetricsRegistry()
    c = r.counter("compass_ticks_total", "t")
    ring = obs_ts.TimeSeriesRing(capacity=8)
    ring.snapshot(r, ts=0.0)
    c.inc(10)
    ring.snapshot(r, ts=5.0)
    c.inc(15)
    ring.snapshot(r, ts=10.0)
    # full window: both increments
    assert ring.delta("compass_ticks_total", window_s=10.0, now=10.0) == 25.0
    assert ring.rate("compass_ticks_total", window_s=10.0, now=10.0) == pytest.approx(2.5)
    # short window: only the last pair
    assert ring.delta("compass_ticks_total", window_s=5.0, now=10.0) == 15.0
    # partial window: ring doesn't reach back 100s — uses the oldest held
    assert ring.delta("compass_ticks_total", window_s=100.0, now=10.0) == 25.0
    assert ring.delta("compass_missing_total", window_s=10.0, now=10.0) is None


def test_ring_wraparound_keeps_capacity_and_correct_deltas():
    r = obs_reg.MetricsRegistry()
    c = r.counter("compass_ticks_total", "t")
    ring = obs_ts.TimeSeriesRing(capacity=4)
    for t in range(10):
        c.inc(1)
        ring.snapshot(r, ts=float(t))
    assert len(ring) == 4
    assert ring.t_first == 6.0 and ring.t_last == 9.0
    # only the 3 increments between the oldest held snapshot and the newest
    assert ring.delta("compass_ticks_total", window_s=100.0, now=9.0) == 3.0
    with pytest.raises(ValueError):
        obs_ts.TimeSeriesRing(capacity=1)


def test_ring_delta_across_registry_reset():
    """A counter that went *down* between snapshots was reset: the delta is
    the new value (Prometheus rate() semantics), never negative."""
    ring = obs_ts.TimeSeriesRing(capacity=8)
    obs_reg.registry().counter("compass_ticks_total", "t").inc(5)
    ring.snapshot(obs_reg.registry(), ts=0.0)
    obs_reg.reset()
    obs_reg.registry().counter("compass_ticks_total", "t").inc(2)
    ring.snapshot(obs_reg.registry(), ts=1.0)
    assert ring.delta("compass_ticks_total", window_s=10.0, now=1.0) == 2.0


def test_ring_windowed_quantile_sees_only_window():
    r = obs_reg.MetricsRegistry()
    h = r.histogram("compass_lat_seconds", "l", buckets=(0.1, 1.0))
    for _ in range(100):
        h.observe(5.0)  # ancient slow traffic, before the window
    ring = obs_ts.TimeSeriesRing(capacity=8)
    ring.snapshot(r, ts=0.0)
    for _ in range(10):
        h.observe(0.05)  # fast traffic inside the window
    ring.snapshot(r, ts=1.0)
    q = ring.quantile("compass_lat_seconds", 0.99, window_s=1.0, now=1.0)
    # lifetime p99 would be ~+Inf-bucket (clamped 1.0); the window sees
    # only the 10 fast observations
    assert q is not None and q <= 0.1
    _, counts, _, n = ring.hist_window("compass_lat_seconds", window_s=1.0, now=1.0)
    assert n == 10 and sum(counts) == 10


def test_ring_label_filtered_delta():
    r = obs_reg.MetricsRegistry()
    c = r.counter("compass_q_total", "q", ("shard",))
    ring = obs_ts.TimeSeriesRing(capacity=4)
    ring.snapshot(r, ts=0.0)
    c.inc(7, shard="0")
    c.inc(3, shard="1")
    ring.snapshot(r, ts=1.0)
    assert ring.delta("compass_q_total", window_s=10.0, now=1.0) == 10.0
    assert ring.delta(
        "compass_q_total", window_s=10.0, now=1.0, labels={"shard": "1"}
    ) == 3.0


def test_timeseries_export_valid_and_corruption_detected():
    r = obs_reg.MetricsRegistry()
    c = r.counter("compass_q_total", "q", ("mode",))
    g = r.gauge("compass_epoch", "e")
    h = r.histogram("compass_lat_seconds", "l", buckets=(0.1, 1.0))
    ring = obs_ts.TimeSeriesRing(capacity=8)
    ring.snapshot(r, ts=0.0)
    c.inc(4, mode="prefilter")
    g.set(2)
    h.observe(0.05)
    ring.snapshot(r, ts=2.0)
    payload = ring.to_json()
    assert payload["schema"] == obs_ts.SCHEMA
    assert obs_ts.validate_timeseries_export(payload) == []
    names = {s["name"] for s in payload["series"]}
    assert {"compass_q_total:rate", "compass_epoch:value", "compass_lat_seconds:p50"} <= names
    rate = next(s for s in payload["series"] if s["name"] == "compass_q_total:rate")
    assert rate["labels"] == {"mode": "prefilter"}
    assert rate["points"] == [[2.0, 2.0]]  # 4 increments over a 2s span
    # corruption must be caught
    for mutate in (
        lambda p: p.update(schema="other/v9"),
        lambda p: p["series"][0].update(name="not a name:rate"),
        lambda p: p["series"][0].update(name="compass_q_total:median"),
        lambda p: p["series"][0].update(points=[]),
        lambda p: p["series"][0].update(points=[[1.0, 2.0], [0.5, 2.0]]),
        lambda p: p["series"][0].update(points=[[0.0, float("nan")]]),
    ):
        bad = json.loads(json.dumps(payload))
        mutate(bad)
        assert obs_ts.validate_timeseries_export(bad)


def test_empty_ring_exports_valid_payload():
    payload = obs_ts.TimeSeriesRing(capacity=4).to_json()
    assert payload["n_snapshots"] == 0 and payload["series"] == []
    assert obs_ts.validate_timeseries_export(payload) == []


def test_snapshotter_cadence():
    t = {"now": 0.0}
    snap = obs_ts.Snapshotter(
        obs_reg.MetricsRegistry(), capacity=8, interval_s=1.0, clock=lambda: t["now"]
    )
    assert snap.maybe_snapshot() is True
    t["now"] = 0.5
    assert snap.maybe_snapshot() is False  # inside the interval
    t["now"] = 1.5
    assert snap.maybe_snapshot() is True
    assert len(snap.ring) == 2


# -- SLO burn rates -----------------------------------------------------------


def _ratio_spec(windows):
    return obs_slo.SloSpec(
        name="avail",
        kind="ratio",
        objective=0.9,
        metric="compass_err_total",
        total_metric="compass_req_total",
        windows=windows,
    )


def test_slo_burn_math_and_multiwindow_semantics():
    """burn = bad_fraction / error_budget; a breach needs *every* informed
    window burning — the short window is the 'still happening' check."""
    r = obs_reg.MetricsRegistry()
    err = r.counter("compass_err_total", "e")
    req = r.counter("compass_req_total", "r")
    ring = obs_ts.TimeSeriesRing(capacity=16)
    spec = _ratio_spec((obs_slo.SloWindow(10.0, 2.0), obs_slo.SloWindow(120.0, 1.0)))
    ring.snapshot(r, ts=0.0)
    req.inc(100)
    err.inc(30)  # burst: bad_fraction 0.3, budget 0.1 -> burn 3.0
    ring.snapshot(r, ts=10.0)
    breaching, burns = spec.evaluate(ring, now=10.0)
    assert burns[10.0] == pytest.approx(3.0) and burns[120.0] == pytest.approx(3.0)
    assert breaching
    # recovery: errors stop, traffic continues; the short window clears
    # while the long window still remembers the burst
    req.inc(100)
    ring.snapshot(r, ts=95.0)
    breaching2, burns2 = spec.evaluate(ring, now=95.0)
    assert burns2[10.0] == pytest.approx(0.0)
    assert burns2[120.0] == pytest.approx((30.0 / 200.0) / 0.1)  # 1.5 > 1.0
    assert not breaching2  # the incident already ended


def test_slo_latency_and_recall_kinds():
    r = obs_reg.MetricsRegistry()
    h = r.histogram("compass_lat_seconds", "l", buckets=(0.1, 0.25, 1.0))
    ring = obs_ts.TimeSeriesRing(capacity=8)
    ring.snapshot(r, ts=0.0)
    for _ in range(9):
        h.observe(0.05)
    h.observe(0.5)  # the one bad request
    ring.snapshot(r, ts=1.0)
    lat = obs_slo.SloSpec(
        name="lat", kind="latency", objective=0.95,
        metric="compass_lat_seconds", threshold=0.25,
        windows=(obs_slo.SloWindow(10.0, 1.0),),
    )
    assert lat.bad_fraction(ring, 10.0, now=1.0) == pytest.approx(0.1)
    _, burns = lat.evaluate(ring, now=1.0)
    assert burns[10.0] == pytest.approx(0.1 / 0.05)

    hr = r.histogram("compass_recall", "r", buckets=(0.5, 0.9, 0.95, 1.0))
    ring2 = obs_ts.TimeSeriesRing(capacity=8)
    ring2.snapshot(r, ts=0.0)
    hr.observe(0.99)  # good
    hr.observe(0.3)  # bad: below the 0.9 threshold's bucket
    ring2.snapshot(r, ts=1.0)
    rec = obs_slo.SloSpec(
        name="rec", kind="recall", objective=0.5,
        metric="compass_recall", threshold=0.9,
        windows=(obs_slo.SloWindow(10.0, 1.0),),
    )
    assert rec.bad_fraction(ring2, 10.0, now=1.0) == pytest.approx(0.5)


def test_slo_abstains_without_data():
    r = obs_reg.MetricsRegistry()
    ring = obs_ts.TimeSeriesRing(capacity=4)
    ring.snapshot(r, ts=0.0)
    ring.snapshot(r, ts=1.0)
    spec = _ratio_spec((obs_slo.SloWindow(10.0, 1.0),))
    breaching, burns = spec.evaluate(ring, now=1.0)
    assert not breaching and burns[10.0] is None


def test_slo_spec_validation():
    with pytest.raises(ValueError):
        obs_slo.SloSpec(name="x", kind="weird", objective=0.9, metric="m")
    with pytest.raises(ValueError):
        obs_slo.SloSpec(name="x", kind="ratio", objective=1.5, metric="m", total_metric="t")
    with pytest.raises(ValueError):
        obs_slo.SloSpec(name="x", kind="latency", objective=0.9, metric="m")
    with pytest.raises(ValueError):
        obs_slo.SloSpec(name="x", kind="ratio", objective=0.9, metric="m")


def test_evaluate_slos_publishes_gauges_and_events():
    obs_reg.set_enabled(True)
    r = obs_reg.registry()
    err = r.counter("compass_err_total", "e")
    req = r.counter("compass_req_total", "r")
    err_ring = obs_ts.TimeSeriesRing(capacity=4)
    err_ring.snapshot(r, ts=0.0)
    req.inc(100)
    err.inc(50)
    err_ring.snapshot(r, ts=5.0)
    spec = _ratio_spec((obs_slo.SloWindow(10.0, 2.0),))
    out = obs_slo.evaluate_slos([spec], err_ring, now=5.0, reg=r)
    assert out["avail"]["breaching"]
    assert r.get("compass_slo_breach").value(slo="avail") == 1.0
    assert r.get("compass_slo_burn_rate").value(slo="avail", window="10s") == pytest.approx(5.0)
    ev = obs_ev.EVENTS.tail(1, kind="slo_burn")[0]
    assert ev["slo"] == "avail" and ev["burns"]["10s"] == pytest.approx(5.0)


# -- watchdogs: injected drift must flip them deterministically ---------------


def _drift_phase(index, queries, pred, pm):
    """Run one search against ``index``, record its stats, and return the
    planner-calibration verdict over a fresh ring/registry."""
    obs_reg.reset()
    ring = obs_ts.TimeSeriesRing(capacity=4)
    ring.snapshot(obs_reg.registry(), ts=0.0)
    res = compass_search(index, queries, pred, pm)
    obs_reg.record_search_stats(res.stats)
    ring.snapshot(obs_reg.registry(), ts=1.0)
    return obs_h.planner_calibration(obs_reg.registry(), ring, now=1.0)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_planner_drift_watchdog_flips_on_stale_stats(built_index, corpus, backend):
    """Attribute stats built from a *different* distribution than the live
    attrs (the corpus moved under the planner) must drive the calibration
    watchdog to CRIT; fresh stats must not."""
    from repro.core.planner.stats import build_attr_stats

    _, attrs, queries = corpus
    obs_reg.set_enabled(True)
    qj = jnp.asarray(queries[:8])
    n_attrs = attrs.shape[1]
    # actual pass fraction ~0.6; under attrs**8 the stats estimate ~0.11
    pred = P.stack_predicates([P.Pred.range(0, 0.4, 1.0).tensor(n_attrs)] * 8)
    pm = CompassParams(k=10, ef=32, planner=True, backend=backend)

    fresh = _drift_phase(built_index, qj, pred, pm)
    stale_stats = build_attr_stats(
        (attrs ** 8).astype(np.float32),
        np.asarray(built_index.cattrs.assignments),
        built_index.nlist,
    )
    stale = _drift_phase(built_index._replace(astats=stale_stats), qj, pred, pm)

    assert stale.status == "crit"
    assert stale.value is not None and stale.value >= obs_h.PLANNER_DRIFT_CRIT
    assert fresh.status != "crit"
    assert (fresh.value or 0.0) < stale.value
    assert "rebuild attr stats" in stale.remediation


def _quant_mutable(n=400, d=16, a=4, seed=0):
    from repro.core.index import BuildConfig, build_index
    from repro.core.mutable import MutableIndex
    from repro.core.quant import QuantConfig, quantize_index

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    at = rng.uniform(size=(n, a)).astype(np.float32)
    cfg = BuildConfig(m=8, nlist=8, kmeans_iters=3)
    qcfg = QuantConfig(m=8, ks=16, iters=4)
    base = quantize_index(build_index(x, at, cfg), qcfg, "l2")
    mi = MutableIndex(base, delta_cap=64, auto_compact=False, cfg=cfg, quant_cfg=qcfg)
    return mi, rng, d, a


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_quant_drift_watchdog_flips_on_shifted_upserts(backend):
    """Upserts from a shifted distribution, folded against frozen
    codebooks, must drive quant_staleness to CRIT; an explicit retrain
    must bring it back to OK."""
    obs_reg.set_enabled(True)
    mi, rng, d, a = _quant_mutable()
    q = jnp.asarray(rng.normal(size=(2, d)).astype(np.float32))
    pred = P.stack_predicates([P.Pred.range(0, 0.0, 0.6).tensor(a)] * 2)
    mi.search(q, pred, CompassParams(k=5, ef=32, backend=backend))
    ring = obs_ts.TimeSeriesRing(capacity=4)

    gid0 = mi.base.n_records
    for i in range(40):  # corpus drifts: new rows live 8 sigma away
        mi.upsert(
            gid0 + i,
            (rng.normal(size=d) + 8.0).astype(np.float32),
            rng.uniform(size=a).astype(np.float32),
        )
    mi.compact()  # fold re-encodes against the FROZEN codebooks
    stale = obs_h.quant_staleness(obs_reg.registry(), ring)
    assert stale.status == "crit"
    assert stale.value is not None and stale.value >= obs_h.QUANT_DRIFT_CRIT
    assert "retrain" in stale.remediation

    mi.compact(retrain_codebooks=True)  # operator remediation
    fresh = obs_h.quant_staleness(obs_reg.registry(), ring)
    assert fresh.status == "ok"
    assert fresh.value == pytest.approx(1.0)
    assert obs_reg.registry().get("compass_codebook_retrains_total").value() == 1


def test_compaction_debt_watchdogs():
    from repro.core.index import BuildConfig
    from repro.core.mutable import MutableIndex

    obs_reg.set_enabled(True)
    rng = np.random.default_rng(1)
    n, d, a = 400, 12, 4
    x = rng.normal(size=(n, d)).astype(np.float32)
    at = rng.uniform(size=(n, a)).astype(np.float32)
    mi = MutableIndex.build(
        x, at, BuildConfig(m=8, nlist=8, kmeans_iters=3),
        delta_cap=32, auto_compact=False,
    )
    ring = obs_ts.TimeSeriesRing(capacity=4)
    reg = obs_reg.registry()
    # no writes yet: no debt gauges, both checks OK
    assert obs_h.delta_occupancy(reg, ring).status == "ok"

    next_gid = [n]

    def burst(k):
        for _ in range(k):
            mi.upsert(
                next_gid[0],
                rng.normal(size=d).astype(np.float32),
                rng.uniform(size=a).astype(np.float32),
            )
            next_gid[0] += 1

    burst(26)  # 26/32 = 0.8125
    chk = obs_h.delta_occupancy(reg, ring)
    assert chk.status == "warn" and chk.value == pytest.approx(26 / 32)
    burst(5)  # 31/32 = 0.969 >= crit 0.95
    assert obs_h.delta_occupancy(reg, ring).status == "crit"

    mi.delete(np.arange(250))  # 250/400 dead base rows
    chk = obs_h.tombstone_debt(reg, ring)
    assert chk.status == "crit" and chk.value >= obs_h.TOMBSTONE_CRIT
    mi.compact()  # the remediation clears both debts
    assert obs_h.delta_occupancy(reg, ring).status == "ok"
    assert obs_h.tombstone_debt(reg, ring).status == "ok"


def test_recompile_churn_watchdog_ignores_warmup():
    r = obs_reg.MetricsRegistry()
    ring = obs_ts.TimeSeriesRing(capacity=8)
    c = r.counter("compass_compiles_total", "c", ("cache",))
    # warmup window: counter born inside it -> expected compiles, OK
    ring.snapshot(r, ts=0.0)
    c.inc(3, cache="aot")
    ring.snapshot(r, ts=1.0)
    assert obs_h.recompile_churn(r, ring, now=1.0).status == "ok"
    # steady-state window: counter was already warm at the window start and
    # still moves -> WARN.  A fresh ring models the post-warmup regime (a
    # long-lived ring's oldest snapshot is past warmup once it wraps).
    ring2 = obs_ts.TimeSeriesRing(capacity=8)
    ring2.snapshot(r, ts=2.0)
    c.inc(1, cache="aot")
    ring2.snapshot(r, ts=3.0)
    churn = obs_h.recompile_churn(r, ring2, now=3.0)
    assert churn.status == "warn" and churn.value == 1.0
    assert "ShapePolicy" in churn.remediation


def test_shard_skew_watchdog():
    r = obs_reg.MetricsRegistry()
    ring = obs_ts.TimeSeriesRing(capacity=8)
    c = r.counter("compass_dist_total", "d", ("bucket", "shard"))
    ring.snapshot(r, ts=0.0)
    for s, v in (("0", 400.0), ("1", 0.0), ("2", 0.0), ("3", 0.0)):
        c.inc(v, bucket="", shard=s)
    c.inc(999, bucket="", shard="")  # unsharded traffic must not count
    ring.snapshot(r, ts=1.0)
    chk = obs_h.shard_skew(r, ring, now=1.0)
    assert chk.status == "crit" and chk.value == pytest.approx(4.0)
    assert "shard 0" in chk.detail
    # balanced traffic: OK
    for s in ("0", "1", "2", "3"):
        c.inc(100, bucket="", shard=s)
    ring.snapshot(r, ts=2.0)
    pair_now = obs_h.shard_skew(r, ring, now=2.0)
    # window spans both bursts: shard 0 at 500 vs mean 200 -> 2.5x warn
    assert pair_now.status == "warn"


# -- Monitor: gating, cadence, transitions ------------------------------------


def test_monitor_tick_gated_on_enablement_and_cadence():
    t = {"now": 0.0}
    mon = obs_h.Monitor(interval_s=1.0, clock=lambda: t["now"])
    assert mon.tick() is None  # obs disabled: no snapshot, no report
    assert len(mon.ring) == 0
    obs_reg.set_enabled(True)
    rep = mon.tick()
    assert rep is not None and rep.status == "ok"
    t["now"] = 0.5
    assert mon.tick() is None  # inside the interval
    t["now"] = 1.5
    assert mon.tick() is not None
    assert len(mon.ring) == 2
    # every default check published a health-status gauge
    g = obs_reg.registry().get("compass_health_status")
    names = {s["labels"]["check"] for s in g.samples()}
    assert {"slo:serve_latency", "planner_calibration", "shard_skew"} <= names


def test_monitor_emits_health_event_on_transition():
    obs_reg.set_enabled(True)
    state = {"status": "ok"}

    def flappy(reg, ring, now=None):
        return obs_h.HealthCheck("flappy", state["status"], value=1.0)

    t = {"now": 0.0}
    mon = obs_h.Monitor(
        interval_s=0.0, clock=lambda: t["now"], slos=(), watchdogs=(flappy,)
    )
    assert mon.evaluate().status == "ok"
    assert obs_ev.EVENTS.counts().get("health") is None  # first sighting: no event
    state["status"] = "crit"
    t["now"] = 1.0
    rep = mon.evaluate()
    assert rep.status == "crit" and rep.check("flappy").status == "crit"
    ev = obs_ev.EVENTS.tail(1, kind="health")[0]
    assert ev["check"] == "flappy" and ev["prev"] == "ok" and ev["status"] == "crit"
    assert obs_reg.registry().get("compass_health_status").value(check="flappy") == 2.0


# -- serving surface ----------------------------------------------------------


def _service(mutable: bool):
    from repro.core.index import BuildConfig, build_index
    from repro.core.mutable import MutableIndex
    from repro.serving.search_service import SearchService

    rng = np.random.default_rng(12)
    n, d, a = 400, 12, 4
    x = rng.normal(size=(n, d)).astype(np.float32)
    at = rng.uniform(size=(n, a)).astype(np.float32)
    cfg = BuildConfig(m=8, nlist=8, kmeans_iters=3)
    idx = MutableIndex.build(x, at, cfg, delta_cap=32) if mutable else build_index(x, at, cfg)
    pm = CompassParams(k=5, ef=32, backend="ref")
    svc = SearchService(idx, pm, batch_size=4, max_wait_s=0.0)
    return svc, rng, d, a


def test_service_health_and_stats_surface():
    obs_reg.set_enabled(True)
    svc, rng, d, a = _service(mutable=True)
    assert svc.stats()["health"] is None  # monitoring not attached yet
    for _ in range(4):
        svc.submit(rng.normal(size=d).astype(np.float32), P.Pred.range(0, 0.0, 0.6))
    svc.run_until_idle()
    rep = svc.health()  # lazily attaches a default Monitor
    assert rep.status in ("ok", "warn", "crit")
    assert rep.check("slo:serve_latency") is not None
    assert rep.check("delta_occupancy") is not None
    got = svc.stats()["health"]
    assert got["status"] == rep.status
    assert {c["name"] for c in got["checks"]} == {c.name for c in rep.checks}


def test_service_step_ticks_monitor():
    obs_reg.set_enabled(True)
    svc, rng, d, a = _service(mutable=False)
    svc.enable_monitoring(interval_s=0.0)
    for _ in range(2):  # two scheduling rounds -> two monitor ticks
        for _ in range(4):
            svc.submit(rng.normal(size=d).astype(np.float32), P.Pred.range(0, 0.0, 0.6))
        svc.run_until_idle()
    assert len(svc.monitor.ring) >= 2  # step() snapshotted each round
    assert svc.monitor.last_report is not None
    payload = svc.monitor.ring.to_json()
    assert obs_ts.validate_timeseries_export(payload) == []
    assert any(s["name"] == "compass_serve_requests_total:rate" for s in payload["series"])


def test_service_monitoring_is_bitwise_invariant():
    """The full monitoring stack (snapshots + SLOs + watchdogs every
    round) must not change a bit of any result."""
    def run(monitored: bool):
        svc, rng, d, a = _service(mutable=False)
        if monitored:
            obs_reg.set_enabled(True)
            svc.enable_monitoring(interval_s=0.0)
        else:
            obs_reg.set_enabled(False)
        for _ in range(6):
            svc.submit(rng.normal(size=d).astype(np.float32), P.Pred.range(0, 0.0, 0.6))
        return sorted(svc.run_until_idle(), key=lambda r: r.rid)

    plain, monitored = run(False), run(True)
    assert len(plain) == len(monitored) == 6
    for a_, b in zip(plain, monitored):
        np.testing.assert_array_equal(np.asarray(a_.ids), np.asarray(b.ids))
        np.testing.assert_array_equal(np.asarray(a_.dists), np.asarray(b.dists))


# -- distributed explain fan-out ----------------------------------------------


def test_distributed_explain_sharded_traces():
    from repro.core.distributed import DistributedMutableIndex
    from repro.core.index import BuildConfig
    from repro.obs import ShardedQueryTrace, explain

    rng = np.random.default_rng(21)
    n, d, a = 400, 12, 4
    x = rng.normal(size=(n, d)).astype(np.float32)
    at = rng.uniform(size=(n, a)).astype(np.float32)
    dmi = DistributedMutableIndex.build(
        x, at, 2, BuildConfig(m=8, nlist=8, kmeans_iters=3), delta_cap=32
    )
    q = jnp.asarray(rng.normal(size=(3, d)).astype(np.float32))
    pred = P.stack_predicates([P.Pred.range(0, 0.0, 0.6).tensor(a)] * 3)
    pm = CompassParams(k=5, ef=32, backend="ref")
    plain = dmi.search(q, pred, pm)
    res, traces = dmi.search(q, pred, pm, explain=True)
    np.testing.assert_array_equal(np.asarray(plain.ids), np.asarray(res.ids))
    np.testing.assert_array_equal(np.asarray(plain.dists), np.asarray(res.dists))
    assert len(traces) == 3 and all(isinstance(t, ShardedQueryTrace) for t in traces)
    for t in traces:
        assert len(t.shards) == 2
        assert [s.shard for s in t.shards] == [0, 1]
        assert all(s.epoch == dmi.shards[i].epoch for i, s in enumerate(t.shards))
        # aggregate semantics: work sums, critical path maxes
        assert t.aggregate.n_dist == sum(s.n_dist for s in t.shards)
        assert t.aggregate.n_steps == max(s.n_steps for s in t.shards)
    rendered = explain(traces)
    assert "fan-out: 2 shards" in rendered and "shard[1]" in rendered
    # single sharded trace renders too
    assert "fan-out" in explain(traces[0])


# -- registry reconstruction (report CLI path) --------------------------------


def test_registry_from_json_roundtrip():
    obs_reg.set_enabled(True)
    r = obs_reg.registry()
    r.counter("compass_q_total", "queries", ("mode",)).inc(3, mode="prefilter")
    r.gauge("compass_epoch", "epoch").set(2)
    h = r.histogram("compass_lat_seconds", "latency", buckets=(0.01, 0.1))
    h.observe(0.05)
    h.observe(5.0)
    payload = r.to_json()
    r2 = obs_reg.MetricsRegistry.from_json(payload)
    assert r2.get("compass_q_total").value(mode="prefilter") == 3.0
    assert r2.get("compass_epoch").value() == 2.0
    counts, total, n = r2.get("compass_lat_seconds").series()
    assert list(counts) == [0, 1, 1] and n == 2 and total == pytest.approx(5.05)
    assert obs_reg.validate_export(r2.to_json()) == []
    with pytest.raises(ValueError):
        obs_reg.MetricsRegistry.from_json({"schema": "wrong/v0", "metrics": []})
