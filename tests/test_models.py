"""Per-architecture smoke tests (reduced configs) + algebraic consistency:
the chunked SSD path must match the recurrent path, and prefill+decode must
match a full forward — the invariants serving correctness rests on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, get_config, reduced
from repro.models.model import forward, init_caches, init_params

ARCHS = sorted(all_configs().keys())


def _inputs(cfg, b, s, key):
    kw = {}
    if cfg.embed_inputs and not cfg.frontend:
        kw["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    elif cfg.frontend == "patch":
        k1, k2 = jax.random.split(key)
        kw["tokens"] = jax.random.randint(k1, (b, s), 0, cfg.vocab_size)
        kw["prefix_embeds"] = jax.random.normal(k2, (b, cfg.n_prefix, cfg.d_model)) * 0.1
    else:
        kw["inputs_embeds"] = jax.random.normal(key, (b, s, cfg.d_model)) * 0.1
    return kw


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 32
    kw = _inputs(cfg, b, s, jax.random.PRNGKey(1))
    logits, _ = forward(params, cfg, **kw)
    expect_s = s + (cfg.n_prefix if cfg.frontend == "patch" else 0)
    assert logits.shape == (b, expect_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_no_nans(arch):
    """One SGD step on the reduced config: loss finite, grads finite."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    kw = _inputs(cfg, b, s, jax.random.PRNGKey(1))
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)

    def loss_fn(p):
        logits, _ = forward(p, cfg, **kw)
        logits = logits[:, -s:, :].astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, -1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2.5-3b", "deepseek-v2-lite-16b", "musicgen-large"])
def test_prefill_decode_matches_full_forward(arch):
    """KV-cache invariant: forward(s tokens) == prefill(s-1) + decode(1)."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    key = jax.random.PRNGKey(3)
    if cfg.frontend == "frame":
        emb = jax.random.normal(key, (b, s, cfg.d_model)) * 0.1
        full, _ = forward(params, cfg, inputs_embeds=emb)
        caches = init_caches(cfg, b, s)
        _, caches = forward(params, cfg, inputs_embeds=emb[:, : s - 1], caches=caches,
                            cache_pos=jnp.int32(0))
        last, _ = forward(params, cfg, inputs_embeds=emb[:, s - 1 :], caches=caches,
                          cache_pos=jnp.int32(s - 1))
    else:
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        full, _ = forward(params, cfg, tokens=toks)
        caches = init_caches(cfg, b, s)
        _, caches = forward(params, cfg, tokens=toks[:, : s - 1], caches=caches,
                            cache_pos=jnp.int32(0))
        last, _ = forward(params, cfg, tokens=toks[:, s - 1 :], caches=caches,
                          cache_pos=jnp.int32(s - 1))
    np.testing.assert_allclose(
        np.asarray(last[:, 0].astype(jnp.float32)),
        np.asarray(full[:, -1].astype(jnp.float32)),
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-7b"])
def test_ssm_chunked_matches_recurrent(arch):
    """SSD duality check: chunked prefill logits == step-by-step recurrent
    decode logits over the same sequence."""
    cfg = reduced(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 1, 16  # one chunk = 16 in reduced cfg
    toks = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, tokens=toks)

    caches = init_caches(cfg, b, s)
    outs = []
    for t in range(s):
        lg, caches = forward(params, cfg, tokens=toks[:, t : t + 1], caches=caches,
                             cache_pos=jnp.int32(t))
        outs.append(lg[:, 0])
    stepped = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(stepped.astype(jnp.float32)),
        np.asarray(full.astype(jnp.float32)),
        rtol=5e-2, atol=5e-2,
    )


def test_param_count_sane():
    # full-size configs should land within ~35% of the nominal sizes
    expected = {
        "tinyllama-1.1b": 1.1e9,
        "yi-34b": 34e9,
        "nemotron-4-340b": 340e9,
        "mamba2-2.7b": 2.7e9,
    }
    for name, want in expected.items():
        got = get_config(name).param_count()
        assert 0.6 * want < got < 1.45 * want, (name, got, want)
