"""End-to-end behaviour of CompassSearch against brute-force ground truth,
covering the paper's claim surface: conjunctions, disjunctions, selectivity
extremes, ablations, and baselines."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import predicate as P
from repro.core.baselines import (
    brute_force,
    navix_search,
    postfilter_search,
    prefilter_search,
    recall,
)
from repro.compass import CompassParams, compass_search


def _preds(rng, n_queries, n_attrs, passrate, n_terms, disj=False):
    preds = []
    for _ in range(n_queries):
        terms = []
        for a in range(n_terms):
            lo = rng.uniform(0, 1 - passrate)
            terms.append(P.Pred.range(a, lo, lo + passrate))
        tree = P.Pred.or_(*terms) if disj else P.Pred.and_(*terms)
        preds.append(tree.tensor(n_attrs))
    return P.stack_predicates(preds)


def _recall(index, corpus, pred, pm):
    x, attrs, queries = corpus
    qj = jnp.asarray(queries)
    truth = brute_force(jnp.asarray(x), jnp.asarray(attrs), qj, pred, pm.k)
    res = compass_search(index, qj, pred, pm)
    n = x.shape[0]
    return (
        recall(np.asarray(res.ids), np.asarray(truth.ids), np.asarray(truth.dists), n),
        res,
        truth,
    )


def test_unfiltered_high_recall(built_index, corpus):
    rng = np.random.default_rng(0)
    pred = _preds(rng, 16, 4, 1.0, 1)
    r, res, _ = _recall(built_index, corpus, pred, CompassParams(k=10, ef=128))
    assert r >= 0.85, r


def test_moderate_passrate_conjunction(built_index, corpus):
    rng = np.random.default_rng(1)
    pred = _preds(rng, 16, 4, 0.3, 2)
    r, res, _ = _recall(built_index, corpus, pred, CompassParams(k=10, ef=128))
    assert r >= 0.9, r


def test_low_passrate_uses_btree(built_index, corpus):
    rng = np.random.default_rng(2)
    pred = _preds(rng, 16, 4, 0.3, 4)  # ~0.8% passrate
    r, res, _ = _recall(built_index, corpus, pred, CompassParams(k=10, ef=64))
    assert r >= 0.9, r
    assert np.asarray(res.stats.n_bcalls).mean() > 0  # relational injection fired


def test_disjunction(built_index, corpus):
    rng = np.random.default_rng(3)
    pred = _preds(rng, 16, 4, 0.3, 3, disj=True)
    r, _, _ = _recall(built_index, corpus, pred, CompassParams(k=10, ef=128))
    assert r >= 0.9, r


def test_results_pass_predicate_and_sorted(built_index, corpus):
    x, attrs, queries = corpus
    rng = np.random.default_rng(4)
    pred = _preds(rng, 16, 4, 0.3, 2)
    res = compass_search(built_index, jnp.asarray(queries), pred, CompassParams(k=10, ef=64))
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    n = x.shape[0]
    lo, hi = np.asarray(pred.lo), np.asarray(pred.hi)
    for b in range(ids.shape[0]):
        valid = ids[b] < n
        assert np.all(np.diff(dists[b][np.isfinite(dists[b])]) >= 0)  # sorted
        for i in ids[b][valid]:
            ok = np.any(np.all((attrs[i] >= lo[b]) & (attrs[i] <= hi[b]), axis=-1))
            assert ok, (b, i)
        # returned distances match recomputed distances
        want = ((x[ids[b][valid]] - queries[b]) ** 2).sum(-1)
        np.testing.assert_allclose(dists[b][valid], want, rtol=1e-4)


def test_ef_monotonically_improves(built_index, corpus):
    rng = np.random.default_rng(5)
    pred = _preds(rng, 16, 4, 0.3, 1)
    r32, *_ = _recall(built_index, corpus, pred, CompassParams(k=10, ef=32))
    r256, *_ = _recall(built_index, corpus, pred, CompassParams(k=10, ef=256))
    assert r256 >= r32 - 0.02
    assert r256 >= 0.95


def test_navix_fails_low_passrate_compass_does_not(built_index, corpus):
    x, attrs, queries = corpus
    rng = np.random.default_rng(6)
    pred = _preds(rng, 16, 4, 0.02, 1)
    qj = jnp.asarray(queries)
    truth = brute_force(jnp.asarray(x), jnp.asarray(attrs), qj, pred, 10)
    n = x.shape[0]
    nav = navix_search(built_index, qj, pred, CompassParams(k=10, ef=128))
    com = compass_search(built_index, qj, pred, CompassParams(k=10, ef=128))
    r_nav = recall(np.asarray(nav.ids), np.asarray(truth.ids), np.asarray(truth.dists), n)
    r_com = recall(np.asarray(com.ids), np.asarray(truth.ids), np.asarray(truth.dists), n)
    assert r_com >= 0.9, r_com
    assert r_com > r_nav  # the paper's central robustness claim


def test_prefilter_is_exact(built_index, corpus):
    x, attrs, queries = corpus
    rng = np.random.default_rng(7)
    pred = _preds(rng, 16, 4, 0.1, 1)
    qj = jnp.asarray(queries)
    truth = brute_force(jnp.asarray(x), jnp.asarray(attrs), qj, pred, 10)
    pf = prefilter_search(built_index, qj, pred, 10)
    n = x.shape[0]
    assert recall(np.asarray(pf.ids), np.asarray(truth.ids), np.asarray(truth.dists), n) == 1.0


def test_postfilter_runs(built_index, corpus):
    x, attrs, queries = corpus
    rng = np.random.default_rng(8)
    pred = _preds(rng, 16, 4, 0.5, 1)
    res = postfilter_search(built_index, jnp.asarray(queries), pred, 10)
    ids = np.asarray(res.ids)
    lo, hi = np.asarray(pred.lo), np.asarray(pred.hi)
    n = x.shape[0]
    for b in range(ids.shape[0]):
        for i in ids[b][ids[b] < n]:
            assert np.any(np.all((attrs[i] >= lo[b]) & (attrs[i] <= hi[b]), axis=-1))


def test_compass_relational_ablation(built_index, corpus):
    rng = np.random.default_rng(9)
    pred = _preds(rng, 16, 4, 0.3, 1)
    pm = CompassParams(k=10, ef=64, use_graph=False)
    r, res, _ = _recall(built_index, corpus, pred, pm)
    assert np.asarray(res.stats.n_bcalls).mean() > 0
    # runs and returns only valid, predicate-passing records
    assert r >= 0.2


# ---------------------------------------------------------------------------
# Execution-engine backend parity: the "pallas" backend (kernels on the VISIT
# hot path, interpret mode on CPU) must be indistinguishable from the "ref"
# jnp path — identical ids, dists, and distance counts.  Seeds are fixed:
# centroid scores may differ in ULPs between the two formulas (see
# engine/backend.py), so exact equality is asserted on these workloads, not
# claimed for adversarially tie-heavy data.
# ---------------------------------------------------------------------------

_PARITY_CASES = {
    "conjunction": dict(passrate=0.3, n_terms=2, disj=False),
    "disjunction": dict(passrate=0.3, n_terms=3, disj=True),
    "high_selectivity": dict(passrate=0.05, n_terms=2, disj=False),  # ~0.25%
}


@pytest.mark.parametrize("case", sorted(_PARITY_CASES))
def test_backend_parity(built_index, corpus, case):
    x, attrs, queries = corpus
    rng = np.random.default_rng(12)
    pred = _preds(rng, 16, 4, **_PARITY_CASES[case])
    qj = jnp.asarray(queries)
    ref = compass_search(built_index, qj, pred, CompassParams(k=10, ef=64, backend="ref"))
    pal = compass_search(built_index, qj, pred, CompassParams(k=10, ef=64, backend="pallas"))
    np.testing.assert_array_equal(np.asarray(ref.ids), np.asarray(pal.ids))
    np.testing.assert_array_equal(np.asarray(ref.dists), np.asarray(pal.dists))
    np.testing.assert_array_equal(
        np.asarray(ref.stats.n_dist), np.asarray(pal.stats.n_dist)
    )


def test_pallas_backend_routes_visit_through_kernel(built_index, corpus, monkeypatch):
    """backend="pallas" must hit the fused kernels.visit_step (the VISIT hot
    path since engine/5) plus kernels.ivf_score at trace time, and
    fused_visit=False must fall back to the unfused kernels.filter_distance
    route (a fresh ef forces a fresh trace for each)."""
    from repro.kernels import ops

    calls = {"visit_step": 0, "filter_distance": 0, "ivf_score": 0}
    real_vs, real_fd, real_ivf = ops.visit_step, ops.filter_distance, ops.ivf_score

    def spy_vs(*a, **kw):
        calls["visit_step"] += 1
        return real_vs(*a, **kw)

    def spy_fd(*a, **kw):
        calls["filter_distance"] += 1
        return real_fd(*a, **kw)

    def spy_ivf(*a, **kw):
        calls["ivf_score"] += 1
        return real_ivf(*a, **kw)

    monkeypatch.setattr(ops, "visit_step", spy_vs)
    monkeypatch.setattr(ops, "filter_distance", spy_fd)
    monkeypatch.setattr(ops, "ivf_score", spy_ivf)
    x, attrs, queries = corpus
    rng = np.random.default_rng(13)
    pred = _preds(rng, 16, 4, 0.3, 2)
    compass_search(
        built_index, jnp.asarray(queries), pred, CompassParams(k=7, ef=48, backend="pallas")
    )
    assert calls["visit_step"] > 0
    assert calls["filter_distance"] == 0  # VISIT fused: no unfused kernel calls
    assert calls["ivf_score"] > 0
    compass_search(
        built_index, jnp.asarray(queries), pred,
        CompassParams(k=7, ef=40, backend="pallas", fused_visit=False),
    )
    assert calls["filter_distance"] > 0  # unfused route restored on demand


def test_unknown_backend_rejected(built_index, corpus):
    x, attrs, queries = corpus
    rng = np.random.default_rng(14)
    pred = _preds(rng, 16, 4, 0.3, 1)
    with pytest.raises(ValueError, match="unknown backend"):
        compass_search(
            built_index, jnp.asarray(queries), pred, CompassParams(k=10, ef=64, backend="vulkan")
        )


def test_unsatisfiable_predicate_terminates_empty(built_index, corpus):
    x, attrs, queries = corpus
    preds = P.stack_predicates(
        [P.Pred.range(0, 2.0, 3.0).tensor(4) for _ in range(16)]
    )  # attrs are U[0,1] -> empty
    res = compass_search(built_index, jnp.asarray(queries), preds, CompassParams(k=10, ef=64))
    assert np.all(~np.isfinite(np.asarray(res.dists)))
    assert np.all(np.asarray(res.ids) == x.shape[0])
