"""Distributed Compass search: executed on 8 virtual devices in a
subprocess (device count must be set before jax initializes), validating
that corpus-sharded search + global top-k merge matches brute force."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import predicate as P
    from repro.core.baselines import brute_force, recall
    from repro.core.distributed import build_sharded_index, make_distributed_search
    from repro.core.index import BuildConfig
    from repro.compass import CompassParams
    from repro.data.synthetic import make_vector_corpus

    n, d, a, n_shards = 8000, 24, 4, 8
    x, attrs, queries = make_vector_corpus(n, d, a, n_modes=32, seed=3)
    queries = queries[:8]
    sidx = build_sharded_index(x, attrs, n_shards, BuildConfig(m=12, nlist=16))
    mesh = jax.make_mesh((8,), ("shard",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    pm = CompassParams(k=10, ef=64)
    search = make_distributed_search(mesh, pm)
    rng = np.random.default_rng(0)
    preds = []
    for _ in range(8):
        lo = rng.uniform(0, 0.7)
        preds.append(P.Pred.and_(P.Pred.range(0, lo, lo + 0.3),
                                 P.Pred.range(1, 0.2, 0.8)).tensor(a))
    pred = P.stack_predicates(preds)
    with jax.set_mesh(mesh):
        ids, dists = search(sidx, jnp.asarray(queries), pred)
    # map global ids back: shard * n_local + local, n_local = n // n_shards
    truth = brute_force(jnp.asarray(x), jnp.asarray(attrs), jnp.asarray(queries), pred, 10)
    n_loc = n // n_shards
    gids = np.asarray(ids)
    # translate shard-local ids to corpus ids (shards were contiguous splits)
    corpus_ids = np.where(gids < n, (gids // n_loc) * n_loc + gids % n_loc, n)
    r = recall(corpus_ids, np.asarray(truth.ids), np.asarray(truth.dists), n)
    print("RECALL", r)
    assert r >= 0.9, r
    # distances sorted ascending and finite where valid
    dd = np.asarray(dists)
    for b in range(dd.shape[0]):
        fin = dd[b][np.isfinite(dd[b])]
        assert np.all(np.diff(fin) >= 0)
    print("DISTRIBUTED_OK")
    """
)


@pytest.mark.xfail(strict=False, reason="pre-existing at seed: script uses jax.sharding.AxisType, absent in pinned jax 0.4.37")
@pytest.mark.slow
def test_distributed_search_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=1200, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "DISTRIBUTED_OK" in out.stdout, out.stdout + out.stderr


@pytest.mark.xfail(strict=False, reason="pre-existing at seed: script uses jax.sharding.AxisType, absent in pinned jax 0.4.37")
@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """The dry-run driver itself (512 virtual devices) on the smallest cell."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "granite-moe-1b-a400m", "--shape", "decode_32k",
        ],
        capture_output=True, text=True, timeout=1200, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "OK granite-moe-1b-a400m x decode_32k" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
