"""Import hypothesis if available; otherwise provide stand-ins that skip
ONLY the property tests, so the deterministic tests in the same module keep
running (a module-level ``pytest.importorskip`` would silently drop them
all — see requirements.txt for the pinned hypothesis).

Usage (instead of importing from hypothesis directly):

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # fall back to skip-marking just the @given tests
    import pytest

    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any st.<name>(...) call; tests using it are skipped."""

        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _AnyStrategy()

    def given(*_a, **_kw):
        return pytest.mark.skip(
            reason="hypothesis not installed (see requirements.txt)"
        )

    def settings(*_a, **_kw):
        return lambda f: f

__all__ = ["HAS_HYPOTHESIS", "given", "settings", "st"]
