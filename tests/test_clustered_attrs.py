import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.clustered_attrs import (
    build_clustered_attrs,
    count_in_cluster,
    range_in_cluster,
)


@pytest.fixture(scope="module")
def ca_data():
    rng = np.random.default_rng(1)
    n, a, nlist = 3000, 3, 16
    attrs = rng.uniform(size=(n, a)).astype(np.float32)
    assign = rng.integers(0, nlist, n)
    return attrs, assign, build_clustered_attrs(attrs, assign, nlist)


def test_range_matches_bruteforce(ca_data):
    attrs, assign, ca = ca_data
    rng = np.random.default_rng(2)
    for _ in range(25):
        c = int(rng.integers(0, 16))
        a = int(rng.integers(0, 3))
        lo, hi = sorted(rng.uniform(0, 1, 2))
        beg, end = range_in_cluster(ca, c, a, lo, hi)
        got = set(np.asarray(ca.order[a])[int(beg) : int(end)].tolist())
        want = set(np.where((assign == c) & (attrs[:, a] >= lo) & (attrs[:, a] <= hi))[0].tolist())
        assert got == want


def test_empty_range(ca_data):
    _, _, ca = ca_data
    beg, end = range_in_cluster(ca, 0, 0, 0.5, 0.4)
    assert int(end - beg) <= 0 or int(end) == int(beg)


def test_count_matches_range(ca_data):
    attrs, assign, ca = ca_data
    cnt = int(count_in_cluster(ca, 3, 1, 0.25, 0.75))
    want = int(((assign == 3) & (attrs[:, 1] >= 0.25) & (attrs[:, 1] <= 0.75)).sum())
    assert cnt == want


@settings(max_examples=25, deadline=None)
@given(
    lo=st.floats(0, 1),
    hi=st.floats(0, 1),
    c=st.integers(0, 15),
    a=st.integers(0, 2),
)
def test_property_range_counts(ca_data, lo, hi, c, a):
    attrs, assign, ca = ca_data
    lo, hi = min(lo, hi), max(lo, hi)
    beg, end = range_in_cluster(ca, c, a, np.float32(lo), np.float32(hi))
    want = int(
        ((assign == c) & (attrs[:, a] >= np.float32(lo)) & (attrs[:, a] <= np.float32(hi))).sum()
    )
    assert int(end) - int(beg) == want
