"""Observability tests: registry units + exports, explain traces (per-mode
est-vs-actual selectivity, determinism, bitwise invariance on ref AND
pallas), event log + JSONL sink, mutable/serving/distributed wiring, and
the kernel fallback/autotune counters.

The two contracts under test everywhere: obs OFF means results are bitwise
identical to a build without the subsystem, and obs ON changes nothing
about the traced program (recording happens host-side at existing sync
points only).
"""
from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import predicate as P
from repro.core.engine import CompassParams, compass_search
from repro.core.planner import plan as QP
from repro.obs import events as obs_ev
from repro.obs import registry as obs_reg
from repro.obs.trace import QueryTrace, explain, kernel_route


@pytest.fixture(autouse=True)
def _isolated_obs():
    """Every test starts with a clean registry/event log and obs disabled,
    and cannot leak its enablement into the rest of the suite."""
    prev = obs_reg.set_enabled(False)
    obs_reg.reset()
    obs_ev.EVENTS.clear()
    yield
    obs_reg.set_enabled(prev)
    obs_reg.reset()
    obs_ev.EVENTS.clear()
    obs_ev.EVENTS.configure(None)


def _preds(rng, n_queries, n_attrs, passrate, n_terms):
    preds = []
    for _ in range(n_queries):
        terms = []
        for a in range(n_terms):
            lo = rng.uniform(0, 1 - passrate)
            terms.append(P.Pred.range(a, lo, lo + passrate))
        preds.append(P.Pred.and_(*terms).tensor(n_attrs))
    return P.stack_predicates(preds)


# -- registry units -----------------------------------------------------------


def test_counter_gauge_histogram_basics():
    r = obs_reg.MetricsRegistry()
    c = r.counter("compass_test_total", "help", ("shard",))
    c.inc(shard="0")
    c.inc(2.5, shard="0")
    c.inc(shard="1")
    assert c.value(shard="0") == pytest.approx(3.5)
    assert c.value(shard="1") == pytest.approx(1.0)
    with pytest.raises(ValueError):
        c.inc(-1, shard="0")
    with pytest.raises(ValueError):  # labels must match labelnames exactly
        c.inc(bucket="B8")
    g = r.gauge("compass_test_epoch")
    g.set(7)
    assert g.value() == 7.0
    h = r.histogram("compass_test_seconds", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    counts, total, n = h.series()
    assert list(counts) == [1, 1, 1] and n == 3 and total == pytest.approx(5.55)


def test_registry_redeclare_conflicts():
    r = obs_reg.MetricsRegistry()
    r.counter("compass_x_total", labelnames=("kind",))
    with pytest.raises(ValueError):
        r.gauge("compass_x_total")  # type conflict
    with pytest.raises(ValueError):
        r.counter("compass_x_total", labelnames=("other",))  # labelname conflict
    with pytest.raises(ValueError):
        r.counter("0bad-name")  # illegal prometheus name


def test_export_json_and_prometheus_validate():
    r = obs_reg.MetricsRegistry()
    r.counter("compass_q_total", "queries", ("mode",)).inc(3, mode="prefilter")
    r.gauge("compass_epoch", "epoch").set(2)
    h = r.histogram("compass_lat_seconds", "latency", buckets=(0.01, 0.1))
    h.observe(0.05)
    payload = r.to_json()
    assert payload["schema"] == obs_reg.SCHEMA
    assert obs_reg.validate_export(payload) == []
    text = r.to_prometheus()
    assert '# TYPE compass_q_total counter' in text
    assert 'compass_q_total{mode="prefilter"} 3' in text
    # cumulative le buckets + the +Inf terminator
    assert 'le="0.1"' in text and 'le="+Inf"' in text
    assert "compass_lat_seconds_count" in text


def test_prometheus_hist_inf_sum_count_consistency():
    """The text exposition's histogram lines must be internally consistent:
    cumulative ``le`` counts non-decreasing, the +Inf bucket equal to
    ``_count``, and ``_sum`` present — the invariants a Prometheus scraper
    relies on."""
    r = obs_reg.MetricsRegistry()
    h = r.histogram("compass_lat_seconds", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 7.0):
        h.observe(v)
    lines = [ln for ln in r.to_prometheus().splitlines() if not ln.startswith("#")]
    bucket_lines = [ln for ln in lines if ln.startswith("compass_lat_seconds_bucket")]
    cum = [float(ln.rsplit(" ", 1)[1]) for ln in bucket_lines]
    assert cum == sorted(cum)  # cumulative counts never decrease
    assert 'le="+Inf"' in bucket_lines[-1]
    count = next(
        float(ln.rsplit(" ", 1)[1])
        for ln in lines
        if ln.startswith("compass_lat_seconds_count")
    )
    total = next(
        float(ln.rsplit(" ", 1)[1])
        for ln in lines
        if ln.startswith("compass_lat_seconds_sum")
    )
    assert cum[-1] == count == 4
    assert total == pytest.approx(8.05)


def test_label_escaping_roundtrip():
    r"""Label values carrying backslashes, quotes and newlines must escape
    in the text exposition and survive a JSON export -> from_json
    reconstruction byte-for-byte."""
    nasty = 'a"b\\c\nd'
    r = obs_reg.MetricsRegistry()
    r.counter("compass_q_total", "q", ("tag",)).inc(2, tag=nasty)
    text = r.to_prometheus()
    assert 'tag="a\\"b\\\\c\\nd"' in text
    assert "\n" not in text.split("compass_q_total{", 1)[1].split("}", 1)[0]
    payload = r.to_json()
    assert obs_reg.validate_export(payload) == []
    r2 = obs_reg.MetricsRegistry.from_json(json.loads(json.dumps(payload)))
    assert r2.get("compass_q_total").value(tag=nasty) == 2.0
    assert r2.to_prometheus() == text


def test_truncated_metrics_json_rejected(tmp_path):
    """A METRICS.json cut off mid-write (partial disk flush, killed run)
    must fail validation loudly, not parse as a smaller registry."""
    from repro.obs.validate import validate_any_file

    r = obs_reg.MetricsRegistry()
    r.counter("compass_q_total", "q").inc(3)
    r.histogram("compass_lat_seconds", "l", buckets=(0.1,)).observe(0.05)
    blob = json.dumps(r.to_json(), indent=1)
    good = tmp_path / "METRICS.json"
    good.write_text(blob)
    assert validate_any_file(str(good)) == []
    truncated = tmp_path / "TRUNC.json"
    truncated.write_text(blob[: len(blob) // 2])
    errs = validate_any_file(str(truncated))
    assert errs and "malformed JSON" in errs[0]
    # histogram invariants: count must equal the bucket-count sum
    bad = json.loads(blob)
    for m in bad["metrics"]:
        if m["type"] == "histogram":
            m["samples"][0]["count"] += 1
    (tmp_path / "BADSUM.json").write_text(json.dumps(bad))
    assert validate_any_file(str(tmp_path / "BADSUM.json"))


def test_validate_export_catches_corruption():
    r = obs_reg.MetricsRegistry()
    r.counter("compass_ok_total").inc()
    good = r.to_json()
    bad = json.loads(json.dumps(good))
    bad["metrics"][0]["name"] = "not a legal name!"
    assert obs_reg.validate_export(bad)
    bad2 = json.loads(json.dumps(good))
    bad2["schema"] = "something/else"
    assert obs_reg.validate_export(bad2)


# -- explain traces -----------------------------------------------------------


def test_explain_flag_shapes_and_bitwise(built_index, corpus):
    _, _, queries = corpus
    rng = np.random.default_rng(3)
    qj = jnp.asarray(queries[:8])
    pred = _preds(rng, 8, 4, 0.45, 2)
    pm = CompassParams(k=10, ef=32, planner=True, backend="ref")
    res = compass_search(built_index, qj, pred, pm)
    out = compass_search(built_index, qj, pred, pm, explain=True)
    assert isinstance(out, tuple) and len(out) == 2
    res2, traces = out
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(res2.ids))
    np.testing.assert_array_equal(np.asarray(res.dists), np.asarray(res2.dists))
    assert len(traces) == 8 and all(isinstance(t, QueryTrace) for t in traces)
    # explain=False (the default) returns the bare SearchResult, not a
    # (result, traces) pair — SearchResult is itself a NamedTuple, so probe
    # the wrapper shape, not tuple-ness
    assert isinstance(res2, type(res)) and hasattr(res, "ids")
    rendered = explain(traces)
    assert "selectivity est=" in rendered and "mode=" in rendered


def test_explain_determinism(built_index, corpus):
    _, _, queries = corpus
    rng = np.random.default_rng(4)
    qj = jnp.asarray(queries[:4])
    pred = _preds(rng, 4, 4, 0.45, 2)
    pm = CompassParams(k=10, ef=32, planner=True, backend="ref")
    _, t1 = compass_search(built_index, qj, pred, pm, explain=True)
    _, t2 = compass_search(built_index, qj, pred, pm, explain=True)
    assert t1 == t2  # frozen dataclasses of host scalars: exact equality


@pytest.mark.parametrize(
    "passrate,n_terms,want_mode,want_name",
    [
        (0.01, 1, QP.PREFILTER, "prefilter"),
        (0.45, 2, QP.COOPERATIVE, "cooperative"),
        (0.99, 1, QP.POSTFILTER, "postfilter"),
    ],
)
def test_explain_selectivity_per_mode(
    built_index, corpus, passrate, n_terms, want_mode, want_name
):
    """Each planner mode yields traces with BOTH the planner's estimate and
    the measured actual selectivity populated and sane."""
    _, _, queries = corpus
    rng = np.random.default_rng(5)
    qj = jnp.asarray(queries[:8])
    pred = _preds(rng, 8, 4, passrate, n_terms)
    pm = CompassParams(k=10, ef=64, planner=True, backend="ref")
    res, traces = compass_search(built_index, qj, pred, pm, explain=True)
    assert np.all(np.asarray(res.stats.mode) == want_mode)
    for t in traces:
        assert t.mode == want_name
        assert t.planner is True
        assert t.est_selectivity is not None and 0.0 <= t.est_selectivity <= 1.0
        assert t.actual_selectivity is not None and 0.0 <= t.actual_selectivity <= 1.0
        assert t.run_total is not None and t.run_total >= 0
        assert t.kernel_route == "ref"
    # the estimate should be in the right regime for the extremes
    if want_mode == QP.PREFILTER:
        assert all(t.est_selectivity < 0.1 for t in traces)
    if want_mode == QP.POSTFILTER:
        assert all(t.est_selectivity > 0.5 for t in traces)


def test_planner_off_trace_fields_none(built_index, corpus):
    _, _, queries = corpus
    rng = np.random.default_rng(6)
    qj = jnp.asarray(queries[:4])
    pred = _preds(rng, 4, 4, 0.45, 2)
    pm = CompassParams(k=10, ef=32, planner=False, backend="ref")
    _, traces = compass_search(built_index, qj, pred, pm, explain=True)
    for t in traces:
        assert t.planner is False
        assert t.est_selectivity is None and t.run_total is None
        # measured selectivity still reports — it comes from SearchStats
        assert t.actual_selectivity is not None


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_obs_enabled_is_bitwise_invariant(built_index, corpus, backend):
    """Flipping the registry on (and recording into it) must not change a
    single bit of ids or dists, on the jnp path AND the kernel path."""
    _, _, queries = corpus
    rng = np.random.default_rng(7)
    qj = jnp.asarray(queries[:4])
    pred = _preds(rng, 4, 4, 0.45, 2)
    pm = CompassParams(k=10, ef=32, planner=True, backend=backend)
    off = compass_search(built_index, qj, pred, pm)
    obs_reg.set_enabled(True)
    on = compass_search(built_index, qj, pred, pm)
    obs_reg.record_search_stats(on.stats)  # recording is host-side only
    np.testing.assert_array_equal(np.asarray(off.ids), np.asarray(on.ids))
    np.testing.assert_array_equal(np.asarray(off.dists), np.asarray(on.dists))
    assert (
        obs_reg.registry()
        .get("compass_queries_total")
        .value(bucket="", shard="", tenant="")
        == 4
    )


def test_kernel_route_strings():
    pm = CompassParams(k=10, ef=32, backend="pallas")
    assert kernel_route(pm.resolved(), quant_active=False, metric="l2").startswith(
        "pallas/visit_step/"
    )
    assert kernel_route(pm.resolved(), quant_active=True, metric="ip").startswith(
        "pallas/pq_score/"
    )
    pm_unfused = CompassParams(k=10, ef=32, backend="pallas", fused_visit=False)
    assert kernel_route(
        pm_unfused.resolved(), quant_active=False, metric="l2"
    ).startswith("pallas/filter_distance/")
    assert kernel_route(pm.resolved(), quant_active=False, metric="weird") == (
        "ref(metric=weird)"
    )
    pm_ref = CompassParams(k=10, ef=32, backend="ref")
    assert kernel_route(pm_ref.resolved(), quant_active=False, metric="l2") == "ref"


def test_record_search_stats_noop_when_disabled(built_index, corpus):
    _, _, queries = corpus
    rng = np.random.default_rng(8)
    qj = jnp.asarray(queries[:2])
    pred = _preds(rng, 2, 4, 0.45, 1)
    res = compass_search(built_index, qj, pred, CompassParams(k=5, ef=32, backend="ref"))
    obs_reg.record_search_stats(res.stats)  # disabled: must not register
    assert obs_reg.registry().get("compass_queries_total") is None
    with pytest.raises(ValueError):
        obs_reg.set_enabled(True)
        obs_reg.record_search_stats(res.stats, labels={"nonsense": "x"})


# -- mutable tier: explain epoch, events, JSONL sink --------------------------


def _tiny_mutable(n=400, d=12, a=4, cap=32, seed=0):
    from repro.core.index import BuildConfig
    from repro.core.mutable import MutableIndex

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    at = rng.uniform(size=(n, a)).astype(np.float32)
    mi = MutableIndex.build(
        x, at, BuildConfig(m=8, nlist=8, kmeans_iters=3), delta_cap=cap
    )
    q = jnp.asarray(rng.normal(size=(4, d)).astype(np.float32))
    pred = P.stack_predicates([P.Pred.range(0, 0.0, 0.6).tensor(a)] * 4)
    return mi, q, pred, rng


def test_mutable_explain_carries_epoch():
    mi, q, pred, _ = _tiny_mutable()
    pm = CompassParams(k=5, ef=32, backend="ref")
    res, traces = mi.search(q, pred, pm, explain=True)
    assert all(t.epoch == mi.epoch for t in traces)
    res2 = mi.search(q, pred, pm)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(res2.ids))


def test_mutable_lifecycle_events_and_sink(tmp_path):
    sink = tmp_path / "events.jsonl"
    obs_ev.EVENTS.configure(str(sink))
    mi, q, pred, rng = _tiny_mutable(cap=16)
    d, a = 12, 4
    gid = mi.base.n_records
    for i in range(40):  # overflow the 16-slot delta -> forced compactions
        mi.upsert(
            gid + i,
            rng.normal(size=d).astype(np.float32),
            rng.uniform(size=a).astype(np.float32),
        )
    assert mi.epoch >= 1
    kinds = {e["kind"] for e in obs_ev.EVENTS.tail(200)}
    assert {"delta_overflow", "compaction", "epoch_swap"} <= kinds
    comp = obs_ev.EVENTS.tail(5, kind="compaction")[-1]
    assert comp["epoch"] == mi.epoch and comp["wall_s"] >= 0
    # the JSONL sink mirrors the ring, one parseable object per line
    lines = [json.loads(ln) for ln in sink.read_text().splitlines()]
    assert len(lines) == sum(obs_ev.EVENTS.counts().values())
    assert all("ts" in e and "kind" in e for e in lines)


def test_mutable_compaction_metrics_in_registry():
    obs_reg.set_enabled(True)
    mi, _, _, _ = _tiny_mutable()
    mi.compact()
    r = obs_reg.registry()
    assert r.get("compass_compactions_total").value() >= 1
    assert r.get("compass_epoch").value() == mi.epoch
    counts, _, n = r.get("compass_compaction_seconds").series()
    assert n >= 1 and sum(counts) == n
    assert obs_reg.validate_export(r.to_json()) == []


# -- distributed: aggregation semantics + shard labels ------------------------


def test_aggregate_shard_stats_semantics():
    from repro.core.distributed import (
        STATS_FIRST_FIELDS,
        STATS_MAX_FIELDS,
        STATS_SUM_FIELDS,
        aggregate_shard_stats,
    )
    from repro.core.engine import SearchStats

    # the classification must cover every SearchStats field exactly once
    all_classified = (
        set(STATS_SUM_FIELDS) | set(STATS_MAX_FIELDS) | set(STATS_FIRST_FIELDS)
    )
    assert all_classified == set(SearchStats._fields)
    assert (
        len(STATS_SUM_FIELDS) + len(STATS_MAX_FIELDS) + len(STATS_FIRST_FIELDS)
        == len(SearchStats._fields)
    )

    def mk(base):
        return SearchStats(
            n_dist=jnp.array([base, base + 1]),
            n_cdist=jnp.array([base] * 2),
            n_steps=jnp.array([base, 2 * base]),
            n_bcalls=jnp.array([1, 1]),
            n_clusters_ranked=jnp.array([2, 2]),
            n_adc=jnp.array([0, 0]),
            n_rerank=jnp.array([0, 0]),
            n_pass=jnp.array([base, base]),
            mode=jnp.array([base % 3, base % 3]),
            efs_final=jnp.array([32, 32]),
            est_sel=jnp.array([0.1 * base, 0.2]),
            run_total=jnp.array([5, 5]),
        )

    agg = aggregate_shard_stats([mk(10), mk(4)])
    np.testing.assert_array_equal(np.asarray(agg.n_dist), [14, 16])  # summed
    np.testing.assert_array_equal(np.asarray(agg.n_pass), [14, 14])  # summed
    np.testing.assert_array_equal(np.asarray(agg.n_steps), [10, 20])  # max
    np.testing.assert_array_equal(np.asarray(agg.mode), [1, 1])  # shard 0
    np.testing.assert_allclose(np.asarray(agg.est_sel), [1.0, 0.2])  # shard 0


def test_distributed_search_records_per_shard():
    from repro.core.distributed import DistributedMutableIndex
    from repro.core.index import BuildConfig

    rng = np.random.default_rng(11)
    n, d, a = 400, 12, 4
    x = rng.normal(size=(n, d)).astype(np.float32)
    at = rng.uniform(size=(n, a)).astype(np.float32)
    dmi = DistributedMutableIndex.build(
        x, at, 2, BuildConfig(m=8, nlist=8, kmeans_iters=3), delta_cap=32
    )
    assert dmi.shards[0].obs_labels == {"shard": "0"}
    assert dmi.shards[1].obs_labels == {"shard": "1"}
    q = jnp.asarray(rng.normal(size=(2, d)).astype(np.float32))
    pred = P.stack_predicates([P.Pred.range(0, 0.0, 0.6).tensor(a)] * 2)
    pm = CompassParams(k=5, ef=32, backend="ref")
    off = dmi.search(q, pred, pm)
    obs_reg.set_enabled(True)
    on = dmi.search(q, pred, pm)
    np.testing.assert_array_equal(np.asarray(off.ids), np.asarray(on.ids))
    c = obs_reg.registry().get("compass_queries_total")
    assert c.value(bucket="", shard="0", tenant="") == 2
    assert c.value(bucket="", shard="1", tenant="") == 2
    # the aggregate the caller sees matches the per-shard sum in the registry
    per_shard_dist = obs_reg.registry().get("compass_dist_total")
    assert per_shard_dist.value(bucket="", shard="0", tenant="") + per_shard_dist.value(
        bucket="", shard="1", tenant=""
    ) == pytest.approx(float(np.asarray(on.stats.n_dist).sum()))


# -- serving: per-batch metrics, compile events, write-error routing ----------


def _service(mutable: bool):
    from repro.core.index import BuildConfig, build_index
    from repro.core.mutable import MutableIndex
    from repro.serving.search_service import SearchService

    rng = np.random.default_rng(12)
    n, d, a = 400, 12, 4
    x = rng.normal(size=(n, d)).astype(np.float32)
    at = rng.uniform(size=(n, a)).astype(np.float32)
    cfg = BuildConfig(m=8, nlist=8, kmeans_iters=3)
    idx = MutableIndex.build(x, at, cfg, delta_cap=32) if mutable else build_index(x, at, cfg)
    pm = CompassParams(k=5, ef=32, backend="ref")
    svc = SearchService(idx, pm, batch_size=4, max_wait_s=0.0)
    return svc, rng, d, a


def test_service_records_batch_metrics():
    obs_reg.set_enabled(True)
    svc, rng, d, a = _service(mutable=False)
    for i in range(6):  # one full batch of 4 + one padded batch of 2
        svc.submit(rng.normal(size=d).astype(np.float32), P.Pred.range(0, 0.0, 0.6))
    svc.run_until_idle()
    r = obs_reg.registry()
    req = r.get("compass_serve_requests_total")
    samples = req.samples()
    assert len(samples) == 1  # one (B, T) bucket for this uniform workload
    bname = samples[0]["labels"]["bucket"]
    assert bname.startswith("B4xT")
    assert req.value(bucket=bname, tenant="") == 6
    assert r.get("compass_serve_batches_total").value(bucket=bname, tenant="") == 2
    assert r.get("compass_serve_fillers_total").value(bucket=bname, tenant="") == 2
    # queries recorded == real lanes, not padded lanes
    assert r.get("compass_queries_total").value(bucket=bname, shard="", tenant="") == 6
    _, _, n_exec = r.get("compass_serve_exec_seconds").series(bucket=bname, tenant="")
    assert n_exec == 2
    assert svc.stats()["obs_enabled"] is True
    assert svc.stats()["obs_events"].get("compile", 0) >= 1
    assert obs_reg.validate_export(r.to_json()) == []


def test_service_write_error_routing():
    obs_reg.set_enabled(True)
    svc, rng, d, a = _service(mutable=True)
    gid = 7
    svc.submit_delete(gid)
    svc.submit_delete(gid)  # raced duplicate: becomes a counted no-op
    svc.step()
    assert svc.n_write_errors == 1
    assert svc.stats()["n_write_errors"] == 1
    assert obs_reg.registry().get("compass_write_errors_total").value(tenant="") == 1
    assert obs_ev.EVENTS.counts().get("write_error") == 1
    ev = obs_ev.EVENTS.tail(1, kind="write_error")[0]
    assert ev["gid"] == gid


def test_service_compile_events_and_counter():
    obs_reg.set_enabled(True)
    svc, rng, d, a = _service(mutable=False)
    svc.submit(rng.normal(size=d).astype(np.float32), P.Pred.range(0, 0.0, 0.6))
    svc.flush()
    assert obs_reg.registry().get("compass_compiles_total").value(cache="aot") == 1
    ev = obs_ev.EVENTS.tail(1, kind="compile")[0]
    assert ev["cache"] == "aot" and ev["wall_s"] > 0
    # second identical-shape request: cache hit, no new compile event
    svc.submit(rng.normal(size=d).astype(np.float32), P.Pred.range(0, 0.0, 0.6))
    svc.flush()
    assert obs_reg.registry().get("compass_compiles_total").value(cache="aot") == 1


# -- kernel wrappers: trace scopes, fallback + autotune counters --------------


def test_kernel_fallback_and_trace_counters():
    """The wrapper counters record at call time (trace time under jit) and
    stay on even with the registry disabled — they are compile-rate-bounded."""
    from repro.kernels import ops

    rng = np.random.default_rng(13)
    queries = jnp.asarray(rng.normal(size=(2, 8)).astype(np.float32))
    cents = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    ref_out = ops.ivf_score(queries, cents, use_pallas=False)
    r = obs_reg.registry()
    assert (
        r.get("compass_kernel_fallback_total").value(
            kernel="ivf_score", reason="use_pallas=False"
        )
        == 1
    )
    pallas_out = ops.ivf_score(queries, cents, use_pallas=True)
    assert r.get("compass_kernel_traces_total").value(kernel="ivf_score") >= 1
    np.testing.assert_allclose(
        np.asarray(ref_out), np.asarray(pallas_out), rtol=1e-5, atol=1e-5
    )


def test_metric_fallback_counter_from_backend():
    from repro.core.engine.backend import PallasBackend

    class FakeIndex:
        pass

    idx = FakeIndex()
    idx.centroids = jnp.zeros((4, 8), jnp.float32)
    PallasBackend().centroid_scores(idx, jnp.zeros((2, 8), jnp.float32), "hamming")
    c = obs_reg.registry().get("compass_kernel_fallback_total")
    assert c.value(kernel="ivf_score", reason="metric:hamming") == 1


def test_autotune_decision_counters():
    from repro.kernels import autotune

    autotune.clear()
    cands = [{"rb": 2}, {"rb": 4}]
    autotune.choose("visit_step", (1, 2, 3), cands)  # no measure_fn -> default
    autotune.choose("visit_step", (1, 2, 3), cands)  # cached -> table
    c = obs_reg.registry().get("compass_autotune_total")
    assert c.value(kernel="visit_step", source="default") >= 1
    assert c.value(kernel="visit_step", source="table") >= 1
    autotune.clear()


def test_events_inactive_without_enable_or_sink():
    assert not obs_ev.EVENTS.active()
    assert obs_ev.emit("compaction", epoch=1) is None
    assert obs_ev.EVENTS.counts() == {}
