"""Shared fixtures: a small clustered corpus + built index, reused across
test modules (session scope) to keep CPU build time bounded.

NOTE: no XLA_FLAGS here on purpose — tests must see the single real CPU
device; only launch/dryrun.py fakes 512 devices.
"""
from __future__ import annotations

import numpy as np
import pytest


@pytest.fixture(scope="session")
def corpus():
    rng = np.random.default_rng(0)
    n, d, a = 6000, 24, 4
    centers = rng.normal(size=(40, d)).astype(np.float32) * 3
    x = (centers[rng.integers(0, 40, n)] + rng.normal(size=(n, d))).astype(np.float32)
    attrs = rng.uniform(size=(n, a)).astype(np.float32)
    queries = (centers[rng.integers(0, 40, 16)] + rng.normal(size=(16, d))).astype(np.float32)
    return x, attrs, queries


@pytest.fixture(scope="session")
def built_index(corpus):
    from repro.core.index import BuildConfig, build_index

    x, attrs, _ = corpus
    return build_index(x, attrs, BuildConfig(m=12, nlist=32))
