"""Shape-stable serving under churn: ShapePolicy, bucketed compaction
folds, epoch-crossing executable-cache reuse, and the repro.compass
public surface (DESIGN.md §Mutability, bucket-fold contract)."""
from __future__ import annotations

import dataclasses
import importlib
import sys
import warnings

import numpy as np
import pytest

from repro.compass import (
    CompassParams,
    MutableIndex,
    SearchService,
    ShapePolicy,
    compass_search,
)
from repro.core import predicate as P
from repro.core.index import BuildConfig, build_index
from repro.core.mutable import mutable_search
from repro.core.mutable.compact import pad_index_rows
from repro.core.planner.stats import build_attr_stats

A = 4
CFG = BuildConfig(m=8, nlist=16, kmeans_iters=4)


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(3)
    n, d = 700, 16
    centers = rng.normal(size=(12, d)).astype(np.float32) * 3
    x = (centers[rng.integers(0, 12, n)] + rng.normal(size=(n, d))).astype(np.float32)
    attrs = rng.uniform(size=(n, A)).astype(np.float32)
    queries = (centers[rng.integers(0, 12, 8)] + rng.normal(size=(8, d))).astype(
        np.float32
    )
    return x, attrs, queries


@pytest.fixture(scope="module")
def base(corpus):
    x, attrs, _ = corpus
    return build_index(x, attrs, CFG)


def stacked(tree, b):
    return P.stack_predicates([tree.tensor(A)] * b)


def churn(indices, rng, n_rounds, writes_per_round, d, next_gid, live):
    """Apply an identical mixed write history to every index in ``indices``."""
    for _ in range(n_rounds):
        for _ in range(writes_per_round):
            u = rng.random()
            if u < 0.6 or not live:
                gid, next_gid = next_gid, next_gid + 1
                live.append(gid)
                v = rng.normal(size=d).astype(np.float32)
                a = rng.uniform(size=A).astype(np.float32)
                for mi in indices:
                    mi.upsert(gid, v, a)
            elif u < 0.8:
                gid = live[rng.integers(len(live))]
                v = rng.normal(size=d).astype(np.float32)
                a = rng.uniform(size=A).astype(np.float32)
                for mi in indices:
                    mi.upsert(gid, v, a)
            else:
                gid = live.pop(int(rng.integers(len(live))))
                for mi in indices:
                    mi.delete(gid)
    return next_gid


# ---------------------------------------------------------------------------
# ShapePolicy mechanics
# ---------------------------------------------------------------------------


def test_row_bucket_power_of_two_with_floor():
    sp = ShapePolicy(min_rows=1024)
    assert sp.row_bucket(1) == 1024
    assert sp.row_bucket(1024) == 1024
    assert sp.row_bucket(1025) == 2048
    assert sp.row_bucket(5000) == 8192
    assert ShapePolicy(bucket_rows=False).row_bucket(5000) == 5000


def test_ef_step_rounds_and_collapses_equality():
    sp = ShapePolicy(ef_step=16)
    assert sp.bucket_ef(64) == 64 and sp.bucket_ef(65) == 80
    a = CompassParams(ef=50, shape=sp)
    b = CompassParams(ef=64, shape=sp)
    assert a.ef == 64 and a == b and hash(a) == hash(b)


def test_shape_overrides_adopt_then_normalize():
    pm = CompassParams(shape=ShapePolicy(ef=128))
    assert pm.ef == 128 and pm.shape.ef == 0  # adopted, then normalized
    # normalization keeps __post_init__ idempotent under replace (the
    # quant-widening path re-runs it with a widened ef)
    pm2 = dataclasses.replace(pm, ef=pm.ef * 3)
    assert pm2.ef == 384


def test_delta_cap_resolution():
    assert ShapePolicy(delta_cap=96).resolve_delta_cap(256) == 96
    assert ShapePolicy().resolve_delta_cap(256) == 256


# ---------------------------------------------------------------------------
# pad_index_rows: padding is structurally inert
# ---------------------------------------------------------------------------


def test_pad_index_rows_invariants(base):
    n = base.n_records
    padded = pad_index_rows(base, 1024)
    assert padded.n_records == 1024
    npad = 1024 - n
    # planner stats untouched: histogram mass and the selectivity
    # denominator count live rows only
    assert float(np.asarray(padded.astats.cluster_counts).sum()) == n
    # padding rows: +inf attrs (fail every term), sentinel-only edges,
    # no in-edges from real rows
    attrs = np.asarray(padded.attrs)
    assert np.all(np.isinf(attrs[n:]))
    nb = np.asarray(padded.graph.neighbors)
    assert nb.shape[0] == 1024
    assert np.all(nb[n:] == 1024)  # out-edges: sentinel only
    assert not np.any((nb[:n] >= n) & (nb[:n] < 1024))  # no in-edges
    # clustered runs: padding appended to the last cluster with +inf keys
    offs = np.asarray(padded.cattrs.offsets)
    assert offs[-1] - np.asarray(base.cattrs.offsets)[-1] == npad
    assert np.all(np.isinf(np.asarray(padded.cattrs.sorted_vals)[:, -npad:]))
    assert np.all(np.asarray(padded.cattrs.assignments)[n:] == base.nlist - 1)
    # idempotent / validated
    assert pad_index_rows(padded, 1024) is padded
    with pytest.raises(ValueError):
        pad_index_rows(padded, 512)


def test_build_attr_stats_live_mask():
    rng = np.random.default_rng(0)
    attrs = rng.uniform(size=(100, 2)).astype(np.float32)
    assign = rng.integers(0, 4, size=100)
    live = np.zeros(100, bool)
    live[:60] = True
    st = build_attr_stats(attrs, assign, 4, live=live)
    assert float(np.asarray(st.cluster_counts).sum()) == 60.0
    ref = build_attr_stats(attrs[:60], assign[:60], 4)
    assert np.array_equal(np.asarray(st.edges), np.asarray(ref.edges))


# ---------------------------------------------------------------------------
# bitwise parity: padding rows never surface
# ---------------------------------------------------------------------------


def test_bucketed_bitwise_parity_across_epochs(base, corpus):
    x, attrs, queries = corpus
    d = x.shape[1]
    cap = 48
    mi = MutableIndex(base, cfg=CFG, shape=ShapePolicy(min_rows=1024, delta_cap=cap))
    ref = MutableIndex(
        build_index(x, attrs, CFG),
        cfg=CFG,
        delta_cap=cap,
        shape=ShapePolicy(bucket_rows=False),
    )
    assert mi.base.n_records == 1024 and ref.base.n_records == x.shape[0]
    assert mi.n_live == ref.n_live == x.shape[0]
    assert len(mi.gids) == x.shape[0]  # padding rows carry no gid

    pm = CompassParams(k=10, ef=48, planner=True, backend="ref")
    pred = stacked(P.Pred.range(0, 0.2, 0.8), 8)
    rng = np.random.default_rng(7)
    live = list(range(x.shape[0]))
    next_gid = x.shape[0]
    # epoch 0 parity (the wrapped base is padded too), then across >= 3
    # compaction epochs under identical write histories
    for _ in range(4):
        r_b = mi.search(queries, pred, pm)
        r_u = ref.search(queries, pred, pm)
        assert np.array_equal(np.asarray(r_b.ids), np.asarray(r_u.ids))
        assert np.array_equal(np.asarray(r_b.dists), np.asarray(r_u.dists))
        # planner mode choice unchanged by padding (live-row histograms)
        assert np.array_equal(
            np.asarray(r_b.stats.mode), np.asarray(r_u.stats.mode)
        )
        next_gid = churn([mi, ref], rng, 2, cap // 2, d, next_gid, live)
    assert mi.epoch >= 3 and mi.epoch == ref.epoch
    assert mi.n_live == ref.n_live
    # row count stayed in the bucket the whole run
    assert mi.base.n_records == 1024


def test_epoch_crossing_zero_recompiles(base, corpus):
    x, attrs, queries = corpus
    d = x.shape[1]
    cap = 40
    mi = MutableIndex(base, cfg=CFG, shape=ShapePolicy(min_rows=1024, delta_cap=cap))
    pm = CompassParams(k=10, ef=32, backend="ref")
    pred = stacked(P.Pred.range(1, 0.1, 0.9), 8)
    mi.search(queries, pred, pm).ids.block_until_ready()  # warmup compile
    rng = np.random.default_rng(5)
    live = list(range(x.shape[0]))
    next_gid = x.shape[0]
    c0 = mutable_search._cache_size()
    while mi.epoch < 3:
        next_gid = churn([mi], rng, 1, cap // 2, d, next_gid, live)
        mi.search(queries, pred, pm).ids.block_until_ready()
    assert mi.epoch >= 3
    assert mutable_search._cache_size() - c0 == 0


# ---------------------------------------------------------------------------
# serving: executable-cache keys stable across compactions
# ---------------------------------------------------------------------------


def test_service_cache_hits_across_compactions(base, corpus):
    x, attrs, queries = corpus
    cap = 40
    pol = ShapePolicy(min_rows=1024, delta_cap=cap)
    mi = MutableIndex(base, cfg=CFG, shape=pol)
    svc = SearchService(
        mi,
        CompassParams(k=10, ef=32, backend="ref", shape=pol),
        batch_size=4,
        max_wait_s=0.0,
    )
    rng = np.random.default_rng(9)
    pred = P.Pred.range(0, 0.1, 0.9)
    d = x.shape[1]
    live = list(range(x.shape[0]))
    next_gid = x.shape[0]
    epochs_seen = set()
    for _ in range(6):
        for q in queries[:4]:
            svc.submit(q, pred)
        results = svc.run_until_idle()
        epochs_seen.update(r.epoch for r in results)
        next_gid = churn([mi], rng, 1, cap, d, next_gid, live)
    for q in queries[:4]:
        svc.submit(q, pred)
    epochs_seen.update(r.epoch for r in svc.run_until_idle())
    st = svc.stats()
    assert mi.epoch >= 3 and len(epochs_seen) >= 3
    # ONE mutable snapshot shape across every served epoch: compiles ==
    # occupied buckets, zero recompiles across the compaction swaps
    assert st["compiles"] == st["occupied_buckets"] == 1
    assert st["shape_policy"]["bucket_rows"] is True


def test_service_rejects_mismatched_policy(base):
    mi = MutableIndex(base, cfg=CFG, shape=ShapePolicy(min_rows=1024))
    with pytest.raises(ValueError, match="ShapePolicy"):
        SearchService(
            mi, CompassParams(shape=ShapePolicy(bucket_rows=False)), batch_size=4
        )
    # construction-time ef override is normalized out of the comparison
    SearchService(
        mi, CompassParams(shape=ShapePolicy(min_rows=1024, ef=48)), batch_size=4
    )


# ---------------------------------------------------------------------------
# public surface
# ---------------------------------------------------------------------------


def test_compass_surface_exports_everything():
    import repro.compass as compass

    for name in compass.__all__:
        assert getattr(compass, name, None) is not None, name
    assert compass.build is compass.build_index
    assert compass.search is compass.compass_search


def test_legacy_shim_warns_deprecation():
    sys.modules.pop("repro.core.search", None)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        shim = importlib.import_module("repro.core.search")
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert shim.compass_search is compass_search
    assert shim.CompassParams is CompassParams
