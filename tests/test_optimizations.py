"""Beyond-paper optimizations keep correctness: beam expansion matches
beam=1 quality; EP MoE matches the pjit MoE numerically (subprocess with
8 virtual devices)."""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import predicate as P
from repro.core.baselines import brute_force, recall
from repro.compass import CompassParams, compass_search


def test_beam_expansion_preserves_recall(built_index, corpus):
    x, attrs, queries = corpus
    rng = np.random.default_rng(11)
    preds = []
    for _ in range(16):
        lo = rng.uniform(0, 0.7)
        preds.append(P.Pred.range(0, lo, lo + 0.3).tensor(4))
    pred = P.stack_predicates(preds)
    qj = jnp.asarray(queries)
    truth = brute_force(jnp.asarray(x), jnp.asarray(attrs), qj, pred, 10)
    n = x.shape[0]
    res1 = compass_search(built_index, qj, pred, CompassParams(k=10, ef=96, beam=1))
    res4 = compass_search(built_index, qj, pred, CompassParams(k=10, ef=96, beam=4))
    r1 = recall(np.asarray(res1.ids), np.asarray(truth.ids), np.asarray(truth.dists), n)
    r4 = recall(np.asarray(res4.ids), np.asarray(truth.ids), np.asarray(truth.dists), n)
    # beam trades a little fixed-ef quality for iteration count (see
    # EXPERIMENTS.md §P4); must stay within a few points and recoverable
    assert r4 >= r1 - 0.08
    assert float(np.asarray(res4.stats.n_steps).mean()) < float(
        np.asarray(res1.stats.n_steps).mean()
    )


EP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as PS, NamedSharding
    from repro.configs import get_config, reduced
    from repro.models.moe import EPContext, init_moe, moe_block
    cfg = reduced(get_config("granite-moe-1b-a400m"))
    # drop-free capacity so pjit and EP paths agree exactly
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    key = jax.random.PRNGKey(0)
    params = init_moe(key, cfg)
    b, s = 4, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model), jnp.float32) * 0.3
    ref = moe_block(params, x, cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    ep = EPContext(batch_axes=("data",))
    with jax.set_mesh(mesh):
        xs = jax.device_put(x, NamedSharding(mesh, PS("data", "model", None)))
        got = jax.jit(lambda p, xx: moe_block(p, xx, cfg, ep))(params, xs)
    d = np.abs(np.asarray(ref, np.float32) - np.asarray(got, np.float32)).max()
    print("EP_DIFF", d)
    assert d < 2e-2, d
    print("EP_OK")
    """
)


@pytest.mark.slow
@pytest.mark.xfail(strict=False, reason="pre-existing at seed: EP script uses jax APIs absent in pinned jax 0.4.37")
def test_ep_moe_matches_pjit_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", EP_SCRIPT],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "EP_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
