"""End-to-end metric coverage: "ip" runs the full kernel path (engine/5),
"cos" is rewritten to ip over normalized rows at the build/search entries.

Parity discipline: ref-vs-pallas comparisons are *bitwise* (ids, dists,
n_dist) because both backends evaluate the shared per-row expression
(kernels.ref.row_distance) inside the same compiled program.  cos-vs-ip
comparisons cross two compile contexts (the cos run normalizes queries
inside its own jit), so ids are asserted equal but dists only to ~1 ULP.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import predicate as P
from repro.core.baselines import brute_force, recall
from repro.core.distances import normalize_rows
from repro.core.index import BuildConfig, build_index
from repro.core.planner import plan as QP
from repro.compass import CompassParams, compass_search


@pytest.fixture(scope="module")
def mcorpus():
    rng = np.random.default_rng(42)
    n, d, a = 2500, 16, 4
    centers = rng.normal(size=(24, d)).astype(np.float32) * 3
    x = (centers[rng.integers(0, 24, n)] + rng.normal(size=(n, d))).astype(np.float32)
    attrs = rng.uniform(size=(n, a)).astype(np.float32)
    queries = (centers[rng.integers(0, 24, 12)] + rng.normal(size=(12, d))).astype(
        np.float32
    )
    return x, attrs, queries


@pytest.fixture(scope="module")
def ip_index(mcorpus):
    x, attrs, _ = mcorpus
    return build_index(x, attrs, BuildConfig(m=10, nlist=16, metric="ip"))


@pytest.fixture(scope="module")
def cos_index(mcorpus):
    x, attrs, _ = mcorpus
    return build_index(x, attrs, BuildConfig(m=10, nlist=16, metric="cos"))


def _preds(rng, n_queries, n_attrs, passrate, n_terms, disj=False):
    preds = []
    for _ in range(n_queries):
        terms = []
        for a in range(n_terms):
            lo = rng.uniform(0, 1 - passrate)
            terms.append(P.Pred.range(a, lo, lo + passrate))
        tree = P.Pred.or_(*terms) if disj else P.Pred.and_(*terms)
        preds.append(tree.tensor(n_attrs))
    return P.stack_predicates(preds)


def _assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.dists), np.asarray(b.dists))
    np.testing.assert_array_equal(
        np.asarray(a.stats.n_dist), np.asarray(b.stats.n_dist)
    )


_CASES = {
    "conjunction": dict(passrate=0.3, n_terms=2, disj=False),
    "disjunction": dict(passrate=0.3, n_terms=3, disj=True),
    "high_selectivity": dict(passrate=0.05, n_terms=2, disj=False),
}


@pytest.mark.parametrize("case", sorted(_CASES))
def test_ip_backend_parity(ip_index, mcorpus, case):
    x, attrs, queries = mcorpus
    rng = np.random.default_rng(50)
    pred = _preds(rng, 12, 4, **_CASES[case])
    qj = jnp.asarray(queries)
    r = compass_search(ip_index, qj, pred, CompassParams(k=10, ef=64, metric="ip", backend="ref"))
    p = compass_search(ip_index, qj, pred, CompassParams(k=10, ef=64, metric="ip", backend="pallas"))
    _assert_bitwise(r, p)


def test_ip_fused_equals_unfused(ip_index, mcorpus):
    """CompassParams.fused_visit is a pure execution-strategy knob: the
    fused visit_step kernel and the unfused filter_distance route must be
    bitwise interchangeable on the pallas backend (and fused is a no-op
    relabel on ref)."""
    x, attrs, queries = mcorpus
    rng = np.random.default_rng(51)
    pred = _preds(rng, 12, 4, 0.3, 2)
    qj = jnp.asarray(queries)
    for metric in ("l2", "ip"):
        idx = ip_index if metric == "ip" else build_index(
            x, attrs, BuildConfig(m=10, nlist=16)
        )
        fused = compass_search(
            idx, qj, pred, CompassParams(k=10, ef=48, metric=metric, backend="pallas")
        )
        unfused = compass_search(
            idx, qj, pred,
            CompassParams(k=10, ef=48, metric=metric, backend="pallas", fused_visit=False),
        )
        _assert_bitwise(fused, unfused)


@pytest.mark.parametrize(
    "workload,mode",
    [
        ("prefilter", QP.PREFILTER),
        ("cooperative", QP.COOPERATIVE),
        ("postfilter", QP.POSTFILTER),
    ],
)
def test_ip_planner_modes_parity(ip_index, mcorpus, workload, mode):
    """Every planner execution mode runs ip bitwise-identically across
    backends — PREFILTER exercises the batched scan_scores kernel path,
    POSTFILTER the graph-only loop, COOPERATIVE the paper loop."""
    x, attrs, queries = mcorpus
    rng = np.random.default_rng(52)
    passrate = {"prefilter": 0.01, "cooperative": 0.3, "postfilter": 1.0}[workload]
    n_terms = 2 if workload == "cooperative" else 1
    pred = _preds(rng, 12, 4, passrate, n_terms)
    qj = jnp.asarray(queries)
    pm = CompassParams(k=10, ef=64, metric="ip", planner=True, backend="ref")
    r = compass_search(ip_index, qj, pred, pm)
    p = compass_search(ip_index, qj, pred, dataclasses.replace(pm, backend="pallas"))
    assert np.all(np.asarray(r.stats.mode) == mode), np.asarray(r.stats.mode)
    np.testing.assert_array_equal(np.asarray(r.stats.mode), np.asarray(p.stats.mode))
    _assert_bitwise(r, p)


def test_ip_recall_against_brute_force(ip_index, mcorpus):
    x, attrs, queries = mcorpus
    rng = np.random.default_rng(53)
    pred = _preds(rng, 12, 4, 0.4, 2)
    qj = jnp.asarray(queries)
    truth = brute_force(
        jnp.asarray(x), jnp.asarray(attrs), qj, pred, 10, metric="ip"
    )
    res = compass_search(
        ip_index, qj, pred, CompassParams(k=10, ef=128, metric="ip", backend="pallas")
    )
    r = recall(np.asarray(res.ids), np.asarray(truth.ids), np.asarray(truth.dists), x.shape[0])
    assert r >= 0.85, r
    # returned dists really are negated inner products of the returned rows
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    valid = ids[0] < x.shape[0]
    want = -(x[ids[0][valid]] @ queries[0])
    np.testing.assert_allclose(dists[0][valid], want, rtol=1e-5)


def test_cos_backend_parity_and_ip_equivalence(cos_index, mcorpus):
    """cos ref-vs-pallas is bitwise (one rewrite, then the ip path); cos
    must equal ip-over-pre-normalized-data up to query-normalization ULPs
    (ids exactly — dists cross compile contexts, so ~1 ULP)."""
    x, attrs, queries = mcorpus
    rng = np.random.default_rng(54)
    pred = _preds(rng, 12, 4, 0.3, 2)
    qj = jnp.asarray(queries)
    r = compass_search(cos_index, qj, pred, CompassParams(k=10, ef=64, metric="cos", backend="ref"))
    p = compass_search(cos_index, qj, pred, CompassParams(k=10, ef=64, metric="cos", backend="pallas"))
    _assert_bitwise(r, p)

    xn = np.asarray(normalize_rows(x))
    ip_idx = build_index(xn, attrs, BuildConfig(m=10, nlist=16, metric="ip"))
    qn = normalize_rows(qj)
    ri = compass_search(ip_idx, qn, pred, CompassParams(k=10, ef=64, metric="ip", backend="ref"))
    np.testing.assert_array_equal(np.asarray(r.ids), np.asarray(ri.ids))
    np.testing.assert_allclose(np.asarray(r.dists), np.asarray(ri.dists), atol=1e-6)
    # cosine distances live in [-1, 1] (negated similarity of unit rows)
    finite = np.isfinite(np.asarray(r.dists))
    assert np.all(np.abs(np.asarray(r.dists)[finite]) <= 1.0 + 1e-5)


def test_quant_adc_under_ip(ip_index, mcorpus):
    """The quantized tier under ip: raw (uncentered) codebooks, negated-IP
    ADC tables — ref and pallas bitwise, and the rerank contract holds."""
    from repro.core.quant import QuantConfig, QuantParams, quantize_index

    x, attrs, queries = mcorpus
    rng = np.random.default_rng(55)
    pred = _preds(rng, 12, 4, 0.4, 2)
    qj = jnp.asarray(queries)
    qidx = quantize_index(ip_index, QuantConfig(m=8, ks=16), metric="ip")
    assert np.all(np.asarray(qidx.qvecs.mean) == 0.0)  # raw encoding for ip
    pm = CompassParams(k=10, ef=64, metric="ip", quant=QuantParams(refine_factor=4))
    r = compass_search(qidx, qj, pred, dataclasses.replace(pm, backend="ref"))
    p = compass_search(qidx, qj, pred, dataclasses.replace(pm, backend="pallas"))
    np.testing.assert_array_equal(np.asarray(r.ids), np.asarray(p.ids))
    np.testing.assert_array_equal(np.asarray(r.dists), np.asarray(p.dists))
    assert np.all(np.asarray(p.stats.n_adc) > 0)
    assert np.all(np.asarray(p.stats.n_rerank) > 0)
    # rerank="full" means returned dists are exact ip of the returned rows
    ids = np.asarray(p.ids)
    valid = ids[0] < x.shape[0]
    want = -(x[ids[0][valid]] @ queries[0])
    np.testing.assert_allclose(np.asarray(p.dists)[0][valid], want, rtol=1e-5)


def test_mutable_ip_delta_parity(ip_index, mcorpus):
    from repro.core.mutable import MutableIndex

    x, attrs, queries = mcorpus
    rng = np.random.default_rng(56)
    pred = _preds(rng, 12, 4, 0.4, 2)
    qj = jnp.asarray(queries)
    mi = MutableIndex(ip_index, metric="ip", delta_cap=64)
    for i in range(24):
        mi.upsert(
            50_000 + i,
            rng.normal(size=x.shape[1]).astype(np.float32),
            rng.uniform(size=attrs.shape[1]).astype(np.float32),
        )
    mi.delete(int(np.asarray(compass_search(
        ip_index, qj[:1], P.Predicate(pred.lo[:1], pred.hi[:1]),
        CompassParams(k=1, ef=16, metric="ip"),
    ).ids)[0, 0]))  # tombstone a known-good result: the live mask must hide it
    r = mi.search(qj, pred, CompassParams(k=10, ef=64, metric="ip", backend="ref"))
    p = mi.search(qj, pred, CompassParams(k=10, ef=64, metric="ip", backend="pallas"))
    np.testing.assert_array_equal(np.asarray(r.ids), np.asarray(p.ids))
    np.testing.assert_array_equal(np.asarray(r.dists), np.asarray(p.dists))


def test_mutable_rejects_cos(ip_index):
    from repro.core.mutable import MutableIndex

    with pytest.raises(ValueError, match="cos"):
        MutableIndex(ip_index, metric="cos")
