import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import predicate as P


def test_simple_range():
    p = P.Pred.range(0, 0.2, 0.5).tensor(n_attrs=2)
    attrs = jnp.asarray([[0.3, 9.0], [0.1, 0.0], [0.5, -1.0], [0.51, 0.0]])
    out = np.asarray(P.evaluate(p, attrs))
    assert out.tolist() == [True, False, True, False]


def test_conjunction_and_disjunction():
    conj = P.Pred.and_(P.Pred.range(0, 0.0, 0.5), P.Pred.ge(1, 0.5)).tensor(2)
    disj = P.Pred.or_(P.Pred.range(0, 0.0, 0.5), P.Pred.ge(1, 0.5)).tensor(2)
    attrs = jnp.asarray([[0.2, 0.9], [0.2, 0.1], [0.9, 0.9], [0.9, 0.1]])
    assert np.asarray(P.evaluate(conj, attrs)).tolist() == [True, False, False, False]
    assert np.asarray(P.evaluate(disj, attrs)).tolist() == [True, True, True, False]


def test_nested_tree_dnf_equals_python_eval():
    # ((a0 in [.1,.4] AND a1 >= .5) OR a2 <= .2) AND a3 in [.3,.9]
    tree = P.Pred.and_(
        P.Pred.or_(
            P.Pred.and_(P.Pred.range(0, 0.1, 0.4), P.Pred.ge(1, 0.5)),
            P.Pred.le(2, 0.2),
        ),
        P.Pred.range(3, 0.3, 0.9),
    )
    pred = tree.tensor(4)
    rng = np.random.default_rng(0)
    attrs = rng.uniform(size=(500, 4)).astype(np.float32)
    got = np.asarray(P.evaluate(pred, jnp.asarray(attrs)))
    want = (
        ((attrs[:, 0] >= 0.1) & (attrs[:, 0] <= 0.4) & (attrs[:, 1] >= 0.5))
        | (attrs[:, 2] <= 0.2)
    ) & ((attrs[:, 3] >= 0.3) & (attrs[:, 3] <= 0.9))
    np.testing.assert_array_equal(got, want)


def test_equality_predicate():
    p = P.Pred.eq(1, 3.0).tensor(2)
    attrs = jnp.asarray([[0.0, 3.0], [0.0, 2.999]])
    assert np.asarray(P.evaluate(p, attrs)).tolist() == [True, False]


def test_stack_predicates_pads_unsatisfiable():
    p1 = P.Pred.range(0, 0.0, 1.0).tensor(2)  # T=1
    p2 = P.Pred.or_(P.Pred.le(0, 0.1), P.Pred.ge(1, 0.9)).tensor(2)  # T=2
    batched = P.stack_predicates([p1, p2])
    assert batched.lo.shape == (2, 2, 2)
    attrs = jnp.asarray([[0.5, 0.5]])
    # query 0: in range -> True; pad term must not fire
    out0 = P.evaluate(P.Predicate(batched.lo[0], batched.hi[0]), attrs)
    assert bool(out0[0])
    out1 = P.evaluate(P.Predicate(batched.lo[1], batched.hi[1]), attrs)
    assert not bool(out1[0])


def test_empty_dnf_is_unsatisfiable():
    # contradictory conjunction: every DNF term drops -> empty -> tensor()
    # must lower to an unsatisfiable predicate, not an empty array
    tree = P.Pred.and_(P.Pred.le(0, 0.2), P.Pred.ge(0, 0.8))
    assert tree.to_dnf() == []
    pred = tree.tensor(2)
    assert pred.lo.shape == (1, 2)
    attrs = jnp.asarray([[0.0, 0.0], [0.5, 0.5], [1.0, 1.0]])
    assert not np.asarray(P.evaluate(pred, attrs)).any()


def test_never_true_rejects_everything():
    pred = P.never_true(3, n_terms=2)
    attrs = jnp.asarray([[0.0, 0.5, 1.0], [P.NEG_INF, 0.0, P.POS_INF]])
    assert not np.asarray(P.evaluate(pred, attrs)).any()


def test_term_bucket_powers_of_two():
    assert [P.term_bucket(t) for t in (1, 2, 3, 4, 5, 8, 9)] == [1, 2, 4, 4, 8, 8, 16]
    with pytest.raises(ValueError):
        P.term_bucket(0)


def test_pad_terms_preserves_evaluation():
    tree = P.Pred.or_(P.Pred.le(0, 0.3), P.Pred.ge(1, 0.7))  # T=2
    base = tree.tensor(2)
    rng = np.random.default_rng(3)
    attrs = jnp.asarray(rng.uniform(size=(64, 2)).astype(np.float32))
    want = np.asarray(P.evaluate(base, attrs))
    for T in (2, 4, 8):
        padded = P.pad_terms(base, T)
        assert padded.lo.shape == (T, 2)
        np.testing.assert_array_equal(np.asarray(P.evaluate(padded, attrs)), want)
    with pytest.raises(ValueError, match="terms"):
        P.pad_terms(base, 1)


def test_stack_predicates_to_requested_bucket():
    p1 = P.Pred.range(0, 0.0, 1.0).tensor(2)  # T=1
    p2 = P.Pred.or_(P.Pred.le(0, 0.1), P.Pred.ge(1, 0.9)).tensor(2)  # T=2
    batched = P.stack_predicates([p1, p2], n_terms=4)
    assert batched.lo.shape == (2, 4, 2)
    attrs = jnp.asarray([[0.5, 0.5]])
    assert bool(P.evaluate(P.Predicate(batched.lo[0], batched.hi[0]), attrs)[0])
    assert not bool(P.evaluate(P.Predicate(batched.lo[1], batched.hi[1]), attrs)[0])
    with pytest.raises(ValueError, match="terms"):
        P.stack_predicates([p1, p2], n_terms=1)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0, 1), min_size=4, max_size=4), st.data())
def test_property_dnf_matches_tree_semantics(attr_vals, data):
    """Random small predicate trees: DNF tensor evaluation == direct eval."""

    def gen_tree(depth):
        if depth == 0 or data.draw(st.booleans()):
            a = data.draw(st.integers(0, 3))
            lo = data.draw(st.floats(0, 1))
            hi = data.draw(st.floats(0, 1))
            return P.Pred.range(a, min(lo, hi), max(lo, hi))
        kids = [gen_tree(depth - 1) for _ in range(data.draw(st.integers(2, 3)))]
        return P.Pred.and_(*kids) if data.draw(st.booleans()) else P.Pred.or_(*kids)

    def eval_tree(t, vals):
        if t.kind == "leaf":
            return t.lo <= vals[t.attr] <= t.hi
        if t.kind == "and":
            return all(eval_tree(c, vals) for c in t.children)
        return any(eval_tree(c, vals) for c in t.children)

    tree = gen_tree(2)
    pred = tree.tensor(4)
    got = bool(P.evaluate(pred, jnp.asarray([attr_vals], jnp.float32))[0])
    want = eval_tree(tree, [np.float32(v) for v in attr_vals])
    assert got == want
