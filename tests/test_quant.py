"""Quantized-tier tests (core/quant + kernels/pq_score + engine wiring).

Covers the contracts DESIGN.md §Quantization promises: encode/decode error
bounds, bitwise ADC ref/pallas parity (sentinel-id-under-true-mask
included), rerank exactness at sufficient refine_factor, planner-mode
parity with quantization on, mutable re-encode on compaction, serving
cache-key separation, and the quant=None bitwise-no-op guarantee.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import predicate as P
from repro.core.baselines import brute_force, recall
from repro.core.quant import (
    QuantConfig,
    QuantParams,
    decode_all,
    encode_rows,
    quant_mse,
    quantize_index,
    quantize_vectors,
)
from repro.compass import CompassParams, compass_search
from repro.kernels import ops, ref

K = 10


@pytest.fixture(scope="module")
def quant_index(built_index):
    return quantize_index(built_index, QuantConfig(m=8, iters=6), "l2")


def _pred_batch(tree, a, b):
    return P.stack_predicates([tree.tensor(a)] * b)


WORKLOADS = {
    "conj": P.Pred.and_(P.Pred.range(0, 0.2, 0.7), P.Pred.range(1, 0.1, 0.9)),
    "disj": P.Pred.or_(
        P.Pred.range(0, 0.0, 0.2), P.Pred.range(1, 0.8, 1.0), P.Pred.range(2, 0.4, 0.5)
    ),
    "narrow": P.Pred.and_(P.Pred.range(0, 0.4, 0.5), P.Pred.range(1, 0.3, 0.4)),
}


# ---------------------------------------------------------------------------
# encode / decode
# ---------------------------------------------------------------------------


def test_encode_decode_error_bounds(corpus):
    x, _, _ = corpus
    var = float(np.var(x))
    errs = {}
    for m in (4, 8):
        qv = quantize_vectors(x, QuantConfig(m=m, iters=6))
        assert qv.codes.shape == (x.shape[0] + 1, m) and qv.codes.dtype == jnp.uint8
        dec = np.asarray(decode_all(qv))
        assert dec.shape == x.shape
        mse = float(np.mean((dec - x) ** 2))
        errs[m] = mse
        # quantization error must be well below the data's own variance,
        # and the recorded train_mse must be the real figure
        assert mse < 0.5 * var
        np.testing.assert_allclose(float(qv.train_mse), mse, rtol=1e-5)
        np.testing.assert_allclose(quant_mse(qv, x), mse, rtol=1e-5)
    # more subspaces -> finer quantization
    assert errs[8] < errs[4]


def test_encode_pads_odd_dims():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(500, 17)).astype(np.float32)  # 17 % 4 != 0
    qv = quantize_vectors(x, QuantConfig(m=4, iters=4))
    assert qv.dsub == 5  # ceil(17/4)
    dec = np.asarray(decode_all(qv))
    assert dec.shape == x.shape
    assert float(np.mean((dec - x) ** 2)) < float(np.var(x))


def test_quant_config_validation():
    with pytest.raises(ValueError):
        QuantConfig(ks=512)  # uint8 overflow
    with pytest.raises(ValueError):
        QuantConfig(residual=True).resolve_residual("ip")
    assert QuantConfig().resolve_residual("l2") is True
    assert QuantConfig().resolve_residual("ip") is False
    with pytest.raises(ValueError):
        QuantParams(refine_factor=0)
    with pytest.raises(ValueError):
        QuantParams(rerank="fast")


def test_bytes_per_vector_compression(quant_index):
    d = quant_index.dim
    bpv = quant_index.qvecs.bytes_per_vector
    assert bpv >= quant_index.qvecs.m  # codes alone
    assert 4.0 * d / bpv >= 2.0  # honest (codebook-amortized) compression


# ---------------------------------------------------------------------------
# kernel parity (ref oracle vs pallas interpret) — bitwise
# ---------------------------------------------------------------------------


def _mk_pq(rng, n, m, ks, dsub, a):
    codes = np.concatenate(
        [rng.integers(0, ks, (n, m)), np.zeros((1, m))], 0
    ).astype(np.uint8)
    attrs = np.concatenate(
        [rng.uniform(size=(n, a)), np.full((1, a), np.inf)], 0
    ).astype(np.float32)
    cb = rng.normal(size=(m, ks, dsub)).astype(np.float32)
    return jnp.asarray(codes), jnp.asarray(attrs), jnp.asarray(cb)


@pytest.mark.parametrize("n,m,ks,dsub,a,t,v", [
    (50, 4, 16, 3, 2, 1, 16),
    (200, 8, 256, 4, 4, 4, 33),   # full uint8 range, non-multiple V
    (100, 16, 64, 5, 3, 2, 8),
])
def test_pq_score_matches_ref_bitwise(n, m, ks, dsub, a, t, v):
    rng = np.random.default_rng(0)
    codes, attrs, cb = _mk_pq(rng, n, m, ks, dsub, a)
    idx = jnp.asarray(rng.integers(0, n + 1, v).astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=v) > 0.3)
    q = jnp.asarray(rng.normal(size=m * dsub).astype(np.float32))
    lo = jnp.asarray(rng.uniform(0, 0.5, (t, a)).astype(np.float32))
    hi = jnp.asarray(rng.uniform(0.5, 1.0, (t, a)).astype(np.float32))
    # both sides jitted: parity is bitwise inside a compile context (the
    # eager ref differs by float-contraction choices, not math)
    d_k, p_k = jax.jit(lambda *z: ops.pq_score(*z))(codes, attrs, idx, mask, q, cb, lo, hi)
    d_r, p_r = jax.jit(lambda *z: ref.pq_score_ref(*z))(codes, attrs, idx, mask, q, cb, lo, hi)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


@pytest.mark.parametrize("b,n,m,ks,dsub,a,t,v", [
    (1, 50, 4, 16, 3, 2, 1, 16),
    (4, 200, 8, 256, 4, 4, 4, 33),
    (3, 100, 16, 64, 5, 3, 2, 8),
])
def test_pq_score_batch_matches_ref_bitwise(b, n, m, ks, dsub, a, t, v):
    rng = np.random.default_rng(1)
    codes, attrs, cb = _mk_pq(rng, n, m, ks, dsub, a)
    idx = jnp.asarray(rng.integers(0, n + 1, (b, v)).astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=(b, v)) > 0.3)
    q = jnp.asarray(rng.normal(size=(b, m * dsub)).astype(np.float32))
    lo = jnp.asarray(rng.uniform(0, 0.5, (b, t, a)).astype(np.float32))
    hi = jnp.asarray(rng.uniform(0.5, 1.0, (b, t, a)).astype(np.float32))
    d_k, p_k = jax.jit(lambda *z: ops.pq_score_batch(*z))(
        codes, attrs, idx, mask, q, cb, lo, hi
    )
    d_r, p_r = jax.jit(lambda *z: ref.pq_score_batch_ref(*z))(
        codes, attrs, idx, mask, q, cb, lo, hi
    )
    assert d_k.shape == (b, v) and p_k.shape == (b, v)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


def test_pq_score_sentinel_under_true_mask():
    """A sentinel id is a masked-out visit even when the mask bit is true —
    the same validity rule as filter_distance (dist +inf, passed False)."""
    rng = np.random.default_rng(2)
    n, m, ks, dsub, a = 30, 4, 8, 2, 2
    codes, attrs, cb = _mk_pq(rng, n, m, ks, dsub, a)
    idx = jnp.asarray(np.array([0, n, 5, n], np.int32))  # two sentinels
    mask = jnp.asarray(np.array([True, True, True, True]))
    q = jnp.asarray(rng.normal(size=m * dsub).astype(np.float32))
    lo = jnp.full((1, a), -np.inf, jnp.float32)  # vacuous bounds: all pass
    hi = jnp.full((1, a), np.inf, jnp.float32)
    for use_pallas in (False, True):
        d, p = jax.jit(
            lambda *z: ops.pq_score(*z, use_pallas=use_pallas)
        )(codes, attrs, idx, mask, q, cb, lo, hi)
        d, p = np.asarray(d), np.asarray(p)
        assert np.isinf(d[1]) and np.isinf(d[3])
        assert not p[1] and not p[3]
        assert np.isfinite(d[0]) and np.isfinite(d[2])
        assert p[0] and p[2]


# ---------------------------------------------------------------------------
# two-stage search: rerank exactness + counters
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workload", sorted(WORKLOADS))
def test_rerank_matches_exact_search(corpus, built_index, quant_index, workload):
    """With refine_factor high enough, the quantized top-k recovers the
    exact engine's top-k (the rerank contract)."""
    x, attrs, queries = corpus
    n = x.shape[0]
    pred = _pred_batch(WORKLOADS[workload], attrs.shape[1], len(queries))
    qj = jnp.asarray(queries)
    pm = CompassParams(k=K, ef=64, backend="ref")
    exact = compass_search(built_index, qj, pred, pm)
    quant = compass_search(
        quant_index, qj, pred,
        dataclasses.replace(pm, quant=QuantParams(refine_factor=4)),
    )
    r = recall(
        np.asarray(quant.ids), np.asarray(exact.ids), np.asarray(exact.dists), n
    )
    assert r >= 0.95, f"quantized vs exact recall {r} on {workload}"
    # reranked distances are true full-precision distances
    truth = brute_force(jnp.asarray(x), jnp.asarray(attrs), qj, pred, K)
    ids_q, d_q = np.asarray(quant.ids), np.asarray(quant.dists)
    for lane in range(len(queries)):
        fin = np.isfinite(d_q[lane])
        diff = x[ids_q[lane][fin]] - queries[lane][None, :]
        np.testing.assert_allclose(
            d_q[lane][fin], np.sum(diff * diff, axis=1), rtol=1e-4
        )


def test_refine_factor_monotone_recall(corpus, quant_index):
    """Against brute-force ground truth (not the exact engine's ef-bounded
    run, which a wider stage one can legitimately *beat*, making overlap
    non-monotone), more refine means more recall."""
    x, attrs, queries = corpus
    n = x.shape[0]
    pred = _pred_batch(WORKLOADS["conj"], attrs.shape[1], len(queries))
    qj = jnp.asarray(queries)
    truth = brute_force(jnp.asarray(x), jnp.asarray(attrs), qj, pred, K)
    pm = CompassParams(k=K, ef=32, backend="ref")
    rs = []
    for rf in (1, 4):
        res = compass_search(
            quant_index, qj, pred, dataclasses.replace(pm, quant=QuantParams(refine_factor=rf))
        )
        rs.append(
            recall(np.asarray(res.ids), np.asarray(truth.ids), np.asarray(truth.dists), n)
        )
    assert rs[1] >= rs[0]


def test_quant_counters(corpus, quant_index):
    x, attrs, queries = corpus
    pred = _pred_batch(WORKLOADS["conj"], attrs.shape[1], len(queries))
    qj = jnp.asarray(queries)
    pm = CompassParams(k=K, ef=32, backend="ref")
    res = compass_search(quant_index, qj, pred, pm)  # quant off
    assert np.all(np.asarray(res.stats.n_adc) == 0)
    assert np.all(np.asarray(res.stats.n_rerank) == 0)
    resq = compass_search(
        quant_index, qj, pred, dataclasses.replace(pm, quant=QuantParams(refine_factor=2))
    )
    assert np.all(np.asarray(resq.stats.n_adc) > 0)
    # rerank touched exactly the live stage-one survivors, and those exact
    # reads are counted in the full-precision #Comp figure too
    nr = np.asarray(resq.stats.n_rerank)
    assert np.all(nr > 0) and np.all(nr <= 2 * 32)
    assert np.all(np.asarray(resq.stats.n_dist) >= nr)


def test_rerank_modes_run(corpus, quant_index):
    x, attrs, queries = corpus
    pred = _pred_batch(WORKLOADS["conj"], attrs.shape[1], len(queries))
    qj = jnp.asarray(queries)
    base = CompassParams(k=K, ef=32, backend="ref")
    res_full = compass_search(
        quant_index, qj, pred, dataclasses.replace(base, quant=QuantParams(2, "full"))
    )
    res_dec = compass_search(
        quant_index, qj, pred, dataclasses.replace(base, quant=QuantParams(2, "decode"))
    )
    res_none = compass_search(
        quant_index, qj, pred, dataclasses.replace(base, quant=QuantParams(2, "none"))
    )
    for res in (res_full, res_dec, res_none):
        assert res.ids.shape == (len(queries), K)
    # "none" skips stage two entirely
    assert np.all(np.asarray(res_none.stats.n_rerank) == 0)
    assert np.all(np.asarray(res_dec.stats.n_rerank) > 0)
    # decode-mode distances are ADC-equal (summation order aside), so the
    # top-1 candidate should broadly agree with the full rerank
    agree = np.mean(
        np.asarray(res_dec.ids)[:, 0] == np.asarray(res_full.ids)[:, 0]
    )
    assert agree >= 0.5


# ---------------------------------------------------------------------------
# engine integration: quant=None no-op, backend parity, planner parity
# ---------------------------------------------------------------------------


def test_quant_none_bitwise_unchanged(corpus, built_index, quant_index):
    """Attaching codes to an index must not move a single bit of exact
    search — the qvecs branch is trace-time (pytree-structural)."""
    x, attrs, queries = corpus
    qj = jnp.asarray(queries)
    for workload, tree in sorted(WORKLOADS.items()):
        pred = _pred_batch(tree, attrs.shape[1], len(queries))
        for pm in (
            CompassParams(k=K, ef=48, backend="ref"),
            CompassParams(k=K, ef=48, backend="ref", planner=True),
            CompassParams(k=K, ef=48, backend="pallas"),
        ):
            plain = compass_search(built_index, qj, pred, pm)
            carried = compass_search(quant_index, qj, pred, pm)
            np.testing.assert_array_equal(
                np.asarray(plain.ids), np.asarray(carried.ids), err_msg=workload
            )
            np.testing.assert_array_equal(
                np.asarray(plain.dists), np.asarray(carried.dists), err_msg=workload
            )


def test_quant_backend_parity(corpus, quant_index):
    """ref and pallas backends agree bitwise on the quantized path (the
    pq_score kernel's in-kernel LUT equals the jnp table, and the rerank
    scan is the existing filter_distance parity surface)."""
    x, attrs, queries = corpus
    qj = jnp.asarray(queries)
    for workload, tree in sorted(WORKLOADS.items()):
        pred = _pred_batch(tree, attrs.shape[1], len(queries))
        for planner in (False, True):
            pm = CompassParams(
                k=K, ef=48, planner=planner, quant=QuantParams(refine_factor=2)
            )
            r_ref = compass_search(
                quant_index, qj, pred, dataclasses.replace(pm, backend="ref")
            )
            r_pal = compass_search(
                quant_index, qj, pred, dataclasses.replace(pm, backend="pallas")
            )
            np.testing.assert_array_equal(
                np.asarray(r_ref.ids), np.asarray(r_pal.ids),
                err_msg=f"{workload} planner={planner}",
            )
            np.testing.assert_array_equal(
                np.asarray(r_ref.dists), np.asarray(r_pal.dists),
                err_msg=f"{workload} planner={planner}",
            )


def test_planner_modes_with_quant(corpus, quant_index):
    """The planner keeps planning under quantization: a narrow predicate
    goes PREFILTER and (ADC scan + exact rerank) still recovers the exact
    engine's answer; work lands in n_adc, not n_dist."""
    x, attrs, queries = corpus
    n = x.shape[0]
    qj = jnp.asarray(queries)
    pred = _pred_batch(WORKLOADS["narrow"], attrs.shape[1], len(queries))
    pm = CompassParams(k=K, ef=48, backend="ref", planner=True,
                       quant=QuantParams(refine_factor=4))
    res = compass_search(quant_index, qj, pred, pm)
    from repro.core.planner.plan import PREFILTER

    assert np.all(np.asarray(res.stats.mode) == PREFILTER)
    assert np.all(np.asarray(res.stats.n_adc) > 0)
    truth = brute_force(jnp.asarray(x), jnp.asarray(attrs), qj, pred, K)
    r = recall(np.asarray(res.ids), np.asarray(truth.ids), np.asarray(truth.dists), n)
    assert r == 1.0  # PREFILTER materializes every match; rerank is exact
    # planner-on and planner-off agree on the quantized result set
    res_off = compass_search(
        quant_index, qj, pred, dataclasses.replace(pm, planner=False)
    )
    r_par = recall(
        np.asarray(res.ids), np.asarray(res_off.ids), np.asarray(res_off.dists), n
    )
    assert r_par >= 0.95


def test_quant_requires_quantized_index(built_index, corpus):
    x, attrs, queries = corpus
    pred = _pred_batch(WORKLOADS["conj"], attrs.shape[1], len(queries))
    with pytest.raises(ValueError, match="quantized index"):
        compass_search(
            built_index, jnp.asarray(queries), pred,
            CompassParams(k=K, quant=QuantParams()),
        )


# ---------------------------------------------------------------------------
# mutable: delta encoding, re-encode on compaction, retrain
# ---------------------------------------------------------------------------


@pytest.fixture()
def mutable_quant(corpus):
    from repro.core.index import BuildConfig, build_index
    from repro.core.mutable import MutableIndex

    x, attrs, _ = corpus
    cfg = BuildConfig(m=12, nlist=16)
    base = quantize_index(build_index(x[:3000], attrs[:3000], cfg), QuantConfig(m=8, iters=5))
    return MutableIndex(base, delta_cap=64, cfg=cfg)


def test_mutable_delta_scored_quantized(corpus, mutable_quant):
    x, attrs, queries = corpus
    a = attrs.shape[1]
    pm = CompassParams(k=K, ef=32, backend="ref", quant=QuantParams(refine_factor=4))
    gid = 9_000_000
    mutable_quant.upsert(gid, queries[0], np.float32([0.5] * a))
    snap = mutable_quant.snapshot()
    assert snap.delta.qvecs is not None
    # delta codes are the base codebooks' encoding of the delta rows
    want = np.asarray(
        encode_rows(
            snap.index.qvecs.codebooks, snap.index.qvecs.mean, queries[:1]
        )
    )
    np.testing.assert_array_equal(np.asarray(snap.delta.qvecs.codes)[0], want[0])
    pred = _pred_batch(P.Pred.range(0, 0.0, 1.0), a, 1)
    res = mutable_quant.search(queries[:1], pred, pm)
    assert np.asarray(res.ids)[0][0] == gid  # exact-match vector wins top-1
    assert np.all(np.asarray(res.stats.n_adc) > 0)
    assert np.all(np.asarray(res.stats.n_rerank) > 0)


def test_mutable_reencode_on_compaction(corpus, mutable_quant):
    x, attrs, queries = corpus
    a = attrs.shape[1]
    gid = 9_000_001
    mutable_quant.upsert(gid, queries[1], np.float32([0.5] * a))
    old_cb = np.asarray(mutable_quant.base.qvecs.codebooks)
    mutable_quant.compact()
    qv = mutable_quant.base.qvecs
    assert qv is not None, "quantized tier lost in the fold"
    # frozen codebooks carried over; the folded row's code is a fresh
    # encoding of its vector against them
    np.testing.assert_array_equal(np.asarray(qv.codebooks), old_cb)
    pos = int(np.where(mutable_quant.gids == gid)[0][0])
    want = np.asarray(encode_rows(qv.codebooks, qv.mean, queries[1:2]))[0]
    np.testing.assert_array_equal(np.asarray(qv.codes)[pos], want)
    assert len(mutable_quant.quant_drift_log) == 1
    # search still quantized after the fold
    pm = CompassParams(k=K, ef=32, backend="ref", quant=QuantParams(refine_factor=4))
    pred = _pred_batch(P.Pred.range(0, 0.0, 1.0), a, 1)
    res = mutable_quant.search(queries[1:2], pred, pm)
    assert np.asarray(res.ids)[0][0] == gid


def test_mutable_retrain_on_explicit_compact(corpus, mutable_quant):
    x, attrs, queries = corpus
    a = attrs.shape[1]
    mutable_quant.upsert(9_000_002, queries[2], np.float32([0.5] * a))
    old_cb = np.asarray(mutable_quant.base.qvecs.codebooks)
    mutable_quant.compact(retrain_codebooks=True)
    new_cb = np.asarray(mutable_quant.base.qvecs.codebooks)
    assert new_cb.shape == old_cb.shape
    assert not np.array_equal(new_cb, old_cb)  # actually retrained
    assert len(mutable_quant.quant_drift_log) == 1


def test_distributed_mutable_aggregates_quant_counters(corpus):
    from repro.core.distributed import DistributedMutableIndex
    from repro.core.index import BuildConfig, build_index
    from repro.core.mutable import MutableIndex

    x, attrs, queries = corpus
    a = attrs.shape[1]
    cfg = BuildConfig(m=8, nlist=8)
    shards = []
    for s in range(2):
        sl = slice(s * 1000, (s + 1) * 1000)
        base = quantize_index(build_index(x[sl], attrs[sl], cfg), QuantConfig(m=8, iters=4))
        shards.append(
            MutableIndex(
                base, delta_cap=16, cfg=cfg,
                gids=np.arange(sl.start, sl.stop, dtype=np.int64),
            )
        )
    dmi = DistributedMutableIndex(shards)
    pm = CompassParams(k=K, ef=32, backend="ref", quant=QuantParams(refine_factor=2))
    pred = _pred_batch(WORKLOADS["conj"], a, 4)
    res = dmi.search(jnp.asarray(queries[:4]), pred, pm)
    per_shard = [
        sh.search(jnp.asarray(queries[:4]), pred, pm) for sh in dmi.shards
    ]
    np.testing.assert_array_equal(
        np.asarray(res.stats.n_adc),
        sum(np.asarray(p.stats.n_adc) for p in per_shard),
    )
    np.testing.assert_array_equal(
        np.asarray(res.stats.n_rerank),
        sum(np.asarray(p.stats.n_rerank) for p in per_shard),
    )


# ---------------------------------------------------------------------------
# serving: cache-key separation
# ---------------------------------------------------------------------------


def test_serving_cache_key_separation(corpus, quant_index):
    from repro.serving.search_service import SearchService

    x, attrs, queries = corpus
    a = attrs.shape[1]
    tree = WORKLOADS["conj"]
    pm_exact = CompassParams(k=K, ef=32, backend="ref")
    pm_quant = dataclasses.replace(pm_exact, quant=QuantParams(refine_factor=2))
    # the quant config is part of the frozen CompassParams, so the
    # executable cache key separates quantized from exact automatically
    assert pm_exact != pm_quant and hash(pm_exact) != hash(pm_quant)
    svc_q = SearchService(quant_index, pm_quant, batch_size=2, max_wait_s=0.0)
    svc_e = SearchService(quant_index, pm_exact, batch_size=2, max_wait_s=0.0)
    for svc in (svc_q, svc_e):
        svc.submit(queries[0], tree)
        svc.submit(queries[1], tree)
        out = svc.run_until_idle()
        assert len(out) == 2
    assert svc_q.compile_count == 1 and svc_e.compile_count == 1
    sq, se = svc_q.stats(), svc_e.stats()
    assert sq["quant"] == {"refine_factor": 2, "rerank": "full"}
    assert se["quant"] is None
    assert sq["bytes_per_vector"] < se["bytes_per_vector"]
    # quantized service response equals the direct quantized call
    direct = compass_search(
        quant_index,
        jnp.asarray(queries[:1]),
        _pred_batch(tree, a, 1),
        pm_quant,
    )
    svc_q.submit(queries[0], tree)
    (r,) = svc_q.flush()
    np.testing.assert_array_equal(r.ids, np.asarray(direct.ids)[0])
    np.testing.assert_array_equal(r.dists, np.asarray(direct.dists)[0])


def test_serving_rejects_quant_params_without_codes(built_index):
    from repro.serving.search_service import SearchService

    with pytest.raises(ValueError, match="quantized index"):
        SearchService(built_index, CompassParams(k=K, quant=QuantParams()))
