"""Substrate tests: optimizer, data pipeline, checkpoint/restart/elastic,
gradient compression, watchdog."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint.checkpoint import latest_steps, restore, save
from repro.data.synthetic import DataConfig, SyntheticTokens
from repro.ft.elastic import ElasticPlan, remap_data_shards
from repro.ft.watchdog import StepWatchdog, WatchdogConfig
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state, schedule
from repro.optim.compression import compress_with_feedback, dequantize, init_residual, quantize


def test_adamw_reduces_quadratic_loss():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = init_opt_state(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(100):
        grads = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(loss(params)) < 0.05 * l0


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = apply_updates(params, grads, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # raw norm reported


def test_data_pipeline_deterministic_and_shardable():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8, seed=42)
    ds = SyntheticTokens(cfg)
    b1 = ds.batch(step=3)
    b2 = ds.batch(step=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shards partition the batch deterministically
    s0 = ds.batch(step=3, shard=0, n_shards=2)
    assert s0["tokens"].shape == (4, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_checkpoint_roundtrip_and_retention(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.float32(2.5)}}
    for step in (10, 20, 30, 40):
        save(str(tmp_path), step, tree, keep=2)
    assert latest_steps(str(tmp_path)) == [30, 40]
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = restore(str(tmp_path), like)
    assert step == 40
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_checkpoint_restore_detects_mismatch(tmp_path):
    save(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore(str(tmp_path), {"different": jnp.zeros(3)})


def test_elastic_plan_and_shard_remap():
    plan = ElasticPlan(old_devices=256, new_devices=512, global_batch=512)
    assert plan.validate() == []
    bad = ElasticPlan(old_devices=256, new_devices=384, global_batch=256)
    assert bad.validate()
    rec = remap_data_shards(100, 256, 512)
    assert rec["new_shards"] == 512


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4096), st.integers(0, 3))
def test_property_quantize_dequantize_error_bounded(n, seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=n).astype(np.float32))}
    c = quantize(g, block=128)
    deq = dequantize(c, g, block=128)
    err = np.abs(np.asarray(deq["w"]) - np.asarray(g["w"]))
    # error bounded by half a quantization bucket of the block absmax
    assert err.max() <= (np.abs(np.asarray(g["w"])).max() / 127.0) * 0.75 + 1e-7


def test_error_feedback_conserves_signal():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=512).astype(np.float32))}
    residual = init_residual(g)
    acc = np.zeros(512, np.float32)
    for _ in range(8):
        c, residual = compress_with_feedback(g, residual)
        acc += np.asarray(dequantize(c, g)["w"])
    # over k steps, sum of dequantized ~= k * g (residual carries the error)
    np.testing.assert_allclose(acc / 8, np.asarray(g["w"]), atol=2e-2)


def test_watchdog_flags_stragglers():
    import time

    wd = StepWatchdog(WatchdogConfig(straggler_factor=5.0, warmup_steps=1))
    flagged = []
    for step in range(6):
        wd.start_step()
        time.sleep(0.15 if step == 4 else 0.01)
        flagged.append(wd.end_step(step))
    assert flagged[4] and not any(flagged[:4]) and not flagged[5]
