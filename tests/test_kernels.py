"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracle in ref.py, plus hypothesis property tests."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _mk_corpus(rng, n, d, a):
    vectors = rng.normal(size=(n + 1, d)).astype(np.float32)
    attrs = rng.uniform(size=(n + 1, a)).astype(np.float32)
    attrs[-1] = np.inf  # sentinel row
    return jnp.asarray(vectors), jnp.asarray(attrs)


@pytest.mark.parametrize("n,d,a,t,v", [
    (50, 8, 2, 1, 16),
    (200, 32, 4, 4, 33),   # non-multiple V
    (100, 17, 3, 2, 8),    # odd dim
])
def test_filter_distance_matches_ref(n, d, a, t, v):
    rng = np.random.default_rng(0)
    vectors, attrs = _mk_corpus(rng, n, d, a)
    idx = jnp.asarray(rng.integers(0, n + 1, v).astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=v) > 0.3)
    q = jnp.asarray(rng.normal(size=d).astype(np.float32))
    lo = jnp.asarray(rng.uniform(0, 0.5, (t, a)).astype(np.float32))
    hi = jnp.asarray(rng.uniform(0.5, 1.0, (t, a)).astype(np.float32))
    d_k, p_k = ops.filter_distance(vectors, attrs, idx, mask, q, lo, hi)
    d_r, p_r = ref.filter_distance_ref(vectors, attrs, idx, mask, q, lo, hi)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


@pytest.mark.parametrize("b,n,d,a,t,v", [
    (1, 50, 8, 2, 1, 16),
    (4, 200, 32, 4, 4, 33),   # non-multiple V
    (3, 100, 17, 3, 2, 8),    # odd dim
])
def test_filter_distance_batch_matches_ref(b, n, d, a, t, v):
    """The planner's batched run-scan entry point: per-lane queries and
    bounds, grid (B, V) — against the vmapped single-query oracle."""
    rng = np.random.default_rng(1)
    vectors, attrs = _mk_corpus(rng, n, d, a)
    idx = jnp.asarray(rng.integers(0, n + 1, (b, v)).astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=(b, v)) > 0.3)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    lo = jnp.asarray(rng.uniform(0, 0.5, (b, t, a)).astype(np.float32))
    hi = jnp.asarray(rng.uniform(0.5, 1.0, (b, t, a)).astype(np.float32))
    d_k, p_k = ops.filter_distance_batch(vectors, attrs, idx, mask, q, lo, hi)
    d_r, p_r = ref.filter_distance_batch_ref(vectors, attrs, idx, mask, q, lo, hi)
    assert d_k.shape == (b, v) and p_k.shape == (b, v)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


@pytest.mark.parametrize("b,c,d,dtype", [
    (4, 100, 32, jnp.float32),
    (3, 257, 48, jnp.float32),   # non-multiples of block
    (8, 64, 130, jnp.bfloat16),  # odd feature dim + bf16
])
def test_ivf_score_matches_ref(b, c, d, dtype):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(b, d))).astype(dtype)
    cent = jnp.asarray(rng.normal(size=(c, d))).astype(dtype)
    got = ops.ivf_score(q, cent, bb=2, bc=64, bd=32)
    want = ref.ivf_score_ref(q, cent)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,s,h,kv,dh,dtype", [
    (2, 128, 4, 4, 32, jnp.float32),
    (1, 200, 8, 2, 64, jnp.float32),   # GQA + ragged seq
    (2, 96, 4, 1, 16, jnp.bfloat16),   # MQA + bf16
])
def test_flash_attention_matches_ref(b, s, h, kv, dh, dtype):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)) * 0.5).astype(dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kv, dh)) * 0.5).astype(dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kv, dh)) * 0.5).astype(dtype)
    got = ops.flash_attention(q, k, v, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@settings(max_examples=10, deadline=None)
@given(
    v=st.integers(1, 40),
    d=st.integers(2, 24),
    seed=st.integers(0, 100),
)
def test_property_filter_distance(v, d, seed):
    """Masked entries are +inf/false; unmasked distances are exact."""
    rng = np.random.default_rng(seed)
    n, a, t = 30, 2, 2
    vectors, attrs = _mk_corpus(rng, n, d, a)
    idx = jnp.asarray(rng.integers(0, n, v).astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=v) > 0.5)
    q = jnp.asarray(rng.normal(size=d).astype(np.float32))
    lo = jnp.zeros((t, a), jnp.float32)
    hi = jnp.ones((t, a), jnp.float32)
    d_k, p_k = ops.filter_distance(vectors, attrs, idx, mask, q, lo, hi)
    m = np.asarray(mask)
    assert np.all(np.isinf(np.asarray(d_k)[~m]))
    assert not np.any(np.asarray(p_k)[~m])
    want = ((np.asarray(vectors)[np.asarray(idx)[m]] - np.asarray(q)) ** 2).sum(-1)
    np.testing.assert_allclose(np.asarray(d_k)[m], want, rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(s=st.integers(3, 80), seed=st.integers(0, 50))
def test_property_flash_attention_row_stochastic(s, seed):
    """Causality: output at position 0 equals v[0] exactly (only itself
    visible); all outputs are finite."""
    rng = np.random.default_rng(seed)
    b, h, dh = 1, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    out = ops.flash_attention(q, k, v, bq=32, bk=32)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(
        np.asarray(out[:, 0]), np.asarray(v[:, 0]), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# Metric sweep: every scoring kernel implements "ip" alongside "l2", sharing
# the ref path's per-row expression (kernels.ref.row_distance / adc_lut), so
# parity is *bitwise* — but only inside one compile context: XLA may fuse the
# eager oracle differently, so both sides go through jax.jit before compare
# (the discipline test_quant.py established for the LUT chain).
# ---------------------------------------------------------------------------


def _both_jitted(kernel_fn, ref_fn, *args):
    got = jax.jit(lambda *z: kernel_fn(*z))(*args)
    want = jax.jit(lambda *z: ref_fn(*z))(*args)
    return got, want


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_filter_distance_metric_parity(metric):
    rng = np.random.default_rng(21)
    n, d, a, t, v = 120, 24, 3, 2, 33
    vectors, attrs = _mk_corpus(rng, n, d, a)
    idx = jnp.asarray(rng.integers(0, n + 1, v).astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=v) > 0.3)
    q = jnp.asarray(rng.normal(size=d).astype(np.float32))
    lo = jnp.asarray(rng.uniform(0, 0.5, (t, a)).astype(np.float32))
    hi = jnp.asarray(rng.uniform(0.5, 1.0, (t, a)).astype(np.float32))
    (d_k, p_k), (d_r, p_r) = _both_jitted(
        lambda *z: ops.filter_distance(*z, metric=metric),
        lambda *z: ref.filter_distance_ref(*z, metric),
        vectors, attrs, idx, mask, q, lo, hi,
    )
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_ivf_score_ip_matches_ref(metric):
    rng = np.random.default_rng(22)
    b, c, d = 5, 130, 40
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    cent = jnp.asarray(rng.normal(size=(c, d)).astype(np.float32))
    got = ops.ivf_score(q, cent, metric=metric, bb=2, bc=64, bd=32)
    want = ref.ivf_score_ref(q, cent, metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_pq_score_metric_parity(metric):
    rng = np.random.default_rng(23)
    n, a, t, v = 90, 3, 2, 17
    m, ks, dsub = 4, 16, 4
    _, attrs = _mk_corpus(rng, n, 8, a)
    codes = jnp.asarray(
        np.concatenate(
            [rng.integers(0, ks, size=(n, m)), np.zeros((1, m), np.int64)]
        ).astype(np.uint8)
    )
    codebooks = jnp.asarray(rng.normal(size=(m, ks, dsub)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n + 1, v).astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=v) > 0.3)
    qr = jnp.asarray(rng.normal(size=m * dsub).astype(np.float32))
    lo = jnp.asarray(rng.uniform(0, 0.5, (t, a)).astype(np.float32))
    hi = jnp.asarray(rng.uniform(0.5, 1.0, (t, a)).astype(np.float32))
    (d_k, p_k), (d_r, p_r) = _both_jitted(
        lambda *z: ops.pq_score(*z, metric=metric),
        lambda *z: ref.pq_score_ref(*z, metric),
        codes, attrs, idx, mask, qr, codebooks, lo, hi,
    )
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


# ---------------------------------------------------------------------------
# Fused visit-step kernel: one pallas_call for gather + distance + predicate
# + tombstone + admission.  rows_per_step blocking must never change the
# math (rows are independent), so parity is asserted across rb values,
# metrics, live/no-live, and under vmap (how the engine calls it).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["l2", "ip"])
@pytest.mark.parametrize("with_live", [False, True])
@pytest.mark.parametrize("rb", [1, 3, None])
def test_visit_step_matches_ref(metric, with_live, rb):
    rng = np.random.default_rng(31)
    n, d, a, t, v = 150, 19, 3, 2, 29  # odd dim, V not a multiple of rb
    vectors, attrs = _mk_corpus(rng, n, d, a)
    live = jnp.asarray(rng.uniform(size=n + 1) > 0.2) if with_live else None
    idx = jnp.asarray(rng.integers(0, n + 1, v).astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=v) > 0.3)
    q = jnp.asarray(rng.normal(size=d).astype(np.float32))
    lo = jnp.asarray(rng.uniform(0, 0.5, (t, a)).astype(np.float32))
    hi = jnp.asarray(rng.uniform(0.5, 1.0, (t, a)).astype(np.float32))
    kw = {} if rb is None else {"rows_per_step": rb}
    (d_k, ad_k), (d_r, ad_r) = _both_jitted(
        lambda *z: ops.visit_step(*z, metric=metric, **kw),
        lambda *z: ref.visit_step_ref(*z, metric),
        vectors, attrs, live, idx, mask, q, lo, hi,
    )
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(ad_k), np.asarray(ad_r))
    # admission semantics: admit is either the distance or +inf, and is +inf
    # wherever the row is masked out
    ad = np.asarray(ad_k)
    dk = np.asarray(d_k)
    assert np.all(np.isinf(ad) | (ad == dk))
    assert np.all(np.isinf(ad[~np.asarray(mask)]))


def test_visit_step_vmapped_matches_ref():
    """The engine vmaps per-query visit_step over the batch — blocking and
    the scalar-prefetch grid must survive batching bitwise."""
    rng = np.random.default_rng(32)
    b, n, d, a, t, v = 4, 100, 16, 2, 2, 24
    vectors, attrs = _mk_corpus(rng, n, d, a)
    live = jnp.asarray(rng.uniform(size=n + 1) > 0.2)
    idx = jnp.asarray(rng.integers(0, n + 1, (b, v)).astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=(b, v)) > 0.3)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    lo = jnp.asarray(rng.uniform(0, 0.5, (t, a)).astype(np.float32))
    hi = jnp.asarray(rng.uniform(0.5, 1.0, (t, a)).astype(np.float32))

    def run(fn):
        return jax.jit(
            lambda qs, ids, ms: jax.vmap(
                lambda q1, i1, m1: fn(vectors, attrs, live, i1, m1, q1, lo, hi)
            )(qs, ids, ms)
        )(q, idx, mask)

    (d_k, ad_k) = run(lambda *z: ops.visit_step(*z, metric="l2"))
    (d_r, ad_r) = run(lambda *z: ref.visit_step_ref(*z, "l2"))
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(ad_k), np.asarray(ad_r))


# ---------------------------------------------------------------------------
# Per-shape block autotuner (kernels/autotune.py) + env pin resolution
# (kernels/interpret.py REPRO_PALLAS_BLOCK_*).
# ---------------------------------------------------------------------------


def test_autotune_pin_beats_measured_table(monkeypatch):
    from repro.kernels import autotune

    autotune.clear()
    cands = [{"rb": 4}, {"rb": 1}, {"rb": 8}]
    # pre-populate the measured table with a different winner
    autotune._TABLE[("visit_step", ("x",))] = {"rb": 8}
    monkeypatch.setenv("REPRO_PALLAS_BLOCK_VISIT_STEP", "rb=2")
    got = autotune.choose("visit_step", ("x",), cands)
    assert got == {"rb": 2}  # env pin wins over the measured table
    monkeypatch.delenv("REPRO_PALLAS_BLOCK_VISIT_STEP")
    assert autotune.choose("visit_step", ("x",), cands) == {"rb": 8}
    autotune.clear()


def test_autotune_pin_fills_missing_fields(monkeypatch):
    from repro.kernels import autotune

    autotune.clear()
    cands = [{"bb": 8, "bc": 128, "bd": 128}, {"bb": 16, "bc": 128, "bd": 128}]
    monkeypatch.setenv("REPRO_PALLAS_BLOCK_IVF_SCORE", "bb=4")
    got = autotune.choose("ivf_score", ("y",), cands)
    assert got == {"bb": 4, "bc": 128, "bd": 128}  # defaults fill the rest
    autotune.clear()


def test_autotune_measures_each_shape_once(monkeypatch):
    from repro.kernels import autotune

    autotune.clear()
    monkeypatch.setenv("REPRO_PALLAS_AUTOTUNE", "1")
    calls = []

    def fake_measure(cand):
        # _measure wall-clocks the call, so the cost difference must be
        # real time, not a return value — equal-cost fakes made the
        # winner timing noise (flaky under a loaded suite)
        calls.append(dict(cand))
        time.sleep(0.02 if cand["rb"] == 4 else 0.001)

    cands = [{"rb": 4}, {"rb": 2}]
    got1 = autotune.choose("visit_step", ("shape_a",), cands, fake_measure)
    n_after_first = len(calls)
    got2 = autotune.choose("visit_step", ("shape_a",), cands, fake_measure)
    assert got1 == got2 == {"rb": 2}  # fastest candidate cached
    # every candidate was probed (warmup + reps each), but the second choose
    # hit the table: measured once per shape, not per call
    assert {c["rb"] for c in calls} == {4, 2} and len(calls) == n_after_first
    assert autotune._N_MEASURED[("visit_step", ("shape_a",))] == 1
    autotune.choose("visit_step", ("shape_b",), cands, fake_measure)
    assert len(calls) > n_after_first  # a new shape re-measures
    autotune.clear()


def test_autotune_disabled_uses_default(monkeypatch):
    from repro.kernels import autotune

    autotune.clear()
    monkeypatch.setenv("REPRO_PALLAS_AUTOTUNE", "0")
    calls = []

    def fake_measure(cand):
        calls.append(cand)
        return 1.0

    got = autotune.choose("visit_step", ("z",), [{"rb": 4}, {"rb": 2}], fake_measure)
    assert got == {"rb": 4} and not calls  # candidates[0], nothing measured
    autotune.clear()


def test_visit_step_env_pin_end_to_end(monkeypatch):
    """A pinned rb must actually reach the kernel — and, because blocking
    never changes the math, stay bitwise identical to the ref oracle."""
    from repro.kernels import autotune

    autotune.clear()
    monkeypatch.setenv("REPRO_PALLAS_BLOCK_VISIT_STEP", "rb=2")
    rng = np.random.default_rng(33)
    n, d, a, t, v = 80, 12, 2, 2, 21
    vectors, attrs = _mk_corpus(rng, n, d, a)
    idx = jnp.asarray(rng.integers(0, n + 1, v).astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=v) > 0.3)
    q = jnp.asarray(rng.normal(size=d).astype(np.float32))
    lo = jnp.asarray(rng.uniform(0, 0.5, (t, a)).astype(np.float32))
    hi = jnp.asarray(rng.uniform(0.5, 1.0, (t, a)).astype(np.float32))
    (d_k, ad_k), (d_r, ad_r) = _both_jitted(
        lambda *z: ops.visit_step(*z, metric="l2"),
        lambda *z: ref.visit_step_ref(*z, "l2"),
        vectors, attrs, None, idx, mask, q, lo, hi,
    )
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(ad_k), np.asarray(ad_r))
    autotune.clear()


def test_block_override_parsing(monkeypatch):
    from repro.kernels.interpret import block_override

    monkeypatch.delenv("REPRO_PALLAS_BLOCK_VISIT_STEP", raising=False)
    assert block_override("visit_step") == {}
    monkeypatch.setenv("REPRO_PALLAS_BLOCK_VISIT_STEP", "rb=4")
    assert block_override("visit_step") == {"rb": 4}
    monkeypatch.setenv("REPRO_PALLAS_BLOCK_IVF_SCORE", "bb=8, bc=256")
    assert block_override("ivf_score") == {"bb": 8, "bc": 256}
    monkeypatch.setenv("REPRO_PALLAS_BLOCK_VISIT_STEP", "rb=four")
    with pytest.raises(ValueError, match="REPRO_PALLAS_BLOCK_VISIT_STEP"):
        block_override("visit_step")
