"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs the
pure-jnp oracle in ref.py, plus hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _mk_corpus(rng, n, d, a):
    vectors = rng.normal(size=(n + 1, d)).astype(np.float32)
    attrs = rng.uniform(size=(n + 1, a)).astype(np.float32)
    attrs[-1] = np.inf  # sentinel row
    return jnp.asarray(vectors), jnp.asarray(attrs)


@pytest.mark.parametrize("n,d,a,t,v", [
    (50, 8, 2, 1, 16),
    (200, 32, 4, 4, 33),   # non-multiple V
    (100, 17, 3, 2, 8),    # odd dim
])
def test_filter_distance_matches_ref(n, d, a, t, v):
    rng = np.random.default_rng(0)
    vectors, attrs = _mk_corpus(rng, n, d, a)
    idx = jnp.asarray(rng.integers(0, n + 1, v).astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=v) > 0.3)
    q = jnp.asarray(rng.normal(size=d).astype(np.float32))
    lo = jnp.asarray(rng.uniform(0, 0.5, (t, a)).astype(np.float32))
    hi = jnp.asarray(rng.uniform(0.5, 1.0, (t, a)).astype(np.float32))
    d_k, p_k = ops.filter_distance(vectors, attrs, idx, mask, q, lo, hi)
    d_r, p_r = ref.filter_distance_ref(vectors, attrs, idx, mask, q, lo, hi)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


@pytest.mark.parametrize("b,n,d,a,t,v", [
    (1, 50, 8, 2, 1, 16),
    (4, 200, 32, 4, 4, 33),   # non-multiple V
    (3, 100, 17, 3, 2, 8),    # odd dim
])
def test_filter_distance_batch_matches_ref(b, n, d, a, t, v):
    """The planner's batched run-scan entry point: per-lane queries and
    bounds, grid (B, V) — against the vmapped single-query oracle."""
    rng = np.random.default_rng(1)
    vectors, attrs = _mk_corpus(rng, n, d, a)
    idx = jnp.asarray(rng.integers(0, n + 1, (b, v)).astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=(b, v)) > 0.3)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    lo = jnp.asarray(rng.uniform(0, 0.5, (b, t, a)).astype(np.float32))
    hi = jnp.asarray(rng.uniform(0.5, 1.0, (b, t, a)).astype(np.float32))
    d_k, p_k = ops.filter_distance_batch(vectors, attrs, idx, mask, q, lo, hi)
    d_r, p_r = ref.filter_distance_batch_ref(vectors, attrs, idx, mask, q, lo, hi)
    assert d_k.shape == (b, v) and p_k.shape == (b, v)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


@pytest.mark.parametrize("b,c,d,dtype", [
    (4, 100, 32, jnp.float32),
    (3, 257, 48, jnp.float32),   # non-multiples of block
    (8, 64, 130, jnp.bfloat16),  # odd feature dim + bf16
])
def test_ivf_score_matches_ref(b, c, d, dtype):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(b, d))).astype(dtype)
    cent = jnp.asarray(rng.normal(size=(c, d))).astype(dtype)
    got = ops.ivf_score(q, cent, bb=2, bc=64, bd=32)
    want = ref.ivf_score_ref(q, cent)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,s,h,kv,dh,dtype", [
    (2, 128, 4, 4, 32, jnp.float32),
    (1, 200, 8, 2, 64, jnp.float32),   # GQA + ragged seq
    (2, 96, 4, 1, 16, jnp.bfloat16),   # MQA + bf16
])
def test_flash_attention_matches_ref(b, s, h, kv, dh, dtype):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)) * 0.5).astype(dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kv, dh)) * 0.5).astype(dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kv, dh)) * 0.5).astype(dtype)
    got = ops.flash_attention(q, k, v, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@settings(max_examples=10, deadline=None)
@given(
    v=st.integers(1, 40),
    d=st.integers(2, 24),
    seed=st.integers(0, 100),
)
def test_property_filter_distance(v, d, seed):
    """Masked entries are +inf/false; unmasked distances are exact."""
    rng = np.random.default_rng(seed)
    n, a, t = 30, 2, 2
    vectors, attrs = _mk_corpus(rng, n, d, a)
    idx = jnp.asarray(rng.integers(0, n, v).astype(np.int32))
    mask = jnp.asarray(rng.uniform(size=v) > 0.5)
    q = jnp.asarray(rng.normal(size=d).astype(np.float32))
    lo = jnp.zeros((t, a), jnp.float32)
    hi = jnp.ones((t, a), jnp.float32)
    d_k, p_k = ops.filter_distance(vectors, attrs, idx, mask, q, lo, hi)
    m = np.asarray(mask)
    assert np.all(np.isinf(np.asarray(d_k)[~m]))
    assert not np.any(np.asarray(p_k)[~m])
    want = ((np.asarray(vectors)[np.asarray(idx)[m]] - np.asarray(q)) ** 2).sum(-1)
    np.testing.assert_allclose(np.asarray(d_k)[m], want, rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(s=st.integers(3, 80), seed=st.integers(0, 50))
def test_property_flash_attention_row_stochastic(s, seed):
    """Causality: output at position 0 equals v[0] exactly (only itself
    visible); all outputs are finite."""
    rng = np.random.default_rng(seed)
    b, h, dh = 1, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    out = ops.flash_attention(q, k, v, bq=32, bk=32)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(
        np.asarray(out[:, 0]), np.asarray(v[:, 0]), rtol=1e-5, atol=1e-5
    )
