"""Roofline machinery: collective parser against hand-built HLO snippets,
cost-calibration arithmetic, and an end-to-end check that per-device
cost_analysis matches a hand-counted matmul."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.analysis import (
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    model_flops,
    parse_collectives,
)


def test_parse_collectives_anchored_not_operands():
    hlo = """
  %all-gather.1 = f32[16,1024]{1,0} all-gather(%p0), channel_id=1, replica_groups={{0,1,2,3}}, dimensions={0}
  %fusion.2 = f32[64,1024]{1,0} fusion(%all-gather.1), kind=kLoop
  %all-reduce.7 = bf16[512,256]{1,0} all-reduce(%fusion.2), channel_id=2, replica_groups={{0,1}}, to_apply=%add
"""
    out = parse_collectives(hlo)
    assert out["count_by_kind"] == {"all-gather": 1, "all-reduce": 1}
    ag = 16 * 1024 * 4 * (3 / 4)  # result bytes * (n-1)/n
    ar = 2 * 512 * 256 * 2 * (1 / 2)
    assert out["bytes_by_kind"]["all-gather"] == pytest.approx(ag)
    assert out["bytes_by_kind"]["all-reduce"] == pytest.approx(ar)


def test_parse_collectives_iota_groups():
    hlo = "%reduce-scatter.3 = f32[8,128]{1,0} reduce-scatter(%x), replica_groups=[64,8]<=[512], dimensions={0}"
    out = parse_collectives(hlo)
    # ring cost: result * (n-1) with n=8
    assert out["bytes_by_kind"]["reduce-scatter"] == pytest.approx(8 * 128 * 4 * 7)


@pytest.mark.xfail(strict=False, reason="pre-existing at seed: cost_analysis() returns a list under pinned jaxlib 0.4.36")
def test_cost_analysis_matches_hand_count():
    """flops for an unrolled matmul chain == 2*m*k*n each."""

    def f(x, w):
        return jnp.tanh(x @ w) @ w

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ca = jax.jit(f).lower(x, w).compile().cost_analysis()
    want = 2 * (2 * 64 * 128 * 128)
    assert ca["flops"] == pytest.approx(want, rel=0.05)


def test_model_flops_train_vs_decode():
    from repro.configs import SHAPES, get_config

    cfg = get_config("tinyllama-1.1b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    dec = model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.param_count()
    assert tr == pytest.approx(6 * n * 256 * 4096, rel=1e-6)
    assert dec == pytest.approx(2 * n * 128, rel=1e-6)


def test_moe_active_params_smaller():
    from repro.configs import get_config

    cfg = get_config("deepseek-v2-lite-16b")
    assert cfg.active_param_count() < 0.3 * cfg.param_count()
    # sanity vs the published 16B total / 2.4B active
    assert 10e9 < cfg.param_count() < 22e9
    assert 1.5e9 < cfg.active_param_count() < 4e9


def test_hardware_constants():
    assert PEAK_FLOPS == 197e12 and HBM_BW == 819e9 and LINK_BW == 50e9
