"""Fused ADC scoring Pallas TPU kernel — the quantized tier's query
hot-spot (Algorithm 4's VISIT, and the PREFILTER / delta brute scans, over
uint8 PQ codes instead of float32 rows).

Asymmetric distance computation turns one d-dim distance into ``m`` table
lookups.  The kernel fuses the whole per-query pipeline:

  * **LUT construction** — at each lane's first grid step the (m, ks)
    subspace distance table is built in VMEM scratch from the centered
    query block and the VMEM-resident codebooks (``ref.subspace_lut`` — the
    same expression the jnp path vmaps, so parity is bitwise); it then
    persists in scratch across that lane's code gathers.
  * **blocked code gather** — candidate ids are scalar-prefetched
    (PrefetchScalarGridSpec) so the BlockSpec index_map steers per-step
    DMA of the (1, m) uint8 code row, double-buffered by the pipeline —
    m bytes per candidate instead of 4·d.
  * **table lookups on the VPU** — the dynamic per-code gather is lowered
    as a one-hot select over the (m, ks) LUT (TPU vector units have no
    arbitrary-index VMEM gather; ks <= 256 keeps the select tiny).  Adding
    the masked-out zeros is exact in f32, so the reduction is bitwise
    identical to the oracle's take-then-sum.
  * **predicate masking** — the gathered (1, A) attr row evaluates the DNF
    bounds exactly as kernels/filter_distance.py; masked steps point at
    the sentinel row N and yield +inf / false.

VMEM working set per step: m·ks (LUT) + m·ks·dsub (codebooks) + d + A +
2·T·A float32s — e.g. m=16, ks=256, d=128: 16 KB LUT + 131 KB codebooks
≈ 148 KB, far under the ~16 MB budget.
Tables are squared-L2 or negated inner product (static ``metric``; ip
codes are raw, not residual-centered — see quant/params.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .interpret import default_interpret
from .ref import adc_lut, chain_sum_m


def _lookup_sum(codes, lut_ref, ks: int):
    """dist = sum_m lut[m, codes[m]] via one-hot select (VPU-friendly: TPU
    vector units have no arbitrary-index VMEM gather; adding the masked
    zeros is exact in f32).  The m partial values fold through the same
    sequential chain as the oracle (ref.chain_sum_m) for bitwise parity."""
    m = codes.shape[0]
    onehot = codes[:, None] == jax.lax.broadcasted_iota(jnp.int32, (m, ks), 1)
    row = jnp.sum(jnp.where(onehot, lut_ref[...], 0.0), axis=1)  # (m,)
    return chain_sum_m([row[mi] for mi in range(m)])


def _kernel(idx_ref, codes_ref, attr_ref, q_ref, cb_ref, lo_ref, hi_ref,
            dist_ref, pass_ref, lut_ref, *, n, ks, metric):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _build_lut():
        lut_ref[...] = adc_lut(cb_ref[...], q_ref[0, :], metric)

    valid = idx_ref[i] < n  # sentinel row == masked-out visit
    codes = codes_ref[0, :].astype(jnp.int32)  # (m,) gathered code row
    dist = _lookup_sum(codes, lut_ref, ks)
    attrs = attr_ref[0, :]  # (A,)
    lo = lo_ref[...]  # (T, A)
    hi = hi_ref[...]
    term_ok = jnp.all((attrs[None, :] >= lo) & (attrs[None, :] <= hi), axis=1)
    passed = jnp.any(term_ok)
    dist_ref[0] = jnp.where(valid, dist, jnp.inf)
    pass_ref[0] = jnp.where(valid, passed, False).astype(jnp.int32)


def pq_score(
    codes: jax.Array,  # (N + 1, m) uint8 PQ codes (row N = sentinel)
    attrs: jax.Array,  # (N + 1, A)
    idx: jax.Array,  # (V,) int32 candidate ids (may repeat / sentinel)
    mask: jax.Array,  # (V,) bool visit mask
    q_resid: jax.Array,  # (d_pad,) centered zero-padded query
    codebooks: jax.Array,  # (m, ks, dsub)
    lo: jax.Array,  # (T, A)
    hi: jax.Array,  # (T, A)
    *,
    metric: str = "l2",
    interpret: bool | None = None,
):
    """Returns (dists (V,) f32, +inf where masked; passed (V,) bool).

    ``metric`` selects the in-scratch LUT expression (ref.adc_lut): "l2"
    squared-L2 tables, "ip" negated-inner-product tables over raw (non-
    residual) codes.  The interpret default comes from
    kernels/interpret.py — see its docstring for the env overrides and the
    trace-time-baking caveat.
    """
    if interpret is None:
        interpret = default_interpret()
    return _pq_score(codes, attrs, idx, mask, q_resid, codebooks, lo, hi,
                     metric=metric, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def _pq_score(codes, attrs, idx, mask, q_resid, codebooks, lo, hi, *,
              metric: str, interpret: bool):
    v = idx.shape[0]
    n = codes.shape[0] - 1
    m, ks, dsub = codebooks.shape
    dp = q_resid.shape[0]
    a = attrs.shape[1]
    t = lo.shape[0]
    safe_idx = jnp.where(mask, jnp.clip(idx, 0, n), n).astype(jnp.int32)
    dists, passed = pl.pallas_call(
        functools.partial(_kernel, n=n, ks=ks, metric=metric),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(v,),
            in_specs=[
                pl.BlockSpec((1, m), lambda i, idx_ref: (idx_ref[i], 0)),
                pl.BlockSpec((1, a), lambda i, idx_ref: (idx_ref[i], 0)),
                pl.BlockSpec((1, dp), lambda i, idx_ref: (0, 0)),
                pl.BlockSpec((m, ks, dsub), lambda i, idx_ref: (0, 0, 0)),
                pl.BlockSpec((t, a), lambda i, idx_ref: (0, 0)),
                pl.BlockSpec((t, a), lambda i, idx_ref: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1,), lambda i, idx_ref: (i,)),
                pl.BlockSpec((1,), lambda i, idx_ref: (i,)),
            ],
            scratch_shapes=[pltpu.VMEM((m, ks), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((v,), jnp.float32),
            jax.ShapeDtypeStruct((v,), jnp.int32),
        ],
        interpret=interpret,
    )(safe_idx, codes, attrs, q_resid[None, :], codebooks, lo, hi)
    return dists, passed.astype(bool)


# ---------------------------------------------------------------------------
# Batched scan entry point — PREFILTER / delta brute scans over codes.
# ---------------------------------------------------------------------------


def _kernel_batch(idx_ref, codes_ref, attr_ref, q_ref, cb_ref, lo_ref, hi_ref,
                  dist_ref, pass_ref, lut_ref, *, n, ks, metric):
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)  # lane boundary: rebuild this lane's LUT once
    def _build_lut():
        lut_ref[...] = adc_lut(cb_ref[...], q_ref[0, :], metric)

    valid = idx_ref[b, i] < n
    codes = codes_ref[0, :].astype(jnp.int32)
    dist = _lookup_sum(codes, lut_ref, ks)
    attrs = attr_ref[0, :]
    lo = lo_ref[0]  # (T, A) this lane's DNF bounds
    hi = hi_ref[0]
    term_ok = jnp.all((attrs[None, :] >= lo) & (attrs[None, :] <= hi), axis=1)
    passed = jnp.any(term_ok)
    dist_ref[0, 0] = jnp.where(valid, dist, jnp.inf)
    pass_ref[0, 0] = jnp.where(valid, passed, False).astype(jnp.int32)


def pq_score_batch(
    codes: jax.Array,  # (N + 1, m) uint8 PQ codes (row N = sentinel)
    attrs: jax.Array,  # (N + 1, A)
    idx: jax.Array,  # (B, V) int32 candidate ids
    mask: jax.Array,  # (B, V) bool valid-slot mask
    q_resid: jax.Array,  # (B, d_pad) centered zero-padded queries
    codebooks: jax.Array,  # (m, ks, dsub)
    lo: jax.Array,  # (B, T, A) per-lane DNF bounds
    hi: jax.Array,  # (B, T, A)
    *,
    metric: str = "l2",
    interpret: bool | None = None,
):
    """Batched :func:`pq_score`: one blocked grid-(B, V) call for a whole
    micro-batch; the per-lane LUT is rebuilt in scratch at each lane
    boundary and reused across that lane's V code gathers.

    Returns (dists (B, V) f32, +inf where masked; passed (B, V) bool).
    """
    if interpret is None:
        interpret = default_interpret()
    return _pq_score_batch(codes, attrs, idx, mask, q_resid, codebooks, lo, hi,
                           metric=metric, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def _pq_score_batch(codes, attrs, idx, mask, q_resid, codebooks, lo, hi, *,
                    metric: str, interpret: bool):
    b, v = idx.shape
    n = codes.shape[0] - 1
    m, ks, dsub = codebooks.shape
    dp = q_resid.shape[1]
    a = attrs.shape[1]
    t = lo.shape[1]
    safe_idx = jnp.where(mask, jnp.clip(idx, 0, n), n).astype(jnp.int32)
    dists, passed = pl.pallas_call(
        functools.partial(_kernel_batch, n=n, ks=ks, metric=metric),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, v),
            in_specs=[
                pl.BlockSpec((1, m), lambda bi, i, idx_ref: (idx_ref[bi, i], 0)),
                pl.BlockSpec((1, a), lambda bi, i, idx_ref: (idx_ref[bi, i], 0)),
                pl.BlockSpec((1, dp), lambda bi, i, idx_ref: (bi, 0)),
                pl.BlockSpec((m, ks, dsub), lambda bi, i, idx_ref: (0, 0, 0)),
                pl.BlockSpec((1, t, a), lambda bi, i, idx_ref: (bi, 0, 0)),
                pl.BlockSpec((1, t, a), lambda bi, i, idx_ref: (bi, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1), lambda bi, i, idx_ref: (bi, i)),
                pl.BlockSpec((1, 1), lambda bi, i, idx_ref: (bi, i)),
            ],
            scratch_shapes=[pltpu.VMEM((m, ks), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, v), jnp.float32),
            jax.ShapeDtypeStruct((b, v), jnp.int32),
        ],
        interpret=interpret,
    )(safe_idx, codes, attrs, q_resid, codebooks, lo, hi)
    return dists, passed.astype(bool)
