"""Per-shape block-size autotuner for the Pallas kernel wrappers.

Block sizes (rows gathered per visit-step grid step, the ivf_score matmul
tiles) trade VMEM residency against pipeline depth, and the right choice
depends on the problem shape — d, V, m, B — not just the kernel.  Rather
than hard-coding one default per kernel, each wrapper asks :func:`choose`
for its block config.  Resolution order:

  1. **env pin** — ``REPRO_PALLAS_BLOCK_<KERNEL>`` (parsed by
     ``kernels/interpret.py``), e.g. ``REPRO_PALLAS_BLOCK_VISIT_STEP="rb=4"``.
     A pin wins over everything and is never measured against.
  2. **measured table** — an in-process ``{(kernel, shape_key): config}``
     cache.  On first sight of a shape (and only when measurement is
     enabled — see ``interpret.autotune_measurement_enabled``) every
     candidate is timed on throwaway arrays of the real shape and the
     fastest wins; the result is cached so each shape pays the probe once
     per process.
  3. **built-in default** — ``candidates[0]``, used when measurement is
     off (the CPU-interpret path: interpret-mode timings would tune for
     the interpreter, not the hardware).

Timing happens eagerly on concrete dummy arrays, so it is legal even when
``choose`` is reached at trace time inside an outer jit (the engine hot
path) — only the *chosen ints* flow into the traced program.  Block
choice never affects results: every candidate computes the same values
(tests assert bitwise equality across block sizes), so a cold cache, a
pin, or a mis-measured table can cost speed but never correctness.

The table format (what BENCH_kernels.json snapshots and DESIGN.md §Perf
documents): ``key = (kernel, shape_key)`` where ``shape_key`` is the
wrapper-chosen tuple of shape-determining ints/strs (e.g. visit_step uses
``(d, a, t, v, metric, has_live, interpret)``), ``value`` the config dict
(e.g. ``{"rb": 4}``).  ``snapshot()`` exports it for bench provenance.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Sequence

from .interpret import autotune_measurement_enabled, block_override

Config = dict[str, int]

_TABLE: dict[tuple[str, tuple], Config] = {}
#: shapes measured this process (bookkeeping, asserted on by tests)
_N_MEASURED: dict[tuple[str, tuple], int] = {}


def clear() -> None:
    """Drop the measured table (tests)."""
    _TABLE.clear()
    _N_MEASURED.clear()


def snapshot() -> dict[str, Config]:
    """The measured table as a JSON-able dict (bench provenance)."""
    return {f"{k[0]}:{k[1]}": dict(v) for k, v in sorted(_TABLE.items(), key=str)}


def _measure(fn: Callable[[Config], Any], cand: Config, reps: int = 3) -> float:
    fn(cand)  # warmup: compile + first run
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(cand)
        best = min(best, time.perf_counter() - t0)
    return best


def choose(
    kernel: str,
    shape_key: tuple,
    candidates: Sequence[Config],
    measure_fn: Callable[[Config], Any] | None = None,
) -> Config:
    """Resolve the block config for one kernel launch shape.

    ``measure_fn`` runs one candidate end-to-end on dummy data of the real
    shape and blocks until done (the wrapper supplies it); candidates that
    raise are skipped.  ``candidates[0]`` is the built-in default.

    Every resolution bumps ``compass_autotune_total{kernel,source}`` with
    the outcome (``pin``/``table``/``measured``/``default`` — see
    obs/profiling.py), so the decision that produced a given block config
    is visible at runtime without re-deriving the resolution order.
    """
    from repro.obs import profiling as prof

    pinned = block_override(kernel)
    if pinned:
        cfg = dict(candidates[0])
        cfg.update(pinned)
        prof.count_autotune(kernel, "pin")
        return cfg
    key = (kernel, tuple(shape_key))
    hit = _TABLE.get(key)
    if hit is not None:
        prof.count_autotune(kernel, "table")
        return dict(hit)
    cfg = dict(candidates[0])
    if measure_fn is not None and autotune_measurement_enabled():
        _N_MEASURED[key] = _N_MEASURED.get(key, 0) + 1
        best_t = float("inf")
        for cand in candidates:
            try:
                t = _measure(measure_fn, dict(cand))
            except Exception:  # an illegal tiling for this shape: skip it
                continue
            if t < best_t:
                best_t, cfg = t, dict(cand)
        prof.count_autotune(kernel, "measured")
    else:
        prof.count_autotune(kernel, "default")
    _TABLE[key] = dict(cfg)
    return cfg
