"""Causal flash-attention forward Pallas kernel (GQA-aware).

Tiling (TPU-native):
  grid = (B, H, S/BQ, T/BK) — the kv dimension is the innermost grid axis;
  streaming-softmax state (m, l, acc) lives in VMEM scratch and survives
  across kv steps (TPU grids iterate sequentially, so scratch carries).
  q tile (BQ, dh) stays resident; k/v tiles (BK, dh) stream HBM->VMEM.
  Scores (BQ, BK) land on the MXU; hardware-aligned 128-multiples.

GQA: the kv-head index_map folds h -> h // group so grouped query heads
re-read the same kv tile (VMEM-cached across consecutive h steps).

VMEM per step: BQ*dh + 2*BK*dh + BQ*BK + BQ*(dh+2) floats
            (= 512*128 + 2*512*128 + 512*512 + ... ~ 1.6 MB at defaults).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .interpret import default_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, bq, bk, nk, scale, seq_q, seq_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, dh)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, dh)
    sc = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)
    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = (cols <= rows) & (cols < seq_k) & (rows < seq_q)
    sc = jnp.where(valid, sc, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, sc.max(axis=1))
    p = jnp.exp(sc - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, S, H, dh)
    k: jax.Array,  # (B, T, KV, dh)
    v: jax.Array,  # (B, T, KV, dh)
    *,
    bq: int = 512,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Causal attention output (B, S, H, dh).

    The interpret default comes from kernels/interpret.py — see its
    docstring for the env overrides and the trace-time-baking caveat.
    """
    if interpret is None:
        interpret = default_interpret()
    return _flash_attention(q, k, v, bq=bq, bk=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def _flash_attention(q, k, v, *, bq: int, bk: int, interpret: bool):
    b, s, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    scale = 1.0 / math.sqrt(dh)
    pq, pk = (-s) % bq, (-t) % bk
    qp = jnp.moveaxis(jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))), 2, 1)  # (b,h,S,dh)
    kp = jnp.moveaxis(jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))), 2, 1)
    vp = jnp.moveaxis(jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))), 2, 1)
    nq, nk = qp.shape[2] // bq, kp.shape[2] // bk
    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        functools.partial(
            _kernel, bq=bq, bk=bk, nk=nk, scale=scale, seq_q=s, seq_k=t
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    out = jnp.moveaxis(out, 1, 2)[:, :s]
    return out
