"""jit'd public wrappers around the Pallas kernels.

On this CPU container every kernel runs with ``interpret=True`` (the Pallas
interpreter executes the kernel body on CPU for correctness); on real TPU
set ``REPRO_PALLAS_COMPILE=1`` to lower natively.  ``use_pallas=False``
falls back to the jnp oracle — search code paths stay identical either way.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import ref
from .filter_distance import filter_distance as _filter_distance_kernel
from .flash_attention import flash_attention as _flash_kernel
from .ivf_score import ivf_score as _ivf_kernel

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def filter_distance(vectors, attrs, idx, mask, q, lo, hi, *, use_pallas: bool = True):
    if not use_pallas:
        return ref.filter_distance_ref(vectors, attrs, idx, mask, q, lo, hi)
    return _filter_distance_kernel(
        vectors, attrs, idx, mask, q, lo, hi, interpret=_INTERPRET
    )


def ivf_score(queries, centroids, *, use_pallas: bool = True, **kw):
    if not use_pallas:
        return ref.ivf_score_ref(queries, centroids)
    return _ivf_kernel(queries, centroids, interpret=_INTERPRET, **kw)


def flash_attention(q, k, v, *, use_pallas: bool = True, **kw):
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v)
    return _flash_kernel(q, k, v, interpret=_INTERPRET, **kw)
