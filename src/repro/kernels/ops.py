"""jit'd public wrappers around the Pallas kernels.

Interpret mode is platform auto-detected (see ``kernels/interpret.py``:
native TPU lowers to Mosaic, everywhere else the Pallas interpreter
executes the kernel body for correctness, so the engine's ``"pallas"``
backend is testable on CPU; ``REPRO_PALLAS_COMPILE=1`` /
``REPRO_PALLAS_INTERPRET=1`` force-override).  The detection runs per
*trace*, not per call: inside an outer jit (e.g. ``compass_search``) the
value is baked into the cached executable, so set the env overrides before
the first traced call.  ``use_pallas=False`` falls back to the jnp oracle —
search code paths stay identical either way.
"""
from __future__ import annotations

from . import ref
from .filter_distance import filter_distance as _filter_distance_kernel
from .filter_distance import filter_distance_batch as _filter_distance_batch_kernel
from .flash_attention import flash_attention as _flash_kernel
from .ivf_score import ivf_score as _ivf_kernel
from .pq_score import pq_score as _pq_score_kernel
from .pq_score import pq_score_batch as _pq_score_batch_kernel


def filter_distance(vectors, attrs, idx, mask, q, lo, hi, *, use_pallas: bool = True):
    if not use_pallas:
        return ref.filter_distance_ref(vectors, attrs, idx, mask, q, lo, hi)
    return _filter_distance_kernel(vectors, attrs, idx, mask, q, lo, hi)


def filter_distance_batch(
    vectors, attrs, idx, mask, queries, lo, hi, *, use_pallas: bool = True
):
    if not use_pallas:
        return ref.filter_distance_batch_ref(vectors, attrs, idx, mask, queries, lo, hi)
    return _filter_distance_batch_kernel(vectors, attrs, idx, mask, queries, lo, hi)


def pq_score(codes, attrs, idx, mask, q_resid, codebooks, lo, hi, *, use_pallas: bool = True):
    if not use_pallas:
        return ref.pq_score_ref(codes, attrs, idx, mask, q_resid, codebooks, lo, hi)
    return _pq_score_kernel(codes, attrs, idx, mask, q_resid, codebooks, lo, hi)


def pq_score_batch(
    codes, attrs, idx, mask, q_resid, codebooks, lo, hi, *, use_pallas: bool = True
):
    if not use_pallas:
        return ref.pq_score_batch_ref(codes, attrs, idx, mask, q_resid, codebooks, lo, hi)
    return _pq_score_batch_kernel(codes, attrs, idx, mask, q_resid, codebooks, lo, hi)


def ivf_score(queries, centroids, *, use_pallas: bool = True, **kw):
    if not use_pallas:
        return ref.ivf_score_ref(queries, centroids)
    return _ivf_kernel(queries, centroids, **kw)


def flash_attention(q, k, v, *, use_pallas: bool = True, **kw):
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v)
    return _flash_kernel(q, k, v, **kw)
