"""jit'd public wrappers around the Pallas kernels.

Interpret mode is platform auto-detected (see ``kernels/interpret.py``:
native TPU lowers to Mosaic, everywhere else the Pallas interpreter
executes the kernel body for correctness, so the engine's ``"pallas"``
backend is testable on CPU; ``REPRO_PALLAS_COMPILE=1`` /
``REPRO_PALLAS_INTERPRET=1`` force-override, and
``REPRO_PALLAS_BLOCK_*`` pins kernel block sizes past the autotuner).
The detection runs per *trace*, not per call: inside an outer jit (e.g.
``compass_search``) the value is baked into the cached executable, so set
the env overrides before the first traced call.  ``use_pallas=False``
falls back to the jnp oracle — search code paths stay identical either
way.

Observability (obs/profiling.py): every kernel launch is wrapped in
``obs.kernel_scope`` — a ``jax.named_scope`` + ``TraceAnnotation`` pair
(pure metadata; the compiled program is identical) plus a per-kernel
wrapper counter, and every reference-path fallback (``use_pallas=False``)
bumps ``compass_kernel_fallback_total{kernel,reason}``.  Both record at
wrapper-call time — inside a jit that is *trace time*, once per compile,
the same semantics as the ``visit_step.TRACE_COUNT`` CI tripwire.

Scoring kernels take ``metric`` ("l2" squared L2 / "ip" negated inner
product); cosine runs as ip over normalized rows and never reaches this
layer (the engine rewrites it — see core/engine/driver.py).
"""
from __future__ import annotations

from repro.obs import profiling as prof

from . import ref
from .filter_distance import filter_distance as _filter_distance_kernel
from .filter_distance import filter_distance_batch as _filter_distance_batch_kernel
from .flash_attention import flash_attention as _flash_kernel
from .ivf_score import ivf_score as _ivf_kernel
from .pq_score import pq_score as _pq_score_kernel
from .pq_score import pq_score_batch as _pq_score_batch_kernel
from .visit_step import visit_step as _visit_step_kernel


def filter_distance(vectors, attrs, idx, mask, q, lo, hi, *,
                    metric: str = "l2", use_pallas: bool = True):
    if not use_pallas:
        prof.count_fallback("filter_distance", "use_pallas=False")
        return ref.filter_distance_ref(vectors, attrs, idx, mask, q, lo, hi, metric)
    with prof.kernel_scope("filter_distance"):
        return _filter_distance_kernel(
            vectors, attrs, idx, mask, q, lo, hi, metric=metric
        )


def filter_distance_batch(
    vectors, attrs, idx, mask, queries, lo, hi, *,
    metric: str = "l2", use_pallas: bool = True
):
    if not use_pallas:
        prof.count_fallback("filter_distance", "use_pallas=False")
        return ref.filter_distance_batch_ref(
            vectors, attrs, idx, mask, queries, lo, hi, metric
        )
    with prof.kernel_scope("filter_distance"):
        return _filter_distance_batch_kernel(
            vectors, attrs, idx, mask, queries, lo, hi, metric=metric
        )


def visit_step(vectors, attrs, live, idx, mask, q, lo, hi, *,
               metric: str = "l2", use_pallas: bool = True, **kw):
    """Fused visit step (gather + distance + predicate + tombstone +
    admission) — returns (dist (V,), admit (V,)); see kernels/visit_step.py."""
    if not use_pallas:
        prof.count_fallback("visit_step", "use_pallas=False")
        return ref.visit_step_ref(vectors, attrs, live, idx, mask, q, lo, hi, metric)
    with prof.kernel_scope("visit_step"):
        return _visit_step_kernel(vectors, attrs, live, idx, mask, q, lo, hi,
                                  metric=metric, **kw)


def pq_score(codes, attrs, idx, mask, q_resid, codebooks, lo, hi, *,
             metric: str = "l2", use_pallas: bool = True):
    if not use_pallas:
        prof.count_fallback("pq_score", "use_pallas=False")
        return ref.pq_score_ref(codes, attrs, idx, mask, q_resid, codebooks, lo, hi, metric)
    with prof.kernel_scope("pq_score"):
        return _pq_score_kernel(codes, attrs, idx, mask, q_resid, codebooks, lo, hi,
                                metric=metric)


def pq_score_batch(
    codes, attrs, idx, mask, q_resid, codebooks, lo, hi, *,
    metric: str = "l2", use_pallas: bool = True
):
    if not use_pallas:
        prof.count_fallback("pq_score", "use_pallas=False")
        return ref.pq_score_batch_ref(
            codes, attrs, idx, mask, q_resid, codebooks, lo, hi, metric
        )
    with prof.kernel_scope("pq_score"):
        return _pq_score_batch_kernel(
            codes, attrs, idx, mask, q_resid, codebooks, lo, hi, metric=metric
        )


def ivf_score(queries, centroids, *, metric: str = "l2", use_pallas: bool = True, **kw):
    if not use_pallas:
        prof.count_fallback("ivf_score", "use_pallas=False")
        return ref.ivf_score_ref(queries, centroids, metric)
    with prof.kernel_scope("ivf_score"):
        return _ivf_kernel(queries, centroids, metric=metric, **kw)


def flash_attention(q, k, v, *, use_pallas: bool = True, **kw):
    if not use_pallas:
        prof.count_fallback("flash_attention", "use_pallas=False")
        return ref.flash_attention_ref(q, k, v)
    with prof.kernel_scope("flash_attention"):
        return _flash_kernel(q, k, v, **kw)
