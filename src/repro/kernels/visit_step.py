"""Fused visit-step Pallas TPU kernel — Algorithm 4's whole per-step hot
spot (gather → distance → DNF predicate → tombstone mask → queue-admission
candidates) in one ``pallas_call``.

``filter_distance`` fused the gather + distance + predicate; the engine
then still paid two more HBM round-trips per visit batch on the jnp side:
the tombstone gather ``live[safe]`` and the admission select
``where(passing, dist, +inf)`` that feeds the result queue.  This kernel
folds both in and emits exactly what ``engine/state.visit`` merges:

  * **dist**  — the raw visit distance (+inf where masked/sentinel), fed
    to the traversal queues (CandQ / graph-top) so dead records keep
    routing (DESIGN.md §Mutability).
  * **admit** — ``dist`` where the row is valid, predicate-passing AND
    alive, else +inf — merged into the filtered result queue directly.

TPU design, extending the filter_distance pattern:
  * candidate ids are scalar-prefetched (PrefetchScalarGridSpec); each
    grid step gathers a *block of RB rows* — RB separate index-mapped
    (1, d) row DMAs steered by ``idx[i*RB + j]`` — double-buffered by the
    pipeline while step i-1 computes.  RB (``rows_per_step``) is the
    autotuned knob: larger RB amortizes per-step grid overhead, smaller RB
    keeps the VMEM working set and DMA latency per step low.
  * distance (squared-L2 or negated inner product, static ``metric``)
    reduces on the VPU via the same ``ref.row_distance`` expression the
    oracle uses — bitwise parity by construction.
  * the tombstone vector rides along as RB index-mapped (1, 1) int32
    gathers; immutable indices (``live is None``) compile a variant
    without those operands (trace-time branch, zero cost).

VMEM working set per step: RB·(d + A + 1) + d + 2·T·A + O(1) floats —
e.g. RB=8, d=128, A=8, T=4: ~9.3 KB, far under the ~16 MB budget.  The
win over the unfused sequence is one kernel launch and zero intermediate
(V,)-sized HBM traffic between scoring and admission.

Block-size resolution (``rows_per_step=None``) goes through
``kernels/autotune.py``: pin with ``REPRO_PALLAS_BLOCK_VISIT_STEP="rb=4"``,
else the measured per-shape table, else RB=4.  RB never changes results —
every row is computed independently by the same expressions — so tests
assert bitwise equality across RB values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import autotune
from .interpret import default_interpret
from .ref import row_distance

#: wrapper entries (trace-time inside jit) — benchmarks/bench_kernels.py's
#: selfcheck asserts this advances when the engine claims the fused path,
#: catching silent fallbacks to ref on any platform including interpret.
TRACE_COUNT = 0

_RB_CANDIDATES = (4, 1, 2, 8)


def _row_map(j: int, rb: int):
    def index_map(i, idx_ref):
        return (idx_ref[i * rb + j], 0)

    return index_map


def _kernel(idx_ref, *refs, n, rb, metric, has_live):
    vec_refs = refs[:rb]
    attr_refs = refs[rb : 2 * rb]
    off = 2 * rb
    if has_live:
        live_refs = refs[off : off + rb]
        off += rb
    q_ref, lo_ref, hi_ref, dist_ref, admit_ref = refs[off : off + 5]
    i = pl.program_id(0)
    q = q_ref[0, :]  # (d,) VMEM-resident query
    lo = lo_ref[...]  # (T, A)
    hi = hi_ref[...]
    for j in range(rb):  # static unroll over the RB gathered rows
        valid = idx_ref[i * rb + j] < n  # sentinel row == masked-out visit
        vec = vec_refs[j][0, :]  # (d,) gathered row (index-mapped)
        dist = row_distance(vec, q, metric)
        attrs = attr_refs[j][0, :]  # (A,)
        term_ok = jnp.all((attrs[None, :] >= lo) & (attrs[None, :] <= hi), axis=1)
        admit_ok = valid & jnp.any(term_ok)
        if has_live:
            admit_ok = admit_ok & (live_refs[j][0, 0] > 0)
        dist_ref[j] = jnp.where(valid, dist, jnp.inf)
        admit_ref[j] = jnp.where(admit_ok, dist, jnp.inf)


@functools.partial(jax.jit, static_argnames=("metric", "rb", "has_live", "interpret"))
def _visit_step(vectors, attrs, live2d, idx, mask, q, lo, hi, *,
                metric: str, rb: int, has_live: bool, interpret: bool):
    v = idx.shape[0]
    n = vectors.shape[0] - 1
    d = vectors.shape[1]
    a = attrs.shape[1]
    t = lo.shape[0]
    pad = (-v) % rb
    # pad the visit list to a block multiple with masked sentinel slots
    # (+inf / +inf rows, sliced off below)
    idx_p = jnp.pad(idx, (0, pad), constant_values=n)
    mask_p = jnp.pad(mask, (0, pad), constant_values=False)
    safe_idx = jnp.where(mask_p, jnp.clip(idx_p, 0, n), n).astype(jnp.int32)
    vp = v + pad
    in_specs = [pl.BlockSpec((1, d), _row_map(j, rb)) for j in range(rb)]
    in_specs += [pl.BlockSpec((1, a), _row_map(j, rb)) for j in range(rb)]
    operands = [vectors] * rb + [attrs] * rb
    if has_live:
        in_specs += [pl.BlockSpec((1, 1), _row_map(j, rb)) for j in range(rb)]
        operands += [live2d] * rb
    in_specs += [
        pl.BlockSpec((1, d), lambda i, idx_ref: (0, 0)),
        pl.BlockSpec((t, a), lambda i, idx_ref: (0, 0)),
        pl.BlockSpec((t, a), lambda i, idx_ref: (0, 0)),
    ]
    operands += [q[None, :], lo, hi]
    dist, admit = pl.pallas_call(
        functools.partial(_kernel, n=n, rb=rb, metric=metric, has_live=has_live),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(vp // rb,),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((rb,), lambda i, idx_ref: (i,)),
                pl.BlockSpec((rb,), lambda i, idx_ref: (i,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((vp,), jnp.float32),
            jax.ShapeDtypeStruct((vp,), jnp.float32),
        ],
        interpret=interpret,
    )(safe_idx, *operands)
    return dist[:v], admit[:v]


def _tuned_rb(nrows, d, a, t, v, metric, has_live, interpret) -> int:
    candidates = [{"rb": r} for r in _RB_CANDIDATES if r <= v or r == 1]

    def measure(cfg):
        # throwaway concrete arrays of the real shape; runs eagerly even
        # when this resolves at trace time inside an outer jit
        vecs = jnp.zeros((nrows, d), jnp.float32)
        ats = jnp.zeros((nrows, a), jnp.float32)
        lv = jnp.zeros((nrows, 1) if has_live else (1, 1), jnp.int32)
        out = _visit_step(
            vecs, ats, lv,
            jnp.zeros((v,), jnp.int32), jnp.ones((v,), bool),
            jnp.zeros((d,), jnp.float32),
            jnp.zeros((t, a), jnp.float32), jnp.ones((t, a), jnp.float32),
            metric=metric, rb=cfg["rb"], has_live=has_live, interpret=interpret,
        )
        jax.block_until_ready(out)

    cfg = autotune.choose(
        "visit_step", (nrows, d, a, t, v, metric, has_live, interpret),
        candidates, measure,
    )
    return cfg["rb"]


def visit_step(
    vectors: jax.Array,  # (N + 1, d) padded corpus (row N = sentinel)
    attrs: jax.Array,  # (N + 1, A)
    live: jax.Array | None,  # (N + 1,) bool tombstones, or None (immutable)
    idx: jax.Array,  # (V,) int32 candidate ids (may repeat / sentinel)
    mask: jax.Array,  # (V,) bool visit mask
    q: jax.Array,  # (d,) query
    lo: jax.Array,  # (T, A)
    hi: jax.Array,  # (T, A)
    *,
    metric: str = "l2",
    rows_per_step: int | None = None,
    interpret: bool | None = None,
):
    """Returns ``(dist (V,) f32, admit (V,) f32)`` — see module docstring.

    ``rows_per_step=None`` resolves the block size through the autotuner;
    an explicit value always wins.  The interpret default comes from
    kernels/interpret.py (env overrides, trace-time-baking caveat)."""
    global TRACE_COUNT
    if interpret is None:
        interpret = default_interpret()
    has_live = live is not None
    if rows_per_step is None:
        rows_per_step = _tuned_rb(
            vectors.shape[0], vectors.shape[1], attrs.shape[1], lo.shape[0],
            idx.shape[0], metric, has_live, interpret,
        )
    live2d = live.astype(jnp.int32)[:, None] if has_live else jnp.zeros((1, 1), jnp.int32)
    TRACE_COUNT += 1
    return _visit_step(
        vectors, attrs, live2d, idx, mask, q, lo, hi,
        metric=metric, rb=rows_per_step, has_live=has_live, interpret=interpret,
    )
