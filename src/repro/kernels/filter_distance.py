"""Fused gather + distance + predicate Pallas TPU kernel — the Compass
query hot-spot (Algorithm 4's VISIT over a batch of candidate ids).

TPU design (vs. the paper's CPU SIMD loop):
  * candidate ids are *scalar-prefetched* (PrefetchScalarGridSpec) so the
    BlockSpec index_map can steer per-step DMA: grid step i pulls row
    idx[i] of `vectors`/`attrs` HBM->VMEM while step i-1 computes — the
    canonical TPU row-gather pattern (double-buffered by the pipeline).
  * distance (squared L2 or negated inner product — static ``metric``,
    shared expression ``ref.row_distance``) reduces on the VPU over the
    (1, d) row against the VMEM-resident query.
  * the DNF interval predicate evaluates on the gathered (1, A) attr row
    against (T, A) bounds; the visit mask fuses in by pointing masked
    steps at the sentinel row N, yielding +inf distance and pass=false —
    exactly the reference semantics in kernels/ref.py.

VMEM working set per step: d + A + 2*T*A + O(1) floats — tiny; the win is
fusing three HBM round-trips (gather, distance, filter) into one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .interpret import default_interpret
from .ref import row_distance


def _kernel(idx_ref, vec_ref, attr_ref, q_ref, lo_ref, hi_ref, dist_ref, pass_ref, *, n, metric):
    i = pl.program_id(0)
    valid = idx_ref[i] < n  # sentinel row == masked-out visit
    vec = vec_ref[0, :]  # (d,) gathered row (index-mapped via idx_ref)
    q = q_ref[0, :]
    dist = row_distance(vec, q, metric)
    attrs = attr_ref[0, :]  # (A,)
    lo = lo_ref[...]  # (T, A)
    hi = hi_ref[...]
    term_ok = jnp.all((attrs[None, :] >= lo) & (attrs[None, :] <= hi), axis=1)
    passed = jnp.any(term_ok)
    dist_ref[0] = jnp.where(valid, dist, jnp.inf)
    pass_ref[0] = jnp.where(valid, passed, False).astype(jnp.int32)


def filter_distance(
    vectors: jax.Array,  # (N + 1, d) padded corpus (row N = sentinel)
    attrs: jax.Array,  # (N + 1, A)
    idx: jax.Array,  # (V,) int32 candidate ids (may repeat / sentinel)
    mask: jax.Array,  # (V,) bool visit mask
    q: jax.Array,  # (d,) query
    lo: jax.Array,  # (T, A)
    hi: jax.Array,  # (T, A)
    *,
    metric: str = "l2",
    interpret: bool | None = None,
):
    """Returns (dists (V,) f32, +inf where masked; passed (V,) bool).

    ``metric``: "l2" (squared L2) or "ip" (negated inner product).  The
    interpret default comes from kernels/interpret.py — see its docstring
    for the env overrides and the trace-time-baking caveat.
    """
    if interpret is None:
        interpret = default_interpret()
    return _filter_distance(vectors, attrs, idx, mask, q, lo, hi,
                            metric=metric, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def _filter_distance(vectors, attrs, idx, mask, q, lo, hi, *, metric: str, interpret: bool):
    v = idx.shape[0]
    n = vectors.shape[0] - 1
    d = vectors.shape[1]
    a = attrs.shape[1]
    t = lo.shape[0]
    safe_idx = jnp.where(mask, jnp.clip(idx, 0, n), n).astype(jnp.int32)
    dists, passed = pl.pallas_call(
        functools.partial(_kernel, n=n, metric=metric),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(v,),
            in_specs=[
                pl.BlockSpec((1, d), lambda i, idx_ref: (idx_ref[i], 0)),
                pl.BlockSpec((1, a), lambda i, idx_ref: (idx_ref[i], 0)),
                pl.BlockSpec((1, d), lambda i, idx_ref: (0, 0)),
                pl.BlockSpec((t, a), lambda i, idx_ref: (0, 0)),
                pl.BlockSpec((t, a), lambda i, idx_ref: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1,), lambda i, idx_ref: (i,)),
                pl.BlockSpec((1,), lambda i, idx_ref: (i,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((v,), jnp.float32),
            jax.ShapeDtypeStruct((v,), jnp.int32),
        ],
        interpret=interpret,
    )(safe_idx, vectors, attrs, q[None, :], lo, hi)
    return dists, passed.astype(bool)


# ---------------------------------------------------------------------------
# Batched run-scan entry point — the planner's PREFILTER hot spot.
# ---------------------------------------------------------------------------


def _kernel_batch(idx_ref, vec_ref, attr_ref, q_ref, lo_ref, hi_ref, dist_ref, pass_ref, *, n, metric):
    b = pl.program_id(0)
    i = pl.program_id(1)
    valid = idx_ref[b, i] < n  # sentinel row == masked-out slot
    vec = vec_ref[0, :]  # (d,) gathered row (index-mapped via idx_ref)
    q = q_ref[0, :]  # (d,) this lane's query
    dist = row_distance(vec, q, metric)
    attrs = attr_ref[0, :]  # (A,)
    lo = lo_ref[0]  # (T, A) this lane's DNF bounds
    hi = hi_ref[0]
    term_ok = jnp.all((attrs[None, :] >= lo) & (attrs[None, :] <= hi), axis=1)
    passed = jnp.any(term_ok)
    dist_ref[0, 0] = jnp.where(valid, dist, jnp.inf)
    pass_ref[0, 0] = jnp.where(valid, passed, False).astype(jnp.int32)


def filter_distance_batch(
    vectors: jax.Array,  # (N + 1, d) padded corpus (row N = sentinel)
    attrs: jax.Array,  # (N + 1, A)
    idx: jax.Array,  # (B, V) int32 candidate ids (may repeat / sentinel)
    mask: jax.Array,  # (B, V) bool valid-slot mask
    queries: jax.Array,  # (B, d) per-lane queries
    lo: jax.Array,  # (B, T, A) per-lane DNF bounds
    hi: jax.Array,  # (B, T, A)
    *,
    metric: str = "l2",
    interpret: bool | None = None,
):
    """Batched variant of :func:`filter_distance` for the planner's
    PREFILTER run scan: one blocked ``pallas_call`` over grid (B, V) for the
    whole micro-batch instead of a vmapped per-query call.  The inner grid
    dimension keeps the scalar-prefetched per-step row gather; the per-lane
    query / bounds blocks only re-DMA when the outer (lane) index advances.

    Returns (dists (B, V) f32, +inf where masked; passed (B, V) bool).
    """
    if interpret is None:
        interpret = default_interpret()
    return _filter_distance_batch(
        vectors, attrs, idx, mask, queries, lo, hi, metric=metric, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("metric", "interpret"))
def _filter_distance_batch(vectors, attrs, idx, mask, queries, lo, hi, *,
                           metric: str, interpret: bool):
    b, v = idx.shape
    n = vectors.shape[0] - 1
    d = vectors.shape[1]
    a = attrs.shape[1]
    t = lo.shape[1]
    safe_idx = jnp.where(mask, jnp.clip(idx, 0, n), n).astype(jnp.int32)
    dists, passed = pl.pallas_call(
        functools.partial(_kernel_batch, n=n, metric=metric),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, v),
            in_specs=[
                pl.BlockSpec((1, d), lambda bi, i, idx_ref: (idx_ref[bi, i], 0)),
                pl.BlockSpec((1, a), lambda bi, i, idx_ref: (idx_ref[bi, i], 0)),
                pl.BlockSpec((1, d), lambda bi, i, idx_ref: (bi, 0)),
                pl.BlockSpec((1, t, a), lambda bi, i, idx_ref: (bi, 0, 0)),
                pl.BlockSpec((1, t, a), lambda bi, i, idx_ref: (bi, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1), lambda bi, i, idx_ref: (bi, i)),
                pl.BlockSpec((1, 1), lambda bi, i, idx_ref: (bi, i)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, v), jnp.float32),
            jax.ShapeDtypeStruct((b, v), jnp.int32),
        ],
        interpret=interpret,
    )(safe_idx, vectors, attrs, queries, lo, hi)
    return dists, passed.astype(bool)
