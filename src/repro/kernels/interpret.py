"""Single source of truth for the Pallas interpret-mode default.

Interpret mode is platform auto-detected: native TPU lowers to Mosaic,
everywhere else (CPU containers included) the Pallas interpreter executes
the kernel body for correctness.  Env overrides, checked in order:

  REPRO_PALLAS_COMPILE=1    force native lowering
  REPRO_PALLAS_INTERPRET=1  force the interpreter

The overrides are read when :func:`default_interpret` runs, which for the
engine hot path is at *trace* time inside the outer ``compass_search`` jit
— the result is baked into the cached executable and later in-process env
changes are ignored for already-traced shapes.  Set the override before
the first traced call (eager kernel calls re-read it every time).
"""
from __future__ import annotations

import os

import jax


def default_interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1":
        return False
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return True
    return jax.default_backend() != "tpu"
