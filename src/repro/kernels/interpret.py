"""Single source of truth for the Pallas kernel env knobs.

Interpret mode is platform auto-detected: native TPU lowers to Mosaic,
everywhere else (CPU containers included) the Pallas interpreter executes
the kernel body for correctness.  Env overrides, checked in order:

  REPRO_PALLAS_COMPILE=1    force native lowering
  REPRO_PALLAS_INTERPRET=1  force the interpreter

Block-size pins (consumed by kernels/autotune.py, one variable per
kernel, comma-separated ``field=int`` pairs):

  REPRO_PALLAS_BLOCK_VISIT_STEP="rb=4"
  REPRO_PALLAS_BLOCK_IVF_SCORE="bb=8,bc=128,bd=128"

A pinned override beats both the measured autotune table and the built-in
defaults (see :func:`repro.kernels.autotune.choose`).  Autotune
measurement itself is gated by REPRO_PALLAS_AUTOTUNE=1/0 (default: only
measure when the kernels lower natively — interpret-mode timings would
tune for the interpreter, not the hardware).

All of these are read when the wrapper runs, which for the engine hot
path is at *trace* time inside the outer ``compass_search`` jit — the
result is baked into the cached executable and later in-process env
changes are ignored for already-traced shapes.  Set overrides before the
first traced call (eager kernel calls re-read them every time).
"""
from __future__ import annotations

import os

import jax


def default_interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1":
        return False
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return True
    return jax.default_backend() != "tpu"


def block_override(kernel: str) -> dict[str, int]:
    """Parse ``REPRO_PALLAS_BLOCK_<KERNEL>`` into a block-config dict.

    Returns {} when the variable is unset or empty; raises ValueError on a
    malformed pin (bad pins should fail loudly, not silently detune)."""
    raw = os.environ.get(f"REPRO_PALLAS_BLOCK_{kernel.upper()}", "").strip()
    if not raw:
        return {}
    out: dict[str, int] = {}
    for part in raw.split(","):
        key, _, val = part.partition("=")
        key, val = key.strip(), val.strip()
        if not key or not val or not val.lstrip("-").isdigit():
            raise ValueError(
                f"malformed REPRO_PALLAS_BLOCK_{kernel.upper()}={raw!r}; "
                "expected comma-separated field=int pairs"
            )
        out[key] = int(val)
    return out


def autotune_measurement_enabled() -> bool:
    """Whether :mod:`repro.kernels.autotune` may time candidates.

    ``REPRO_PALLAS_AUTOTUNE=1`` forces measurement on, ``=0`` off; the
    default measures only when kernels lower natively (interpret-mode
    wall-clock would tune for the interpreter, not the hardware)."""
    flag = os.environ.get("REPRO_PALLAS_AUTOTUNE", "")
    if flag == "1":
        return True
    if flag == "0":
        return False
    return not default_interpret()
