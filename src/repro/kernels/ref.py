"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def filter_distance_ref(vectors, attrs, idx, mask, q, lo, hi):
    n = vectors.shape[0] - 1
    safe = jnp.where(mask, jnp.clip(idx, 0, n), n)
    # ids pointing at the sentinel row are masked-out visits even under a
    # true mask — identical to the kernel's `idx < n` validity check
    valid = mask & (safe < n)
    vec = vectors[safe]
    diff = (vec - q[None, :]).astype(jnp.float32)
    dist = jnp.sum(diff * diff, axis=-1)
    a = attrs[safe]
    term_ok = jnp.all((a[:, None, :] >= lo[None]) & (a[:, None, :] <= hi[None]), axis=-1)
    passed = jnp.any(term_ok, axis=-1) & valid
    return jnp.where(valid, dist, jnp.inf), passed


def filter_distance_batch_ref(vectors, attrs, idx, mask, queries, lo, hi):
    """Batched (B, V) oracle: per-lane query/bounds, same row semantics."""
    return jax.vmap(
        lambda i, m, q, l, h: filter_distance_ref(vectors, attrs, i, m, q, l, h)
    )(idx, mask, queries, lo, hi)


def chain_sum_m(parts):
    """Fold per-subspace partial distances left-to-right.

    ADC distances are a sum of ``m`` table values; XLA's reduce is free to
    pick different association trees for a (m,)->() reduce (kernel) and a
    (V, m)->(V,) reduce (oracle), which costs a ULP.  ``m`` is small and
    static, so both sides fold an explicit sequential chain instead —
    order-deterministic, hence bitwise-identical across paths.
    """
    acc = parts[0]
    for p in parts[1:]:
        acc = acc + p
    return acc


def subspace_lut(codebooks, q_resid):
    """Per-subspace squared-L2 ADC table: (m, ks, dsub), (d_pad,) -> (m, ks).

    Shared by the jnp scoring path (vmapped in quant/encode.build_luts) and
    the pq_score kernel's in-kernel LUT construction — one expression, so
    the two paths agree bitwise.
    """
    m, _, dsub = codebooks.shape
    qs = q_resid.reshape(m, 1, dsub)
    diff = codebooks - qs
    # explicit left-to-right fold over the (small, static) subspace dim:
    # an axis reduce may lower to different association/FMA choices inside
    # the kernel body vs the outer jit, which costs a ULP (see chain_sum_m)
    return chain_sum_m([diff[..., j] * diff[..., j] for j in range(dsub)])


def pq_score_ref(codes, attrs, idx, mask, q_resid, codebooks, lo, hi):
    """ADC oracle: LUT build + code-gather scoring + DNF predicate.

    ``codes``: (N + 1, m) uint8 (sentinel row N); sentinel ids are
    masked-out visits even under a true mask, exactly like
    filter_distance_ref.  Returns (dists (V,) f32 +inf where masked,
    passed (V,) bool).
    """
    n = codes.shape[0] - 1
    safe = jnp.where(mask, jnp.clip(idx, 0, n), n)
    valid = mask & (safe < n)
    lut = subspace_lut(codebooks, q_resid)  # (m, ks)
    cd = codes[safe].astype(jnp.int32)  # (V, m)
    vals = lut[jnp.arange(codebooks.shape[0])[None, :], cd]  # (V, m)
    dist = chain_sum_m([vals[:, mi] for mi in range(codebooks.shape[0])])
    a = attrs[safe]
    term_ok = jnp.all((a[:, None, :] >= lo[None]) & (a[:, None, :] <= hi[None]), axis=-1)
    passed = jnp.any(term_ok, axis=-1) & valid
    return jnp.where(valid, dist, jnp.inf), passed


def pq_score_batch_ref(codes, attrs, idx, mask, q_resid, codebooks, lo, hi):
    """Batched (B, V) ADC oracle: per-lane query residuals and bounds."""
    return jax.vmap(
        lambda i, m, q, l, h: pq_score_ref(codes, attrs, i, m, q, codebooks, l, h)
    )(idx, mask, q_resid, lo, hi)


def ivf_score_ref(queries, centroids):
    q2 = jnp.sum(queries.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    c2 = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=1)
    qc = queries.astype(jnp.float32) @ centroids.astype(jnp.float32).T
    return q2 + c2[None, :] - 2.0 * qc


def flash_attention_ref(q, k, v):
    """Dense causal GQA attention in f32."""
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, kf) / math.sqrt(d)
    mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
