"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def filter_distance_ref(vectors, attrs, idx, mask, q, lo, hi):
    n = vectors.shape[0] - 1
    safe = jnp.where(mask, jnp.clip(idx, 0, n), n)
    # ids pointing at the sentinel row are masked-out visits even under a
    # true mask — identical to the kernel's `idx < n` validity check
    valid = mask & (safe < n)
    vec = vectors[safe]
    diff = (vec - q[None, :]).astype(jnp.float32)
    dist = jnp.sum(diff * diff, axis=-1)
    a = attrs[safe]
    term_ok = jnp.all((a[:, None, :] >= lo[None]) & (a[:, None, :] <= hi[None]), axis=-1)
    passed = jnp.any(term_ok, axis=-1) & valid
    return jnp.where(valid, dist, jnp.inf), passed


def filter_distance_batch_ref(vectors, attrs, idx, mask, queries, lo, hi):
    """Batched (B, V) oracle: per-lane query/bounds, same row semantics."""
    return jax.vmap(
        lambda i, m, q, l, h: filter_distance_ref(vectors, attrs, i, m, q, l, h)
    )(idx, mask, queries, lo, hi)


def ivf_score_ref(queries, centroids):
    q2 = jnp.sum(queries.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    c2 = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=1)
    qc = queries.astype(jnp.float32) @ centroids.astype(jnp.float32).T
    return q2 + c2[None, :] - 2.0 * qc


def flash_attention_ref(q, k, v):
    """Dense causal GQA attention in f32."""
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, kf) / math.sqrt(d)
    mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
