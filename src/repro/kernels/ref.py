"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def row_distance(vec, q, metric):
    """The one distance expression every visit-path oracle and kernel body
    shares: rows-vs-query over the trailing axis, f32.  ``metric``:
    ``"l2"`` squared L2, ``"ip"`` negated inner product (so smaller is
    better for both).  Keeping it a single expression — an elementwise map
    followed by one trailing-axis reduce — is what makes the (V, d) oracle
    and the per-row (d,) kernel reductions bitwise identical."""
    if metric == "l2":
        diff = (vec - q).astype(jnp.float32)
        return jnp.sum(diff * diff, axis=-1)
    if metric == "ip":
        return jnp.sum(-(vec.astype(jnp.float32) * q.astype(jnp.float32)), axis=-1)
    raise ValueError(f"unknown kernel metric {metric!r}; expected 'l2' or 'ip'")


def filter_distance_ref(vectors, attrs, idx, mask, q, lo, hi, metric="l2"):
    n = vectors.shape[0] - 1
    safe = jnp.where(mask, jnp.clip(idx, 0, n), n)
    # ids pointing at the sentinel row are masked-out visits even under a
    # true mask — identical to the kernel's `idx < n` validity check
    valid = mask & (safe < n)
    vec = vectors[safe]
    dist = row_distance(vec, q[None, :], metric)
    a = attrs[safe]
    term_ok = jnp.all((a[:, None, :] >= lo[None]) & (a[:, None, :] <= hi[None]), axis=-1)
    passed = jnp.any(term_ok, axis=-1) & valid
    return jnp.where(valid, dist, jnp.inf), passed


def filter_distance_batch_ref(vectors, attrs, idx, mask, queries, lo, hi, metric="l2"):
    """Batched (B, V) oracle: per-lane query/bounds, same row semantics."""
    return jax.vmap(
        lambda i, m, q, l, h: filter_distance_ref(vectors, attrs, i, m, q, l, h, metric)
    )(idx, mask, queries, lo, hi)


def visit_step_ref(vectors, attrs, live, idx, mask, q, lo, hi, metric="l2"):
    """Oracle for the fused visit step: distance + DNF predicate + tombstone
    mask + queue-admission candidates in one call.

    ``live`` is the (N + 1,) bool tombstone vector or None (immutable
    index).  Returns ``(dist (V,) f32, admit (V,) f32)``: ``dist`` is the
    raw visit distance (+inf where masked/sentinel) that feeds the
    traversal queues, ``admit`` equals ``dist`` where the row is valid,
    predicate-passing AND alive, else +inf — exactly what the result queue
    merges.  Composes the pre-fusion engine sequence
    (backend.visit_scores → live AND → where) verbatim, so the ref engine
    path stays bitwise identical to earlier engine versions."""
    dist, passed = filter_distance_ref(vectors, attrs, idx, mask, q, lo, hi, metric)
    if live is not None:
        n = vectors.shape[0] - 1
        safe = jnp.where(mask, jnp.clip(idx, 0, n), n)
        passed = passed & live[safe]
    return dist, jnp.where(passed, dist, jnp.inf)


def chain_sum_m(parts):
    """Fold per-subspace partial distances left-to-right.

    ADC distances are a sum of ``m`` table values; XLA's reduce is free to
    pick different association trees for a (m,)->() reduce (kernel) and a
    (V, m)->(V,) reduce (oracle), which costs a ULP.  ``m`` is small and
    static, so both sides fold an explicit sequential chain instead —
    order-deterministic, hence bitwise-identical across paths.
    """
    acc = parts[0]
    for p in parts[1:]:
        acc = acc + p
    return acc


def subspace_lut(codebooks, q_resid):
    """Per-subspace squared-L2 ADC table: (m, ks, dsub), (d_pad,) -> (m, ks).

    Shared by the jnp scoring path (vmapped in quant/encode.build_luts) and
    the pq_score kernel's in-kernel LUT construction — one expression, so
    the two paths agree bitwise.
    """
    m, _, dsub = codebooks.shape
    qs = q_resid.reshape(m, 1, dsub)
    diff = codebooks - qs
    # explicit left-to-right fold over the (small, static) subspace dim:
    # an axis reduce may lower to different association/FMA choices inside
    # the kernel body vs the outer jit, which costs a ULP (see chain_sum_m)
    return chain_sum_m([diff[..., j] * diff[..., j] for j in range(dsub)])


def subspace_lut_ip(codebooks, q_resid):
    """Per-subspace negated-inner-product ADC table: (m, ks, dsub),
    (d_pad,) -> (m, ks).  Summing the m tables reconstructs
    ``-(q · decode(code))`` (codes are raw for ip — quant/params.py rejects
    residual centering off-l2, and the zero-padded tail contributes exact
    zeros).  Same explicit fold as :func:`subspace_lut`, same sharing
    contract: the jnp path and the pq_score kernel both call this one
    expression, so the two scoring paths agree bitwise."""
    m, _, dsub = codebooks.shape
    qs = q_resid.reshape(m, 1, dsub)
    prod = codebooks * qs
    return chain_sum_m([-prod[..., j] for j in range(dsub)])


def adc_lut(codebooks, q_resid, metric="l2"):
    """Metric dispatch for the shared ADC table expressions."""
    if metric == "l2":
        return subspace_lut(codebooks, q_resid)
    if metric == "ip":
        return subspace_lut_ip(codebooks, q_resid)
    raise ValueError(f"unknown kernel metric {metric!r}; expected 'l2' or 'ip'")


def pq_score_ref(codes, attrs, idx, mask, q_resid, codebooks, lo, hi, metric="l2"):
    """ADC oracle: LUT build + code-gather scoring + DNF predicate.

    ``codes``: (N + 1, m) uint8 (sentinel row N); sentinel ids are
    masked-out visits even under a true mask, exactly like
    filter_distance_ref.  Returns (dists (V,) f32 +inf where masked,
    passed (V,) bool).
    """
    n = codes.shape[0] - 1
    safe = jnp.where(mask, jnp.clip(idx, 0, n), n)
    valid = mask & (safe < n)
    lut = adc_lut(codebooks, q_resid, metric)  # (m, ks)
    cd = codes[safe].astype(jnp.int32)  # (V, m)
    vals = lut[jnp.arange(codebooks.shape[0])[None, :], cd]  # (V, m)
    dist = chain_sum_m([vals[:, mi] for mi in range(codebooks.shape[0])])
    a = attrs[safe]
    term_ok = jnp.all((a[:, None, :] >= lo[None]) & (a[:, None, :] <= hi[None]), axis=-1)
    passed = jnp.any(term_ok, axis=-1) & valid
    return jnp.where(valid, dist, jnp.inf), passed


def pq_score_batch_ref(codes, attrs, idx, mask, q_resid, codebooks, lo, hi, metric="l2"):
    """Batched (B, V) ADC oracle: per-lane query residuals and bounds."""
    return jax.vmap(
        lambda i, m, q, l, h: pq_score_ref(codes, attrs, i, m, q, codebooks, l, h, metric)
    )(idx, mask, q_resid, lo, hi)


def ivf_score_ref(queries, centroids, metric="l2"):
    qc = queries.astype(jnp.float32) @ centroids.astype(jnp.float32).T
    if metric == "ip":
        return -qc
    q2 = jnp.sum(queries.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    c2 = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=1)
    return q2 + c2[None, :] - 2.0 * qc


def flash_attention_ref(q, k, v):
    """Dense causal GQA attention in f32."""
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, kf) / math.sqrt(d)
    mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
