"""IVF centroid scoring Pallas kernel: blocked (B, C) distance matrix on
the MXU — squared L2 or negated inner product (static ``metric``).

This is Compass's B.OPEN step (exact centroid ranking; see index.py for why
the TPU replaces the paper's cluster graph with a scan).  Tiling:

  grid = (B/BB, C/BC, d/BD)   —  classic three-loop matmul blocking
  VMEM per step: BB*BD (queries) + BC*BD (centroids) + BB*BC f32 (acc)

with hardware-aligned tiles (128-multiples) so the -2*q@c^T (l2) / -q@c^T
(ip) term lands on the MXU; the l2 ||q||^2 / ||c||^2 norms fold in per
d-block.  The accumulator lives in the output block across the d-grid
(revisited dimension).

Block sizes (``bb``/``bc``/``bd``) resolve through ``kernels/autotune.py``
when not passed explicitly: pin with
``REPRO_PALLAS_BLOCK_IVF_SCORE="bb=8,bc=128,bd=128"``, else the measured
per-shape table, else the 8/128/128 default.  Tile choice only re-blocks
the same f32 accumulation order per (query, centroid) pair along d, so
results are tile-independent up to the documented MXU-vs-ref ULP caveat
(engine/backend.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import autotune
from .interpret import default_interpret

_BLOCK_CANDIDATES = (
    {"bb": 8, "bc": 128, "bd": 128},
    {"bb": 16, "bc": 128, "bd": 128},
    {"bb": 8, "bc": 256, "bd": 128},
    {"bb": 8, "bc": 128, "bd": 256},
)


def _kernel(q_ref, c_ref, out_ref, *, nd_blocks, metric):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    qb = q_ref[...].astype(jnp.float32)  # (BB, BD)
    cb = c_ref[...].astype(jnp.float32)  # (BC, BD)
    acc = out_ref[...]
    dot = jax.lax.dot_general(
        qb, cb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    if metric == "l2":
        acc += -2.0 * dot
        acc += jnp.sum(qb * qb, axis=1, keepdims=True)
        acc += jnp.sum(cb * cb, axis=1)[None, :]
    else:  # ip: negated inner product (zero-padded d-tail adds exact zeros)
        acc += -dot
    out_ref[...] = acc


def _tuned_blocks(b, c, d, dtype, metric, interpret) -> dict[str, int]:
    def measure(cfg):
        out = _ivf_score(
            jnp.zeros((b, d), dtype), jnp.zeros((c, d), dtype),
            metric=metric, interpret=interpret, **cfg,
        )
        jax.block_until_ready(out)

    return autotune.choose(
        "ivf_score", (b, c, d, str(dtype), metric, interpret),
        _BLOCK_CANDIDATES, measure,
    )


def ivf_score(
    queries: jax.Array,  # (B, d)
    centroids: jax.Array,  # (C, d)
    *,
    metric: str = "l2",
    bb: int | None = None,
    bc: int | None = None,
    bd: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Centroid distance scores (B, C): squared L2 or negated inner product.

    Unset block sizes resolve through the autotuner; explicit values always
    win.  The interpret default comes from kernels/interpret.py — see its
    docstring for the env overrides and the trace-time-baking caveat.
    """
    if interpret is None:
        interpret = default_interpret()
    if bb is None or bc is None or bd is None:
        tuned = _tuned_blocks(
            queries.shape[0], centroids.shape[0], queries.shape[1],
            queries.dtype, metric, interpret,
        )
        bb, bc, bd = bb or tuned["bb"], bc or tuned["bc"], bd or tuned["bd"]
    return _ivf_score(queries, centroids, metric=metric, bb=bb, bc=bc, bd=bd,
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("metric", "bb", "bc", "bd", "interpret"))
def _ivf_score(queries, centroids, *, metric: str, bb: int, bc: int, bd: int,
               interpret: bool):
    b, d = queries.shape
    c = centroids.shape[0]
    pb, pc, pd = (-b) % bb, (-c) % bc, (-d) % bd
    qp = jnp.pad(queries, ((0, pb), (0, pd)))
    cp = jnp.pad(centroids, ((0, pc), (0, pd)))
    grid = (qp.shape[0] // bb, cp.shape[0] // bc, qp.shape[1] // bd)
    out = pl.pallas_call(
        functools.partial(_kernel, nd_blocks=grid[2], metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bc, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bb, bc), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], cp.shape[0]), jnp.float32),
        interpret=interpret,
    )(qp, cp)
    return out[:b, :c]
