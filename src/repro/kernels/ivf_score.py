"""IVF centroid scoring Pallas kernel: blocked (B, C) squared-L2 distance
matrix on the MXU.

This is Compass's B.OPEN step (exact centroid ranking; see index.py for why
the TPU replaces the paper's cluster graph with a scan).  Tiling:

  grid = (B/BB, C/BC, d/BD)   —  classic three-loop matmul blocking
  VMEM per step: BB*BD (queries) + BC*BD (centroids) + BB*BC f32 (acc)

with hardware-aligned tiles (128-multiples) so the -2*q@c^T term lands on
the MXU; ||q||^2 / ||c||^2 fold in on the final d-block.  The accumulator
lives in the output block across the d-grid (revisited dimension).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .interpret import default_interpret


def _kernel(q_ref, c_ref, out_ref, *, nd_blocks):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    qb = q_ref[...].astype(jnp.float32)  # (BB, BD)
    cb = c_ref[...].astype(jnp.float32)  # (BC, BD)
    acc = out_ref[...]
    acc += -2.0 * jax.lax.dot_general(
        qb, cb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc += jnp.sum(qb * qb, axis=1, keepdims=True)
    acc += jnp.sum(cb * cb, axis=1)[None, :]
    out_ref[...] = acc


def ivf_score(
    queries: jax.Array,  # (B, d)
    centroids: jax.Array,  # (C, d)
    *,
    bb: int = 8,
    bc: int = 128,
    bd: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Squared L2 distances (B, C).

    The interpret default comes from kernels/interpret.py — see its
    docstring for the env overrides and the trace-time-baking caveat.
    """
    if interpret is None:
        interpret = default_interpret()
    return _ivf_score(queries, centroids, bb=bb, bc=bc, bd=bd, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bb", "bc", "bd", "interpret"))
def _ivf_score(queries, centroids, *, bb: int, bc: int, bd: int, interpret: bool):
    b, d = queries.shape
    c = centroids.shape[0]
    pb, pc, pd = (-b) % bb, (-c) % bc, (-d) % bd
    qp = jnp.pad(queries, ((0, pb), (0, pd)))
    cp = jnp.pad(centroids, ((0, pc), (0, pd)))
    grid = (qp.shape[0] // bb, cp.shape[0] // bc, qp.shape[1] // bd)
    out = pl.pallas_call(
        functools.partial(_kernel, nd_blocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bd), lambda i, j, k: (i, k)),
            pl.BlockSpec((bc, bd), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bb, bc), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], cp.shape[0]), jnp.float32),
        interpret=interpret,
    )(qp, cp)
    return out[:b, :c]
