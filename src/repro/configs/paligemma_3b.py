"""PaliGemma-3B [arXiv:2407.07726]: SigLIP + gemma backbone.

The SigLIP vision tower is a STUB per the assignment: ``input_specs``
provides 256 precomputed patch embeddings that enter via prefix_embeds
with a bidirectional prefix-LM mask (PaliGemma's attention layout).
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab_size=257216,
    head_dim=256, mlp_type="geglu", rope_theta=10000.0,
    tie_embeddings=True,
    frontend="patch", n_prefix=256, prefix_bidirectional=True,
))
