"""TinyLlama-1.1B [arXiv:2401.02385]: small llama2-architecture GQA."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=5632, vocab_size=32000,
    mlp_type="swiglu", rope_theta=10000.0,
))
