"""Granite-3.0-1B-A400M [hf:ibm-granite]: 32-expert top-8 MoE."""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab_size=49155,
    mlp_type="swiglu", rope_theta=10000.0, tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8, d_expert=512, n_shared=0),
))
