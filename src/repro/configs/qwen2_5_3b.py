"""Qwen2.5-3B [hf:Qwen]: GQA with QKV bias."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab_size=151936,
    mlp_type="swiglu", qkv_bias=True, rope_theta=1000000.0,
    tie_embeddings=True,
))
