"""Yi-34B [arXiv:2403.04652]: llama-architecture GQA."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    mlp_type="swiglu", rope_theta=5000000.0,
))
