"""Mamba2-2.7B [arXiv:2405.21060]: attention-free SSD (state-space duality)."""
from .base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1, chunk=256),
    sub_quadratic=True,
))
