from .base import (
    SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    all_configs,
    get_config,
    reduced,
    register,
    shape_applicable,
)

__all__ = [
    "SHAPES",
    "ModelConfig",
    "MoEConfig",
    "ShapeConfig",
    "SSMConfig",
    "all_configs",
    "get_config",
    "reduced",
    "register",
    "shape_applicable",
]
