"""Model / run configuration for the serving+training substrate.

One :class:`ModelConfig` instance per assigned architecture lives in
``repro/configs/<id>.py``; the registry resolves ``--arch <id>``.  Input
shapes (the 4 assigned cells per arch) are in :data:`SHAPES`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    first_dense: int = 0  # leading dense layers (e.g. deepseek-v2)
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_kernel: int = 4
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads
    mlp_type: str = "swiglu"  # swiglu | relu2 | geglu
    qkv_bias: bool = False
    attn_type: str = "gqa"  # gqa | mla
    kv_lora_rank: int = 0  # MLA
    q_lora_rank: int = 0  # MLA (0 = full-rank q)
    rope_dim: int = 0  # MLA decoupled rope dims; 0 => head_dim for gqa
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid layout: period p => every p-th layer is (shared) attention
    hybrid_period: int = 0
    shared_attn: bool = False  # zamba2-style single shared attention block
    # modality frontend stub: prefix embeddings prepended to the sequence
    frontend: Optional[str] = None  # None | patch | frame
    n_prefix: int = 0  # prefix embedding count for vlm
    prefix_bidirectional: bool = False  # paligemma prefix-LM mask
    embed_inputs: bool = True  # False => inputs are precomputed embeddings
    sub_quadratic: bool = False  # supports long_500k decode
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_attn = self.n_layers
        n_mamba = 0
        if self.family in ("ssm",):
            n_attn = 0
            n_mamba = self.n_layers
        elif self.hybrid_period:
            n_attn_blocks = self.n_layers // self.hybrid_period
            n_mamba = self.n_layers - n_attn_blocks
            n_attn = 1 if self.shared_attn else n_attn_blocks
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        # attention
        if self.attn_type == "mla":
            r, rd = self.kv_lora_rank, self.rope_dim
            attn = d * (self.n_heads * (hd + rd)) + d * (r + rd)
            attn += r * self.n_heads * 2 * hd + self.n_heads * hd * d
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        total += n_attn * attn
        # mlp / moe
        mult = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        if self.moe:
            per_expert = mult * d * self.moe.d_expert
            layers_moe = self.n_layers - self.moe.first_dense
            total += layers_moe * (
                (self.moe.n_experts + self.moe.n_shared) * per_expert + d * self.moe.n_experts
            )
            total += self.moe.first_dense * mult * d * self.d_ff
        elif self.family != "ssm" and not self.hybrid_period:
            total += self.n_layers * mult * d * self.d_ff
        elif self.hybrid_period:
            total += (1 if self.shared_attn else self.n_layers // self.hybrid_period) * mult * d * self.d_ff
        # mamba blocks
        if n_mamba and self.ssm:
            di = self.ssm.d_inner(d)
            nh = self.ssm.n_ssm_heads(d)
            g = self.ssm.n_groups
            per = d * (2 * di + 2 * g * self.ssm.d_state + nh) + di * d
            total += n_mamba * per
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE-aware), for 6*N_active*D."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        mult = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        per_expert = mult * d * self.moe.d_expert
        layers_moe = self.n_layers - self.moe.first_dense
        inactive = layers_moe * (self.moe.n_experts - self.moe.top_k) * per_expert
        return int(self.param_count() - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    from . import (  # noqa: F401
        deepseek_v2_lite_16b,
        granite_moe_1b_a400m,
        mamba2_2_7b,
        musicgen_large,
        nemotron_4_340b,
        paligemma_3b,
        qwen2_5_3b,
        tinyllama_1_1b,
        yi_34b,
        zamba2_7b,
    )


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k requires sub-quadratic sequence mixing (DESIGN.md §Skips)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False
    return True


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw: dict = dict(
        n_layers=max(2, min(cfg.n_layers, 2)),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(4, max(1, cfg.n_kv_heads // max(1, cfg.n_heads // 4))),
        d_ff=256,
        vocab_size=512,
        head_dim=32 if cfg.head_dim else 0,
    )
    if cfg.attn_type == "mla":
        kw.update(kv_lora_rank=32, q_lora_rank=0, rope_dim=16)
    if cfg.moe:
        # capacity_factor high enough to be drop-free at smoke scale so the
        # prefill/decode consistency invariant holds exactly (capacity
        # dropping is inherently batch-dependent; accepted at real scale)
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_expert=64, capacity_factor=8.0,
            n_shared=min(cfg.moe.n_shared, 1), first_dense=min(cfg.moe.first_dense, 1),
        )
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32, chunk=16)
    if cfg.hybrid_period:
        kw["hybrid_period"] = 2
        kw["n_layers"] = 4
    if cfg.n_prefix:
        kw["n_prefix"] = 8
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
