"""Zamba2-7B [arXiv:2411.15242]: Mamba2 backbone with a *shared*
transformer block applied periodically (hybrid)."""
from .base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    mlp_type="swiglu", rope_theta=10000.0,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, n_groups=2, chunk=256),
    hybrid_period=6, shared_attn=True,
    sub_quadratic=True,  # attention blocks are sparse-in-depth; decode state
                         # is dominated by Mamba2 states => long_500k runs
))
