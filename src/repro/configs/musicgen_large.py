"""MusicGen-large [arXiv:2306.05284]: decoder-only over EnCodec tokens.

The EnCodec frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (the sum of codebook embeddings); the backbone
is a plain causal transformer with a 2048-way codebook head.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    mlp_type="geglu", rope_theta=10000.0,
    frontend="frame", embed_inputs=False,
))
