"""DeepSeek-V2-Lite (16B) [arXiv:2405.04434]: MLA (kv_lora=512) + MoE
(64 routed top-6, 2 shared), first layer dense."""
from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    head_dim=128, attn_type="mla", kv_lora_rank=512, rope_dim=64,
    mlp_type="swiglu", rope_theta=10000.0,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2, first_dense=1),
))
