"""Deterministic, shard-aware synthetic data pipelines.

Token stream: a mixture of Zipfian unigrams and copied n-gram motifs so a
~100M model trained for a few hundred steps shows a *decreasing* loss curve
(pure uniform tokens would pin loss at log V).

Shard-awareness / fault tolerance: batches are a pure function of
(seed, step, shard) — any worker can deterministically regenerate any batch
after a restart, and elastic re-sharding just changes the (shard, n_shards)
split with no coordination state.  This mirrors how deterministic data
pipelines are built at scale.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2
    motif_len: int = 16
    n_motifs: int = 512


class SyntheticTokens:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed motif bank (regenerated identically on every worker)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.probs = p / p.sum()
        self.motifs = rng.choice(
            cfg.vocab_size, size=(cfg.n_motifs, cfg.motif_len), p=self.probs
        ).astype(np.int32)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        """Batch for (step, shard). tokens/labels: (global_batch/n_shards, seq)."""
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        bsz = cfg.global_batch // n_shards
        rng = np.random.default_rng((cfg.seed, step, shard))
        toks = rng.choice(cfg.vocab_size, size=(bsz, cfg.seq_len + 1), p=self.probs).astype(
            np.int32
        )
        # splice motifs (learnable structure)
        n_splice = max(1, cfg.seq_len // (4 * cfg.motif_len))
        for b in range(bsz):
            for _ in range(n_splice):
                m = rng.integers(0, cfg.n_motifs)
                pos = rng.integers(0, cfg.seq_len + 1 - cfg.motif_len)
                toks[b, pos : pos + cfg.motif_len] = self.motifs[m]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_vector_corpus(
    n: int,
    dim: int,
    n_attrs: int,
    *,
    n_modes: int = 64,
    mode_scale: float = 3.0,
    attr_correlated: bool = False,
    seed: int = 0,
):
    """Clustered Gaussian corpus + uniform attributes (paper §V.A augments
    real vector sets with 4 uniformly generated relational attributes).

    attr_correlated=True ties attr 0 to the mode id — the adversarial case
    where relational locality aligns with vector locality.
    """
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_modes, dim)).astype(np.float32) * mode_scale
    modes = rng.integers(0, n_modes, n)
    x = (centers[modes] + rng.normal(size=(n, dim))).astype(np.float32)
    attrs = rng.uniform(size=(n, n_attrs)).astype(np.float32)
    if attr_correlated:
        attrs[:, 0] = (modes + rng.uniform(size=n)) / n_modes
    queries_modes = rng.integers(0, n_modes, 1024)
    queries = (centers[queries_modes] + rng.normal(size=(1024, dim))).astype(np.float32)
    return x, attrs, queries
