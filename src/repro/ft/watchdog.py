"""Fault-tolerance runtime pieces: step watchdog, straggler detection,
and the restart-from-checkpoint policy.

At 1000+ nodes the failure model is: slow host (straggler), dead host
(SIGKILL/network partition), and corrupted step (NaN burst).  The
corresponding mitigations wired in here:

  * StepWatchdog — wall-clock per step with an EWMA baseline; a step
    exceeding ``factor`` x EWMA flags a straggler.  In multi-host JAX the
    flag feeds the launcher (repro.launch.train) which can evict the host
    (restart with a spare) — eviction itself is a scheduler action, the
    in-process part is detection + clean checkpoint-exit.
  * NaN sentinel — global-norm NaN/Inf after each step triggers rollback:
    reload the last checkpoint and skip the poisoned data shard (the data
    pipeline is deterministic in (seed, step, shard) so the skip is exact:
    we advance the step counter without consuming the batch).
  * Heartbeat file — external orchestrators (k8s, Borg) watch mtime; a
    wedged process (deadlocked collective) stops heartbeating and gets
    preempted, landing in the restart path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional


@dataclasses.dataclass
class WatchdogConfig:
    ewma_alpha: float = 0.1
    straggler_factor: float = 2.5
    warmup_steps: int = 3
    heartbeat_path: Optional[str] = None


class StepWatchdog:
    def __init__(self, cfg: WatchdogConfig = WatchdogConfig()):
        self.cfg = cfg
        self.ewma: Optional[float] = None
        self.n = 0
        self.straggler_events: list[tuple[int, float, float]] = []
        self._t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> bool:
        """Returns True if this step was a straggler."""
        assert self._t0 is not None
        dt = time.monotonic() - self._t0
        self._t0 = None
        self.n += 1
        if self.cfg.heartbeat_path:
            with open(self.cfg.heartbeat_path, "a") as f:
                f.write(f"{step},{dt:.3f}\n")
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = (
            self.n > self.cfg.warmup_steps and dt > self.cfg.straggler_factor * self.ewma
        )
        if is_straggler:
            self.straggler_events.append((step, dt, self.ewma))
        else:
            self.ewma = (1 - self.cfg.ewma_alpha) * self.ewma + self.cfg.ewma_alpha * dt
        return is_straggler


def loss_is_poisoned(loss: float) -> bool:
    import math

    return not math.isfinite(loss)
