"""Elastic scaling: resume a run on a different mesh shape.

Because (a) checkpoints are mesh-agnostic (checkpoint.restore re-device_puts
every leaf with the *target* shardings) and (b) the data pipeline is a pure
function of (seed, step, shard), growing 256 -> 512 chips or shrinking after
losing a pod is: stop, restart with the new mesh, restore, continue — no
resharding service needed.  This module holds the policy arithmetic the
launcher uses.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_devices: int
    new_devices: int
    global_batch: int

    @property
    def per_device_batch_old(self) -> int:
        return self.global_batch // self.old_devices

    @property
    def per_device_batch_new(self) -> int:
        return self.global_batch // self.new_devices

    def validate(self) -> list[str]:
        """Constraints a resize must satisfy to preserve run semantics."""
        problems = []
        if self.global_batch % self.new_devices:
            problems.append(
                f"global_batch {self.global_batch} not divisible by "
                f"{self.new_devices} devices; adjust microbatching"
            )
        return problems


def remap_data_shards(step: int, old_shards: int, new_shards: int) -> dict:
    """Deterministic pipeline means shard remapping is pure bookkeeping:
    the new worker s regenerates batch(step, s, new_shards).  Returns an
    audit record for the run log."""
    return {
        "step": step,
        "old_shards": old_shards,
        "new_shards": new_shards,
        "note": "batches are pure f(seed, step, shard); no data motion",
    }
