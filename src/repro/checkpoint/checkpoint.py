"""Checkpointing with atomic writes, step retention, and elastic restore.

Design points for 1000+-node runs (DESIGN.md §Fault-tolerance):
  * save(): every leaf is materialized host-side (fully replicated values
    once per host; sharded values are gathered per-process in multi-host
    runs via jax.experimental.multihost_utils — here single-process) and
    written to a temp dir, then atomically renamed.  A crashed save never
    corrupts the latest checkpoint.
  * restore(mesh, shardings): leaves are *re-sharded on load* by passing
    target shardings, so a run checkpointed on a (16,16) mesh restarts on
    (2,16,16) or any other topology — elastic scaling.
  * retention: keep the newest `keep` steps; cleanup is best-effort.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    meta = {
        "step": step,
        "keys": sorted(flat.keys()),
        "treedef": str(jax.tree_util.tree_structure(tree)),
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _cleanup(ckpt_dir, keep)
    return final


def _cleanup(ckpt_dir: str, keep: int) -> None:
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return sorted(out)


def restore(
    ckpt_dir: str,
    tree_like: Any,
    step: Optional[int] = None,
    *,
    shardings: Any = None,
) -> tuple[Any, int]:
    """Restore into the structure of `tree_like`.

    shardings: optional pytree (matching tree_like) of NamedSharding — when
    given, each leaf is device_put with its target sharding, implementing
    elastic mesh-shape changes at restore time.
    """
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step = step if step is not None else steps[-1]
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like = _flatten(tree_like)
    if sorted(data.files) != sorted(flat_like.keys()):
        missing = set(flat_like) - set(data.files)
        extra = set(data.files) - set(flat_like)
        raise ValueError(f"checkpoint/tree mismatch: missing={missing} extra={extra}")

    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(paths)
    )
    leaves = []
    for (path_keys, leaf), shard in zip(paths, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
