"""Core transformer layers: RMSNorm, RoPE, GQA / MLA attention, MLPs.

Pure-functional (params are nested dicts of arrays) so every layer composes
with ``jax.lax.scan`` over stacked per-layer params and shards transparently
under pjit.  Initializers take an explicit key; dtypes follow the config
(params kept in float32 for optimizer friendliness, compute cast per call).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = dict[str, Any]


def _dense_init(key, shape, scale: float | None = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, shape, jnp.float32) * scale


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight).astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "wi": _dense_init(ks[0], (d, f)),
            "wg": _dense_init(ks[1], (d, f)),
            "wo": _dense_init(ks[2], (f, d)),
        }
    return {"wi": _dense_init(ks[0], (d, f)), "wo": _dense_init(ks[2], (f, d))}


def mlp(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["wg"].astype(dt)) * (x @ params["wi"].astype(dt))
    elif cfg.mlp_type == "geglu":
        h = jax.nn.gelu(x @ params["wg"].astype(dt)) * (x @ params["wi"].astype(dt))
    elif cfg.mlp_type == "relu2":  # nemotron-4 squared ReLU
        h = jnp.square(jax.nn.relu(x @ params["wi"].astype(dt)))
    else:
        raise ValueError(cfg.mlp_type)
    return h @ params["wo"].astype(dt)


# ---------------------------------------------------------------------------
# Attention (GQA)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 5)
    p = {
        "wq": _dense_init(ks[0], (d, nh * hd)),
        "wk": _dense_init(ks[1], (d, nkv * hd)),
        "wv": _dense_init(ks[2], (d, nkv * hd)),
        "wo": _dense_init(ks[3], (nh * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), jnp.float32)
        p["bk"] = jnp.zeros((nkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((nkv * hd,), jnp.float32)
    return p


def _attn_mask(seq: int, n_prefix: int, bidirectional_prefix: bool) -> jax.Array:
    """Causal mask, optionally bidirectional over the leading prefix
    (PaliGemma-style prefix-LM)."""
    i = jnp.arange(seq)[:, None]
    j = jnp.arange(seq)[None, :]
    causal = j <= i
    if bidirectional_prefix and n_prefix > 0:
        prefix = (i < n_prefix) & (j < n_prefix)
        causal = causal | prefix
    return causal


def _sdpa(q, k, v, mask) -> jax.Array:
    """q: (B,S,H,D) k,v: (B,T,KV,D); grouped-query attention (dense)."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    group = h // kv
    qg = q.reshape(b, s, kv, group, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / math.sqrt(d)
    scores = scores.astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, d)


_FLASH_THRESHOLD = 2048  # switch to blocked attention above this seq len
_FLASH_BLOCK_Q = 512
_FLASH_BLOCK_KV = 1024


def _sdpa_flash(q, k, v, n_prefix: int, bidirectional_prefix: bool) -> jax.Array:
    """Flash-attention-style kv-blocked causal attention in pure jnp.

    Never materializes (S, T) scores: a single scan over kv blocks carries
    the streaming-softmax (m, l, acc) state.  The query/sequence axis stays
    whole — under the sequence-parallel activation sharding it is already
    model-sharded, so the live tile per device is (b_loc, kv, g, S_loc, BK).
    Scanning over kv (replicated after a small per-block all-gather) keeps
    the scan axis unsharded — scanning a *sharded* axis makes SPMD gather
    whole tiles per step.  Also the reference oracle for
    kernels/flash_attention (same math, same tiling).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    dv = v.shape[3]  # may differ from d (MLA folds rope dims into q/k only)
    g = h // kvh
    bk = _FLASH_BLOCK_KV
    pad_k = (-t) % bk
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nk = kp.shape[1] // bk
    kb = jnp.moveaxis(kp.reshape(b, nk, bk, kvh, d), 1, 0)  # (nk, b, bk, kv, d)
    vb = jnp.moveaxis(vp.reshape(b, nk, bk, kvh, dv), 1, 0)
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, s, kvh, g, d)
    rows = jnp.arange(s)

    def kv_block(state, inp):
        m, l, acc = state
        kblk, vblk, ki = inp
        cols = ki * bk + jnp.arange(bk)
        sc = jnp.einsum("bskgd,btkd->bkgst", qg, kblk).astype(jnp.float32) * scale
        valid = (cols[None, :] <= rows[:, None]) & (cols[None, :] < t)
        if bidirectional_prefix and n_prefix > 0:
            pre = (rows[:, None] < n_prefix) & (cols[None, :] < n_prefix)
            valid = valid | (pre & (cols[None, :] < t))
        sc = jnp.where(valid[None, None, None], sc, -1e30)
        m_new = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(q.dtype), vblk
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, s, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        kv_block, (m0, l0, a0), (kb, vb, jnp.arange(nk))
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # (b, kv, g, s, dv) -> (b, s, kv, g, dv)
    out = jnp.moveaxis(out, 3, 1)
    return out.reshape(b, s, h, dv).astype(q.dtype)


def attention(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    cache: Optional[dict] = None,
    cache_pos: Optional[jax.Array] = None,
    n_prefix: int = 0,
):
    """GQA attention.  Train/prefill when ``cache is None`` (returns y, new
    kv for cache init); decode when cache given (single-step update).
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    dt = x.dtype
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, s, nkv, hd)
    v = v.reshape(b, s, nkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        if s > _FLASH_THRESHOLD:
            y = _sdpa_flash(q, k, v, n_prefix, cfg.prefix_bidirectional)
        else:
            mask = _attn_mask(s, n_prefix, cfg.prefix_bidirectional)
            y = _sdpa(q, k, v, mask)
        new_cache = {"k": k, "v": v}
    else:
        # decode: scatter the new kv at cache_pos, attend over the cache
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_pos, axis=1)
        t = ck.shape[1]
        # causal within the new block: row i sees cache positions <= pos + i
        valid = jnp.arange(t)[None, :] <= (cache_pos + jnp.arange(s)[:, None])  # (s, t)
        group = nh // nkv
        qg = q.reshape(b, s, nkv, group, hd)
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, ck) / math.sqrt(hd)
        scores = scores.astype(jnp.float32)
        scores = jnp.where(valid[None, None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        y = jnp.einsum("bkgst,btkd->bskgd", probs, cv).reshape(b, s, nh, hd)
        new_cache = {"k": ck, "v": cv}
    y = y.reshape(b, s, nh * hd) @ params["wo"].astype(dt)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, r, rd = cfg.n_heads, cfg.kv_lora_rank, cfg.rope_dim
    ks = jax.random.split(key, 6)
    return {
        # queries: full-rank (q_lora omitted for the lite config)
        "wq": _dense_init(ks[0], (d, nh * (hd + rd))),
        # joint kv compression + decoupled rope key
        "wdkv": _dense_init(ks[1], (d, r + rd)),
        "wuk": _dense_init(ks[2], (r, nh * hd)),
        "wuv": _dense_init(ks[3], (r, nh * hd)),
        "wo": _dense_init(ks[4], (nh * hd, d)),
        "norm_ckv": jnp.ones((r,), jnp.float32),
    }


def mla_attention(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    cache: Optional[dict] = None,
    cache_pos: Optional[jax.Array] = None,
    n_prefix: int = 0,
):
    """Multi-head latent attention.  The cache stores only the compressed
    c_kv (rank r) and the shared rope key (rd) — MLA's memory saving."""
    b, s, d = x.shape
    hd, nh = cfg.resolved_head_dim, cfg.n_heads
    r, rd = cfg.kv_lora_rank, cfg.rope_dim
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(b, s, nh, hd + rd)
    q_nope, q_pe = q[..., :hd], q[..., hd:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    dkv = x @ params["wdkv"].astype(dt)  # (b, s, r + rd)
    c_kv, k_pe = dkv[..., :r], dkv[..., r:]
    c_kv = rms_norm(c_kv, params["norm_ckv"], cfg.norm_eps)
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    if cache is not None:
        c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, cache_pos, axis=1)
        k_pe = jax.lax.dynamic_update_slice_in_dim(cache["k_pe"], k_pe, cache_pos, axis=1)
    new_cache = {"c_kv": c_kv, "k_pe": k_pe}
    t = c_kv.shape[1]

    k_nope = (c_kv @ params["wuk"].astype(dt)).reshape(b, t, nh, hd)
    v = (c_kv @ params["wuv"].astype(dt)).reshape(b, t, nh, hd)

    if cache is None and s > _FLASH_THRESHOLD:
        # flash path: fold the decoupled rope dims into the head dim — the
        # score is one dot product over (hd + rd), and flash's 1/sqrt(hd+rd)
        # scale is exactly MLA's; MLA is MHA post-up-projection.
        qc = jnp.concatenate([q_nope, q_pe], axis=-1)
        kc = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (b, t, nh, rd))], axis=-1
        )
        y = _sdpa_flash(qc, kc, v, n_prefix, cfg.prefix_bidirectional)
        y = y.reshape(b, s, nh * hd) @ params["wo"].astype(dt)
        return y, new_cache

    scale = 1.0 / math.sqrt(hd + rd)
    scores = (
        jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
        + jnp.einsum("bshd,btd->bhst", q_pe, k_pe)
    ) * scale
    scores = scores.astype(jnp.float32)
    if cache is None:
        mask = _attn_mask(s, n_prefix, cfg.prefix_bidirectional)
        scores = jnp.where(mask[None, None], scores, -1e30)
    else:
        valid = jnp.arange(t)[None, :] <= (cache_pos + jnp.arange(s)[:, None])  # (s, t)
        scores = jnp.where(valid[None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    y = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(b, s, nh * hd)
    y = y @ params["wo"].astype(dt)
    return y, new_cache
