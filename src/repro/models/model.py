"""Model assembly: embeddings -> stacked blocks (lax.scan) -> head.

Covers all assigned families:
  dense / vlm / audio : [attn + mlp] x L, scanned (homogeneous stack)
  moe                 : [attn + moe] x L (+ leading dense layers)
  ssm                 : [mamba2] x L, scanned
  hybrid (zamba2)     : groups of (p-1) mamba layers + a *shared* attention
                        block applied between groups (weights reused)

Params are nested dicts; homogeneous per-layer params are stacked along a
leading L axis so the layer loop is a single ``lax.scan`` (compile-time and
HLO size stay flat in depth — essential for the 96-layer dry-runs).

Modality frontends (vlm/audio) are stubs per the assignment: `input_specs`
provides precomputed patch/frame embeddings; here they enter through
``prefix_embeds`` / ``inputs_embeds``.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import layers as L
from . import moe as MOE
from . import ssm as SSM

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _layer_kind(cfg: ModelConfig, i: int) -> str:
    if cfg.family == "ssm":
        return "mamba"
    if cfg.hybrid_period:
        return "attn" if (i + 1) % cfg.hybrid_period == 0 else "mamba"
    if cfg.moe and i >= cfg.moe.first_dense:
        return "moe"
    return "attn"


def _init_attn_layer(key, cfg: ModelConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    attn_init = L.init_mla if cfg.attn_type == "mla" else L.init_attention
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": attn_init(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "mlp": L.init_mlp(k2, cfg),
    }


def _init_moe_layer(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    attn_init = L.init_mla if cfg.attn_type == "mla" else L.init_attention
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": attn_init(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "moe": MOE.init_moe(k2, cfg),
    }


def _init_mamba_layer(key, cfg: ModelConfig) -> Params:
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "mamba": SSM.init_mamba2(key, cfg),
    }


def _stack(trees: list[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 4)
    p: Params = {
        "embed": jax.random.normal(keys[-1], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02,
        "final_ln": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["head"] = L._dense_init(keys[-2], (cfg.d_model, cfg.vocab_size), scale=0.02)
    if cfg.frontend:
        # stub projection from precomputed features to d_model
        p["frontend_proj"] = L._dense_init(keys[-3], (cfg.d_model, cfg.d_model))

    kinds = [_layer_kind(cfg, i) for i in range(cfg.n_layers)]
    if cfg.family == "ssm":
        p["mamba_layers"] = _stack([_init_mamba_layer(keys[i], cfg) for i in range(cfg.n_layers)])
    elif cfg.hybrid_period:
        mamba_idx = [i for i, k in enumerate(kinds) if k == "mamba"]
        p["mamba_layers"] = _stack([_init_mamba_layer(keys[i], cfg) for i in mamba_idx])
        if cfg.shared_attn:
            p["attn_shared"] = _init_attn_layer(keys[cfg.n_layers], cfg)
        else:
            attn_idx = [i for i, k in enumerate(kinds) if k == "attn"]
            p["attn_layers"] = _stack([_init_attn_layer(keys[i], cfg) for i in attn_idx])
    elif cfg.moe:
        dense_idx = [i for i, k in enumerate(kinds) if k == "attn"]
        moe_idx = [i for i, k in enumerate(kinds) if k == "moe"]
        if dense_idx:
            p["dense_layers"] = _stack([_init_attn_layer(keys[i], cfg) for i in dense_idx])
        p["moe_layers"] = _stack([_init_moe_layer(keys[i], cfg) for i in moe_idx])
    else:
        p["layers"] = _stack([_init_attn_layer(keys[i], cfg) for i in range(cfg.n_layers)])
    return p


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _attn_block(lp, x, cfg, positions, cache=None, cache_pos=None, n_prefix=0, ep=None):
    attn_fn = L.mla_attention if cfg.attn_type == "mla" else L.attention
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    y, new_cache = attn_fn(lp["attn"], h, cfg, positions, cache, cache_pos, n_prefix)
    x = x + y
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        x = x + MOE.moe_block(lp["moe"], h, cfg, ep)
    else:
        x = x + L.mlp(lp["mlp"], h, cfg)
    return x, new_cache


def _mamba_block(lp, x, cfg, cache=None):
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    y, new_cache = SSM.mamba2_block(lp["mamba"], h, cfg, cache)
    return x + y, new_cache


def _constrain(x, act_sharding):
    if act_sharding is not None:
        x = jax.lax.with_sharding_constraint(x, act_sharding)
    return x


def _scan_attn_stack(stacked, x, cfg, positions, caches, cache_pos, n_prefix, remat, act_sharding=None, unroll=False, ep=None):
    """Scan a homogeneous stack of attention(+mlp/moe) layers.

    caches: pytree with leading layer axis, or None (train/prefill: caches
    are *returned* with leading layer axis for cache init)."""

    def body(x, inp):
        lp, cache_l = inp
        fn = _attn_block
        if remat:
            # cfg, n_prefix, ep are static (checkpoint would trace the ints)
            fn = jax.checkpoint(_attn_block, static_argnums=(2, 6, 7))
        x, new_cache = fn(lp, x, cfg, positions, cache_l, cache_pos, n_prefix, ep)
        return _constrain(x, act_sharding), new_cache

    x, new_caches = jax.lax.scan(body, x, (stacked, caches), unroll=unroll)
    return x, new_caches


def _scan_mamba_stack(stacked, x, cfg, caches, remat, act_sharding=None, unroll=False):
    def body(x, inp):
        lp, cache_l = inp
        fn = _mamba_block
        if remat:
            fn = jax.checkpoint(_mamba_block, static_argnums=(2,))
        x, new_cache = fn(lp, x, cfg, cache_l)
        return _constrain(x, act_sharding), new_cache

    x, new_caches = jax.lax.scan(body, x, (stacked, caches), unroll=unroll)
    return x, new_caches


def _broadcast_none(tree_proto, n):
    """None stand-in caches with a leading layer axis for scan."""
    return None


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,  # (B, S) int32
    inputs_embeds: Optional[jax.Array] = None,  # (B, S, d) modality stub
    prefix_embeds: Optional[jax.Array] = None,  # (B, P, d) vlm patches
    caches: Optional[dict] = None,
    cache_pos: Optional[jax.Array] = None,
    remat: bool = False,
    act_sharding=None,
    unroll: bool = False,
    ep=None,
):
    """Returns (logits, new_caches).

    Train / prefill: caches=None; new_caches hold full-length kv (prefill)
    suitable for subsequent decode.  Decode: pass caches + cache_pos.
    """
    dt = jnp.dtype(cfg.dtype)
    if inputs_embeds is not None:
        x = inputs_embeds.astype(dt)
        if cfg.frontend:
            x = x @ params["frontend_proj"].astype(dt)
    else:
        x = params["embed"].astype(dt)[tokens]
    n_prefix = 0
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(dt)
        if cfg.frontend:
            pe = pe @ params["frontend_proj"].astype(dt)
        x = jnp.concatenate([pe, x], axis=1)
        n_prefix = prefix_embeds.shape[1]

    b, s, _ = x.shape
    if cache_pos is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    else:
        positions = cache_pos + jnp.arange(s, dtype=jnp.int32)[None, :]
    pos_b = jnp.broadcast_to(positions, (b, s))

    new_caches: dict = {}
    if cfg.family == "ssm":
        stack = params["mamba_layers"]
        cin = caches["mamba"] if caches else _none_like_stack(cfg.n_layers)
        x, nc = _scan_mamba_stack(stack, x, cfg, cin, remat, act_sharding, unroll)
        new_caches["mamba"] = nc
    elif cfg.hybrid_period:
        x, new_caches = _hybrid_forward(
            params, cfg, x, pos_b, caches, cache_pos, remat, act_sharding, unroll
        )
    elif cfg.moe:
        nd = cfg.moe.first_dense
        if nd:
            cin = caches["dense"] if caches else _none_like_stack(nd)
            x, ncd = _scan_attn_stack(
                params["dense_layers"], x, cfg, pos_b, cin, cache_pos, n_prefix, remat,
                act_sharding, unroll, ep,
            )
            new_caches["dense"] = ncd
        cin = caches["moe"] if caches else _none_like_stack(cfg.n_layers - nd)
        x, ncm = _scan_attn_stack(
            params["moe_layers"], x, cfg, pos_b, cin, cache_pos, n_prefix, remat,
            act_sharding, unroll, ep,
        )
        new_caches["moe"] = ncm
    else:
        cin = caches["attn"] if caches else _none_like_stack(cfg.n_layers)
        x, nc = _scan_attn_stack(
            params["layers"], x, cfg, pos_b, cin, cache_pos, n_prefix, remat,
            act_sharding, unroll,
        )
        new_caches["attn"] = nc

    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ head.astype(dt)
    return logits, new_caches


def _none_like_stack(n: int):
    return None


def _hybrid_forward(params, cfg, x, pos_b, caches, cache_pos, remat, act_sharding=None, unroll=False):
    """zamba2-style: groups of (period-1) mamba layers with a (shared)
    attention block between groups.  Mamba sub-stacks are scanned per group;
    the attention block is applied n_groups times with shared weights."""
    p = cfg.hybrid_period
    n_groups = cfg.n_layers // p
    per_group = p - 1
    mamba_stack = params["mamba_layers"]  # (n_groups*per_group + rem, ...)
    new_m_caches = []
    new_a_caches = []
    for gidx in range(n_groups):
        lo = gidx * per_group
        sub = jax.tree.map(lambda a: a[lo : lo + per_group], mamba_stack)
        cin = (
            jax.tree.map(lambda a: a[lo : lo + per_group], caches["mamba"])
            if caches
            else None
        )
        x, nmc = _scan_mamba_stack(sub, x, cfg, cin, remat, act_sharding, unroll)
        new_m_caches.append(nmc)
        ap = params["attn_shared"] if cfg.shared_attn else jax.tree.map(
            lambda a: a[gidx], params["attn_layers"]
        )
        ac = jax.tree.map(lambda a: a[gidx], caches["attn"]) if caches else None
        x, nac = _attn_block(ap, x, cfg, pos_b, ac, cache_pos, 0)
        new_a_caches.append(nac)
    # trailing mamba layers (n_layers % p, plus the per-group remainder)
    used = n_groups * per_group
    total_m = cfg.n_layers - n_groups
    if total_m > used:
        sub = jax.tree.map(lambda a: a[used:], mamba_stack)
        cin = jax.tree.map(lambda a: a[used:], caches["mamba"]) if caches else None
        x, nmc = _scan_mamba_stack(sub, x, cfg, cin, remat, act_sharding, unroll)
        new_m_caches.append(nmc)
    new_caches = {
        "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_m_caches),
        "attn": jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_a_caches),
    }
    return x, new_caches


# ---------------------------------------------------------------------------
# Cache initialization (for decode dry-runs and serving)
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """Allocate decode caches (zeros) for a given batch/context length."""
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)

    def attn_cache(n):
        if cfg.attn_type == "mla":
            return {
                "c_kv": jnp.zeros((n, batch, max_seq, cfg.kv_lora_rank), dt),
                "k_pe": jnp.zeros((n, batch, max_seq, cfg.rope_dim), dt),
            }
        return {
            "k": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((n, batch, max_seq, cfg.n_kv_heads, hd), dt),
        }

    def mamba_cache(n):
        base = SSM.init_ssm_cache(cfg, batch)
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), base)

    if cfg.family == "ssm":
        return {"mamba": mamba_cache(cfg.n_layers)}
    if cfg.hybrid_period:
        n_attn = cfg.n_layers // cfg.hybrid_period
        return {"mamba": mamba_cache(cfg.n_layers - n_attn), "attn": attn_cache(n_attn)}
    if cfg.moe:
        nd = cfg.moe.first_dense
        out = {"moe": attn_cache(cfg.n_layers - nd)}
        if nd:
            out["dense"] = attn_cache(nd)
        return out
    return {"attn": attn_cache(cfg.n_layers)}
