"""Mixture-of-Experts with token-choice top-k routing and capacity-based
dispatch.

Dispatch is scatter/gather into dense (E, C, d) buffers followed by a
batched expert einsum — the standard TPU-native formulation: the expert
matmul is block-diagonal on the MXU, shards cleanly along the expert axis
(EP on the 'model' mesh axis), and its FLOPs are proportional to
tokens * top_k * capacity_factor (so the roofline "useful compute" ratio
stays honest, unlike dense one-hot dispatch which burns tokens * E).
Over-capacity tokens are dropped (standard practice; the residual path
carries them).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig

from .layers import _dense_init, init_mlp, mlp

Params = dict[str, Any]


def init_moe(key, cfg: ModelConfig) -> Params:
    mo = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 3 + mo.n_shared)
    mult = ("wi", "wg", "wo") if cfg.mlp_type in ("swiglu", "geglu") else ("wi", "wo")

    def expert_weights(k):
        sub = jax.random.split(k, len(mult))
        out = {}
        for name, kk in zip(mult, sub):
            if name == "wo":
                out[name] = _dense_init(kk, (mo.n_experts, mo.d_expert, d))
            else:
                out[name] = _dense_init(kk, (mo.n_experts, d, mo.d_expert))
        return out

    p: Params = {
        "router": _dense_init(ks[0], (d, mo.n_experts), scale=0.02),
        "experts": expert_weights(ks[1]),
    }
    if mo.n_shared:
        import dataclasses

        shared_cfg = dataclasses.replace(cfg, d_ff=mo.d_expert * mo.n_shared)
        p["shared"] = init_mlp(ks[2], shared_cfg, d_ff=mo.d_expert * mo.n_shared)
    return p


def _capacity(n_tokens: int, mo: MoEConfig) -> int:
    c = int(n_tokens * mo.top_k * mo.capacity_factor / mo.n_experts)
    return max(8, min(n_tokens, (c + 7) // 8 * 8))


import dataclasses


@dataclasses.dataclass(frozen=True)
class EPContext:
    """Expert-parallel execution context (threaded from the launcher).

    batch_axes shard the token batch; model_axis shards experts AND the
    sequence (sequence-parallel token split).  hash/eq by axis names so it
    can ride through jax.checkpoint static args; the mesh is taken from the
    ambient jax.set_mesh context at trace time.
    """

    batch_axes: tuple  # e.g. ("pod", "data")
    model_axis: str = "model"

    def all_axes(self):
        return tuple(self.batch_axes) + (self.model_axis,)


def moe_block_ep(params: Params, x: jax.Array, cfg: ModelConfig, ep: EPContext) -> jax.Array:
    """Expert-parallel MoE via shard_map + all_to_all (the distributed-
    optimization fix measured in EXPERIMENTS.md §Perf).

    Why: under plain pjit, capacity dispatch is a data-dependent scatter;
    SPMD cannot shard it and replicates the (E, C, d) expert compute on
    every device (measured 150x useful flops).  Explicit EP:

      tokens sharded (batch -> data axes, seq -> model axis);
      local dispatch into (E, C_loc, d)  [per-device scatter, no SPMD];
      all_to_all over `model`: experts E/M per rank x (M*C_loc) tokens;
      batched expert matmul (sharded over BOTH data and model);
      reverse all_to_all; local combine.

    a2a bytes/device/layer ~ 2 * E * C_loc * d — orders below the
    replicated compute it replaces.
    """
    mesh = jax.sharding.get_abstract_mesh()
    mo = cfg.moe
    P = jax.sharding.PartitionSpec
    bspec = ep.batch_axes if len(ep.batch_axes) > 1 else ep.batch_axes[0]

    def local(w_router, w_experts, w_shared, xl):
        b_loc, s_loc, d = xl.shape
        t_loc = b_loc * s_loc
        xt = xl.reshape(t_loc, d)
        dt = xl.dtype
        logits = (xt @ w_router.astype(dt)).astype(jnp.float32)
        gates = jax.nn.softmax(logits, axis=-1)
        top_g, top_e = jax.lax.top_k(gates, mo.top_k)
        top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)
        cap = _capacity(t_loc, mo)
        flat_e = top_e.reshape(-1)
        one_hot = jax.nn.one_hot(flat_e, mo.n_experts, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(one_hot, axis=0) - 1
        my_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
        keep = my_pos < cap
        tok_idx = jnp.repeat(jnp.arange(t_loc), mo.top_k)
        safe_e = jnp.where(keep, flat_e, 0)
        safe_p = jnp.where(keep, my_pos, cap - 1)
        buf = jnp.zeros((mo.n_experts, cap, d), dt)
        buf = buf.at[safe_e, safe_p].add(jnp.where(keep[:, None], xt[tok_idx], 0))

        # exchange: (E, C, d) -> (E/M, M*C, d); experts live on model ranks
        buf = jax.lax.all_to_all(
            buf, ep.model_axis, split_axis=0, concat_axis=1, tiled=True
        )
        we = {k: v for k, v in w_experts.items()}  # (E/M, d, f) local slices
        if cfg.mlp_type in ("swiglu", "geglu"):
            act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
            h = act(jnp.einsum("ecd,edf->ecf", buf, we["wg"].astype(dt))) * jnp.einsum(
                "ecd,edf->ecf", buf, we["wi"].astype(dt)
            )
        else:
            h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", buf, we["wi"].astype(dt))))
        out_buf = jnp.einsum("ecf,efd->ecd", h, we["wo"].astype(dt))
        out_buf = jax.lax.all_to_all(
            out_buf, ep.model_axis, split_axis=1, concat_axis=0, tiled=True
        )  # back to (E, C, d)

        picked = out_buf[safe_e, safe_p]
        gate_flat = top_g.reshape(-1).astype(dt)
        contrib = picked * jnp.where(keep, gate_flat, 0.0)[:, None]
        y = jax.ops.segment_sum(contrib, tok_idx, num_segments=t_loc)
        if mo.n_shared:
            y = y + mlp(w_shared, xt, cfg)
        return y.reshape(b_loc, s_loc, d)

    shared = params.get("shared", {})
    fn = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(),  # router replicated (auto-gathered from FSDP storage)
            jax.tree.map(lambda _: P(ep.model_axis), params["experts"]),
            jax.tree.map(lambda _: P(), shared),
            P(bspec, ep.model_axis, None),
        ),
        out_specs=P(bspec, ep.model_axis, None),
        check_vma=False,
    )
    return fn(params["router"], params["experts"], shared, x)


def moe_block(params: Params, x: jax.Array, cfg: ModelConfig, ep: EPContext | None = None) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    if ep is not None:
        return moe_block_ep(params, x, cfg, ep)
    mo = cfg.moe
    b, s, d = x.shape
    n_tok = b * s
    xt = x.reshape(n_tok, d)
    dt = x.dtype

    logits = (xt @ params["router"].astype(dt)).astype(jnp.float32)  # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, mo.top_k)  # (T, K)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)  # renorm

    cap = _capacity(n_tok, mo)
    # position of each (token, k) assignment within its expert's buffer
    flat_e = top_e.reshape(-1)  # (T*K,)
    one_hot = jax.nn.one_hot(flat_e, mo.n_experts, dtype=jnp.int32)  # (T*K, E)
    pos_in_e = jnp.cumsum(one_hot, axis=0) - 1  # running count per expert
    my_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]  # (T*K,)
    keep = my_pos < cap
    # scatter tokens into (E, C, d)
    tok_idx = jnp.repeat(jnp.arange(n_tok), mo.top_k)
    safe_e = jnp.where(keep, flat_e, 0)
    safe_p = jnp.where(keep, my_pos, cap - 1)
    buf = jnp.zeros((mo.n_experts, cap, d), dt)
    buf = buf.at[safe_e, safe_p].add(jnp.where(keep[:, None], xt[tok_idx], 0))

    # batched expert MLP: (E, C, d) x (E, d, f) -> (E, C, f)
    w = params["experts"]
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", buf, w["wg"].astype(dt))) * jnp.einsum(
            "ecd,edf->ecf", buf, w["wi"].astype(dt)
        )
    else:
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", buf, w["wi"].astype(dt))))
    out_buf = jnp.einsum("ecf,efd->ecd", h, w["wo"].astype(dt))  # (E, C, d)

    # gather back and combine with gate weights
    picked = out_buf[safe_e, safe_p]  # (T*K, d)
    gate_flat = top_g.reshape(-1).astype(dt)
    contrib = picked * jnp.where(keep, gate_flat, 0.0)[:, None]
    y = jax.ops.segment_sum(contrib, tok_idx, num_segments=n_tok)

    if mo.n_shared:
        y = y + mlp(params["shared"], xt, cfg)
    return y.reshape(b, s, d)


def aux_load_balance_loss(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (mean over layers is added
    to the training objective by the caller)."""
    mo = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, -1)
    _, top_e = jax.lax.top_k(gates, mo.top_k)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e, mo.n_experts, dtype=jnp.float32).sum(1), axis=0
    ) / mo.top_k
    frac_probs = jnp.mean(gates, axis=0)
    return mo.n_experts * jnp.sum(frac_tokens * frac_probs)
