"""Mamba2 (SSD — state-space duality) blocks.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060 §6):
quadratic attention-like compute *within* chunks (MXU-friendly batched
matmuls) and a linear recurrence *across* chunks (lax.scan over nc chunks).
Decode is the O(1) recurrent update on the (H, P, N) state.

This is precisely the hardware adaptation the SSD paper advocates: the
chunk size trades VMEM working set against recurrence length; on TPU we
keep chunks at 128-256 so the intra-chunk einsums land on the MXU at
hardware-aligned sizes.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import _dense_init, rms_norm

Params = dict[str, Any]


def init_mamba2(key, cfg: ModelConfig) -> Params:
    sm = cfg.ssm
    d = cfg.d_model
    di = sm.d_inner(d)
    nh = sm.n_ssm_heads(d)
    g, n = sm.n_groups, sm.d_state
    ks = jax.random.split(key, 5)
    # in_proj emits [z (di), x (di), B (g*n), C (g*n), dt (nh)]
    d_in_proj = 2 * di + 2 * g * n + nh
    conv_dim = di + 2 * g * n
    return {
        "in_proj": _dense_init(ks[0], (d, d_in_proj)),
        "conv_w": _dense_init(ks[1], (sm.conv_kernel, conv_dim), scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[4], (di, d)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv1d. x: (B, S, C); w: (K, C).

    Returns (y, new_state) where state holds the trailing K-1 inputs for
    streaming decode.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype) for i in range(k))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(k - 1) :, :] if k > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(y), new_state


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD forward.

    x: (b, L, H, P); dt: (b, L, H); A: (H,) (negative); B, C: (b, L, G, N).
    Returns y: (b, L, H, P), final_state: (b, H, P, N).
    """
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert L % chunk == 0, (L, chunk)
    nc, Q = L // chunk, chunk
    rep = H // G

    xr = x.reshape(b, nc, Q, H, P)
    dtr = dt.reshape(b, nc, Q, H)
    Br = B.reshape(b, nc, Q, G, N)
    Cr = C.reshape(b, nc, Q, G, N)

    dA = dtr * A[None, None, None, :]  # (b, nc, Q, H) log-decay increments
    cum = jnp.cumsum(dA, axis=2)  # inclusive cumulative log decay

    # intra-chunk (the "duality" quadratic form)
    # decay L[i, j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,Q_i,Q_j,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcign,bcjgn->bcijg", Cr, Br)  # (b,nc,Qi,Qj,G)
    scores = jnp.repeat(scores, rep, axis=-1)  # (b,nc,Qi,Qj,H)
    w = scores * Lmat * dtr[:, :, None, :, :]  # weight for x_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xr)

    # inter-chunk recurrence over states
    seg_end = cum[:, :, -1, :]  # (b, nc, H) total log decay per chunk
    # state contribution of chunk c: sum_j exp(seg_end - cum_j) dt_j B_j x_j
    decay_to_end = jnp.exp(seg_end[:, :, None, :] - cum)  # (b,nc,Q,H)
    Br_h = jnp.repeat(Br, rep, axis=3)  # (b,nc,Q,H,N)
    Cr_h = jnp.repeat(Cr, rep, axis=3)
    contrib = jnp.einsum(
        "bcqhn,bcqhp->bchpn", Br_h * (dtr * decay_to_end)[..., None], xr
    )  # (b,nc,H,P,N)

    def scan_fn(state, inp):
        contrib_c, seg_end_c = inp  # (b,H,P,N), (b,H)
        new_state = state * jnp.exp(seg_end_c)[:, :, None, None] + contrib_c
        return new_state, state  # emit state *entering* the chunk

    init = jnp.zeros((b, H, P, N), x.dtype)
    final_state, states_in = jax.lax.scan(
        scan_fn,
        init,
        (jnp.moveaxis(contrib, 1, 0), jnp.moveaxis(seg_end, 1, 0)),
    )
    states_in = jnp.moveaxis(states_in, 0, 1)  # (b, nc, H, P, N)

    # y_inter[i] = exp(cum_i) * C_i . S_in
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Cr_h * jnp.exp(cum)[..., None], states_in)
    y = (y_intra + y_inter).reshape(b, L, H, P)
    return y, final_state


def mamba2_block(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    cache: Optional[dict] = None,
):
    """x: (B, S, d).  cache = {'conv': (B,K-1,C), 'ssm': (B,H,P,N)} for
    streaming decode (S small, typically 1); None for train/prefill."""
    sm = cfg.ssm
    b, s, d = x.shape
    di = sm.d_inner(d)
    nh = sm.n_ssm_heads(d)
    g, n, p_dim = sm.n_groups, sm.d_state, sm.head_dim
    dt_ = x.dtype

    zxbcdt = x @ params["in_proj"].astype(dt_)
    z, xin, Bc, Cc, dt_raw = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1
    )
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv_state = _causal_conv(conv_in, params["conv_w"], params["conv_b"], conv_state)
    xin, Bc, Cc = jnp.split(conv_out, [di, di + g * n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (b,s,nh)
    A = -jnp.exp(params["A_log"])  # (nh,) negative
    xh = xin.reshape(b, s, nh, p_dim)
    Bh = Bc.reshape(b, s, g, n).astype(jnp.float32)
    Ch = Cc.reshape(b, s, g, n).astype(jnp.float32)

    if cache is None:
        # pad sequence to a chunk multiple
        pad = (-s) % sm.chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, ssm_state = _ssd_chunked(
            xh.astype(jnp.float32), dt, A, Bh, Ch, sm.chunk
        )
        y = y[:, :s]
    else:
        # recurrent single-step (or short-segment) update
        rep = nh // g

        def step(state, inp):
            x_t, dt_t, B_t, C_t = inp  # (b,nh,p), (b,nh), (b,g,n), (b,g,n)
            Bh_t = jnp.repeat(B_t, rep, axis=1)  # (b,nh,n)
            Ch_t = jnp.repeat(C_t, rep, axis=1)
            decay = jnp.exp(dt_t * A[None, :])  # (b,nh)
            new_state = state * decay[..., None, None] + jnp.einsum(
                "bh,bhn,bhp->bhpn", dt_t, Bh_t, x_t
            )
            y_t = jnp.einsum("bhpn,bhn->bhp", new_state, Ch_t)
            return new_state, y_t

        xs = (
            jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
            jnp.moveaxis(dt, 1, 0),
            jnp.moveaxis(Bh, 1, 0),
            jnp.moveaxis(Ch, 1, 0),
        )
        ssm_state, ys = jax.lax.scan(step, cache["ssm"].astype(jnp.float32), xs)
        y = jnp.moveaxis(ys, 0, 1)  # (b,s,nh,p)

    y = y + xh.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(b, s, di).astype(dt_)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)
    new_cache = {"conv": new_conv_state, "ssm": ssm_state.astype(jnp.float32)}
    return out, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int) -> dict:
    sm = cfg.ssm
    d = cfg.d_model
    di = sm.d_inner(d)
    nh = sm.n_ssm_heads(d)
    conv_dim = di + 2 * sm.n_groups * sm.d_state
    return {
        "conv": jnp.zeros((batch, sm.conv_kernel - 1, conv_dim), jnp.float32),
        "ssm": jnp.zeros((batch, nh, sm.head_dim, sm.d_state), jnp.float32),
    }
