"""Time-series monitoring: a fixed-size ring of registry snapshots with
delta-derived series (DESIGN.md §Observability, continuous monitoring).

PR 8's registry answers "how much since process start"; this module
answers "how much *lately*, and which way is it trending" — the form
every continuous consumer (SLO burn rates in slo.py, health watchdogs in
health.py, admission control and shard pruning on the ROADMAP) actually
needs.  The design is deliberately Prometheus-shaped:

* **Snapshots, not streams.**  ``TimeSeriesRing.snapshot`` copies the
  registry's current series values (host dicts — no device access, no
  sync) into a bounded ``deque``.  Everything derived — windowed rates,
  bucket-delta quantiles — is computed lazily from snapshot *pairs*, so
  the steady-state cost of the ring is one dict walk per snapshot and
  zero per recorded metric.
* **Counter-reset semantics.**  A counter (or histogram bucket) whose
  value went *down* between snapshots was reset (``registry.reset()``,
  tests, bench isolation); the delta is then the new value, exactly like
  Prometheus ``rate()``.  A series absent from the older snapshot was
  born in the window and contributes its full value.  Deltas are never
  negative.
* **Quantiles from bucket deltas.**  ``quantile_from_counts`` linearly
  interpolates inside the first bucket whose cumulative *windowed* count
  reaches the rank (Prometheus ``histogram_quantile``); the +Inf
  overflow slot clamps to the highest finite edge.  p50/p99 over a
  window therefore reflect only the observations *in* that window, not
  the process lifetime.

``to_json()`` emits the ``repro.obs.timeseries/v1`` schema — one point
series per (metric, label-set, derivation) — validated by
:func:`validate_timeseries_export` and the CI step
``python -m repro.obs.validate`` (which dispatches on the ``schema``
field), landing as TIMESERIES.json next to METRICS.json in bench runs.

Timestamps come from the injected clock (``time.monotonic`` by default;
tests pass a fake).  Nothing here runs unless something ticks a
snapshot, so the off-by-default contract of repro.obs is unchanged.
"""
from __future__ import annotations

import bisect
import math
import time
from collections import deque
from typing import Callable, Iterable, Optional

from . import registry as R

SCHEMA = "repro.obs.timeseries/v1"

#: derivations the exporter emits per metric kind
_DERIVS = ("rate", "value", "p50", "p99")


def quantile_from_counts(buckets, counts, q: float) -> Optional[float]:
    """Prometheus-style quantile over per-bucket counts (len(buckets)+1,
    +Inf overflow last).  Linear interpolation within the winning bucket
    (lower edge 0 for the first); the overflow slot clamps to the highest
    finite edge.  None when the counts are empty."""
    total = sum(counts)
    if total <= 0:
        return None
    rank = q * total
    cum = 0.0
    for i, c in enumerate(counts[:-1]):
        prev = cum
        cum += c
        if cum >= rank and c > 0:
            lo = buckets[i - 1] if i > 0 else 0.0
            hi = buckets[i]
            return lo + (hi - lo) * (rank - prev) / c
    return float(buckets[-1])


def _delta_scalar(new: float, old: Optional[float]) -> float:
    """Counter delta with reset semantics: missing-before or decreased
    means the series was (re)born in the window — delta is the new value."""
    if old is None or new < old:
        return float(new)
    return float(new - old)


def _delta_counts(new: list, old: Optional[list]) -> list:
    if old is None or len(old) != len(new) or any(n < o for n, o in zip(new, old)):
        return [int(c) for c in new]
    return [int(n - o) for n, o in zip(new, old)]


class Snapshot:
    """One point-in-time copy of a registry's series values."""

    __slots__ = ("ts", "counters", "gauges", "hists", "labelnames", "buckets")

    def __init__(self, ts: float):
        self.ts = float(ts)
        self.counters: dict[str, dict[tuple, float]] = {}
        self.gauges: dict[str, dict[tuple, float]] = {}
        self.hists: dict[str, dict[tuple, tuple[list, float, int]]] = {}
        self.labelnames: dict[str, tuple[str, ...]] = {}
        self.buckets: dict[str, tuple[float, ...]] = {}

    @classmethod
    def of(cls, reg: R.MetricsRegistry, ts: float) -> "Snapshot":
        snap = cls(ts)
        for m in reg.all_metrics():
            snap.labelnames[m.name] = m.labelnames
            if m.kind == "counter":
                snap.counters[m.name] = {k: float(v) for k, v in m._series.items()}
            elif m.kind == "gauge":
                snap.gauges[m.name] = {k: float(v) for k, v in m._series.items()}
            elif m.kind == "histogram":
                snap.buckets[m.name] = m.buckets
                snap.hists[m.name] = {
                    k: (list(s[0]), float(s[1]), int(s[2]))
                    for k, s in m._series.items()
                }
        return snap


def _match(key: tuple, lnames: tuple, labels: Optional[dict]) -> bool:
    """Does a series key satisfy a partial label filter?  None matches
    everything (aggregate across the family)."""
    if not labels:
        return True
    got = dict(zip(lnames, key))
    return all(got.get(k) == str(v) for k, v in labels.items())


class TimeSeriesRing:
    """Bounded ring of :class:`Snapshot`\\ s + the delta-derived reads."""

    def __init__(self, capacity: int = 128):
        if capacity < 2:
            raise ValueError(f"capacity must be >= 2, got {capacity}")
        self.capacity = int(capacity)
        self._snaps: deque[Snapshot] = deque(maxlen=self.capacity)

    def __len__(self) -> int:
        return len(self._snaps)

    def snapshot(self, reg: Optional[R.MetricsRegistry] = None, ts: Optional[float] = None) -> Snapshot:
        snap = Snapshot.of(reg or R.registry(), time.monotonic() if ts is None else ts)
        self._snaps.append(snap)
        return snap

    def clear(self) -> None:
        self._snaps.clear()

    @property
    def t_first(self) -> Optional[float]:
        return self._snaps[0].ts if self._snaps else None

    @property
    def t_last(self) -> Optional[float]:
        return self._snaps[-1].ts if self._snaps else None

    def latest(self) -> Optional[Snapshot]:
        return self._snaps[-1] if self._snaps else None

    def window(self, window_s: float, now: Optional[float] = None) -> Optional[tuple[Snapshot, Snapshot]]:
        """(older, newer) snapshot pair spanning ~``window_s`` back from
        ``now``.  The older end is the newest snapshot at or before
        ``now - window_s`` — or the oldest held, when the ring does not
        reach that far (partial window; ``rate`` divides by the actual
        span).  None with fewer than two snapshots."""
        if len(self._snaps) < 2:
            return None
        newest = self._snaps[-1]
        now = newest.ts if now is None else now
        cutoff = now - float(window_s)
        older = self._snaps[0]
        for s in self._snaps:
            if s.ts <= cutoff:
                older = s
            else:
                break
        if older.ts >= newest.ts:
            return None
        return older, newest

    # -- delta-derived reads -----------------------------------------------

    def delta(
        self, name: str, *, window_s: float, now: Optional[float] = None,
        labels: Optional[dict] = None,
    ) -> Optional[float]:
        """Windowed counter increase summed over matching series (reset-
        aware).  None without a usable window or when the metric never
        appeared."""
        pair = self.window(window_s, now)
        if pair is None:
            return None
        old, new = pair
        series = new.counters.get(name)
        if series is None:
            return None
        lnames = new.labelnames.get(name, ())
        olds = old.counters.get(name, {})
        return sum(
            _delta_scalar(v, olds.get(k))
            for k, v in series.items()
            if _match(k, lnames, labels)
        )

    def rate(
        self, name: str, *, window_s: float, now: Optional[float] = None,
        labels: Optional[dict] = None,
    ) -> Optional[float]:
        """Windowed per-second rate (delta over the pair's actual span)."""
        pair = self.window(window_s, now)
        if pair is None:
            return None
        d = self.delta(name, window_s=window_s, now=now, labels=labels)
        if d is None:
            return None
        span = pair[1].ts - pair[0].ts
        return d / span if span > 0 else None

    def hist_window(
        self, name: str, *, window_s: float, now: Optional[float] = None,
        labels: Optional[dict] = None,
    ) -> Optional[tuple[tuple[float, ...], list, float, int]]:
        """(buckets, windowed counts, windowed sum, windowed count) for a
        histogram family, summed over matching series."""
        pair = self.window(window_s, now)
        if pair is None:
            return None
        old, new = pair
        series = new.hists.get(name)
        if series is None:
            return None
        buckets = new.buckets[name]
        lnames = new.labelnames.get(name, ())
        olds = old.hists.get(name, {})
        counts = [0] * (len(buckets) + 1)
        total_sum, total_n = 0.0, 0
        for k, (c, s, n) in series.items():
            if not _match(k, lnames, labels):
                continue
            oc = olds.get(k)
            dc = _delta_counts(c, oc[0] if oc else None)
            counts = [a + b for a, b in zip(counts, dc)]
            total_sum += _delta_scalar(s, oc[1] if oc else None)
            total_n += int(_delta_scalar(n, oc[2] if oc else None))
        return buckets, counts, total_sum, total_n

    def quantile(
        self, name: str, q: float, *, window_s: float,
        now: Optional[float] = None, labels: Optional[dict] = None,
    ) -> Optional[float]:
        """Windowed quantile from histogram bucket deltas."""
        hw = self.hist_window(name, window_s=window_s, now=now, labels=labels)
        if hw is None:
            return None
        buckets, counts, _, _ = hw
        return quantile_from_counts(buckets, counts, q)

    # -- export -------------------------------------------------------------

    def to_json(self) -> dict:
        """The ``repro.obs.timeseries/v1`` export: per-(metric, labels)
        derived point series over every adjacent snapshot pair — counter
        ``:rate`` points, gauge ``:value`` points, histogram ``:p50`` /
        ``:p99`` points.  Empty-but-valid with fewer than two snapshots."""
        series: dict[tuple[str, tuple], list] = {}
        lnames_of: dict[str, tuple] = {}

        def add(name: str, key: tuple, t: float, v: float) -> None:
            series.setdefault((name, key), []).append([t, v])

        snaps = list(self._snaps)
        for old, new in zip(snaps, snaps[1:]):
            span = new.ts - old.ts
            for name, fam in new.counters.items():
                lnames_of[name + ":rate"] = new.labelnames.get(name, ())
                for k, v in fam.items():
                    d = _delta_scalar(v, old.counters.get(name, {}).get(k))
                    if span > 0:
                        add(name + ":rate", k, new.ts, d / span)
            for name, fam in new.gauges.items():
                lnames_of[name + ":value"] = new.labelnames.get(name, ())
                for k, v in fam.items():
                    add(name + ":value", k, new.ts, v)
            for name, fam in new.hists.items():
                buckets = new.buckets[name]
                for suffix in (":p50", ":p99"):
                    lnames_of[name + suffix] = new.labelnames.get(name, ())
                olds = old.hists.get(name, {})
                for k, (c, _, _) in fam.items():
                    oc = olds.get(k)
                    dc = _delta_counts(c, oc[0] if oc else None)
                    for suffix, q in ((":p50", 0.5), (":p99", 0.99)):
                        qv = quantile_from_counts(buckets, dc, q)
                        if qv is not None:
                            add(name + suffix, k, new.ts, qv)
        return {
            "schema": SCHEMA,
            "capacity": self.capacity,
            "n_snapshots": len(self._snaps),
            "t_first": self.t_first,
            "t_last": self.t_last,
            "series": [
                {
                    "name": name,
                    "labels": dict(zip(lnames_of.get(name, ()), key)),
                    "points": pts,
                }
                for (name, key), pts in sorted(series.items())
            ],
        }


class Snapshotter:
    """Cadenced snapshots: ``maybe_snapshot`` ticks the ring at most once
    per ``interval_s`` (0 = every call).  The serving layer calls this at
    its existing scheduling-round boundary — never from traced code."""

    def __init__(
        self,
        reg: Optional[R.MetricsRegistry] = None,
        *,
        capacity: int = 128,
        interval_s: float = 1.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        self._reg = reg
        self.interval_s = float(interval_s)
        self.clock = clock
        self.ring = TimeSeriesRing(capacity)
        self._t_prev: Optional[float] = None

    @property
    def reg(self) -> R.MetricsRegistry:
        return self._reg if self._reg is not None else R.registry()

    def maybe_snapshot(self, now: Optional[float] = None) -> bool:
        now = self.clock() if now is None else now
        if self._t_prev is not None and now - self._t_prev < self.interval_s:
            return False
        self.ring.snapshot(self.reg, now)
        self._t_prev = now
        return True


# -- export validation --------------------------------------------------------

_DERIV_SUFFIXES = tuple(f":{d}" for d in _DERIVS)


def validate_timeseries_export(payload) -> list[str]:
    """Schema-validate a :meth:`TimeSeriesRing.to_json` export; returns
    problems (empty == valid).  Mirrors ``registry.validate_export``:
    legal derived names, string label maps, per-series points with
    non-decreasing timestamps and finite values."""
    errs: list[str] = []
    if not isinstance(payload, dict):
        return [f"top level is {type(payload).__name__}, expected object"]
    if payload.get("schema") != SCHEMA:
        errs.append(f"schema is {payload.get('schema')!r}, expected {SCHEMA!r}")
    cap = payload.get("capacity")
    if not isinstance(cap, int) or cap < 2:
        errs.append(f"capacity {cap!r} is not an int >= 2")
    n = payload.get("n_snapshots")
    if not isinstance(n, int) or n < 0 or (isinstance(cap, int) and n > cap):
        errs.append(f"n_snapshots {n!r} outside [0, capacity]")
    for tk in ("t_first", "t_last"):
        tv = payload.get(tk)
        if tv is not None and (not isinstance(tv, (int, float)) or not math.isfinite(tv)):
            errs.append(f"{tk} is non-finite")
    series = payload.get("series")
    if not isinstance(series, list):
        return errs + ["series is not a list"]
    for i, s in enumerate(series):
        if not isinstance(s, dict):
            errs.append(f"series[{i}] is not an object")
            continue
        name = s.get("name", f"<series[{i}]>")
        base, _, deriv = str(name).rpartition(":")
        if (
            not isinstance(name, str)
            or not base
            or deriv not in _DERIVS
            or not R._NAME_RE.match(base)
        ):
            errs.append(f"series[{i}]: invalid derived name {name!r}")
        labels = s.get("labels")
        if not isinstance(labels, dict) or any(
            not isinstance(k, str) or not isinstance(v, str) for k, v in labels.items()
        ):
            errs.append(f"{name}: malformed labels {labels!r}")
        points = s.get("points")
        if not isinstance(points, list) or not points:
            errs.append(f"{name}: points must be a non-empty list")
            continue
        prev_t = None
        for j, p in enumerate(points):
            if (
                not isinstance(p, list)
                or len(p) != 2
                or not all(isinstance(x, (int, float)) and math.isfinite(x) for x in p)
            ):
                errs.append(f"{name}: point {j} malformed ({p!r})")
                continue
            if prev_t is not None and p[0] < prev_t:
                errs.append(f"{name}: point {j} timestamp decreases")
            prev_t = p[0]
    return errs
