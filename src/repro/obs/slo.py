"""Declarative SLOs with multi-window burn-rate evaluation
(DESIGN.md §Observability, continuous monitoring).

An :class:`SloSpec` says "over the long run, fraction ``objective`` of
observations must be good"; *burn rate* is how fast the error budget is
being spent right now::

    burn = bad_fraction / (1 - objective)

burn == 1 means spending budget exactly as fast as the objective allows;
burn == 10 exhausts a 30-day budget in 3 days.  Following the SRE
multi-window pattern, a spec alerts only when **every** configured
window is burning past its threshold — the long window proves the
problem is material, the short window proves it is *still happening*
(no alert for an incident that already ended).  Windows without data
(no observations in the delta) abstain rather than veto, so a burst
followed by silence still alerts on the windows that saw it.

Three objective kinds map onto what the registry actually holds:

* ``latency``  — good = histogram observation ≤ ``threshold``; the
  threshold must be (or is snapped to) a declared bucket edge, since
  good/bad classification comes from bucket-delta counts.
* ``recall``   — good = observation in a bucket whose edge ≥
  ``threshold`` (recall histograms are cumulative-``le`` like any other;
  an observation in the ``le=0.95`` bucket means recall ∈ (0.9, 0.95],
  counted good for a 0.9 threshold — a documented half-bucket optimism).
* ``ratio``    — bad = ``delta(metric)``, total = ``delta(total_metric)``
  over plain counters (e.g. write errors per request).

:func:`evaluate_slos` reads windows from a :class:`TimeSeriesRing`,
sets ``compass_slo_burn_rate{slo,window}`` / ``compass_slo_breach{slo}``
gauges, and emits an ``slo_burn`` event on each breach — all host-side,
nothing unless observability is enabled and something ticks the ring.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Optional

from . import events as E
from . import registry as R
from .timeseries import TimeSeriesRing

SLO_KINDS = ("latency", "recall", "ratio")

#: default burn thresholds, SRE-workbook shaped for a snapshot-cadence
#: ring: (window seconds, max burn rate) — the short window is the
#: "still happening" check, the long window the "material" check.
DEFAULT_WINDOWS = ((60.0, 14.4), (300.0, 6.0))


@dataclass(frozen=True)
class SloWindow:
    window_s: float
    max_burn: float


@dataclass(frozen=True)
class SloSpec:
    """One objective over one metric family.

    ``objective`` is the long-run good fraction (0.999 = three nines);
    ``threshold`` classifies histogram observations (latency/recall
    kinds); ``total_metric`` names the denominator counter (ratio kind).
    ``labels`` optionally restricts evaluation to matching series.
    """

    name: str
    kind: str
    objective: float
    metric: str
    threshold: Optional[float] = None
    total_metric: Optional[str] = None
    labels: Optional[dict] = None
    windows: tuple = field(
        default_factory=lambda: tuple(SloWindow(w, b) for w, b in DEFAULT_WINDOWS)
    )

    def __post_init__(self):
        if self.kind not in SLO_KINDS:
            raise ValueError(f"{self.name}: unknown SLO kind {self.kind!r}")
        if not 0.0 < self.objective < 1.0:
            raise ValueError(f"{self.name}: objective must be in (0, 1)")
        if self.kind in ("latency", "recall") and self.threshold is None:
            raise ValueError(f"{self.name}: {self.kind} SLO needs a threshold")
        if self.kind == "ratio" and not self.total_metric:
            raise ValueError(f"{self.name}: ratio SLO needs total_metric")
        if not self.windows:
            raise ValueError(f"{self.name}: at least one window required")

    def bad_fraction(
        self, ring: TimeSeriesRing, window_s: float, now: Optional[float] = None
    ) -> Optional[float]:
        """Fraction of windowed observations that violate the objective;
        None when the window holds no observations (abstain)."""
        if self.kind == "ratio":
            bad = ring.delta(self.metric, window_s=window_s, now=now, labels=self.labels)
            total = ring.delta(
                self.total_metric, window_s=window_s, now=now, labels=self.labels
            )
            if bad is None or not total:
                return None
            return min(1.0, bad / total)
        hw = ring.hist_window(
            self.metric, window_s=window_s, now=now, labels=self.labels
        )
        if hw is None:
            return None
        buckets, counts, _, total = hw
        if total <= 0:
            return None
        # threshold -> bucket boundary: good counts are the buckets at or
        # below the edge (latency) / at or above it (recall)
        cut = bisect.bisect_left(buckets, float(self.threshold))
        if self.kind == "latency":
            good = sum(counts[: cut + 1])
        else:
            good = sum(counts[cut:-1]) + counts[-1]
        return max(0.0, 1.0 - good / total)

    def burn_rates(
        self, ring: TimeSeriesRing, now: Optional[float] = None
    ) -> dict[float, Optional[float]]:
        """{window_s: burn rate or None-abstain} for every window."""
        budget = 1.0 - self.objective
        out = {}
        for w in self.windows:
            bf = self.bad_fraction(ring, w.window_s, now)
            out[w.window_s] = None if bf is None else bf / budget
        return out

    def evaluate(
        self, ring: TimeSeriesRing, now: Optional[float] = None
    ) -> tuple[bool, dict[float, Optional[float]]]:
        """(breaching?, per-window burns).  Breaching when every window
        *with data* exceeds its max_burn — and at least one has data."""
        burns = self.burn_rates(ring, now)
        informed = [
            (w, burns[w.window_s]) for w in self.windows if burns[w.window_s] is not None
        ]
        breaching = bool(informed) and all(b > w.max_burn for w, b in informed)
        return breaching, burns


def default_slos() -> tuple[SloSpec, ...]:
    """The serving-layer objectives the Monitor evaluates out of the box:
    p-latency on batch execution (250ms — a declared LATENCY_BUCKETS_S
    edge) and write-error availability against request volume."""
    return (
        SloSpec(
            name="serve_latency",
            kind="latency",
            objective=0.99,
            metric="compass_serve_exec_seconds",
            threshold=0.25,
        ),
        SloSpec(
            name="write_availability",
            kind="ratio",
            objective=0.999,
            metric="compass_write_errors_total",
            total_metric="compass_serve_requests_total",
        ),
    )


def tenant_slos(tenant: str, *, latency_threshold_s: float = 0.25) -> tuple[SloSpec, ...]:
    """Per-tenant objectives over the ``tenant``-labeled serving series
    the :class:`~repro.serving.tenancy.CollectionService` records: batch
    p-latency restricted to the tenant's micro-batches, and admission
    availability (shed fraction of offered load — shedding is typed and
    deliberate, but it still spends this tenant's error budget).

    Compose with the defaults per hot tenant::

        svc.enable_monitoring(slos=default_slos() + tenant_slos("hot"))
    """
    return (
        SloSpec(
            name=f"serve_latency:{tenant}",
            kind="latency",
            objective=0.99,
            metric="compass_serve_exec_seconds",
            threshold=latency_threshold_s,
            labels={"tenant": tenant},
        ),
        SloSpec(
            name=f"admission:{tenant}",
            kind="ratio",
            objective=0.999,
            metric="compass_shed_total",
            total_metric="compass_submitted_total",
            labels={"tenant": tenant},
        ),
    )


def evaluate_slos(
    specs,
    ring: TimeSeriesRing,
    *,
    now: Optional[float] = None,
    reg: Optional[R.MetricsRegistry] = None,
) -> dict[str, dict]:
    """Evaluate every spec; publish ``compass_slo_burn_rate{slo,window}``
    and ``compass_slo_breach{slo}`` gauges and emit one ``slo_burn``
    event per breaching spec.  Returns {name: {breaching, burns}}."""
    r = reg or R.registry()
    g_burn = r.gauge(
        "compass_slo_burn_rate", "error-budget burn rate per window", ("slo", "window")
    )
    g_breach = r.gauge(
        "compass_slo_breach", "1 when all informed windows burn past max", ("slo",)
    )
    out: dict[str, dict] = {}
    for spec in specs:
        breaching, burns = spec.evaluate(ring, now)
        for w_s, b in burns.items():
            if b is not None:
                g_burn.set(b, slo=spec.name, window=f"{w_s:g}s")
        g_breach.set(1.0 if breaching else 0.0, slo=spec.name)
        if breaching:
            E.emit(
                "slo_burn",
                slo=spec.name,
                slo_kind=spec.kind,
                objective=spec.objective,
                burns={f"{w:g}s": b for w, b in burns.items() if b is not None},
            )
        out[spec.name] = {"breaching": breaching, "burns": burns}
    return out
