"""Text dashboard over the observability surfaces.

  python -m repro.obs.report                      # the live global registry
  python -m repro.obs.report --from METRICS.json  # an exported registry
  python -m repro.obs.report --from TIMESERIES.json

One renderer, three sources: a live :class:`MetricsRegistry`, a
``repro.obs.metrics/v1`` export (reconstructed via
``MetricsRegistry.from_json`` so file and live render identically), or a
``repro.obs.timeseries/v1`` export (derived series with min/last/max and
a unicode sparkline).  Histogram rows show count/mean plus p50/p99 read
from the cumulative bucket counts — the same
:func:`~repro.obs.timeseries.quantile_from_counts` math the monitoring
layer uses, so the dashboard and the watchdogs can never disagree about
what a quantile is.

Everything here is read-only formatting; it is safe to run against a
registry being written by a live service.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

from . import registry as R
from . import timeseries as TS

_SPARK = "▁▂▃▄▅▆▇█"


def _fmt(v: Optional[float]) -> str:
    if v is None:
        return "-"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.4g}"


def _labstr(labels: dict) -> str:
    if not any(v for v in labels.values()):
        return ""
    body = ",".join(f"{k}={v}" for k, v in sorted(labels.items()) if v)
    return "{" + body + "}"


def sparkline(values: list[float], width: int = 24) -> str:
    """Downsampled unicode sparkline (empty string for < 2 points)."""
    if len(values) < 2:
        return ""
    if len(values) > width:
        step = len(values) / width
        values = [values[int(i * step)] for i in range(width)]
    lo, hi = min(values), max(values)
    if hi <= lo:
        return _SPARK[0] * len(values)
    return "".join(
        _SPARK[int((v - lo) / (hi - lo) * (len(_SPARK) - 1))] for v in values
    )


def render_registry(reg: R.MetricsRegistry, *, limit: int = 0) -> str:
    """The live/METRICS.json view: one section per metric kind."""
    counters, gauges, hists = [], [], []
    for m in reg.all_metrics():
        for s in m.samples():
            row = f"  {m.name}{_labstr(s['labels'])}"
            if m.kind == "histogram":
                p50 = TS.quantile_from_counts(s["buckets"], s["counts"], 0.5)
                p99 = TS.quantile_from_counts(s["buckets"], s["counts"], 0.99)
                mean = s["sum"] / s["count"] if s["count"] else None
                hists.append(
                    f"{row}  count={s['count']} mean={_fmt(mean)} "
                    f"p50={_fmt(p50)} p99={_fmt(p99)}"
                )
            elif m.kind == "counter":
                counters.append((s["value"], f"{row}  {_fmt(s['value'])}"))
            else:
                gauges.append(f"{row}  {_fmt(s['value'])}")
    counters.sort(key=lambda t: -t[0])
    rows = [r for _, r in counters]
    if limit:
        rows = rows[:limit]
    out = []
    for title, body in (
        ("counters", rows),
        ("gauges", gauges if not limit else gauges[:limit]),
        ("histograms", hists if not limit else hists[:limit]),
    ):
        if body:
            out.append(f"== {title} ==")
            out.extend(body)
    return "\n".join(out) if out else "(registry is empty)"


def render_timeseries(payload: dict, *, limit: int = 0) -> str:
    """The TIMESERIES.json view: derived series with range + sparkline."""
    errs = TS.validate_timeseries_export(payload)
    if errs:
        raise ValueError(f"invalid timeseries export: {errs[0]}")
    span = None
    if payload.get("t_first") is not None and payload.get("t_last") is not None:
        span = payload["t_last"] - payload["t_first"]
    head = (
        f"== timeseries: {payload['n_snapshots']} snapshots"
        + (f" over {span:.1f}s" if span is not None else "")
        + f" (capacity {payload['capacity']}) =="
    )
    rows = []
    for s in payload["series"]:
        vals = [p[1] for p in s["points"]]
        rows.append(
            f"  {s['name']}{_labstr(s['labels'])}  "
            f"min={_fmt(min(vals))} last={_fmt(vals[-1])} max={_fmt(max(vals))}  "
            f"{sparkline(vals)}"
        )
    if limit:
        rows = rows[:limit]
    if not rows:
        rows = ["  (no derived series — need at least two snapshots)"]
    return "\n".join([head] + rows)


def render_health(report) -> str:
    """A :class:`~repro.obs.health.HealthReport` as aligned check rows."""
    icon = {"ok": "·", "warn": "!", "crit": "✗"}
    lines = [f"== health: {report.status.upper()} =="]
    for c in report.checks:
        line = f"  [{icon.get(c.status, '?')}] {c.name:<24} {c.status:<4}"
        if c.value is not None:
            line += f" {_fmt(c.value)}"
        if c.detail:
            line += f"  {c.detail}"
        if c.status != "ok" and c.remediation:
            line += f"  → {c.remediation}"
        lines.append(line)
    return "\n".join(lines)


def render_file(path: str, *, limit: int = 0) -> str:
    with open(path) as f:
        payload = json.load(f)
    if isinstance(payload, dict) and payload.get("schema") == TS.SCHEMA:
        return render_timeseries(payload, limit=limit)
    return render_registry(R.MetricsRegistry.from_json(payload), limit=limit)


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="render a text dashboard from the live registry or an export",
    )
    p.add_argument(
        "--from",
        dest="paths",
        action="append",
        default=[],
        metavar="PATH",
        help="METRICS.json or TIMESERIES.json export (repeatable); "
        "omit to render the live global registry",
    )
    p.add_argument(
        "--limit", type=int, default=0, help="cap rows per section (0 = all)"
    )
    args = p.parse_args(argv)
    try:
        if not args.paths:
            print(render_registry(R.registry(), limit=args.limit))
        else:
            for i, path in enumerate(args.paths):
                if i:
                    print()
                print(f"# {path}")
                print(render_file(path, limit=args.limit))
    except (OSError, json.JSONDecodeError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
