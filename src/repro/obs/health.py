"""Health watchdogs + the Monitor that drives continuous observability
(DESIGN.md §Observability, continuous monitoring).

A *watchdog* is a pure function ``(reg, ring, now) -> HealthCheck`` that
turns signals the system already records into an OK/WARN/CRIT verdict
with a concrete remediation — the operational question ("should I
rebuild attr stats? compact? retrain codebooks?") answered from data,
not vibes:

* **planner_calibration** — rolling mean |est_sel − n_pass/n_seen| from
  the windowed ``compass_sel_abs_err_sum`` / ``compass_sel_obs_total``
  deltas.  Compass's mode choice (and the cooperative strategies the
  systems-analysis paper stresses) is only as good as the selectivity
  estimate; sustained misestimation means the attribute distribution
  moved under the stats → rebuild ``astats``.
* **quant_staleness** — latest ``compass_quant_drift_mse`` over its
  training-time baseline ``compass_quant_train_mse`` (paired by series
  labels).  Drift ratio growing means the folded table no longer looks
  like the corpus the codebooks were trained on →
  ``compact(retrain_codebooks=True)``.
* **delta_occupancy / tombstone_debt** — compaction debt from the
  ``compass_delta_fill``/``_cap``/``compass_tombstone_fraction`` gauges:
  a near-full delta is one burst from a forced fold; a tombstone-heavy
  base routes through dead rows → ``compact()``.
* **recompile_churn** — compiles still accruing *after* warmup
  (``compass_compiles_total`` moved in the window and was already
  nonzero at its start).  Steady-state recompiles are the failure mode
  ShapePolicy exists to prevent.
* **shard_skew** — max/mean of windowed per-shard ``compass_dist_total``
  / ``compass_steps_total`` deltas.  Fan-out latency is the *slowest*
  shard; skew means one shard does multiples of the average work
  (straggler, hot shard, bad placement).
* **admission_pressure** — worst per-tenant windowed shed fraction
  (``compass_shed_total`` / ``compass_submitted_total``) plus worst
  queue fill against the shed limit.  Typed shedding is working as
  designed, but a *sustained* shed rate means a tenant's offered load
  exceeds its share → raise its weight / queue depth or push back
  upstream.

:class:`Monitor` owns the snapshot cadence: ``tick()`` (called from
``SearchService.step()``) snapshots at most once per ``interval_s`` and
then evaluates SLOs + watchdogs, publishing ``compass_health_status``
gauges and emitting a ``health`` event on every status transition.
Everything is host-side dict work gated on ``registry.enabled()`` — the
disabled cost at the serving loop is one attribute check.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

from . import events as E
from . import registry as R
from .slo import default_slos, evaluate_slos
from .timeseries import Snapshotter, TimeSeriesRing, _delta_scalar

STATUS_LEVELS = {"ok": 0, "warn": 1, "crit": 2}

# watchdog thresholds (documented in DESIGN.md §Observability; tests
# reference these constants rather than re-hardcoding)
PLANNER_DRIFT_WARN = 0.15  # mean |est_sel - actual| over the window
PLANNER_DRIFT_CRIT = 0.30
QUANT_DRIFT_WARN = 1.5  # drift_mse / train_mse ratio
QUANT_DRIFT_CRIT = 3.0
DELTA_FILL_WARN = 0.80  # occupied fraction of delta_cap
DELTA_FILL_CRIT = 0.95
TOMBSTONE_WARN = 0.25  # dead fraction of real base rows
TOMBSTONE_CRIT = 0.50
SKEW_WARN = 2.0  # max/mean windowed per-shard work
SKEW_CRIT = 4.0
SHED_RATE_WARN = 0.01  # windowed shed / submitted fraction per tenant
SHED_RATE_CRIT = 0.05
QUEUE_FILL_WARN = 0.80  # queue depth / shed limit per tenant
QUEUE_FILL_CRIT = 0.95
#: default lookback for windowed watchdogs — long enough that a ring at
#: any realistic cadence resolves it as "the whole ring" in tests
WATCH_WINDOW_S = 600.0


@dataclass(frozen=True)
class HealthCheck:
    """One watchdog verdict."""

    name: str
    status: str  # "ok" | "warn" | "crit"
    value: Optional[float] = None  # the signal that drove the verdict
    detail: str = ""
    remediation: str = ""  # what an operator should do about it

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "status": self.status,
            "value": self.value,
            "detail": self.detail,
            "remediation": self.remediation,
        }


@dataclass(frozen=True)
class HealthReport:
    """All checks from one evaluation; ``status`` is the worst of them."""

    ts: float
    status: str
    checks: tuple

    def to_dict(self) -> dict:
        return {
            "ts": self.ts,
            "status": self.status,
            "checks": [c.to_dict() for c in self.checks],
        }

    def check(self, name: str) -> Optional[HealthCheck]:
        for c in self.checks:
            if c.name == name:
                return c
        return None


def _grade(value: float, warn: float, crit: float) -> str:
    if value >= crit:
        return "crit"
    if value >= warn:
        return "warn"
    return "ok"


def planner_calibration(
    reg: R.MetricsRegistry, ring: TimeSeriesRing, now: Optional[float] = None
) -> HealthCheck:
    err = ring.delta("compass_sel_abs_err_sum", window_s=WATCH_WINDOW_S, now=now)
    n = ring.delta("compass_sel_obs_total", window_s=WATCH_WINDOW_S, now=now)
    if err is None or not n:
        return HealthCheck("planner_calibration", "ok", detail="no observations in window")
    mae = err / n
    return HealthCheck(
        "planner_calibration",
        _grade(mae, PLANNER_DRIFT_WARN, PLANNER_DRIFT_CRIT),
        value=mae,
        detail=f"mean |est_sel - actual| = {mae:.3f} over {int(n)} queries",
        remediation="rebuild attr stats (core.planner.stats.build_attr_stats)",
    )


def quant_staleness(
    reg: R.MetricsRegistry, ring: TimeSeriesRing, now: Optional[float] = None
) -> HealthCheck:
    drift = reg.get("compass_quant_drift_mse")
    train = reg.get("compass_quant_train_mse")
    if drift is None or train is None:
        return HealthCheck("quant_staleness", "ok", detail="no quantized tier folded yet")
    base = {
        frozenset(s["labels"].items()): s["value"] for s in train.samples()
    }
    worst = None
    for s in drift.samples():
        t = base.get(frozenset(s["labels"].items()))
        if t and t > 0:
            ratio = s["value"] / t
            if worst is None or ratio > worst:
                worst = ratio
    if worst is None:
        return HealthCheck("quant_staleness", "ok", detail="no train-MSE baseline")
    return HealthCheck(
        "quant_staleness",
        _grade(worst, QUANT_DRIFT_WARN, QUANT_DRIFT_CRIT),
        value=worst,
        detail=f"worst drift_mse/train_mse = {worst:.2f}x",
        remediation="compact(retrain_codebooks=True)",
    )


def delta_occupancy(
    reg: R.MetricsRegistry, ring: TimeSeriesRing, now: Optional[float] = None
) -> HealthCheck:
    fill = reg.get("compass_delta_fill")
    cap = reg.get("compass_delta_cap")
    if fill is None or cap is None:
        return HealthCheck("delta_occupancy", "ok", detail="no mutable index")
    caps = {frozenset(s["labels"].items()): s["value"] for s in cap.samples()}
    worst = 0.0
    for s in fill.samples():
        c = caps.get(frozenset(s["labels"].items()))
        if c:
            worst = max(worst, s["value"] / c)
    return HealthCheck(
        "delta_occupancy",
        _grade(worst, DELTA_FILL_WARN, DELTA_FILL_CRIT),
        value=worst,
        detail=f"fullest delta segment at {worst:.0%} of capacity",
        remediation="compact() before the next write burst forces a fold",
    )


def tombstone_debt(
    reg: R.MetricsRegistry, ring: TimeSeriesRing, now: Optional[float] = None
) -> HealthCheck:
    g = reg.get("compass_tombstone_fraction")
    if g is None:
        return HealthCheck("tombstone_debt", "ok", detail="no mutable index")
    worst = max((s["value"] for s in g.samples()), default=0.0)
    return HealthCheck(
        "tombstone_debt",
        _grade(worst, TOMBSTONE_WARN, TOMBSTONE_CRIT),
        value=worst,
        detail=f"worst base is {worst:.0%} tombstoned",
        remediation="compact() to fold dead rows out of the routing graph",
    )


def recompile_churn(
    reg: R.MetricsRegistry, ring: TimeSeriesRing, now: Optional[float] = None
) -> HealthCheck:
    pair = ring.window(WATCH_WINDOW_S, now)
    if pair is None:
        return HealthCheck("recompile_churn", "ok", detail="not enough snapshots")
    old, new = pair
    name = "compass_compiles_total"
    warm = sum(old.counters.get(name, {}).values())
    total_new = new.counters.get(name, {})
    fresh = sum(
        _delta_scalar(v, old.counters.get(name, {}).get(k))
        for k, v in total_new.items()
    )
    # compiles during warmup (counter was zero at window start) are the
    # expected cost of occupying shape buckets; compiles after that are
    # churn — exactly what ShapePolicy's bucketing is supposed to prevent
    if warm <= 0 or fresh <= 0:
        return HealthCheck(
            "recompile_churn", "ok", value=fresh,
            detail="no steady-state recompiles in window",
        )
    return HealthCheck(
        "recompile_churn",
        "warn",
        value=fresh,
        detail=f"{int(fresh)} recompiles after warmup in the window",
        remediation="check ShapePolicy row bucketing / delta_cap stability",
    )


def shard_skew(
    reg: R.MetricsRegistry, ring: TimeSeriesRing, now: Optional[float] = None
) -> HealthCheck:
    pair = ring.window(WATCH_WINDOW_S, now)
    if pair is None:
        return HealthCheck("shard_skew", "ok", detail="not enough snapshots")
    old, new = pair
    worst, worst_detail = 0.0, ""
    for name in ("compass_dist_total", "compass_steps_total"):
        fam = new.counters.get(name)
        lnames = new.labelnames.get(name, ())
        if fam is None or "shard" not in lnames:
            continue
        si = lnames.index("shard")
        olds = old.counters.get(name, {})
        per_shard: dict[str, float] = {}
        for k, v in fam.items():
            if k[si] == "":  # unsharded series — not fan-out traffic
                continue
            per_shard[k[si]] = per_shard.get(k[si], 0.0) + _delta_scalar(v, olds.get(k))
        if len(per_shard) < 2:
            continue
        mean = sum(per_shard.values()) / len(per_shard)
        if mean <= 0:
            continue
        hot = max(per_shard, key=per_shard.get)
        skew = per_shard[hot] / mean
        if skew > worst:
            worst = skew
            worst_detail = f"shard {hot} at {skew:.1f}x mean {name.split('_')[1]} work"
    if worst == 0.0:
        return HealthCheck("shard_skew", "ok", detail="fewer than 2 active shards")
    return HealthCheck(
        "shard_skew",
        _grade(worst, SKEW_WARN, SKEW_CRIT),
        value=worst,
        detail=worst_detail,
        remediation="rebalance shard assignment / investigate straggler",
    )


def admission_pressure(
    reg: R.MetricsRegistry, ring: TimeSeriesRing, now: Optional[float] = None
) -> HealthCheck:
    """Multi-tenant admission health: worst per-tenant windowed shed
    fraction (``compass_shed_total`` / ``compass_submitted_total``) and
    worst instantaneous queue fill (``compass_queue_depth`` over
    ``compass_queue_limit``).  Shedding *is* the designed overload
    response — typed, never silent — but a sustained shed rate means a
    tenant's offered load exceeds its fair share, and a near-limit queue
    is one burst from shedding; both deserve an operator's eye before
    the SLO burn does."""
    submitted = reg.get("compass_submitted_total")
    if submitted is None:
        return HealthCheck("admission_pressure", "ok", detail="no collection service")
    tenants = sorted({s["labels"].get("tenant", "") for s in submitted.samples()})
    worst, detail, remediation = 0.0, "no admission pressure", ""
    for t in tenants:
        lab = {"tenant": t}
        shed = ring.delta("compass_shed_total", window_s=WATCH_WINDOW_S, now=now, labels=lab)
        total = ring.delta(
            "compass_submitted_total", window_s=WATCH_WINDOW_S, now=now, labels=lab
        )
        if shed and total:
            rate = shed / total
            score = _grade(rate, SHED_RATE_WARN, SHED_RATE_CRIT)
            if STATUS_LEVELS[score] > 0 and rate > worst:
                worst, detail = rate, (
                    f"tenant {t!r} shed {rate:.1%} of submissions in the window"
                )
                remediation = "raise the tenant's weight/queue depth or shed earlier upstream"
    status = _grade(worst, SHED_RATE_WARN, SHED_RATE_CRIT)
    # queue fill is a leading indicator: only escalates, never calms, the
    # verdict the shed rate already gave
    depth = reg.get("compass_queue_depth")
    limit = reg.get("compass_queue_limit")
    if depth is not None and limit is not None:
        limits = {
            frozenset(s["labels"].items()): s["value"] for s in limit.samples()
        }
        for s in depth.samples():
            cap = limits.get(frozenset(s["labels"].items()))
            if cap:
                fill = s["value"] / cap
                g = _grade(fill, QUEUE_FILL_WARN, QUEUE_FILL_CRIT)
                if STATUS_LEVELS[g] > STATUS_LEVELS[status]:
                    status, worst = g, fill
                    detail = (
                        f"tenant {s['labels'].get('tenant', '')!r} queue at "
                        f"{fill:.0%} of its shed limit"
                    )
                    remediation = "drain faster (more step() budget) or raise max_queue_depth"
    if status == "ok":
        return HealthCheck("admission_pressure", "ok", value=worst, detail=detail)
    return HealthCheck(
        "admission_pressure", status, value=worst, detail=detail,
        remediation=remediation,
    )


DEFAULT_WATCHDOGS: tuple[Callable, ...] = (
    planner_calibration,
    quant_staleness,
    delta_occupancy,
    tombstone_debt,
    recompile_churn,
    shard_skew,
    admission_pressure,
)


class Monitor:
    """Cadenced snapshots + SLO evaluation + watchdogs, in one object.

    ``tick()`` is the serving-loop entry point: cheap no-op when
    observability is disabled, snapshot-and-evaluate at most once per
    ``interval_s`` otherwise.  ``evaluate()`` forces an immediate report
    (``SearchService.health()``).
    """

    def __init__(
        self,
        reg: Optional[R.MetricsRegistry] = None,
        *,
        capacity: int = 128,
        interval_s: float = 1.0,
        clock: Callable[[], float] = time.monotonic,
        slos=None,
        watchdogs=None,
    ):
        self.snapshotter = Snapshotter(
            reg, capacity=capacity, interval_s=interval_s, clock=clock
        )
        self.slos = tuple(default_slos() if slos is None else slos)
        self.watchdogs = tuple(DEFAULT_WATCHDOGS if watchdogs is None else watchdogs)
        self._last_status: dict[str, str] = {}
        self.last_report: Optional[HealthReport] = None

    @property
    def ring(self) -> TimeSeriesRing:
        return self.snapshotter.ring

    @property
    def reg(self) -> R.MetricsRegistry:
        return self.snapshotter.reg

    def tick(self, now: Optional[float] = None) -> Optional[HealthReport]:
        """Snapshot + evaluate if the cadence says so; None otherwise."""
        if not R.enabled():
            return None
        now = self.snapshotter.clock() if now is None else now
        if not self.snapshotter.maybe_snapshot(now):
            return None
        return self.evaluate(now, snapshot=False)

    def evaluate(
        self, now: Optional[float] = None, *, snapshot: bool = True
    ) -> HealthReport:
        """Run SLOs + watchdogs against the current ring and registry.

        Publishes ``compass_health_status{check=...}`` gauges (0/1/2) and
        emits a ``health`` event for every check whose status changed
        since the previous evaluation.
        """
        now = self.snapshotter.clock() if now is None else now
        if snapshot and len(self.ring) == 0:
            self.ring.snapshot(self.reg, now)
        checks: list[HealthCheck] = []
        slo_results = evaluate_slos(self.slos, self.ring, now=now, reg=self.reg)
        for name, res in slo_results.items():
            burns = {
                f"{w:g}s": round(b, 3)
                for w, b in res["burns"].items()
                if b is not None
            }
            checks.append(
                HealthCheck(
                    name=f"slo:{name}",
                    status="crit" if res["breaching"] else "ok",
                    value=max(burns.values(), default=None),
                    detail=f"burn rates {burns}" if burns else "no observations",
                    remediation="shed load / raise capacity until burn < 1",
                )
            )
        for wd in self.watchdogs:
            checks.append(wd(self.reg, self.ring, now))
        worst = max(checks, key=lambda c: STATUS_LEVELS[c.status], default=None)
        report = HealthReport(
            ts=now,
            status=worst.status if worst else "ok",
            checks=tuple(checks),
        )
        g = self.reg.gauge(
            "compass_health_status", "0=ok 1=warn 2=crit per check", ("check",)
        )
        for c in checks:
            g.set(STATUS_LEVELS[c.status], check=c.name)
            prev = self._last_status.get(c.name)
            if prev is not None and prev != c.status:
                E.emit(
                    "health",
                    check=c.name,
                    status=c.status,
                    prev=prev,
                    value=c.value,
                    detail=c.detail,
                )
            self._last_status[c.name] = c.status
        self.last_report = report
        return report
