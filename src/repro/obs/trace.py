"""Per-query explain traces: ``compass_search(..., explain=True)``.

A :class:`QueryTrace` is the host-side story of one query: what the
planner *estimated* (selectivity, materialization budget), what it
*chose* (mode, cost-model inputs), what actually *happened* (distance /
ADC / rerank / cluster counters, measured selectivity), and *where* it
ran (backend, fused/unfused kernel route, quant config, snapshot epoch).

The contract that keeps explain free: everything a trace needs already
rides in the device-side ``SearchStats`` — the traced computation is
IDENTICAL with and without ``explain=True`` (same jitted program, same
executable-cache key), and :func:`build_traces` merely reads the result
arrays host-side.  ``n_pass`` / ``est_sel`` / ``run_total`` were added to
``SearchStats`` for exactly this (engine/state.py); the kernel route is
recomputed host-side from the same trace-time facts the backend layer
branches on, so it names the route the compiled program actually took.

Estimated vs. actual selectivity is the strategy-mistake telemetry the
filtered-ANN systems analysis calls for (PAPERS.md): PREFILTER chosen off
an estimate of 0.02 that measures 0.4 is a planner bug you can now see
per query.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class QueryTrace:
    """The explain record for one query of a batch (all host scalars)."""

    query: int  # position in the batch
    # -- planner ----------------------------------------------------------
    mode: str  # "prefilter" | "cooperative" | "postfilter"
    planner: bool  # was the cost-based planner on?
    est_selectivity: Optional[float]  # planner estimate; None when planner off
    actual_selectivity: Optional[float]  # measured pass-fraction of scored rows
    run_total: Optional[int]  # estimated candidate run rows (cost-model input)
    prefilter_cap: int  # PREFILTER materialization budget (cost-model input)
    # -- work counters (device-measured, summed over the whole search) ----
    n_dist: int
    n_adc: int
    n_rerank: int
    n_cdist: int
    n_pass: int
    n_steps: int
    n_bcalls: int
    n_clusters_ranked: int
    efs_final: int
    # -- route ------------------------------------------------------------
    backend: str  # resolved backend name ("ref" | "pallas")
    kernel_route: str  # e.g. "pallas/visit_step/interpret", "ref"
    metric: str  # effective metric the engine ran ("cos" rewrites to "ip")
    ef: int
    k: int
    quant: Optional[dict]  # QuantParams as a dict; None for exact search
    engine_version: str
    epoch: Optional[int]  # snapshot epoch (mutable indices); None otherwise
    # which shard produced this trace (distributed fan-out); None for a
    # single-index search or for the cross-shard aggregate view
    shard: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ShardedQueryTrace:
    """One query's explain record across a distributed fan-out.

    ``aggregate`` composes per :func:`~repro.core.distributed
    .aggregate_shard_stats` (work SUMMED, ``n_steps`` MAXed — the
    critical path, planner decisions from shard 0); ``shards`` holds the
    per-shard traces, each stamped with its shard id and its own
    snapshot epoch, so a skewed or stale shard is visible per query.
    """

    aggregate: QueryTrace
    shards: tuple  # (QueryTrace, ...) — one per shard, same query index


def kernel_route(pm, *, quant_active: bool, metric: str) -> str:
    """The scoring route the compiled program takes for VISIT, recomputed
    from the same trace-time facts backend.py branches on."""
    from repro.core.engine.backend import resolve_backend
    from repro.kernels.interpret import default_interpret

    backend = resolve_backend(pm.backend)
    if backend.name != "pallas":
        return "ref"
    if metric not in ("l2", "ip"):  # the PallasBackend metric fallback
        return f"ref(metric={metric})"
    if quant_active:
        kern = "pq_score"
    elif pm.fused_visit:
        kern = "visit_step"
    else:
        kern = "filter_distance"
    mode = "interpret" if default_interpret() else "mosaic"
    return f"pallas/{kern}/{mode}"


def build_traces(
    res, pm, *, epoch: int | None = None, shard: int | None = None
) -> list[QueryTrace]:
    """Materialize one :class:`QueryTrace` per batch lane from a finished
    :class:`SearchResult`.  Reads (and therefore syncs) the stats arrays —
    call it after the result is consumed, not on the dispatch hot path."""
    from repro.core.engine import ENGINE_VERSION, resolve_backend
    from repro.core.planner.plan import MODE_NAMES

    pmr = pm.resolved()
    metric = "ip" if pmr.metric == "cos" else pmr.metric
    quant_active = pmr.quant is not None
    route = kernel_route(pmr, quant_active=quant_active, metric=metric)
    backend = resolve_backend(pmr.backend).name
    quant = dataclasses.asdict(pmr.quant) if quant_active else None
    st = {f: np.asarray(getattr(res.stats, f)) for f in res.stats._fields}
    nq = int(st["mode"].size)
    traces = []
    for i in range(nq):
        def g(field, _i=i):
            a = st[field]
            return a.ravel()[_i] if a.size == nq else a.ravel()[0]

        n_dist, n_adc, n_rerank = int(g("n_dist")), int(g("n_adc")), int(g("n_rerank"))
        # unique rows examined: rerank="full" rows land in BOTH n_adc
        # (stage one) and n_dist (stage two #Comp), so subtract the
        # double count before dividing the pass count through
        n_seen = n_dist + n_adc
        if quant_active and pmr.quant.rerank == "full":
            n_seen -= n_rerank
        est = float(g("est_sel"))
        rt = int(g("run_total"))
        traces.append(
            QueryTrace(
                query=i,
                mode=MODE_NAMES[int(g("mode"))],
                planner=bool(pmr.planner),
                est_selectivity=est if est >= 0.0 else None,
                actual_selectivity=(int(g("n_pass")) / n_seen) if n_seen > 0 else None,
                run_total=rt if rt >= 0 else None,
                prefilter_cap=int(pmr.prefilter_cap),
                n_dist=n_dist,
                n_adc=n_adc,
                n_rerank=n_rerank,
                n_cdist=int(g("n_cdist")),
                n_pass=int(g("n_pass")),
                n_steps=int(g("n_steps")),
                n_bcalls=int(g("n_bcalls")),
                n_clusters_ranked=int(g("n_clusters_ranked")),
                efs_final=int(g("efs_final")),
                backend=backend,
                kernel_route=route,
                metric=metric,
                ef=int(pmr.ef),
                k=int(pmr.k),
                quant=quant,
                engine_version=ENGINE_VERSION,
                epoch=epoch,
                shard=shard,
            )
        )
    return traces


def format_trace(t: QueryTrace) -> str:
    """One query's trace as an aligned, greppable block."""
    def sel(v):
        return "-" if v is None else f"{v:.4f}"

    lines = [
        f"query[{t.query}]"
        + (f" shard[{t.shard}]" if t.shard is not None else "")
        + f"  mode={t.mode}  backend={t.backend}  "
        f"route={t.kernel_route}  metric={t.metric}  {t.engine_version}"
        + (f"  epoch={t.epoch}" if t.epoch is not None else ""),
        f"  planner={'on' if t.planner else 'off'}  "
        f"selectivity est={sel(t.est_selectivity)} actual={sel(t.actual_selectivity)}"
        + (
            f"  run_total={t.run_total} prefilter_cap={t.prefilter_cap}"
            if t.planner
            else ""
        ),
        f"  work: n_dist={t.n_dist} n_adc={t.n_adc} n_rerank={t.n_rerank} "
        f"n_cdist={t.n_cdist} n_pass={t.n_pass}",
        f"  loop: n_steps={t.n_steps} n_bcalls={t.n_bcalls} "
        f"n_clusters_ranked={t.n_clusters_ranked} efs_final={t.efs_final} "
        f"ef={t.ef} k={t.k}",
    ]
    if t.quant is not None:
        lines.append(f"  quant: {t.quant}")
    return "\n".join(lines)


def _shard_line(t: QueryTrace) -> str:
    """One shard's contribution, compressed to a single comparable row."""
    sel = "-" if t.actual_selectivity is None else f"{t.actual_selectivity:.4f}"
    return (
        f"  shard[{t.shard}]"
        + (f" epoch={t.epoch}" if t.epoch is not None else "")
        + f"  mode={t.mode}  n_dist={t.n_dist} n_adc={t.n_adc} "
        f"n_steps={t.n_steps} n_pass={t.n_pass}  sel={sel}"
    )


def format_sharded_trace(t: ShardedQueryTrace) -> str:
    """Aggregate block + one breakdown row per shard."""
    lines = [format_trace(t.aggregate)]
    lines.append(f"  fan-out: {len(t.shards)} shards (work summed, n_steps maxed)")
    lines.extend(_shard_line(s) for s in t.shards)
    return "\n".join(lines)


def explain(traces) -> str:
    """Pretty-print one trace or a list of traces (``repro.compass
    .explain``) — plain :class:`QueryTrace` or distributed
    :class:`ShardedQueryTrace`.  Returns the rendering; print or log it."""
    if isinstance(traces, (QueryTrace, ShardedQueryTrace)):
        traces = [traces]
    return "\n".join(
        format_sharded_trace(t) if isinstance(t, ShardedQueryTrace) else format_trace(t)
        for t in traces
    )
