"""Structured event log — the discrete-lifecycle side of observability.

Counters answer "how much"; the event log answers "what happened, when,
in what order".  Subsystems emit typed events at host-side lifecycle
points (never from traced code):

  * ``compaction``       — MutableIndex.compact: fold wall, rows, drift
  * ``epoch_swap``       — the snapshot publish at the end of a fold
  * ``delta_overflow``   — an upsert hit a full delta and forced a fold
  * ``codebook_retrain`` — an explicit compact(retrain_codebooks=True)
  * ``write_error``      — a raced delete counted as a no-op (serving)
  * ``compile``          — an executable-cache miss (serving AOT / jit)
  * ``slo_burn``         — an SloSpec's burn rate crossed every window
  * ``health``           — a watchdog check changed status (obs/health.py)

Events land in a bounded in-memory ring (``tail()`` for tests and
``SearchService.stats()``) and optionally stream to a JSONL sink — one
``json.dumps`` line per event — opened from ``REPRO_OBS_EVENTS=<path>``
at import or :meth:`EventLog.configure` at runtime.  Each event also
bumps ``compass_events_total{kind=...}`` in the registry so dashboards
see rates without parsing the log.

Emission is active when observability is enabled *or* a sink is
configured; otherwise ``emit`` is one bool check.  Timestamps are host
wall-clock (``time.time()``) taken outside any trace.
"""
from __future__ import annotations

import json
import os
import time
from collections import Counter as _TallyCounter
from collections import deque

from . import registry as R

EVENT_KINDS = (
    "compaction",
    "epoch_swap",
    "delta_overflow",
    "codebook_retrain",
    "write_error",
    "compile",
    "slo_burn",
    "health",
)


class EventLog:
    """Bounded in-memory event ring with an optional JSONL file sink."""

    def __init__(self, capacity: int = 4096, path: str | None = None):
        self._ring: deque[dict] = deque(maxlen=int(capacity))
        self._counts: _TallyCounter = _TallyCounter()
        self._path: str | None = None
        self._fh = None
        if path:
            self.configure(path)

    def configure(self, path: str | None) -> None:
        """Attach (or detach, with None) the JSONL sink."""
        if self._fh is not None:
            self._fh.close()
            self._fh = None
        self._path = path or None
        if self._path:
            self._fh = open(self._path, "a", buffering=1)

    @property
    def path(self) -> str | None:
        return self._path

    def active(self) -> bool:
        return R.enabled() or self._fh is not None

    def emit(self, kind: str, **fields) -> dict | None:
        """Record one event; returns it, or None when inactive."""
        if not self.active():
            return None
        ev = {"ts": time.time(), "kind": str(kind), **fields}
        self._ring.append(ev)
        self._counts[ev["kind"]] += 1
        if self._fh is not None:
            self._fh.write(json.dumps(ev, sort_keys=True, default=str) + "\n")
        if R.enabled():
            R.registry().counter(
                "compass_events_total", "structured lifecycle events", ("kind",)
            ).inc(1, kind=ev["kind"])
        return ev

    def tail(self, n: int = 20, kind: str | None = None) -> list[dict]:
        evs = [e for e in self._ring if kind is None or e["kind"] == kind]
        return evs[-n:]

    def counts(self) -> dict[str, int]:
        """Per-kind totals since the last clear (ring-independent)."""
        return dict(self._counts)

    def clear(self) -> None:
        self._ring.clear()
        self._counts.clear()


#: the process-global log every subsystem emits into; the env var wires a
#: sink before any subsystem import runs
EVENTS = EventLog(path=os.environ.get("REPRO_OBS_EVENTS") or None)


def emit(kind: str, **fields) -> dict | None:
    """Emit onto the global :data:`EVENTS` log."""
    return EVENTS.emit(kind, **fields)
