"""CLI gate over the observability export schemas (the CI bench-smoke
step).

  python -m repro.obs.validate <METRICS.json|TIMESERIES.json> [...]

Dispatches on each payload's ``schema`` field — ``repro.obs.metrics/v1``
goes through :func:`repro.obs.registry.validate_export`,
``repro.obs.timeseries/v1`` through
:func:`repro.obs.timeseries.validate_timeseries_export`.  Exit 0 iff
every named file exists, parses, and passes its validator.
"""
from __future__ import annotations

import json
import sys

from . import registry as R
from . import timeseries as TS


def validate_any_file(path: str) -> list[str]:
    """Schema-dispatched validation of one export file on disk."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable/malformed JSON: {e}"]
    if not isinstance(payload, dict):
        return [f"top level is {type(payload).__name__}, expected object"]
    schema = payload.get("schema")
    if schema == TS.SCHEMA:
        return TS.validate_timeseries_export(payload)
    # default to the metrics validator: it reports an unknown/missing
    # schema field itself, so unrecognized payloads still fail loudly
    return R.validate_export(payload)


def main(argv: list[str] | None = None) -> int:
    args = sys.argv[1:] if argv is None else argv
    if not args:
        print("usage: python -m repro.obs.validate <METRICS.json|TIMESERIES.json> [...]")
        return 2
    bad = 0
    for path in args:
        errs = validate_any_file(path)
        if errs:
            bad += 1
            for e in errs:
                print(f"FAIL {path}: {e}")
        else:
            print(f"ok   {path}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
