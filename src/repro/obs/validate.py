"""CLI gate over the metrics-export schema (the CI bench-smoke step).

  python -m repro.obs.validate <METRICS.json> [...]

Exit 0 iff every named file exists and passes
:func:`repro.obs.registry.validate_export`.
"""
from __future__ import annotations

import sys

from .registry import validate_file


def main(argv: list[str] | None = None) -> int:
    args = sys.argv[1:] if argv is None else argv
    if not args:
        print("usage: python -m repro.obs.validate <METRICS.json> [...]")
        return 2
    bad = 0
    for path in args:
        errs = validate_file(path)
        if errs:
            bad += 1
            for e in errs:
                print(f"FAIL {path}: {e}")
        else:
            print(f"ok   {path}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
