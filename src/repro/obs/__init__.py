"""repro.obs — end-to-end observability for the Compass stack.

Four surfaces (DESIGN.md §Observability), all off by default and all
bitwise-invariant to search results:

* **registry** — host-side counters/gauges/fixed-bucket histograms with
  Prometheus-text + JSON exporters and a validateable schema; device
  ``SearchStats`` fold in only at existing sync points
  (:func:`record_search_stats`).  Enable with ``REPRO_OBS=1`` or
  :func:`set_enabled`.
* **trace** — per-query explain traces: ``compass_search(...,
  explain=True)`` returns :class:`QueryTrace` records rendered by
  :func:`explain` (re-exported as ``repro.compass.explain``).
* **profiling** — ``jax.named_scope``/``TraceAnnotation`` wrappers around
  every Pallas kernel and the serving micro-batch, an
  ``REPRO_OBS_PROFILE`` XPlane capture helper, and trace-time
  kernel/fallback/autotune counters that stay on even when the registry
  is disabled (one dict add per *compile*).
* **events** — a structured lifecycle log (compactions, epoch swaps,
  delta overflows, write errors, codebook retrains, executable compiles)
  with an optional JSONL sink (``REPRO_OBS_EVENTS=<path>``).

PR 9 adds the *continuous* layer on top — point-in-time becomes
over-time:

* **timeseries** — a bounded ring of registry snapshots with windowed
  delta/rate/quantile reads and the ``repro.obs.timeseries/v1`` export.
* **slo** — declarative objectives evaluated as multi-window burn rates
  (``SloSpec``, ``evaluate_slos``) publishing ``compass_slo_*`` gauges.
* **health** — drift/debt/skew watchdogs and the :class:`Monitor` that
  ``SearchService.step()`` ticks; ``python -m repro.obs.report`` renders
  any of it as a text dashboard.
"""
from . import events, health, profiling, registry, slo, timeseries, trace  # noqa: F401 — keep the
# submodules addressable as attributes: the convenience re-exports below
# must NOT shadow them (``repro.obs.registry`` stays the module; the
# accessor for the global MetricsRegistry is :func:`get_registry`)
from .events import EVENTS, EventLog, emit
from .health import DEFAULT_WATCHDOGS, HealthCheck, HealthReport, Monitor
from .profiling import (
    KERNELS,
    annotate,
    kernel_scope,
    profile_capture,
)
from .registry import (
    LATENCY_BUCKETS_S,
    RECALL_BUCKETS,
    SCHEMA,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    enabled,
    record_search_stats,
    reset,
    set_enabled,
    validate_export,
    validate_file,
)
from .registry import registry as get_registry
from .slo import SloSpec, SloWindow, default_slos, evaluate_slos
from .timeseries import (
    Snapshotter,
    TimeSeriesRing,
    quantile_from_counts,
    validate_timeseries_export,
)
from .trace import QueryTrace, ShardedQueryTrace, build_traces, explain, format_trace

__all__ = [
    "Counter",
    "DEFAULT_WATCHDOGS",
    "EVENTS",
    "EventLog",
    "Gauge",
    "HealthCheck",
    "HealthReport",
    "Histogram",
    "KERNELS",
    "LATENCY_BUCKETS_S",
    "MetricsRegistry",
    "Monitor",
    "QueryTrace",
    "RECALL_BUCKETS",
    "SCHEMA",
    "ShardedQueryTrace",
    "SloSpec",
    "SloWindow",
    "Snapshotter",
    "TimeSeriesRing",
    "annotate",
    "build_traces",
    "default_slos",
    "emit",
    "enabled",
    "evaluate_slos",
    "events",
    "explain",
    "format_trace",
    "get_registry",
    "health",
    "kernel_scope",
    "profile_capture",
    "profiling",
    "quantile_from_counts",
    "record_search_stats",
    "registry",
    "reset",
    "set_enabled",
    "slo",
    "timeseries",
    "trace",
    "validate_export",
    "validate_file",
    "validate_timeseries_export",
]
