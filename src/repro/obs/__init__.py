"""repro.obs — end-to-end observability for the Compass stack.

Four surfaces (DESIGN.md §Observability), all off by default and all
bitwise-invariant to search results:

* **registry** — host-side counters/gauges/fixed-bucket histograms with
  Prometheus-text + JSON exporters and a validateable schema; device
  ``SearchStats`` fold in only at existing sync points
  (:func:`record_search_stats`).  Enable with ``REPRO_OBS=1`` or
  :func:`set_enabled`.
* **trace** — per-query explain traces: ``compass_search(...,
  explain=True)`` returns :class:`QueryTrace` records rendered by
  :func:`explain` (re-exported as ``repro.compass.explain``).
* **profiling** — ``jax.named_scope``/``TraceAnnotation`` wrappers around
  every Pallas kernel and the serving micro-batch, an
  ``REPRO_OBS_PROFILE`` XPlane capture helper, and trace-time
  kernel/fallback/autotune counters that stay on even when the registry
  is disabled (one dict add per *compile*).
* **events** — a structured lifecycle log (compactions, epoch swaps,
  delta overflows, write errors, codebook retrains, executable compiles)
  with an optional JSONL sink (``REPRO_OBS_EVENTS=<path>``).
"""
from . import events, profiling, registry, trace  # noqa: F401 — keep the
# submodules addressable as attributes: the convenience re-exports below
# must NOT shadow them (``repro.obs.registry`` stays the module; the
# accessor for the global MetricsRegistry is :func:`get_registry`)
from .events import EVENTS, EventLog, emit
from .profiling import (
    KERNELS,
    annotate,
    kernel_scope,
    profile_capture,
)
from .registry import (
    LATENCY_BUCKETS_S,
    RECALL_BUCKETS,
    SCHEMA,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    enabled,
    record_search_stats,
    reset,
    set_enabled,
    validate_export,
    validate_file,
)
from .registry import registry as get_registry
from .trace import QueryTrace, build_traces, explain, format_trace

__all__ = [
    "Counter",
    "EVENTS",
    "EventLog",
    "Gauge",
    "Histogram",
    "KERNELS",
    "LATENCY_BUCKETS_S",
    "MetricsRegistry",
    "QueryTrace",
    "RECALL_BUCKETS",
    "SCHEMA",
    "annotate",
    "build_traces",
    "emit",
    "enabled",
    "events",
    "explain",
    "format_trace",
    "get_registry",
    "kernel_scope",
    "profile_capture",
    "profiling",
    "record_search_stats",
    "registry",
    "reset",
    "set_enabled",
    "trace",
    "validate_export",
    "validate_file",
]
