"""The metrics registry — host-side counters/gauges/histograms with
Prometheus-text and JSON exporters (DESIGN.md §Observability).

Design constraints, in order:

1. **Bitwise invariance.**  Nothing here ever touches a traced value: the
   registry is plain host Python, and every instrumentation site either
   runs at trace time (kernel wrappers — once per compile, constant work)
   or at an *existing* host sync point (``block_until_ready`` in serving,
   ``np.asarray`` in benchmarks).  Observability can change wall-clock by
   nanoseconds per batch; it cannot change a single result bit, because it
   never adds a device op or a sync.
2. **Off by default.**  ``enabled()`` gates every per-batch recording;
   the steady-state cost of a disabled registry is one module-level bool
   read per sync point.  ``REPRO_OBS=1`` (read at import) or
   ``set_enabled(True)`` turns it on; the bench_obs CI tripwire asserts
   the *enabled* overhead stays within 5% of disabled QPS.
3. **Fixed-bucket histograms.**  Latency/recall distributions use
   fixed, declared bucket edges (Prometheus ``le`` convention: cumulative
   counts at export, per-bucket counts internally, one overflow slot for
   ``+Inf``) — no dynamic resizing, so ``observe`` is one bisect + two
   adds.

Series are keyed by label values; metric names and label names follow the
Prometheus data model (validated at creation).  ``to_json`` emits the
``repro.obs.metrics/v1`` schema that :func:`validate_export` (and the CI
step ``python -m repro.obs.validate``) checks.
"""
from __future__ import annotations

import bisect
import json
import math
import os
import re
import threading

SCHEMA = "repro.obs.metrics/v1"
METRIC_TYPES = ("counter", "gauge", "histogram")

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: fixed bucket edges (seconds) for serving latency histograms — spans the
#: CI interpret-mode tail (seconds) down to native-TPU micro-batches
LATENCY_BUCKETS_S = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0,
    2.5, 5.0,
)
#: fixed bucket edges for recall@k histograms (cumulative `le` semantics)
RECALL_BUCKETS = (0.5, 0.8, 0.9, 0.95, 0.99, 0.999, 1.0)

_ENABLED = os.environ.get("REPRO_OBS", "0") not in ("", "0")


def enabled() -> bool:
    """Is per-batch metric recording on?  One bool read — the entire cost
    of a disabled registry at a sync point."""
    return _ENABLED


def set_enabled(flag: bool) -> bool:
    """Flip recording on/off; returns the previous value (so callers can
    restore — see benchmarks/bench_obs.py)."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(flag)
    return prev


class _Metric:
    """One named metric = a family of label-keyed series."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: tuple[str, ...]):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r} on {name}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._series: dict[tuple[str, ...], float | list] = {}

    def _key(self, labels: dict) -> tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}"
            )
        return tuple(str(labels[ln]) for ln in self.labelnames)

    def _labels_of(self, key: tuple[str, ...]) -> dict[str, str]:
        return dict(zip(self.labelnames, key))

    def samples(self) -> list[dict]:
        return [
            {"labels": self._labels_of(k), "value": v}
            for k, v in sorted(self._series.items())
        ]

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "type": self.kind,
            "help": self.help,
            "labelnames": list(self.labelnames),
            "samples": self.samples(),
        }


class Counter(_Metric):
    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"{self.name}: counters only go up (got {value})")
        k = self._key(labels)
        self._series[k] = self._series.get(k, 0.0) + float(value)

    def value(self, **labels) -> float:
        return float(self._series.get(self._key(labels), 0.0))


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[self._key(labels)] = float(value)

    def value(self, **labels) -> float:
        return float(self._series.get(self._key(labels), 0.0))


class Histogram(_Metric):
    """Fixed-bucket histogram.  Internally each series is
    ``[counts (len(buckets)+1 with the +Inf overflow slot), sum, count]``;
    the exporters emit the Prometheus cumulative-``le`` view."""

    kind = "histogram"

    def __init__(self, name, help, labelnames, buckets):
        super().__init__(name, help, labelnames)
        b = tuple(float(x) for x in buckets)
        if not b or list(b) != sorted(b) or len(set(b)) != len(b):
            raise ValueError(f"{name}: buckets must be non-empty ascending, got {b}")
        self.buckets = b

    def observe(self, value: float, **labels) -> None:
        k = self._key(labels)
        s = self._series.get(k)
        if s is None:
            s = self._series[k] = [[0] * (len(self.buckets) + 1), 0.0, 0]
        v = float(value)
        s[0][bisect.bisect_left(self.buckets, v)] += 1
        s[1] += v
        s[2] += 1

    def series(self, **labels):
        """(per-bucket counts incl. +Inf slot, sum, count) for one series."""
        s = self._series.get(self._key(labels))
        if s is None:
            return [0] * (len(self.buckets) + 1), 0.0, 0
        return list(s[0]), float(s[1]), int(s[2])

    def samples(self) -> list[dict]:
        return [
            {
                "labels": self._labels_of(k),
                "buckets": list(self.buckets),
                "counts": list(s[0]),
                "sum": float(s[1]),
                "count": int(s[2]),
            }
            for k, s in sorted(self._series.items())
        ]


class MetricsRegistry:
    """A namespace of metrics; get-or-create with type/label checking."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name, help, labelnames, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, tuple(labelnames), **kw)
            elif type(m) is not cls or m.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} re-declared as {cls.kind} with labels "
                    f"{tuple(labelnames)} (was {m.kind} / {m.labelnames})"
                )
            return m

    def counter(self, name: str, help: str = "", labelnames=()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "", labelnames=()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(
        self, name: str, help: str = "", labelnames=(), buckets=LATENCY_BUCKETS_S
    ) -> Histogram:
        return self._get(Histogram, name, help, labelnames, buckets=buckets)

    def get(self, name: str) -> _Metric | None:
        return self._metrics.get(name)

    def all_metrics(self) -> list[_Metric]:
        """Stable-ordered view of every metric family (the timeseries ring
        walks this when snapshotting; copied under the lock so concurrent
        creates are safe)."""
        with self._lock:
            return [m for _, m in sorted(self._metrics.items())]

    @classmethod
    def from_json(cls, payload) -> "MetricsRegistry":
        """Reconstruct a registry from a ``to_json()`` export — how the
        report CLI renders an on-disk METRICS.json as if it were live.
        Rejects payloads that fail :func:`validate_export`."""
        errs = validate_export(payload)
        if errs:
            raise ValueError(f"invalid metrics export: {errs[0]}")
        reg = cls()
        for m in payload["metrics"]:
            lnames = tuple(m["labelnames"])
            samples = m["samples"]
            if m["type"] == "histogram":
                buckets = tuple(samples[0]["buckets"]) if samples else LATENCY_BUCKETS_S
                met = reg.histogram(m["name"], m.get("help", ""), lnames, buckets=buckets)
                for s in samples:
                    k = tuple(str(s["labels"][ln]) for ln in lnames)
                    met._series[k] = [list(s["counts"]), float(s["sum"]), int(s["count"])]
            else:
                mk = reg.counter if m["type"] == "counter" else reg.gauge
                met = mk(m["name"], m.get("help", ""), lnames)
                for s in samples:
                    k = tuple(str(s["labels"][ln]) for ln in lnames)
                    met._series[k] = float(s["value"])
        return reg

    def clear(self) -> None:
        """Drop every metric (tests / bench isolation)."""
        with self._lock:
            self._metrics.clear()

    # -- exporters ---------------------------------------------------------

    def to_json(self) -> dict:
        """The ``repro.obs.metrics/v1`` export (what METRICS.json holds)."""
        return {
            "schema": SCHEMA,
            "metrics": [
                m.to_json() for _, m in sorted(self._metrics.items())
            ],
        }

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one scrape body)."""
        out = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                out.append(f"# HELP {name} {m.help}")
            out.append(f"# TYPE {name} {m.kind}")
            if m.kind == "histogram":
                for s in m.samples():
                    base = dict(s["labels"])
                    cum = 0
                    for edge, c in zip(s["buckets"], s["counts"]):
                        cum += c
                        out.append(
                            f"{name}_bucket{_fmt_labels({**base, 'le': _fmt_edge(edge)})} {cum}"
                        )
                    cum += s["counts"][-1]
                    out.append(f"{name}_bucket{_fmt_labels({**base, 'le': '+Inf'})} {cum}")
                    out.append(f"{name}_sum{_fmt_labels(base)} {_fmt_val(s['sum'])}")
                    out.append(f"{name}_count{_fmt_labels(base)} {s['count']}")
            else:
                for s in m.samples():
                    out.append(f"{name}{_fmt_labels(s['labels'])} {_fmt_val(s['value'])}")
        return "\n".join(out) + "\n"


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    body = ",".join(f'{k}="{_escape(str(v))}"' for k, v in sorted(labels.items()))
    return "{" + body + "}"


def _fmt_edge(edge: float) -> str:
    return repr(edge) if edge != int(edge) else str(int(edge))


def _fmt_val(v: float) -> str:
    return repr(float(v)) if v != int(v) else str(int(v))


# -- the process-global registry --------------------------------------------

_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-global registry every subsystem records into."""
    return _REGISTRY


def reset() -> None:
    """Clear the global registry's series (tests / bench isolation)."""
    _REGISTRY.clear()


# -- SearchStats aggregation --------------------------------------------------


def record_search_stats(stats, *, labels: dict | None = None, reg=None) -> None:
    """Fold one device-side ``SearchStats`` pytree into host counters.

    Call ONLY at an existing sync point (after ``block_until_ready`` or an
    ``np.asarray`` of the results): the ``np.asarray`` here then reads
    already-transferred buffers instead of forcing a new device sync —
    that is the whole sync-point-aggregation contract (DESIGN.md
    §Observability).  No-ops when disabled.

    The search counters share one canonical label schema —
    ``(bucket, shard, tenant)`` — whatever subset the caller supplies;
    absent dimensions record as ``""`` (Prometheus treats an empty label
    value as unset).  A fixed schema is what lets the serving layer
    (bucket labels), the distributed layer (shard labels) and the
    multi-tenant collection layer (tenant labels) fold into the same
    series family in one process without a labelname redeclaration
    conflict.  Pre-tenancy ``(bucket, shard)`` exports stay valid:
    re-importing them just lacks the ``tenant`` dimension, and new
    recorders default it to ``""``.
    """
    if not _ENABLED:
        return
    import numpy as np

    r = reg or _REGISTRY
    lnames = ("bucket", "shard", "tenant")
    given = dict(labels or {})
    unknown = set(given) - set(lnames)
    if unknown:
        raise ValueError(
            f"record_search_stats labels {sorted(unknown)} outside the "
            f"canonical schema {lnames}"
        )
    lab = {k: str(given.get(k, "")) for k in lnames}

    def tot(x) -> float:
        return float(np.asarray(x).sum())

    n_queries = int(np.asarray(stats.mode).size)
    r.counter(
        "compass_queries_total", "queries folded into the registry", lnames
    ).inc(n_queries, **lab)
    for metric, field, help in (
        ("compass_dist_total", stats.n_dist, "full-precision distance computations (paper #Comp)"),
        ("compass_cdist_total", stats.n_cdist, "centroid distance computations"),
        ("compass_steps_total", stats.n_steps, "driver loop iterations"),
        ("compass_bcalls_total", stats.n_bcalls, "relational (B.NEXT) injections"),
        ("compass_clusters_ranked_total", stats.n_clusters_ranked, "clusters opened by B.NEXT"),
        ("compass_adc_total", stats.n_adc, "quantized ADC table scores"),
        ("compass_rerank_total", stats.n_rerank, "stage-two exact rerank rows"),
        ("compass_pass_total", stats.n_pass, "predicate-passing live rows encountered"),
    ):
        r.counter(metric, help, lnames).inc(tot(field), **lab)
    # Planner-calibration drift: per-query |est_sel - n_pass/n_seen|
    # accumulated as (sum, count) counters so windowed deltas recover the
    # rolling mean absolute error (obs/health.py's planner watchdog).
    # n_seen counts candidate rows scored (full-precision + ADC); with
    # quantized full rerank the reranked rows are scored twice — a small
    # downward bias on `actual`, acceptable against the coarse WARN/CRIT
    # thresholds.  Queries with no estimate (est_sel < 0) or no seen rows
    # contribute nothing.
    def per_q(x):
        a = np.asarray(x, dtype=np.float64).ravel()
        if a.size == n_queries:
            return a
        return np.full(n_queries, float(a[0]) if a.size else 0.0)

    est = per_q(stats.est_sel)
    n_seen = per_q(stats.n_dist) + per_q(stats.n_adc)
    obs_mask = (est >= 0.0) & (n_seen > 0)
    if obs_mask.any():
        actual = np.clip(per_q(stats.n_pass)[obs_mask] / n_seen[obs_mask], 0.0, 1.0)
        err = np.abs(np.clip(est[obs_mask], 0.0, 1.0) - actual)
        r.counter(
            "compass_sel_abs_err_sum",
            "summed |estimated - observed| selectivity per query",
            lnames,
        ).inc(float(err.sum()), **lab)
        r.counter(
            "compass_sel_obs_total",
            "queries contributing a selectivity calibration observation",
            lnames,
        ).inc(int(obs_mask.sum()), **lab)

    from repro.core.planner.plan import MODE_NAMES  # lazy: no import cycle

    modes = np.asarray(stats.mode).ravel()
    c = r.counter(
        "compass_mode_total", "planner-chosen execution modes", lnames + ("mode",)
    )
    for mid, mname in enumerate(MODE_NAMES):
        n = int((modes == mid).sum())
        if n:
            c.inc(n, mode=mname, **lab)


# -- export validation --------------------------------------------------------


def validate_export(payload) -> list[str]:
    """Schema-validate a ``to_json()`` export; returns problems (empty ==
    valid).  This is the CI gate behind METRICS.json — the checks mirror
    the Prometheus data model: legal names, known types, finite
    non-negative counters, ascending histogram buckets with
    ``len(counts) == len(buckets) + 1`` and ``sum(counts) == count``."""
    errs = []
    if not isinstance(payload, dict):
        return [f"top level is {type(payload).__name__}, expected object"]
    if payload.get("schema") != SCHEMA:
        errs.append(f"schema is {payload.get('schema')!r}, expected {SCHEMA!r}")
    metrics = payload.get("metrics")
    if not isinstance(metrics, list):
        return errs + ["metrics is not a list"]
    seen = set()
    for i, m in enumerate(metrics):
        if not isinstance(m, dict):
            errs.append(f"metrics[{i}] is not an object")
            continue
        name = m.get("name", f"<metrics[{i}]>")
        if not isinstance(name, str) or not _NAME_RE.match(name):
            errs.append(f"metrics[{i}]: invalid name {name!r}")
        if name in seen:
            errs.append(f"{name}: duplicate metric name")
        seen.add(name)
        kind = m.get("type")
        if kind not in METRIC_TYPES:
            errs.append(f"{name}: unknown type {kind!r}")
        labelnames = m.get("labelnames")
        if not isinstance(labelnames, list) or any(
            not isinstance(ln, str) or not _LABEL_RE.match(ln) for ln in labelnames
        ):
            errs.append(f"{name}: malformed labelnames {labelnames!r}")
        samples = m.get("samples")
        if not isinstance(samples, list):
            errs.append(f"{name}: samples is not a list")
            continue
        for j, s in enumerate(samples):
            if not isinstance(s, dict) or not isinstance(s.get("labels"), dict):
                errs.append(f"{name}: sample {j} malformed")
                continue
            if isinstance(labelnames, list) and set(s["labels"]) != set(labelnames):
                errs.append(f"{name}: sample {j} labels != labelnames")
            if kind == "histogram":
                b, c = s.get("buckets"), s.get("counts")
                if not isinstance(b, list) or sorted(b) != b or len(set(b)) != len(b):
                    errs.append(f"{name}: sample {j} buckets not ascending")
                elif not isinstance(c, list) or len(c) != len(b) + 1:
                    errs.append(
                        f"{name}: sample {j} len(counts) != len(buckets)+1"
                    )
                elif any(not isinstance(x, int) or x < 0 for x in c):
                    errs.append(f"{name}: sample {j} negative/non-int bucket count")
                elif s.get("count") != sum(c):
                    errs.append(f"{name}: sample {j} count != sum(counts)")
                if not isinstance(s.get("sum"), (int, float)) or not math.isfinite(
                    s.get("sum", math.nan)
                ):
                    errs.append(f"{name}: sample {j} non-finite sum")
            else:
                v = s.get("value")
                if not isinstance(v, (int, float)) or not math.isfinite(v):
                    errs.append(f"{name}: sample {j} non-finite value {v!r}")
                elif kind == "counter" and v < 0:
                    errs.append(f"{name}: sample {j} negative counter {v}")
    return errs


def validate_file(path: str) -> list[str]:
    """``validate_export`` over a file on disk (unreadable == invalid)."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable/malformed JSON: {e}"]
    return validate_export(payload)
