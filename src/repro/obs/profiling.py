"""Kernel profiling hooks: named scopes, trace capture, route counters.

Three layers, all result-invariant:

* :func:`kernel_scope` wraps each Pallas kernel wrapper (kernels/ops.py)
  in ``jax.named_scope`` (HLO metadata — the kernel shows up under
  ``compass/<name>`` in a device trace) plus ``jax.profiler
  .TraceAnnotation`` (host timeline), and bumps the per-kernel wrapper
  counter.  named_scope only decorates metadata on ops traced inside it,
  so the compiled program is identical with or without the scope.
* :func:`annotate` is the host-phase sibling (no HLO scope) used around
  the serving micro-batch dispatch.
* :func:`profile_capture` drives ``jax.profiler.start_trace`` /
  ``stop_trace`` and dumps an XPlane trace dir (load it in TensorBoard or
  convert to perfetto) when ``REPRO_OBS_PROFILE`` is set — either ``1``
  (default dir ``./obs-profile``) or a target directory path.

Counter semantics: the kernel/fallback/autotune counters record at
**wrapper-call time**, which inside a jit means *trace time* — once per
compiled program, not per execution (exactly the semantics of the
``visit_step.TRACE_COUNT`` tripwire they generalize).  They record even
when observability is disabled: a silent ref fallback during a disabled
trace would otherwise be invisible forever, the cost is a dict add per
*compile*, and steady-state dispatch never re-enters the wrapper.
"""
from __future__ import annotations

import contextlib
import os

import jax

from . import registry as R

#: every Pallas kernel the repo ships (the five wrapped in kernels/ops.py)
KERNELS = (
    "filter_distance",
    "visit_step",
    "ivf_score",
    "pq_score",
    "flash_attention",
)


def count_kernel(kernel: str) -> None:
    """One kernel-wrapper entry (trace time inside jit)."""
    R.registry().counter(
        "compass_kernel_traces_total",
        "kernel wrapper entries (trace-time inside jit)",
        ("kernel",),
    ).inc(1, kernel=kernel)


def count_fallback(kernel: str, reason: str) -> None:
    """A kernel wrapper routed to the jnp reference path instead of the
    Pallas kernel — the silent fallback the CI tripwire hunts, now a
    runtime-visible counter."""
    R.registry().counter(
        "compass_kernel_fallback_total",
        "kernel calls routed to the jnp reference path",
        ("kernel", "reason"),
    ).inc(1, kernel=kernel, reason=reason)


def count_autotune(kernel: str, source: str) -> None:
    """One autotune block-config resolution, labeled by where the config
    came from: ``pin`` (env override), ``table`` (measured cache hit),
    ``measured`` (fresh probe), ``default`` (candidates[0])."""
    R.registry().counter(
        "compass_autotune_total",
        "autotune block-config resolutions by source",
        ("kernel", "source"),
    ).inc(1, kernel=kernel, source=source)


@contextlib.contextmanager
def kernel_scope(name: str):
    """Wrap one kernel launch: named_scope + TraceAnnotation + counter."""
    count_kernel(name)
    with jax.named_scope(f"compass/{name}"), jax.profiler.TraceAnnotation(
        f"compass/{name}"
    ):
        yield


@contextlib.contextmanager
def annotate(name: str):
    """Host-phase timeline annotation (serving micro-batch path)."""
    with jax.profiler.TraceAnnotation(name):
        yield


def profile_dir() -> str | None:
    """The capture target from ``REPRO_OBS_PROFILE`` (None = capture off)."""
    v = os.environ.get("REPRO_OBS_PROFILE", "")
    if v in ("", "0"):
        return None
    return "obs-profile" if v == "1" else v


@contextlib.contextmanager
def profile_capture(out_dir: str | None = None, force: bool = False):
    """Capture an XPlane/perfetto trace dir around the with-body.

    Gated on ``REPRO_OBS_PROFILE`` unless ``force=True`` (tests); yields
    the trace directory, or None when capture is off.  The profiler
    writes TensorBoard-loadable XPlane protos plus a ``perfetto`` trace
    under ``<dir>/plugins/profile/<run>/``.
    """
    target = out_dir if out_dir is not None else profile_dir()
    if target is None and force:
        target = "obs-profile"
    if target is None:
        yield None
        return
    os.makedirs(target, exist_ok=True)
    jax.profiler.start_trace(target)
    try:
        yield target
    finally:
        jax.profiler.stop_trace()
