"""Training step: loss, grad accumulation (microbatching), remat, AdamW.

Grad accumulation is a ``lax.scan`` over microbatches — each microbatch's
activations die before the next starts, bounding live activation memory to
one microbatch regardless of global batch (the knob §Perf uses against the
memory roofline term).  Optional int8 error-feedback compression wraps the
cross-pod gradient reduction (optim.compression).
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import forward
from repro.optim.adamw import AdamWConfig, OptState, apply_updates


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    n_microbatches: int = 1
    remat: bool = True
    unroll: bool = False  # unroll layer scans (dry-run cost calibration)
    act_sharding: object = None
    ep: object = None  # EPContext for expert-parallel MoE
    z_loss: float = 1e-4
    moe_aux_weight: float = 1e-2


def cross_entropy(logits: jax.Array, labels: jax.Array, z_loss: float = 0.0):
    """Vocab-sharding-friendly CE: logsumexp reduces over the (possibly
    sharded) vocab axis via an all-reduce; no replicated (B,S,V) f32 copy."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - picked)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


def make_loss_fn(cfg: ModelConfig, tc: TrainConfig):
    def loss_fn(params, batch):
        kw = {}
        if "inputs_embeds" in batch:
            kw["inputs_embeds"] = batch["inputs_embeds"]
        else:
            kw["tokens"] = batch["tokens"]
        if "prefix_embeds" in batch:
            kw["prefix_embeds"] = batch["prefix_embeds"]
        logits, _ = forward(params, cfg, remat=tc.remat, unroll=tc.unroll, act_sharding=tc.act_sharding, ep=tc.ep, **kw)
        s = batch["labels"].shape[1]
        logits = logits[:, -s:, :]  # drop vlm prefix positions
        return cross_entropy(logits, batch["labels"], tc.z_loss)

    return loss_fn


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    batch leaves have leading dim global_batch; microbatching reshapes to
    (n_micro, micro, ...) and scans.
    """
    loss_fn = make_loss_fn(cfg, tc)

    def step(params, opt_state: OptState, batch):
        nm = tc.n_microbatches

        if nm == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def resh(x):
                return x.reshape((nm, x.shape[0] // nm) + x.shape[1:])

            mb = jax.tree.map(resh, batch)

            def accum(carry, micro):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, micro)
                return (
                    loss_acc + l / nm,
                    jax.tree.map(lambda a, b: a + b / nm, grad_acc, g),
                ), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(accum, (jnp.float32(0.0), zeros), mb)

        new_params, new_opt, metrics = apply_updates(params, grads, opt_state, tc.optimizer)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return step
