"""Gradient compression for cross-pod all-reduce.

At 2+ pods the data-parallel gradient all-reduce crosses the (slow)
inter-pod links; int8 block-quantized compression with error feedback cuts
those bytes 4x(vs f32)/2x(vs bf16) at negligible quality cost.  This is the
standard large-scale distributed-optimization trick (1-bit Adam family) in
its simplest robust form:

    q = round(g / s),  s = max|g| per block   (int8 payload + f32 scale)
    residual r = g - q * s   (carried to the next step: error feedback)

Usage: wrap the gradient tree before `jax.lax.pmean`-style reduction on the
'pod' axis; the all-reduce then moves int8.  Under jit+pjit the quantized
tree simply reduces over the pod axis like any other pytree.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressedGrads(NamedTuple):
    q: Any  # int8 tree
    scale: Any  # f32 per-block scales


def _block_shape(x: jax.Array, block: int):
    n = x.size
    pad = (-n) % block
    return n, pad


def quantize(grads, block: int = 256):
    """int8 block quantization with per-block absmax scales."""

    def one(g):
        g = g.astype(jnp.float32)
        n = g.size
        pad = (-n) % block
        flat = jnp.pad(g.reshape(-1), (0, pad)).reshape(-1, block)
        s = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
        s = jnp.maximum(s, 1e-12)
        q = jnp.clip(jnp.round(flat / s), -127, 127).astype(jnp.int8)
        return q, s[:, 0]

    qs = jax.tree.map(one, grads)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda t: isinstance(t, tuple))
    return CompressedGrads(q, s)


def dequantize(c: CompressedGrads, like, block: int = 256):
    def one(q, s, ref):
        flat = q.astype(jnp.float32) * s[:, None]
        return flat.reshape(-1)[: ref.size].reshape(ref.shape)

    return jax.tree.map(one, c.q, c.scale, like)


def compress_with_feedback(grads, residual, block: int = 256):
    """Error-feedback compression: returns (compressed, new_residual).

    new_residual = (g + residual) - dequant(quant(g + residual))
    """
    if residual is not None:
        grads = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    c = quantize(grads, block)
    deq = dequantize(c, grads, block)
    new_residual = jax.tree.map(lambda g, d: g.astype(jnp.float32) - d, grads, deq)
    return c, new_residual


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
