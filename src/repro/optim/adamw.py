"""AdamW + global-norm clipping + schedules, dependency-free.

State layout mirrors the param pytree so FSDP-style sharding rules apply to
optimizer state unchanged (m/v shard exactly like their parameter).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_opt_state(params) -> OptState:
    """Moments are always f32 (params may be stored bf16)."""
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(jnp.zeros((), jnp.int32), jax.tree.map(f32, params), jax.tree.map(f32, params))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: (g * scale).astype(jnp.float32), grads)

    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state.v, grads)
    mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** step.astype(jnp.float32)), m)
    vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** step.astype(jnp.float32)), v)

    def upd(p, mh_, vh_):
        u = mh_ / (jnp.sqrt(vh_) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mh, vh)
    return new_params, OptState(step, m, v), {"grad_norm": gnorm, "lr": lr}
