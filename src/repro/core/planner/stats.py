"""Attribute statistics for the cost-based query planner (DESIGN.md §Planner).

Two complementary sources feed the planner, both derived from the same
clustered layout the relational iterator already uses:

* **Equi-depth histograms** — built host-side at index time and stored on
  :class:`~repro.core.index.CompassIndex` as :class:`AttrStats`.  Per
  attribute we keep quantile *edges*, globally (``edges``) and per cluster
  (``cluster_edges``).  Equi-depth rather than equi-width because the
  selectivity of a range predicate is then a CDF difference read off a
  piecewise-linear interpolation with bounded error (≤ ~1/n_bins per
  lookup) *regardless of value skew* — the classic DB-optimizer choice.
  Histograms are tiny (``(nlist, A, n_cluster_bins+1)`` f32) and live on
  device, so estimation is fully traceable inside the jitted search.

* **Exact run probes** — :func:`term_run_bounds` runs vmapped fixed-depth
  binary searches over the existing ``ClusteredAttrs`` sorted runs, giving
  the *exact* per-cluster count of records matching each DNF term's chosen
  attribute range.  ``sum(end - beg)`` upper-bounds (single-attribute
  terms: equals) the true pass count, and the bounds double as the
  PREFILTER mode's materialization cursors, so the probe cost is never
  wasted.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..clustered_attrs import ClusteredAttrs, run_bounds_all_clusters


class AttrStats(NamedTuple):
    """Per-attribute equi-depth histogram edges, global and per-cluster.

    Empty clusters get all-zero edges; their ``cluster_counts`` entry is 0
    so they contribute nothing to any estimate.
    """

    edges: jax.Array  # (A, n_bins + 1) f32, ascending
    cluster_edges: jax.Array  # (nlist, A, n_cluster_bins + 1) f32
    cluster_counts: jax.Array  # (nlist,) f32 records per cluster

    @property
    def n_attrs(self) -> int:
        return self.edges.shape[0]

    @property
    def n_clusters(self) -> int:
        return self.cluster_edges.shape[0]


def build_attr_stats(
    attrs: np.ndarray,
    assignments: np.ndarray,
    nlist: int,
    *,
    n_bins: int = 64,
    n_cluster_bins: int = 8,
    live: np.ndarray | None = None,
) -> AttrStats:
    """Host-side build (index time): quantile edges per attr, per cluster.

    **Live-row discipline** (the bucket-fold contract, DESIGN.md
    §Mutability): statistics must cover *live* rows only.  Dead rows —
    tombstones awaiting compaction, or the dead padding a bucketed fold
    appends — must contribute nothing, or they skew histogram mass and
    inflate ``cluster_counts``, the denominator every selectivity estimate
    divides by (planner/estimate.py).  ``fold_index`` upholds this by
    building stats over the real rows *before* padding; ``live`` is the
    explicit escape hatch for callers whose row table already contains
    dead rows (a (n,) bool mask — False rows are dropped before any
    quantile or count).
    """
    attrs = np.asarray(attrs, np.float32)
    assignments = np.asarray(assignments, np.int64)
    if live is not None:
        live = np.asarray(live, bool)
        attrs = attrs[live]
        assignments = assignments[live]
    n, n_attrs = attrs.shape
    qs_g = np.linspace(0.0, 1.0, n_bins + 1)
    qs_c = np.linspace(0.0, 1.0, n_cluster_bins + 1)
    edges = np.stack([np.quantile(attrs[:, a], qs_g) for a in range(n_attrs)]).astype(
        np.float32
    )
    cluster_edges = np.zeros((nlist, n_attrs, n_cluster_bins + 1), np.float32)
    counts = np.bincount(assignments, minlength=nlist).astype(np.float32)
    for c in range(nlist):
        members = attrs[assignments == c]
        if members.shape[0] == 0:
            continue
        for a in range(n_attrs):
            cluster_edges[c, a] = np.quantile(members[:, a], qs_c)
    return AttrStats(
        jnp.asarray(edges), jnp.asarray(cluster_edges), jnp.asarray(counts)
    )


def term_run_bounds(ca: ClusteredAttrs, pred_lo, pred_hi, chosen):
    """Exact chosen-attr run bounds for every (term, cluster) pair.

    pred_lo / pred_hi: (T, A) interval tensors; chosen: (T,) driving attr
    per term (``predicate.chosen_attrs``).  Returns (beg, end), each
    (T, nlist) int32 — the planner's exact probes and the PREFILTER
    materialization cursors.  All inputs may be traced.
    """
    T = pred_lo.shape[0]

    def one_term(t):
        a = chosen[t]
        return run_bounds_all_clusters(ca, a, pred_lo[t, a], pred_hi[t, a])

    beg, end = jax.vmap(one_term)(jnp.arange(T))
    return beg, end
