"""Traceable DNF selectivity estimation over equi-depth histograms.

Every function here is pure jnp over :class:`~repro.core.planner.stats.
AttrStats` arrays — no host round-trip — so estimation composes into the
jitted search (the planner runs per query *inside* ``compass_search``).

Composition rules (classic System-R style, independence-bounded):

* range mass per attribute: ``F(hi) - F(lo)`` where ``F`` is the
  piecewise-linear CDF through the equi-depth edges;
* conjunction (one DNF term): product over constrained attributes
  (attribute independence);
* disjunction (across terms): ``1 - prod_t (1 - sel_t)`` (term
  independence) — exact for disjoint terms, an overestimate-bounded
  approximation otherwise, never below ``max_t sel_t``.

Both rules are monotone in every interval bound, so widening any range can
only increase the estimate (property-tested in tests/test_planner.py).
Unconstrained attributes carry ``[-FLT_MAX, FLT_MAX]`` bounds which clamp
to mass 1.0, and the unsatisfiable pad terms the serving layer appends
(``lo > hi``) clamp to mass 0.0 — padding never changes an estimate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .stats import AttrStats


def cdf(edges: jax.Array, x) -> jax.Array:
    """P(attr <= x) from one attribute's equi-depth edges (traceable).

    Piecewise-linear through the ``n_bins + 1`` quantile edges; clamps to
    0 / 1 outside the observed range.
    """
    nb = edges.shape[-1] - 1
    return jnp.interp(x, edges, jnp.linspace(0.0, 1.0, nb + 1))


def interval_mass(edges: jax.Array, lo, hi) -> jax.Array:
    """Estimated fraction of values in the closed interval [lo, hi]."""
    return jnp.clip(cdf(edges, hi) - cdf(edges, lo), 0.0, 1.0)


def term_selectivity(edges_set: jax.Array, lo_row: jax.Array, hi_row: jax.Array):
    """One conjunctive term over one edge set (A, nb+1): prod of masses."""
    per_attr = jax.vmap(interval_mass)(edges_set, lo_row, hi_row)  # (A,)
    return jnp.prod(per_attr)


def dnf_selectivity(edges_set: jax.Array, pred_lo: jax.Array, pred_hi: jax.Array):
    """Full (T, A) DNF predicate over one edge set: independence union."""
    sel_t = jax.vmap(lambda lo, hi: term_selectivity(edges_set, lo, hi))(
        pred_lo, pred_hi
    )  # (T,)
    return 1.0 - jnp.prod(1.0 - sel_t)


def estimate_matches(astats: AttrStats, pred_lo: jax.Array, pred_hi: jax.Array):
    """Cluster-refined estimate of (match count, selectivity) for one query.

    Evaluates the DNF against each cluster's local histograms and sums
    ``n_c * sel_c`` — sharper than the global histogram whenever attribute
    distributions differ across clusters (e.g. mode-correlated attrs).
    Returns (est_matches () f32, est_sel () f32).
    """
    per_cluster = jax.vmap(lambda ce: dnf_selectivity(ce, pred_lo, pred_hi))(
        astats.cluster_edges
    )  # (nlist,)
    total = jnp.sum(astats.cluster_counts)
    est = jnp.sum(astats.cluster_counts * per_cluster)
    return est, est / jnp.maximum(total, 1.0)


def estimate_selectivity_global(astats: AttrStats, pred_lo, pred_hi):
    """Selectivity from the global per-attribute histograms only (cheaper,
    no per-cluster refinement) — used by tests and offline calibration."""
    return dnf_selectivity(astats.edges, pred_lo, pred_hi)
