"""Cost-based query planner: attribute statistics, traceable selectivity
estimation, and per-query execution-mode selection (DESIGN.md §Planner).

  * :mod:`~repro.core.planner.stats`    — equi-depth histograms built at
    index time (stored on :class:`~repro.core.index.CompassIndex`) plus
    exact per-cluster run probes over the clustered sorted runs.
  * :mod:`~repro.core.planner.estimate` — traceable DNF selectivity
    estimation (independence-composed range masses).
  * :mod:`~repro.core.planner.plan`     — the calibrated cost model and the
    PREFILTER / COOPERATIVE / POSTFILTER decision + materialization that
    the engine driver dispatches on.
"""
from .estimate import estimate_matches, estimate_selectivity_global
from .plan import (
    COOPERATIVE,
    MODE_NAMES,
    POSTFILTER,
    PREFILTER,
    PlannedBatch,
    QueryPlan,
    plan_batch,
    plan_query,
)
from .stats import AttrStats, build_attr_stats, term_run_bounds

__all__ = [
    "COOPERATIVE",
    "MODE_NAMES",
    "POSTFILTER",
    "PREFILTER",
    "AttrStats",
    "PlannedBatch",
    "QueryPlan",
    "build_attr_stats",
    "estimate_matches",
    "estimate_selectivity_global",
    "plan_batch",
    "plan_query",
    "term_run_bounds",
]
