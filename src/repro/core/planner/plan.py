"""Cost-based per-query execution-mode selection (DESIGN.md §Planner).

Compass's robustness claim is that cooperative G.NEXT/B.NEXT execution stays
competitive across selectivity regimes — but at the extremes a specialized
plan is strictly better, and the filtered-ANN literature (JAG, the 2026
survey) puts the prefilter/graph crossover as the single biggest lever.
The planner closes that gap *inside* the jitted batch: per query it picks
one of three modes from attribute statistics, with no host round-trip.

  * ``PREFILTER``   — the exact chosen-attr runs are small enough
    (``run_total <= prefilter_cap``, i.e. estimated matches ≲ O(ef)) that
    materializing them and running one fused ``filter_distance`` top-k scan
    is cheaper than any graph walk — and exact: every record passing a DNF
    term appears in that term's chosen-attr run, so scanning all runs is a
    brute-force filtered scan over a superset of the matches.
  * ``COOPERATIVE`` — the paper's Algorithm 1 loop (the robust default).
  * ``POSTFILTER``  — selectivity ≈ 1: the filter is nearly vacuous, the
    relational iterator can only inject attribute-ordered (distance-random)
    candidates, so run graph-dominant (B.NEXT disabled).

Mode dispatch is traceable: the driver branches on the (traced) mode with
``lax.cond``; under ``vmap`` both branches execute masked, which is exactly
the TPU-correct trade — the PREFILTER scan is a bounded ``prefilter_cap``-row
kernel and an all-COOPERATIVE batch skips the scan entirely through the
batch-level ``lax.cond`` in :func:`plan_batch` (a *scalar* predicate, so it
stays a real branch after jit).

Cost model: single-dimensional "row units" (one fused scan row ≈ 1).  The
constants below were calibrated on the bench_planner sweep (CPU interpret
path; see DESIGN.md §Planner for the recalibration recipe — rerun the sweep,
fit per-query wall clock against ``run_total`` / ``ef``).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, NamedTuple

import jax
import jax.numpy as jnp

from .. import predicate as P
from ..engine.state import dedup_new
from . import estimate as E
from .stats import term_run_bounds

if TYPE_CHECKING:  # runtime import would cycle: index builds planner stats
    from ..index import CompassIndex

# Execution modes (stats.mode values; order matters: argmin over the cost
# vector [prefilter, cooperative, postfilter] yields the mode id).
PREFILTER, COOPERATIVE, POSTFILTER = 0, 1, 2
MODE_NAMES = ("prefilter", "cooperative", "postfilter")

# -- calibrated cost-model constants (row units) ----------------------------
# The binary-search probes themselves are deliberately NOT charged to any
# arm: they run in plan_query before mode selection, for every mode alike,
# so they are a sunk cost that must not bias the decision.
COST_PRE_ROW = 1.0  # score one materialized run row (fused gather+dist+pred)
COST_COOP_EF = 8.0  # per result-slot cost of the cooperative loop: queue
#   sorts + beam visits dominate and are ~flat in selectivity (the paper's
#   robustness result), so cost ≈ COST_COOP_EF * ef.
COST_POST_ROW = 1.5  # per-visit cost of the graph-only loop; the loop must
#   oversample by 1/selectivity to fill ef passing results.  The fused
#   visit_step kernel (engine/5) cheapens a visited row on the compiled
#   path, but it cheapens COOPERATIVE and POSTFILTER visits identically —
#   both modes score through the same backend.visit_step — so the
#   *relative* constants here are unchanged; bench_kernels' visit_step
#   rows are the tracking artifact for the absolute per-row cost.
SEL_FLOOR = 1e-4  # avoid division blow-up on est_sel ~ 0
# -- quantized-tier costs (CompassParams.quant active) ----------------------
# ADC scores a row with m table lookups instead of a d-dim gather+reduce:
# bytes moved drop from 4*d to m per row, so a scanned row is ~4x cheaper.
# Calibration source: bench_quant's scan microbench (adc_scan vs exact_scan
# wall per row).  Last measured at n=20000, d=48, m ∈ {4, 8, 16}:
# cost_adc/cost_exact = 0.24 / 0.31 / 0.19 — flat in m because the
# (V, m) LUT gathers, not the arithmetic, dominate the scan on this path.
COST_ADC_ROW = 0.25
COST_RERANK_ROW = 1.0


class QueryPlan(NamedTuple):
    """Per-query plan: chosen mode + the PREFILTER materialization."""

    mode: jax.Array  # () int32: PREFILTER | COOPERATIVE | POSTFILTER
    est_sel: jax.Array  # () f32 estimated DNF selectivity
    run_total: jax.Array  # () int32 exact total chosen-attr run size
    ids: jax.Array  # (prefilter_cap,) int32 materialized candidate ids
    mask: jax.Array  # (prefilter_cap,) bool valid (deduped) slots


class PlannedBatch(NamedTuple):
    """Batch of plans + pre-scored PREFILTER candidates (driver input)."""

    mode: jax.Array  # (B,) int32
    est_sel: jax.Array  # (B,) f32
    run_total: jax.Array  # (B,) int32
    ids: jax.Array  # (B, cap) int32
    mask: jax.Array  # (B, cap) bool — valid & mode == PREFILTER
    dist: jax.Array  # (B, cap) f32, +inf where masked
    passing: jax.Array  # (B, cap) bool full-DNF pass


def plan_query(index: CompassIndex, pred_lo, pred_hi, pm, quant: bool = False) -> QueryPlan:
    """Plan one query (traceable; vmapped over the batch by plan_batch).

    pred_lo / pred_hi: (T, A) DNF interval tensors.  ``pm`` must be
    resolved (``prefilter_cap`` > 0).  With ``quant`` (static) the cost
    model prices scanned/visited rows at the ADC rate and adds each arm's
    exact-rerank bill; ``pm.ef`` is then already the widened stage-one
    queue (ef * refine_factor — the driver rewrites it before planning).
    """
    ca = index.cattrs
    nlist = index.nlist
    cap = pm.prefilter_cap
    T = pred_lo.shape[0]
    chosen = P.chosen_attrs(P.Predicate(pred_lo, pred_hi))

    # exact probes (these double as the materialization cursors)
    beg, end = term_run_bounds(ca, pred_lo, pred_hi, chosen)  # (T, nlist)
    rem = jnp.maximum(end - beg, 0)
    run_total = jnp.sum(rem).astype(jnp.int32)

    # histogram estimate (cluster-refined)
    _, est_sel = E.estimate_matches(index.astats, pred_lo, pred_hi)

    # cost model -> mode
    if quant:
        # ADC rows are cheap; the exact rerank of the survivors is not.
        # PREFILTER's queue holds at most its run_total matches, the loop
        # modes rerank the full widened queue (ef here == ef * refine).
        rerank_pre = COST_RERANK_ROW * jnp.minimum(run_total, pm.ef)
        rerank_loop = jnp.float32(COST_RERANK_ROW * pm.ef)
        cost_pre = jnp.where(
            run_total <= cap, COST_ADC_ROW * run_total + rerank_pre, jnp.inf
        )
        cost_coop = jnp.float32(COST_COOP_EF * pm.ef) + rerank_loop
        post_row = COST_POST_ROW * COST_ADC_ROW / COST_PRE_ROW
    else:
        rerank_loop = jnp.float32(0.0)
        cost_pre = jnp.where(run_total <= cap, COST_PRE_ROW * run_total, jnp.inf)
        cost_coop = jnp.float32(COST_COOP_EF * pm.ef)
        post_row = COST_POST_ROW
    if pm.use_graph:
        cost_post = jnp.where(
            est_sel >= pm.postfilter_min_sel,
            post_row * pm.ef / jnp.maximum(est_sel, SEL_FLOOR) + rerank_loop,
            jnp.inf,
        )
    else:  # CompassRelational ablation: no graph to run POSTFILTER on
        cost_post = jnp.float32(jnp.inf)
    mode = jnp.argmin(jnp.stack([cost_pre, cost_coop, cost_post])).astype(jnp.int32)

    # materialize up to `cap` run positions, term-major then cluster-major
    # (same slot->segment mapping as B.NEXT's fetch, over all T*nlist runs)
    flat_beg = beg.reshape(-1)
    flat_rem = rem.reshape(-1)
    cum = jnp.cumsum(flat_rem)
    total = cum[-1]
    slots = jnp.arange(cap, dtype=jnp.int32)
    seg = jnp.clip(
        jnp.searchsorted(cum, slots, side="right").astype(jnp.int32), 0, T * nlist - 1
    )
    before = jnp.where(seg > 0, cum[jnp.maximum(seg - 1, 0)], 0)
    pos = flat_beg[seg] + (slots - before)
    ok = slots < jnp.minimum(total, cap)
    attr_of = chosen[seg // nlist]
    ids = ca.order[attr_of, jnp.clip(pos, 0, ca.n_records - 1)]
    # a record can sit in several terms' runs (disjunctions) — same
    # duplicate-drop the engine applies to visit lists
    mask = dedup_new(ids, ok)
    return QueryPlan(mode, est_sel, run_total, ids, mask)


def plan_batch(
    index: CompassIndex, queries, pred: P.Predicate, pm, backend, luts=None, q_resids=None
) -> PlannedBatch:
    """Plan every query in the batch and pre-score the PREFILTER candidates.

    The candidate scan is hoisted out of the per-query vmap (like the
    centroid ranking) so the pallas backend sees one blocked (B, cap)
    ``filter_distance`` problem, and it is guarded by a *batch-level*
    ``lax.cond`` on "any query chose PREFILTER" — a scalar predicate, so an
    all-COOPERATIVE batch pays only the probes, not the scan.

    With ``luts``/``q_resids`` (the quantized tier: per-query (m, ks) ADC
    tables + centered residual queries, built by the driver), the scan runs
    over the PQ codes instead (``scan_scores_quantized`` — the pq_score
    kernel's (B, cap) grid) and the cost model prices rows at the ADC rate;
    the materialized candidates then carry ADC distances, which stage two's
    exact rerank re-scores like every other quantized result.
    """
    if index.astats is None:
        raise ValueError(
            "CompassParams(planner=True) requires index attribute statistics; "
            "rebuild the index with build_index (build_attr_stats) first"
        )
    quant = luts is not None
    plans = jax.vmap(lambda lo, hi: plan_query(index, lo, hi, pm, quant))(
        pred.lo, pred.hi
    )
    scan_mask = plans.mask & (plans.mode == PREFILTER)[:, None]
    b, cap = scan_mask.shape

    def do_scan(_):
        if quant:
            dist, passing = backend.scan_scores_quantized(
                index, q_resids, luts, pred, plans.ids, scan_mask, pm.metric
            )
        else:
            dist, passing = backend.scan_scores(
                index, queries, pred, plans.ids, scan_mask, pm.metric
            )
        return dist, passing & scan_mask

    def no_scan(_):
        return (
            jnp.full((b, cap), jnp.inf, jnp.float32),
            jnp.zeros((b, cap), bool),
        )

    dist, passing = jax.lax.cond(jnp.any(scan_mask), do_scan, no_scan, None)
    return PlannedBatch(
        mode=plans.mode,
        est_sel=plans.est_sel,
        run_total=plans.run_total,
        ids=plans.ids,
        mask=scan_mask,
        dist=dist,
        passing=passing,
    )
