"""Proximity-graph construction, TPU-native.

Hardware adaptation (DESIGN.md §Adaptation): HNSW's *incremental insertion*
is inherently sequential pointer-chasing — each insert greedily walks the
graph built so far.  That algorithm does not map to a systolic machine, but
the paper itself notes (§IV.D "Flexibility") that the proximity graph is an
interchangeable component ("HNSW can be replaced with a different proximity
graph algorithm like NSG").  We therefore build a *flat* navigable graph
(NSG/Vamana-family) with fully batched, MXU-friendly steps:

  1. coarse k-means over the corpus,
  2. per-cluster candidate pools from the ``link`` nearest clusters;
     exact top-R neighbours inside each pool        (dense matmuls),
  3. optional NN-descent rounds (neighbours-of-neighbours refinement,
     batched gathers + matmuls),
  4. vectorized occlusion ("robust") pruning à la HNSW heuristic / Vamana,
  5. reverse-edge augmentation to a max out-degree M,
  6. medoid entry point (replaces HNSW's upper layers; identical role:
     a navigable, query-independent entry).

Search-time traversal (``repro.core.engine``) is byte-for-byte the paper's
best-first loop and does not care which construction produced the graph.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .distances import pairwise
from .kmeans import kmeans


class GraphIndex(NamedTuple):
    neighbors: jax.Array  # (N, M) int32; sentinel == N for missing edges
    entry: jax.Array  # () int32 medoid entry point

    @property
    def n_nodes(self) -> int:
        return self.neighbors.shape[0]

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]


def _topk_neighbors_in_pools(
    x: np.ndarray,
    assign: np.ndarray,
    centroids: np.ndarray,
    n_candidates: int,
    link: int,
    metric: str,
) -> np.ndarray:
    """Initial candidate lists: exact top-k inside cluster neighbourhoods."""
    n = x.shape[0]
    kc = centroids.shape[0]
    link = min(link, kc)
    cdist = np.asarray(pairwise(jnp.asarray(centroids), jnp.asarray(centroids), metric))
    near_clusters = np.argsort(cdist, axis=1)[:, :link]  # (kc, link)
    members: list[np.ndarray] = [np.where(assign == c)[0] for c in range(kc)]
    cand = np.full((n, n_candidates), n, np.int32)

    # Pure numpy: cluster shapes vary per iteration, which would retrigger
    # XLA compilation every cluster; at these pool sizes BLAS is plenty.
    x2 = (x * x).sum(1)
    for c in range(kc):
        mem = members[c]
        if mem.size == 0:
            continue
        pool = np.concatenate([members[cc] for cc in near_clusters[c]])
        xy = x[mem] @ x[pool].T
        if metric == "l2":
            d = x2[mem][:, None] + x2[pool][None, :] - 2.0 * xy
        else:
            d = -xy
        # mask self
        d[mem[:, None] == pool[None, :]] = np.inf
        k = min(n_candidates, pool.size)
        idx = np.argpartition(d, kth=k - 1, axis=1)[:, :k]
        srt = np.take_along_axis(d, idx, axis=1).argsort(axis=1)
        idx = np.take_along_axis(idx, srt, axis=1)
        cand[mem, :k] = pool[idx]
    return cand


@functools.partial(jax.jit, static_argnames=("metric",))
def _nn_descent_round(x: jax.Array, cand: jax.Array, metric: str) -> jax.Array:
    """One neighbours-of-neighbours refinement round (batched)."""
    n, r = cand.shape
    sentinel = n
    xp = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)], 0)

    def block(node_ids, cand_blk):
        nbrs2 = cand.at[jnp.clip(cand_blk, 0, n - 1)].get(mode="clip")  # (b, r, r)
        nbrs2 = jnp.where(cand_blk[:, :, None] >= n, sentinel, nbrs2)
        pool = jnp.concatenate([cand_blk, nbrs2.reshape(cand_blk.shape[0], -1)], 1)
        vecs = xp[jnp.clip(pool, 0, n)]  # (b, C, d)
        q = x[node_ids]  # (b, d)
        diff = vecs - q[:, None, :]
        if metric == "l2":
            d = jnp.sum(diff * diff, -1)
        else:
            d = -jnp.einsum("bcd,bd->bc", vecs, q)
        invalid = (pool >= n) | (pool == node_ids[:, None])
        d = jnp.where(invalid, jnp.inf, d)
        # Dedup in O(C log C): identical ids have identical distances, so it
        # is safe to keep an arbitrary single occurrence.  Sort ids, flag
        # repeats, scatter flags back to original positions.
        sort_idx = jnp.argsort(pool, axis=1)
        pool_sorted = jnp.take_along_axis(pool, sort_idx, axis=1)
        dup_sorted = jnp.concatenate(
            [jnp.zeros((pool.shape[0], 1), bool), pool_sorted[:, 1:] == pool_sorted[:, :-1]], 1
        )
        dup = jnp.zeros_like(dup_sorted).at[
            jnp.arange(pool.shape[0])[:, None], sort_idx
        ].set(dup_sorted)
        d = jnp.where(dup, jnp.inf, d)
        _, top_idx = jax.lax.top_k(-d, r)
        new_cand = jnp.take_along_axis(pool, top_idx, axis=1)
        new_d = jnp.take_along_axis(d, top_idx, axis=1)
        new_cand = jnp.where(jnp.isinf(new_d), sentinel, new_cand)
        return new_cand.astype(jnp.int32)

    bs = 1024
    pad = (-n) % bs
    ids = jnp.arange(n + pad, dtype=jnp.int32)
    cand_p = jnp.concatenate([cand, jnp.full((pad, r), sentinel, jnp.int32)], 0)
    out = jax.lax.map(
        lambda args: block(*args),
        (ids.reshape(-1, bs), cand_p.reshape(-1, bs, r)),
    )
    return out.reshape(-1, r)[:n]


@functools.partial(jax.jit, static_argnames=("m", "alpha", "metric"))
def _robust_prune(x: jax.Array, cand: jax.Array, m: int, alpha: float, metric: str) -> jax.Array:
    """Vectorized occlusion pruning (HNSW `select_neighbors_heuristic`).

    Keep candidate c_i (ascending by distance) iff for every already-kept
    c_j: alpha * d(c_i, c_j) >= d(node, c_i).
    """
    n, r = cand.shape
    sentinel = n
    xp = jnp.concatenate([x, jnp.zeros((1, x.shape[1]), x.dtype)], 0)

    def block(node_ids, cand_blk):
        vecs = xp[jnp.clip(cand_blk, 0, n)]  # (b, r, d)
        q = x[node_ids]
        if metric == "l2":
            diff = vecs - q[:, None, :]
            d_node = jnp.sum(diff * diff, -1)
            cc = vecs[:, :, None, :] - vecs[:, None, :, :]
            d_cc = jnp.sum(cc * cc, -1)  # (b, r, r)
        else:
            d_node = -jnp.einsum("brd,bd->br", vecs, q)
            d_cc = -jnp.einsum("brd,bsd->brs", vecs, vecs)
        invalid = cand_blk >= n
        d_node = jnp.where(invalid, jnp.inf, d_node)
        order = jnp.argsort(d_node, axis=1)
        inv_d = jnp.take_along_axis(d_node, order, 1)
        inv_c = jnp.take_along_axis(cand_blk, order, 1)
        d_cc_o = jnp.take_along_axis(
            jnp.take_along_axis(d_cc, order[:, :, None], 1), order[:, None, :], 2
        )

        def prune_one(dists, d_pair):
            def body(i, kept):
                occluded = jnp.any(kept & (alpha * d_pair[i] < dists[i]) & (jnp.arange(r) < i))
                keep_i = jnp.isfinite(dists[i]) & ~occluded & (jnp.sum(kept) < m)
                return kept.at[i].set(keep_i)

            return jax.lax.fori_loop(0, r, body, jnp.zeros((r,), bool))

        kept = jax.vmap(prune_one)(inv_d, d_cc_o)
        ranked = jnp.where(kept, jnp.arange(r)[None, :], r)
        slot = jnp.argsort(ranked, axis=1)[:, :m]
        out = jnp.take_along_axis(inv_c, slot, 1)
        out_kept = jnp.take_along_axis(kept, slot, 1)
        return jnp.where(out_kept, out, sentinel).astype(jnp.int32)

    bs = 1024
    pad = (-n) % bs
    ids = jnp.arange(n + pad, dtype=jnp.int32)
    cand_p = jnp.concatenate([cand, jnp.full((pad, r), sentinel, jnp.int32)], 0)
    out = jax.lax.map(
        lambda args: block(*args), (ids.reshape(-1, bs), cand_p.reshape(-1, bs, r))
    )
    return out.reshape(-1, m)[:n]


def _add_reverse_edges(neighbors: np.ndarray, m: int) -> np.ndarray:
    """Host-side reverse-edge augmentation up to out-degree m (vectorized)."""
    n = neighbors.shape[0]
    nb = np.array(neighbors)
    deg = (nb < n).sum(1)
    out = np.full((n, m), n, np.int32)
    # compact existing edges to the left
    rows, cols = np.nonzero(nb < n)
    rank_fwd = np.zeros_like(rows)
    if rows.size:
        # cumcount per row (rows are sorted by construction of nonzero)
        first = np.r_[True, rows[1:] != rows[:-1]]
        idx = np.arange(rows.size)
        start = np.maximum.accumulate(np.where(first, idx, 0))
        rank_fwd = idx - start
    out[rows, rank_fwd] = nb[rows, cols]
    # candidate reverse edges (v <- u), dropping ones already present
    u, v = rows, nb[rows, cols].astype(np.int64)
    key_exist = u.astype(np.int64) * (n + 1) + v
    key_rev = v * (n + 1) + u
    fresh = ~np.isin(key_rev, key_exist, assume_unique=False)
    # dedup duplicate reverse pairs
    key_rev_f = key_rev[fresh]
    uniq, uniq_idx = np.unique(key_rev_f, return_index=True)
    rv = v[fresh][uniq_idx]
    ru = u[fresh][uniq_idx]
    order = np.argsort(rv, kind="stable")
    rv, ru = rv[order], ru[order]
    if rv.size:
        first = np.r_[True, rv[1:] != rv[:-1]]
        idx = np.arange(rv.size)
        start = np.maximum.accumulate(np.where(first, idx, 0))
        rank = idx - start
        slot = deg[rv] + rank
        ok = slot < m
        out[rv[ok], slot[ok]] = ru[ok]
    return out


def _repair_connectivity(neighbors: np.ndarray, x: np.ndarray, entry: int, metric: str) -> np.ndarray:
    """Directed reachability repair: traversal follows out-edges, so repair
    must too.  BFS from the entry; while nodes remain unreached, bridge the
    closest (reached -> unreached) sampled pair bidirectionally and extend
    the BFS from the new node.  Mirrors the connectivity HNSW gets from
    insertion-time search, which a batch build must enforce explicitly."""
    n = neighbors.shape[0]
    out = np.array(neighbors)
    rng = np.random.default_rng(0)
    x2 = (x * x).sum(1)

    reached = np.zeros(n, bool)

    def bfs_from(seeds):
        frontier = np.asarray(seeds, np.int64)
        reached[frontier] = True
        while frontier.size:
            nxt = out[frontier].reshape(-1)
            nxt = nxt[nxt < n]
            nxt = np.unique(nxt)
            nxt = nxt[~reached[nxt]]
            reached[nxt] = True
            frontier = nxt

    bfs_from([entry])
    for _ in range(n):  # each round strictly shrinks the unreached set
        unreached = np.where(~reached)[0]
        if unreached.size == 0:
            break
        r_nodes = np.where(reached)[0]
        r_sample = r_nodes[rng.integers(0, r_nodes.size, min(4096, r_nodes.size))]
        u_sample = unreached[rng.integers(0, unreached.size, min(1024, unreached.size))]
        if metric == "l2":
            dmat = (
                x2[u_sample][:, None]
                + x2[r_sample][None, :]
                - 2.0 * (x[u_sample] @ x[r_sample].T)
            )
        else:
            dmat = -(x[u_sample] @ x[r_sample].T)
        i, j = np.unravel_index(np.argmin(dmat), dmat.shape)
        u, v = int(u_sample[i]), int(r_sample[j])  # u unreached, v reached
        for a, b in ((v, u), (u, v)):
            slots = np.where(out[a] >= n)[0]
            out[a, slots[0] if len(slots) else -1] = b
        bfs_from([u])
    return out


# ---------------------------------------------------------------------------
# Local maintenance (mutable-index compaction, core/mutable/compact.py):
# batch node removal + batch local insertion.  HNSW gets incremental
# maintenance from insertion-time search; a batch-built flat graph gets it
# from these two host-side primitives plus the same connectivity repair the
# initial build runs.
# ---------------------------------------------------------------------------


def remove_nodes(neighbors: np.ndarray, keep: np.ndarray) -> np.ndarray:
    """Drop the nodes where ``keep`` is False and reindex the survivors.

    neighbors: (N, M) int32 with sentinel == N.  Returns (N_keep, M) with
    sentinel == N_keep; edges into removed nodes are dropped and each row's
    surviving edges are compacted to the left (the iterators treat the
    first sentinel as end-of-row only implicitly, but compaction keeps the
    rows dense for the insertion step's reverse-edge scan).
    """
    n, m = neighbors.shape
    keep = np.asarray(keep, bool)
    kept_pos = np.where(keep)[0]
    n_keep = kept_pos.size
    new_id = np.full((n + 1,), n_keep, np.int64)  # removed & sentinel -> sentinel
    new_id[kept_pos] = np.arange(n_keep)
    nb = neighbors[kept_pos].astype(np.int64)
    mapped = new_id[np.clip(nb, 0, n)]
    out = np.full((n_keep, m), n_keep, np.int32)
    rows, cols = np.nonzero(mapped < n_keep)
    if rows.size:
        first = np.r_[True, rows[1:] != rows[:-1]]
        idx = np.arange(rows.size)
        start = np.maximum.accumulate(np.where(first, idx, 0))
        out[rows, idx - start] = mapped[rows, cols]
    return out


def _occlusion_prune_host(d_node: np.ndarray, cand: np.ndarray, x: np.ndarray, m: int, alpha: float, metric: str) -> np.ndarray:
    """Greedy occlusion prune of one candidate list (ascending by d_node);
    host-side counterpart of `_robust_prune` for small insertion batches."""
    order = np.argsort(d_node, kind="stable")
    kept: list[int] = []
    for j in order:
        if len(kept) >= m or not np.isfinite(d_node[j]):
            break
        c = x[cand[j]]
        if kept:
            kx = x[cand[kept]]
            if metric == "l2":
                d_ck = ((kx - c) ** 2).sum(1)
            else:
                d_ck = -(kx @ c)
            if np.any(alpha * d_ck < d_node[j]):
                continue
        kept.append(int(j))
    return cand[kept]


def insert_nodes(
    neighbors: np.ndarray,
    x: np.ndarray,
    n_old: int,
    assign: np.ndarray,
    centroids: np.ndarray,
    m: int,
    *,
    alpha: float = 1.2,
    link: int = 4,
    metric: str = "l2",
) -> np.ndarray:
    """Insert nodes ``n_old..n-1`` of ``x`` into an existing graph.

    neighbors: (n_old, M) with sentinel == n_old.  Returns (n, M) with
    sentinel == n.  Mirrors HNSW insertion locally: each new node draws its
    candidate pool from the ``link`` clusters nearest its own (by centroid
    distance), keeps an occlusion-pruned top-``m``, and pushes reverse
    edges, evicting the farthest edge of a full row.  Connectivity repair
    (and entry choice) is the caller's job — compaction runs
    ``_repair_connectivity`` once over the folded graph.
    """
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    new_ids = np.arange(n_old, n)
    out = np.full((n, m), n, np.int32)
    old = neighbors.astype(np.int64)
    out[:n_old] = np.where(old >= n_old, n, old).astype(np.int32)
    if new_ids.size == 0:
        return out
    kc = centroids.shape[0]
    link = min(link, kc)
    cdist = np.asarray(pairwise(jnp.asarray(centroids), jnp.asarray(centroids), metric))
    near_clusters = np.argsort(cdist, axis=1)[:, :link]  # (kc, link)
    members = [np.where(assign == c)[0] for c in range(kc)]
    x2 = (x * x).sum(1)
    deg = (out < n).sum(1)
    for i in new_ids:
        pool = np.concatenate([members[cc] for cc in near_clusters[assign[i]]])
        pool = pool[pool != i]
        if pool.size == 0:  # degenerate corpus: leave isolated, repair bridges
            continue
        xy = x[pool] @ x[i]
        d = x2[pool] - 2.0 * xy + x2[i] if metric == "l2" else -xy
        chosen = _occlusion_prune_host(d, pool, x, m, alpha, metric)
        out[i, : chosen.size] = chosen
        deg[i] = chosen.size
        # reverse edges: append while the row has room, else evict the
        # farthest edge if the new one is closer (plain distance eviction;
        # occlusion re-pruning on every reverse edge is not worth the host
        # cost at delta scale)
        for j in chosen:
            if deg[j] < m:
                out[j, deg[j]] = i
                deg[j] += 1
                continue
            row = out[j]
            rv = x[row] - x[j]
            d_row = (rv * rv).sum(1) if metric == "l2" else -(x[row] @ x[j])
            w = int(np.argmax(d_row))
            d_new = (
                float(((x[i] - x[j]) ** 2).sum()) if metric == "l2" else float(-(x[i] @ x[j]))
            )
            if d_new < d_row[w]:
                out[j, w] = i
    return out


def build_graph(
    vectors: np.ndarray,
    m: int = 16,
    *,
    n_candidates: int | None = None,
    n_build_clusters: int | None = None,
    link: int = 4,
    nn_descent_rounds: int = 1,
    prune_alpha: float = 1.2,
    metric: str = "l2",
    seed: int = 0,
) -> GraphIndex:
    """Build a flat navigable proximity graph with max out-degree ``m``."""
    x = np.asarray(vectors, np.float32)
    n, d = x.shape
    n_candidates = n_candidates or max(2 * m, 16)
    n_build_clusters = n_build_clusters or max(8, min(n // 128, 4096))
    km = kmeans(jnp.asarray(x), n_build_clusters, iters=8, seed=seed, metric=metric)
    assign = np.asarray(km.assignments)
    cand = _topk_neighbors_in_pools(
        x, assign, np.asarray(km.centroids), n_candidates, link, metric
    )
    xj = jnp.asarray(x)
    cand_j = jnp.asarray(cand)
    for _ in range(nn_descent_rounds):
        cand_j = _nn_descent_round(xj, cand_j, metric)
    pruned = _robust_prune(xj, cand_j, m, prune_alpha, metric)
    neighbors = _add_reverse_edges(np.asarray(pruned), m)
    # medoid entry: point nearest to the global mean
    mean = x.mean(0, keepdims=True)
    entry = int(np.argmin(np.asarray(pairwise(jnp.asarray(mean), xj, metric))[0]))
    neighbors = _repair_connectivity(neighbors, x, entry, metric)
    return GraphIndex(jnp.asarray(neighbors), jnp.asarray(np.int32(entry)))
