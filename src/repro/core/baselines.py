"""Baselines from the paper's evaluation (§III, §V).

* :func:`brute_force`       — exact filtered top-k (ground truth).
* :func:`prefilter_search`  — §III.C: evaluate the predicate over the whole
  corpus, brute-force the survivors.  O(N) predicate pass + masked distance
  matmul; on TPU this is MXU-friendly, which is exactly why it is the right
  baseline at *very* low passrates.
* :func:`postfilter_search` — §III.D: unfiltered ANN with oversampling k',
  filter, double k' and retry until k survivors (host-side retry loop, as in
  real systems).
* NaviX-style in-filtering  — via ``CompassParams(in_filter=True,
  use_btree=False)`` on the shared loop in ``search.py``.

Every baseline consumes the same :class:`CompassIndex`, mirroring the
paper's "reuse battle-tested indices" philosophy.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import predicate as P
from .index import CompassIndex
from .planner.plan import POSTFILTER
from .engine import CompassParams, SearchResult, SearchStats, compass_search


class BruteResult(NamedTuple):
    ids: jax.Array  # (B, k)
    dists: jax.Array  # (B, k)


@functools.partial(jax.jit, static_argnames=("k", "metric", "block"))
def brute_force(
    vectors: jax.Array,
    attrs: jax.Array,
    queries: jax.Array,
    pred: P.Predicate,
    k: int,
    metric: str = "l2",
    block: int = 8192,
) -> BruteResult:
    """Exact filtered top-k via blocked masked distance computation.

    vectors: (N, d) unpadded; pred arrays batched (B, T, A).
    """
    n, d = vectors.shape
    b = queries.shape[0]
    pad = (-n) % block
    vp = jnp.pad(vectors, ((0, pad), (0, 0)))
    ap = jnp.pad(attrs, ((0, pad), (0, 0)), constant_values=jnp.inf)
    nb = vp.shape[0] // block

    def scan_block(carry, blk):
        best_d, best_i = carry
        vb, ab, base = blk
        if metric == "l2":
            v2 = jnp.sum(vb * vb, -1)
            q2 = jnp.sum(queries * queries, -1, keepdims=True)
            dist = q2 + v2[None, :] - 2.0 * (queries @ vb.T)  # (B, block)
        else:
            dist = -(queries @ vb.T)
        ok = jax.vmap(lambda lo, hi: P.evaluate(P.Predicate(lo, hi), ab))(pred.lo, pred.hi)
        idx_row = base + jnp.arange(block, dtype=jnp.int32)
        valid = idx_row < n
        dist = jnp.where(ok & valid[None, :], dist, jnp.inf)
        cat_d = jnp.concatenate([best_d, dist], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(idx_row, (b, block))], axis=1)
        neg, sel = jax.lax.top_k(-cat_d, k)
        return (-neg, jnp.take_along_axis(cat_i, sel, axis=1)), None

    init = (jnp.full((b, k), jnp.inf), jnp.full((b, k), n, jnp.int32))
    bases = (jnp.arange(nb) * block).astype(jnp.int32)
    (best_d, best_i), _ = jax.lax.scan(
        scan_block, init, (vp.reshape(nb, block, d), ap.reshape(nb, block, -1), bases)
    )
    return BruteResult(best_i, best_d)


def prefilter_search(
    index: CompassIndex, queries: jax.Array, pred: P.Predicate, k: int, metric: str = "l2"
) -> BruteResult:
    """§III.C pre-filtering == brute force over the predicate survivors.

    With dense array layouts, filtering-then-scanning IS a masked scan, so
    this shares the brute-force kernel; its cost model (O(N·d) regardless of
    passrate) is what the paper criticises, and what our benchmarks show.
    """
    n = index.n_records
    return brute_force(index.vectors[:n], index.attrs[:n], queries, pred, k, metric)


def postfilter_search(
    index: CompassIndex,
    queries: jax.Array,
    pred: P.Predicate,
    k: int,
    *,
    ef0: int = 64,
    max_rounds: int = 4,
    metric: str = "l2",
    backend: str = "auto",
) -> SearchResult:
    """§III.D post-filtering with host-side k' doubling.

    Runs plain (unfiltered) progressive graph search with an always-true
    predicate, filters the returned candidates, and doubles the search size
    until k survive or the round budget is exhausted.  Distance counts
    accumulate across rounds — mis-estimated k' is paid for, exactly the
    pathology the paper describes.
    """
    bsz = queries.shape[0]
    n = index.n_records
    n_attrs = index.n_attrs
    true_pred = P.Predicate(
        jnp.broadcast_to(jnp.float32(P.NEG_INF), (bsz, 1, n_attrs)),
        jnp.broadcast_to(jnp.float32(P.POS_INF), (bsz, 1, n_attrs)),
    )
    total_dist = jnp.zeros((bsz,), jnp.int32)
    total_cdist = jnp.zeros((bsz,), jnp.int32)
    total_steps = jnp.zeros((bsz,), jnp.int32)
    out_ids = np.full((bsz, k), n, np.int32)
    out_dists = np.full((bsz, k), np.inf, np.float32)
    done = np.zeros((bsz,), bool)
    ef = ef0
    last = None
    for _ in range(max_rounds):
        pm = CompassParams(k=ef, ef=ef, use_btree=False, metric=metric, backend=backend)
        res = compass_search(index, queries, true_pred, pm)
        total_dist = total_dist + res.stats.n_dist
        total_cdist = total_cdist + res.stats.n_cdist
        total_steps = total_steps + res.stats.n_steps
        ok = np.asarray(jax.vmap(lambda lo, hi, at: P.evaluate(P.Predicate(lo, hi), at))(
            pred.lo, pred.hi, index.attrs[res.ids]
        ))  # (B, ef)
        ids_np = np.asarray(res.ids)
        d_np = np.asarray(res.dists)
        for b in range(bsz):
            if done[b]:
                continue
            sel = np.where(ok[b] & np.isfinite(d_np[b]))[0][:k]
            out_ids[b, : len(sel)] = ids_np[b, sel]
            out_dists[b, : len(sel)] = d_np[b, sel]
            if len(sel) >= k:
                done[b] = True
        last = res
        if done.all():
            break
        ef *= 2
    stats = SearchStats(
        n_dist=total_dist,
        n_cdist=total_cdist,
        n_steps=total_steps,
        n_bcalls=jnp.zeros((bsz,), jnp.int32),
        n_clusters_ranked=jnp.zeros((bsz,), jnp.int32),
        n_adc=jnp.zeros((bsz,), jnp.int32),
        n_rerank=jnp.zeros((bsz,), jnp.int32),
        # the vacuous-predicate rounds admit everything they score; the
        # host-side re-filter above is not a scored pass, so the engine's
        # n_pass (all scored rows) is the honest figure to carry over
        n_pass=last.stats.n_pass,
        mode=jnp.full((bsz,), POSTFILTER, jnp.int32),
        efs_final=last.stats.efs_final,
        est_sel=jnp.full((bsz,), -1.0, jnp.float32),
        run_total=jnp.full((bsz,), -1, jnp.int32),
    )
    return SearchResult(jnp.asarray(out_ids), jnp.asarray(out_dists), stats)


def navix_search(
    index: CompassIndex, queries: jax.Array, pred: P.Predicate, pm: CompassParams
) -> SearchResult:
    """NaviX/ACORN-style in-filtering on the shared progressive loop."""
    import dataclasses

    pm = dataclasses.replace(pm, in_filter=True, use_btree=False)
    return compass_search(index, queries, pred, pm)


def recall(result_ids: np.ndarray, truth_ids: np.ndarray, truth_dists: np.ndarray, n: int) -> float:
    """Paper Eq. (1): |S_k ∩ S_k*| / |S_k*| averaged over queries, where
    S_k* drops padded (non-existent) ground-truth entries."""
    total, hit = 0, 0
    for b in range(result_ids.shape[0]):
        t = truth_ids[b][np.isfinite(truth_dists[b]) & (truth_ids[b] < n)]
        if len(t) == 0:
            continue
        total += len(t)
        hit += len(set(result_ids[b].tolist()) & set(t.tolist()))
    return hit / max(total, 1)
