"""Mini-batch-free Lloyd k-means in JAX, used to build the IVF layer.

The IVF component of Compass (§IV.A) groups records into ``nlist`` clusters
by vector; per-cluster relational indices are then built within each
cluster.  On TPU the assignment step is a (N, nlist) distance matmul — MXU
friendly — and the update step is a segment-sum; both are ``jit``-able and
shardable along N.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .distances import pairwise


class KMeansResult(NamedTuple):
    centroids: jax.Array  # (k, d)
    assignments: jax.Array  # (n,) int32
    inertia: jax.Array  # () f32


def _assign_blocked(x: jax.Array, centroids: jax.Array, block: int, metric: str):
    """Blocked assignment to bound peak memory for large (n, k)."""
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    nb = xp.shape[0] // block

    def body(carry, xb):
        d = pairwise(xb, centroids, metric)  # (block, k)
        idx = jnp.argmin(d, axis=-1).astype(jnp.int32)
        best = jnp.min(d, axis=-1)
        return carry, (idx, best)

    _, (idx, best) = jax.lax.scan(body, 0, xp.reshape(nb, block, -1))
    return idx.reshape(-1)[:n], best.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("k",))
def _kmeanspp_init(x: jax.Array, k: int, key: jax.Array) -> jax.Array:
    """k-means++ seeding (D² sampling) — avoids the merged-mode local optima
    random init falls into on multi-modal corpora."""
    n, d = x.shape
    key, sub = jax.random.split(key)
    first = jax.random.randint(sub, (), 0, n)
    c0 = x[first]
    min_d2 = jnp.sum((x - c0[None, :]) ** 2, axis=-1)

    def body(i, carry):
        centroids, min_d2, key = carry
        key, sub = jax.random.split(key)
        probs = jnp.maximum(min_d2, 1e-12)
        idx = jax.random.categorical(sub, jnp.log(probs))
        c = x[idx]
        centroids = centroids.at[i].set(c)
        d2 = jnp.sum((x - c[None, :]) ** 2, axis=-1)
        return centroids, jnp.minimum(min_d2, d2), key

    centroids = jnp.zeros((k, d), x.dtype).at[0].set(c0)
    centroids, _, _ = jax.lax.fori_loop(1, k, body, (centroids, min_d2, key))
    return centroids


@functools.partial(jax.jit, static_argnames=("k", "iters", "block", "metric"))
def kmeans(
    x: jax.Array,
    k: int,
    *,
    iters: int = 12,
    seed: int = 0,
    block: int = 4096,
    metric: str = "l2",
) -> KMeansResult:
    """Lloyd's algorithm with k-means++ init and empty-cluster repair."""
    n, d = x.shape
    key = jax.random.PRNGKey(seed)
    centroids = _kmeanspp_init(x, k, key)

    def step(carry, _):
        centroids, key = carry
        idx, best = _assign_blocked(x, centroids, block, metric)
        one_hot_sum = jax.ops.segment_sum(x, idx, num_segments=k)  # (k, d)
        counts = jax.ops.segment_sum(jnp.ones((n,), x.dtype), idx, num_segments=k)
        new_centroids = one_hot_sum / jnp.maximum(counts[:, None], 1.0)
        # Empty-cluster repair: reseed from the points with the largest error.
        key, sub = jax.random.split(key)
        far_idx = jnp.argsort(-best)[:k]  # k farthest points
        empty = counts < 0.5
        new_centroids = jnp.where(empty[:, None], x[far_idx], new_centroids)
        return (new_centroids, key), jnp.sum(best)

    (centroids, _), inertias = jax.lax.scan(step, (centroids, key), None, length=iters)
    idx, best = _assign_blocked(x, centroids, block, metric)
    return KMeansResult(centroids, idx, jnp.sum(best))
