"""State & queue layer of the Compass execution engine.

Everything the two iterators (G.NEXT / B.NEXT) and the driver loop share
lives here: the fixed-capacity sorted-array queue abstraction, the fused
search state, the VISIT state update (Algorithm 4 minus the scoring, which
a :mod:`~repro.core.engine.backend` provides), and the credit/round-pacing
bookkeeping of Algorithm 1.

Queue representation (DESIGN.md §Adaptation): a priority queue on TPU is a
fixed-capacity ascending-sorted array with ``+inf`` marking empty slots.
``RecycQ`` of Algorithm 2 is *implicit*: the graph-top queue always holds up
to its full capacity and the live prefix is ``efs`` — enlarging ``efs``
re-admits exactly the entries the paper's RecycQ would replay.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

INF = jnp.inf


class FixedQueue(NamedTuple):
    """Fixed-capacity priority queue as a sorted array (+inf == empty slot).

    Shared by the candidate queue (CandQ), the graph-top queue (TopQ width
    control) and the filtered result queue (the global TopQ of Alg. 1).
    Being a NamedTuple of arrays it is a JAX pytree, so it threads through
    ``lax.while_loop`` / ``vmap`` unchanged.
    """

    d: jax.Array  # (cap,) f32, ascending; +inf = empty
    i: jax.Array  # (cap,) int32 record ids; sentinel where empty

    @classmethod
    def full(cls, cap: int, sentinel: int) -> "FixedQueue":
        return cls(
            jnp.full((cap,), INF, jnp.float32),
            jnp.full((cap,), sentinel, jnp.int32),
        )

    @property
    def cap(self) -> int:
        return self.d.shape[0]

    def merge(self, nd: jax.Array, ni: jax.Array) -> "FixedQueue":
        """Merge new (dist, id) entries, keeping the best ``cap``."""
        d = jnp.concatenate([self.d, nd])
        i = jnp.concatenate([self.i, ni])
        order = jnp.argsort(d)
        return FixedQueue(d[order[: self.cap]], i[order[: self.cap]])

    def count(self) -> jax.Array:
        """Number of live (finite) entries."""
        return jnp.sum(jnp.isfinite(self.d)).astype(jnp.int32)

    def pop(self, w: int) -> tuple[jax.Array, jax.Array, "FixedQueue"]:
        """Remove the best ``w`` entries; returns (dists, ids, rest)."""
        heads_d, heads_i = self.d[:w], self.i[:w]
        d = self.d.at[:w].set(INF)
        order = jnp.argsort(d)
        return heads_d, heads_i, FixedQueue(d[order], self.i[order])


def dedup_new(ids: jax.Array, mask: jax.Array) -> jax.Array:
    """Mask out later duplicate ids within a visit list."""
    ids_masked = jnp.where(mask, ids, jnp.iinfo(jnp.int32).max)
    sort_idx = jnp.argsort(ids_masked)
    s = ids_masked[sort_idx]
    dup_sorted = jnp.concatenate([jnp.zeros((1,), bool), s[1:] == s[:-1]])
    dup = jnp.zeros_like(dup_sorted).at[sort_idx].set(dup_sorted)
    return mask & ~dup


class SearchStats(NamedTuple):
    n_dist: jax.Array  # full-precision distance computations (paper #Comp;
    # includes the quantized tier's stage-two rerank rows when those read
    # the float32 table — rerank="full")
    n_cdist: jax.Array  # centroid distance computations; 0 when the exact
    # centroid ranking has no consumer (use_btree=False and non-adaptive
    # entry) and the scan is skipped entirely
    n_steps: jax.Array  # loop iterations
    n_bcalls: jax.Array  # relational injections
    n_clusters_ranked: jax.Array  # clusters actually opened by B.NEXT
    n_adc: jax.Array  # quantized (ADC table-lookup) scores — stage one of
    # the quantized tier; 0 whenever CompassParams.quant is off
    n_rerank: jax.Array  # stage-two exact distances of the quantized tier
    n_pass: jax.Array  # predicate-passing AND live rows among the scored
    # ones (visit admissions + prefilter adoptions + delta scan passes);
    # n_pass / rows-examined is the *measured* selectivity an explain
    # trace reports next to the planner's estimate (obs/trace.py)
    mode: jax.Array  # planner execution mode (planner.plan.MODE_NAMES index);
    # COOPERATIVE when the planner is off
    efs_final: jax.Array
    est_sel: jax.Array  # f32 planner-estimated selectivity; -1.0 when the
    # planner is off (explain renders that as "no estimate")
    run_total: jax.Array  # int32 planner-estimated candidate run rows (the
    # cost-model input behind the mode choice); -1 when the planner is off


class SearchResult(NamedTuple):
    ids: jax.Array  # (k,) int32, padded with N
    dists: jax.Array  # (k,) f32, padded with +inf
    stats: SearchStats


class EngineState(NamedTuple):
    """The fused per-query search state threaded through the driver loop."""

    cand: FixedQueue  # shared candidate queue (CandQ)
    gtop: FixedQueue  # graph-internal top queue (width control; unfiltered)
    efs: jax.Array  # progressive search width
    res: FixedQueue  # filtered result queue (the global TopQ of Alg. 1)
    visited: jax.Array  # (N + 1,) bool
    # clustered B+-tree iterator state (owned by btree_iter)
    rank: jax.Array  # (nlist,) clusters in centroid-distance order
    rank_pos: jax.Array  # cursor into `rank`
    term_beg: jax.Array  # (T,) cursors into order arrays (global positions)
    term_end: jax.Array
    b_exhausted: jax.Array
    # round-pacing bookkeeping (Alg. 1)
    returned: jax.Array  # records handed to the global TopQ so far
    stalled: jax.Array
    last_sel: jax.Array
    stats: SearchStats


def visit(index, q, pred, st: EngineState, ids, mask, pm, backend) -> EngineState:
    """Algorithm 4 over a fixed-size visit list.

    Scoring (distance + predicate) is delegated to ``backend``; this
    function owns the state update: dedup, visited marking, and the pushes
    into the shared queue, the graph top queue, and (for predicate-passing
    records) the filtered result queue.
    """
    n = index.n_records
    mask = dedup_new(ids, mask)
    mask = mask & ~st.visited[ids]
    safe = jnp.where(mask, ids, n).astype(jnp.int32)
    # One fused scoring call per visit batch: distance + DNF predicate +
    # tombstone mask + queue admission.  `dist` feeds the traversal queues
    # (a dead record keeps routing — it stays in cand/gtop so traversal
    # flows through it); `admit` is +inf unless the row is valid, passes
    # the predicate AND is alive, so merging it into the result queue is
    # exactly the old visit_scores -> live-AND -> where sequence (the ref
    # backend literally composes that sequence; the pallas backend runs the
    # kernels/visit_step.py fused kernel unless pm.fused_visit is off).
    dist, admit = backend.visit_step(
        index, q, pred, safe, mask, pm.metric, fused=pm.fused_visit,
        rows_per_step=pm.shape.visit_rb or None,
    )
    visited = st.visited.at[safe].set(True)  # sentinel slot absorbs masked
    cand = st.cand.merge(dist, safe)
    gtop = st.gtop.merge(dist, safe)
    res = st.res.merge(admit, safe)
    # A quant-adapted backend (backend.QuantAdapter) scores visits through
    # the ADC tables, so the work lands in n_adc, not the full-precision
    # #Comp counter.  Trace-time branch: counts_as is a plain attribute.
    # `admit` is finite exactly for valid, predicate-passing, live rows —
    # summing its finite count measures the passrate the planner estimated
    if getattr(backend, "counts_as", "dist") == "adc":
        stats = st.stats._replace(
            n_adc=st.stats.n_adc + jnp.sum(mask),
            n_pass=st.stats.n_pass + jnp.sum(jnp.isfinite(admit)).astype(jnp.int32),
        )
    else:
        stats = st.stats._replace(
            n_dist=st.stats.n_dist + jnp.sum(mask),
            n_pass=st.stats.n_pass + jnp.sum(jnp.isfinite(admit)).astype(jnp.int32),
        )
    return st._replace(
        cand=cand,
        gtop=gtop,
        res=res,
        visited=visited,
        stats=stats,
    )


def res_count(st: EngineState) -> jax.Array:
    return st.res.count()


def credit(st: EngineState, batch: int) -> EngineState:
    """A round boundary: the iterator hands <= batch of its found-but-
    unreturned records to Alg. 1's global TopQ (ResQ/RelQ pops)."""
    give = jnp.minimum(jnp.int32(batch), res_count(st) - st.returned)
    return st._replace(returned=st.returned + jnp.maximum(give, 0))


def graph_frontier(st: EngineState, pm) -> tuple[jax.Array, jax.Array]:
    """(queue_empty, gstop): has the shared queue drained, and has this
    G.NEXT round converged at the current efs (Alg. 2 line 13)."""
    head_d = st.cand.d[0]
    queue_empty = ~jnp.isfinite(head_d)
    worst = st.gtop.d[jnp.minimum(st.efs, pm.ef_cap) - 1]
    return queue_empty, queue_empty | (head_d > worst)
