"""G.NEXT — the pull-based graph iterator (Algorithm 2).

Owns graph entry selection and the passrate-adaptive beam expansion
(one-hop / two-hop / pivot).  :func:`step` advances the iterator by one
driver round and reports whether the relational iterator should be pulled
next, so the driver loop is just Algorithm 1's coordination.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import predicate as P
from . import state as S


def seed_entries(index, rank, pm):
    """SELECTENTRYPOINT (Alg. 2 line 8).

    HNSW descends its upper layers to locate a good entry; our flat build
    instead seeds with the medoids of the ``entry_fanout`` nearest IVF
    clusters — same role, and robust when clusters straddle modes.  The
    global-medoid graph entry rides along as a fallback.
    """
    if pm.adaptive_entry:
        fan = min(pm.entry_fanout, index.nlist)
        entries = index.medoids[rank[:fan]].astype(jnp.int32)
        return jnp.concatenate([entries, index.graph.entry.astype(jnp.int32)[None]])
    return index.graph.entry.astype(jnp.int32)[None]


def expand(index, q, pred, st: S.EngineState, pm, backend) -> S.EngineState:
    """Pop the best `beam` shared-queue candidates and expand per
    neighbourhood passrate (Algorithm 2 lines 12-17; beam == 1 is the
    paper-faithful per-candidate loop)."""
    n = index.n_records
    m = index.graph.degree
    w = pm.beam
    heads_d, heads_i, cand = st.cand.pop(w)
    head_ok = jnp.isfinite(heads_d)
    st = st._replace(cand=cand)

    nbrs = index.graph.neighbors[jnp.clip(heads_i, 0, n - 1)].reshape(-1)  # (W*M,)
    valid = (nbrs < n) & jnp.repeat(head_ok, m)
    safe = jnp.where(valid, nbrs, n)
    npass = P.evaluate(pred, index.attrs[safe]) & valid
    sel = jnp.sum(npass) / jnp.maximum(jnp.sum(valid), 1)

    unvis = valid & ~st.visited[safe]
    wm = w * m
    vl = wm + pm.k2

    def one_hop(_):
        mask = unvis & npass if pm.in_filter else unvis
        ids = jnp.concatenate([nbrs, jnp.full((pm.k2,), n, jnp.int32)])
        mk = jnp.concatenate([mask, jnp.zeros((pm.k2,), bool)])
        return ids, mk

    def two_hop(_):
        nbrs2 = index.graph.neighbors[safe].reshape(-1)  # (W*M*M,)
        valid2 = (nbrs2 < n) & jnp.repeat(valid, m)
        safe2 = jnp.where(valid2, nbrs2, n)
        pass2 = P.evaluate(pred, index.attrs[safe2]) & valid2
        unvis2 = pass2 & ~st.visited[safe2]
        unvis2 = S.dedup_new(nbrs2, unvis2)
        # pick a bounded subset of passing two-hop neighbours
        score = unvis2.astype(jnp.float32)
        _, top_idx = jax.lax.top_k(score, pm.k2)
        sel_ids = nbrs2[top_idx]
        sel_mk = unvis2[top_idx]
        ids = jnp.concatenate([nbrs, sel_ids])
        mk = jnp.concatenate([unvis & npass, sel_mk])
        return ids, mk

    def none_(_):
        return jnp.full((vl,), n, jnp.int32), jnp.zeros((vl,), bool)

    if pm.in_filter:  # NaviX-style: never pivots, two-hop when sel < alpha
        branch = jnp.where(sel >= pm.alpha, 0, 1)
    else:
        branch = jnp.where(sel >= pm.alpha, 0, jnp.where(sel >= pm.beta, 1, 2))
    ids, mk = jax.lax.switch(branch, [one_hop, two_hop, none_], None)
    st = S.visit(index, q, pred, st, ids, mk, pm, backend)
    return st._replace(last_sel=sel)


def step(index, q, pred, st: S.EngineState, pm, backend):
    """One G.NEXT round of the driver loop.

    Returns ``(state, need_b)`` where ``need_b`` asks the driver to pull
    B.NEXT: the graph broke on low passrate (Alg. 2 line 17), converged at
    the efs cap, or ran out of candidates.
    """
    queue_empty, gstop = S.graph_frontier(st, pm)
    # gstop == Alg. 2 line 13: this G.NEXT round converged at the current
    # efs. Return <= k found records to the global TopQ, then ExpandSearch
    # widens efs for the next round.
    st = jax.lax.cond(gstop, lambda s: S.credit(s, pm.k), lambda s: s, st)
    new_efs = jnp.minimum(st.efs + pm.stepsize, pm.ef_cap)
    at_cap = st.efs >= pm.ef_cap
    st = st._replace(efs=jnp.where(gstop & ~at_cap, new_efs, st.efs))
    do_pop = ~gstop
    st = jax.lax.cond(
        do_pop, lambda s: expand(index, q, pred, s, pm, backend), lambda s: s, st
    )
    low_sel = do_pop & (st.last_sel < pm.beta)
    # low-sel break is also a G.NEXT round boundary (Alg. 2 line 17)
    st = jax.lax.cond(low_sel, lambda s: S.credit(s, pm.k), lambda s: s, st)
    need_b = low_sel | (gstop & at_cap) | queue_empty
    return st, need_b


def dead(st: S.EngineState, pm) -> jax.Array:
    """No graph progress is possible anymore (stall detection input)."""
    queue_empty, gstop = S.graph_frontier(st, pm)
    return (gstop & (st.efs >= pm.ef_cap)) | queue_empty
