"""Scoring backends for the Compass execution engine.

A :class:`VisitBackend` answers the two score queries the engine makes on
its hot path, and nothing else:

  * ``visit_scores``    — Algorithm 4's distance + predicate evaluation over
    a fixed-size visit list (the per-step hot spot).
  * ``centroid_scores`` — B.OPEN / G.OPEN's exact centroid ranking input
    (one blocked scan per query *batch*, hoisted out of the per-query vmap
    so the pallas path gets the cross-query MXU blocking ``ivf_score`` is
    built for; see index.py for why this replaces the paper's cluster
    graph G').

Candidate *generation* (queue management, graph expansion, B+-tree cursors)
stays in the iterators — the NaviX/CHASE lesson that hybrid-query engines
need generation and scoring separable.  Backends agree exactly on
semantics (masked entries score ``+inf`` / ``False``; the same records
pass, the same distances are returned for VISIT), and the parity suite in
tests/test_compass_search.py asserts end-to-end identical ids/dists on its
fixed workloads.  One caveat keeps this short of a universal bit-for-bit
guarantee: ``ivf_score`` computes centroid distances via the
``||q||² - 2q·c + ||c||²`` MXU expansion while the ref path computes
``Σ(c-q)²``, so two *near-equidistant* clusters can swap rank order under
float32 rounding, which may reorder cluster visits on adversarial data.
Result-queue contents are distance-sorted either way; only tie-adjacent
candidate sets can differ, and never for VISIT scoring itself (the
filter_distance kernel evaluates the same f32 ``Σ(v-q)²`` as the ref
gather).

``"ref"`` is the plain-jnp gather path (the original core/search.py math,
moved verbatim).  ``"pallas"`` routes VISIT through the fused
``kernels.filter_distance`` TPU kernel and centroid ranking through
``kernels.ivf_score``; on CPU the kernels run in Pallas interpret mode (see
kernels/ops.py) so tests exercise the kernel path.  ``"auto"`` resolves to
``"pallas"`` on TPU and ``"ref"`` elsewhere.
"""
from __future__ import annotations

from typing import Protocol

import jax
import jax.numpy as jnp

from .. import predicate as P


class VisitBackend(Protocol):
    """Scoring interface consumed by :func:`engine.state.visit`, the
    driver's OPEN step, and the planner's PREFILTER run scan."""

    name: str

    def visit_scores(self, index, q, pred, safe_ids, mask, metric):
        """(dist (V,) f32 with +inf where masked; passing (V,) bool)."""
        ...

    def centroid_scores(self, index, queries, metric):
        """Per-cluster distance scores for a query batch: (B, nlist) f32."""
        ...

    def scan_scores(self, index, queries, pred, ids, mask, metric):
        """Batched run-scan scoring for the planner's PREFILTER mode:
        (B, V) candidate ids against (B, d) queries and (B, T, A) predicate
        tensors -> (dist (B, V) f32 with +inf where masked, passing (B, V)
        bool).  Same per-row semantics as visit_scores, hoisted out of the
        per-query vmap so the pallas path gets one blocked problem."""
        ...

    def adc_scores(self, index, q_resid, lut, pred, safe_ids, mask, metric):
        """Quantized visit scoring: distances come from the per-query ADC
        table over ``index.qvecs`` codes instead of the float32 rows.
        ``q_resid`` is the centered zero-padded query (consumed by the
        pallas kernel's fused LUT construction), ``lut`` the precomputed
        (m, ks) table (consumed by the jnp path) — same math, one source
        (kernels.ref.subspace_lut).  Sentinel ids are masked-out slots
        even under a true mask.  Returns (dist (V,), passing (V,))."""
        ...

    def scan_scores_quantized(self, index, q_resid, luts, pred, ids, mask, metric):
        """Batched quantized scan — scan_scores over PQ codes: (B, V) ids,
        (B, d_pad) residual queries, (B, m, ks) tables.  Serves the
        planner's PREFILTER materialization and the mutable delta brute
        scan when the quantized tier is active."""
        ...


class RefBackend:
    """Plain jnp gathers — the original search hot path, moved verbatim."""

    name = "ref"

    def visit_scores(self, index, q, pred, safe_ids, mask, metric):
        vecs = index.vectors[safe_ids]  # (V, d)
        if metric == "l2":
            diff = vecs - q[None, :]
            dist = jnp.sum(diff * diff, axis=-1)
        else:
            dist = -(vecs @ q)
        dist = jnp.where(mask, dist, jnp.inf)
        attrs = index.attrs[safe_ids]
        passing = P.evaluate(pred, attrs) & mask
        return dist, passing

    def centroid_scores(self, index, queries, metric):
        if metric == "l2":
            cdiff = index.centroids[None, :, :] - queries[:, None, :]
            return jnp.sum(cdiff * cdiff, axis=-1)
        return -(queries @ index.centroids.T)

    def scan_scores(self, index, queries, pred, ids, mask, metric):
        n = index.n_records
        safe = jnp.where(mask, jnp.clip(ids, 0, n), n).astype(jnp.int32)
        # sentinel ids are masked-out slots even under a true mask (same
        # validity rule as the filter_distance kernels)
        valid = mask & (safe < n)
        vecs = index.vectors[safe]  # (B, V, d)
        if metric == "l2":
            diff = vecs - queries[:, None, :]
            dist = jnp.sum(diff * diff, axis=-1)
        else:
            dist = -jnp.einsum("bvd,bd->bv", vecs, queries)
        dist = jnp.where(valid, dist, jnp.inf)
        attrs = index.attrs[safe]  # (B, V, A)
        passing = jax.vmap(
            lambda lo, hi, at: P.evaluate(P.Predicate(lo, hi), at)
        )(pred.lo, pred.hi, attrs)
        return dist, passing & valid

    def adc_scores(self, index, q_resid, lut, pred, safe_ids, mask, metric):
        from ...kernels.ref import chain_sum_m

        qv = index.qvecs
        n = index.n_records
        valid = mask & (safe_ids < n)
        cd = qv.codes[safe_ids].astype(jnp.int32)  # (V, m)
        vals = lut[jnp.arange(qv.m)[None, :], cd]  # (V, m)
        dist = chain_sum_m([vals[:, mi] for mi in range(qv.m)])
        dist = jnp.where(valid, dist, jnp.inf)
        attrs = index.attrs[safe_ids]
        passing = P.evaluate(pred, attrs) & valid
        return dist, passing

    def scan_scores_quantized(self, index, q_resid, luts, pred, ids, mask, metric):
        from ...kernels.ref import chain_sum_m

        qv = index.qvecs
        n = index.n_records
        safe = jnp.where(mask, jnp.clip(ids, 0, n), n).astype(jnp.int32)
        valid = mask & (safe < n)
        cd = qv.codes[safe].astype(jnp.int32)  # (B, V, m)
        # per-subspace take_along_axis over the (B, ks) LUT rows — bitwise
        # identical to vmapping adc_scores but ~5x faster on CPU XLA, which
        # lowers the (V, m) two-axis fancy gather to a scalar loop while
        # this shape stays a vectorized single-axis gather; the m partial
        # sums fold through the same chain as the kernel (ref.chain_sum_m)
        parts = [
            jnp.take_along_axis(luts[:, mi, :], cd[:, :, mi], axis=1)
            for mi in range(qv.m)
        ]
        dist = jnp.where(valid, chain_sum_m(parts), jnp.inf)
        attrs = index.attrs[safe]
        passing = jax.vmap(
            lambda lo, hi, at: P.evaluate(P.Predicate(lo, hi), at)
        )(pred.lo, pred.hi, attrs)
        return dist, passing & valid


class PallasBackend:
    """Fused Pallas kernels on the hot path.

    VISIT goes through ``kernels.filter_distance`` (scalar-prefetched row
    gather + VPU distance + DNF predicate in one pass over VMEM) and the
    centroid ranking through ``kernels.ivf_score`` (blocked MXU distance
    matrix).  Both kernels implement squared L2 only, so for other metrics
    this backend falls back to the reference math — the engine still runs,
    just without kernel acceleration.
    """

    name = "pallas"

    def visit_scores(self, index, q, pred, safe_ids, mask, metric):
        if metric != "l2":
            return RefBackend().visit_scores(index, q, pred, safe_ids, mask, metric)
        from ...kernels import ops

        dist, passing = ops.filter_distance(
            index.vectors, index.attrs, safe_ids, mask, q, pred.lo, pred.hi
        )
        return dist, passing & mask

    def centroid_scores(self, index, queries, metric):
        if metric != "l2":
            return RefBackend().centroid_scores(index, queries, metric)
        from ...kernels import ops

        return ops.ivf_score(queries, index.centroids)

    def scan_scores(self, index, queries, pred, ids, mask, metric):
        if metric != "l2":
            return RefBackend().scan_scores(index, queries, pred, ids, mask, metric)
        from ...kernels import ops

        dist, passing = ops.filter_distance_batch(
            index.vectors, index.attrs, ids, mask, queries, pred.lo, pred.hi
        )
        return dist, passing & mask

    def adc_scores(self, index, q_resid, lut, pred, safe_ids, mask, metric):
        # the pq_score kernel builds the l2 LUT in-kernel from q_resid (the
        # fused path); non-l2 tables only exist on the jnp path
        if metric != "l2":
            return RefBackend().adc_scores(index, q_resid, lut, pred, safe_ids, mask, metric)
        from ...kernels import ops

        qv = index.qvecs
        dist, passing = ops.pq_score(
            qv.codes, index.attrs, safe_ids, mask, q_resid, qv.codebooks, pred.lo, pred.hi
        )
        return dist, passing & mask

    def scan_scores_quantized(self, index, q_resid, luts, pred, ids, mask, metric):
        if metric != "l2":
            return RefBackend().scan_scores_quantized(
                index, q_resid, luts, pred, ids, mask, metric
            )
        from ...kernels import ops

        qv = index.qvecs
        dist, passing = ops.pq_score_batch(
            qv.codes, index.attrs, ids, mask, q_resid, qv.codebooks, pred.lo, pred.hi
        )
        return dist, passing & mask


class QuantAdapter:
    """Per-query scoring view over a base backend: VISIT goes through the
    ADC tables, everything else passes through.

    The driver instantiates one per query (inside the vmap) when
    ``CompassParams.quant`` is active, capturing that query's precomputed
    (m, ks) table and centered residual; the iterators and ``state.visit``
    keep calling the ordinary ``visit_scores`` surface, so candidate
    generation is untouched — exactly the generation/scoring split the
    backend layer exists for.  ``counts_as`` routes the work into
    ``SearchStats.n_adc`` (see state.visit).
    """

    counts_as = "adc"

    def __init__(self, inner: VisitBackend, lut, q_resid):
        self.inner = inner
        self.name = inner.name
        self.lut = lut
        self.q_resid = q_resid

    def visit_scores(self, index, q, pred, safe_ids, mask, metric):
        return self.inner.adc_scores(
            index, self.q_resid, self.lut, pred, safe_ids, mask, metric
        )

    def centroid_scores(self, index, queries, metric):
        # the coarse layer stays full-precision (standard IVF-PQ: centroid
        # ranking is (B, C) small and drives candidate generation)
        return self.inner.centroid_scores(index, queries, metric)

    def scan_scores(self, index, queries, pred, ids, mask, metric):
        return self.inner.scan_scores(index, queries, pred, ids, mask, metric)


_BACKENDS = {"ref": RefBackend(), "pallas": PallasBackend()}


def resolve_backend(name: str) -> VisitBackend:
    """Map a CompassParams.backend value to a backend instance.

    ``"auto"`` picks the Pallas kernels when running natively on TPU and the
    reference path elsewhere (interpret-mode kernels are correct on CPU but
    slower than XLA's fused gathers; tests opt in explicitly).
    """
    if name == "auto":
        name = "pallas" if jax.default_backend() == "tpu" else "ref"
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; expected one of {sorted(_BACKENDS)} or 'auto'"
        ) from None
