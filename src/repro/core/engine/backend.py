"""Scoring backends for the Compass execution engine.

A :class:`VisitBackend` answers the score queries the engine makes on
its hot path, and nothing else:

  * ``visit_step``      — Algorithm 4's whole per-step scoring: distance +
    DNF predicate + tombstone mask + queue-admission candidates over a
    fixed-size visit list (the per-step hot spot).  The pallas backend
    runs it as ONE fused kernel (kernels/visit_step.py); ref composes
    ``visit_scores`` + the live gather + the admission select — the exact
    pre-fusion engine sequence, so ``backend="ref"`` stays bitwise
    identical across engine versions.
  * ``visit_scores``    — the unfused distance + predicate evaluation
    (kept public: the planner's probes and the unfused visit path use it).
  * ``centroid_scores`` — B.OPEN / G.OPEN's exact centroid ranking input
    (one blocked scan per query *batch*, hoisted out of the per-query vmap
    so the pallas path gets the cross-query MXU blocking ``ivf_score`` is
    built for; see index.py for why this replaces the paper's cluster
    graph G').

Candidate *generation* (queue management, graph expansion, B+-tree cursors)
stays in the iterators — the NaviX/CHASE lesson that hybrid-query engines
need generation and scoring separable.  Backends agree exactly on
semantics (masked entries score ``+inf`` / ``False``; the same records
pass, the same distances are returned for VISIT), and the parity suite in
tests/test_compass_search.py asserts end-to-end identical ids/dists on its
fixed workloads.  One caveat keeps this short of a universal bit-for-bit
guarantee: ``ivf_score`` computes centroid distances via the
``||q||² - 2q·c + ||c||²`` MXU expansion while the ref path computes
``Σ(c-q)²``, so two *near-equidistant* clusters can swap rank order under
float32 rounding, which may reorder cluster visits on adversarial data.
Result-queue contents are distance-sorted either way; only tie-adjacent
candidate sets can differ, and never for VISIT scoring itself (the
filter_distance kernel evaluates the same f32 ``Σ(v-q)²`` as the ref
gather).

``"ref"`` is the plain-jnp gather path (the original core/search.py math,
moved verbatim).  ``"pallas"`` routes VISIT through the fused
``kernels.visit_step`` TPU kernel (``kernels.filter_distance`` when
``fused_visit=False``) and centroid ranking through ``kernels.ivf_score``;
on CPU the kernels run in Pallas interpret mode (see kernels/ops.py) so
tests exercise the kernel path.  ``"auto"`` resolves to ``"pallas"`` on
TPU and ``"ref"`` elsewhere.

Metrics: every scoring surface takes ``metric`` — "l2" (squared L2) and
"ip" (negated inner product) both run on the kernels; cosine is rewritten
to ip over normalized rows by the driver and never reaches this layer.
The shared per-row expression is ``kernels.ref.row_distance``, so ref and
pallas agree bitwise on VISIT for both metrics.
"""
from __future__ import annotations

from typing import Protocol

import jax
import jax.numpy as jnp

from .. import predicate as P


class VisitBackend(Protocol):
    """Scoring interface consumed by :func:`engine.state.visit`, the
    driver's OPEN step, and the planner's PREFILTER run scan."""

    name: str

    def visit_scores(self, index, q, pred, safe_ids, mask, metric):
        """(dist (V,) f32 with +inf where masked; passing (V,) bool)."""
        ...

    def visit_step(
        self, index, q, pred, safe_ids, mask, metric, fused=True, rows_per_step=None
    ):
        """The fused per-step scoring surface consumed by ``state.visit``:
        returns ``(dist (V,) f32, admit (V,) f32)`` where ``dist`` feeds
        the traversal queues (+inf where masked/sentinel) and ``admit``
        equals ``dist`` for valid, predicate-passing AND live rows, +inf
        otherwise (what the filtered result queue merges).  ``fused=False``
        forces the unfused visit_scores + live + select composition on
        every backend (CompassParams.fused_visit).  ``rows_per_step`` pins
        the fused kernel's block size (ShapePolicy.visit_rb; None =
        autotune); non-kernel backends ignore it — block choice never
        affects results."""
        ...

    def centroid_scores(self, index, queries, metric):
        """Per-cluster distance scores for a query batch: (B, nlist) f32."""
        ...

    def scan_scores(self, index, queries, pred, ids, mask, metric):
        """Batched run-scan scoring for the planner's PREFILTER mode:
        (B, V) candidate ids against (B, d) queries and (B, T, A) predicate
        tensors -> (dist (B, V) f32 with +inf where masked, passing (B, V)
        bool).  Same per-row semantics as visit_scores, hoisted out of the
        per-query vmap so the pallas path gets one blocked problem."""
        ...

    def adc_scores(self, index, q_resid, lut, pred, safe_ids, mask, metric):
        """Quantized visit scoring: distances come from the per-query ADC
        table over ``index.qvecs`` codes instead of the float32 rows.
        ``q_resid`` is the centered zero-padded query (consumed by the
        pallas kernel's fused LUT construction), ``lut`` the precomputed
        (m, ks) table (consumed by the jnp path) — same math, one source
        (kernels.ref.subspace_lut).  Sentinel ids are masked-out slots
        even under a true mask.  Returns (dist (V,), passing (V,))."""
        ...

    def scan_scores_quantized(self, index, q_resid, luts, pred, ids, mask, metric):
        """Batched quantized scan — scan_scores over PQ codes: (B, V) ids,
        (B, d_pad) residual queries, (B, m, ks) tables.  Serves the
        planner's PREFILTER materialization and the mutable delta brute
        scan when the quantized tier is active."""
        ...


class RefBackend:
    """Plain jnp gathers — the original search hot path, moved verbatim."""

    name = "ref"

    def visit_scores(self, index, q, pred, safe_ids, mask, metric):
        from ...kernels.ref import row_distance

        vecs = index.vectors[safe_ids]  # (V, d)
        # the one expression the pallas kernels also evaluate per row
        # (kernels/ref.row_distance) — parity is bitwise for l2 and ip
        dist = row_distance(vecs, q[None, :], metric)
        dist = jnp.where(mask, dist, jnp.inf)
        attrs = index.attrs[safe_ids]
        passing = P.evaluate(pred, attrs) & mask
        return dist, passing

    def visit_step(
        self, index, q, pred, safe_ids, mask, metric, fused=True, rows_per_step=None
    ):
        # the pre-fusion engine sequence, verbatim: unfused scoring, then
        # the tombstone AND, then the admission select (state.visit's old
        # body) — the parity oracle for the fused kernel
        dist, passing = self.visit_scores(index, q, pred, safe_ids, mask, metric)
        if index.live is not None:
            passing = passing & index.live[safe_ids]
        return dist, jnp.where(passing, dist, jnp.inf)

    def centroid_scores(self, index, queries, metric):
        if metric == "l2":
            cdiff = index.centroids[None, :, :] - queries[:, None, :]
            return jnp.sum(cdiff * cdiff, axis=-1)
        return -(queries @ index.centroids.T)

    def scan_scores(self, index, queries, pred, ids, mask, metric):
        n = index.n_records
        safe = jnp.where(mask, jnp.clip(ids, 0, n), n).astype(jnp.int32)
        # sentinel ids are masked-out slots even under a true mask (same
        # validity rule as the filter_distance kernels)
        valid = mask & (safe < n)
        from ...kernels.ref import row_distance

        vecs = index.vectors[safe]  # (B, V, d)
        dist = row_distance(vecs, queries[:, None, :], metric)
        dist = jnp.where(valid, dist, jnp.inf)
        attrs = index.attrs[safe]  # (B, V, A)
        passing = jax.vmap(
            lambda lo, hi, at: P.evaluate(P.Predicate(lo, hi), at)
        )(pred.lo, pred.hi, attrs)
        return dist, passing & valid

    def adc_scores(self, index, q_resid, lut, pred, safe_ids, mask, metric):
        from ...kernels.ref import chain_sum_m

        qv = index.qvecs
        n = index.n_records
        valid = mask & (safe_ids < n)
        cd = qv.codes[safe_ids].astype(jnp.int32)  # (V, m)
        vals = lut[jnp.arange(qv.m)[None, :], cd]  # (V, m)
        dist = chain_sum_m([vals[:, mi] for mi in range(qv.m)])
        dist = jnp.where(valid, dist, jnp.inf)
        attrs = index.attrs[safe_ids]
        passing = P.evaluate(pred, attrs) & valid
        return dist, passing

    def scan_scores_quantized(self, index, q_resid, luts, pred, ids, mask, metric):
        from ...kernels.ref import chain_sum_m

        qv = index.qvecs
        n = index.n_records
        safe = jnp.where(mask, jnp.clip(ids, 0, n), n).astype(jnp.int32)
        valid = mask & (safe < n)
        cd = qv.codes[safe].astype(jnp.int32)  # (B, V, m)
        # per-subspace take_along_axis over the (B, ks) LUT rows — bitwise
        # identical to vmapping adc_scores but ~5x faster on CPU XLA, which
        # lowers the (V, m) two-axis fancy gather to a scalar loop while
        # this shape stays a vectorized single-axis gather; the m partial
        # sums fold through the same chain as the kernel (ref.chain_sum_m)
        parts = [
            jnp.take_along_axis(luts[:, mi, :], cd[:, :, mi], axis=1)
            for mi in range(qv.m)
        ]
        dist = jnp.where(valid, chain_sum_m(parts), jnp.inf)
        attrs = index.attrs[safe]
        passing = jax.vmap(
            lambda lo, hi, at: P.evaluate(P.Predicate(lo, hi), at)
        )(pred.lo, pred.hi, attrs)
        return dist, passing & valid


class PallasBackend:
    """Fused Pallas kernels on the hot path.

    VISIT goes through ``kernels.visit_step`` — one kernel for the whole
    per-step hot spot: scalar-prefetched row gather + VPU distance + DNF
    predicate + tombstone mask + queue-admission candidates (the unfused
    ``kernels.filter_distance`` stays behind ``fused_visit=False``) — and
    the centroid ranking through ``kernels.ivf_score`` (blocked MXU
    distance matrix).  Every kernel implements squared L2 and negated
    inner product (static ``metric``); only genuinely unknown metrics fall
    back to the reference math, and each such fallback bumps
    ``compass_kernel_fallback_total{kernel,reason="metric:<m>"}`` so a
    silently-ref-routed deployment is visible in the registry.
    """

    name = "pallas"

    _KERNEL_METRICS = ("l2", "ip")

    @staticmethod
    def _metric_fallback(kernel: str, metric: str) -> None:
        from repro.obs import profiling as prof

        prof.count_fallback(kernel, f"metric:{metric}")

    def visit_scores(self, index, q, pred, safe_ids, mask, metric):
        if metric not in self._KERNEL_METRICS:
            self._metric_fallback("filter_distance", metric)
            return RefBackend().visit_scores(index, q, pred, safe_ids, mask, metric)
        from ...kernels import ops

        dist, passing = ops.filter_distance(
            index.vectors, index.attrs, safe_ids, mask, q, pred.lo, pred.hi,
            metric=metric,
        )
        return dist, passing & mask

    def visit_step(
        self, index, q, pred, safe_ids, mask, metric, fused=True, rows_per_step=None
    ):
        if not fused or metric not in self._KERNEL_METRICS:
            if metric not in self._KERNEL_METRICS:
                self._metric_fallback("visit_step", metric)
            else:
                self._metric_fallback("visit_step", "fused_visit=False")
            # unfused: the pre-fusion kernel sequence (filter_distance
            # kernel + jnp live gather + admission select)
            dist, passing = self.visit_scores(index, q, pred, safe_ids, mask, metric)
            if index.live is not None:
                passing = passing & index.live[safe_ids]
            return dist, jnp.where(passing, dist, jnp.inf)
        from ...kernels import ops

        return ops.visit_step(
            index.vectors, index.attrs, index.live, safe_ids, mask, q,
            pred.lo, pred.hi, metric=metric, rows_per_step=rows_per_step,
        )

    def centroid_scores(self, index, queries, metric):
        if metric not in self._KERNEL_METRICS:
            self._metric_fallback("ivf_score", metric)
            return RefBackend().centroid_scores(index, queries, metric)
        from ...kernels import ops

        return ops.ivf_score(queries, index.centroids, metric=metric)

    def scan_scores(self, index, queries, pred, ids, mask, metric):
        if metric not in self._KERNEL_METRICS:
            self._metric_fallback("filter_distance", metric)
            return RefBackend().scan_scores(index, queries, pred, ids, mask, metric)
        from ...kernels import ops

        dist, passing = ops.filter_distance_batch(
            index.vectors, index.attrs, ids, mask, queries, pred.lo, pred.hi,
            metric=metric,
        )
        return dist, passing & mask

    def adc_scores(self, index, q_resid, lut, pred, safe_ids, mask, metric):
        # the pq_score kernel builds the LUT in-kernel from q_resid (the
        # fused path); precomputed tables only feed the jnp path
        if metric not in self._KERNEL_METRICS:
            self._metric_fallback("pq_score", metric)
            return RefBackend().adc_scores(index, q_resid, lut, pred, safe_ids, mask, metric)
        from ...kernels import ops

        qv = index.qvecs
        dist, passing = ops.pq_score(
            qv.codes, index.attrs, safe_ids, mask, q_resid, qv.codebooks,
            pred.lo, pred.hi, metric=metric,
        )
        return dist, passing & mask

    def scan_scores_quantized(self, index, q_resid, luts, pred, ids, mask, metric):
        if metric not in self._KERNEL_METRICS:
            self._metric_fallback("pq_score", metric)
            return RefBackend().scan_scores_quantized(
                index, q_resid, luts, pred, ids, mask, metric
            )
        from ...kernels import ops

        qv = index.qvecs
        dist, passing = ops.pq_score_batch(
            qv.codes, index.attrs, ids, mask, q_resid, qv.codebooks,
            pred.lo, pred.hi, metric=metric,
        )
        return dist, passing & mask


class QuantAdapter:
    """Per-query scoring view over a base backend: VISIT goes through the
    ADC tables, everything else passes through.

    The driver instantiates one per query (inside the vmap) when
    ``CompassParams.quant`` is active, capturing that query's precomputed
    (m, ks) table and centered residual; the iterators and ``state.visit``
    keep calling the ordinary ``visit_scores`` surface, so candidate
    generation is untouched — exactly the generation/scoring split the
    backend layer exists for.  ``counts_as`` routes the work into
    ``SearchStats.n_adc`` (see state.visit).
    """

    counts_as = "adc"

    def __init__(self, inner: VisitBackend, lut, q_resid):
        self.inner = inner
        self.name = inner.name
        self.lut = lut
        self.q_resid = q_resid

    def visit_scores(self, index, q, pred, safe_ids, mask, metric):
        return self.inner.adc_scores(
            index, self.q_resid, self.lut, pred, safe_ids, mask, metric
        )

    def visit_step(
        self, index, q, pred, safe_ids, mask, metric, fused=True, rows_per_step=None
    ):
        # ADC scoring stays a separate kernel (pq_score builds the LUT in
        # scratch); the tombstone AND + admission select compose here —
        # both inner backends produce parity-tested (dist, passing), so the
        # composed admit inherits the parity
        dist, passing = self.visit_scores(index, q, pred, safe_ids, mask, metric)
        if index.live is not None:
            passing = passing & index.live[safe_ids]
        return dist, jnp.where(passing, dist, jnp.inf)

    def centroid_scores(self, index, queries, metric):
        # the coarse layer stays full-precision (standard IVF-PQ: centroid
        # ranking is (B, C) small and drives candidate generation)
        return self.inner.centroid_scores(index, queries, metric)

    def scan_scores(self, index, queries, pred, ids, mask, metric):
        return self.inner.scan_scores(index, queries, pred, ids, mask, metric)


_BACKENDS = {"ref": RefBackend(), "pallas": PallasBackend()}


def resolve_backend(name: str) -> VisitBackend:
    """Map a CompassParams.backend value to a backend instance.

    ``"auto"`` picks the Pallas kernels when running natively on TPU and the
    reference path elsewhere (interpret-mode kernels are correct on CPU but
    slower than XLA's fused gathers; tests opt in explicitly).
    """
    if name == "auto":
        name = "pallas" if jax.default_backend() == "tpu" else "ref"
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; expected one of {sorted(_BACKENDS)} or 'auto'"
        ) from None
