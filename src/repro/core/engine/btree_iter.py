"""B.NEXT — the pull-based relational iterator (Algorithm 3).

Pulls predicate-passing records from the clustered B+-trees (per-attribute
sorted runs, see clustered_attrs.py) of the clusters nearest to the query,
on demand, through the ranked-cluster cursor stored in the engine state
(``rank`` / ``rank_pos`` / ``term_beg`` / ``term_end`` / ``b_exhausted``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import predicate as P
from ..clustered_attrs import searchsorted_slice
from . import state as S


def step(index, q, pred, chosen, st: S.EngineState, pm, backend) -> S.EngineState:
    """One B.NEXT pull: fetch up to ``efi`` candidate records and VISIT them."""
    ca = index.cattrs
    nlist = index.nlist
    T = pred.lo.shape[0]

    def advance_cluster(st: S.EngineState):
        """Advance the ranked-cluster cursor; point the per-term cursors at
        the new cluster's per-attribute sorted runs."""
        exhausted = st.rank_pos >= nlist
        c = st.rank[jnp.clip(st.rank_pos, 0, nlist - 1)]
        c_beg, c_end = ca.offsets[c], ca.offsets[c + 1]

        def one_term(t):
            a = chosen[t]
            lo_v, hi_v = pred.lo[t, a], pred.hi[t, a]
            beg = searchsorted_slice(ca.sorted_vals[a], c_beg, c_end, lo_v, "left")
            end = searchsorted_slice(ca.sorted_vals[a], c_beg, c_end, hi_v, "right")
            return beg, end

        beg, end = jax.vmap(one_term)(jnp.arange(T))
        return st._replace(
            rank_pos=jnp.where(exhausted, st.rank_pos, st.rank_pos + 1),
            term_beg=jnp.where(exhausted, st.term_beg, beg),
            term_end=jnp.where(exhausted, st.term_end, end),
            b_exhausted=st.b_exhausted | exhausted,
        )

    def maybe_advance(st: S.EngineState):
        rem = jnp.sum(jnp.maximum(st.term_end - st.term_beg, 0))
        need = (rem == 0) & ~st.b_exhausted
        return jax.lax.cond(need, advance_cluster, lambda s: s, st)

    st = jax.lax.fori_loop(0, pm.cluster_tries, lambda _, s: maybe_advance(s), st)

    # fetch up to efi positions across terms (term-major order)
    rem = jnp.maximum(st.term_end - st.term_beg, 0)  # (T,)
    cum = jnp.cumsum(rem)
    total = cum[-1]
    cum_e = jnp.minimum(cum, pm.efi)
    taken = cum_e - jnp.concatenate([jnp.zeros((1,), cum.dtype), cum_e[:-1]])
    slots = jnp.arange(pm.efi)
    term_of = jnp.searchsorted(cum, slots, side="right").astype(jnp.int32)
    term_of_c = jnp.clip(term_of, 0, T - 1)
    before = jnp.where(term_of_c > 0, cum[jnp.maximum(term_of_c - 1, 0)], 0)
    pos = st.term_beg[term_of_c] + (slots - before)
    slot_ok = slots < jnp.minimum(total, pm.efi)
    attr_of = chosen[term_of_c]
    ids = ca.order[attr_of, jnp.clip(pos, 0, ca.n_records - 1)]
    # full-predicate filter on the remaining attributes (paper: linear scan)
    n = index.n_records
    safe = jnp.where(slot_ok, ids, n)
    passing = P.evaluate(pred, index.attrs[safe]) & slot_ok
    st = st._replace(term_beg=st.term_beg + taken)
    st = S.visit(index, q, pred, st, jnp.where(passing, ids, n), passing, pm, backend)
    return st._replace(stats=st.stats._replace(n_bcalls=st.stats.n_bcalls + 1))
