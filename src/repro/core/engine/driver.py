"""The Compass driver loop — Algorithm 1's G.NEXT/B.NEXT coordination as
one fused, batched ``lax.while_loop``.

Faithfulness notes (full discussion in DESIGN.md §Adaptation):

* The paper structures the search as two pull-based iterators (G.NEXT /
  B.NEXT) coordinating through a shared candidate queue.  On TPU, function
  calls are free but *dynamic shapes are not*, so the two iterators become
  two branches of a single fixed-shape loop body; the shared candidate
  queue, visited set, progressive ``efs``, passrate-adaptive expansion,
  round-paced result returns and relational injection are all preserved
  with identical candidate flow.  The iterators live in graph_iter.py /
  btree_iter.py behind the same ``step(state) -> state`` shape; scoring is
  pluggable via backend.py (``"ref"`` jnp gathers vs ``"pallas"`` fused
  kernels); this module is only the coordination.
* The paper's cluster graph G' (§IV.C) is replaced by an exact centroid
  ranking — one MXU matmul at OPEN — consumed through a cursor, preserving
  the on-demand semantics (see index.py docstring).
* Visited is a plain bool vector (a packed bitmap is a pure memory
  optimization; noted in DESIGN.md §Perf).

The same loop, parameterized by :class:`CompassParams`, also implements the
paper's baselines and ablations:
  * ``in_filter=True, use_btree=False``  -> NaviX/ACORN-style in-filtering.
  * ``use_btree=False``                  -> plain progressive HNSW
    (post-filtering building block).
  * ``use_graph=False``                  -> CompassRelational ablation.
  * index built with ``nlist=1``         -> CompassGraph ablation.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from typing import TYPE_CHECKING

from .. import predicate as P
from ..planner import plan as qplan
from ..quant import encode as Q
from ..quant.params import QuantParams
from ..quant.rerank import rerank_batch
from . import btree_iter, graph_iter
from . import state as S
from .backend import QuantAdapter, VisitBackend, resolve_backend
from .state import EngineState, FixedQueue, SearchResult, SearchStats

if TYPE_CHECKING:  # runtime import would cycle (index -> planner -> engine)
    from ..index import CompassIndex

#: Bumped whenever the engine's candidate flow changes in a way that could
#: move benchmark trajectories (recorded in BENCH_*.json by benchmarks/).
#: engine/2: cost-based planner (per-query PREFILTER/COOPERATIVE/POSTFILTER
#: dispatch) + the centroid scan is skipped when nothing consumes it.
#: engine/3: mutable-index tombstone masking — dead records keep routing in
#: the visit loop but are ANDed out of the result queue and the PREFILTER
#: adoption (no-op for immutable indices: index.live is None).
#: engine/4: quantized tier — with CompassParams.quant set, stage one runs
#: the loop at ef*refine_factor with ADC scoring (kernels/pq_score) and
#: stage two reranks the survivors exactly; quant=None paths are bitwise
#: unchanged (trace-time branch on index.qvecs / pm.quant).
#: engine/5: fused visit step — state.visit scores through the single
#: backend.visit_step surface (pallas: one kernels/visit_step.py call for
#: gather + distance + predicate + tombstone + admission); ip runs on the
#: kernels (no more ref fallback) and "cos" is rewritten to ip over
#: normalized rows at entry.  backend="ref" and fused_visit=False paths
#: stay bitwise identical to engine/4.
ENGINE_VERSION = "engine/5"


@dataclasses.dataclass(frozen=True)
class ShapePolicy:
    """Every steady-state compiled shape in one frozen, hashable config.

    ``compass_search`` / ``mutable_search`` are jitted over static shapes:
    each distinct row count, delta capacity, queue width or kernel block is
    a fresh XLA program.  The shape-affecting knobs used to be scattered
    (row counts implicit in the fold, ``delta_cap`` on MutableIndex, ``ef``
    on CompassParams, block pins in env vars); this object gathers them so
    the serving executable cache can key on *one* value and the mutable
    path can hold every shape fixed across compaction epochs
    (DESIGN.md §Mutability, bucket-fold contract):

      * **row buckets** — compaction folds pad the base to the next
        power-of-two row count (>= ``min_rows``) with dead, tombstoned
        rows, so churn that stays within a bucket re-traces nothing.
      * **delta capacity** — ``delta_cap`` (0 = adopt the MutableIndex
        constructor argument) is a compiled shape; owning it here makes it
        part of the policy identity rather than an ad-hoc constructor int.
      * **ef / refine widths** — ``ef_step`` rounds ``ef`` (and therefore
        the quant-widened ``ef * refine_factor`` stage-one width) up to a
        multiple, collapsing near-miss configurations onto shared
        executables.  Rounding *widens* the search — results are those of
        the rounded ``ef``, never an approximation of the requested one.
      * **fused-visit block** — ``visit_rb`` pins the visit-step kernel's
        rows-per-step (0 = autotune / ``REPRO_PALLAS_BLOCK_VISIT_STEP``),
        making the block choice part of the params identity instead of
        ambient process state.  Block choice never affects results.

    ``ef`` / ``refine_factor`` here are construction-time overrides
    (0 = keep the CompassParams / QuantParams field): ``CompassParams.
    __post_init__`` adopts a non-zero value into the legacy field and
    normalizes it back to 0, so the legacy fields stay the single source
    of truth and existing call sites / BENCH provenance keys keep working.
    """

    bucket_rows: bool = True  # pad compaction folds to power-of-two buckets
    min_rows: int = 1024  # smallest row bucket a fold pads to
    delta_cap: int = 0  # delta-segment capacity; 0 = constructor default
    ef_step: int = 0  # round ef up to a multiple; 0 = exact (no rounding)
    visit_rb: int = 0  # fused visit-step rows-per-step pin; 0 = autotune
    ef: int = 0  # construction-time override of CompassParams.ef
    refine_factor: int = 0  # construction-time override of quant.refine_factor

    def row_bucket(self, n_live: int) -> int:
        """Padded base row count for ``n_live`` real rows (identity when
        ``bucket_rows`` is off)."""
        if not self.bucket_rows:
            return n_live
        return max(self.min_rows, 1 << max(0, n_live - 1).bit_length())

    def bucket_ef(self, ef: int) -> int:
        """``ef`` rounded up to the next ``ef_step`` multiple (identity
        when ``ef_step`` is 0)."""
        if self.ef_step <= 0:
            return ef
        return -(-ef // self.ef_step) * self.ef_step

    def resolve_delta_cap(self, default: int) -> int:
        return self.delta_cap if self.delta_cap > 0 else int(default)


@dataclasses.dataclass(frozen=True)
class CompassParams:
    k: int = 10  # results to return
    ef: int = 64  # target size of the filtered result queue (paper `ef`)
    alpha: float = 0.3  # one-hop passrate threshold (paper default)
    beta: float = 0.05  # two-hop / pivot passrate threshold (paper default)
    efs0: int = 16  # initial progressive search width
    stepsize: int = 16  # progressive efs increment (paper `stepsize`)
    ef_cap: int = 0  # max efs; 0 => 2 * ef + 32
    cand_cap: int = 0  # shared queue capacity; 0 => ef_cap + 64
    efi: int = 32  # records fetched per B.NEXT (paper `efi`)
    k2: int = 16  # two-hop visit budget per expansion
    max_steps: int = 0  # hard iteration budget; 0 => heuristic
    metric: str = "l2"
    use_graph: bool = True  # False => CompassRelational ablation
    use_btree: bool = True  # False => pure graph (NaviX / HNSW modes)
    in_filter: bool = False  # True => NaviX-style distance-only-if-passing
    adaptive_entry: bool = True  # IVF-guided entry (False: global medoid)
    entry_fanout: int = 4  # medoids of the top-R clusters seed the traversal
    cluster_tries: int = 8  # clusters examined per B step at most
    beam: int = 1  # candidates popped+expanded per loop step (DESIGN.md
    # §Perf: beam>1 amortizes the per-step queue sorts and raises the
    # arithmetic intensity of each visit batch; passrate adaptivity is
    # evaluated over the pooled beam neighborhood instead of per candidate)
    backend: str = "auto"  # "ref" | "pallas" | "auto" (pallas on TPU)
    fused_visit: bool = True  # route VISIT through the fused visit-step
    # kernel on the pallas backend (kernels/visit_step.py).  False keeps
    # the unfused filter_distance + live-gather + select sequence — same
    # results bitwise, one extra kernel launch + two HBM round-trips per
    # visit batch (the parity suite asserts on/off equality).
    planner: bool = False  # cost-based per-query mode selection (DESIGN.md
    # §Planner; requires index.astats — i.e. an index built by build_index)
    prefilter_cap: int = 0  # max materialized run rows for PREFILTER;
    # 0 => 8 * ef (the cost-model crossover, see planner/plan.py)
    postfilter_min_sel: float = 0.9  # POSTFILTER eligible above this
    # estimated selectivity ("selectivity ≈ 1": the filter is near-vacuous)
    quant: QuantParams | None = None  # quantized-tier search (DESIGN.md
    # §Quantization; requires index.qvecs — i.e. quantize_index).  None
    # (the default) keeps every program bitwise identical to exact search.
    shape: ShapePolicy = ShapePolicy()  # compiled-shape policy (row/ef
    # buckets, delta capacity, kernel block pin).  Part of hash/eq, so it
    # keys every executable cache that keys on CompassParams.

    def __post_init__(self):
        # Adopt ShapePolicy's construction-time overrides into the legacy
        # fields, then normalize them back to 0.  The normalization makes
        # __post_init__ idempotent under dataclasses.replace — the quant
        # stage does replace(pm, ef=ef*rf, k=ef*rf), and a sticky nonzero
        # shape.ef would silently clobber the widened width on re-init.
        sp = self.shape
        if sp.ef:
            object.__setattr__(self, "ef", sp.ef)
        if sp.refine_factor and self.quant is not None:
            object.__setattr__(
                self,
                "quant",
                dataclasses.replace(self.quant, refine_factor=sp.refine_factor),
            )
        if sp.ef or sp.refine_factor:
            object.__setattr__(
                self, "shape", dataclasses.replace(sp, ef=0, refine_factor=0)
            )
        # ef rounding happens here, not in resolved(): two params that
        # land in the same ef bucket must already be ==/hash-equal so the
        # jit trace cache and serving executable keys collapse them.
        if sp.ef_step > 0:
            object.__setattr__(self, "ef", sp.bucket_ef(self.ef))

    def resolved(self) -> "CompassParams":
        ef_cap = self.ef_cap or 2 * self.ef + 32
        cand_cap = self.cand_cap or ef_cap + 64
        max_steps = self.max_steps or (4 * ef_cap + 8 * self.ef + 64)
        prefilter_cap = self.prefilter_cap or 8 * self.ef
        return dataclasses.replace(
            self,
            ef_cap=ef_cap,
            cand_cap=cand_cap,
            max_steps=max_steps,
            prefilter_cap=prefilter_cap,
        )


def _search_one(
    index: CompassIndex,
    q,
    cdists,
    pred: P.Predicate,
    pm: CompassParams,
    backend: VisitBackend,
    needs_rank: bool = True,
    plan: "qplan.PlannedBatch | None" = None,
    lut=None,
    q_resid=None,
) -> SearchResult:
    n = index.n_records
    nlist = index.nlist
    T = pred.lo.shape[0]
    chosen = P.chosen_attrs(pred)
    if lut is not None:
        # quantized tier: route VISIT scoring through this query's ADC
        # table; candidate generation (iterators, queues) is untouched
        backend = QuantAdapter(backend, lut, q_resid)

    # B.OPEN / G.OPEN: exact centroid ranking shared by the relational
    # iterator and the adaptive entry.  `cdists` is computed batched in
    # compass_search (outside the per-query vmap) so the pallas backend's
    # ivf_score kernel sees the full (B, C) blocked problem.
    rank = jnp.argsort(cdists).astype(jnp.int32)
    mode = jnp.int32(qplan.COOPERATIVE) if plan is None else plan.mode

    zero = jnp.int32(0)
    stats = SearchStats(
        n_dist=zero,
        n_cdist=jnp.int32(nlist if needs_rank else 0),
        n_steps=zero,
        n_bcalls=zero,
        n_clusters_ranked=zero,
        n_adc=zero,
        n_rerank=zero,
        n_pass=zero,
        mode=mode,
        efs_final=jnp.int32(pm.efs0),
        # planner provenance rides in the stats so an explain trace can
        # compare estimate vs. measurement without re-running the planner
        # (obs/trace.py); -1 marks "planner off, no estimate"
        est_sel=jnp.float32(-1.0) if plan is None else plan.est_sel,
        run_total=jnp.int32(-1) if plan is None else plan.run_total,
    )
    st = EngineState(
        cand=FixedQueue.full(pm.cand_cap, n),
        gtop=FixedQueue.full(pm.ef_cap, n),
        efs=jnp.int32(pm.efs0),
        res=FixedQueue.full(pm.ef, n),
        visited=jnp.zeros((n + 1,), bool),
        rank=rank,
        rank_pos=jnp.int32(0),
        term_beg=jnp.zeros((T,), jnp.int32),
        term_end=jnp.zeros((T,), jnp.int32),
        # PREFILTER and POSTFILTER never pull B.NEXT: the former already
        # holds the exact result, the latter is the graph-dominant plan.
        b_exhausted=jnp.asarray(not pm.use_btree) | (mode != qplan.COOPERATIVE),
        returned=jnp.int32(0),
        stalled=jnp.asarray(False),
        last_sel=jnp.float32(1.0),
        stats=stats,
    )

    if plan is not None:
        # PREFILTER: the planner materialized + pre-scored every candidate
        # run row (batched scan, hoisted out of the vmap); adopt the exact
        # top-ef here and retire the query before the loop starts.
        def run_prefilter(s: EngineState) -> EngineState:
            safe = jnp.where(plan.mask, plan.ids, n).astype(jnp.int32)
            visited = s.visited.at[safe].set(True)
            passing = plan.passing
            if index.live is not None:  # tombstoned rows stay out of results
                passing = passing & index.live[safe]
            res = s.res.merge(jnp.where(passing, plan.dist, S.INF), safe)
            n_pass = s.stats.n_pass + jnp.sum(passing).astype(jnp.int32)
            if lut is not None:  # the planner scan scored through ADC tables
                stats2 = s.stats._replace(
                    n_adc=s.stats.n_adc + jnp.sum(plan.mask), n_pass=n_pass
                )
            else:
                stats2 = s.stats._replace(
                    n_dist=s.stats.n_dist + jnp.sum(plan.mask), n_pass=n_pass
                )
            return s._replace(
                res=res,
                visited=visited,
                returned=jnp.int32(pm.ef),
                stalled=jnp.asarray(True),
                stats=stats2,
            )

        st = jax.lax.cond(mode == qplan.PREFILTER, run_prefilter, lambda s: s, st)

    if pm.use_graph:
        entries = graph_iter.seed_entries(index, rank, pm)
        seed_mask = jnp.ones(entries.shape, bool) & (mode != qplan.PREFILTER)
        st = S.visit(index, q, pred, st, entries, seed_mask, pm, backend)

    def cond(st: EngineState):
        return (
            (st.returned < pm.ef)
            & (st.stats.n_steps < pm.max_steps)
            & ~st.stalled
        )

    def body(st: EngineState):
        if pm.use_graph:
            st, need_b = graph_iter.step(index, q, pred, st, pm, backend)
        else:
            need_b = jnp.asarray(True)

        if pm.use_btree:

            def do_b(s):
                s = btree_iter.step(index, q, pred, chosen, s, pm, backend)
                return S.credit(s, max(1, pm.k // 2))  # Alg. 3 line 20: k/2 batch

            st = jax.lax.cond(need_b & ~st.b_exhausted, do_b, lambda s: s, st)
        # stall: nothing can make progress anymore
        graph_dead = graph_iter.dead(st, pm) if pm.use_graph else jnp.asarray(True)
        stalled = graph_dead & st.b_exhausted
        # a stalled search still flushes whatever it found
        st = jax.lax.cond(stalled, lambda s: S.credit(s, pm.ef), lambda s: s, st)
        st = st._replace(
            stalled=stalled,
            stats=st.stats._replace(n_steps=st.stats.n_steps + 1, efs_final=st.efs),
        )
        return st

    st = jax.lax.while_loop(cond, body, st)
    final_stats = st.stats._replace(n_clusters_ranked=st.rank_pos)
    return SearchResult(st.res.i[: pm.k], st.res.d[: pm.k], final_stats)


@functools.partial(jax.jit, static_argnames=("pm",))
def compass_search_jit(
    index: CompassIndex,
    queries: jax.Array,
    pred: P.Predicate,
    pm: CompassParams,
    luts: jax.Array | None = None,
    q_resids: jax.Array | None = None,
) -> SearchResult:
    """The jitted search program behind :func:`compass_search` — use it
    directly for AOT paths (``.lower(...).compile()``, the serving
    executable cache) and jit-cache accounting (``._cache_size()``).

    With ``pm.quant`` set (and a quantized index), this is the two-stage
    quantized search: stage one runs the ordinary loop at
    ``ef * refine_factor`` with ADC scoring, stage two reranks the
    survivors exactly and returns the top ``pm.k`` (quant/rerank.py).
    ``luts``/``q_resids`` optionally supply the per-query ADC tables and
    centered residuals (built here when omitted) — the mutable fan-out
    passes its own so base and delta share one table build per query.
    """
    if pm.metric == "cos":
        # cosine == inner product over unit-norm rows: normalize the query
        # batch here and run the whole engine (planner, quant tables,
        # kernels) as "ip" — one rewrite point, no per-kernel cos variants.
        # Requires an index built with BuildConfig(metric="cos"), which
        # normalized the corpus rows at build time.
        from ..distances import normalize_rows

        queries = normalize_rows(queries)
        pm = dataclasses.replace(pm, metric="ip")
    quant = pm.quant is not None
    if quant and index.qvecs is None:
        raise ValueError(
            "CompassParams.quant requires a quantized index "
            "(attach codes with core.quant.quantize_index first)"
        )
    k_out = pm.k
    if quant:
        # stage one: widen the result queue so the approximate ADC ordering
        # still captures the true top-k for stage two to recover
        rf = pm.quant.refine_factor
        pm = dataclasses.replace(pm, ef=pm.ef * rf, k=pm.ef * rf)
    pm = pm.resolved()
    backend = resolve_backend(pm.backend)
    # One blocked (B, C) centroid scan for the whole batch (B.OPEN / G.OPEN)
    # — skipped entirely when nothing consumes the ranking (pure-graph
    # ablations with non-adaptive entry), so SearchStats.n_cdist is the true
    # count rather than an unconditional nlist.  The coarse layer stays
    # full-precision under quantization (standard IVF-PQ).
    needs_rank = pm.use_btree or (pm.use_graph and pm.adaptive_entry)
    if needs_rank:
        cdists = backend.centroid_scores(index, queries, pm.metric)
    else:
        cdists = jnp.zeros((queries.shape[0], index.nlist), jnp.float32)
    if quant:
        # per-query ADC tables, built batched outside the vmap; derived
        # independently so a caller supplying one of the pair still works
        if luts is None:
            luts = Q.build_luts(index.qvecs, queries, pm.metric)  # (B, m, ks)
        if q_resids is None:
            q_resids = Q.residual_queries(index.qvecs, queries)  # (B, d_pad)
    else:
        luts = q_resids = None
    planned = (
        qplan.plan_batch(index, queries, pred, pm, backend, luts=luts, q_resids=q_resids)
        if pm.planner
        else None
    )
    # one vmap for all planner x quant combinations: None is a leafless
    # pytree, so an absent plan / lut / residual passes through the batch
    # axes untouched and _search_one's trace-time `is None` branches see
    # exactly what a narrower call signature would have passed
    res = jax.vmap(
        lambda q, cd, lo, hi, pl, lut, qr: _search_one(
            index, q, cd, P.Predicate(lo, hi), pm, backend, needs_rank, pl, lut, qr
        )
    )(queries, cdists, pred.lo, pred.hi, planned, luts, q_resids)
    if quant:
        res = rerank_batch(
            index, queries, pred, res, k_out, pm.metric, backend, pm.quant.rerank
        )
    return res


def compass_search(
    index: CompassIndex,
    queries: jax.Array,
    pred: P.Predicate,
    pm: CompassParams,
    luts: jax.Array | None = None,
    q_resids: jax.Array | None = None,
    *,
    explain: bool = False,
):
    """Batched filtered search. queries: (B, d); pred arrays: (B, T, A).

    The public entry point: runs :func:`compass_search_jit` (see its
    docstring for the quantized two-stage semantics) and, with
    ``explain=True``, additionally returns one
    :class:`~repro.obs.trace.QueryTrace` per query::

        res, traces = compass_search(index, q, pred, pm, explain=True)
        print(repro.compass.explain(traces))

    Explain is bitwise-free: every field a trace needs already rides in
    the device-side ``SearchStats``, so the traced program — and thus the
    jit/executable cache key and every result bit — is identical with and
    without the flag; ``explain=True`` merely materializes the stats
    host-side afterwards.  The flag is host-only and must not be used
    under an outer ``jax.jit`` (the default ``False`` path is
    transparent to tracing — ``mutable_search`` relies on that).
    """
    res = compass_search_jit(index, queries, pred, pm, luts, q_resids)
    if not explain:
        return res
    from repro.obs.trace import build_traces  # lazy: obs sits above the engine

    return res, build_traces(res, pm)
