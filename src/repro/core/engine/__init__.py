"""The Compass execution engine: CompassSearch (Algorithms 1-4) as three
coordinated layers behind one public entry point.

  * :mod:`~repro.core.engine.state`      — fixed-capacity queues, the fused
    search state, the VISIT state update, credit/round pacing.
  * :mod:`~repro.core.engine.graph_iter` / :mod:`~repro.core.engine.btree_iter`
    — the pull-based G.NEXT / B.NEXT iterators, each a ``step(state)`` over
    the shared state.
  * :mod:`~repro.core.engine.backend`    — pluggable scoring (``"ref"`` jnp
    gathers vs ``"pallas"`` fused TPU kernels), selected by
    ``CompassParams.backend``.
  * :mod:`~repro.core.engine.driver`     — Algorithm 1's coordination loop
    and the public :func:`compass_search`.

``repro.compass`` is the public surface over this package (the legacy
``repro.core.search`` shim re-exports the same names with a
``DeprecationWarning``).
"""
from .backend import PallasBackend, RefBackend, VisitBackend, resolve_backend
from .driver import (
    ENGINE_VERSION,
    CompassParams,
    ShapePolicy,
    compass_search,
    compass_search_jit,
)
from .state import EngineState, FixedQueue, SearchResult, SearchStats

__all__ = [
    "ENGINE_VERSION",
    "CompassParams",
    "EngineState",
    "ShapePolicy",
    "FixedQueue",
    "PallasBackend",
    "RefBackend",
    "SearchResult",
    "SearchStats",
    "VisitBackend",
    "compass_search",
    "compass_search_jit",
    "resolve_backend",
]
