"""Distributed Compass: corpus-sharded filtered search over the production
mesh (the paper's step for the multi-pod dry-run).

Deployment model (DESIGN.md §Distribution):
  * The corpus is sharded record-wise across ALL mesh axes (512 shards on
    the 2x16x16 pod mesh).  Each device owns a full local Compass index
    over its shard: sub-graph, IVF centroids + medoids, clustered attrs.
    Index build is embarrassingly parallel across hosts.
  * A query batch is replicated; every shard runs the *identical* batched
    CompassSearch loop on its local shard (shard_map), then a global top-k
    merge runs over one all-gather of (B, k) candidates — k*B*8 bytes, so
    the collective term is negligible and throughput scales ~linearly with
    devices; the paper's single-node QPS results compose multiplicatively.
  * Recall composition: per-shard recall lower-bounds global recall (the
    global top-k is over the union of per-shard results, each shard's
    ground-truth contribution is a subset of its local top-k).

This module provides the real executable path (used by tests on 1 device
and by examples) and the abstract 512-way dry-run used by launch/dryrun.py.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import predicate as PR
from .clustered_attrs import ClusteredAttrs
from .graph_build import GraphIndex
from .index import BuildConfig, CompassIndex, build_index
from .planner.stats import AttrStats
from .quant.encode import QuantizedVectors, quantize_index
from .quant.params import QuantConfig
from .engine import CompassParams, SearchStats, compass_search


class ShardedIndex(NamedTuple):
    """CompassIndex leaves stacked with a leading shard axis."""

    vectors: jax.Array  # (S, n_loc + 1, d)
    attrs: jax.Array  # (S, n_loc + 1, A)
    neighbors: jax.Array  # (S, n_loc, M)
    entry: jax.Array  # (S,)
    centroids: jax.Array  # (S, nlist, d)
    medoids: jax.Array  # (S, nlist)
    order: jax.Array  # (S, A, n_loc)
    sorted_vals: jax.Array  # (S, A, n_loc)
    offsets: jax.Array  # (S, nlist + 1)
    assignments: jax.Array  # (S, n_loc)
    # planner attribute statistics (per-shard AttrStats leaves)
    hist_edges: jax.Array  # (S, A, n_bins + 1)
    hist_cluster_edges: jax.Array  # (S, nlist, A, n_cluster_bins + 1)
    hist_cluster_counts: jax.Array  # (S, nlist)
    # quantized tier (core/quant), sharded exactly like the row arrays:
    # every shard owns the codes of its own records plus its own codebooks
    # (built per shard, so no cross-shard codebook broadcast), and the
    # two-stage ADC-then-rerank runs *inside* the shard — only the final
    # (B, k) exact-reranked candidates enter the global merge.  None on an
    # unquantized index (pytree-structural, like CompassIndex.qvecs).
    pq_codes: Optional[jax.Array] = None  # (S, n_loc + 1, m) uint8
    pq_codebooks: Optional[jax.Array] = None  # (S, m, ks, dsub)
    pq_mean: Optional[jax.Array] = None  # (S, d)
    pq_train_mse: Optional[jax.Array] = None  # (S,)

    @property
    def n_shards(self) -> int:
        return self.vectors.shape[0]

    @property
    def n_local(self) -> int:
        return self.vectors.shape[1] - 1

    @property
    def quantized(self) -> bool:
        return self.pq_codes is not None


def _to_local_index(s: ShardedIndex) -> CompassIndex:
    """Inside shard_map: strip the (1,) shard axis into a CompassIndex."""
    sq = lambda a: a[0]
    qvecs = None
    if s.pq_codes is not None:
        qvecs = QuantizedVectors(
            sq(s.pq_codes), sq(s.pq_codebooks), sq(s.pq_mean), sq(s.pq_train_mse)
        )
    return CompassIndex(
        vectors=sq(s.vectors),
        attrs=sq(s.attrs),
        graph=GraphIndex(sq(s.neighbors), sq(s.entry)),
        centroids=sq(s.centroids),
        medoids=sq(s.medoids),
        cattrs=ClusteredAttrs(
            sq(s.order), sq(s.sorted_vals), sq(s.offsets), sq(s.assignments)
        ),
        astats=AttrStats(
            sq(s.hist_edges), sq(s.hist_cluster_edges), sq(s.hist_cluster_counts)
        ),
        qvecs=qvecs,
    )


def build_sharded_index(
    vectors: np.ndarray,
    attrs: np.ndarray,
    n_shards: int,
    cfg: BuildConfig = BuildConfig(),
    quant: QuantConfig | None = None,
) -> ShardedIndex:
    """Host-side build: split the corpus round-robin, build per-shard
    indices independently (as each host would), stack the leaves.

    With ``quant``, each shard trains its *own* codebooks on its own rows
    (embarrassingly parallel, like the rest of the build) and the stacked
    ``pq_*`` leaves carry the quantized tier.
    """
    n = vectors.shape[0]
    per = n // n_shards
    parts = []
    for s in range(n_shards):
        sl = slice(s * per, (s + 1) * per)
        idx = build_index(vectors[sl], attrs[sl], cfg)
        if quant is not None:
            idx = quantize_index(idx, quant, cfg.metric)
        parts.append(idx)
    return ShardedIndex(
        vectors=jnp.stack([p.vectors for p in parts]),
        attrs=jnp.stack([p.attrs for p in parts]),
        neighbors=jnp.stack([p.graph.neighbors for p in parts]),
        entry=jnp.stack([p.graph.entry for p in parts]),
        centroids=jnp.stack([p.centroids for p in parts]),
        medoids=jnp.stack([p.medoids for p in parts]),
        order=jnp.stack([p.cattrs.order for p in parts]),
        sorted_vals=jnp.stack([p.cattrs.sorted_vals for p in parts]),
        offsets=jnp.stack([p.cattrs.offsets for p in parts]),
        assignments=jnp.stack([p.cattrs.assignments for p in parts]),
        hist_edges=jnp.stack([p.astats.edges for p in parts]),
        hist_cluster_edges=jnp.stack([p.astats.cluster_edges for p in parts]),
        hist_cluster_counts=jnp.stack([p.astats.cluster_counts for p in parts]),
        pq_codes=(
            None if quant is None else jnp.stack([p.qvecs.codes for p in parts])
        ),
        pq_codebooks=(
            None if quant is None else jnp.stack([p.qvecs.codebooks for p in parts])
        ),
        pq_mean=(None if quant is None else jnp.stack([p.qvecs.mean for p in parts])),
        pq_train_mse=(
            None if quant is None else jnp.stack([p.qvecs.train_mse for p in parts])
        ),
    )


def make_distributed_search(mesh, pm: CompassParams):
    """Returns jitted fn(sharded_index, queries, pred) -> (ids, dists).

    ids are global record ids (shard * n_local + local).

    With ``pm.quant`` set (and a quantized sharded index), every shard runs
    the full two-stage quantized search locally — ADC candidate generation
    *and* exact rerank against its own float32 rows — so the all-gathered
    (B, k) candidates are already exact distances and the global top-k
    merge is unchanged: per-shard rerank before the merge, never after.
    """
    axes = tuple(mesh.axis_names)

    def _shard_spec(quantized: bool) -> ShardedIndex:
        # the pq_* spec leaves must mirror the *index's* pytree structure
        # (None = empty subtree), not pm.quant: an exact search over an
        # index that happens to carry codes is the documented default, and
        # pm.quant over a codeless index must die with the engine's
        # "requires a quantized index" error, not a tree mismatch
        pq = P(axes) if quantized else None
        return ShardedIndex(
            vectors=P(axes), attrs=P(axes), neighbors=P(axes), entry=P(axes),
            centroids=P(axes), medoids=P(axes), order=P(axes),
            sorted_vals=P(axes), offsets=P(axes), assignments=P(axes),
            hist_edges=P(axes), hist_cluster_edges=P(axes),
            hist_cluster_counts=P(axes),
            pq_codes=pq, pq_codebooks=pq, pq_mean=pq, pq_train_mse=pq,
        )

    def local_search(s_index: ShardedIndex, queries, lo, hi):
        index = _to_local_index(s_index)
        n_loc = index.n_records
        res = compass_search(index, queries, PR.Predicate(lo, hi), pm)
        shard_id = jnp.int32(0)
        for ax in axes:
            shard_id = shard_id * mesh.shape[ax] + jax.lax.axis_index(ax)
        gids = jnp.where(res.ids < n_loc, shard_id * n_loc + res.ids, jnp.iinfo(jnp.int32).max)
        # global merge: tiny (B, k) all-gather then top-k over union
        all_d = jax.lax.all_gather(res.dists, axes, tiled=False)  # (S, B, k)
        all_i = jax.lax.all_gather(gids, axes, tiled=False)
        S, B, K = all_d.shape
        flat_d = jnp.moveaxis(all_d, 0, 1).reshape(B, S * K)
        flat_i = jnp.moveaxis(all_i, 0, 1).reshape(B, S * K)
        neg, sel = jax.lax.top_k(-flat_d, pm.k)
        return jnp.take_along_axis(flat_i, sel, axis=1), -neg

    def _fn(quantized: bool):
        return jax.shard_map(
            local_search,
            mesh=mesh,
            in_specs=(_shard_spec(quantized), P(), P(), P()),
            out_specs=(P(), P()),
            check_vma=False,
        )

    @jax.jit
    def search(s_index: ShardedIndex, queries, pred: PR.Predicate):
        # trace-time branch on the index's own structure (like the engine's
        # qvecs handling) — each variant compiles its own executable
        return _fn(s_index.pq_codes is not None)(s_index, queries, pred.lo, pred.hi)

    return search


# ---------------------------------------------------------------------------
# Cross-shard stats aggregation
# ---------------------------------------------------------------------------

#: How each SearchStats field composes across shards.  SUM fields are work
#: counters — every shard genuinely did that work, so the cluster-wide
#: figure is the total.  MAX fields are *latency-like*: shards run
#: concurrently in a real deployment, so the batch takes as long as the
#: slowest shard's step count, and summing would overstate the critical
#: path S-fold.  FIRST fields are per-shard *decisions* (planner mode,
#: final ef, selectivity estimates) that have no meaningful cross-shard
#: reduction — each shard plans against its own attribute statistics —
#: so the aggregate reports shard 0 and the per-shard values are exposed
#: through the registry's ``shard`` label instead (see
#: :meth:`DistributedMutableIndex.search`).
STATS_SUM_FIELDS = (
    "n_dist", "n_cdist", "n_bcalls", "n_clusters_ranked",
    "n_adc", "n_rerank", "n_pass",
)
STATS_MAX_FIELDS = ("n_steps",)
STATS_FIRST_FIELDS = ("mode", "efs_final", "est_sel", "run_total")

_classified = set(STATS_SUM_FIELDS) | set(STATS_MAX_FIELDS) | set(STATS_FIRST_FIELDS)
_unclassified = set(SearchStats._fields) - _classified
assert not _unclassified, (
    "SearchStats grew fields with no distributed aggregation rule: "
    f"{sorted(_unclassified)} — classify them in core/distributed.py "
    "(STATS_SUM_FIELDS / STATS_MAX_FIELDS / STATS_FIRST_FIELDS)"
)


def aggregate_shard_stats(parts: list) -> SearchStats:
    """Fold per-shard SearchStats into one cluster-wide SearchStats.

    Field semantics are data-driven from the STATS_*_FIELDS tables above;
    the import-time assert guarantees every SearchStats field has exactly
    one rule, so adding an engine stat without deciding its distributed
    semantics fails loudly here instead of silently inheriting shard 0's
    value through ``_replace``.
    """
    first = parts[0]
    out = {}
    for f in SearchStats._fields:
        vals = [getattr(p, f) for p in parts]
        if f in STATS_SUM_FIELDS:
            out[f] = functools.reduce(lambda a, b: a + b, vals)
        elif f in STATS_MAX_FIELDS:
            out[f] = functools.reduce(jnp.maximum, vals)
        else:
            out[f] = getattr(first, f)
    return SearchStats(**out)


# ---------------------------------------------------------------------------
# Mutable sharded index: per-shard deltas + independent compaction
# ---------------------------------------------------------------------------


class DistributedMutableIndex:
    """Sharded mutable index: every shard owns a full write path.

    Each shard is a :class:`~repro.core.mutable.MutableIndex` — its own
    immutable base, tombstone bitmap and delta segment — so writes stay
    local to the owning shard and compaction runs *independently per
    shard*: one shard folding its delta never pauses the others (the
    epoch-swap argument of DESIGN.md §Mutability, shard-wise).  Routing:
    a record's owner is wherever it already lives (tracked host-side);
    brand-new ids land on ``gid % n_shards``.

    Search fans out the same query batch to every shard's base+delta
    merged search and takes a global top-k over the per-shard results —
    the same scatter-gather as ``make_distributed_search``, but over
    *global ids*, which are location-independent, so no shard-arithmetic
    id translation is needed.  Per-shard results are (B, k) arrays, so the
    merge term stays negligible exactly as in the immutable path.
    """

    def __init__(self, shards: list):
        if not shards:
            raise ValueError("need at least one shard")
        self.shards = list(shards)
        self._owner: dict[int, int] = {}
        for s, sh in enumerate(self.shards):
            # stamp each shard's obs identity: its compaction/epoch events
            # and registry series carry a shard label from here on
            sh.obs_labels = {**getattr(sh, "obs_labels", {}), "shard": str(s)}
            for g in sh.gids:
                self._owner[int(g)] = s

    @classmethod
    def build(
        cls,
        vectors: np.ndarray,
        attrs: np.ndarray,
        n_shards: int,
        cfg: BuildConfig = BuildConfig(),
        *,
        delta_cap: int = 256,
        auto_compact: bool = True,
        shape=None,
    ) -> "DistributedMutableIndex":
        """Contiguous split (like build_sharded_index) with global-position
        gids, one independently-built mutable shard per split.

        ``shape`` (a :class:`~repro.core.engine.ShapePolicy`) applies *per
        shard*: each shard buckets its own base row count and delta
        capacity independently, so one shard compacting into a new bucket
        never perturbs the compiled shapes — or cached executables — of
        the others.
        """
        from .mutable import MutableIndex

        n = vectors.shape[0]
        per = n // n_shards
        shards = []
        for s in range(n_shards):
            sl = slice(s * per, (s + 1) * per if s < n_shards - 1 else n)
            shards.append(
                MutableIndex.build(
                    vectors[sl],
                    attrs[sl],
                    cfg,
                    delta_cap=delta_cap,
                    auto_compact=auto_compact,
                    gids=np.arange(sl.start, sl.stop, dtype=np.int64),
                    shape=shape,
                )
            )
        return cls(shards)

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def epochs(self) -> tuple[int, ...]:
        return tuple(sh.epoch for sh in self.shards)

    @property
    def n_live(self) -> int:
        return sum(sh.n_live for sh in self.shards)

    def _route(self, gid: int) -> int:
        return self._owner.get(gid, gid % self.n_shards)

    def upsert(self, gids, vectors, attrs) -> None:
        gids = np.atleast_1d(np.asarray(gids, np.int64))
        vectors = np.asarray(vectors, np.float32).reshape(len(gids), -1)
        attrs = np.asarray(attrs, np.float32).reshape(len(gids), -1)
        for g, v, a in zip(gids, vectors, attrs):
            s = self._route(int(g))
            self.shards[s].upsert(g, v, a)
            self._owner[int(g)] = s

    def delete(self, gids) -> None:
        for g in np.atleast_1d(np.asarray(gids, np.int64)):
            g = int(g)
            s = self._owner.get(g)
            if s is None:
                raise KeyError(f"unknown id {g}")
            self.shards[s].delete(g)
            del self._owner[g]

    def compact(self) -> None:
        for sh in self.shards:
            sh.compact()

    def search(
        self, queries, pred: PR.Predicate, pm: CompassParams, *, explain: bool = False
    ):
        """Scatter-gather over all shards; global top-k merge on gids.

        Stats compose per :func:`aggregate_shard_stats`: work counters
        (``n_dist``/``n_cdist``/``n_bcalls``/``n_clusters_ranked``/
        ``n_adc``/``n_rerank``/``n_pass``) are SUMMED — every shard did
        that work; ``n_steps`` is the MAX — shards run concurrently, so
        the critical path is the slowest shard; and per-shard planner
        decisions (``mode``/``efs_final``/``est_sel``/``run_total``) are
        reported from shard 0, with the full per-shard breakdown flowing
        into the metrics registry under a ``shard`` label when obs is
        enabled.

        ``explain=True`` additionally returns one
        :class:`~repro.obs.trace.ShardedQueryTrace` per query — the
        aggregate view built from the merged stats (same FIRST/SUM/MAX
        semantics) plus per-shard traces stamped with each shard's id and
        epoch.  Same contract as the single-index paths: the traced
        programs are identical either way.
        """
        parts = [sh.search(queries, pred, pm) for sh in self.shards]
        all_d = jnp.concatenate([p.dists for p in parts], axis=1)
        all_g = jnp.concatenate([p.ids for p in parts], axis=1)
        neg, sel = jax.lax.top_k(-all_d, pm.k)
        stats = aggregate_shard_stats([p.stats for p in parts])
        from repro.obs import registry as obs_reg

        if obs_reg.enabled():
            for s, p in enumerate(parts):
                obs_reg.record_search_stats(p.stats, labels={"shard": str(s)})
        from .engine.state import SearchResult

        res = SearchResult(jnp.take_along_axis(all_g, sel, axis=1), -neg, stats)
        if not explain:
            return res
        from repro.obs.trace import ShardedQueryTrace, build_traces

        agg = build_traces(res, pm)
        per_shard = [
            build_traces(p, pm, epoch=self.shards[s].epoch, shard=s)
            for s, p in enumerate(parts)
        ]
        traces = [
            ShardedQueryTrace(
                aggregate=agg[i],
                shards=tuple(per_shard[s][i] for s in range(len(parts))),
            )
            for i in range(len(agg))
        ]
        return res, traces


# ---------------------------------------------------------------------------
# Abstract production-scale dry-run
# ---------------------------------------------------------------------------


def abstract_sharded_index(
    n_total: int,
    dim: int,
    n_attrs: int,
    n_shards: int,
    m: int = 32,
    nlist: int = 4096,
    hist_bins: int = 64,
    cluster_hist_bins: int = 8,
) -> ShardedIndex:
    n_loc = n_total // n_shards
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    return ShardedIndex(
        vectors=sds((n_shards, n_loc + 1, dim), f32),
        attrs=sds((n_shards, n_loc + 1, n_attrs), f32),
        neighbors=sds((n_shards, n_loc, m), i32),
        entry=sds((n_shards,), i32),
        centroids=sds((n_shards, nlist, dim), f32),
        medoids=sds((n_shards, nlist), i32),
        order=sds((n_shards, n_attrs, n_loc), i32),
        sorted_vals=sds((n_shards, n_attrs, n_loc), f32),
        offsets=sds((n_shards, nlist + 1), i32),
        assignments=sds((n_shards, n_loc), i32),
        # planner histograms (defaults mirror BuildConfig's)
        hist_edges=sds((n_shards, n_attrs, hist_bins + 1), f32),
        hist_cluster_edges=sds((n_shards, nlist, n_attrs, cluster_hist_bins + 1), f32),
        hist_cluster_counts=sds((n_shards, nlist), f32),
    )


def abstract_distributed_search(mesh, verbose: bool = True) -> dict:
    """Production-scale cell: 1.07B vectors x 128d x 4 attrs, batch 64
    filtered queries, T=4 DNF terms, over every device in the mesh."""
    import time

    from repro.roofline.analysis import collect_cell_report

    n_dev = mesh.size
    n_total = 2_097_152 * n_dev  # 2M records / device
    dim, n_attrs, T, B = 128, 4, 4, 64
    pm = CompassParams(k=10, ef=128, efi=64)
    s_index = abstract_sharded_index(n_total, dim, n_attrs, n_dev)
    queries = jax.ShapeDtypeStruct((B, dim), jnp.float32)
    pred = PR.Predicate(
        jax.ShapeDtypeStruct((B, T, n_attrs), jnp.float32),
        jax.ShapeDtypeStruct((B, T, n_attrs), jnp.float32),
    )
    fn = make_distributed_search(mesh, pm)
    t0 = time.time()
    with jax.set_mesh(mesh):
        lowered = fn.lower(s_index, queries, pred)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    meta = {
        "arch": "compass-search",
        "shape": f"corpus{n_total}_b{B}_ef{pm.ef}",
        "mesh": "pod2x16x16" if "pod" in mesh.axis_names else "16x16",
        "kind": "search",
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
    }

    class _Cfg:
        @staticmethod
        def active_param_count():
            return 0

        @staticmethod
        def param_count():
            return 0

    class _Shape:
        global_batch = B
        seq_len = 1
        kind = "search"

    rec = collect_cell_report(_Cfg, _Shape, lowered, compiled, meta)
    if verbose:
        ma = rec["memory"]
        print(
            f"OK compass-search [{meta['mesh']}] lower={meta['t_lower_s']}s "
            f"compile={meta['t_compile_s']}s bytes/dev={ma['total_bytes_per_device']/1e9:.2f}GB "
            f"dominant={rec['roofline']['dominant']}"
        )
    return rec
