"""Predicate representation and vectorized evaluation for general filtered search.

The paper (Compass, §II.A) defines a filtered query ``Q = (q, p)`` where ``p``
is an arbitrary boolean combination (conjunctions / disjunctions) of range and
equality conditions over numerical attributes.

TPU adaptation: pointer-based predicate trees do not vectorize, so predicates
are normalized to **DNF interval tensors**:

    lo, hi : (T, A) float32   -- T disjuncts, A attributes, closed intervals.

``pass(x) = OR_t AND_a (lo[t, a] <= x[a] <= hi[t, a])``

* A pure conjunction is ``T == 1``.
* A disjunction of single-attribute ranges is ``T == n_attrs`` with each row
  constraining exactly one attribute (others are [-inf, +inf]).
* Equality on a discrete attribute is the degenerate interval [v, v].

This covers every predicate class in the paper's Table I (equality,
comparison, range, conjunction, disjunction) with fully static shapes, at the
cost of potential DNF blow-up for deeply-nested mixed trees (documented in
DESIGN.md; the helper :class:`Pred` performs the tree -> DNF conversion).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = float(np.finfo(np.float32).min)
POS_INF = float(np.finfo(np.float32).max)


class Predicate(NamedTuple):
    """DNF interval predicate. Arrays of shape (T, A) (or batched (B, T, A))."""

    lo: jax.Array
    hi: jax.Array

    @property
    def n_terms(self) -> int:
        return self.lo.shape[-2]

    @property
    def n_attrs(self) -> int:
        return self.lo.shape[-1]


def always_true(n_attrs: int, n_terms: int = 1) -> Predicate:
    lo = jnp.full((n_terms, n_attrs), NEG_INF, jnp.float32)
    hi = jnp.full((n_terms, n_attrs), POS_INF, jnp.float32)
    return Predicate(lo, hi)


def never_true(n_attrs: int, n_terms: int = 1) -> Predicate:
    """All-unsatisfiable predicate: every term has lo > hi on attr 0.

    Used as the micro-batch filler by the serving layer — a filler query
    can never contribute a result, so stripping it from the batch recovers
    exactly the unpadded responses.
    """
    lo = np.full((n_terms, n_attrs), NEG_INF, np.float32)
    hi = np.full((n_terms, n_attrs), POS_INF, np.float32)
    lo[:, 0], hi[:, 0] = POS_INF, NEG_INF
    return Predicate(jnp.asarray(lo), jnp.asarray(hi))


def evaluate(pred: Predicate, attrs: jax.Array) -> jax.Array:
    """Evaluate predicate on attribute rows.

    attrs: (..., A) -> bool (...,). Broadcasts the (T, A) terms over leading
    dims of ``attrs``.
    """
    a = attrs[..., None, :]  # (..., 1, A)
    term_ok = jnp.all((a >= pred.lo) & (a <= pred.hi), axis=-1)  # (..., T)
    return jnp.any(term_ok, axis=-1)


def term_bounds(pred: Predicate, term: jax.Array, attr: jax.Array):
    """Bounds (lo, hi) for a given (term, attr) pair (dynamic indices)."""
    return pred.lo[term, attr], pred.hi[term, attr]


def chosen_attrs(pred: Predicate) -> jax.Array:
    """Per-term attribute used to drive the clustered relational scan.

    The paper picks a *random* attribute per B+-tree probe and linearly
    filters the rest (§IV.D "Limitations").  We default to the tightest
    constrained attribute per term (smallest interval width) which is the
    classic "most selective first" planning rule — a strict, cheap
    improvement the paper itself suggests.  Unconstrained attributes have
    infinite width so they are never chosen unless the term is
    unconstrained everywhere.
    """
    width = pred.hi - pred.lo  # (T, A)
    return jnp.argmin(width, axis=-1)  # (T,)


# ---------------------------------------------------------------------------
# Host-side predicate construction helpers (tree -> DNF tensors).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Pred:
    """Host-side predicate tree node.

    Build with the class methods then call :meth:`to_dnf` / :meth:`tensor`.

        p = Pred.and_(Pred.range(0, 0.2, 0.5), Pred.ge(1, 0.9))
        pred = p.tensor(n_attrs=4)
    """

    kind: str  # 'leaf' | 'and' | 'or'
    attr: int = -1
    lo: float = NEG_INF
    hi: float = POS_INF
    children: tuple = ()

    # -- constructors -------------------------------------------------------
    @staticmethod
    def range(attr: int, lo: float, hi: float) -> "Pred":
        return Pred("leaf", attr=attr, lo=float(lo), hi=float(hi))

    @staticmethod
    def eq(attr: int, value: float) -> "Pred":
        return Pred("leaf", attr=attr, lo=float(value), hi=float(value))

    @staticmethod
    def le(attr: int, value: float) -> "Pred":
        return Pred("leaf", attr=attr, lo=NEG_INF, hi=float(value))

    @staticmethod
    def ge(attr: int, value: float) -> "Pred":
        return Pred("leaf", attr=attr, lo=float(value), hi=POS_INF)

    @staticmethod
    def and_(*children: "Pred") -> "Pred":
        return Pred("and", children=tuple(children))

    @staticmethod
    def or_(*children: "Pred") -> "Pred":
        return Pred("or", children=tuple(children))

    # -- DNF conversion ------------------------------------------------------
    def to_dnf(self) -> list[dict[int, tuple[float, float]]]:
        """Returns a list of conjunctive terms: {attr: (lo, hi)}."""
        if self.kind == "leaf":
            return [{self.attr: (self.lo, self.hi)}]
        if self.kind == "and":
            terms: list[dict[int, tuple[float, float]]] = [{}]
            for child in self.children:
                child_terms = child.to_dnf()
                new_terms = []
                for t in terms:
                    for ct in child_terms:
                        merged = dict(t)
                        ok = True
                        for a, (lo, hi) in ct.items():
                            plo, phi = merged.get(a, (NEG_INF, POS_INF))
                            nlo, nhi = max(plo, lo), min(phi, hi)
                            if nlo > nhi:  # empty interval: drop term
                                ok = False
                                break
                            merged[a] = (nlo, nhi)
                        if ok:
                            new_terms.append(merged)
                terms = new_terms
            return terms
        if self.kind == "or":
            out = []
            for child in self.children:
                out.extend(child.to_dnf())
            return out
        raise ValueError(self.kind)

    def tensor(self, n_attrs: int, n_terms: int | None = None) -> Predicate:
        """Lower to (T, A) interval tensors; pads with empty terms."""
        dnf = self.to_dnf()
        if not dnf:
            dnf = [{0: (POS_INF, NEG_INF)}]  # unsatisfiable
        T = n_terms if n_terms is not None else len(dnf)
        if len(dnf) > T:
            raise ValueError(f"DNF has {len(dnf)} terms > requested {T}")
        lo = np.full((T, n_attrs), NEG_INF, np.float32)
        hi = np.full((T, n_attrs), POS_INF, np.float32)
        for t, term in enumerate(dnf):
            for a, (l, h) in term.items():
                lo[t, a] = l
                hi[t, a] = h
        # Pad rows: unsatisfiable (lo > hi on attr 0).
        for t in range(len(dnf), T):
            lo[t, 0], hi[t, 0] = POS_INF, NEG_INF
        return Predicate(jnp.asarray(lo), jnp.asarray(hi))


def _pad_terms_np(lo: np.ndarray, hi: np.ndarray, n_terms: int):
    """Pad host-side (T0, A) interval arrays to T == n_terms with
    unsatisfiable rows (lo > hi on attr 0); extra OR-terms that never fire."""
    T0, A = lo.shape
    if T0 > n_terms:
        raise ValueError(f"predicate has {T0} terms > requested {n_terms}")
    if T0 == n_terms:
        return lo, hi
    pad_lo = np.full((n_terms - T0, A), NEG_INF, np.float32)
    pad_hi = np.full((n_terms - T0, A), POS_INF, np.float32)
    pad_lo[:, 0], pad_hi[:, 0] = POS_INF, NEG_INF  # unsatisfiable pad
    return np.concatenate([lo, pad_lo], 0), np.concatenate([hi, pad_hi], 0)


def pad_terms(pred: Predicate, n_terms: int) -> Predicate:
    """Pad a (T, A) predicate to exactly ``n_terms`` disjuncts.

    The pad rows are unsatisfiable, so evaluation (``OR`` over terms) and
    the relational iterator (empty runs) are unaffected — search results
    are identical to the unpadded predicate.
    """
    lo, hi = _pad_terms_np(
        np.asarray(pred.lo, np.float32), np.asarray(pred.hi, np.float32), n_terms
    )
    return Predicate(jnp.asarray(lo), jnp.asarray(hi))


def term_bucket(n_terms: int) -> int:
    """Shape bucket for a term count: the next power of two >= n_terms.

    The serving layer normalizes arbitrary DNF widths into a logarithmic
    number of static shapes so the compiled-executable cache stays small
    under mixed conjunction/disjunction traffic.
    """
    if n_terms < 1:
        raise ValueError(f"n_terms must be >= 1, got {n_terms}")
    return 1 << (n_terms - 1).bit_length()


def stack_predicates(preds: Sequence[Predicate], n_terms: int | None = None) -> Predicate:
    """Stack per-query predicates into batched (B, T, A) tensors.

    T is ``n_terms`` when given (e.g. a serving shape bucket), else the max
    term count in the batch; narrower predicates are padded with
    unsatisfiable terms.
    """
    T = n_terms if n_terms is not None else max(p.n_terms for p in preds)
    los, his = [], []
    for p in preds:
        lo, hi = _pad_terms_np(
            np.asarray(p.lo, np.float32), np.asarray(p.hi, np.float32), T
        )
        los.append(lo)
        his.append(hi)
    return Predicate(jnp.asarray(np.stack(los)), jnp.asarray(np.stack(his)))
