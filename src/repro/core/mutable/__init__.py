"""Mutable index subsystem: delta segments, tombstones, online compaction.

Public surface:

  * :class:`MutableIndex` — upsert / delete / search / compact over a base
    :class:`~repro.core.index.CompassIndex`.
  * :func:`mutable_search` — the jitted base+delta fan-out search.
  * :class:`Snapshot` / :class:`DeltaView` — the epoch-swapped read state.
"""
from .delta import DeltaView, delta_topk, delta_topk_quantized
from .mutable_index import GID_SENTINEL, MutableIndex, Snapshot, mutable_search

__all__ = [
    "DeltaView",
    "GID_SENTINEL",
    "MutableIndex",
    "Snapshot",
    "delta_topk",
    "delta_topk_quantized",
    "mutable_search",
]
