"""Delta segment: the mutable tier of the LSM-style index (core/mutable).

Recent upserts live in a fixed-capacity segment with their own vectors and
attribute rows, padded to a static shape (``cap`` slots + one sentinel row)
so the search path stays fully jitted whatever the fill level.  Search over
the delta is a brute-force predicate-filtered scan — at delta scale
(hundreds to a few thousand rows) one fused gather+distance+predicate pass
is cheaper than maintaining any structure, and it is *exact*, so the delta
never costs recall.  The scan reuses the engine's batched
``VisitBackend.scan_scores`` surface (``kernels/filter_distance``'s (B, V)
grid on the pallas path), exactly like the planner's PREFILTER mode.

Slots are append-only between compactions: a re-upsert of a delta-resident
id invalidates the old slot rather than rewriting it, so a snapshot taken
earlier stays internally consistent (epoch swap, see mutable_index.py).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..quant.encode import QuantizedVectors


class DeltaView(NamedTuple):
    """Device-side snapshot of the delta segment (a JAX pytree).

    Mirrors just enough of :class:`~repro.core.index.CompassIndex`'s row
    layout (sentinel-padded ``vectors``/``attrs``, ``n_records``, optional
    ``qvecs``) that the engine's ``VisitBackend.scan_scores`` /
    ``scan_scores_quantized`` accept it unchanged.
    """

    vectors: jax.Array  # (cap + 1, d) — sentinel row cap is zeros
    attrs: jax.Array  # (cap + 1, A) — sentinel row is +inf (fails ranges)
    gids: jax.Array  # (cap,) int32 global record ids; -1 on empty slots
    valid: jax.Array  # (cap,) bool — occupied and not superseded/deleted
    # delta rows encoded against the *base's frozen codebooks* (attached by
    # MutableIndex.snapshot when the base carries a quantized tier), so the
    # quantized scan is one ADC pass over base+delta with shared tables
    qvecs: Optional[QuantizedVectors] = None

    @property
    def n_records(self) -> int:
        return self.vectors.shape[0] - 1

    @property
    def cap(self) -> int:
        return self.gids.shape[0]


def delta_topk(delta: DeltaView, queries, pred, k: int, metric: str, backend):
    """Exact top-k over the delta segment for a query batch.

    Returns (gids (B, k') int32 with -1 padding, dists (B, k') f32 with
    +inf padding, n_scanned () int32, n_pass (B,) int32 predicate-passing
    rows per query) where k' = min(k, cap).
    """
    b = queries.shape[0]
    cap = delta.cap
    ids = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32), (b, cap))
    mask = jnp.broadcast_to(delta.valid, (b, cap))
    dist, passing = backend.scan_scores(delta, queries, pred, ids, mask, metric)
    dist = jnp.where(passing, dist, jnp.inf)
    kk = min(k, cap)
    neg, sel = jax.lax.top_k(-dist, kk)
    top_d = -neg
    top_g = jnp.where(jnp.isfinite(top_d), jnp.take(delta.gids, sel), jnp.int32(-1))
    n_pass = jnp.sum(passing, axis=1).astype(jnp.int32)
    return top_g, top_d, jnp.sum(delta.valid).astype(jnp.int32), n_pass


def delta_topk_quantized(
    delta: DeltaView, queries, pred, k: int, metric: str, backend, quant,
    luts=None, q_resids=None,
):
    """Quantized two-stage top-k over the delta segment.

    Stage one is the same brute scan as :func:`delta_topk` but over the PQ
    codes (``VisitBackend.scan_scores_quantized`` — the pq_score kernel's
    (B, cap) grid on the pallas path, exactly like the planner's PREFILTER
    materialization), widened to ``k * refine_factor`` survivors; stage two
    re-scores those exactly per ``quant.rerank`` ("full": the float32 delta
    rows, "decode": decoded codes, "none": trust the ADC order).

    ``luts``/``q_resids`` optionally supply the per-query ADC tables —
    the delta's codebooks are the base's frozen codebooks (see
    DeltaView.qvecs), so ``mutable_search`` builds the tables once and
    shares them with the base search; built here when omitted.

    Returns (gids (B, k') int32 with -1 padding, dists (B, k') f32 with
    +inf padding, n_adc (B,) int32 stage-one table scores, n_rerank (B,)
    int32 stage-two exact distances, n_pass (B,) int32 predicate-passing
    rows per query) with k' = min(k, cap).
    """
    from ..quant import encode as Q
    from ..quant.rerank import rerank_candidates

    b = queries.shape[0]
    cap = delta.cap
    ids = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32), (b, cap))
    mask = jnp.broadcast_to(delta.valid, (b, cap))
    if luts is None:
        luts = Q.build_luts(delta.qvecs, queries, metric)
        q_resids = Q.residual_queries(delta.qvecs, queries)
    dist, passing = backend.scan_scores_quantized(
        delta, q_resids, luts, pred, ids, mask, metric
    )
    dist = jnp.where(passing, dist, jnp.inf)
    n_adc = jnp.sum(mask, axis=1).astype(jnp.int32)
    k1 = min(k * quant.refine_factor, cap)
    neg1, sel1 = jax.lax.top_k(-dist, k1)  # stage-one ADC survivors
    cand_mask = jnp.isfinite(-neg1)
    # stage two is the same rerank step the base tier runs (quant/rerank.py)
    sel2, top_d, n_rerank = rerank_candidates(
        delta, queries, pred, sel1, -neg1, cand_mask, k, metric, backend, quant.rerank
    )
    slots = jnp.take_along_axis(sel1, sel2, axis=1)
    top_g = jnp.where(jnp.isfinite(top_d), jnp.take(delta.gids, slots), jnp.int32(-1))
    n_pass = jnp.sum(passing, axis=1).astype(jnp.int32)
    return top_g, top_d, n_adc, n_rerank, n_pass
