"""Delta segment: the mutable tier of the LSM-style index (core/mutable).

Recent upserts live in a fixed-capacity segment with their own vectors and
attribute rows, padded to a static shape (``cap`` slots + one sentinel row)
so the search path stays fully jitted whatever the fill level.  Search over
the delta is a brute-force predicate-filtered scan — at delta scale
(hundreds to a few thousand rows) one fused gather+distance+predicate pass
is cheaper than maintaining any structure, and it is *exact*, so the delta
never costs recall.  The scan reuses the engine's batched
``VisitBackend.scan_scores`` surface (``kernels/filter_distance``'s (B, V)
grid on the pallas path), exactly like the planner's PREFILTER mode.

Slots are append-only between compactions: a re-upsert of a delta-resident
id invalidates the old slot rather than rewriting it, so a snapshot taken
earlier stays internally consistent (epoch swap, see mutable_index.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DeltaView(NamedTuple):
    """Device-side snapshot of the delta segment (a JAX pytree).

    Mirrors just enough of :class:`~repro.core.index.CompassIndex`'s row
    layout (sentinel-padded ``vectors``/``attrs``, ``n_records``) that the
    engine's ``VisitBackend.scan_scores`` accepts it unchanged.
    """

    vectors: jax.Array  # (cap + 1, d) — sentinel row cap is zeros
    attrs: jax.Array  # (cap + 1, A) — sentinel row is +inf (fails ranges)
    gids: jax.Array  # (cap,) int32 global record ids; -1 on empty slots
    valid: jax.Array  # (cap,) bool — occupied and not superseded/deleted

    @property
    def n_records(self) -> int:
        return self.vectors.shape[0] - 1

    @property
    def cap(self) -> int:
        return self.gids.shape[0]


def delta_topk(delta: DeltaView, queries, pred, k: int, metric: str, backend):
    """Exact top-k over the delta segment for a query batch.

    Returns (gids (B, k') int32 with -1 padding, dists (B, k') f32 with
    +inf padding, n_scanned () int32) where k' = min(k, cap).
    """
    b = queries.shape[0]
    cap = delta.cap
    ids = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32), (b, cap))
    mask = jnp.broadcast_to(delta.valid, (b, cap))
    dist, passing = backend.scan_scores(delta, queries, pred, ids, mask, metric)
    dist = jnp.where(passing, dist, jnp.inf)
    kk = min(k, cap)
    neg, sel = jax.lax.top_k(-dist, kk)
    top_d = -neg
    top_g = jnp.where(jnp.isfinite(top_d), jnp.take(delta.gids, sel), jnp.int32(-1))
    return top_g, top_d, jnp.sum(delta.valid).astype(jnp.int32)
