"""Compaction: fold the delta segment into a fresh immutable base.

This is the LSM merge step, built from *local* maintenance of each index
component rather than a from-scratch ``build_index``:

  * **rows** — tombstoned base rows drop out, delta rows append; the
    canonical row order (surviving base order, then delta slot order) keeps
    folds deterministic.
  * **clusters** — centroids are kept fixed across folds (recomputing
    k-means would invalidate every cached cluster-locality property at
    once); new rows take nearest-centroid assignments, and the medoids are
    re-derived with the segmented-argmin ``cluster_medoids`` since cluster
    membership changed.  Centroid drift under heavy churn is bounded by the
    delta size per fold; the trigger policy is documented in DESIGN.md
    §Mutability.
  * **clustered B+-trees** — per-cluster re-sorts: ``build_clustered_attrs``
    over the folded table (the maintenance operation clustered_attrs.py
    always advertised).
  * **graph** — ``remove_nodes`` drops tombstoned routing nodes and
    reindexes, ``insert_nodes`` runs HNSW-style local insertion for the
    delta rows (candidates from the nearest clusters, occlusion-pruned,
    reverse edges), and ``_repair_connectivity`` re-establishes directed
    reachability from the recomputed entry, exactly as the initial build
    does.
  * **planner stats** — ``build_attr_stats`` refresh, so PREFILTER /
    POSTFILTER selection keeps seeing the true value distribution.

The fold is pure: it returns a brand-new :class:`CompassIndex` (live mask
``None`` — nothing is tombstoned in a fresh base) plus the row->cluster
assignments; the caller (``MutableIndex.compact``) swaps it in under a new
epoch.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..clustered_attrs import build_clustered_attrs
from ..graph_build import GraphIndex, _repair_connectivity, insert_nodes, remove_nodes
from ..index import BuildConfig, CompassIndex, cluster_medoids
from ..planner.stats import build_attr_stats
from ..quant.encode import QuantizedVectors, encode_rows


def assign_to_centroids(vectors: np.ndarray, centroids: np.ndarray, metric: str = "l2") -> np.ndarray:
    """Nearest-centroid cluster assignment for a batch of new rows."""
    xy = vectors @ centroids.T  # (n, nlist)
    if metric == "l2":
        d = (centroids * centroids).sum(1)[None, :] - 2.0 * xy
    else:
        d = -xy
    return np.argmin(d, axis=1).astype(np.int32)


def fold_index(
    vectors: np.ndarray,  # (n_new, d) folded table: kept base rows + delta rows
    attrs: np.ndarray,  # (n_new, A)
    n_kept: int,  # how many leading rows come from the old base
    old_neighbors: np.ndarray,  # (n_old, M) old graph, sentinel n_old
    keep_mask: np.ndarray,  # (n_old,) bool — False = tombstoned
    old_assign: np.ndarray,  # (n_old,) old cluster assignments
    centroids: np.ndarray,  # (nlist, d) — carried over unchanged
    cfg: BuildConfig,
    qvecs: QuantizedVectors | None = None,  # old quantized tier, if any
) -> tuple[CompassIndex, np.ndarray]:
    """Fold a (keep_mask, delta rows) pair into a fresh CompassIndex.

    With ``qvecs``, the quantized tier folds too: surviving rows carry
    their uint8 codes over (codes are per-row, independent of graph or
    cluster structure), and the appended delta rows are encoded against
    the *frozen* codebooks — retraining is the caller's explicit decision
    (``MutableIndex.compact(retrain_codebooks=True)``), because new
    codebooks invalidate every cached ADC executable at once.
    """
    vectors = np.asarray(vectors, np.float32)
    attrs = np.asarray(attrs, np.float32)
    n_new, d = vectors.shape
    nlist = centroids.shape[0]
    assert n_kept == int(np.asarray(keep_mask).sum())

    # graph: drop tombstones, locally insert the delta rows, repair
    kept_graph = remove_nodes(old_neighbors, keep_mask)
    assign = np.concatenate(
        [
            np.asarray(old_assign)[np.asarray(keep_mask, bool)].astype(np.int32),
            assign_to_centroids(vectors[n_kept:], centroids, cfg.metric),
        ]
    )
    neighbors = insert_nodes(
        kept_graph,
        vectors,
        n_kept,
        assign,
        centroids,
        cfg.m,
        alpha=cfg.prune_alpha,
        metric=cfg.metric,
    )
    mean = vectors.mean(0)
    if cfg.metric == "l2":
        entry = int(np.argmin(((vectors - mean) ** 2).sum(1)))
    else:
        entry = int(np.argmax(vectors @ mean))
    neighbors = _repair_connectivity(neighbors, vectors, entry, cfg.metric)
    graph = GraphIndex(jnp.asarray(neighbors), jnp.asarray(np.int32(entry)))

    medoids = cluster_medoids(vectors, assign, centroids, entry, cfg.metric)
    cattrs = build_clustered_attrs(attrs, assign, nlist)
    astats = build_attr_stats(
        attrs, assign, nlist, n_bins=cfg.hist_bins, n_cluster_bins=cfg.cluster_hist_bins
    )
    vpad = np.concatenate([vectors, np.zeros((1, d), np.float32)], 0)
    apad = np.concatenate([attrs, np.full((1, attrs.shape[1]), np.inf, np.float32)], 0)
    new_qvecs = None
    if qvecs is not None:
        kept_codes = np.asarray(qvecs.codes)[:-1][np.asarray(keep_mask, bool)]
        new_rows = vectors[n_kept:]
        if new_rows.shape[0]:
            delta_codes = np.asarray(encode_rows(qvecs.codebooks, qvecs.mean, new_rows))
        else:
            delta_codes = np.zeros((0, qvecs.m), np.uint8)
        codes = np.concatenate(
            [kept_codes, delta_codes, np.zeros((1, qvecs.m), np.uint8)], axis=0
        )
        new_qvecs = QuantizedVectors(
            jnp.asarray(codes), qvecs.codebooks, qvecs.mean, qvecs.train_mse
        )
    index = CompassIndex(
        jnp.asarray(vpad),
        jnp.asarray(apad),
        graph,
        jnp.asarray(np.asarray(centroids, np.float32)),
        jnp.asarray(medoids),
        cattrs,
        astats,
        qvecs=new_qvecs,
    )
    return index, assign
