"""Compaction: fold the delta segment into a fresh immutable base.

This is the LSM merge step, built from *local* maintenance of each index
component rather than a from-scratch ``build_index``:

  * **rows** — tombstoned base rows drop out, delta rows append; the
    canonical row order (surviving base order, then delta slot order) keeps
    folds deterministic.
  * **clusters** — centroids are kept fixed across folds (recomputing
    k-means would invalidate every cached cluster-locality property at
    once); new rows take nearest-centroid assignments, and the medoids are
    re-derived with the segmented-argmin ``cluster_medoids`` since cluster
    membership changed.  Centroid drift under heavy churn is bounded by the
    delta size per fold; the trigger policy is documented in DESIGN.md
    §Mutability.
  * **clustered B+-trees** — per-cluster re-sorts: ``build_clustered_attrs``
    over the folded table (the maintenance operation clustered_attrs.py
    always advertised).
  * **graph** — ``remove_nodes`` drops tombstoned routing nodes and
    reindexes, ``insert_nodes`` runs HNSW-style local insertion for the
    delta rows (candidates from the nearest clusters, occlusion-pruned,
    reverse edges), and ``_repair_connectivity`` re-establishes directed
    reachability from the recomputed entry, exactly as the initial build
    does.
  * **planner stats** — ``build_attr_stats`` refresh, so PREFILTER /
    POSTFILTER selection keeps seeing the true value distribution.

The fold is pure: it returns a brand-new :class:`CompassIndex` (live mask
``None`` — nothing is tombstoned in a fresh base) plus the row->cluster
assignments; the caller (``MutableIndex.compact``) swaps it in under a new
epoch.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..clustered_attrs import ClusteredAttrs, build_clustered_attrs
from ..graph_build import GraphIndex, _repair_connectivity, insert_nodes, remove_nodes
from ..index import BuildConfig, CompassIndex, cluster_medoids
from ..planner.stats import build_attr_stats
from ..quant.encode import QuantizedVectors, encode_rows


def assign_to_centroids(vectors: np.ndarray, centroids: np.ndarray, metric: str = "l2") -> np.ndarray:
    """Nearest-centroid cluster assignment for a batch of new rows."""
    xy = vectors @ centroids.T  # (n, nlist)
    if metric == "l2":
        d = (centroids * centroids).sum(1)[None, :] - 2.0 * xy
    else:
        d = -xy
    return np.argmin(d, axis=1).astype(np.int32)


def pad_index_rows(index: CompassIndex, n_rows: int) -> CompassIndex:
    """Pad a freshly built index to ``n_rows`` total rows with dead rows.

    The bucket-fold contract (DESIGN.md §Mutability): every component is
    built over the *real* rows first — so a padded index is bitwise the
    unpadded one plus inert tail rows — and the padding can never surface
    in a search:

      * **vectors / attrs** — padding rows take the sentinel-row values
        (zero vector, ``+inf`` attrs).  ``+inf`` exceeds ``POS_INF``
        (float32 max), so a padding row fails *every* predicate term,
        including one-sided ``a <= POS_INF`` bounds — admission is closed
        even without a live mask.
      * **graph** — padding rows have no in-edges (no real row links to
        them) and sentinel-only out-rows, so traversal never reaches them;
        the sentinel edge id is remapped ``n -> n_rows`` to keep the
        "sentinel == row count" convention.
      * **clustered runs** — padding appends to the *last* cluster's tail
        with ``+inf`` sort keys; ``searchsorted`` run probes exclude them
        for any finite (or ``POS_INF``) bound, so PREFILTER never
        materializes a padding id.
      * **planner stats** — untouched: ``astats`` was built over real rows
        only, so histogram mass and ``cluster_counts`` (the selectivity
        denominator, see planner/estimate.py) count live rows only.
      * **medoids / entry / centroids** — untouched; padding rows are
        never cluster representatives or traversal seeds.

    The returned index keeps ``live=None`` — deadness is the *caller's*
    bookkeeping (``MutableIndex`` marks padding rows dead in its tombstone
    bitmap, so the engine's existing live-mask admission also excludes
    them; the graph/predicate/run properties above make them free even on
    the masked path: never visited, never scored).
    """
    n = index.n_records
    if n_rows < n:
        raise ValueError(f"n_rows={n_rows} < {n} real rows")
    if n_rows == n:
        return index
    npad = n_rows - n
    d = index.vectors.shape[1]
    A = index.attrs.shape[1]
    nlist = index.centroids.shape[0]
    vec = np.asarray(index.vectors)  # (n+1, d) — sentinel row last
    att = np.asarray(index.attrs)
    vpad = np.concatenate([vec[:n], np.zeros((npad + 1, d), np.float32)], 0)
    apad = np.concatenate(
        [att[:n], np.full((npad + 1, A), np.inf, np.float32)], 0
    )
    nb = np.asarray(index.graph.neighbors)
    nb = np.where(nb >= n, n_rows, nb)
    nb = np.concatenate(
        [nb, np.full((npad, nb.shape[1]), n_rows, nb.dtype)], 0
    ).astype(np.int32)
    graph = GraphIndex(jnp.asarray(nb), index.graph.entry)
    pad_ids = np.arange(n, n_rows, dtype=np.int32)
    order = np.concatenate(
        [np.asarray(index.cattrs.order), np.tile(pad_ids, (A, 1))], 1
    )
    svals = np.concatenate(
        [np.asarray(index.cattrs.sorted_vals), np.full((A, npad), np.inf, np.float32)], 1
    )
    offsets = np.asarray(index.cattrs.offsets).copy()
    offsets[-1] += npad
    assign = np.concatenate(
        [
            np.asarray(index.cattrs.assignments),
            np.full((npad,), nlist - 1, np.int32),
        ]
    )
    cattrs = ClusteredAttrs(
        jnp.asarray(order), jnp.asarray(svals), jnp.asarray(offsets), jnp.asarray(assign)
    )
    qv = index.qvecs
    if qv is not None:
        codes = np.asarray(qv.codes)  # (n+1, m) — sentinel row last
        codes = np.concatenate(
            [codes[:n], np.zeros((npad + 1, qv.m), np.uint8)], 0
        )
        qv = QuantizedVectors(jnp.asarray(codes), qv.codebooks, qv.mean, qv.train_mse)
    return index._replace(
        vectors=jnp.asarray(vpad),
        attrs=jnp.asarray(apad),
        graph=graph,
        cattrs=cattrs,
        qvecs=qv,
    )


def fold_index(
    vectors: np.ndarray,  # (n_new, d) folded table: kept base rows + delta rows
    attrs: np.ndarray,  # (n_new, A)
    n_kept: int,  # how many leading rows come from the old base
    old_neighbors: np.ndarray,  # (n_old, M) old graph, sentinel n_old
    keep_mask: np.ndarray,  # (n_old,) bool — False = tombstoned
    old_assign: np.ndarray,  # (n_old,) old cluster assignments
    centroids: np.ndarray,  # (nlist, d) — carried over unchanged
    cfg: BuildConfig,
    qvecs: QuantizedVectors | None = None,  # old quantized tier, if any
    n_rows: int | None = None,  # pad the fold to this many total rows
) -> tuple[CompassIndex, np.ndarray]:
    """Fold a (keep_mask, delta rows) pair into a fresh CompassIndex.

    With ``qvecs``, the quantized tier folds too: surviving rows carry
    their uint8 codes over (codes are per-row, independent of graph or
    cluster structure), and the appended delta rows are encoded against
    the *frozen* codebooks — retraining is the caller's explicit decision
    (``MutableIndex.compact(retrain_codebooks=True)``), because new
    codebooks invalidate every cached ADC executable at once.

    ``n_rows`` pads the fold to a fixed total row count with dead rows
    (``pad_index_rows``) — the shape-bucketing half of the contract: the
    caller picks the bucket (``ShapePolicy.row_bucket``), the fold builds
    every component over the real rows first and pads after, so a bucketed
    fold is bitwise the unbucketed fold plus inert tail rows.  The
    returned assignments cover the padded rows too (last cluster).
    """
    vectors = np.asarray(vectors, np.float32)
    attrs = np.asarray(attrs, np.float32)
    n_new, d = vectors.shape
    nlist = centroids.shape[0]
    assert n_kept == int(np.asarray(keep_mask).sum())

    # graph: drop tombstones, locally insert the delta rows, repair
    kept_graph = remove_nodes(old_neighbors, keep_mask)
    assign = np.concatenate(
        [
            np.asarray(old_assign)[np.asarray(keep_mask, bool)].astype(np.int32),
            assign_to_centroids(vectors[n_kept:], centroids, cfg.metric),
        ]
    )
    neighbors = insert_nodes(
        kept_graph,
        vectors,
        n_kept,
        assign,
        centroids,
        cfg.m,
        alpha=cfg.prune_alpha,
        metric=cfg.metric,
    )
    mean = vectors.mean(0)
    if cfg.metric == "l2":
        entry = int(np.argmin(((vectors - mean) ** 2).sum(1)))
    else:
        entry = int(np.argmax(vectors @ mean))
    neighbors = _repair_connectivity(neighbors, vectors, entry, cfg.metric)
    graph = GraphIndex(jnp.asarray(neighbors), jnp.asarray(np.int32(entry)))

    medoids = cluster_medoids(vectors, assign, centroids, entry, cfg.metric)
    cattrs = build_clustered_attrs(attrs, assign, nlist)
    astats = build_attr_stats(
        attrs, assign, nlist, n_bins=cfg.hist_bins, n_cluster_bins=cfg.cluster_hist_bins
    )
    vpad = np.concatenate([vectors, np.zeros((1, d), np.float32)], 0)
    apad = np.concatenate([attrs, np.full((1, attrs.shape[1]), np.inf, np.float32)], 0)
    new_qvecs = None
    if qvecs is not None:
        kept_codes = np.asarray(qvecs.codes)[:-1][np.asarray(keep_mask, bool)]
        new_rows = vectors[n_kept:]
        if new_rows.shape[0]:
            delta_codes = np.asarray(encode_rows(qvecs.codebooks, qvecs.mean, new_rows))
        else:
            delta_codes = np.zeros((0, qvecs.m), np.uint8)
        codes = np.concatenate(
            [kept_codes, delta_codes, np.zeros((1, qvecs.m), np.uint8)], axis=0
        )
        new_qvecs = QuantizedVectors(
            jnp.asarray(codes), qvecs.codebooks, qvecs.mean, qvecs.train_mse
        )
    index = CompassIndex(
        jnp.asarray(vpad),
        jnp.asarray(apad),
        graph,
        jnp.asarray(np.asarray(centroids, np.float32)),
        jnp.asarray(medoids),
        cattrs,
        astats,
        qvecs=new_qvecs,
    )
    if n_rows is not None and n_rows != n_new:
        index = pad_index_rows(index, n_rows)
        assign = np.asarray(index.cattrs.assignments)
    return index, assign
