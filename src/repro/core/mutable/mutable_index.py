"""MutableIndex — the LSM-style write path over the immutable CompassIndex.

Layout (DESIGN.md §Mutability):

  * **base** — an ordinary :class:`CompassIndex` (graph + IVF + clustered
    runs + planner stats), immutable between compactions.
  * **tombstones** — a host bitmap over base rows; deleted or superseded
    rows keep *routing* (graph traversal and B+-tree runs still flow
    through them) but the engine masks them out of the result queue and
    the PREFILTER adoption (``CompassIndex.live``).
  * **delta segment** — a fixed-capacity append-only buffer of recent
    upserts with its own vectors/attrs, searched by an exact brute scan
    (delta.py).  Overflow triggers compaction (compact.py).

Search fans out over {base (tombstone-masked), delta (predicate-filtered
scan)} and merges top-k by distance; both tiers are searched under the same
``CompassParams``, so planner modes, backends and metrics all apply.

**Epoch-swapped snapshots, not locks**: every mutation invalidates a cached
:class:`Snapshot`; readers grab the current snapshot object (a plain Python
reference — atomic under the GIL) and run entirely against it.  Compaction
builds the *next* base off to the side and publishes it by swapping the
snapshot reference and bumping ``epoch``; an in-flight search keeps its
old-epoch arrays alive for free (JAX buffers are immutable), which is the
whole point of choosing epochs over a reader–writer lock: zero reader
coordination on the hot path, and a serving batch can pin one epoch for its
entire lifetime (serving/search_service.py).

Ids: callers address records by *global id* (``gid``), stable across
compactions; search results report gids (-1 for empty slots), unlike the
positional ids of raw ``compass_search``.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import events as obs_events
from repro.obs import registry as obs_registry

from .. import predicate as P
from ..engine.backend import resolve_backend
from ..engine.driver import ShapePolicy
from ..engine.state import SearchResult
from ..index import BuildConfig, CompassIndex, build_index
from ..quant.encode import (
    QuantizedVectors,
    build_luts,
    encode_rows,
    quant_mse,
    quantize_vectors,
    residual_queries,
)
from ..quant.params import QuantConfig
from .compact import fold_index, pad_index_rows
from .delta import DeltaView, delta_topk, delta_topk_quantized

GID_SENTINEL = -1  # empty result slot / empty delta slot


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """One immutable epoch of the mutable index (what a search runs on)."""

    index: CompassIndex  # base with .live tombstone mask attached
    base_gids: jax.Array  # (N + 1,) int32; sentinel row -> -1
    delta: DeltaView
    epoch: int


@functools.partial(jax.jit, static_argnames=("pm",))
def mutable_search(
    index: CompassIndex, base_gids, delta: DeltaView, queries, pred: P.Predicate, pm
) -> SearchResult:
    """Fan-out search: base (tombstone-masked) + delta (brute scan), merged.

    Returns a :class:`SearchResult` whose ids are *global ids* (-1 padding).
    Stats are the base engine stats with the delta's scanned rows folded
    into ``n_dist`` — or, when ``pm.quant`` is active and the snapshot
    carries delta codes, into ``n_adc``/``n_rerank``: the delta then runs
    the same two-stage ADC-scan-then-exact-rerank as the base
    (delta.delta_topk_quantized), so both tiers obey one scoring contract.
    """
    from ..engine import compass_search  # local: avoids import-order cycles

    pmr = pm.resolved()
    backend = resolve_backend(pmr.backend)
    quant_delta = pm.quant is not None and delta.qvecs is not None
    if quant_delta:
        # one ADC table build per query for the whole fan-out: the delta's
        # codebooks ARE the base's frozen codebooks (snapshot), so the same
        # (B, m, ks) tables score both tiers
        luts = build_luts(delta.qvecs, queries, pmr.metric)
        q_resids = residual_queries(delta.qvecs, queries)
    else:
        luts = q_resids = None
    base = compass_search(index, queries, pred, pm, luts, q_resids)
    bg = jnp.take(base_gids, jnp.clip(base.ids, 0, index.n_records), axis=0)
    bg = jnp.where(jnp.isfinite(base.dists), bg, jnp.int32(GID_SENTINEL))
    if quant_delta:
        dg, dd, n_adc, n_rr, n_pass = delta_topk_quantized(
            delta, queries, pred, pmr.k, pmr.metric, backend, pm.quant,
            luts, q_resids,
        )
        stats = base.stats._replace(
            n_adc=base.stats.n_adc + n_adc,
            n_rerank=base.stats.n_rerank + n_rr,
            n_pass=base.stats.n_pass + n_pass,
        )
        if pm.quant.rerank == "full":  # stage two read float32 delta rows
            stats = stats._replace(n_dist=stats.n_dist + n_rr)
    else:
        dg, dd, n_scanned, n_pass = delta_topk(
            delta, queries, pred, pmr.k, pmr.metric, backend
        )
        stats = base.stats._replace(
            n_dist=base.stats.n_dist + n_scanned,
            n_pass=base.stats.n_pass + n_pass,
        )
    all_d = jnp.concatenate([base.dists, dd], axis=1)
    all_g = jnp.concatenate([bg, dg], axis=1)
    neg, sel = jax.lax.top_k(-all_d, pmr.k)
    return SearchResult(jnp.take_along_axis(all_g, sel, axis=1), -neg, stats)


class MutableIndex:
    """Mutable filtered-search index: upsert / delete / search / compact.

    Host-side writes are cheap dictionary-and-array mutations; the device
    snapshot is rebuilt lazily on the next search (write bursts amortize to
    one transfer).  All reads go through :meth:`snapshot`.
    """

    def __init__(
        self,
        base: CompassIndex,
        *,
        delta_cap: int = 256,
        auto_compact: bool = True,
        cfg: BuildConfig | None = None,
        metric: str = "l2",
        gids: np.ndarray | None = None,
        quant_cfg: QuantConfig | None = None,
        shape: ShapePolicy | None = None,
    ):
        if base.astats is None:
            raise ValueError("MutableIndex requires an index built by build_index (astats)")
        if metric == "cos" or (cfg is not None and cfg.metric == "cos"):
            # the delta scan and LUT builds run outside compass_search's
            # cos->ip rewrite; supporting cos here would need a second
            # rewrite point on the write path.  build_index(metric="cos")
            # already stores unit rows, so wrap that index with "ip" and
            # normalize upserted rows/queries upstream.
            raise ValueError(
                "MutableIndex does not support metric='cos'; normalize rows "
                "upstream and use metric='ip' (an index built with "
                "BuildConfig(metric='cos') is already unit-normalized)"
            )
        # CompassIndex does not record its build metric, so a non-l2 index
        # wrapped without an explicit ``cfg`` must pass ``metric`` here or
        # compaction would fold with l2 geometry.
        self._cfg = cfg or BuildConfig(
            m=base.graph.degree,
            nlist=base.nlist,
            metric=metric,
            hist_bins=base.astats.edges.shape[1] - 1,
            cluster_hist_bins=base.astats.cluster_edges.shape[2] - 1,
        )
        # the quantized tier's *training* config, used only by
        # compact(retrain_codebooks=True): QuantizedVectors carries no
        # training hyperparameters (it is a pure-array pytree), so without
        # this the retrain would fall back to shape inference and silently
        # drop a non-default iters/seed choice
        self._quant_cfg = quant_cfg
        # the compiled-shape policy (DESIGN.md §Mutability, bucket-fold
        # contract): row buckets for every base the index ever serves —
        # the wrapped one included, so epoch 0 shares the bucket's
        # executable with every post-compaction epoch — plus the delta
        # capacity (shape.delta_cap wins over the legacy argument)
        self.shape = shape if shape is not None else ShapePolicy()
        self.delta_cap = self.shape.resolve_delta_cap(delta_cap)
        self.auto_compact = bool(auto_compact)
        self.compaction_log: list[float] = []  # fold wall-clock seconds
        # quantized-tier drift: decode MSE of the folded table against the
        # frozen codebooks, appended at every compaction (compare against
        # base.qvecs.train_mse to decide when to retrain — DESIGN.md
        # §Quantization on codebook staleness)
        self.quant_drift_log: list[float] = []
        # registry labels this index's metrics/events carry (e.g.
        # DistributedMutableIndex sets {"shard": "3"} per shard so the
        # per-shard breakdowns are separable series, not pre-summed)
        self.obs_labels: dict[str, str] = {}
        self._epoch = 0
        self._snap: Snapshot | None = None
        n_real = base.n_records
        if self.shape.bucket_rows:
            base = pad_index_rows(
                base._replace(live=None), self.shape.row_bucket(n_real)
            )
        self._install_base(base, gids, n_real=n_real)
        self._reset_delta()

    # -- wiring ------------------------------------------------------------

    def _install_base(
        self, base: CompassIndex, gids: np.ndarray | None, n_real: int | None = None
    ) -> None:
        n = base.n_records
        if n_real is None:
            n_real = n
        if gids is None:
            gids = np.arange(n_real, dtype=np.int64)
        gids = np.asarray(gids, np.int64)
        if gids.shape != (n_real,):
            raise ValueError(f"gids shape {gids.shape} != ({n_real},)")
        self._base = base._replace(live=None)
        self._base_gids_dev = None  # per-epoch device cache (see snapshot)
        # host mirrors consumed by compaction
        self._vectors = np.asarray(base.vectors)[:n]
        self._attrs = np.asarray(base.attrs)[:n]
        self._assign = np.asarray(base.cattrs.assignments)
        self._centroids = np.asarray(base.centroids)
        # rows [n_real, n) are the bucket's dead padding (pad_index_rows):
        # never addressable (sentinel gid), born tombstoned so the engine's
        # live mask excludes them on top of the structural guarantees
        self._n_base_real = n_real
        if n_real < n:
            gids = np.concatenate(
                [gids, np.full((n - n_real,), GID_SENTINEL, np.int64)]
            )
        self._gids = gids
        self._gid2base = {int(g): p for p, g in enumerate(gids[:n_real])}
        self._live = np.ones((n + 1,), bool)
        self._live[n_real:n] = False

    def _reset_delta(self) -> None:
        cap = self.delta_cap
        self._dvec = np.zeros((cap, self.dim), np.float32)
        self._dattr = np.full((cap, self.n_attrs), np.inf, np.float32)
        self._dgid = np.full((cap,), GID_SENTINEL, np.int64)
        self._dvalid = np.zeros((cap,), bool)
        self._dcount = 0
        self._gid2slot: dict[int, int] = {}

    @classmethod
    def build(
        cls,
        vectors: np.ndarray,
        attrs: np.ndarray,
        cfg: BuildConfig = BuildConfig(),
        *,
        delta_cap: int = 256,
        auto_compact: bool = True,
        gids: np.ndarray | None = None,
        shape: ShapePolicy | None = None,
    ) -> "MutableIndex":
        return cls(
            build_index(vectors, attrs, cfg),
            delta_cap=delta_cap,
            auto_compact=auto_compact,
            cfg=cfg,
            gids=gids,
            shape=shape,
        )

    # -- introspection -----------------------------------------------------

    @property
    def base(self) -> CompassIndex:
        return self._base

    @property
    def dim(self) -> int:
        return self._base.dim

    @property
    def n_attrs(self) -> int:
        return self._base.n_attrs

    @property
    def epoch(self) -> int:
        return self._epoch

    @property
    def gids(self) -> np.ndarray:
        """Global ids of the current base rows (positional order; bucket
        padding rows, which carry no gid, are excluded)."""
        return self._gids[: self._n_base_real]

    @property
    def delta_fill(self) -> int:
        return self._dcount

    @property
    def n_live(self) -> int:
        """Live record count across both tiers."""
        return int(self._live[:-1].sum()) + int(self._dvalid.sum())

    def __contains__(self, gid: int) -> bool:
        gid = int(gid)
        if gid in self._gid2slot:
            return True
        pos = self._gid2base.get(gid)
        return pos is not None and bool(self._live[pos])

    # -- writes ------------------------------------------------------------

    def upsert(self, gids, vectors, attrs) -> None:
        """Insert or replace records by global id (scalar or batched)."""
        gids = np.atleast_1d(np.asarray(gids, np.int64))
        vectors = np.asarray(vectors, np.float32).reshape(len(gids), self.dim)
        attrs = np.asarray(attrs, np.float32).reshape(len(gids), self.n_attrs)
        if gids.size and (gids.min() < 0 or gids.max() >= np.iinfo(np.int32).max):
            raise ValueError("gids must fit in non-negative int32")
        for g, v, a in zip(gids, vectors, attrs):
            g = int(g)
            if self._dcount >= self.delta_cap:
                if not self.auto_compact:
                    raise RuntimeError(
                        f"delta segment full ({self.delta_cap}); call compact()"
                    )
                obs_events.emit(
                    "delta_overflow",
                    delta_cap=self.delta_cap,
                    epoch=self._epoch,
                    **self.obs_labels,
                )
                self.compact()
            old_slot = self._gid2slot.pop(g, None)
            if old_slot is not None:  # superseded within the delta
                self._dvalid[old_slot] = False
            pos = self._gid2base.get(g)
            if pos is not None:  # superseded base version becomes a tombstone
                self._live[pos] = False
            slot = self._dcount
            self._dvec[slot] = v
            self._dattr[slot] = a
            self._dgid[slot] = g
            self._dvalid[slot] = True
            self._gid2slot[g] = slot
            self._dcount += 1
        self._snap = None
        self._record_debt()

    def delete(self, gids) -> None:
        """Delete records by global id; KeyError on unknown/already-deleted."""
        for g in np.atleast_1d(np.asarray(gids, np.int64)):
            g = int(g)
            slot = self._gid2slot.pop(g, None)
            if slot is not None:
                self._dvalid[slot] = False
                continue
            pos = self._gid2base.get(g)
            if pos is None or not self._live[pos]:
                raise KeyError(f"unknown or already-deleted id {g}")
            self._live[pos] = False
        self._snap = None
        self._record_debt()

    def _record_debt(self) -> None:
        """Compaction-debt gauges for the health watchdogs (obs/health.py):
        delta occupancy vs capacity and the tombstone fraction of real base
        rows.  Canonical ``("shard",)`` labels — ``""`` for a standalone
        index — so standalone and sharded indices fold into one series
        family regardless of ``obs_labels``.  Host-side dict writes; no-op
        when observability is off."""
        if not obs_registry.enabled():
            return
        r = obs_registry.registry()
        lab = {"shard": str(self.obs_labels.get("shard", ""))}
        lnames = ("shard",)
        r.gauge(
            "compass_delta_fill", "occupied delta-segment slots", lnames
        ).set(self._dcount, **lab)
        r.gauge(
            "compass_delta_cap", "delta-segment capacity", lnames
        ).set(self.delta_cap, **lab)
        live = self._live[: self._n_base_real]
        r.gauge(
            "compass_tombstone_fraction",
            "dead fraction of real (non-padding) base rows",
            lnames,
        ).set(1.0 - float(live.sum()) / max(1, live.size), **lab)

    # -- reads -------------------------------------------------------------

    def snapshot(self) -> Snapshot:
        """Current epoch's immutable device snapshot (cached until dirty)."""
        if self._snap is None:
            index = self._base._replace(live=jnp.asarray(self._live))
            if self._base_gids_dev is None:  # constant within an epoch
                self._base_gids_dev = jnp.asarray(
                    np.concatenate([self._gids, [GID_SENTINEL]]).astype(np.int32)
                )
            base_gids = self._base_gids_dev
            dqv = None
            if self._base.qvecs is not None:
                # encode the delta buffer against the base's frozen
                # codebooks so the quantized scan covers both tiers; cap is
                # small and the snapshot is cached until the next write, so
                # this stays off the search hot path
                bq = self._base.qvecs
                dcodes = np.asarray(encode_rows(bq.codebooks, bq.mean, self._dvec))
                dcodes = np.concatenate(
                    [dcodes, np.zeros((1, bq.m), np.uint8)], axis=0
                )
                dqv = QuantizedVectors(
                    jnp.asarray(dcodes), bq.codebooks, bq.mean, bq.train_mse
                )
            delta = DeltaView(
                jnp.asarray(
                    np.concatenate([self._dvec, np.zeros((1, self.dim), np.float32)], 0)
                ),
                jnp.asarray(
                    np.concatenate(
                        [self._dattr, np.full((1, self.n_attrs), np.inf, np.float32)], 0
                    )
                ),
                jnp.asarray(self._dgid.astype(np.int32)),
                jnp.asarray(self._dvalid),
                qvecs=dqv,
            )
            self._snap = Snapshot(index, base_gids, delta, self._epoch)
        return self._snap

    def search(self, queries, pred: P.Predicate, pm, *, explain: bool = False):
        """Batched filtered search over base+delta; ids are global ids.

        ``explain=True`` additionally returns per-query
        :class:`~repro.obs.trace.QueryTrace` records (stamped with this
        snapshot's epoch) — same contract as ``compass_search``: the
        traced program is identical either way.
        """
        snap = self.snapshot()
        res = mutable_search(
            snap.index, snap.base_gids, snap.delta, jnp.asarray(queries), pred, pm
        )
        if not explain:
            return res
        from repro.obs.trace import build_traces  # lazy: obs sits above core

        return res, build_traces(res, pm, epoch=snap.epoch)

    def materialize(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The equivalent immutable table: (vectors, attrs, gids) in
        canonical order — surviving base rows first, delta rows after."""
        keep = self._live[:-1]
        dsel = self._dvalid
        vec = np.concatenate([self._vectors[keep], self._dvec[dsel]], 0)
        attr = np.concatenate([self._attrs[keep], self._dattr[dsel]], 0)
        gids = np.concatenate([self._gids[keep], self._dgid[dsel]], 0)
        return vec, attr, gids

    # -- maintenance -------------------------------------------------------

    def compact(self, retrain_codebooks: bool = False) -> None:
        """Fold the delta into a fresh base and swap epochs.

        Local maintenance, not a rebuild: tombstoned rows leave the graph
        (``remove_nodes``), delta rows are locally inserted
        (``insert_nodes``), clustered runs are re-sorted, medoids and
        planner stats refreshed (compact.py).  The swap is the last step,
        so concurrent readers keep their old snapshot untouched.

        When the base carries a quantized tier, the fold *re-encodes* the
        delta rows against the frozen codebooks (kept rows carry their
        codes over) and records the folded table's decode MSE in
        ``quant_drift_log`` — the staleness signal.  Codebooks are only
        retrained on an explicit ``compact(retrain_codebooks=True)``
        (auto-compaction never retrains: retraining changes every ADC table
        and thus every cached executable, so it must be an operator
        decision, not an overflow side effect).
        """
        t0 = time.perf_counter()
        keep = self._live[:-1]
        vec, attr, gids = self.materialize()
        # bucket the fold (ShapePolicy.row_bucket is the identity when
        # bucketing is off): churn that stays within a bucket keeps
        # n_records — and therefore every compiled program — fixed across
        # the epoch swap; the old bucket's padding rows are tombstoned
        # (keep=False) and drop out of the fold like any dead row
        index, assign = fold_index(
            vec,
            attr,
            int(keep.sum()),
            np.asarray(self._base.graph.neighbors),
            keep,
            self._assign,
            self._centroids,
            self._cfg,
            qvecs=self._base.qvecs,
            n_rows=self.shape.row_bucket(vec.shape[0]),
        )
        if index.qvecs is not None:
            if retrain_codebooks:
                # prefer the explicit training config (construction-time
                # ``quant_cfg``); shape inference recovers only the
                # *effective* trained shapes — NOT iters/seed, and a ks
                # that train_codebooks clipped to a small original corpus
                # stays clipped forever even after the corpus grows — so a
                # non-default configuration must be passed in to survive
                cfg = self._quant_cfg or QuantConfig(
                    m=index.qvecs.m,
                    ks=index.qvecs.ks,
                    residual=bool(np.any(np.asarray(index.qvecs.mean))),
                )
                qv = quantize_vectors(vec, cfg, self._cfg.metric)
                if index.n_records != vec.shape[0]:
                    # re-pad the retrained codes to the row bucket (the
                    # retrain sees real rows only — padding must not train)
                    npad = index.n_records - vec.shape[0]
                    codes = np.asarray(qv.codes)
                    codes = np.concatenate(
                        [codes[:-1], np.zeros((npad + 1, qv.m), np.uint8)], 0
                    )
                    qv = QuantizedVectors(
                        jnp.asarray(codes), qv.codebooks, qv.mean, qv.train_mse
                    )
                index = index._replace(qvecs=qv)
            self.quant_drift_log.append(quant_mse(index.qvecs, vec))
        # publish: install the new epoch, then reset the write tiers
        self._install_base(index, gids, n_real=vec.shape[0])
        self._assign = assign
        self._reset_delta()
        self._epoch += 1
        self._snap = None
        wall = time.perf_counter() - t0
        self.compaction_log.append(wall)
        lab = self.obs_labels
        obs_events.emit(
            "compaction",
            epoch=self._epoch,
            wall_s=wall,
            n_rows=vec.shape[0],
            row_bucket=index.n_records,
            retrained=bool(retrain_codebooks and index.qvecs is not None),
            quant_drift_mse=self.quant_drift_log[-1] if index.qvecs is not None else None,
            **lab,
        )
        obs_events.emit("epoch_swap", epoch=self._epoch, **lab)
        if retrain_codebooks and index.qvecs is not None:
            obs_events.emit("codebook_retrain", epoch=self._epoch, **lab)
        if obs_registry.enabled():
            r = obs_registry.registry()
            lnames = tuple(sorted(lab))
            r.counter(
                "compass_compactions_total", "delta folds completed", lnames
            ).inc(1, **lab)
            r.histogram(
                "compass_compaction_seconds", "compaction fold wall time", lnames
            ).observe(wall, **lab)
            r.gauge("compass_epoch", "current snapshot epoch", lnames).set(
                self._epoch, **lab
            )
            if retrain_codebooks and index.qvecs is not None:
                r.counter(
                    "compass_codebook_retrains_total", "explicit codebook retrains",
                    lnames,
                ).inc(1, **lab)
            if index.qvecs is not None:
                r.gauge(
                    "compass_quant_drift_mse",
                    "decode MSE of the folded table vs frozen codebooks",
                    lnames,
                ).set(self.quant_drift_log[-1], **lab)
                # same labelnames as the drift gauge so the quant-staleness
                # watchdog (obs/health.py) can pair the two series by key
                r.gauge(
                    "compass_quant_train_mse",
                    "decode MSE baseline at codebook training time",
                    lnames,
                ).set(float(index.qvecs.train_mse), **lab)
        self._record_debt()
