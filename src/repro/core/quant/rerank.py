"""Stage two of quantized search: exact rerank of the ADC survivors.

Stage one runs the ordinary engine loop with ADC scoring at a widened
``ef * refine_factor`` result queue; this module re-scores those survivors
and keeps the top ``k``.  Three scorers (``QuantParams.rerank``):

  * ``"full"``   — fused gather+distance+predicate over the full-precision
    rows (``VisitBackend.scan_scores``, i.e. the ``filter_distance`` kernel
    on the pallas path): the default, and what makes quantized top-k match
    exact search once ``refine_factor`` covers the ADC ordering error.
  * ``"decode"`` — distances against decoded codes, for indices that
    dropped the float32 table.  The l2 ADC table already sums to the exact
    decoded distance, so this only canonicalizes summation order — recall
    is bounded by quantization error, which is the honest trade.
  * ``"none"``   — trust ADC ordering, truncate to ``k``.

The stable-id / padding contract is preserved: empty slots keep ``+inf``
distance and the sentinel id ``n_records``, exactly as in exact search.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .encode import decode


def decode_distances(qv, queries, ids, mask, metric: str) -> jax.Array:
    """(B, E) distances between queries and decoded candidate rows."""
    vecs = decode(qv, jnp.clip(ids, 0, qv.n_records))  # (B, E, d)
    if metric == "l2":
        diff = vecs - queries[:, None, :]
        dist = jnp.sum(diff * diff, axis=-1)
    else:
        dist = -jnp.einsum("bed,bd->be", vecs, queries)
    return jnp.where(mask, dist, jnp.inf)


def rerank_candidates(view, queries, pred, ids, dists1, mask, k, metric, backend, mode):
    """The shared stage-two step: re-score survivors, take the top ``k``.

    ``view`` is any index-like pytree the backend scan surfaces accept
    (``CompassIndex`` or the mutable tier's ``DeltaView`` — both carry
    sentinel-padded ``vectors``/``attrs`` and ``qvecs``); ``ids``/
    ``dists1``/``mask`` are the (B, E) stage-one survivors in ADC order.
    Returns ``(sel (B, k') int32 positions into E, dists (B, k') f32 with
    +inf padding, n_rerank (B,) int32 exact distances computed)``,
    k' = min(k, E).  Used by both :func:`rerank_batch` (base tier) and
    ``mutable.delta.delta_topk_quantized`` so the two tiers cannot drift.
    """
    kk = min(k, ids.shape[1])
    if mode == "none":
        # trust ADC order: top-k over the stage-one distances (already
        # sorted for the base result queue; cheap either way), zero exact
        # distances computed
        ex_d = jnp.where(mask, dists1, jnp.inf)
        n_rerank = jnp.zeros((ids.shape[0],), jnp.int32)
    elif mode == "full":
        ex_d, passing = backend.scan_scores(view, queries, pred, ids, mask, metric)
        ex_d = jnp.where(passing, ex_d, jnp.inf)
        n_rerank = jnp.sum(mask, axis=1).astype(jnp.int32)
    else:  # "decode"
        ex_d = decode_distances(view.qvecs, queries, ids, mask, metric)
        n_rerank = jnp.sum(mask, axis=1).astype(jnp.int32)
    neg, sel = jax.lax.top_k(-ex_d, kk)
    return sel, -neg, n_rerank


def rerank_batch(index, queries, pred, res, k: int, metric: str, backend, mode: str):
    """Exact rerank of a stage-one SearchResult -> top-``k`` SearchResult.

    ``res.ids``/``res.dists`` are the (B, E) ADC-ordered survivors
    (E == stage-one ef).  Returns the same NamedTuple type with stats
    updated: ``n_rerank`` counts stage-two distance evaluations, and
    ``n_dist`` additionally counts them when they read full-precision rows
    (mode ``"full"``) — ``n_dist`` stays the full-precision #Comp figure.
    """
    n = index.n_records
    ids, dists = res.ids, res.dists
    mask = jnp.isfinite(dists)  # (B, E) live result-queue entries
    sel, out_d, n_rerank = rerank_candidates(
        index, queries, pred, ids, dists, mask, k, metric, backend, mode
    )
    out_i = jnp.where(
        jnp.isfinite(out_d), jnp.take_along_axis(ids, sel, axis=1), jnp.int32(n)
    )
    stats = res.stats._replace(n_rerank=res.stats.n_rerank + n_rerank)
    if mode == "full":
        stats = stats._replace(n_dist=stats.n_dist + n_rerank)
    return res._replace(ids=out_i, dists=out_d, stats=stats)
