"""Configuration records for the product-quantization tier (core/quant).

Two configs, two lifetimes:

  * :class:`QuantConfig` is *build-time*: how codebooks are trained and
    rows encoded.  It is consumed by ``train_codebooks`` /
    ``quantize_vectors`` and then forgotten — everything search needs is
    carried by the :class:`~repro.core.quant.encode.QuantizedVectors`
    arrays themselves, so an index file does not depend on this object.
  * :class:`QuantParams` is *search-time*: how the two-stage
    ADC-then-rerank search behaves.  It hangs off
    ``CompassParams.quant`` (default ``None`` == quantization off), so it
    must stay a frozen, hashable dataclass — ``CompassParams`` is a
    static jit argument and a compiled-executable cache key.

Kept dependency-free (no jax import) so the engine can import it without
pulling the quantization subsystem onto the exact-search path.
"""
from __future__ import annotations

import dataclasses

#: rerank modes for QuantParams.rerank
RERANK_MODES = ("full", "decode", "none")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Codebook training / encoding configuration.

    ``m`` subspaces of ``ceil(d/m)`` dims each (vectors are zero-padded to
    a multiple of ``m``), ``ks`` centroids per subspace (<= 256 so codes
    fit uint8).  ``residual`` selects encoding the rows' offsets from the
    corpus mean instead of the raw rows; ``None`` picks per metric — the
    classic choice: centered residuals for l2 (quantization error drops
    when the corpus is off-origin), raw rows for inner product (a mean
    offset would need a per-query bias term in every ADC table).
    """

    m: int = 8
    ks: int = 256
    iters: int = 10
    seed: int = 0
    residual: bool | None = None

    def __post_init__(self):
        if self.m < 1:
            raise ValueError(f"m must be >= 1, got {self.m}")
        if not 2 <= self.ks <= 256:
            raise ValueError(f"ks must be in [2, 256] (uint8 codes), got {self.ks}")

    def resolve_residual(self, metric: str) -> bool:
        if self.residual is None:
            return metric == "l2"
        if self.residual and metric != "l2":
            raise ValueError(
                "residual encoding is l2-only (an inner-product residual needs a "
                "per-query bias the ADC tables do not carry); use residual=False"
            )
        return self.residual


@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Search-time parameters of the quantized tier (``CompassParams.quant``).

    ``refine_factor`` widens stage one: ADC ordering is approximate, so the
    candidate search runs at ``ef * refine_factor`` and stage two reranks
    those survivors exactly, returning the top ``k``.  ``rerank`` picks the
    stage-two scorer: ``"full"`` reads the full-precision rows,
    ``"decode"`` re-scores against decoded codes (for deployments that
    dropped the float32 table; mathematically this equals the ADC distance,
    so it only canonicalizes summation order), ``"none"`` trusts the ADC
    ordering outright.
    """

    refine_factor: int = 4
    rerank: str = "full"

    def __post_init__(self):
        if self.refine_factor < 1:
            raise ValueError(f"refine_factor must be >= 1, got {self.refine_factor}")
        if self.rerank not in RERANK_MODES:
            raise ValueError(f"rerank must be one of {RERANK_MODES}, got {self.rerank!r}")
