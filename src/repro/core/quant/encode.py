"""Vectorized PQ encode/decode and the :class:`QuantizedVectors` pytree.

``QuantizedVectors`` is the device-resident quantized tier: uint8 codes
(one byte per subspace per row, sentinel-padded like ``CompassIndex``'s
row arrays), the frozen per-subspace codebooks, and the centering mean.
It rides on ``CompassIndex.qvecs`` alongside — or, for deployments that
drop the float32 table and rerank by decoding, instead of — the
full-precision rows; ``None`` (the default) keeps every pre-quantization
index bitwise identical.

Everything search needs at query time is a pure function of these arrays:

  * :func:`residual_queries` — center + zero-pad the query batch.
  * :func:`build_luts` — the per-query ``(m, ks)`` subspace distance
    tables (ADC's whole trick: a distance becomes ``m`` table lookups).
    The l2 table math is shared with the Pallas kernel's in-kernel LUT
    construction (``kernels.ref.subspace_lut``) so the ref and pallas
    scoring paths agree bitwise.
  * :func:`decode` — codebook gather, for on-demand exact rerank without
    the full-precision rows.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ...kernels.ref import adc_lut
from .codebook import pad_dim, train_codebooks
from .params import QuantConfig


class QuantizedVectors(NamedTuple):
    """Quantized row storage (a JAX pytree; every field is an array)."""

    codes: jax.Array  # (N + 1, m) uint8 — row N is the sentinel (all-zero)
    codebooks: jax.Array  # (m, ks, dsub) f32 frozen per-subspace centroids
    mean: jax.Array  # (d,) f32 centering offset (all-zero for raw encoding)
    train_mse: jax.Array  # () f32 quantization MSE at train time (drift anchor)

    @property
    def n_records(self) -> int:
        return self.codes.shape[0] - 1

    @property
    def m(self) -> int:
        return self.codebooks.shape[0]

    @property
    def ks(self) -> int:
        return self.codebooks.shape[1]

    @property
    def dsub(self) -> int:
        return self.codebooks.shape[2]

    @property
    def dim(self) -> int:
        return self.mean.shape[0]

    @property
    def bytes_per_vector(self) -> float:
        """Per-row storage of the quantized tier: codes plus the codebook
        amortized over the rows (the honest figure for small corpora)."""
        n = max(self.n_records, 1)
        codebook_bytes = self.m * self.ks * self.dsub * 4 + self.dim * 4
        return self.m * 1.0 + codebook_bytes / n


def _center_pad(vectors: jax.Array, mean: jax.Array, m: int) -> jax.Array:
    """(N, d) -> (N, d_pad) centered rows, zero-padded to ``m`` subspaces."""
    d = vectors.shape[-1]
    x = vectors - mean
    dp = pad_dim(d, m)
    if dp != d:
        x = jnp.concatenate(
            [x, jnp.zeros(x.shape[:-1] + (dp - d,), jnp.float32)], axis=-1
        )
    return x


@functools.partial(jax.jit, static_argnames=("block",))
def _encode_padded(xp: jax.Array, codebooks: jax.Array, *, block: int = 4096) -> jax.Array:
    """Nearest-centroid code per subspace, blocked over rows to bound the
    (block, m, ks) distance tensor (same trick as kmeans._assign_blocked)."""
    n = xp.shape[0]
    m, _, dsub = codebooks.shape
    pad = (-n) % block
    xpp = jnp.pad(xp, ((0, pad), (0, 0)))
    nb = xpp.shape[0] // block
    c2 = jnp.sum(codebooks * codebooks, axis=-1)  # (m, ks)

    def body(carry, xb):
        xs = xb.reshape(block, m, dsub)
        # ||x - c||^2 up to the row-constant ||x||^2, which cannot move argmin
        dist = c2[None, :, :] - 2.0 * jnp.einsum("nmd,mkd->nmk", xs, codebooks)
        return carry, jnp.argmin(dist, axis=-1).astype(jnp.uint8)

    _, codes = jax.lax.scan(body, 0, xpp.reshape(nb, block, -1))
    return codes.reshape(-1, m)[:n]


def encode_rows(codebooks: jax.Array, mean: jax.Array, vectors) -> jax.Array:
    """Encode (N, d) rows against frozen codebooks -> (N, m) uint8."""
    m = codebooks.shape[0]
    xp = _center_pad(jnp.asarray(vectors, jnp.float32), jnp.asarray(mean), m)
    return _encode_padded(xp, jnp.asarray(codebooks))


def decode(qv: QuantizedVectors, ids: jax.Array) -> jax.Array:
    """Decode rows by id -> (..., d) float32 approximations."""
    codes = qv.codes[ids].astype(jnp.int32)  # (..., m)
    m = qv.m
    sub = qv.codebooks[jnp.arange(m), codes]  # (..., m, dsub)
    flat = sub.reshape(sub.shape[:-2] + (m * qv.dsub,))[..., : qv.dim]
    return flat + qv.mean


def decode_all(qv: QuantizedVectors) -> jax.Array:
    """Decode the whole table (without the sentinel row) -> (N, d)."""
    return decode(qv, jnp.arange(qv.n_records))


def quant_mse(qv: QuantizedVectors, vectors) -> float:
    """Mean squared decode error over ``vectors`` (rows in table order) —
    the drift metric compaction tracks against ``train_mse``."""
    x = jnp.asarray(vectors, jnp.float32)
    err = decode(qv, jnp.arange(x.shape[0])) - x
    return float(jnp.mean(err * err))


def quantize_vectors(
    vectors, cfg: QuantConfig = QuantConfig(), metric: str = "l2"
) -> QuantizedVectors:
    """Train codebooks on ``vectors`` and encode them: the build entry point."""
    vectors = np.asarray(vectors, np.float32)
    codebooks, mean = train_codebooks(vectors, cfg, metric)
    codes = np.asarray(encode_rows(jnp.asarray(codebooks), jnp.asarray(mean), vectors))
    codes = np.concatenate([codes, np.zeros((1, cfg.m), np.uint8)], axis=0)
    qv = QuantizedVectors(
        jnp.asarray(codes),
        jnp.asarray(codebooks),
        jnp.asarray(mean),
        jnp.float32(0.0),
    )
    return qv._replace(train_mse=jnp.float32(quant_mse(qv, vectors)))


def quantize_index(index, cfg: QuantConfig = QuantConfig(), metric: str = "l2"):
    """Attach a quantized tier to a built CompassIndex (new index returned;
    pass the result anywhere the original was accepted — ``qvecs`` is an
    optional field, exact search paths ignore it)."""
    n = index.n_records
    qv = quantize_vectors(np.asarray(index.vectors)[:n], cfg, metric)
    return index._replace(qvecs=qv)


def residual_queries(qv: QuantizedVectors, queries: jax.Array) -> jax.Array:
    """Center + pad a query batch: (B, d) -> (B, d_pad) f32."""
    return _center_pad(jnp.asarray(queries, jnp.float32), qv.mean, qv.m)


def build_luts(qv: QuantizedVectors, queries: jax.Array, metric: str) -> jax.Array:
    """Per-query ADC tables: (B, m, ks).

    l2: ``lut[m, k] = ||q'_m - cb[m, k]||^2`` over centered-padded queries,
    summing to the exact decoded-row distance.  ip: ``lut[m, k] =
    -(q_m . cb[m, k])`` (raw encoding only; residual-ip is rejected at
    train time because it would need a per-query bias).  Both metrics vmap
    the same per-query expression the pq_score kernel builds in scratch
    (``kernels.ref.adc_lut``), so the ref and pallas scoring paths agree
    bitwise.
    """
    qr = residual_queries(qv, queries)  # (B, d_pad)
    return jax.vmap(lambda q: adc_lut(qv.codebooks, q, metric))(qr)
