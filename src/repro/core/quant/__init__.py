"""Product-quantization tier: codebooks, encode/decode, ADC search support.

See DESIGN.md §Quantization.  Build with :func:`quantize_index` (or
``quantize_vectors`` for raw tables), search by setting
``CompassParams(quant=QuantParams(...))`` — every execution mode
(COOPERATIVE / PREFILTER / POSTFILTER, mutable delta scans, distributed
shards) then scores candidates through the ADC tables and reranks the
survivors exactly.
"""
from .codebook import train_codebooks  # noqa: F401
from .encode import (  # noqa: F401
    QuantizedVectors,
    build_luts,
    decode,
    decode_all,
    encode_rows,
    quant_mse,
    quantize_index,
    quantize_vectors,
    residual_queries,
)
from .params import QuantConfig, QuantParams  # noqa: F401
from .rerank import rerank_batch  # noqa: F401

__all__ = [
    "QuantConfig",
    "QuantParams",
    "QuantizedVectors",
    "build_luts",
    "decode",
    "decode_all",
    "encode_rows",
    "quant_mse",
    "quantize_index",
    "quantize_vectors",
    "rerank_batch",
    "residual_queries",
    "train_codebooks",
]
