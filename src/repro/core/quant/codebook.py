"""Per-subspace PQ codebook training (reusing the IVF k-means of
core/kmeans.py).

Product quantization splits the (zero-padded) vector into ``m`` contiguous
subspaces and trains an independent ``ks``-way k-means codebook per
subspace; a row is then the ``m`` uint8 centroid ids.  Training cost is
``m`` small k-means problems over ``(N, d/m)`` slices — each one the same
jitted Lloyd loop the IVF layer uses, so on TPU the assignment step stays
an MXU matmul.  Residual-vs-raw is resolved per metric by
:meth:`QuantConfig.resolve_residual` (see params.py).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..kmeans import kmeans
from .params import QuantConfig


def pad_dim(d: int, m: int) -> int:
    """Vectors are zero-padded to the next multiple of ``m`` so subspaces
    are equal-width; the pad dims train to exactly-zero centroids (k-means
    centroids are means of zeros) and contribute 0 to every ADC table."""
    return ((d + m - 1) // m) * m


def split_subspaces(x: np.ndarray, m: int) -> np.ndarray:
    """(N, d) -> (m, N, dsub) zero-padded contiguous subspace slices."""
    n, d = x.shape
    dp = pad_dim(d, m)
    if dp != d:
        x = np.concatenate([x, np.zeros((n, dp - d), np.float32)], axis=1)
    return np.ascontiguousarray(x.reshape(n, m, dp // m).transpose(1, 0, 2))


def train_codebooks(
    vectors: np.ndarray, cfg: QuantConfig, metric: str = "l2"
) -> tuple[np.ndarray, np.ndarray]:
    """Train per-subspace codebooks.

    Returns ``(codebooks (m, ks, dsub) f32, mean (d,) f32)`` — ``mean`` is
    all-zero when raw encoding was resolved, so downstream code never
    branches on the residual choice: queries/rows are always centered by
    ``mean`` before table building / encoding.
    """
    vectors = np.asarray(vectors, np.float32)
    n, d = vectors.shape
    ks = min(cfg.ks, n)  # degenerate tiny corpora: never more codes than rows
    residual = cfg.resolve_residual(metric)
    mean = vectors.mean(axis=0) if residual else np.zeros((d,), np.float32)
    mean = mean.astype(np.float32)
    subs = split_subspaces(vectors - mean[None, :], cfg.m)  # (m, N, dsub)
    cbs = []
    for mi in range(cfg.m):
        km = kmeans(jnp.asarray(subs[mi]), ks, iters=cfg.iters, seed=cfg.seed + mi)
        cbs.append(np.asarray(km.centroids, np.float32))
    return np.stack(cbs), mean
