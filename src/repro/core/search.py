"""CompassSearch — compatibility shim over :mod:`repro.core.engine`.

The search core used to live here as one 430-line module; it is now the
execution-engine package (state/queues, G.NEXT/B.NEXT iterators, pluggable
scoring backends, driver loop — see ``engine/__init__.py`` and DESIGN.md
§Perf).  This module re-exports the public surface so existing imports
(``serving/rag.py``, ``benchmarks/``, ``examples/``, tests) keep working:

    from repro.core.search import CompassParams, compass_search

Backend selection: ``CompassParams(backend="pallas")`` routes VISIT through
``kernels.filter_distance`` and centroid ranking through
``kernels.ivf_score``; ``"ref"`` is the plain-jnp path; the default
``"auto"`` picks pallas on TPU and ref elsewhere.  Both produce identical
results (enforced by tests/test_compass_search.py).
"""
from __future__ import annotations

from .engine import (  # noqa: F401
    ENGINE_VERSION,
    CompassParams,
    EngineState,
    FixedQueue,
    SearchResult,
    SearchStats,
    compass_search,
    resolve_backend,
)
__all__ = [
    "ENGINE_VERSION",
    "CompassParams",
    "EngineState",
    "FixedQueue",
    "SearchResult",
    "SearchStats",
    "compass_search",
    "resolve_backend",
]
