"""DEPRECATED compatibility shim — import from :mod:`repro.compass` instead.

The search core used to live here as one 430-line module; it then became
the execution-engine package (``repro.core.engine``), and this module kept
the old import path alive.  With the unified public surface
(``repro.compass``: build / search / predicates / params / mutable /
serving / distributed in one namespace), this shim is deprecated and will
be removed after one release of grace:

    # old                                        # new
    from repro.core.search import ...      ->    from repro.compass import ...

Internal modules must not import through here (CI greps for it); the
re-exports remain only for external callers mid-migration.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.core.search is deprecated; import from repro.compass "
    "(engine internals: repro.core.engine). This shim will be removed "
    "after one release.",
    DeprecationWarning,
    stacklevel=2,
)

from .engine import (  # noqa: F401,E402
    ENGINE_VERSION,
    CompassParams,
    EngineState,
    FixedQueue,
    SearchResult,
    SearchStats,
    ShapePolicy,
    compass_search,
    resolve_backend,
)

__all__ = [
    "ENGINE_VERSION",
    "CompassParams",
    "EngineState",
    "FixedQueue",
    "SearchResult",
    "SearchStats",
    "ShapePolicy",
    "compass_search",
    "resolve_backend",
]
