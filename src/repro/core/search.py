"""CompassSearch — Algorithms 1-4 of the paper as one fused, batched
``lax.while_loop``.

Faithfulness notes (full discussion in DESIGN.md §Adaptation):

* The paper structures the search as two pull-based iterators (G.NEXT /
  B.NEXT) coordinating through a shared candidate queue.  On TPU, function
  calls are free but *dynamic shapes are not*, so the two iterators become
  two branches of a single fixed-shape loop body; the shared candidate
  queue, visited set, progressive ``efs``, passrate-adaptive expansion,
  round-paced result returns and relational injection are all preserved
  with identical candidate flow.
* Priority queues are fixed-capacity sorted arrays (+inf == empty slot).
  ``RecycQ`` of Algorithm 2 is *implicit*: our TopQ array always holds up to
  its full capacity and the live prefix is ``efs`` — enlarging ``efs``
  re-admits exactly the entries the paper's RecycQ would replay.  Instead of
  the pop-then-recycle dance we *peek* the shared queue before committing,
  which arrays support at no cost (heaps do not).
* The paper's cluster graph G' (§IV.C) is replaced by an exact centroid
  ranking — one MXU matmul at OPEN — consumed through a cursor, preserving
  the on-demand semantics (see index.py docstring).
* Graph entry is query-adaptive: the medoid of the nearest IVF cluster.
  This is the role HNSW's upper layers play; our flat build has no
  hierarchy, so the IVF layer (already in the index) provides the descent.
* Visited is a plain bool vector (a packed bitmap is a pure memory
  optimization; noted in §Perf).

The same loop, parameterized by :class:`CompassParams`, also implements the
paper's baselines and ablations:
  * ``in_filter=True, use_btree=False``  -> NaviX/ACORN-style in-filtering.
  * ``use_btree=False``                  -> plain progressive HNSW
    (post-filtering building block).
  * ``use_graph=False``                  -> CompassRelational ablation.
  * index built with ``nlist=1``         -> CompassGraph ablation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import predicate as P
from .clustered_attrs import searchsorted_slice
from .index import CompassIndex

INF = jnp.inf


@dataclasses.dataclass(frozen=True)
class CompassParams:
    k: int = 10  # results to return
    ef: int = 64  # target size of the filtered result queue (paper `ef`)
    alpha: float = 0.3  # one-hop passrate threshold (paper default)
    beta: float = 0.05  # two-hop / pivot passrate threshold (paper default)
    efs0: int = 16  # initial progressive search width
    stepsize: int = 16  # progressive efs increment (paper `stepsize`)
    ef_cap: int = 0  # max efs; 0 => 2 * ef + 32
    cand_cap: int = 0  # shared queue capacity; 0 => ef_cap + 64
    efi: int = 32  # records fetched per B.NEXT (paper `efi`)
    k2: int = 16  # two-hop visit budget per expansion
    max_steps: int = 0  # hard iteration budget; 0 => heuristic
    metric: str = "l2"
    use_graph: bool = True  # False => CompassRelational ablation
    use_btree: bool = True  # False => pure graph (NaviX / HNSW modes)
    in_filter: bool = False  # True => NaviX-style distance-only-if-passing
    adaptive_entry: bool = True  # IVF-guided entry (False: global medoid)
    entry_fanout: int = 4  # medoids of the top-R clusters seed the traversal
    cluster_tries: int = 8  # clusters examined per B step at most
    beam: int = 1  # candidates popped+expanded per loop step (§Perf:
    # beam>1 amortizes the per-step queue sorts and raises the arithmetic
    # intensity of each visit batch; passrate adaptivity is evaluated over
    # the pooled beam neighborhood instead of per candidate)

    def resolved(self) -> "CompassParams":
        ef_cap = self.ef_cap or 2 * self.ef + 32
        cand_cap = self.cand_cap or ef_cap + 64
        max_steps = self.max_steps or (4 * ef_cap + 8 * self.ef + 64)
        return dataclasses.replace(self, ef_cap=ef_cap, cand_cap=cand_cap, max_steps=max_steps)


class SearchStats(NamedTuple):
    n_dist: jax.Array  # base-vector distance computations (paper #Comp)
    n_cdist: jax.Array  # centroid distance computations
    n_steps: jax.Array  # loop iterations
    n_bcalls: jax.Array  # relational injections
    efs_final: jax.Array


class SearchResult(NamedTuple):
    ids: jax.Array  # (k,) int32, padded with N
    dists: jax.Array  # (k,) f32, padded with +inf
    stats: SearchStats


class _State(NamedTuple):
    # shared candidate queue (sorted ascending; +inf = empty)
    cand_d: jax.Array
    cand_i: jax.Array
    # graph-internal top queue (width control; unfiltered)
    gtop_d: jax.Array
    efs: jax.Array
    # filtered result queue (the global TopQ of Alg. 1)
    res_d: jax.Array
    res_i: jax.Array
    # visited flags
    visited: jax.Array  # (N + 1,) bool
    # clustered B+-tree iterator state
    rank: jax.Array  # (nlist,) clusters in centroid-distance order
    rank_pos: jax.Array  # cursor into `rank`
    term_beg: jax.Array  # (T,) cursors into order arrays (global positions)
    term_end: jax.Array
    b_exhausted: jax.Array
    # bookkeeping
    returned: jax.Array  # records handed to the global TopQ so far (Alg. 1)
    stalled: jax.Array
    last_sel: jax.Array
    stats: SearchStats


def _merge(qd, qi, nd, ni, cap):
    """Merge new entries into a sorted fixed-capacity queue."""
    d = jnp.concatenate([qd, nd])
    i = jnp.concatenate([qi, ni])
    order = jnp.argsort(d)
    return d[order[:cap]], i[order[:cap]]


def _dedup_new(ids, mask):
    """Mask out later duplicate ids within a visit list."""
    ids_masked = jnp.where(mask, ids, jnp.iinfo(jnp.int32).max)
    sort_idx = jnp.argsort(ids_masked)
    s = ids_masked[sort_idx]
    dup_sorted = jnp.concatenate([jnp.zeros((1,), bool), s[1:] == s[:-1]])
    dup = jnp.zeros_like(dup_sorted).at[sort_idx].set(dup_sorted)
    return mask & ~dup


def _visit(index: CompassIndex, q, pred, st: _State, ids, mask, pm: CompassParams):
    """Algorithm 4 over a fixed-size visit list.

    Computes distances for the masked list, marks visited, pushes into the
    shared queue + graph top queue, and into the filtered result queue for
    predicate-passing records.
    """
    n = index.n_records
    mask = _dedup_new(ids, mask)
    mask = mask & ~st.visited[ids]
    safe = jnp.where(mask, ids, n).astype(jnp.int32)
    vecs = index.vectors[safe]  # (V, d)
    if pm.metric == "l2":
        diff = vecs - q[None, :]
        dist = jnp.sum(diff * diff, axis=-1)
    else:
        dist = -(vecs @ q)
    dist = jnp.where(mask, dist, INF)
    attrs = index.attrs[safe]
    passing = P.evaluate(pred, attrs) & mask

    visited = st.visited.at[safe].set(True)  # sentinel slot absorbs masked
    cand_d, cand_i = _merge(st.cand_d, st.cand_i, dist, safe, pm.cand_cap)
    gtop_d, _ = _merge(st.gtop_d, jnp.zeros_like(st.gtop_d, jnp.int32), dist, safe, pm.ef_cap)
    res_dist = jnp.where(passing, dist, INF)
    res_d, res_i = _merge(st.res_d, st.res_i, res_dist, safe, pm.ef)
    n_dist = st.stats.n_dist + jnp.sum(mask)
    return st._replace(
        cand_d=cand_d,
        cand_i=cand_i,
        gtop_d=gtop_d,
        res_d=res_d,
        res_i=res_i,
        visited=visited,
        stats=st.stats._replace(n_dist=n_dist),
    )


def _inject_relational(index: CompassIndex, q, pred, chosen, st: _State, pm: CompassParams):
    """B.NEXT (Algorithm 3): pull predicate-passing records from the
    clustered B+-trees of the clusters nearest to the query, on demand."""
    ca = index.cattrs
    nlist = index.nlist
    T = pred.lo.shape[0]

    def advance_cluster(st: _State):
        """Advance the ranked-cluster cursor; point the per-term cursors at
        the new cluster's per-attribute sorted runs."""
        exhausted = st.rank_pos >= nlist
        c = st.rank[jnp.clip(st.rank_pos, 0, nlist - 1)]
        c_beg, c_end = ca.offsets[c], ca.offsets[c + 1]

        def one_term(t):
            a = chosen[t]
            lo_v, hi_v = pred.lo[t, a], pred.hi[t, a]
            beg = searchsorted_slice(ca.sorted_vals[a], c_beg, c_end, lo_v, "left")
            end = searchsorted_slice(ca.sorted_vals[a], c_beg, c_end, hi_v, "right")
            return beg, end

        beg, end = jax.vmap(one_term)(jnp.arange(T))
        return st._replace(
            rank_pos=jnp.where(exhausted, st.rank_pos, st.rank_pos + 1),
            term_beg=jnp.where(exhausted, st.term_beg, beg),
            term_end=jnp.where(exhausted, st.term_end, end),
            b_exhausted=st.b_exhausted | exhausted,
        )

    def maybe_advance(st: _State):
        rem = jnp.sum(jnp.maximum(st.term_end - st.term_beg, 0))
        need = (rem == 0) & ~st.b_exhausted
        return jax.lax.cond(need, advance_cluster, lambda s: s, st)

    st = jax.lax.fori_loop(0, pm.cluster_tries, lambda _, s: maybe_advance(s), st)

    # fetch up to efi positions across terms (term-major order)
    rem = jnp.maximum(st.term_end - st.term_beg, 0)  # (T,)
    cum = jnp.cumsum(rem)
    total = cum[-1]
    cum_e = jnp.minimum(cum, pm.efi)
    taken = cum_e - jnp.concatenate([jnp.zeros((1,), cum.dtype), cum_e[:-1]])
    slots = jnp.arange(pm.efi)
    term_of = jnp.searchsorted(cum, slots, side="right").astype(jnp.int32)
    term_of_c = jnp.clip(term_of, 0, T - 1)
    before = jnp.where(term_of_c > 0, cum[jnp.maximum(term_of_c - 1, 0)], 0)
    pos = st.term_beg[term_of_c] + (slots - before)
    slot_ok = slots < jnp.minimum(total, pm.efi)
    attr_of = chosen[term_of_c]
    ids = ca.order[attr_of, jnp.clip(pos, 0, ca.n_records - 1)]
    # full-predicate filter on the remaining attributes (paper: linear scan)
    n = index.n_records
    safe = jnp.where(slot_ok, ids, n)
    passing = P.evaluate(pred, index.attrs[safe]) & slot_ok
    st = st._replace(term_beg=st.term_beg + taken)
    st = _visit(index, q, pred, st, jnp.where(passing, ids, n), passing, pm)
    return st._replace(stats=st.stats._replace(n_bcalls=st.stats.n_bcalls + 1))


def _expand_graph(index: CompassIndex, q, pred, st: _State, pm: CompassParams):
    """Pop the best `beam` shared-queue candidates and expand per
    neighbourhood passrate (Algorithm 2 lines 12-17; beam == 1 is the
    paper-faithful per-candidate loop)."""
    n = index.n_records
    m = index.graph.degree
    w = pm.beam
    heads_d = st.cand_d[:w]
    heads_i = st.cand_i[:w]
    head_ok = jnp.isfinite(heads_d)
    # pop: drop heads, keep sorted
    cand_d = st.cand_d.at[:w].set(INF)
    order = jnp.argsort(cand_d)
    st = st._replace(cand_d=cand_d[order], cand_i=st.cand_i[order])

    nbrs = index.graph.neighbors[jnp.clip(heads_i, 0, n - 1)].reshape(-1)  # (W*M,)
    valid = (nbrs < n) & jnp.repeat(head_ok, m)
    safe = jnp.where(valid, nbrs, n)
    npass = P.evaluate(pred, index.attrs[safe]) & valid
    sel = jnp.sum(npass) / jnp.maximum(jnp.sum(valid), 1)

    unvis = valid & ~st.visited[safe]
    wm = w * m
    vl = wm + pm.k2

    def one_hop(_):
        mask = unvis & npass if pm.in_filter else unvis
        ids = jnp.concatenate([nbrs, jnp.full((pm.k2,), n, jnp.int32)])
        mk = jnp.concatenate([mask, jnp.zeros((pm.k2,), bool)])
        return ids, mk

    def two_hop(_):
        nbrs2 = index.graph.neighbors[safe].reshape(-1)  # (W*M*M,)
        valid2 = (nbrs2 < n) & jnp.repeat(valid, m)
        safe2 = jnp.where(valid2, nbrs2, n)
        pass2 = P.evaluate(pred, index.attrs[safe2]) & valid2
        unvis2 = pass2 & ~st.visited[safe2]
        unvis2 = _dedup_new(nbrs2, unvis2)
        # pick a bounded subset of passing two-hop neighbours
        score = unvis2.astype(jnp.float32)
        _, top_idx = jax.lax.top_k(score, pm.k2)
        sel_ids = nbrs2[top_idx]
        sel_mk = unvis2[top_idx]
        ids = jnp.concatenate([nbrs, sel_ids])
        mk = jnp.concatenate([unvis & npass, sel_mk])
        return ids, mk

    def none_(_):
        return jnp.full((vl,), n, jnp.int32), jnp.zeros((vl,), bool)

    if pm.in_filter:  # NaviX-style: never pivots, two-hop when sel < alpha
        branch = jnp.where(sel >= pm.alpha, 0, 1)
    else:
        branch = jnp.where(sel >= pm.alpha, 0, jnp.where(sel >= pm.beta, 1, 2))
    ids, mk = jax.lax.switch(branch, [one_hop, two_hop, none_], None)
    st = _visit(index, q, pred, st, ids, mk, pm)
    return st._replace(last_sel=sel)


def _search_one(index: CompassIndex, q, pred: P.Predicate, pm: CompassParams) -> SearchResult:
    n = index.n_records
    nlist = index.nlist
    T = pred.lo.shape[0]
    chosen = P.chosen_attrs(pred)

    # B.OPEN / G.OPEN: exact centroid ranking (one MXU matmul; see module
    # docstring) shared by the relational iterator and the adaptive entry.
    if pm.metric == "l2":
        cdiff = index.centroids - q[None, :]
        cdists = jnp.sum(cdiff * cdiff, axis=-1)
    else:
        cdists = -(index.centroids @ q)
    rank = jnp.argsort(cdists).astype(jnp.int32)

    zero = jnp.int32(0)
    stats = SearchStats(zero, jnp.int32(nlist), zero, zero, jnp.int32(pm.efs0))
    st = _State(
        cand_d=jnp.full((pm.cand_cap,), INF, jnp.float32),
        cand_i=jnp.full((pm.cand_cap,), n, jnp.int32),
        gtop_d=jnp.full((pm.ef_cap,), INF, jnp.float32),
        efs=jnp.int32(pm.efs0),
        res_d=jnp.full((pm.ef,), INF, jnp.float32),
        res_i=jnp.full((pm.ef,), n, jnp.int32),
        visited=jnp.zeros((n + 1,), bool),
        rank=rank,
        rank_pos=jnp.int32(0),
        term_beg=jnp.zeros((T,), jnp.int32),
        term_end=jnp.zeros((T,), jnp.int32),
        b_exhausted=jnp.asarray(not pm.use_btree),
        returned=jnp.int32(0),
        stalled=jnp.asarray(False),
        last_sel=jnp.float32(1.0),
        stats=stats,
    )
    # visit the graph entry points (Alg. 2 line 8, SELECTENTRYPOINT).
    # HNSW descends its upper layers to locate a good entry; our flat build
    # instead seeds with the medoids of the entry_fanout nearest IVF
    # clusters — same role, and robust when clusters straddle modes.
    if pm.use_graph:
        if pm.adaptive_entry:
            fan = min(pm.entry_fanout, nlist)
            entries = index.medoids[rank[:fan]].astype(jnp.int32)
            entries = jnp.concatenate(
                [entries, index.graph.entry.astype(jnp.int32)[None]]
            )
        else:
            entries = index.graph.entry.astype(jnp.int32)[None]
        st = _visit(index, q, pred, st, entries, jnp.ones(entries.shape, bool), pm)

    def res_count(st):
        return jnp.sum(jnp.isfinite(st.res_d)).astype(jnp.int32)

    def credit(st: _State, batch: int):
        """A round boundary: the iterator hands <= batch of its found-but-
        unreturned records to Alg. 1's global TopQ (ResQ/RelQ pops)."""
        give = jnp.minimum(jnp.int32(batch), res_count(st) - st.returned)
        return st._replace(returned=st.returned + jnp.maximum(give, 0))

    def cond(st: _State):
        return (
            (st.returned < pm.ef)
            & (st.stats.n_steps < pm.max_steps)
            & ~st.stalled
        )

    def body(st: _State):
        head_d = st.cand_d[0]
        queue_empty = ~jnp.isfinite(head_d)
        worst = st.gtop_d[jnp.minimum(st.efs, pm.ef_cap) - 1]
        gstop = queue_empty | (head_d > worst)

        if pm.use_graph:
            # gstop == Alg. 2 line 13: this G.NEXT round converged at the
            # current efs. Return <= k found records to the global TopQ,
            # then ExpandSearch widens efs for the next round.
            st = jax.lax.cond(gstop, lambda s: credit(s, pm.k), lambda s: s, st)
            new_efs = jnp.minimum(st.efs + pm.stepsize, pm.ef_cap)
            at_cap = st.efs >= pm.ef_cap
            st = st._replace(efs=jnp.where(gstop & ~at_cap, new_efs, st.efs))
            do_pop = ~gstop
            st = jax.lax.cond(
                do_pop, lambda s: _expand_graph(index, q, pred, s, pm), lambda s: s, st
            )
            low_sel = do_pop & (st.last_sel < pm.beta)
            # low-sel break is also a G.NEXT round boundary (Alg. 2 line 17)
            st = jax.lax.cond(low_sel, lambda s: credit(s, pm.k), lambda s: s, st)
            need_b = low_sel | (gstop & at_cap) | queue_empty
        else:
            need_b = jnp.asarray(True)
            gstop = jnp.asarray(True)
            at_cap = jnp.asarray(True)

        if pm.use_btree:

            def do_b(s):
                s = _inject_relational(index, q, pred, chosen, s, pm)
                return credit(s, max(1, pm.k // 2))  # Alg. 3 line 20: k/2 batch

            st = jax.lax.cond(need_b & ~st.b_exhausted, do_b, lambda s: s, st)
        # stall: nothing can make progress anymore
        head_d2 = st.cand_d[0]
        empty2 = ~jnp.isfinite(head_d2)
        worst2 = st.gtop_d[jnp.minimum(st.efs, pm.ef_cap) - 1]
        gstop2 = empty2 | (head_d2 > worst2)
        graph_dead = (gstop2 & (st.efs >= pm.ef_cap)) | empty2 if pm.use_graph else jnp.asarray(True)
        stalled = graph_dead & st.b_exhausted
        # a stalled search still flushes whatever it found
        st = jax.lax.cond(stalled, lambda s: credit(s, pm.ef), lambda s: s, st)
        st = st._replace(
            stalled=stalled,
            stats=st.stats._replace(n_steps=st.stats.n_steps + 1, efs_final=st.efs),
        )
        return st

    st = jax.lax.while_loop(cond, body, st)
    ids = st.res_i[: pm.k]
    dists = st.res_d[: pm.k]
    return SearchResult(ids, dists, st.stats)


@functools.partial(jax.jit, static_argnames=("pm",))
def compass_search(
    index: CompassIndex, queries: jax.Array, pred: P.Predicate, pm: CompassParams
) -> SearchResult:
    """Batched filtered search. queries: (B, d); pred arrays: (B, T, A)."""
    pm = pm.resolved()
    return jax.vmap(lambda q, lo, hi: _search_one(index, q, P.Predicate(lo, hi), pm))(
        queries, pred.lo, pred.hi
    )
