"""Distance primitives shared by every index component.

All distances are *squared* L2 by default (monotone w.r.t. L2, cheaper) or
negative inner product for MIPS-style corpora.  Batched forms are plain
matmuls so XLA maps them onto the MXU; the per-candidate gathered form is
implemented as a Pallas kernel in ``repro.kernels.filter_distance`` with
``pairwise_*`` here serving as the reference path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

METRICS = ("l2", "ip", "cos")


def normalize_rows(x: jax.Array) -> jax.Array:
    """Unit-normalize trailing-dim rows (cosine -> inner product reduction:
    ``cos`` corpora are normalized at build, queries at search entry, and
    everything downstream — kernels included — runs plain "ip")."""
    x = jnp.asarray(x, jnp.float32)
    nrm = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))
    return x / jnp.maximum(nrm, jnp.float32(1e-12))


def pairwise_l2(x: jax.Array, y: jax.Array) -> jax.Array:
    """Squared L2 distances. x: (m, d), y: (n, d) -> (m, n)."""
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)  # (m, 1)
    y2 = jnp.sum(y * y, axis=-1)  # (n,)
    xy = x @ y.T  # (m, n) -- MXU
    return jnp.maximum(x2 + y2[None, :] - 2.0 * xy, 0.0)


def pairwise_ip(x: jax.Array, y: jax.Array) -> jax.Array:
    """Negative inner product (so smaller == closer, like L2)."""
    return -(x @ y.T)


def pairwise(x: jax.Array, y: jax.Array, metric: str = "l2") -> jax.Array:
    if metric == "l2":
        return pairwise_l2(x, y)
    if metric == "ip":
        return pairwise_ip(x, y)
    if metric == "cos":
        return pairwise_ip(normalize_rows(x), normalize_rows(y))
    raise ValueError(f"unknown metric {metric!r}")


@functools.partial(jax.jit, static_argnames=("metric",))
def point_to_points(q: jax.Array, ys: jax.Array, metric: str = "l2") -> jax.Array:
    """q: (d,), ys: (v, d) -> (v,)."""
    if metric == "l2":
        diff = ys - q[None, :]
        return jnp.sum(diff * diff, axis=-1)
    return -(ys @ q)
