"""Clustered relational indices — the TPU-native equivalent of the paper's
"clustered B+-trees" (§IV.A).

Hardware adaptation (recorded in DESIGN.md): a B+-tree is a pointer-chasing
structure with no TPU analogue.  Its role in Compass is exactly two
operations per (cluster, attribute): (1) locate the contiguous run of
records whose attribute value falls in a query range, (2) iterate that run.
A *cluster-major sorted permutation* + fixed-depth binary search provides
identical O(log n + m) semantics with pure array reads:

  order[a]       : (N,)  int32 — record ids sorted by (cluster, attr_a)
  sorted_vals[a] : (N,)  f32   — attr_a values in that order
  offsets        : (nlist+1,) int32 — CSR cluster boundaries

A range probe inside cluster ``c`` is a 32-step branchless binary search
confined to ``[offsets[c], offsets[c+1])`` — the "B+-tree descent" — and the
run ``order[a][beg:end]`` is the leaf scan.  Updates to attribute values are
per-cluster re-sorts (cheap, local), mirroring the paper's point that only
the relational side needs maintenance on attribute update.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ClusteredAttrs(NamedTuple):
    order: jax.Array  # (A, N) int32: record ids, cluster-major, attr-sorted
    sorted_vals: jax.Array  # (A, N) f32: values aligned with `order`
    offsets: jax.Array  # (nlist + 1,) int32
    assignments: jax.Array  # (N,) int32 cluster of each record

    @property
    def n_attrs(self) -> int:
        return self.order.shape[0]

    @property
    def n_records(self) -> int:
        return self.order.shape[1]

    @property
    def n_clusters(self) -> int:
        return self.offsets.shape[0] - 1


def build_clustered_attrs(attrs: np.ndarray, assignments: np.ndarray, nlist: int) -> ClusteredAttrs:
    """Host-side build: sort each attribute within each cluster."""
    attrs = np.asarray(attrs, np.float32)
    assignments = np.asarray(assignments, np.int64)
    n, n_attrs = attrs.shape
    counts = np.bincount(assignments, minlength=nlist)
    offsets = np.zeros(nlist + 1, np.int32)
    np.cumsum(counts, out=offsets[1:])
    order = np.empty((n_attrs, n), np.int32)
    sorted_vals = np.empty((n_attrs, n), np.float32)
    for a in range(n_attrs):
        # lexsort: primary key cluster, secondary key attribute value.
        perm = np.lexsort((attrs[:, a], assignments))
        order[a] = perm.astype(np.int32)
        sorted_vals[a] = attrs[perm, a]
    return ClusteredAttrs(
        jnp.asarray(order),
        jnp.asarray(sorted_vals),
        jnp.asarray(offsets),
        jnp.asarray(assignments.astype(np.int32)),
    )


_BSEARCH_ITERS = 32  # supports N up to 2^32


def searchsorted_slice(vals: jax.Array, lo_idx, hi_idx, x, side: str = "left"):
    """Insertion point of ``x`` within ``vals[lo_idx:hi_idx]`` (global index).

    Branchless fixed-depth binary search; all arguments may be traced.
    """

    def body(_, bounds):
        lo, hi = bounds
        valid = lo < hi
        mid = (lo + hi) // 2
        v = vals[jnp.clip(mid, 0, vals.shape[0] - 1)]
        go_right = (v < x) if side == "left" else (v <= x)
        new_lo = jnp.where(go_right, mid + 1, lo)
        new_hi = jnp.where(go_right, hi, mid)
        return (jnp.where(valid, new_lo, lo), jnp.where(valid, new_hi, hi))

    lo, hi = jax.lax.fori_loop(0, _BSEARCH_ITERS, body, (lo_idx, hi_idx))
    return lo


def range_in_cluster(ca: ClusteredAttrs, cluster, attr, lo_val, hi_val):
    """(beg, end) global positions into ``order[attr]`` for records of
    ``cluster`` with attr value in the closed interval [lo_val, hi_val]."""
    c_beg = ca.offsets[cluster]
    c_end = ca.offsets[cluster + 1]
    vals = ca.sorted_vals[attr]
    beg = searchsorted_slice(vals, c_beg, c_end, lo_val, side="left")
    end = searchsorted_slice(vals, c_beg, c_end, hi_val, side="right")
    return beg, end


def count_in_cluster(ca: ClusteredAttrs, cluster, attr, lo_val, hi_val):
    beg, end = range_in_cluster(ca, cluster, attr, lo_val, hi_val)
    return end - beg


def run_bounds_all_clusters(ca: ClusteredAttrs, attr, lo_val, hi_val):
    """Per-cluster [beg, end) run bounds over ``order[attr]`` for records
    whose attr value lies in the closed interval [lo_val, hi_val] — every
    cluster probed at once (vmapped B+-tree descents).

    This is the planner's exact pass-count probe: ``sum(end - beg)`` is the
    exact number of records matching the single-attribute range, and the
    bounds themselves are the PREFILTER mode's materialization cursors.
    Returns (beg, end), each (nlist,) int32 global positions.
    """
    vals = ca.sorted_vals[attr]
    c_beg = ca.offsets[:-1]
    c_end = ca.offsets[1:]
    beg = jax.vmap(lambda b, e: searchsorted_slice(vals, b, e, lo_val, "left"))(c_beg, c_end)
    end = jax.vmap(lambda b, e: searchsorted_slice(vals, b, e, hi_val, "right"))(c_beg, c_end)
    return beg, end
