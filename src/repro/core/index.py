"""CompassIndex: the composed index of §IV.A.

Components (one per paper element):
  * ``graph``     — proximity graph over all record vectors (HNSW role).
  * ``centroids`` — IVF layer.  The paper additionally builds a small
    proximity graph over the centroids for "on-demand" cluster ranking
    (§IV.C) because a CPU linear scan over many centroids is expensive.
    On TPU a full centroid scan is a single (B, nlist) x (nlist, d) MXU
    matmul — cheaper than pointer-chasing — so the ranking is computed
    exactly in one shot and consumed *on demand* through a cursor, which
    preserves the paper's semantics (clusters visited in centroid-distance
    order, only as many as needed) while deleting the nprobe-tuning problem
    the same way the paper's cluster graph does.  (DESIGN.md §Adaptation.)
  * ``medoids``   — per-cluster medoid record, used for query-adaptive
    graph entry (the role HNSW's upper layers play on CPU).
  * ``cattrs``    — clustered per-attribute sorted permutations (the
    clustered B+-trees).

``vectors`` / ``attrs`` are stored padded with one sentinel row (index N) so
fixed-shape gathers of sentinel edges read harmless data that is masked out.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .clustered_attrs import ClusteredAttrs, build_clustered_attrs
from .graph_build import GraphIndex, build_graph
from .kmeans import kmeans
from .planner.stats import AttrStats, build_attr_stats
from .quant.encode import QuantizedVectors


class CompassIndex(NamedTuple):
    vectors: jax.Array  # (N + 1, d) padded
    attrs: jax.Array  # (N + 1, A) padded (sentinel row fails all predicates)
    graph: GraphIndex  # neighbors (N, M), entry (global medoid fallback)
    centroids: jax.Array  # (nlist, d)
    medoids: jax.Array  # (nlist,) int32 — medoid record id per cluster
    cattrs: ClusteredAttrs
    # per-cluster/per-attribute equi-depth histograms for the cost-based
    # planner; None on indices built before the planner existed (the
    # planner then refuses to run — CompassParams(planner=True) raises).
    astats: AttrStats | None = None
    # tombstone mask for the mutable-index subsystem (core/mutable): (N + 1,)
    # bool, False == deleted/superseded.  A dead record stays in the graph
    # and the sorted runs as a routing node — traversal still flows through
    # it — but the engine never admits it to the filtered result queue
    # (state.visit / the PREFILTER adoption both AND with this mask).  None
    # on a plain immutable index: zero cost until mutability is in play.
    live: jax.Array | None = None
    # product-quantized tier (core/quant): uint8 codes + frozen per-subspace
    # codebooks, attached by ``quantize_index``.  Scored through the ADC
    # tables when ``CompassParams.quant`` is set; ``None`` (the default)
    # keeps every exact-search program bitwise identical to pre-quant code
    # (trace-time branch on the pytree treedef, like ``live``).
    qvecs: QuantizedVectors | None = None

    @property
    def n_records(self) -> int:
        return self.vectors.shape[0] - 1

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def n_attrs(self) -> int:
        return self.attrs.shape[1]

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]


@dataclasses.dataclass(frozen=True)
class BuildConfig:
    m: int = 16  # graph max out-degree
    nlist: int = 64  # IVF cluster count
    kmeans_iters: int = 10
    nn_descent_rounds: int = 1
    prune_alpha: float = 1.2
    metric: str = "l2"
    seed: int = 0
    hist_bins: int = 64  # global equi-depth histogram bins per attribute
    cluster_hist_bins: int = 8  # per-cluster equi-depth bins per attribute


def cluster_medoids(
    vectors: np.ndarray,
    assign: np.ndarray,
    centroids: np.ndarray,
    fallback: int,
    metric: str = "l2",
) -> np.ndarray:
    """Per-cluster medoid (member closest to its centroid), computed as one
    segmented argmin instead of an O(nlist) host loop: every record scores
    against its *own* centroid (one gather + row-wise reduction), then a
    single ``lexsort`` by (cluster, distance) makes each cluster's first row
    its medoid.  Compaction re-derives medoids on every delta fold, so this
    is on the write path, not just index build.

    Empty clusters get ``fallback`` (the graph entry point).
    """
    vectors = np.asarray(vectors, np.float32)
    assign = np.asarray(assign, np.int64)
    nlist = centroids.shape[0]
    own = centroids[assign]  # (n, d) each record's centroid
    xy = np.einsum("nd,nd->n", vectors, own)
    if metric == "l2":
        d = np.einsum("nd,nd->n", vectors, vectors) - 2.0 * xy
    else:
        d = -xy
    perm = np.lexsort((d, assign))  # primary: cluster, secondary: distance
    a_sorted = assign[perm]
    first = np.r_[True, a_sorted[1:] != a_sorted[:-1]]
    medoids = np.full((nlist,), fallback, np.int32)
    medoids[a_sorted[first]] = perm[first]
    return medoids


def build_index(vectors: np.ndarray, attrs: np.ndarray, cfg: BuildConfig = BuildConfig()) -> CompassIndex:
    vectors = np.asarray(vectors, np.float32)
    attrs = np.asarray(attrs, np.float32)
    if cfg.metric == "cos":
        # cosine == inner product over unit rows: normalize the corpus once
        # here and build everything (graph, kmeans, medoids) as "ip"; the
        # driver normalizes queries at search entry (driver.compass_search)
        from .distances import normalize_rows

        vectors = np.asarray(normalize_rows(vectors))
        cfg = dataclasses.replace(cfg, metric="ip")
    n, d = vectors.shape
    graph = build_graph(
        vectors,
        cfg.m,
        nn_descent_rounds=cfg.nn_descent_rounds,
        prune_alpha=cfg.prune_alpha,
        metric=cfg.metric,
        seed=cfg.seed,
    )
    km = kmeans(jnp.asarray(vectors), cfg.nlist, iters=cfg.kmeans_iters, seed=cfg.seed, metric=cfg.metric)
    centroids = np.asarray(km.centroids)
    assign = np.asarray(km.assignments)
    medoids = cluster_medoids(vectors, assign, centroids, int(graph.entry), cfg.metric)
    cattrs = build_clustered_attrs(attrs, assign, cfg.nlist)
    astats = build_attr_stats(
        attrs, assign, cfg.nlist, n_bins=cfg.hist_bins, n_cluster_bins=cfg.cluster_hist_bins
    )
    # Sentinel padding rows. Attr sentinel = +inf fails every closed interval
    # whose hi is finite; predicates with hi = +inf (one-sided) are protected
    # by the validity masks in search, this is defence-in-depth.
    vpad = np.concatenate([vectors, np.zeros((1, d), np.float32)], 0)
    apad = np.concatenate([attrs, np.full((1, attrs.shape[1]), np.inf, np.float32)], 0)
    return CompassIndex(
        jnp.asarray(vpad),
        jnp.asarray(apad),
        graph,
        jnp.asarray(centroids),
        jnp.asarray(medoids),
        cattrs,
        astats,
    )
