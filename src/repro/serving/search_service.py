"""Continuous-batching serving layer for Compass filtered search.

``compass_search`` is a jitted function over static shapes: every distinct
term count ``T``, batch size ``B``, attribute count ``A`` or
:class:`CompassParams` is a fresh XLA program.  Serving traffic with
arbitrary mixed conjunction/disjunction shapes through it directly would
compile without bound.  :class:`SearchService` closes the gap between a
request stream and the engine:

* **Predicate-shape bucketing** — each request's DNF predicate is padded to
  the next power-of-two term count (``predicate.term_bucket``), so arbitrary
  widths collapse into a logarithmic number of static shapes.
* **Micro-batch formation** — per-bucket admission queues; a bucket flushes
  when it holds ``batch_size`` requests (full flush) or when its oldest
  request has waited ``max_wait_s`` (deadline flush).  Partial batches are
  padded to the fixed ``B`` with unsatisfiable-predicate fillers
  (``predicate.never_true``) whose lanes can never produce a result.
* **Compiled-executable cache** — one AOT-compiled executable per occupied
  ``(B, T, A, CompassParams)`` key (``compass_search_jit.lower(...).compile()``);
  steady-state traffic runs with a bounded, observable number of
  compilations (``stats()["compiles"]`` == occupied buckets).  For mutable
  services the snapshot shapes enter the key too — and because
  ``ShapePolicy`` buckets the base row count across compaction folds and
  fixes the delta capacity, those shapes are *epoch-stable*: a compaction
  swap re-uses the previous epoch's executables and the steady-state
  recompile budget is zero (the bench_updates ``--selfcheck`` tripwire).
* **Padding stripping** — :class:`ServiceResult` drops filler lanes, pad
  terms and the ``k``-prefix, so a response is bitwise-identical to calling
  ``compass_search`` directly on that query with its natural-``T`` predicate
  and the service's ``CompassParams`` (enforced by
  tests/test_search_service.py).

Per-request ``k`` must satisfy ``k <= params.k``: the engine's candidate
flow depends on ``params.k`` (round pacing uses ``k // 2``), so the service
searches at the fixed ``params.k`` and truncates — the response equals the
``k``-prefix of the direct call, not a differently-paced search.

The service is single-threaded by design (JAX dispatch is the bottleneck,
not Python): callers ``submit`` then drive ``step()`` / ``run_until_idle``.
A ``clock`` injection point makes deadline behaviour testable.

Observability: the service is the system's natural sync point (every batch
ends in ``block_until_ready``), so per-batch registry recording happens
here when ``repro.obs`` is enabled — request/batch/filler counters,
exec/wait latency histograms, and the device-side ``SearchStats`` of the
real (non-filler) lanes, all labelled by ``bucket="B{B}xT{T}"``.  Compile
events (both cache families) and write errors flow to the structured event
log.  All of it is off by default and never touches the traced program —
results are bitwise identical with obs on or off.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import OrderedDict, deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predicate as P
from repro.core.engine import CompassParams, compass_search_jit
from repro.core.index import CompassIndex
from repro.core.mutable import MutableIndex, mutable_search
from repro.core.planner import plan as plan_mod
from repro.obs import events as obs_events
from repro.obs import health as obs_health
from repro.obs import profiling as obs_prof
from repro.obs import registry as obs_reg


@dataclasses.dataclass
class SearchJob:
    """One admitted request, routed to the ``t_bucket`` queue."""

    rid: int
    query: np.ndarray  # (d,) float32
    pred: P.Predicate  # (T, A) natural (unpadded) shape
    k: int
    t_submit: float
    t_bucket: int


@dataclasses.dataclass
class WriteJob:
    """One admitted mutation (mutable-index services only).

    Writes are applied in admission order at scheduling-round boundaries
    (:meth:`SearchService.apply_writes`), never between the formation and
    execution of a search micro-batch — that is what keeps every batch
    pinned to a single index epoch.
    """

    kind: str  # "upsert" | "delete"
    gid: int
    vector: Optional[np.ndarray] = None  # (d,) for upserts
    attrs: Optional[np.ndarray] = None  # (A,) for upserts


@dataclasses.dataclass
class ServiceResult:
    """Response with all padding stripped.

    ``ids``/``dists`` are the first ``k`` rows of the engine result for this
    query's lane; ``ids == index.n_records`` marks empty (unfilled) slots
    exactly as in a direct ``compass_search`` call.
    """

    rid: int
    ids: np.ndarray  # (k,) int32
    dists: np.ndarray  # (k,) float32
    bucket: tuple  # (B, T) shape bucket that served the request
    queue_wait_s: float
    batch_exec_s: float
    # index epoch the whole micro-batch ran against (mutable-index services;
    # None when serving an immutable CompassIndex).  Every result of one
    # batch carries the same epoch — a batch never straddles a compaction.
    epoch: Optional[int] = None


@dataclasses.dataclass
class BucketStats:
    """Per-(B, T) bucket counters, serializable into BENCH JSON."""

    n_requests: int = 0
    n_batches: int = 0
    n_full_flush: int = 0
    n_deadline_flush: int = 0
    n_fillers: int = 0  # padded lanes dispatched
    n_compiles: int = 0
    n_cache_hits: int = 0
    total_wait_s: float = 0.0
    total_exec_s: float = 0.0
    # planner execution modes chosen for real (non-filler) lanes; all
    # cooperative when the planner is off (CompassParams.planner=False)
    n_mode_prefilter: int = 0
    n_mode_cooperative: int = 0
    n_mode_postfilter: int = 0


class SearchService:
    """Continuous-batching filtered-search service over one CompassIndex.

    Parameters
    ----------
    index : the (immutable) index to serve.
    params : engine parameters shared by every request; ``params.k`` is the
        max per-request ``k``.
    batch_size : fixed micro-batch width ``B`` every executable is built for.
    max_wait_s : deadline — a non-empty bucket older than this flushes
        partially padded rather than waiting for a full batch.
    max_terms : reject predicates whose DNF exceeds this many terms
        (bounds the largest compiled shape).
    result_buffer : how many completed results :meth:`poll` retains
        (oldest evicted first).  ``step()``/``flush()`` return values are
        the primary delivery path; the poll buffer exists for callers that
        track request ids, and is bounded so a caller consuming only the
        return values cannot leak memory under sustained traffic.
    clock : monotonic time source (injectable for deterministic tests).
    """

    def __init__(
        self,
        index: "CompassIndex | MutableIndex",
        params: CompassParams = CompassParams(),
        *,
        batch_size: int = 8,
        max_wait_s: float = 0.01,
        max_terms: int = 64,
        result_buffer: int = 4096,
        clock: Callable[[], float] = time.monotonic,
    ):
        # A MutableIndex enables the write path (submit_upsert/submit_delete)
        # and epoch-pinned dispatch; searches then report global ids.
        self.mutable = index if isinstance(index, MutableIndex) else None
        self.params = params
        self.batch_size = int(batch_size)
        self.max_wait_s = float(max_wait_s)
        self.max_terms = int(max_terms)
        self.result_buffer = int(result_buffer)
        self.clock = clock
        self._index = index if self.mutable is None else None
        self._rid = itertools.count()
        self._queues: dict[int, deque[SearchJob]] = {}
        self._writes: deque[WriteJob] = deque()
        self._executables: dict[tuple, Callable] = {}
        self._mutable_shapes: set[tuple] = set()  # compile accounting (jit path)
        self._results: OrderedDict[int, ServiceResult] = OrderedDict()
        self._stats: dict[tuple, BucketStats] = {}
        self.n_upserts = 0
        self.n_deletes = 0
        self.n_write_errors = 0
        # continuous monitoring (obs/health.py): attached explicitly via
        # enable_monitoring() or lazily by the first health() call; when
        # present, step() ticks it — a no-op unless obs is enabled, so the
        # disabled steady-state cost is one None check per round
        self.monitor: Optional[obs_health.Monitor] = None
        if params.quant is not None and self.index.qvecs is None:
            raise ValueError(
                "params.quant requires a quantized index "
                "(core.quant.quantize_index) — fail at construction, not "
                "at the first dispatch"
            )
        if self.mutable is not None:
            # the executable-cache key embeds params.shape while the actual
            # compiled shapes (row bucket, delta cap) come from the index's
            # own policy — a mismatch would make the cache accounting lie
            # about the steady-state recompile budget, so fail loudly here.
            # Compare with the construction-time overrides zeroed: params
            # normalizes shape.ef / shape.refine_factor after adoption.
            mine = dataclasses.replace(params.shape, ef=0, refine_factor=0)
            theirs = dataclasses.replace(self.mutable.shape, ef=0, refine_factor=0)
            if mine != theirs:
                raise ValueError(
                    "params.shape != mutable index's ShapePolicy "
                    f"({mine} vs {theirs}); construct both from one policy "
                    "so cache keys reflect the served shapes"
                )

    @property
    def index(self) -> CompassIndex:
        """The index being served (the current base for mutable services)."""
        return self._index if self.mutable is None else self.mutable.base

    # -- admission -----------------------------------------------------------

    def submit(
        self,
        query: np.ndarray,
        pred: "P.Pred | P.Predicate",
        k: Optional[int] = None,
    ) -> int:
        """Admit one ``(query, pred, k)`` job; returns a request id.

        ``pred`` may be a host-side :class:`Pred` tree (lowered here with
        its natural term count) or an already-lowered ``(T, A)``
        :class:`Predicate`.
        """
        if isinstance(pred, P.Pred):
            pred = pred.tensor(self.index.n_attrs)
        if pred.lo.ndim != 2:
            raise ValueError(f"expected (T, A) predicate, got shape {pred.lo.shape}")
        if pred.n_attrs != self.index.n_attrs:
            raise ValueError(
                f"predicate has {pred.n_attrs} attrs, index has {self.index.n_attrs}"
            )
        k = self.params.k if k is None else int(k)
        if not 0 < k <= self.params.k:
            raise ValueError(f"k={k} outside (0, params.k={self.params.k}]")
        if pred.n_terms > self.max_terms:
            raise ValueError(f"predicate has {pred.n_terms} terms > max_terms={self.max_terms}")
        query = np.asarray(query, np.float32)
        if query.shape != (self.index.dim,):
            raise ValueError(f"query shape {query.shape} != ({self.index.dim},)")
        rid = next(self._rid)
        job = SearchJob(
            rid=rid,
            query=query,
            pred=pred,
            k=k,
            t_submit=self.clock(),
            t_bucket=P.term_bucket(pred.n_terms),
        )
        self._queues.setdefault(job.t_bucket, deque()).append(job)
        return rid

    # -- write admission (mutable services) ----------------------------------

    def _require_mutable(self) -> MutableIndex:
        if self.mutable is None:
            raise ValueError("writes require a SearchService over a MutableIndex")
        return self.mutable

    def submit_upsert(self, gid: int, vector: np.ndarray, attrs: np.ndarray) -> None:
        """Admit an upsert; applied at the next scheduling-round boundary."""
        self._require_mutable()
        vector = np.asarray(vector, np.float32)
        attrs = np.asarray(attrs, np.float32)
        if vector.shape != (self.index.dim,):
            raise ValueError(f"vector shape {vector.shape} != ({self.index.dim},)")
        if attrs.shape != (self.index.n_attrs,):
            raise ValueError(f"attrs shape {attrs.shape} != ({self.index.n_attrs},)")
        self._writes.append(WriteJob("upsert", int(gid), vector, attrs))

    def submit_delete(self, gid: int) -> None:
        """Admit a delete; applied at the next scheduling-round boundary.

        Admission checks the id against the *current* index state — a gid
        queued for upsert in the same round is not yet visible.  The drain
        re-checks (the authoritative ordering is application order), so a
        delete raced by an earlier queued delete degrades to a counted
        no-op rather than poisoning the scheduling round.
        """
        mut = self._require_mutable()
        gid = int(gid)
        if gid not in mut and not any(
            w.kind == "upsert" and w.gid == gid for w in self._writes
        ):
            raise KeyError(f"unknown id {gid}")
        self._writes.append(WriteJob("delete", gid))

    def apply_writes(self) -> int:
        """Drain the write queue into the mutable index (may compact).

        Runs at the top of :meth:`step` / :meth:`flush`, i.e. strictly
        between micro-batches: a batch formed afterwards sees every applied
        write, and a batch already dispatched saw none of them — each batch
        is pinned to exactly one epoch.  Returns the number of writes
        applied.
        """
        applied = 0
        while self._writes:
            w = self._writes.popleft()
            if w.kind == "upsert":
                self.mutable.upsert(w.gid, w.vector, w.attrs)
                self.n_upserts += 1
            else:
                try:
                    self.mutable.delete(w.gid)
                    self.n_deletes += 1
                except KeyError:  # raced by a queued delete of the same gid
                    self.n_write_errors += 1
                    obs_events.emit("write_error", kind_detail="delete_missing", gid=w.gid)
                    if obs_reg.enabled():
                        obs_reg.registry().counter(
                            "compass_write_errors_total",
                            "Rejected/raced write operations",
                            labelnames=("tenant",),
                        ).inc(tenant="")
            applied += 1
        return applied

    # -- batch formation -----------------------------------------------------

    def step(self) -> list[ServiceResult]:
        """One scheduling round: apply queued writes, then flush every full
        bucket and every non-empty bucket whose oldest request has exceeded
        the deadline.  Returns the results completed this round (also
        retrievable via :meth:`poll`)."""
        if self.mutable is not None:
            self.apply_writes()
        done: list[ServiceResult] = []
        now = self.clock()
        for t_bucket, q in self._queues.items():
            while len(q) >= self.batch_size:
                done.extend(self._dispatch(t_bucket, full=True))
            if q and now - q[0].t_submit >= self.max_wait_s:
                done.extend(self._dispatch(t_bucket, full=False))
        if self.monitor is not None:
            # after dispatch so this round's sync-point records are in the
            # snapshot; Monitor.tick is a no-op when obs is disabled and
            # rate-limited by its interval_s otherwise
            self.monitor.tick()
        return done

    def flush(self) -> list[ServiceResult]:
        """Dispatch everything queued regardless of deadlines (drain)."""
        if self.mutable is not None:
            self.apply_writes()
        done: list[ServiceResult] = []
        for t_bucket, q in self._queues.items():
            while q:
                done.extend(self._dispatch(t_bucket, full=len(q) >= self.batch_size))
        return done

    def run_until_idle(self) -> list[ServiceResult]:
        """Step until queues empty, then drain the remainder."""
        done = self.step()
        done.extend(self.flush())
        return done

    def poll(self, rid: int) -> Optional[ServiceResult]:
        """Pop the result for ``rid`` if its batch has run, else None.

        Only the newest ``result_buffer`` unpolled results are retained.
        """
        return self._results.pop(rid, None)

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # -- execution -----------------------------------------------------------

    def _record_compile(self, cache: str, shape: tuple, wall_s: float | None) -> None:
        """Structured-event + counter trail for executable-cache misses.

        ``cache`` is "aot" (the immutable ``compass_search_jit.lower``
        cache) or "jit" (the mutable-snapshot shape set, where compilation
        happens inside the first traced call so no wall time is
        attributable here).  The bench_updates steady-state-recompile
        tripwire has a runtime twin now: ``compass_compiles_total`` should
        stop moving once every served shape is occupied.
        """
        obs_events.emit(
            "compile",
            cache=cache,
            shape=list(shape),
            wall_s=None if wall_s is None else round(wall_s, 6),
        )
        if obs_reg.enabled():
            obs_reg.registry().counter(
                "compass_compiles_total",
                "Search executable compilations",
                labelnames=("cache",),
            ).inc(cache=cache)

    def _executable(self, queries: jax.Array, pred: P.Predicate) -> Callable:
        B, T, A = pred.lo.shape
        # self.params embeds CompassParams.quant (a frozen, hashable
        # QuantParams), so quantized and exact configurations hash to
        # distinct keys and their executables coexist in one cache — the
        # same separation the (B, T, A) shape axes get.
        key = (B, T, A, self.params)
        st = self._stats.setdefault((B, T), BucketStats())
        exe = self._executables.get(key)
        if exe is None:
            t0 = self.clock()
            exe = compass_search_jit.lower(
                self.index, queries, pred, self.params
            ).compile()
            self._executables[key] = exe
            st.n_compiles += 1
            self._record_compile("aot", (B, T, A), self.clock() - t0)
        else:
            st.n_cache_hits += 1
        return exe

    def _dispatch(self, t_bucket: int, full: bool) -> list[ServiceResult]:
        q = self._queues[t_bucket]
        jobs = [q.popleft() for _ in range(min(self.batch_size, len(q)))]
        B = self.batch_size
        n_fill = B - len(jobs)
        queries = np.zeros((B, self.index.dim), np.float32)
        for i, job in enumerate(jobs):
            queries[i] = job.query
        preds = [j.pred for j in jobs] + [P.never_true(self.index.n_attrs)] * n_fill
        pred = P.stack_predicates(preds, n_terms=t_bucket)
        qj = jnp.asarray(queries)

        t0 = self.clock()
        epoch = None
        st = self._stats.setdefault((B, t_bucket), BucketStats())
        if self.mutable is not None:
            # Pin the epoch: take one snapshot and run the whole batch
            # against it.  Writes only apply at round boundaries
            # (apply_writes), so nothing can swap the base mid-batch — the
            # snapshot makes that guarantee explicit and keeps the result's
            # provenance (epoch) reportable.
            snap = self.mutable.snapshot()
            epoch = snap.epoch
            key = (B, t_bucket, pred.lo.shape[-1], self.params,
                   snap.index.n_records, snap.delta.cap)
            if key in self._mutable_shapes:
                st.n_cache_hits += 1
            else:
                self._mutable_shapes.add(key)
                st.n_compiles += 1
                self._record_compile(
                    "jit",
                    (B, t_bucket, pred.lo.shape[-1],
                     snap.index.n_records, snap.delta.cap),
                    None,
                )
            with obs_prof.annotate(f"compass/serve_batch/B{B}xT{t_bucket}"):
                res = mutable_search(
                    snap.index, snap.base_gids, snap.delta, qj, pred, self.params
                )
                res.ids.block_until_ready()
        else:
            exe = self._executable(qj, pred)
            with obs_prof.annotate(f"compass/serve_batch/B{B}xT{t_bucket}"):
                res = exe(self.index, qj, pred)
                res.ids.block_until_ready()
        exec_s = self.clock() - t0

        st = self._stats[(B, t_bucket)]
        st.n_requests += len(jobs)
        st.n_batches += 1
        st.n_fillers += n_fill
        st.n_full_flush += int(full)
        st.n_deadline_flush += int(not full)
        st.total_exec_s += exec_s
        # planner-chosen execution mode per real lane (filler lanes are the
        # service's padding, not traffic — excluded from the counters)
        modes = np.asarray(res.stats.mode)[: len(jobs)]
        st.n_mode_prefilter += int(np.sum(modes == plan_mod.PREFILTER))
        st.n_mode_cooperative += int(np.sum(modes == plan_mod.COOPERATIVE))
        st.n_mode_postfilter += int(np.sum(modes == plan_mod.POSTFILTER))

        if obs_reg.enabled():
            # we are already at the batch's sync point (block_until_ready
            # above), so folding device stats into host counters adds no
            # extra synchronization.  Filler lanes are the service's
            # padding, not traffic: slice them off before recording, same
            # rule as the mode counters above.
            bname = f"B{B}xT{t_bucket}"
            lanes = len(jobs)
            sliced = jax.tree_util.tree_map(
                lambda a: np.asarray(a)[:lanes], res.stats
            )
            obs_reg.record_search_stats(sliced, labels={"bucket": bname})
            # the serve families share their declaration with the
            # multi-tenant CollectionService: same (bucket, tenant)
            # schema, this single-index service recording tenant="" (the
            # unset-value convention record_search_stats already uses)
            R = obs_reg.registry()
            R.counter(
                "compass_serve_requests_total", "Real requests served",
                labelnames=("bucket", "tenant"),
            ).inc(lanes, bucket=bname, tenant="")
            R.counter(
                "compass_serve_batches_total", "Micro-batches dispatched",
                labelnames=("bucket", "tenant"),
            ).inc(bucket=bname, tenant="")
            if n_fill:
                R.counter(
                    "compass_serve_fillers_total", "Padded filler lanes dispatched",
                    labelnames=("bucket", "tenant"),
                ).inc(n_fill, bucket=bname, tenant="")
            R.histogram(
                "compass_serve_exec_seconds", "Micro-batch execution wall time",
                labelnames=("bucket", "tenant"), buckets=obs_reg.LATENCY_BUCKETS_S,
            ).observe(exec_s, bucket=bname, tenant="")
            wait_h = R.histogram(
                "compass_serve_wait_seconds", "Per-request queue wait",
                labelnames=("bucket", "tenant"), buckets=obs_reg.LATENCY_BUCKETS_S,
            )
            for job in jobs:
                wait_h.observe(t0 - job.t_submit, bucket=bname, tenant="")

        ids = np.asarray(res.ids)
        dists = np.asarray(res.dists)
        out = []
        for i, job in enumerate(jobs):
            wait = t0 - job.t_submit
            st.total_wait_s += wait
            r = ServiceResult(
                rid=job.rid,
                ids=ids[i, : job.k].copy(),
                dists=dists[i, : job.k].copy(),
                bucket=(B, t_bucket),
                queue_wait_s=wait,
                batch_exec_s=exec_s,
                epoch=epoch,
            )
            self._results[job.rid] = r
            out.append(r)
        while len(self._results) > self.result_buffer:
            self._results.popitem(last=False)  # evict oldest unpolled
        return out

    # -- observability -------------------------------------------------------

    def enable_monitoring(self, **kwargs) -> "obs_health.Monitor":
        """Attach (or replace) the continuous :class:`~repro.obs.health
        .Monitor`; ``step()`` ticks it from here on.  kwargs pass through
        to the Monitor (capacity, interval_s, slos, watchdogs); the
        service's clock is the default time source so deadline tests and
        snapshot cadence share one fake clock."""
        kwargs.setdefault("clock", self.clock)
        self.monitor = obs_health.Monitor(**kwargs)
        return self.monitor

    def health(self) -> "obs_health.HealthReport":
        """Evaluate SLOs + watchdogs now and return the report (attaches
        a default Monitor on first use)."""
        if self.monitor is None:
            self.enable_monitoring()
        return self.monitor.evaluate()

    def pending_writes(self) -> int:
        return len(self._writes)

    @property
    def compile_count(self) -> int:
        """Total XLA compilations so far == occupied (B, T, A, pm) keys
        (plus, for mutable services, occupied snapshot shapes)."""
        return len(self._executables) + len(self._mutable_shapes)

    def stats(self) -> dict:
        """JSON-ready snapshot: per-bucket counters plus service totals."""
        buckets = {
            f"B{b}xT{t}": dataclasses.asdict(s) for (b, t), s in sorted(self._stats.items())
        }
        n_req = sum(s.n_requests for s in self._stats.values())
        wait = sum(s.total_wait_s for s in self._stats.values())
        return {
            "batch_size": self.batch_size,
            "max_wait_s": self.max_wait_s,
            "compiles": self.compile_count,
            "occupied_buckets": len(self._stats),
            # the compiled-shape policy in force — with bucket_rows on, the
            # mutable snapshot shapes in the cache keys are epoch-stable,
            # so compiles stays == occupied shapes across compactions
            "shape_policy": dataclasses.asdict(self.params.shape),
            "n_requests": n_req,
            "n_batches": sum(s.n_batches for s in self._stats.values()),
            "n_fillers": sum(s.n_fillers for s in self._stats.values()),
            "mean_wait_s": wait / n_req if n_req else 0.0,
            "planner": self.params.planner,
            # quantized-tier provenance: which quant config this service's
            # executables were keyed on, and the per-row footprint actually
            # being served (codes+amortized codebook vs 4*d float32)
            "quant": (
                None
                if self.params.quant is None
                else dataclasses.asdict(self.params.quant)
            ),
            # footprint of the tier the candidate scans actually read:
            # exact-mode services read the float32 rows even when the
            # served index happens to carry codes alongside
            "bytes_per_vector": (
                round(self.index.qvecs.bytes_per_vector, 2)
                if self.params.quant is not None
                else 4 * self.index.dim
            ),
            "mutable": self.mutable is not None,
            "epoch": None if self.mutable is None else self.mutable.epoch,
            "n_upserts": self.n_upserts,
            "n_deletes": self.n_deletes,
            "n_write_errors": self.n_write_errors,
            "n_compactions": (
                0 if self.mutable is None else len(self.mutable.compaction_log)
            ),
            "modes": {
                "prefilter": sum(s.n_mode_prefilter for s in self._stats.values()),
                "cooperative": sum(s.n_mode_cooperative for s in self._stats.values()),
                "postfilter": sum(s.n_mode_postfilter for s in self._stats.values()),
            },
            # structured-event tallies (compaction / epoch_swap / compile /
            # write_error / ...) — zeros unless obs is enabled or a JSONL
            # sink is configured (REPRO_OBS_EVENTS)
            "obs_events": dict(obs_events.EVENTS.counts()),
            "obs_enabled": obs_reg.enabled(),
            # the last continuous-monitoring report (None until a Monitor
            # is attached and has evaluated at least once)
            "health": (
                None
                if self.monitor is None or self.monitor.last_report is None
                else self.monitor.last_report.to_dict()
            ),
            "buckets": buckets,
        }
