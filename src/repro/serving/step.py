"""Serving steps: prefill and decode, plus greedy sampling.

``serve_step`` (decode) is what the decode_* / long_* dry-run cells lower:
one new token per request against a seq_len-deep KV cache.  Prefill
returns last-position logits only (never materializes (B, S, V) logits —
that alone would exceed HBM at 32k x 256k vocab).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import forward


def make_prefill_step(cfg: ModelConfig, act_sharding=None, unroll: bool = False, ep=None):
    """Prefill runs the cache-free (flash-attention) path and *returns* the
    populated caches — routing prefill through the decode branch would
    materialize dense (S, T) score buffers."""

    def prefill(params, batch):
        kw = {}
        if "inputs_embeds" in batch:
            kw["inputs_embeds"] = batch["inputs_embeds"]
        else:
            kw["tokens"] = batch["tokens"]
        if "prefix_embeds" in batch:
            kw["prefix_embeds"] = batch["prefix_embeds"]
        logits, new_caches = forward(
            params, cfg, act_sharding=act_sharding, unroll=unroll, ep=ep, **kw
        )
        return logits[:, -1, :], new_caches

    return prefill


def make_decode_step(cfg: ModelConfig, unroll: bool = False):
    """One token for every sequence in the batch. cache_pos: scalar int32
    (uniform position — continuous batching handles ragged positions by
    per-slot pos vectors upstream; see serving/scheduler.py)."""

    def decode(params, tokens, caches, cache_pos):
        kw = {}
        if cfg.embed_inputs:
            kw["tokens"] = tokens
        else:
            # audio stub: decode consumes the previous frame embedding
            kw["inputs_embeds"] = tokens
        logits, new_caches = forward(params, cfg, caches=caches, cache_pos=cache_pos, unroll=unroll, **kw)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, logits[:, -1, :], new_caches

    return decode
