"""Multi-tenant collection service: one front door over many indexes
(DESIGN.md §Tenancy).

A :class:`CollectionService` manages *named collections* — each its own
index (:class:`MutableIndex` or immutable :class:`CompassIndex`), quant
configuration and result cache — behind a single scheduler:

* **Per-tenant admission queues + weighted-fair scheduling.**  Every
  collection keeps its own per-``t_bucket`` queues; dispatch order
  follows start-time-fair virtual time (SCFQ): a collection is charged
  ``1/weight`` of virtual time per micro-batch, and the ready collection
  with the smallest virtual time dispatches next.  A weight-3 tenant
  therefore gets ~3x the batch slots of a weight-1 tenant under
  contention, while an idle tenant's unused share flows to the others
  (its virtual time is clamped forward on its next dispatch, so no
  tenant banks credit while idle).
* **Queue-depth load shedding, never silent.**  When a collection's
  total queued depth reaches ``CollectionSpec.max_queue_depth``,
  ``submit`` returns a typed :class:`Rejected` (synchronously — the
  caller always learns the fate of the request) and increments
  ``compass_shed_total{tenant=...}``.
* **Executable-cache sharing across tenants.**  Compiled programs are
  keyed by shape, not by collection: mutable collections share one
  shape-key set (the underlying ``mutable_search`` jit cache is global,
  so N tenants whose ``(B, T, A, params, rows, delta_cap)`` keys
  collapse run one compiled program), and immutable collections share
  AOT executables keyed on ``(B, T, A, params, index-signature)`` — the
  index is an *argument* of the compiled program, so any same-shaped
  index reuses it.  ``compile_count`` == occupied shape keys, not
  tenants x buckets (the bench_tenancy ``--selfcheck`` tripwire).
* **Two-tier semantic result cache** per collection
  (:mod:`.cache`): exact request-byte hits (bitwise-identical replay)
  plus an opt-in near-duplicate tier keyed on the collection's own PQ
  codes; invalidated on every applied write and every epoch swap of the
  owning collection only.

Observability rides the PR-8/9 stack: every serving family carries a
``tenant`` label (``""`` for the single-index :class:`SearchService`),
so per-tenant p50/p99, shed rate and cache hit rate land in the existing
``compass_*`` series, `obs.health`'s ``admission_pressure`` watchdog
grades shed rate + queue fill, and :func:`repro.obs.slo.tenant_slos`
builds per-tenant burn-rate objectives from the same labels.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import OrderedDict, deque
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import predicate as P
from repro.core.engine import CompassParams, compass_search_jit
from repro.core.index import CompassIndex
from repro.core.mutable import MutableIndex, mutable_search
from repro.core.planner import plan as plan_mod
from repro.core.quant.encode import encode_rows
from repro.obs import events as obs_events
from repro.obs import health as obs_health
from repro.obs import profiling as obs_prof
from repro.obs import registry as obs_reg
from repro.serving.search_service import BucketStats, WriteJob

from .cache import CollectionCache


@dataclasses.dataclass(frozen=True)
class CollectionSpec:
    """Per-collection policy: QoS weight, admission bound, cache sizing.

    ``weight`` is the fair-share ratio (a weight-3 collection gets 3x
    the micro-batch slots of a weight-1 collection under contention).
    ``max_queue_depth`` is the shed threshold over the collection's
    total queued requests.  ``cache_capacity`` bounds the exact result
    tier (0 disables caching); ``near_cache`` opts into the PQ-code
    near-duplicate tier (requires a quantized index).  ``quant``
    overrides the service-level search-time quant params for this
    collection only.
    """

    name: str
    weight: float = 1.0
    max_queue_depth: int = 1024
    cache_capacity: int = 256
    near_cache: bool = False
    quant: Optional[object] = None  # QuantParams | None

    def __post_init__(self):
        if not self.name:
            raise ValueError("collection name must be non-empty")
        if not self.weight > 0:
            raise ValueError(f"{self.name}: weight must be > 0, got {self.weight}")
        if self.max_queue_depth <= 0:
            raise ValueError(f"{self.name}: max_queue_depth must be > 0")
        if self.cache_capacity < 0:
            raise ValueError(f"{self.name}: cache_capacity must be >= 0")


@dataclasses.dataclass(frozen=True)
class Rejected:
    """Typed load-shed verdict — the *result* of an over-limit submit.

    Returned synchronously from :meth:`CollectionService.submit` instead
    of a request id; the request was never queued.  ``queue_depth`` is
    the depth observed at admission, ``limit`` the spec's threshold.
    """

    rid: int
    collection: str
    reason: str  # currently always "queue_depth"
    queue_depth: int
    limit: int


@dataclasses.dataclass
class TenantResult:
    """A :class:`~repro.serving.search_service.ServiceResult` plus
    tenancy provenance: the owning collection and, for cache-served
    responses, which tier answered (``"exact"`` hits are bitwise
    identical to an uncached search; ``"near"`` hits are approximate by
    contract and flagged so callers can ignore them per request)."""

    rid: int
    collection: str
    ids: np.ndarray  # (k,) int32
    dists: np.ndarray  # (k,) float32
    bucket: Optional[tuple]  # (B, T) shape bucket; None for cache hits
    queue_wait_s: float
    batch_exec_s: float
    epoch: Optional[int] = None
    cache_tier: Optional[str] = None  # None | "exact" | "near"


@dataclasses.dataclass
class _Job:
    """One admitted request inside a collection's ``t_bucket`` queue."""

    rid: int
    query: np.ndarray  # (d,) float32
    pred: P.Predicate  # (T, A) natural shape
    k: int
    t_submit: float
    t_bucket: int
    exact_key: Optional[tuple] = None
    near_key: Optional[tuple] = None


class _Collection:
    """Internal per-collection state: index, params, queues, cache,
    counters.  The public face is :class:`CollectionClient`."""

    def __init__(self, spec: CollectionSpec, index, params: CompassParams):
        self.spec = spec
        self.mutable = index if isinstance(index, MutableIndex) else None
        self._index = index if self.mutable is None else None
        self.params = params
        self.queues: dict[int, deque[_Job]] = {}
        self.writes: deque[WriteJob] = deque()
        self.vtime = 0.0
        self.cache = CollectionCache(
            spec.cache_capacity,
            near_capacity=spec.cache_capacity if spec.near_cache else 0,
        )
        self.cached_epoch = None if self.mutable is None else self.mutable.epoch
        self.stats: dict[tuple, BucketStats] = {}
        self.n_submitted = 0
        self.n_shed = 0
        self.n_cache_served = 0
        self.n_upserts = 0
        self.n_deletes = 0
        self.n_write_errors = 0

    @property
    def index(self) -> CompassIndex:
        return self._index if self.mutable is None else self.mutable.base

    def depth(self) -> int:
        return sum(len(q) for q in self.queues.values())


def _index_sig(index: CompassIndex) -> tuple:
    """Hashable shape/dtype signature of an index pytree — the part of
    the AOT executable key that makes cross-tenant sharing safe: two
    indexes with the same signature are interchangeable arguments of one
    compiled program."""
    leaves, treedef = jax.tree_util.tree_flatten(index)
    return (
        str(treedef),
        tuple((tuple(leaf.shape), str(leaf.dtype)) for leaf in leaves),
    )


class CollectionClient:
    """Handle to one named collection — the per-tenant API surface.

    Duck-type compatible with :class:`SearchService` for read traffic
    (``submit`` / ``step`` / ``flush`` / ``run_until_idle`` / ``poll`` /
    ``stats``), which is how ``RagIndex.make_service`` hands existing
    callers tenancy without an interface change.  ``run_until_idle`` and
    ``step`` drive the *whole* service (batches of other collections may
    execute) but return only this collection's results; other tenants'
    results stay pollable by rid.
    """

    def __init__(self, service: "CollectionService", name: str):
        self.service = service
        self.name = name

    def submit(self, query, pred, k: Optional[int] = None) -> Union[int, Rejected]:
        return self.service.submit(self.name, query, pred, k=k)

    def submit_upsert(self, gid: int, vector, attrs) -> None:
        self.service.submit_upsert(self.name, gid, vector, attrs)

    def submit_delete(self, gid: int) -> None:
        self.service.submit_delete(self.name, gid)

    def _mine(self, results: list[TenantResult]) -> list[TenantResult]:
        return [r for r in results if r.collection == self.name]

    def step(self) -> list[TenantResult]:
        return self._mine(self.service.step())

    def flush(self) -> list[TenantResult]:
        return self._mine(self.service.flush())

    def run_until_idle(self) -> list[TenantResult]:
        return self._mine(self.service.run_until_idle())

    def poll(self, rid: int) -> Optional[TenantResult]:
        return self.service.poll(rid)

    def pending(self) -> int:
        return self.service._col(self.name).depth()

    def compact(self, retrain_codebooks: bool = False) -> None:
        self.service.compact(self.name, retrain_codebooks=retrain_codebooks)

    def health(self):
        return self.service.health()

    @property
    def mutable(self) -> Optional[MutableIndex]:
        return self.service._col(self.name).mutable

    @property
    def index(self) -> CompassIndex:
        return self.service._col(self.name).index

    def stats(self) -> dict:
        return self.service.collection_stats(self.name)


class CollectionService:
    """Weighted-fair, load-shedding, result-caching front door over many
    named collections (module docstring has the design contract).

    Parameters mirror :class:`SearchService` where they overlap;
    ``max_batches_per_step`` bounds how many micro-batches one
    :meth:`step` may dispatch (0 = drain everything ready), which makes
    fair-share ratios observable per round and lets queues actually
    build toward the shed threshold under synthetic overload.
    """

    def __init__(
        self,
        params: CompassParams = CompassParams(),
        *,
        batch_size: int = 8,
        max_wait_s: float = 0.01,
        max_terms: int = 64,
        max_batches_per_step: int = 0,
        result_buffer: int = 4096,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.params = params
        self.batch_size = int(batch_size)
        self.max_wait_s = float(max_wait_s)
        self.max_terms = int(max_terms)
        self.max_batches_per_step = int(max_batches_per_step)
        self.result_buffer = int(result_buffer)
        self.clock = clock
        self._collections: dict[str, _Collection] = {}
        self._executables: dict[tuple, Callable] = {}  # immutable AOT, shared
        self._mutable_shapes: set[tuple] = set()  # mutable jit shapes, shared
        self._results: OrderedDict[int, TenantResult] = OrderedDict()
        self._cache_served: list[TenantResult] = []
        self._rid = itertools.count()
        self._vtime = 0.0
        self.monitor: Optional[obs_health.Monitor] = None

    # -- collection lifecycle ------------------------------------------------

    def create(
        self,
        name: str,
        index: "CompassIndex | MutableIndex",
        *,
        spec: Optional[CollectionSpec] = None,
        **spec_kw,
    ) -> CollectionClient:
        """Register ``index`` under ``name``; returns the tenant handle.

        ``spec_kw`` (weight, max_queue_depth, cache_capacity, near_cache,
        quant) builds a :class:`CollectionSpec` when ``spec`` is not
        given.  Fails loudly at registration for every misconfiguration
        that would otherwise surface at first dispatch: duplicate names,
        quant params over an unquantized index, near-cache without PQ
        codes, and (mutable) a ShapePolicy that disagrees with the
        service params — the same cache-accounting guard
        :class:`SearchService` enforces.
        """
        if name in self._collections:
            raise ValueError(f"collection {name!r} already exists")
        spec = CollectionSpec(name=name, **spec_kw) if spec is None else spec
        if spec.name != name:
            raise ValueError(f"spec.name {spec.name!r} != collection name {name!r}")
        params = (
            self.params
            if spec.quant is None
            else dataclasses.replace(self.params, quant=spec.quant)
        )
        base = index.base if isinstance(index, MutableIndex) else index
        if params.quant is not None and base.qvecs is None:
            raise ValueError(
                f"collection {name!r}: quant params require a quantized index"
            )
        if spec.near_cache and base.qvecs is None:
            raise ValueError(
                f"collection {name!r}: near_cache keys on the index's PQ "
                "codes — quantize_index first"
            )
        if isinstance(index, MutableIndex):
            mine = dataclasses.replace(params.shape, ef=0, refine_factor=0)
            theirs = dataclasses.replace(index.shape, ef=0, refine_factor=0)
            if mine != theirs:
                raise ValueError(
                    f"collection {name!r}: params.shape != index ShapePolicy "
                    f"({mine} vs {theirs}); shared shape keys need one policy"
                )
        self._collections[name] = _Collection(spec, index, params)
        obs_events.emit(
            "collection_create",
            collection=name,
            weight=spec.weight,
            max_queue_depth=spec.max_queue_depth,
            mutable=isinstance(index, MutableIndex),
        )
        return CollectionClient(self, name)

    def drop(self, name: str) -> None:
        """Unregister a collection (queued work is discarded; shared
        executables stay — other tenants may hold the same shapes)."""
        col = self._col(name)
        dropped = col.depth() + len(col.writes)
        del self._collections[name]
        obs_events.emit("collection_drop", collection=name, dropped_queued=dropped)

    def collection(self, name: str) -> CollectionClient:
        self._col(name)  # raise on unknown
        return CollectionClient(self, name)

    def collections(self) -> tuple[str, ...]:
        return tuple(sorted(self._collections))

    def _col(self, name: str) -> _Collection:
        try:
            return self._collections[name]
        except KeyError:
            raise KeyError(f"unknown collection {name!r}") from None

    # -- admission -----------------------------------------------------------

    def submit(
        self,
        name: str,
        query: np.ndarray,
        pred: "P.Pred | P.Predicate",
        k: Optional[int] = None,
    ) -> Union[int, Rejected]:
        """Admit one request to collection ``name``.

        Returns a request id, or a typed :class:`Rejected` when the
        collection's queue is at its shed threshold (the request was
        never queued — the caller learns synchronously, nothing is
        dropped silently).  Cache hits are admitted as already-complete:
        the id is returned and the result is delivered by the next
        ``step()``/``flush()`` (and via :meth:`poll` immediately).
        """
        col = self._col(name)
        index = col.index
        if isinstance(pred, P.Pred):
            pred = pred.tensor(index.n_attrs)
        if pred.lo.ndim != 2:
            raise ValueError(f"expected (T, A) predicate, got shape {pred.lo.shape}")
        if pred.n_attrs != index.n_attrs:
            raise ValueError(
                f"predicate has {pred.n_attrs} attrs, collection {name!r} "
                f"has {index.n_attrs}"
            )
        k = col.params.k if k is None else int(k)
        if not 0 < k <= col.params.k:
            raise ValueError(f"k={k} outside (0, params.k={col.params.k}]")
        if pred.n_terms > self.max_terms:
            raise ValueError(
                f"predicate has {pred.n_terms} terms > max_terms={self.max_terms}"
            )
        query = np.asarray(query, np.float32)
        if query.shape != (index.dim,):
            raise ValueError(f"query shape {query.shape} != ({index.dim},)")

        rid = next(self._rid)
        col.n_submitted += 1
        if obs_reg.enabled():
            obs_reg.registry().counter(
                "compass_submitted_total",
                "Requests offered for admission",
                labelnames=("tenant",),
            ).inc(tenant=name)

        depth = col.depth()
        if depth >= col.spec.max_queue_depth:
            col.n_shed += 1
            if obs_reg.enabled():
                obs_reg.registry().counter(
                    "compass_shed_total",
                    "Requests shed at admission (typed Rejected)",
                    labelnames=("tenant",),
                ).inc(tenant=name)
            obs_events.emit(
                "shed",
                collection=name,
                queue_depth=depth,
                limit=col.spec.max_queue_depth,
            )
            return Rejected(
                rid=rid,
                collection=name,
                reason="queue_depth",
                queue_depth=depth,
                limit=col.spec.max_queue_depth,
            )

        # an epoch swap done directly on the MutableIndex (not via this
        # service) must not let stale entries serve — reconcile before lookup
        self._check_epoch(col)
        exact_key = near_key = None
        if col.cache.enabled:
            exact_key = (
                query.tobytes(),
                np.asarray(pred.lo, np.float32).tobytes(),
                np.asarray(pred.hi, np.float32).tobytes(),
                k,
            )
            if col.cache.near_capacity > 0:
                near_key = (
                    self._query_codes(col, query),
                    exact_key[1],
                    exact_key[2],
                    k,
                )
            entry, tier = col.cache.lookup(exact_key, near_key)
            if entry is not None:
                res = TenantResult(
                    rid=rid,
                    collection=name,
                    ids=entry.ids[:k].copy(),
                    dists=entry.dists[:k].copy(),
                    bucket=None,
                    queue_wait_s=0.0,
                    batch_exec_s=0.0,
                    epoch=entry.epoch,
                    cache_tier=tier,
                )
                col.n_cache_served += 1
                self._store(res)
                self._cache_served.append(res)
                if obs_reg.enabled():
                    obs_reg.registry().counter(
                        "compass_result_cache_hits_total",
                        "Requests answered from the semantic result cache",
                        labelnames=("tenant", "tier"),
                    ).inc(tenant=name, tier=tier)
                return rid
            if obs_reg.enabled():
                obs_reg.registry().counter(
                    "compass_result_cache_misses_total",
                    "Cache-enabled requests that required a live search",
                    labelnames=("tenant",),
                ).inc(tenant=name)

        job = _Job(
            rid=rid,
            query=query,
            pred=pred,
            k=k,
            t_submit=self.clock(),
            t_bucket=P.term_bucket(pred.n_terms),
            exact_key=exact_key,
            near_key=near_key,
        )
        col.queues.setdefault(job.t_bucket, deque()).append(job)
        return rid

    def _query_codes(self, col: _Collection, query: np.ndarray) -> bytes:
        """The query's PQ code word under this collection's codebooks —
        the near-duplicate cache key (ISSUE: keyed on the collection's
        *own* codes, so a word can never mean the same thing in another
        collection)."""
        qv = col.index.qvecs
        codes = np.asarray(encode_rows(qv.codebooks, qv.mean, query[None]))
        return codes[0].tobytes()

    # -- write admission -----------------------------------------------------

    def _require_mutable(self, col: _Collection) -> MutableIndex:
        if col.mutable is None:
            raise ValueError(
                f"writes require collection {col.spec.name!r} to wrap a MutableIndex"
            )
        return col.mutable

    def submit_upsert(self, name: str, gid: int, vector, attrs) -> None:
        col = self._col(name)
        self._require_mutable(col)
        vector = np.asarray(vector, np.float32)
        attrs = np.asarray(attrs, np.float32)
        if vector.shape != (col.index.dim,):
            raise ValueError(f"vector shape {vector.shape} != ({col.index.dim},)")
        if attrs.shape != (col.index.n_attrs,):
            raise ValueError(f"attrs shape {attrs.shape} != ({col.index.n_attrs},)")
        col.writes.append(WriteJob("upsert", int(gid), vector, attrs))

    def submit_delete(self, name: str, gid: int) -> None:
        col = self._col(name)
        mut = self._require_mutable(col)
        gid = int(gid)
        if gid not in mut and not any(
            w.kind == "upsert" and w.gid == gid for w in col.writes
        ):
            raise KeyError(f"unknown id {gid} in collection {name!r}")
        col.writes.append(WriteJob("delete", gid))

    def _apply_writes(self, col: _Collection) -> int:
        """Drain one collection's write queue (round boundary only —
        batches stay pinned to a single epoch).  Any applied write
        invalidates *this collection's* result cache (upserts can
        auto-compact on delta overflow, so this also covers implicit
        epoch swaps)."""
        applied = 0
        while col.writes:
            w = col.writes.popleft()
            if w.kind == "upsert":
                col.mutable.upsert(w.gid, w.vector, w.attrs)
                col.n_upserts += 1
            else:
                try:
                    col.mutable.delete(w.gid)
                    col.n_deletes += 1
                except KeyError:  # raced by a queued delete of the same gid
                    col.n_write_errors += 1
                    obs_events.emit(
                        "write_error",
                        kind_detail="delete_missing",
                        gid=w.gid,
                        collection=col.spec.name,
                    )
                    if obs_reg.enabled():
                        obs_reg.registry().counter(
                            "compass_write_errors_total",
                            "Rejected/raced write operations",
                            labelnames=("tenant",),
                        ).inc(tenant=col.spec.name)
            applied += 1
        if applied:
            col.cache.invalidate()
            col.cached_epoch = col.mutable.epoch
        return applied

    def _check_epoch(self, col: _Collection) -> None:
        """Invalidate the collection's cache if its index epoch moved
        outside this service's write path (direct ``compact()`` on the
        operator's MutableIndex handle)."""
        if col.mutable is not None and col.mutable.epoch != col.cached_epoch:
            col.cache.invalidate()
            col.cached_epoch = col.mutable.epoch

    def compact(self, name: str, retrain_codebooks: bool = False) -> None:
        """Epoch-swap one collection; its cache (and only its cache) is
        invalidated."""
        col = self._col(name)
        self._require_mutable(col).compact(retrain_codebooks=retrain_codebooks)
        self._check_epoch(col)

    def invalidate(self, name: str) -> int:
        """Manually clear one collection's result cache."""
        return self._col(name).cache.invalidate()

    # -- scheduling ----------------------------------------------------------

    def _charge(self, col: _Collection) -> None:
        """SCFQ virtual-time accounting: one micro-batch costs
        ``1/weight``; clamping the start to the service virtual time is
        what stops an idle tenant banking credit."""
        start = max(col.vtime, self._vtime)
        col.vtime = start + 1.0 / col.spec.weight
        self._vtime = start

    def _pick_ready(self, now: float):
        """The next (collection, t_bucket, full) to dispatch: among
        collections with a ready bucket (full batch, or oldest request
        past the deadline), the one with the smallest virtual time; full
        buckets beat deadline flushes within a collection."""
        best = None
        for col in self._collections.values():
            cands = []
            for tb, q in col.queues.items():
                if len(q) >= self.batch_size:
                    cands.append((True, len(q), -tb, tb))
                elif q and now - q[0].t_submit >= self.max_wait_s:
                    cands.append((False, len(q), -tb, tb))
            if not cands:
                continue
            full, _, _, tb = max(cands)
            if best is None or (col.vtime, col.spec.name) < (
                best[0].vtime,
                best[0].spec.name,
            ):
                best = (col, tb, full)
        return best

    def step(self) -> list[TenantResult]:
        """One scheduling round: apply every collection's queued writes,
        deliver pending cache hits, then dispatch ready micro-batches in
        weighted-fair order (at most ``max_batches_per_step`` when set).
        """
        for col in self._collections.values():
            if col.mutable is not None:
                self._apply_writes(col)
            self._check_epoch(col)
        done = self._drain_cache_served()
        now = self.clock()
        budget = self.max_batches_per_step or float("inf")
        while budget > 0:
            pick = self._pick_ready(now)
            if pick is None:
                break
            col, tb, full = pick
            done.extend(self._dispatch(col, tb, full))
            self._charge(col)
            budget -= 1
        self._publish_gauges()
        if self.monitor is not None:
            self.monitor.tick()
        return done

    def flush(self) -> list[TenantResult]:
        """Dispatch everything queued regardless of deadlines, still in
        weighted-fair order (drain)."""
        for col in self._collections.values():
            if col.mutable is not None:
                self._apply_writes(col)
            self._check_epoch(col)
        done = self._drain_cache_served()
        while True:
            ready = [
                (col, tb)
                for col in self._collections.values()
                for tb, q in col.queues.items()
                if q
            ]
            if not ready:
                break
            col = min(
                {c for c, _ in ready}, key=lambda c: (c.vtime, c.spec.name)
            )
            tbs = [tb for c, tb in ready if c is col]
            tb = max(tbs, key=lambda t: (len(col.queues[t]), -t))
            done.extend(
                self._dispatch(col, tb, full=len(col.queues[tb]) >= self.batch_size)
            )
            self._charge(col)
        self._publish_gauges()
        return done

    def run_until_idle(self) -> list[TenantResult]:
        done = self.step()
        done.extend(self.flush())
        return done

    def poll(self, rid: int) -> Optional[TenantResult]:
        return self._results.pop(rid, None)

    def pending(self) -> int:
        return sum(col.depth() for col in self._collections.values())

    def pending_writes(self) -> int:
        return sum(len(col.writes) for col in self._collections.values())

    def _drain_cache_served(self) -> list[TenantResult]:
        out = self._cache_served
        self._cache_served = []
        return out

    def _store(self, res: TenantResult) -> None:
        self._results[res.rid] = res
        while len(self._results) > self.result_buffer:
            self._results.popitem(last=False)

    # -- execution -----------------------------------------------------------

    def _record_compile(self, cache: str, shape: tuple) -> None:
        obs_events.emit("compile", cache=cache, shape=list(shape), wall_s=None)
        if obs_reg.enabled():
            obs_reg.registry().counter(
                "compass_compiles_total",
                "Search executable compilations",
                labelnames=("cache",),
            ).inc(cache=cache)

    def _dispatch(self, col: _Collection, t_bucket: int, full: bool) -> list[TenantResult]:
        name = col.spec.name
        index = col.index
        q = col.queues[t_bucket]
        jobs = [q.popleft() for _ in range(min(self.batch_size, len(q)))]
        B = self.batch_size
        n_fill = B - len(jobs)
        queries = np.zeros((B, index.dim), np.float32)
        for i, job in enumerate(jobs):
            queries[i] = job.query
        preds = [j.pred for j in jobs] + [P.never_true(index.n_attrs)] * n_fill
        pred = P.stack_predicates(preds, n_terms=t_bucket)
        qj = jnp.asarray(queries)

        t0 = self.clock()
        epoch = None
        st = col.stats.setdefault((B, t_bucket), BucketStats())
        if col.mutable is not None:
            snap = col.mutable.snapshot()
            epoch = snap.epoch
            # same key fields as SearchService's mutable path — tenants
            # whose shapes collapse share one entry here AND one compiled
            # program in the global mutable_search jit cache
            key = (B, t_bucket, pred.lo.shape[-1], col.params,
                   snap.index.n_records, snap.delta.cap)
            if key in self._mutable_shapes:
                st.n_cache_hits += 1
            else:
                self._mutable_shapes.add(key)
                st.n_compiles += 1
                self._record_compile(
                    "jit",
                    (B, t_bucket, pred.lo.shape[-1],
                     snap.index.n_records, snap.delta.cap),
                )
            with obs_prof.annotate(f"compass/serve_batch/B{B}xT{t_bucket}"):
                res = mutable_search(
                    snap.index, snap.base_gids, snap.delta, qj, pred, col.params
                )
                res.ids.block_until_ready()
        else:
            key = (B, t_bucket, pred.lo.shape[-1], col.params, _index_sig(index))
            exe = self._executables.get(key)
            if exe is None:
                exe = compass_search_jit.lower(index, qj, pred, col.params).compile()
                self._executables[key] = exe
                st.n_compiles += 1
                self._record_compile("aot", (B, t_bucket, pred.lo.shape[-1]))
            else:
                st.n_cache_hits += 1
            with obs_prof.annotate(f"compass/serve_batch/B{B}xT{t_bucket}"):
                res = exe(index, qj, pred)
                res.ids.block_until_ready()
        exec_s = self.clock() - t0

        st.n_requests += len(jobs)
        st.n_batches += 1
        st.n_fillers += n_fill
        st.n_full_flush += int(full)
        st.n_deadline_flush += int(not full)
        st.total_exec_s += exec_s
        modes = np.asarray(res.stats.mode)[: len(jobs)]
        st.n_mode_prefilter += int(np.sum(modes == plan_mod.PREFILTER))
        st.n_mode_cooperative += int(np.sum(modes == plan_mod.COOPERATIVE))
        st.n_mode_postfilter += int(np.sum(modes == plan_mod.POSTFILTER))

        if obs_reg.enabled():
            bname = f"B{B}xT{t_bucket}"
            lanes = len(jobs)
            sliced = jax.tree_util.tree_map(
                lambda a: np.asarray(a)[:lanes], res.stats
            )
            obs_reg.record_search_stats(
                sliced, labels={"bucket": bname, "tenant": name}
            )
            R = obs_reg.registry()
            R.counter(
                "compass_serve_requests_total", "Real requests served",
                labelnames=("bucket", "tenant"),
            ).inc(lanes, bucket=bname, tenant=name)
            R.counter(
                "compass_serve_batches_total", "Micro-batches dispatched",
                labelnames=("bucket", "tenant"),
            ).inc(bucket=bname, tenant=name)
            if n_fill:
                R.counter(
                    "compass_serve_fillers_total", "Padded filler lanes dispatched",
                    labelnames=("bucket", "tenant"),
                ).inc(n_fill, bucket=bname, tenant=name)
            R.histogram(
                "compass_serve_exec_seconds", "Micro-batch execution wall time",
                labelnames=("bucket", "tenant"), buckets=obs_reg.LATENCY_BUCKETS_S,
            ).observe(exec_s, bucket=bname, tenant=name)
            wait_h = R.histogram(
                "compass_serve_wait_seconds", "Per-request queue wait",
                labelnames=("bucket", "tenant"), buckets=obs_reg.LATENCY_BUCKETS_S,
            )
            for job in jobs:
                wait_h.observe(t0 - job.t_submit, bucket=bname, tenant=name)

        ids = np.asarray(res.ids)
        dists = np.asarray(res.dists)
        out = []
        for i, job in enumerate(jobs):
            wait = t0 - job.t_submit
            st.total_wait_s += wait
            r = TenantResult(
                rid=job.rid,
                collection=name,
                ids=ids[i, : job.k].copy(),
                dists=dists[i, : job.k].copy(),
                bucket=(B, t_bucket),
                queue_wait_s=wait,
                batch_exec_s=exec_s,
                epoch=epoch,
            )
            self._store(r)
            out.append(r)
            if job.exact_key is not None:
                # cache the engine's full-k row so the entry replays the
                # exact bytes the live path would have truncated from
                col.cache.insert(
                    job.exact_key, job.near_key,
                    ids[i].copy(), dists[i].copy(), epoch=epoch,
                )
        return out

    # -- observability -------------------------------------------------------

    def _publish_gauges(self) -> None:
        if not obs_reg.enabled():
            return
        R = obs_reg.registry()
        g_depth = R.gauge(
            "compass_queue_depth", "Queued requests per collection", ("tenant",)
        )
        g_limit = R.gauge(
            "compass_queue_limit", "Admission shed threshold per collection",
            ("tenant",),
        )
        g_entries = R.gauge(
            "compass_result_cache_entries", "Live result-cache entries",
            ("tenant", "tier"),
        )
        for name, col in self._collections.items():
            g_depth.set(col.depth(), tenant=name)
            g_limit.set(col.spec.max_queue_depth, tenant=name)
            ent = col.cache.stats()
            g_entries.set(ent["entries_exact"], tenant=name, tier="exact")
            g_entries.set(ent["entries_near"], tenant=name, tier="near")

    def enable_monitoring(self, **kwargs) -> "obs_health.Monitor":
        kwargs.setdefault("clock", self.clock)
        self.monitor = obs_health.Monitor(**kwargs)
        return self.monitor

    def health(self) -> "obs_health.HealthReport":
        if self.monitor is None:
            self.enable_monitoring()
        return self.monitor.evaluate()

    @property
    def compile_count(self) -> int:
        """Total XLA compilations == occupied shape keys across ALL
        collections (shared caches — never tenants x buckets)."""
        return len(self._executables) + len(self._mutable_shapes)

    def collection_stats(self, name: str) -> dict:
        """JSON-ready per-collection counters (plus the service-level
        compile accounting callers historically read off a
        SearchService: ``compiles`` / ``occupied_buckets``)."""
        col = self._col(name)
        n_req = sum(s.n_requests for s in col.stats.values())
        wait = sum(s.total_wait_s for s in col.stats.values())
        return {
            "collection": name,
            "weight": col.spec.weight,
            "max_queue_depth": col.spec.max_queue_depth,
            "compiles": self.compile_count,
            "occupied_buckets": len(col.stats),
            "pending": col.depth(),
            "n_submitted": col.n_submitted,
            "n_shed": col.n_shed,
            "n_requests": n_req + col.n_cache_served,
            "n_searched": n_req,
            "n_cache_served": col.n_cache_served,
            "n_batches": sum(s.n_batches for s in col.stats.values()),
            "n_fillers": sum(s.n_fillers for s in col.stats.values()),
            "mean_wait_s": wait / n_req if n_req else 0.0,
            "cache": col.cache.stats(),
            "mutable": col.mutable is not None,
            "epoch": None if col.mutable is None else col.mutable.epoch,
            "n_upserts": col.n_upserts,
            "n_deletes": col.n_deletes,
            "n_write_errors": col.n_write_errors,
            "quant": (
                None
                if col.params.quant is None
                else dataclasses.asdict(col.params.quant)
            ),
            "buckets": {
                f"B{b}xT{t}": dataclasses.asdict(s)
                for (b, t), s in sorted(col.stats.items())
            },
        }

    def stats(self) -> dict:
        """Service-wide snapshot: shared-cache accounting + every
        collection's section (disjoint by construction — the isolation
        the tenant label gives the registry, mirrored host-side)."""
        cols = {name: self.collection_stats(name) for name in sorted(self._collections)}
        return {
            "batch_size": self.batch_size,
            "max_wait_s": self.max_wait_s,
            "max_batches_per_step": self.max_batches_per_step,
            "compiles": self.compile_count,
            "occupied_shape_buckets": self.compile_count,
            "n_collections": len(self._collections),
            "n_requests": sum(c["n_requests"] for c in cols.values()),
            "n_submitted": sum(c["n_submitted"] for c in cols.values()),
            "n_shed": sum(c["n_shed"] for c in cols.values()),
            "n_cache_served": sum(c["n_cache_served"] for c in cols.values()),
            "obs_enabled": obs_reg.enabled(),
            "obs_events": dict(obs_events.EVENTS.counts()),
            "health": (
                None
                if self.monitor is None or self.monitor.last_report is None
                else self.monitor.last_report.to_dict()
            ),
            "collections": cols,
        }
