"""Multi-tenant collection serving: named collections, weighted-fair
admission, typed load shedding, shared executable caches and a two-tier
semantic result cache (DESIGN.md §Tenancy)."""
from .cache import CacheEntry, CollectionCache
from .service import (
    CollectionClient,
    CollectionService,
    CollectionSpec,
    Rejected,
    TenantResult,
)

__all__ = [
    "CacheEntry",
    "CollectionCache",
    "CollectionClient",
    "CollectionService",
    "CollectionSpec",
    "Rejected",
    "TenantResult",
]
