"""Two-tier semantic result cache, one instance per collection
(DESIGN.md §Tenancy).

Tier 1 (**exact**) keys on the request bytes themselves — query float32
bytes + lowered predicate interval bytes + requested ``k`` — so a hit
replays a previously computed engine result verbatim: bitwise identical
to re-running the search, because the cached entry *is* the engine
output for those exact inputs against the same index epoch.

Tier 2 (**near-duplicate**, opt-in) keys on the collection's own PQ
codes (``core.quant.encode.encode_rows`` against the index codebooks):
two queries that quantize to the same code word under *this
collection's* codebooks are close enough that serving one's result for
the other is an acceptable approximation.  Near hits are flagged in the
response (``TenantResult.cache_tier == "near"``) so callers can opt out
per request by ignoring them.  Keys embed the codebooks only implicitly
(each collection owns its cache object), so a code word can never match
across collections — isolation is structural, not probabilistic.

Invalidation contract: the owning :class:`CollectionService` clears the
whole cache whenever the collection's visible state can change — any
applied write (upsert/delete, including the auto-compaction a delta
overflow triggers) and any explicit epoch swap (``compact()``).  Entries
carry the epoch they were computed against purely as provenance; the
clear-on-write policy means a served hit always matches the current
epoch.  Whole-cache clearing is deliberately coarse: per-entry
re-validation would need to know which cached results a write could have
perturbed, which is the search problem itself.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class CacheEntry:
    """One cached engine result at the service's full ``params.k`` width
    (responses are served as the request's ``k``-prefix, same truncation
    rule as the live dispatch path)."""

    ids: np.ndarray  # (params.k,) int32
    dists: np.ndarray  # (params.k,) float32
    epoch: Optional[int]  # index epoch the result was computed against


class CollectionCache:
    """LRU exact tier + LRU near-duplicate tier for one collection.

    ``capacity`` bounds the exact tier; ``near_capacity`` bounds the
    near tier (0 disables it).  ``capacity == 0`` disables caching
    entirely — every lookup misses and inserts are dropped.
    """

    def __init__(self, capacity: int, near_capacity: int = 0):
        self.capacity = int(capacity)
        self.near_capacity = int(near_capacity)
        self._exact: OrderedDict[tuple, CacheEntry] = OrderedDict()
        self._near: OrderedDict[tuple, CacheEntry] = OrderedDict()
        self.hits_exact = 0
        self.hits_near = 0
        self.misses = 0
        self.insertions = 0
        self.invalidations = 0

    @property
    def enabled(self) -> bool:
        return self.capacity > 0

    def lookup(
        self, exact_key: tuple, near_key: Optional[tuple] = None
    ) -> tuple[Optional[CacheEntry], Optional[str]]:
        """``(entry, "exact"|"near")`` on a hit, ``(None, None)`` on a
        miss.  The exact tier always wins — a near hit is only consulted
        when the request bytes themselves are not cached."""
        if not self.enabled:
            return None, None
        e = self._exact.get(exact_key)
        if e is not None:
            self._exact.move_to_end(exact_key)
            self.hits_exact += 1
            return e, "exact"
        if near_key is not None and self.near_capacity > 0:
            e = self._near.get(near_key)
            if e is not None:
                self._near.move_to_end(near_key)
                self.hits_near += 1
                return e, "near"
        self.misses += 1
        return None, None

    def insert(
        self,
        exact_key: tuple,
        near_key: Optional[tuple],
        ids: np.ndarray,
        dists: np.ndarray,
        epoch: Optional[int] = None,
    ) -> None:
        if not self.enabled:
            return
        entry = CacheEntry(
            ids=np.asarray(ids).copy(), dists=np.asarray(dists).copy(), epoch=epoch
        )
        self._exact[exact_key] = entry
        self._exact.move_to_end(exact_key)
        while len(self._exact) > self.capacity:
            self._exact.popitem(last=False)
        if near_key is not None and self.near_capacity > 0:
            self._near[near_key] = entry
            self._near.move_to_end(near_key)
            while len(self._near) > self.near_capacity:
                self._near.popitem(last=False)
        self.insertions += 1

    def invalidate(self) -> int:
        """Clear both tiers; returns the number of entries dropped."""
        n = len(self._exact) + len(self._near)
        if n:
            self.invalidations += 1
        self._exact.clear()
        self._near.clear()
        return n

    def stats(self) -> dict:
        lookups = self.hits_exact + self.hits_near + self.misses
        return {
            "capacity": self.capacity,
            "near_capacity": self.near_capacity,
            "entries_exact": len(self._exact),
            "entries_near": len(self._near),
            "hits_exact": self.hits_exact,
            "hits_near": self.hits_near,
            "misses": self.misses,
            "hit_rate": (
                (self.hits_exact + self.hits_near) / lookups if lookups else 0.0
            ),
            "insertions": self.insertions,
            "invalidations": self.invalidations,
        }
