"""Batched request scheduler for serving (continuous batching, slot-based).

A fixed pool of ``n_slots`` decode slots runs in lockstep through the jitted
decode step (fixed shapes => one compiled program).  Requests queue up,
claim a free slot (prefill writes its KV segment), decode until EOS or
max_tokens, release the slot.  Per-slot position vectors handle ragged
sequence lengths; finished slots keep decoding into a scratch position
(masked out) until replaced — the standard fixed-shape continuous-batching
compromise.

Prefill is jitted over *bucketed* prompt lengths: prompts are right-padded
to the next power of two (min 8, capped at ``max_seq``), so arbitrary
ragged lengths compile O(log max_seq) programs instead of one per distinct
length.  The true length is a dynamic argument (selects the next-token
logit row); KV written for pad positions is never attended — the decode
mask is causal in cache position, and decode overwrites those positions
in order.  That argument only holds for attention caches: an SSM scan
folds every input token into its recurrent state, so configs with mamba
layers (``family == "ssm"`` or ``hybrid_period``) prefill at the exact
prompt length instead (one jitted compile per distinct length).

Works with any arch config; used by examples/serve_filtered_rag.py.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import forward, init_caches


def prefill_bucket(plen: int, max_seq: int, recurrent: bool = False) -> int:
    """Padded prompt length: next power of two (>= 8, <= max_seq).

    ``recurrent`` configs (SSM / hybrid) get the exact length — right-pad
    tokens would be scanned into the recurrent state and corrupt decode.
    """
    if plen > max_seq:
        raise ValueError(f"prompt length {plen} > max_seq {max_seq}")
    if recurrent:
        return plen
    return min(max(8, 1 << (plen - 1).bit_length()), max_seq)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (len,) int32
    max_tokens: int = 32
    eos_id: int = -1  # -1: never
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4, max_seq: int = 512):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.caches = init_caches(cfg, n_slots, max_seq)
        self.pos = np.zeros(n_slots, np.int32)  # next cache position per slot
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.queue: deque[Request] = deque()
        self.last_tok = np.zeros(n_slots, np.int32)

        def decode(params, tokens, caches, positions):
            # per-slot positions: run slots at their own cache_pos via vmap
            def one(p, tok, cache, pos):
                cache = jax.tree.map(lambda a: a[:, None], cache)  # batch dim
                logits, new_cache = forward(
                    p, cfg, tokens=tok[None, None], caches=cache, cache_pos=pos
                )
                new_cache = jax.tree.map(lambda a: a[:, 0], new_cache)
                return jnp.argmax(logits[0, -1]).astype(jnp.int32), new_cache

            # vmap over slots: cache leaves are (L, n_slots, ...) -> axis 1
            return jax.vmap(one, in_axes=(None, 0, 1, 0), out_axes=(0, 1))(
                params, tokens, caches, positions
            )

        self._decode = jax.jit(decode)

        def prefill(params, tokens, slot_caches, plen):
            # tokens: (1, L) right-padded to a bucket length; plen dynamic
            logits, new_caches = forward(
                params, cfg, tokens=tokens, caches=slot_caches, cache_pos=jnp.int32(0)
            )
            return jnp.argmax(logits[0, plen - 1]).astype(jnp.int32), new_caches

        # one compile per (bucket length,) thanks to jit's shape cache
        self._prefill = jax.jit(prefill)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.n_slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.popleft()
                # prefill into slot s: run the model over the bucket-padded
                # prompt with a single-slot cache view, then scatter it back
                slot_caches = jax.tree.map(lambda a: a[:, s : s + 1], self.caches)
                plen = len(req.prompt)
                recurrent = self.cfg.family == "ssm" or bool(self.cfg.hybrid_period)
                padded = np.zeros(prefill_bucket(plen, self.max_seq, recurrent), np.int32)
                padded[:plen] = req.prompt
                tok0, new_sc = self._prefill(
                    self.params, jnp.asarray(padded[None]), slot_caches, jnp.int32(plen)
                )
                self.caches = jax.tree.map(
                    lambda a, nsc: a.at[:, s : s + 1].set(nsc.astype(a.dtype)),
                    self.caches,
                    new_sc,
                )
                first = int(tok0)
                req.out_tokens.append(first)
                self.last_tok[s] = first
                self.pos[s] = plen
                self.slot_req[s] = req

    def step(self) -> None:
        """One lockstep decode over all active slots."""
        self._admit()
        active = [s for s in range(self.n_slots) if self.slot_req[s] is not None]
        if not active:
            return
        toks, caches = self._decode(
            self.params,
            jnp.asarray(self.last_tok),
            self.caches,
            jnp.asarray(self.pos),
        )
        self.caches = caches
        toks = np.asarray(toks)
        for s in active:
            req = self.slot_req[s]
            self.pos[s] += 1
            tok = int(toks[s])
            req.out_tokens.append(tok)
            self.last_tok[s] = tok
            if (
                len(req.out_tokens) >= req.max_tokens
                or tok == req.eos_id
                or self.pos[s] >= self.max_seq - 1
            ):
                req.done = True
                self.slot_req[s] = None

    def run_until_done(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.slot_req):
                return
            self.step()
