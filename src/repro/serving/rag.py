"""Filtered retrieval-augmented serving: Compass as a first-class serving
feature.

Pipeline (examples/serve_filtered_rag.py):
  1. corpus documents -> embeddings (mean-pooled hidden states of the LM)
  2. CompassIndex over (embedding, structured attrs) — e.g. price, date
  3. query -> embed -> CompassSearch with the request's predicate
  4. retrieved doc tokens prepended to the prompt -> continuous batcher

This is the "vector + structured data inside one serving system" use the
paper motivates (§I: "products similar to X but priced below $100").
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import predicate as P
from repro.core.index import BuildConfig, CompassIndex, build_index
from repro.core.engine import CompassParams, compass_search
from repro.models.model import forward
from repro.serving.search_service import SearchService
from repro.serving.tenancy import CollectionClient, CollectionService


def embed_tokens(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    """Mean-pooled final hidden state as the document/query embedding.

    Uses logits-free forward: we take the pre-head representation by
    running forward and pooling the final-norm output via the embedding
    trick (head application is linear; pooling logits would be wasteful).
    Here we simply pool the token embeddings transformed by the trunk:
    cheap and deterministic for the demo corpus.
    """
    logits, _ = forward(params, cfg, tokens=tokens)
    # pool pre-vocab by projecting back: use logits @ embed as a cheap proxy
    # is wasteful; instead pool the embedding table rows (stub-grade).
    emb = params["embed"][tokens]  # (B, S, d)
    return jnp.asarray(emb.mean(axis=1), jnp.float32)


@dataclasses.dataclass
class RagIndex:
    index: CompassIndex
    doc_tokens: np.ndarray  # (n_docs, doc_len)

    @classmethod
    def build(cls, params, cfg, doc_tokens: np.ndarray, doc_attrs: np.ndarray,
              build_cfg: BuildConfig = BuildConfig(m=8, nlist=8)):
        embs = np.asarray(embed_tokens(params, cfg, jnp.asarray(doc_tokens)))
        return cls(build_index(embs, doc_attrs, build_cfg), doc_tokens)

    def make_service(self, k: int = 4, ef: int = 16, backend: str = "auto",
                     collection: str = "docs",
                     service: CollectionService | None = None,
                     **service_kw) -> CollectionClient:
        """Register this index as a named collection on a multi-tenant
        :class:`CollectionService` and return the tenant handle — RAG
        callers get admission control, fair scheduling and the semantic
        result cache for free, through the same submit/poll surface the
        single-index ``SearchService`` exposed.

        Pass an existing ``service`` to co-host several RAG corpora
        (each a collection) behind one scheduler; by default a private
        service is created.  ``service_kw`` splits between the service
        constructor (batch_size, max_wait_s, ...) and the collection
        spec (weight, max_queue_depth, cache_capacity, near_cache).
        """
        spec_keys = ("weight", "max_queue_depth", "cache_capacity", "near_cache", "quant")
        spec_kw = {kk: service_kw.pop(kk) for kk in spec_keys if kk in service_kw}
        if service is None:
            service = CollectionService(
                CompassParams(k=k, ef=ef, backend=backend), **service_kw
            )
        elif service_kw:
            raise ValueError(
                f"service_kw {sorted(service_kw)} need a fresh service "
                "(the shared one is already constructed)"
            )
        return service.create(collection, self.index, **spec_kw)

    def retrieve(self, params, cfg, query_tokens: np.ndarray, pred: P.Predicate,
                 k: int = 2, ef: int = 16, backend: str = "auto",
                 service: "SearchService | CollectionClient | None" = None) -> np.ndarray:
        """Filtered retrieval for a batch of queries sharing one predicate.

        With ``service`` the queries go through the continuous-batching
        serving layer (shape-bucketed predicates, compiled-executable
        cache) and ``k`` truncates the service's ``params.k`` results;
        without it this is a direct one-shot ``compass_search``
        (``backend`` selects the engine's scoring path).  Service padding
        is result-neutral: responses match a direct call made with the
        service's ``CompassParams``.
        """
        q = embed_tokens(params, cfg, jnp.asarray(query_tokens))
        if service is not None:
            rids = [service.submit(np.asarray(q[b]), pred, k=k) for b in range(q.shape[0])]
            service.run_until_idle()
            return np.stack([service.poll(rid).ids for rid in rids])
        res = compass_search(
            self.index, q,
            P.Predicate(
                jnp.broadcast_to(pred.lo, (q.shape[0],) + pred.lo.shape),
                jnp.broadcast_to(pred.hi, (q.shape[0],) + pred.hi.shape),
            ),
            CompassParams(k=k, ef=ef, backend=backend),
        )
        return np.asarray(res.ids)  # (B, k), id == n_docs for padding


def augment_prompt(doc_tokens: np.ndarray, doc_ids: np.ndarray, prompt: np.ndarray) -> np.ndarray:
    """Prepend retrieved docs (that exist) to the prompt."""
    n_docs = doc_tokens.shape[0]
    parts = [doc_tokens[i] for i in doc_ids if i < n_docs]
    return np.concatenate(parts + [prompt]) if parts else prompt
