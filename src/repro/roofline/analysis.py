"""Roofline-term extraction from compiled dry-run artifacts (deliverable g).

Per (arch, shape, mesh):
  compute    = HLO_FLOPs_per_device / PEAK_FLOPS          [s]
  memory     = HLO_bytes_per_device / HBM_BW              [s]
  collective = link_bytes_per_device / LINK_BW            [s]

`compiled.cost_analysis()` is per-device after SPMD partitioning (verified
against hand-counts in tests/test_roofline.py).  collective bytes are not
in cost_analysis; we parse the partitioned HLO and charge each op its ring
cost:

  all-gather         : result bytes            ((n-1)/n * result received)
  reduce-scatter     : operand ~ n * result -> (n-1) * result
  all-reduce         : 2 * (n-1)/n * operand   (RS + AG)
  all-to-all         : (n-1)/n * result
  collective-permute : result bytes

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# Anchored: `%name = type[shape]{layout} <collective>(...` — the keyword must
# be the op itself, not an operand name inside a fusion call.
_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([\d,]*)\]\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device link bytes by collective kind from partitioned HLO."""
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.groups()
        if f" {kind}(" not in line and f"{kind}-start(" not in line and f"{kind}(" not in line:
            pass
        result_bytes = _shape_bytes(dtype, dims)
        gm = _GROUPS_RE.search(line)
        if gm:
            n = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            n = int(gi.group(2)) if gi else 2
        if n <= 1:
            continue
        frac = (n - 1) / n
        if kind == "all-gather":
            bytes_moved = result_bytes * frac
        elif kind == "reduce-scatter":
            bytes_moved = result_bytes * (n - 1)
        elif kind == "all-reduce":
            bytes_moved = 2 * result_bytes * frac
        elif kind == "all-to-all":
            bytes_moved = result_bytes * frac
        else:  # collective-permute
            bytes_moved = result_bytes
        out[kind] = out.get(kind, 0.0) + bytes_moved
        count[kind] = count.get(kind, 0) + 1
    return {
        "bytes_by_kind": out,
        "count_by_kind": count,
        "total_bytes": float(sum(out.values())),
    }


def extract_costs(compiled) -> dict:
    """Flat per-device cost dict for calibration arithmetic."""
    ca = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    out = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "coll_total": coll["total_bytes"],
    }
    for kind in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"):
        out[f"coll_{kind}"] = coll["bytes_by_kind"].get(kind, 0.0)
    return out


def model_flops(cfg, shape) -> float:
    """Analytic 'useful' FLOPs: 6*N*D train, 2*N*D forward (MoE: N_active)."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult * n_active * tokens)


def collect_cell_report(cfg, shape, lowered, compiled, meta: dict, calibrated: dict | None = None) -> dict:
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    if calibrated is not None:
        tot = calibrated["total"]
        flops = tot["flops"]
        bytes_accessed = tot["bytes"]
        coll_bytes = tot["coll_total"]
    else:
        flops = float(ca.get("flops", 0.0))
        bytes_accessed = float(ca.get("bytes accessed", 0.0))
        coll_bytes = coll["total_bytes"]
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_coll = coll_bytes / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    n_dev = 512 if meta.get("mesh", "").startswith("pod") else 256
    useful_ratio = mf / (flops * n_dev) if flops else 0.0

    mem_total = (
        ma.argument_size_in_bytes
        + ma.output_size_in_bytes
        + ma.temp_size_in_bytes
        - ma.alias_size_in_bytes
    )
    step_time = max(terms.values())
    out_calib = None
    if calibrated is not None:
        out_calib = {
            "k1": calibrated["k1"], "k2": calibrated["k2"],
            "per_layer": calibrated["per_layer"], "total": calibrated["total"],
            "raw_scanned_flops": float(ca.get("flops", 0.0)),
            "raw_scanned_bytes": float(ca.get("bytes accessed", 0.0)),
        }
    return {
        **meta,
        "calibration": out_calib,
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "total_bytes_per_device": int(mem_total),
            "fits_16gb_hbm": bool(mem_total < 16e9),
        },
        "cost": {
            "flops_per_device": flops,
            "bytes_per_device": bytes_accessed,
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        },
        "collectives": coll,
        "roofline": {
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "bound_step_time_s": step_time,
            "model_flops_total": mf,
            "useful_flops_ratio": useful_ratio,
            "roofline_fraction": (t_compute / step_time) if step_time else 0.0,
            "mfu_upper_bound": (mf / n_dev / PEAK_FLOPS) / step_time if step_time else 0.0,
        },
    }
