"""Assemble the §Dry-run / §Roofline tables from experiments/dryrun/*.json.

  PYTHONPATH=src python -m repro.roofline.report [--mesh 16x16] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def load_all(mesh: str | None = None) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def fmt_row(r: dict) -> str:
    if "skipped" in r:
        return (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | — | — | "
            f"skip: sub-quadratic only |"
        )
    rl = r["roofline"]
    mem = r["memory"]["total_bytes_per_device"] / 1e9
    fits = "y" if r["memory"]["fits_16gb_hbm"] else "**n**"
    note = ""
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
        f"{rl['t_compute_s']:.4f} | {rl['t_memory_s']:.4f} | "
        f"{rl['t_collective_s']:.4f} | {rl['dominant'][:4]} | "
        f"{rl['useful_flops_ratio']:.2f} | {mem:.1f}/{fits} | "
        f"{rl['mfu_upper_bound']:.3f} {note}|"
    )


HEADER = (
    "| arch | shape | mesh | T_comp (s) | T_mem (s) | T_coll (s) | dom | "
    "useful | GB/dev fits | MFU-UB |\n"
    "|---|---|---|---|---|---|---|---|---|---|"
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    recs = load_all(args.mesh)
    print(HEADER)
    for r in recs:
        print(fmt_row(r))
    done = [r for r in recs if "skipped" not in r]
    skipped = [r for r in recs if "skipped" in r]
    print(f"\n{len(done)} compiled cells, {len(skipped)} skips")


if __name__ == "__main__":
    main()
