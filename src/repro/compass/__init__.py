"""The Compass public API — one import surface for the whole system.

Everything a caller needs to build, query, mutate, serve and shard a
filtered-search index lives here, under stable names:

    from repro.compass import (
        build, search, Pred, BuildConfig, CompassParams, ShapePolicy,
        MutableIndex, SearchService, DistributedMutableIndex,
    )

    index = build(vectors, attrs, BuildConfig(metric="l2"))
    res = search(index, queries, Pred.all(Pred.attr(0).between(0.2, 0.8)),
                 CompassParams(k=10, planner=True))

Layer map (each name re-exported from its implementation module):

* **build / query** — ``build`` (:func:`repro.core.index.build_index`),
  ``search`` (:func:`repro.core.engine.compass_search`), ``BuildConfig``,
  ``CompassParams``, ``SearchResult`` / ``SearchStats``.
* **predicates** — ``Pred`` (host-side DNF builder), ``Predicate`` (the
  lowered ``(T, A)`` interval tensors), ``stack_predicates``.
* **shapes** — ``ShapePolicy``: the compiled-shape policy (row buckets
  across compaction folds, delta capacity, ef rounding, kernel block
  pins) shared by ``CompassParams``, ``MutableIndex`` and the serving
  executable-cache keys (DESIGN.md §Mutability, bucket-fold contract).
* **mutability** — ``MutableIndex`` (LSM delta + tombstones + compaction),
  ``Snapshot``.
* **quantization** — ``QuantConfig`` (training) / ``QuantParams``
  (search), ``quantize_index``.
* **serving** — ``SearchService`` (continuous batching, AOT executable
  cache), ``ServiceResult``.
* **tenancy** — ``CollectionService`` (named collections behind one
  weighted-fair front door: per-tenant admission queues, typed
  ``Rejected`` load shedding, cross-tenant executable-cache sharing and
  a two-tier semantic result cache), ``CollectionSpec`` (per-collection
  weight / queue depth / cache policy), ``TenantResult``.
* **distributed** — ``DistributedMutableIndex`` (owner-routed mutable
  shards), ``build_sharded_index`` / ``make_distributed_search`` (static
  shard_map fan-out).
* **observability** — ``search(..., explain=True)`` returns ``(result,
  traces)`` where each :class:`QueryTrace` carries the planner's estimate
  vs the measured selectivity, the chosen mode, work counters and the
  kernel route; ``DistributedMutableIndex.search(..., explain=True)``
  returns :class:`ShardedQueryTrace` records adding the per-shard
  breakdown; ``explain`` renders either.  The metrics registry, event
  log, profiling hooks and the continuous-monitoring layer (timeseries
  ring, SLO burn rates, health watchdogs, ``python -m repro.obs.report``)
  live in :mod:`repro.obs`; ``SearchService.health()`` surfaces the
  watchdog verdicts for a live service.

Engine internals (queues, iterators, backends) intentionally stay out:
import them from :mod:`repro.core.engine`.  The legacy
``repro.core.search`` shim is deprecated and re-exports a subset of this
surface with a ``DeprecationWarning``.
"""
from __future__ import annotations

from repro.core.distributed import (
    DistributedMutableIndex,
    build_sharded_index,
    make_distributed_search,
)
from repro.core.engine import (
    ENGINE_VERSION,
    CompassParams,
    SearchResult,
    SearchStats,
    ShapePolicy,
    compass_search,
)
from repro.core.index import BuildConfig, CompassIndex, build_index
from repro.core.mutable import MutableIndex, Snapshot
from repro.core.predicate import Pred, Predicate, stack_predicates
from repro.core.quant import QuantConfig, QuantParams
from repro.core.quant.encode import quantize_index
from repro.obs import QueryTrace, ShardedQueryTrace, explain
from repro.serving.search_service import SearchService, ServiceResult
from repro.serving.tenancy import (
    CollectionClient,
    CollectionService,
    CollectionSpec,
    Rejected,
    TenantResult,
)

# the canonical short names; the long forms stay available for callers
# migrating mechanically from repro.core.* imports
build = build_index
search = compass_search

__all__ = [
    "ENGINE_VERSION",
    "BuildConfig",
    "CollectionClient",
    "CollectionService",
    "CollectionSpec",
    "CompassIndex",
    "CompassParams",
    "DistributedMutableIndex",
    "MutableIndex",
    "Pred",
    "Predicate",
    "QuantConfig",
    "QuantParams",
    "QueryTrace",
    "Rejected",
    "SearchResult",
    "SearchService",
    "SearchStats",
    "ServiceResult",
    "ShapePolicy",
    "TenantResult",
    "ShardedQueryTrace",
    "Snapshot",
    "build",
    "build_index",
    "build_sharded_index",
    "compass_search",
    "explain",
    "make_distributed_search",
    "quantize_index",
    "search",
    "stack_predicates",
]
