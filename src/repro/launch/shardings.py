"""Sharding rules: parameters (FSDP x TP x EP), caches, and batches.

Strategy (DESIGN.md §Distribution):
  * 2D weight sharding: the "parallel" output dim of each projection goes to
    'model' (TP), the other big dim to 'data' (FSDP/ZeRO-3 — XLA inserts the
    per-layer all-gathers; with lax.scan these happen once per layer step).
  * MoE experts shard across 'model' (EP); within-expert dims take 'data'.
  * Vocab: embed rows / head columns on 'model' so the (B,S,V) logits are
    vocab-sharded (cross-entropy reduces with an all-reduce, never
    materializing replicated 256k-wide logits).
  * KV caches: batch on data axes; for long contexts the *sequence* axis is
    sharded (sequence-parallel flash-decoding: XLA turns the masked softmax
    reductions into all-reduces over the shard axis).
  * Divisibility guard: any dim not divisible by its mesh axis falls back to
    replicated on that axis (e.g. yi-34b's 56 heads on a 16-way model axis
    shard fine at the weight level because 7168 % 16 == 0, but odd-sized
    dims like vocab 49155 must drop the constraint).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

from .mesh import data_axes


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= _axis_size(mesh, a)
        return out
    return mesh.shape[axis] if axis in mesh.axis_names else 0


def _guard(mesh, spec: P, shape: tuple) -> P:
    """Drop partitions that don't divide or whose axis is absent."""
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axis is None:
            out.append(None)
            continue
        size = _axis_size(mesh, axis)
        if size == 0 or size == 1 or dim % size != 0:
            # try single-axis fallback for composite axes
            if isinstance(axis, tuple):
                picked = None
                for a in axis:
                    s = _axis_size(mesh, a)
                    if s > 1 and dim % s == 0:
                        picked = a
                        break
                out.append(picked)
            else:
                out.append(None)
        else:
            out.append(axis)
    return P(*out)


def _ns(mesh, spec: P, shape: tuple) -> NamedSharding:
    return NamedSharding(mesh, _guard(mesh, spec, shape))


def param_shardings(params: Any, cfg: ModelConfig, mesh, mode: str = "fsdp") -> Any:
    """PartitionSpec tree matching the param tree, by name + rank.

    mode="fsdp": 2D (data x model) sharding — training/prefill (params are
      re-gathered per layer; optimizer state shards alongside).
    mode="tp": weight-stationary full tensor parallelism over ALL axes —
      decode (§Perf hillclimb: FSDP decode re-gathers every weight every
      token step; TP keeps weights resident and only all-reduces small
      activations)."""
    da = data_axes(mesh)
    if mode == "tp":
        tp_axis = tuple(da) + ("model",)
        return _tp_param_shardings(params, cfg, mesh, tp_axis)
    fsdp = da[-1] if da else None  # 'data'

    def rule(path, leaf):
        names = [str(getattr(p, "key", "")) for p in path]
        name = names[-1]
        shape = leaf.shape
        r = len(shape)
        stacked = any(n.endswith("_layers") or n == "layers" for n in names)
        lead = (None,) if stacked else ()

        def spec(*core):
            core = core[: r - len(lead)]
            return _ns(mesh, P(*lead, *core), shape)

        # embed (V, D): vocab over data (FSDP), D over model — the gather
        # output is then D-sharded, matching the activation layout with no
        # resharding (avoids SPMD "involuntary full rematerialization").
        if name in ("embed",):
            return _ns(mesh, P(fsdp, "model"), shape)
        if name in ("head",):
            return _ns(mesh, P(fsdp, "model"), shape)
        if name in ("frontend_proj",):
            return _ns(mesh, P(fsdp, "model"), shape)
        # expert weights: (L?, E, din, dout)
        if "experts" in names:
            if name == "wo":
                return spec("model", fsdp, None)
            return spec("model", None, fsdp)
        if name == "router":
            return spec(fsdp, None)
        # attention / mlp 2D weights
        if name in ("wq", "wk", "wv", "wi", "wg", "wdkv", "in_proj"):
            return spec(fsdp, "model")
        if name in ("wuk", "wuv"):
            return spec(fsdp, "model")
        if name in ("wo", "out_proj"):
            return spec("model", fsdp)
        if name == "conv_w":  # (L?, K, C)
            return spec(None, "model")
        # 1D: norms, biases, A_log, D, dt_bias, conv_b
        if r - len(lead) == 1:
            return spec(None)
        return spec(*([None] * (r - len(lead))))

    paths = jax.tree_util.tree_flatten_with_path(params)[0]
    leaves = [rule(p, l) for p, l in paths]
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(params), leaves)


def _tp_param_shardings(params: Any, cfg: ModelConfig, mesh, tp_axis) -> Any:
    """Full tensor parallelism: every big weight sharded over the combined
    axis on its parallel dim; contracting-dim weights (wo/out_proj) shard
    the contraction (output all-reduce).  1D params replicate."""

    def rule(path, leaf):
        names = [str(getattr(p, "key", "")) for p in path]
        name = names[-1]
        shape = leaf.shape
        r = len(shape)
        stacked = any(n.endswith("_layers") or n == "layers" for n in names)
        lead = (None,) if stacked else ()

        def spec(*core):
            core = core[: r - len(lead)]
            return _ns(mesh, P(*lead, *core), shape)

        if name in ("embed", "head", "frontend_proj"):
            return _ns(mesh, P(None, tp_axis), shape)
        if "experts" in names:
            if name == "wo":
                return spec("model", None, None)
            return spec("model", None, None)
        if name == "router":
            return spec(None, None)
        if name in ("wq", "wk", "wv", "wi", "wg", "wdkv", "wuk", "wuv", "in_proj"):
            return spec(None, tp_axis)
        if name in ("wo", "out_proj"):
            return spec(tp_axis, None)
        if name == "conv_w":
            return spec(None, tp_axis)
        return spec(*([None] * (r - len(lead))))

    paths = jax.tree_util.tree_flatten_with_path(params)[0]
    leaves = [rule(p, l) for p, l in paths]
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(params), leaves)


def batch_shardings(cfg: ModelConfig, mesh, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct-compatible shardings for step inputs."""
    da = data_axes(mesh)
    bspec = da if len(da) > 1 else (da[0] if da else None)
    out = {}
    if shape.kind == "train":
        out["tokens"] = _ns(mesh, P(bspec, None), (shape.global_batch, shape.seq_len))
        out["labels"] = _ns(mesh, P(bspec, None), (shape.global_batch, shape.seq_len))
    return out


def cache_shardings(cache_tree: Any, cfg: ModelConfig, mesh, shape: ShapeConfig) -> Any:
    """Decode-cache shardings.

    decode_32k: batch on data axes, kv sequence on 'model' (flash-decoding).
    long_500k (batch 1): sequence sharded over ('data','model') jointly.
    """
    da = data_axes(mesh)
    bspec = da if len(da) > 1 else (da[0] if da else None)
    long_ctx = shape.global_batch < _axis_size(mesh, bspec)

    seq_axes = (
        ((bspec, "model") if isinstance(bspec, str) else tuple(bspec) + ("model",))
        if bspec
        else "model"
    )

    def rule(path, leaf):
        names = [str(getattr(p, "key", "")) for p in path]
        name = names[-1]
        shape_ = leaf.shape
        if name in ("k", "v"):  # (L, B, S, KV, hd)
            if long_ctx or shape.kind == "decode":
                # weight-stationary decode: cache seq over ALL axes (batch
                # stays whole — the data axes are spent on TP)
                return _ns(mesh, P(None, None, seq_axes, None, None), shape_)
            return _ns(mesh, P(None, bspec, "model", None, None), shape_)
        if name in ("c_kv", "k_pe"):  # (L, B, S, r)
            if long_ctx or shape.kind == "decode":
                return _ns(mesh, P(None, None, seq_axes, None), shape_)
            return _ns(mesh, P(None, bspec, "model", None), shape_)
        if name == "conv":  # (L, B, K-1, C)
            return _ns(mesh, P(None, bspec, None, "model"), shape_)
        if name == "ssm":  # (L, B, H, P, N)
            return _ns(mesh, P(None, bspec, "model", None, None), shape_)
        return _ns(mesh, P(*([None] * len(shape_))), shape_)

    paths = jax.tree_util.tree_flatten_with_path(cache_tree)[0]
    leaves = [rule(p, l) for p, l in paths]
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(cache_tree), leaves)


def opt_state_shardings(opt_state, param_shards):
    """m/v shard exactly like their parameter; step is replicated."""
    mesh = jax.tree_util.tree_leaves(param_shards)[0].mesh
    return type(opt_state)(
        NamedSharding(mesh, P()),
        param_shards,
        param_shards,
    )
