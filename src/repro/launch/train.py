"""Training launcher: data -> step -> checkpoint -> watchdog, restartable.

CPU-scale driver used by examples/train_lm.py and the fault-tolerance
tests; the same loop drives the production mesh (the jitted step and the
checkpoint/restore path are mesh-agnostic).

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import latest_steps, restore, save
from repro.configs import get_config, reduced
from repro.data.synthetic import DataConfig, SyntheticTokens
from repro.ft.watchdog import StepWatchdog, WatchdogConfig, loss_is_poisoned
from repro.models.model import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train.step import TrainConfig, make_train_step


def train_loop(
    cfg,
    *,
    steps: int,
    global_batch: int,
    seq_len: int,
    ckpt_dir: str | None = None,
    ckpt_every: int = 20,
    seed: int = 0,
    lr: float = 3e-4,
    n_microbatches: int = 1,
    log=print,
):
    data = SyntheticTokens(DataConfig(cfg.vocab_size, seq_len, global_batch, seed=seed))
    tc = TrainConfig(
        optimizer=AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 5), total_steps=steps),
        n_microbatches=n_microbatches,
        remat=False,
    )
    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))

    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params)
    start = 0
    if ckpt_dir and latest_steps(ckpt_dir):
        (params, opt_state), start = restore(ckpt_dir, (params, opt_state))
        log(f"restored checkpoint at step {start}")

    wd = StepWatchdog(WatchdogConfig())
    losses = []
    for step in range(start, steps):
        batch = data.batch(step)
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        wd.start_step()
        params, opt_state, metrics = step_fn(params, opt_state, jb)
        loss = float(metrics["loss"])
        straggler = wd.end_step(step)
        losses.append(loss)
        if loss_is_poisoned(loss):
            if not ckpt_dir or not latest_steps(ckpt_dir):
                raise RuntimeError(f"non-finite loss at step {step}, no checkpoint")
            (params, opt_state), rollback = restore(ckpt_dir, (params, opt_state))
            log(f"NaN at step {step}: rolled back to {rollback}, skipping batch")
            continue
        if step % max(1, steps // 20) == 0 or step == steps - 1:
            log(
                f"step {step}: loss={loss:.4f} gnorm={float(metrics['grad_norm']):.3f}"
                + (" [straggler]" if straggler else "")
            )
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            save(ckpt_dir, step + 1, (params, opt_state))
    if ckpt_dir:
        save(ckpt_dir, steps, (params, opt_state))
    return params, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    t0 = time.time()
    _, losses = train_loop(
        cfg,
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        ckpt_dir=args.ckpt_dir,
        n_microbatches=args.microbatches,
    )
    print(
        f"done in {time.time()-t0:.0f}s: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
        f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})"
    )


if __name__ == "__main__":
    main()
