import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any other import: jax locks the device count on first init.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell, and for the Compass
distributed-search step, this lowers + compiles the sharded step on the
production mesh — (16,16) single-pod and (2,16,16) = 512-chip multi-pod —
and records memory_analysis / cost_analysis / the collective schedule into
experiments/dryrun/*.json for §Roofline.

Cost calibration: XLA's HloCostAnalysis counts a while-loop body ONCE, so a
scanned L-layer stack under-reports flops/bytes/collectives by ~L x.  Each
cell is therefore lowered twice more at small depths k1 < k2 with the layer
scan *unrolled* and nm=1, giving per-layer costs by finite difference:
    per_layer = (C(k2) - C(k1)) / (k2 - k1)
    total     = C(k1) + (L - k1) * per_layer        (exact for homogeneous
stacks; ~5% approximation for zamba2's trailing mamba layers).  The real
scanned artifact still provides memory_analysis + compile-success + the
collective schedule shape.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod | --both-meshes]
  PYTHONPATH=src python -m repro.launch.dryrun --compass
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, all_configs, get_config, shape_applicable  # noqa: E402
from repro.launch import input_specs as ispec  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.roofline.analysis import collect_cell_report, extract_costs  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _ep_context(cfg, shape, mesh):
    """Expert-parallel context where applicable (MoE + divisible seq)."""
    from repro.launch.mesh import data_axes
    from repro.models.moe import EPContext

    if not cfg.moe or shape.kind == "decode":
        return None
    if shape.seq_len % mesh.shape.get("model", 1):
        return None
    return EPContext(batch_axes=data_axes(mesh))


def _lower(cfg, shape, mesh, specs, *, unroll=False, force_nm=None, use_ep=True):
    ep = _ep_context(cfg, shape, mesh) if use_ep else None
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            from repro.optim.adamw import AdamWConfig
            from repro.train.step import TrainConfig, make_train_step

            tc = TrainConfig(
                optimizer=AdamWConfig(),
                n_microbatches=force_nm or specs["n_microbatches"],
                remat=True,
                unroll=unroll,
                act_sharding=specs["act_sharding"],
                ep=ep,
            )
            step = make_train_step(cfg, tc)
            fn = jax.jit(
                step,
                in_shardings=(
                    specs["param_shardings"],
                    specs["opt_shardings"],
                    specs["batch_shardings"],
                ),
                out_shardings=(specs["param_shardings"], specs["opt_shardings"], None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(specs["params"], specs["opt_state"], specs["batch"])
        elif shape.kind == "prefill":
            from repro.serving.step import make_prefill_step

            pf = make_prefill_step(cfg, act_sharding=specs["act_sharding"], unroll=unroll, ep=ep)
            fn = jax.jit(
                pf,
                in_shardings=(
                    specs["param_shardings"],
                    specs["batch_shardings"],
                ),
                out_shardings=(None, specs["cache_shardings"]),
            )
            lowered = fn.lower(specs["params"], specs["batch"])
        else:
            from repro.serving.step import make_decode_step

            dec = make_decode_step(cfg, unroll=unroll)
            fn = jax.jit(
                dec,
                in_shardings=(
                    specs["param_shardings"],
                    specs["token_shardings"],
                    specs["cache_shardings"],
                    None,
                ),
                out_shardings=(None, None, specs["cache_shardings"]),
                donate_argnums=(2,),
            )
            lowered = fn.lower(
                specs["params"], specs["tokens"], specs["caches"], specs["cache_pos"]
            )
        return lowered


def _calibration_depths(cfg) -> tuple[int, int]:
    if cfg.hybrid_period:
        return cfg.hybrid_period, 2 * cfg.hybrid_period
    if cfg.moe and cfg.moe.first_dense:
        return cfg.moe.first_dense + 1, cfg.moe.first_dense + 2
    return 1, 2


def calibrate_costs(cfg, shape, mesh, bf16_weights: bool = False) -> dict:
    """Two-point finite-difference extrapolation of per-device costs."""
    k1, k2 = _calibration_depths(cfg)
    costs = {}
    for k in (k1, k2):
        c = dataclasses.replace(cfg, n_layers=k)
        specs = ispec.input_specs(c, shape, mesh, bf16_weights=bf16_weights)
        lowered = _lower(c, shape, mesh, specs, unroll=True, force_nm=1)
        compiled = lowered.compile()
        costs[k] = extract_costs(compiled)
    per_layer = {
        # clamp: XLA occasionally optimizes the k1 program differently
        # (e.g. fusing away a collective), which would extrapolate negative
        key: max((costs[k2][key] - costs[k1][key]) / (k2 - k1), 0.0)
        for key in costs[k1]
    }
    total = {
        key: costs[k1][key] + (cfg.n_layers - k1) * per_layer[key] for key in costs[k1]
    }
    return {
        "k1": k1,
        "k2": k2,
        "c_k1": costs[k1],
        "per_layer": per_layer,
        "total": total,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             bf16_weights: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "16x16"
    if not shape_applicable(cfg, shape):
        if verbose:
            print(f"SKIP {arch} x {shape_name}: full attention at 500k (DESIGN.md §Skips)")
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "skipped": "long_500k requires sub-quadratic sequence mixing",
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    specs = ispec.input_specs(cfg, shape, mesh, bf16_weights=bf16_weights)
    t0 = time.time()
    lowered = _lower(cfg, shape, mesh, specs)
    t_lower = time.time() - t0
    t0 = time.time()
    with jax.set_mesh(mesh):
        compiled = lowered.compile()
    t_compile = time.time() - t0
    meta = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "kind": shape.kind,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
    }
    if shape.kind == "train":
        meta["n_microbatches"] = specs["n_microbatches"]
    calib = calibrate_costs(cfg, shape, mesh, bf16_weights=bf16_weights)
    rec = collect_cell_report(cfg, shape, lowered, compiled, meta, calibrated=calib)
    if verbose:
        ma, rl = rec["memory"], rec["roofline"]
        print(
            f"OK {arch} x {shape_name} [{mesh_name}] "
            f"compile={meta['t_compile_s']}s mem/dev={ma['total_bytes_per_device']/1e9:.2f}GB "
            f"Tc={rl['t_compute_s']:.4f}s Tm={rl['t_memory_s']:.4f}s "
            f"Tcoll={rl['t_collective_s']:.4f}s dom={rl['dominant']} "
            f"useful={rl['useful_flops_ratio']:.2f} mfu_ub={rl['mfu_upper_bound']:.2f}",
            flush=True,
        )
    return rec


def run_compass(multi_pod: bool, verbose: bool = True) -> dict:
    """Distributed Compass filtered-search dry-run (the paper's own step):
    corpus sharded over all devices, per-shard search, global top-k merge."""
    from repro.core.distributed import abstract_distributed_search

    mesh = make_production_mesh(multi_pod=multi_pod)
    return abstract_distributed_search(mesh, verbose=verbose)


def save(rec: dict) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json".replace("/", "_")
    with open(os.path.join(OUT_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--compass", action="store_true")
    ap.add_argument("--start-from", default=None)
    ap.add_argument("--bf16-params", action="store_true",
                    help="store >=2D weights bf16 (hillclimb variant; "
                         "records land in *_bf16.json)")
    args = ap.parse_args()

    if args.compass:
        for mp in ([False, True] if args.both_meshes else [args.multipod]):
            save(run_compass(mp))
        return

    failures = []
    if args.all:
        archs = sorted(all_configs().keys())
        if args.start_from:
            archs = archs[archs.index(args.start_from) :]
        for arch in archs:
            for shape_name in SHAPES:
                for mp in [False, True] if args.both_meshes else [args.multipod]:
                    try:
                        save(run_cell(arch, shape_name, mp))
                    except Exception as e:  # noqa: BLE001
                        traceback.print_exc()
                        failures.append((arch, shape_name, mp, repr(e)))
        if failures:
            print("FAILURES:")
            for f in failures:
                print(" ", f)
            raise SystemExit(1)
        print("all cells OK")
        return

    rec = run_cell(args.arch, args.shape, args.multipod, bf16_weights=args.bf16_params)
    if args.bf16_params:
        rec["variant"] = "bf16_params"
        rec["shape"] = rec["shape"] + "_bf16"
    save(rec)


if __name__ == "__main__":
    main()
