"""Production mesh construction.

Axes:
  pod   — inter-pod data parallelism (gradient all-reduce crosses the slow
          inter-pod links; see optim.compression for the int8 path)
  data  — intra-pod data parallelism + FSDP parameter/optimizer sharding
  model — tensor / expert / sequence-parallel axis (fast ICI ring)

A function, not a module-level constant: importing this module must never
touch jax device state (dryrun.py sets XLA_FLAGS *before* first jax use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (same axis names)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2
    )


def data_axes(mesh) -> tuple:
    """Axes that shard the batch: ('pod','data') when pod exists."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
