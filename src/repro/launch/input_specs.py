"""ShapeDtypeStruct stand-ins for every dry-run cell: weak-type-correct,
shardable, zero allocation.

For each (arch, shape) cell this module produces the abstract arguments the
lowered step consumes:
  train   : (params, opt_state, batch{tokens, labels[, prefix/frame embeds]})
  prefill : (params, batch, empty caches)
  decode  : (params, tokens|frame, caches @ seq_len, cache_pos)
plus the matching NamedShardings from launch.shardings.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import init_caches, init_params
from repro.optim.adamw import init_opt_state

from .mesh import data_axes
from .shardings import (
    _ns,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
)


def _sds(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def abstract_params(cfg: ModelConfig, bf16_weights: bool = False):
    """bf16_weights: store >=2D weights in bf16 (f32 master-less training
    with f32 moments — §Perf hillclimb: halves param memory, param HBM
    reads, and FSDP all-gather bytes)."""
    ap = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    if not bf16_weights:
        return ap
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16 if l.ndim >= 2 else l.dtype),
        ap,
    )


def abstract_opt_state(aparams):
    return jax.eval_shape(init_opt_state, aparams)


def token_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> tuple[dict, dict]:
    """(abstract batch, shardings) for a train batch."""
    da = data_axes(mesh)
    bspec = da if len(da) > 1 else (da[0] if da else None)
    gb, s = shape.global_batch, shape.seq_len
    batch: dict[str, Any] = {}
    shard: dict[str, Any] = {}
    if cfg.embed_inputs and cfg.frontend != "frame":
        batch["tokens"] = jax.ShapeDtypeStruct((gb, s), jnp.int32)
        shard["tokens"] = _ns(mesh, P(bspec, None), (gb, s))
    else:  # audio stub: precomputed frame embeddings
        batch["inputs_embeds"] = jax.ShapeDtypeStruct((gb, s, cfg.d_model), jnp.bfloat16)
        shard["inputs_embeds"] = _ns(mesh, P(bspec, None, None), (gb, s, cfg.d_model))
    if cfg.frontend == "patch":
        batch["prefix_embeds"] = jax.ShapeDtypeStruct((gb, cfg.n_prefix, cfg.d_model), jnp.bfloat16)
        shard["prefix_embeds"] = _ns(mesh, P(bspec, None, None), (gb, cfg.n_prefix, cfg.d_model))
    batch["labels"] = jax.ShapeDtypeStruct((gb, s), jnp.int32)
    shard["labels"] = _ns(mesh, P(bspec, None), (gb, s))
    return batch, shard


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    da = data_axes(mesh)
    bspec = da if len(da) > 1 else (da[0] if da else None)
    gb = shape.global_batch
    if cfg.embed_inputs:
        tok = jax.ShapeDtypeStruct((gb, 1), jnp.int32)
        shard = _ns(mesh, P(bspec, None), (gb, 1))
    else:
        tok = jax.ShapeDtypeStruct((gb, 1, cfg.d_model), jnp.bfloat16)
        shard = _ns(mesh, P(bspec, None, None), (gb, 1, cfg.d_model))
    return tok, shard


def abstract_caches(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(lambda: init_caches(cfg, shape.global_batch, shape.seq_len))


def pick_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh, tokens_budget: int = 8192) -> int:
    """Choose grad-accumulation microbatches so per-device live activation
    tokens per microbatch stay near the budget (§Perf memory knob)."""
    da = data_axes(mesh)
    n_data = 1
    for a in da:
        n_data *= mesh.shape[a]
    per_dev_batch = max(1, shape.global_batch // n_data)
    per_dev_tokens = per_dev_batch * shape.seq_len
    nm = max(1, math.ceil(per_dev_tokens / tokens_budget))
    nm = min(nm, per_dev_batch)
    # nm must divide global batch
    while shape.global_batch % nm:
        nm -= 1
    return max(1, nm)


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, mesh,
    bf16_weights: bool = False, decode_tp: bool = True,
) -> dict:
    """Everything dryrun.py needs for one cell.

    decode steps default to weight-stationary TP (decode_tp) and bf16
    weights — inference has no optimizer so FSDP buys nothing and costs a
    full param re-gather per token (§Perf)."""
    if shape.kind == "decode":
        bf16_weights = True
    aparams = abstract_params(cfg, bf16_weights)
    mode = "tp" if (shape.kind == "decode" and decode_tp) else "fsdp"
    p_shard = param_shardings(aparams, cfg, mesh, mode=mode)
    out = {"params": aparams, "param_shardings": p_shard}
    da = data_axes(mesh)
    bspec = da if len(da) > 1 else (da[0] if da else None)
    # sequence-parallel residuals between layers (norms stay local on D);
    # decode has s == 1, so no activation constraint there
    if shape.kind in ("train", "prefill") and shape.seq_len % max(mesh.shape.get("model", 1), 1) == 0:
        out["act_sharding"] = NamedSharding(mesh, P(bspec, "model", None))
    else:
        out["act_sharding"] = None

    if shape.kind == "train":
        aopt = abstract_opt_state(aparams)
        out["opt_state"] = aopt
        out["opt_shardings"] = opt_state_shardings(aopt, p_shard)
        batch, bshard = token_batch_specs(cfg, shape, mesh)
        out["batch"] = batch
        out["batch_shardings"] = bshard
        out["n_microbatches"] = pick_microbatches(cfg, shape, mesh)
    elif shape.kind == "prefill":
        batch, bshard = token_batch_specs(cfg, shape, mesh)
        batch.pop("labels")
        bshard.pop("labels")
        out["batch"] = batch
        out["batch_shardings"] = bshard
        ac = abstract_caches(cfg, shape)
        out["caches"] = ac
        out["cache_shardings"] = cache_shardings(ac, cfg, mesh, shape)
    else:  # decode
        tok, tshard = decode_token_specs(cfg, shape, mesh)
        out["tokens"] = tok
        out["token_shardings"] = tshard
        ac = abstract_caches(cfg, shape)
        out["caches"] = ac
        out["cache_shardings"] = cache_shardings(ac, cfg, mesh, shape)
        out["cache_pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out
